//! SmolVLM low-power validation (§4.12, Table 19): the same RL
//! formulation, low-power profile (weights 0.2/0.6/0.2, 10 MHz clock,
//! INT4+windowed KV), across all 7 process nodes.
//!
//! Usage: cargo run --release --example smolvlm_lowpower [-- key=value ...]
//!   defaults: all 7 nodes, 400 episodes/node.

use std::path::Path;

use silicon_rl::config::RunConfig;
use silicon_rl::error::{Error, Result};
use silicon_rl::nn::backend;
use silicon_rl::report::{self, NodeSummary};
use silicon_rl::rl::{self, SacAgent};
use silicon_rl::util::Rng;

fn main() -> Result<()> {
    let mut cfg = RunConfig::smolvlm_low_power();
    cfg.rl.episodes_per_node = 400;
    cfg.rl.warmup_steps = 256;
    cfg.out_dir = "out/smolvlm_lowpower".into();
    for a in std::env::args().skip(1) {
        if let Some((k, v)) = a.split_once('=') {
            cfg.apply(k, v).map_err(Error::msg)?;
        }
    }

    let be = backend::load(&cfg.artifacts_dir, cfg.backend)?;
    let mut rng = Rng::new(cfg.seed);
    let mut agent = SacAgent::new(be, cfg.rl, &mut rng)?;

    let out_dir = Path::new(&cfg.out_dir);
    std::fs::create_dir_all(out_dir)?;
    println!("SmolVLM low-power sweep ({} episodes/node)\n", cfg.rl.episodes_per_node);
    println!(
        "{:>5} {:>7} {:>6} {:>9} {:>9} {:>7} {:>7} {:>7}",
        "node", "mesh", "MHz", "power_mW", "area_mm2", "tok/s", "score", "leak%"
    );
    let mut results = Vec::new();
    for &nm in &cfg.nodes_nm {
        let r = rl::run_node(&cfg, nm, &mut agent, &mut rng)?;
        if let Some(b) = &r.best {
            let o = &b.outcome;
            println!(
                "{:>4}nm {:>7} {:>6.0} {:>9.2} {:>9.1} {:>7.1} {:>7.3} {:>6.0}%",
                nm,
                format!("{}x{}", o.decoded.mesh.width, o.decoded.mesh.height),
                o.decoded.avg.clock_mhz,
                o.ppa.power.total(),
                o.ppa.area.total(),
                o.ppa.tokens_per_s,
                o.reward.score,
                100.0 * o.ppa.power.leakage / o.ppa.power.total(),
            );
            silicon_rl::artifacts_out::write_node_artifacts(out_dir, nm, o)?;
        } else {
            println!("{nm:>4}nm: no feasible configuration");
        }
        results.push(r);
    }

    let rows: Vec<NodeSummary> =
        results.iter().filter_map(NodeSummary::from_result).collect();
    let t19 = report::nodes_table(&rows);
    t19.write_csv(&out_dir.join("table19_smolvlm.csv"))?;
    println!("\n{}", t19.to_text());

    // paper's headline claims for this run
    let under_13 = rows.iter().filter(|r| r.power.total() < 13.0).count();
    println!(
        "{} / {} nodes under 13 mW (paper: all 7 at 10 MHz, leakage-dominated)",
        under_13,
        rows.len()
    );
    Ok(())
}
