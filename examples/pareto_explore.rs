//! Pareto-frontier exploration (§3.10 "Pareto-based final selection" and
//! §5.5's designer-tool future work): run a search at one node, dump the
//! non-dominated frontier, and show how different PPA weight profiles
//! select different operating points from the SAME frontier.
//!
//! Uses the random-search proposal mechanism so it runs without PJRT
//! artifacts (the frontier logic is identical under SAC).
//!
//! Usage: cargo run --release --example pareto_explore [-- key=value ...]

use silicon_rl::config::RunConfig;
use silicon_rl::error::{Error, Result};
use silicon_rl::ppa::PpaWeights;
use silicon_rl::rl::baselines;
use silicon_rl::util::Rng;

fn main() -> Result<()> {
    let mut cfg = RunConfig::default();
    cfg.rl.episodes_per_node = 250;
    for a in std::env::args().skip(1) {
        if let Some((k, v)) = a.split_once('=') {
            cfg.apply(k, v).map_err(Error::msg)?;
        }
    }
    let nm = *cfg.nodes_nm.first().unwrap_or(&3);
    let mut rng = Rng::new(cfg.seed);

    println!("exploring {nm}nm with {} episodes...", cfg.rl.episodes_per_node);
    let result = baselines::random_search(&cfg, nm, &mut rng);
    println!(
        "{} feasible / {} episodes -> {} non-dominated frontier points\n",
        result.feasible_count,
        result.total_episodes,
        result.pareto.len()
    );

    println!(
        "{:>10} {:>12} {:>10} {:>10} {:>8}",
        "perf_gops", "power_mw", "area_mm2", "tok/s", "episode"
    );
    let mut pts: Vec<_> = result.pareto.frontier().to_vec();
    pts.sort_by(|a, b| a.power_mw.total_cmp(&b.power_mw));
    for p in &pts {
        println!(
            "{:>10.0} {:>12.0} {:>10.0} {:>10.0} {:>8}",
            p.perf_gops, p.power_mw, p.area_mm2, p.tokens_per_s, p.episode
        );
    }

    println!("\nscalarized selection under different weight profiles:");
    for (name, w) in [
        ("high-performance (0.4/0.4/0.2)", PpaWeights::HIGH_PERF),
        ("low-power        (0.2/0.6/0.2)", PpaWeights::LOW_POWER),
        ("area-priority    (0.2/0.2/0.6)", PpaWeights { perf: 0.2, power: 0.2, area: 0.6 }),
        ("throughput-max   (0.9/0.05/0.05)", PpaWeights { perf: 0.9, power: 0.05, area: 0.05 }),
    ] {
        if let Some(sel) = result.pareto.select(&w) {
            println!(
                "  {name}: {:>8.0} GOps  {:>8.1} W  {:>7.0} mm2  (episode {})",
                sel.perf_gops,
                sel.power_mw / 1000.0,
                sel.area_mm2,
                sel.episode
            );
        }
    }
    Ok(())
}
