//! Optimizer-component ablation (DESIGN.md ablation benches): SAC with
//! the full stack (PER + world-model MPC) vs SAC without MPC vs SAC with
//! uniform (non-prioritized) replay, same episode budget and seed.
//!
//! Quantifies §3.16's claim that MPC lookahead helps navigate correlated
//! parameter interactions, and §3.11's prioritized-replay choice.
//!
//! Run: cargo run --release --example ablation_mpc [-- episodes=N]

use silicon_rl::config::RunConfig;
use silicon_rl::error::{Error, Result};
use silicon_rl::nn::backend;
use silicon_rl::rl::{self, SacAgent};
use silicon_rl::util::Rng;

fn run_variant(
    name: &str,
    cfg: &RunConfig,
    rng_seed: u64,
) -> Result<(String, f64, f64, usize)> {
    let be = backend::load(&cfg.artifacts_dir, cfg.backend)?;
    let mut rng = Rng::new(rng_seed);
    let mut agent = SacAgent::new(be, cfg.rl, &mut rng)?;
    let r = rl::run_node(cfg, 3, &mut agent, &mut rng)?;
    let (score, toks) = r
        .best
        .as_ref()
        .map(|b| (b.outcome.reward.score, b.outcome.ppa.tokens_per_s))
        .unwrap_or((f64::NAN, 0.0));
    Ok((name.to_string(), score, toks, r.feasible_count))
}

fn main() -> Result<()> {
    let mut base = RunConfig::default();
    base.rl.episodes_per_node = 500;
    base.rl.warmup_steps = 256;
    for a in std::env::args().skip(1) {
        if let Some((k, v)) = a.split_once('=') {
            base.apply(k, v).map_err(Error::msg)?;
        }
    }

    let mut no_mpc = base.clone();
    no_mpc.rl.mpc_eps_gate = -1.0; // gate never opens: MPC off

    let mut uniform_replay = base.clone();
    uniform_replay.rl.per_alpha = 0.0; // p_i = const: uniform sampling
    uniform_replay.rl.per_beta0 = 1.0; // no IS correction needed

    println!(
        "ablation at 3nm, {} episodes each (seed {})\n",
        base.rl.episodes_per_node, base.seed
    );
    println!("{:<26} {:>8} {:>10} {:>9}", "variant", "score", "tok/s", "feasible");
    for (name, cfg) in [
        ("SAC + PER + MPC (full)", &base),
        ("SAC + PER, no MPC", &no_mpc),
        ("SAC + MPC, uniform replay", &uniform_replay),
    ] {
        let (n, score, toks, feas) = run_variant(name, cfg, cfg.seed)?;
        println!("{n:<26} {score:>8.3} {toks:>10.0} {feas:>9}");
    }
    Ok(())
}
