//! Quickstart: evaluate a handful of candidate ASIC configurations for
//! Llama 3.1 8B at 3nm through the full analytical pipeline (partition →
//! heterogeneous derivation → PPA → reward) — no RL, no PJRT artifacts
//! needed. Run: `cargo run --release --example quickstart`

use silicon_rl::config::{Granularity, RunConfig};
use silicon_rl::env::{Action, Env};
use silicon_rl::report;

fn main() {
    let mut cfg = RunConfig::default();
    cfg.granularity = Granularity::Group;

    // Table 9: workload statistics straight from the graph generator
    let graph = cfg.workload.build();
    println!("{}", report::model_stats(&graph, cfg.kv_strategy).to_text());

    let mut env = Env::new(&cfg, 3);
    println!(
        "optimizing for {}nm (budget: {:.1} W, {:.0} mm2)\n",
        env.node.nm,
        env.budget.power_budget_mw / 1000.0,
        env.budget.area_budget_mm2
    );

    // candidate sweep: VLEN x partitioning aggressiveness
    println!(
        "{:>6} {:>10} {:>10} {:>9} {:>9} {:>8} {:>9}",
        "vlen", "mesh", "tok/s", "power_W", "area_mm2", "score", "feasible"
    );
    for (vlen_u, rho_u) in [(-1.0, 0.0), (-0.5, 0.0), (0.0, 0.0), (0.5, 0.5), (1.0, 1.0)] {
        let mut a = Action::neutral();
        a.cont[2] = vlen_u; // VLEN
        a.cont[19] = rho_u; // matmul partition delta
        a.cont[22] = 0.8; // input streaming
        let out = env.eval_action(&a);
        println!(
            "{:>6} {:>10} {:>10.0} {:>9.1} {:>9.0} {:>8.3} {:>9}",
            out.decoded.avg.vlen_bits,
            format!("{}x{}", out.decoded.mesh.width, out.decoded.mesh.height),
            out.ppa.tokens_per_s,
            out.ppa.power.total() / 1000.0,
            out.ppa.area.total(),
            out.reward.score,
            out.reward.feasible,
        );
    }

    println!("\nceilings of the last design (Eq 24 binding analysis):");
    let out = env.eval_action(&Action::neutral());
    println!(
        "  compute {:>12.0} tok/s\n  memory  {:>12.0} tok/s\n  noc     {:>12.0} tok/s  -> binding: {:?}",
        out.ppa.ceilings.compute,
        out.ppa.ceilings.memory,
        out.ppa.ceilings.noc,
        out.ppa.ceilings.binding()
    );
}
