//! End-to-end driver (DESIGN.md deliverable b / EXPERIMENTS.md §E2E):
//! the full three-layer system on the paper's headline workload.
//!
//! Runs Algorithm 1 — SAC actor/critics/world-model executing as
//! AOT-compiled HLO through the PJRT CPU runtime, the analytical PPA
//! evaluation in Rust — for Llama 3.1 8B FP16 in high-performance mode
//! across process nodes, then regenerates the paper's Tables 10/11/12/17
//! /18 and the Fig 3 convergence CSV from the run.
//!
//! Usage: cargo run --release --example llama_highperf [-- key=value ...]
//!   defaults: nodes=3,14,28 episodes=600 warmup=256 (a laptop-scale
//!   version of the paper's 7-node x 4,613-episode run; pass
//!   nodes=3,5,7,10,14,22,28 episodes=4613 for the full sweep)

use std::path::Path;

use silicon_rl::artifacts_out;
use silicon_rl::config::RunConfig;
use silicon_rl::error::{Error, Result};
use silicon_rl::nn::backend;
use silicon_rl::report::{self, NodeSummary};
use silicon_rl::rl::{self, SacAgent};
use silicon_rl::util::Rng;

fn main() -> Result<()> {
    let mut cfg = RunConfig::default();
    cfg.nodes_nm = vec![3, 14, 28];
    cfg.rl.episodes_per_node = 600;
    cfg.rl.warmup_steps = 256;
    cfg.out_dir = "out/llama_highperf".into();
    for a in std::env::args().skip(1) {
        if let Some((k, v)) = a.split_once('=') {
            cfg.apply(k, v).map_err(Error::msg)?;
        }
    }

    let be = backend::load(&cfg.artifacts_dir, cfg.backend)?;
    println!("backend: {} | mode: {}", be.describe(), cfg.mode.name);
    let mut rng = Rng::new(cfg.seed);
    let mut agent = SacAgent::new(be, cfg.rl, &mut rng)?;

    let out_dir = Path::new(&cfg.out_dir);
    std::fs::create_dir_all(out_dir)?;
    let mut results = Vec::new();
    for &nm in &cfg.nodes_nm {
        let t0 = std::time::Instant::now();
        let r = rl::run_node(&cfg, nm, &mut agent, &mut rng)?;
        let dt = t0.elapsed().as_secs_f64();
        if let Some(b) = &r.best {
            let o = &b.outcome;
            println!(
                "{nm:>2}nm: {:>8.0} tok/s  {:>7.1} W  {:>6.0} mm2  mesh {:>2}x{:<2}  score {:.3}  pareto {:>3}  [{:.0} ms/episode]",
                o.ppa.tokens_per_s,
                o.ppa.power.total() / 1000.0,
                o.ppa.area.total(),
                o.decoded.mesh.width,
                o.decoded.mesh.height,
                o.reward.score,
                r.pareto.len(),
                dt * 1000.0 / r.total_episodes as f64,
            );
            artifacts_out::write_node_artifacts(out_dir, nm, o)?;
        }
        report::convergence_csv(&r.episodes)
            .write_csv(&out_dir.join(format!("fig3_convergence_{nm}nm.csv")))?;
        results.push(r);
    }

    let rows: Vec<NodeSummary> =
        results.iter().filter_map(NodeSummary::from_result).collect();
    for t in [
        report::nodes_table(&rows),
        report::power_breakdown(&rows),
        report::efficiency_table(&rows),
        report::run_stats(&results, cfg.mode.name, &cfg.scenario()),
        report::industry_comparison(rows.first()),
    ] {
        println!("\n{}", t.to_text());
    }
    if rows.len() >= 2 {
        let best = rows.iter().min_by(|a, b| a.ppa_score.total_cmp(&b.ppa_score)).unwrap();
        let worst = rows.last().unwrap();
        println!("{}", report::cross_node_compare(best, worst).to_text());
    }
    if rows.len() >= 3 {
        println!("{}", report::scaling_analysis(&rows).to_text());
    }
    println!("artifacts + CSVs in {}", out_dir.display());
    Ok(())
}
