//! KV-compaction ablation (§3.9, Eqs 29–33): sweep the compaction
//! strategies at a fixed design point and report footprint, memory
//! ceiling and realized throughput — the mechanism behind Eq 33's
//! "relaxes the memory ceiling".
//!
//! Pure analytical pipeline (no PJRT needed).
//! Run: cargo run --release --example kv_ablation

use silicon_rl::config::{Granularity, RunConfig};
use silicon_rl::env::{Action, Env};
use silicon_rl::kv::{self, KvStrategy};

fn main() {
    let strategies: [(&str, KvStrategy); 6] = [
        ("FP16 full", KvStrategy::Full),
        ("INT8 quant", KvStrategy::Quantized { bits: 8 }),
        ("INT4 quant", KvStrategy::Quantized { bits: 4 }),
        ("window 1024", KvStrategy::Window { tokens: 1024 }),
        ("INT8 + win 1024", KvStrategy::QuantizedWindow { bits: 8, tokens: 1024 }),
        ("paged 64KB", KvStrategy::Paged { page_kb: 64 }),
    ];

    let kvc = silicon_rl::ir::llama::build().kv.unwrap();
    println!(
        "Llama 3.1 8B @ 3nm, L=2048 — KV base: {} KB/token, {} MB total\n",
        kv::bytes_per_token(&kvc) / 1024.0,
        kv::total_bytes(&kvc, 2048, KvStrategy::Full) / (1024.0 * 1024.0),
    );
    println!(
        "{:<16} {:>7} {:>10} {:>14} {:>12} {:>10}",
        "strategy", "kappa", "kv_MB", "mem_ceiling", "tok/s", "binding"
    );
    for (name, s) in strategies {
        let mut cfg = RunConfig::default();
        cfg.granularity = Granularity::Group;
        cfg.kv_strategy = s;
        let mut env = Env::new(&cfg, 3);
        let mut a = Action::neutral();
        a.cont[22] = 0.8; // realistic streaming
        let out = env.eval_action(&a);
        println!(
            "{:<16} {:>7.1} {:>10.0} {:>14.0} {:>12.0} {:>10?}",
            name,
            kv::compaction_factor(s, 2048),
            kv::total_bytes(&kvc, 2048, s) / (1024.0 * 1024.0),
            out.ppa.ceilings.memory,
            out.ppa.tokens_per_s,
            out.ppa.ceilings.binding(),
        );
    }
    println!(
        "\npaper example check (Eq 32): INT8 + 1024-window at L=2048 -> kappa = {} (paper: 4x, 256->64 MB)",
        kv::compaction_factor(KvStrategy::QuantizedWindow { bits: 8, tokens: 1024 }, 2048)
    );
}
