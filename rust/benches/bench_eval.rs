//! L3 hot-path microbenchmarks (EXPERIMENTS.md §Perf): the per-episode
//! evaluation cost — per stage and end-to-end — plus batched candidate
//! scoring under the stage-split memos and roofline admission pruning.
//! The paper quotes ~10 ms per full PPA evaluation; `group` granularity
//! must land at or under that on this single-core testbed.
//!
//! Set `BENCH_SMOKE=1` for the CI perf-smoke mode: shorter sampling, the
//! large mesh sweeps skipped. Both modes emit `out/bench/BENCH_eval.json`
//! (episodes/sec, per-stage timings, cache hit rates, prune fraction) so
//! the perf trajectory is tracked over time.

use silicon_rl::config::{Granularity, RunConfig};
use silicon_rl::env::{Action, Env};
use silicon_rl::eval::{parallel, EvalScratch, Evaluator, StageCache};
use silicon_rl::hazard::Mitigation;
use silicon_rl::ir::llama;
use silicon_rl::partition::{self, PartitionKnobs};
use silicon_rl::util::bench::Bencher;
use silicon_rl::util::{json, Rng};

/// Candidate batch shaped like SAC/MPC exploitation: perturb only
/// non-partition continuous dims (clock/VLEN/DMEM), so the placement key
/// is shared and the stage memo replays.
fn sac_shaped(rng: &mut Rng, k: usize) -> Vec<Action> {
    (0..k)
        .map(|_| {
            let mut a = Action::neutral();
            a.cont[2] = rng.uniform_in(-1.0, 1.0); // vlen
            a.cont[3] = rng.uniform_in(-1.0, 1.0); // dmem
            a.cont[11] = rng.uniform_in(-1.0, 1.0); // clock
            a
        })
        .collect()
}

/// Candidate batch shaped like the grid baseline: a lattice over VLEN,
/// DMEM, ρ_matmul, DFLIT and mesh deltas.
fn grid_shaped(k: usize) -> Vec<Action> {
    const LEVELS: [f64; 5] = [-1.0, -0.5, 0.0, 0.5, 1.0];
    let mesh_deltas: [i32; 3] = [-2, 0, 2];
    (0..k)
        .map(|t| {
            let mut a = Action::neutral();
            let mut i = t;
            a.cont[2] = LEVELS[i % 5];
            i /= 5;
            a.cont[3] = LEVELS[i % 5];
            i /= 5;
            a.cont[19] = LEVELS[i % 5];
            i /= 5;
            a.cont[6] = LEVELS[i % 5];
            i /= 5;
            let md = mesh_deltas[i % 3];
            a.deltas = [md, md, 0, 0];
            a
        })
        .collect()
}

fn random_shaped(rng: &mut Rng, k: usize) -> Vec<Action> {
    (0..k)
        .map(|_| {
            let mut a = Action::neutral();
            for v in a.cont.iter_mut() {
                *v = rng.uniform_in(-1.0, 1.0);
            }
            a
        })
        .collect()
}

fn main() {
    // BENCH_SMOKE=1 (anything but "0"/empty) = CI short mode
    let smoke = std::env::var("BENCH_SMOKE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    let mut b = Bencher::default();
    if smoke {
        b.warmup = std::time::Duration::from_millis(50);
        b.budget = std::time::Duration::from_millis(500);
        b.max_samples = 10;
        println!("== bench_eval (SMOKE mode): episode evaluation hot path ==");
    } else {
        println!("== bench_eval: episode evaluation hot path ==");
    }

    let mut cfg = RunConfig::default();
    cfg.granularity = Granularity::Group;
    let ev = Evaluator::new(&cfg, 3);
    let mesh = ev.initial_mesh();
    let workers = parallel::num_threads();

    // ---- per-stage timings (the stage-split decomposition)
    let a0 = Action::neutral();
    let (decoded, _) = ev.stage_decode(&mesh, &a0);
    let mut cold = EvalScratch::default();
    cold.stages = StageCache::new(0); // memo off: every placement is real
    let mut warm = EvalScratch::default();
    warm.stages = StageCache::new(64);
    let decode_s = b.bench("stage/decode+project", || ev.stage_decode(&mesh, &a0).1).mean_s();
    let place_cold_s =
        b.bench("stage/place(cold)", || ev.stage_place(&decoded, &mut cold).n_units).mean_s();
    let place_warm_s =
        b.bench("stage/place(memo hit)", || ev.stage_place(&decoded, &mut warm).n_units).mean_s();
    let placement = ev.stage_place(&decoded, &mut warm);
    let tiles = ev.stage_tiles(&decoded, &placement);
    let tiles_s =
        b.bench("stage/derive_tiles", || ev.stage_tiles(&decoded, &placement).len()).mean_s();
    let ppa_s = b
        .bench("stage/ppa", || {
            ev.stage_ppa(&decoded, &placement, &tiles).tokens_per_s
        })
        .mean_s();
    let bound_s =
        b.bench("stage/admission_bound", || ev.admission_bound(&decoded)).mean_s();

    // ---- batched candidate evaluation: PR 1 baseline (fresh scratches,
    // exact) vs stage-cached + pruned, for the three batch shapes the
    // drivers produce
    let k = 32usize;
    let mut rng = Rng::new(7);
    let shapes: [(&str, Vec<Action>); 3] = [
        ("sac", sac_shaped(&mut rng, k)),
        ("grid", grid_shaped(k)),
        ("random", random_shaped(&mut rng, k)),
    ];
    let mut batch_json: Vec<(&str, json::Json)> = Vec::new();
    let mut headline_exact_s = 0.0f64;
    let mut headline_opt_s = 0.0f64;
    for (name, actions) in &shapes {
        let exact_s = b
            .bench(&format!("batch{k}/{name}/exact_fresh"), || {
                ev.evaluate_many(&mesh, actions, workers).len()
            })
            .mean_s();
        let mut scratches: Vec<EvalScratch> =
            (0..workers.max(1)).map(|_| EvalScratch::default()).collect();
        let opt_s = b
            .bench(&format!("batch{k}/{name}/staged_pruned"), || {
                ev.evaluate_best_with(&mesh, actions, &mut scratches, true).best
            })
            .mean_s();
        let probe = ev.evaluate_best_with(&mesh, actions, &mut scratches, true);
        let mut place_hits = 0u64;
        let mut place_misses = 0u64;
        for s in &scratches {
            place_hits += s.stages.hits;
            place_misses += s.stages.misses;
        }
        let hit_rate =
            place_hits as f64 / (place_hits + place_misses).max(1) as f64;
        let pruned_frac = probe.n_pruned as f64 / k as f64;
        println!(
            "  {name}: {:.1} eps/s exact -> {:.1} eps/s staged+pruned \
             ({:.2}x, {:.0}% pruned, {:.0}% place hits)",
            k as f64 / exact_s,
            k as f64 / opt_s,
            exact_s / opt_s,
            pruned_frac * 100.0,
            hit_rate * 100.0
        );
        batch_json.push((
            *name,
            json::obj(vec![
                ("episodes_per_sec_exact", json::num(k as f64 / exact_s)),
                ("episodes_per_sec_staged_pruned", json::num(k as f64 / opt_s)),
                ("speedup", json::num(exact_s / opt_s)),
                ("pruned_frac", json::num(pruned_frac)),
                ("place_hit_rate", json::num(hit_rate)),
            ]),
        ));
        if *name == "grid" {
            headline_exact_s = exact_s;
            headline_opt_s = opt_s;
        }
    }

    // ---- legacy end-to-end + sweep benches (skipped in smoke mode)
    if !smoke {
        for nm in [3u32, 28] {
            let mut c = RunConfig::default();
            c.granularity = Granularity::Group;
            let mut env = Env::new(&c, nm);
            let mut rng = Rng::new(1);
            b.bench(&format!("eval_action/group/{nm}nm"), || {
                let mut a = Action::neutral();
                for v in a.cont.iter_mut() {
                    *v = rng.uniform_in(-1.0, 1.0);
                }
                env.eval_action(&a).ppa.tokens_per_s
            });
        }
        {
            let mut c = RunConfig::default();
            c.granularity = Granularity::Op;
            let mut env = Env::new(&c, 3);
            b.bench("eval_action/op/3nm", || {
                env.eval_action(&Action::neutral()).ppa.tokens_per_s
            });
        }
        let g = llama::build();
        let units = partition::groups::units_from_groups(&g);
        let mit = Mitigation { stanum: 4, fetch: 4, xr_wp: 2, vr_wp: 2 };
        for side in [8u32, 16, 32, 48] {
            let m = silicon_rl::arch::MeshConfig::new(side, side);
            let knobs = PartitionKnobs::default();
            b.bench(&format!("place_units/group/{side}x{side}"), || {
                partition::place_units(&units, &m, &knobs, &mit).n_units
            });
        }
        b.bench("llama_graph_build", || llama::build().ops.len());
        b.bench("units_from_groups", || {
            partition::groups::units_from_groups(&g).len()
        });
    }

    // ---- JSON perf record (consumed by the CI perf-smoke step)
    let stages = json::obj(vec![
        ("decode_s", json::num(decode_s)),
        ("place_cold_s", json::num(place_cold_s)),
        ("place_memo_hit_s", json::num(place_warm_s)),
        ("derive_tiles_s", json::num(tiles_s)),
        ("ppa_s", json::num(ppa_s)),
        ("admission_bound_s", json::num(bound_s)),
    ]);
    let batches = json::obj(batch_json);
    let record = json::obj(vec![
        ("bench", json::s("bench_eval")),
        ("smoke", json::num(if smoke { 1.0 } else { 0.0 })),
        ("workers", json::num(workers as f64)),
        ("batch_size", json::num(k as f64)),
        (
            "episodes_per_sec_exact",
            json::num(k as f64 / headline_exact_s.max(1e-12)),
        ),
        (
            "episodes_per_sec_staged_pruned",
            json::num(k as f64 / headline_opt_s.max(1e-12)),
        ),
        (
            "speedup_grid_batch",
            json::num(headline_exact_s / headline_opt_s.max(1e-12)),
        ),
        ("stage_s", stages),
        ("batches", batches),
    ]);
    let _ = std::fs::create_dir_all("out/bench");
    let _ = silicon_rl::util::fsio::atomic_write_str(
        "out/bench/BENCH_eval.json",
        &record.to_string_pretty(),
    );
    println!("json: out/bench/BENCH_eval.json");

    b.write_csv("out/bench/bench_eval.csv");
    println!("csv: out/bench/bench_eval.csv");
}
