//! L3 hot-path microbenchmarks (EXPERIMENTS.md §Perf): the per-episode
//! evaluation cost — placement, heterogeneous derivation, PPA — across
//! placement granularities and mesh sizes. The paper quotes ~10 ms per
//! full PPA evaluation; `group` granularity must land at or under that
//! on this single-core testbed.

use silicon_rl::config::{Granularity, RunConfig};
use silicon_rl::env::{Action, Env};
use silicon_rl::eval::{parallel, Evaluator};
use silicon_rl::hazard::Mitigation;
use silicon_rl::ir::llama;
use silicon_rl::partition::{self, PartitionKnobs};
use silicon_rl::util::bench::Bencher;
use silicon_rl::util::Rng;

fn main() {
    let mut b = Bencher::default();
    println!("== bench_eval: episode evaluation hot path ==");

    // candidate-set scoring through the stateless evaluator: serial vs
    // all-worker fan-out (the MPC-rerank / baseline-round shape)
    {
        let mut cfg = RunConfig::default();
        cfg.granularity = Granularity::Group;
        let ev = Evaluator::new(&cfg, 3);
        let mesh = ev.initial_mesh();
        let mut rng = Rng::new(7);
        let actions: Vec<Action> = (0..16)
            .map(|_| {
                let mut a = Action::neutral();
                for v in a.cont.iter_mut() {
                    *v = rng.uniform_in(-1.0, 1.0);
                }
                a
            })
            .collect();
        let workers = parallel::num_threads();
        b.bench("evaluate_many/16cand/1thread", || {
            ev.evaluate_many(&mesh, &actions, 1).len()
        });
        b.bench(&format!("evaluate_many/16cand/{workers}threads"), || {
            ev.evaluate_many(&mesh, &actions, workers).len()
        });
    }

    // full eval_action at several mesh scales (group granularity)
    for nm in [3u32, 28] {
        let mut cfg = RunConfig::default();
        cfg.granularity = Granularity::Group;
        let mut env = Env::new(&cfg, nm);
        let mut rng = Rng::new(1);
        b.bench(&format!("eval_action/group/{nm}nm"), || {
            let mut a = Action::neutral();
            for v in a.cont.iter_mut() {
                *v = rng.uniform_in(-1.0, 1.0);
            }
            env.eval_action(&a).ppa.tokens_per_s
        });
    }

    // op-granularity (paper-faithful O(N_ops x N_cores)) at 3nm
    {
        let mut cfg = RunConfig::default();
        cfg.granularity = Granularity::Op;
        let mut env = Env::new(&cfg, 3);
        b.bench("eval_action/op/3nm", || {
            env.eval_action(&Action::neutral()).ppa.tokens_per_s
        });
    }

    // placement alone, sweeping mesh size (the O(N_ops x N_cores) core)
    let g = llama::build();
    let units = partition::groups::units_from_groups(&g);
    let mit = Mitigation { stanum: 4, fetch: 4, xr_wp: 2, vr_wp: 2 };
    for side in [8u32, 16, 32, 48] {
        let mesh = silicon_rl::arch::MeshConfig::new(side, side);
        let knobs = PartitionKnobs::default();
        b.bench(&format!("place_units/group/{side}x{side}"), || {
            partition::place_units(&units, &mesh, &knobs, &mit).n_units
        });
    }

    // graph generation + grouping (one-time setup costs)
    b.bench("llama_graph_build", || llama::build().ops.len());
    b.bench("units_from_groups", || {
        partition::groups::units_from_groups(&g).len()
    });

    b.write_csv("out/bench/bench_eval.csv");
    println!("csv: out/bench/bench_eval.csv");
}
