//! Agent-loop benchmarks (EXPERIMENTS.md §Perf): the NN hot path of
//! Algorithm 1 — B=1 actor inference, the fused B=256 SAC update,
//! world-model/surrogate updates, the K=64 batched MPC surrogate forward
//! and a full MPC refine (K×H = 64×5 rollout) — on the native backend,
//! head-to-head against PJRT when AOT artifacts are built.
//!
//! The native backend needs no artifacts, so this bench runs everywhere;
//! set `BENCH_SMOKE=1` for the CI short mode. Both modes emit
//! `out/bench/BENCH_agent.json` so the perf trajectory finally has
//! agent-loop numbers next to the evaluator's `BENCH_eval.json`.

use std::path::Path;

use silicon_rl::config::RunConfig;
use silicon_rl::env::{ACT_DIM, SAC_STATE_DIM};
use silicon_rl::nn::backend::{self, BackendSel};
use silicon_rl::nn::kernels::{self, KernelSel};
use silicon_rl::rl::{SacAgent, Transition};
use silicon_rl::runtime;
use silicon_rl::util::bench::Bencher;
use silicon_rl::util::{json, Rng};

fn populate_replay(agent: &mut SacAgent, rng: &mut Rng) {
    for i in 0..300 {
        let mut t = Transition {
            s: [0.0; SAC_STATE_DIM],
            a_cont: [0.0; ACT_DIM],
            a_disc: [0.0; 20],
            r: (i % 5) as f32 * 0.2,
            s2: [0.0; SAC_STATE_DIM],
            done: 0.0,
            ppa: [0.4, 0.5, 0.3],
        };
        for v in t.s.iter_mut().chain(t.s2.iter_mut()) {
            *v = rng.uniform() as f32;
        }
        for v in t.a_cont.iter_mut() {
            *v = rng.uniform_in(-0.9, 0.9) as f32;
        }
        t.a_disc[rng.below(5)] = 1.0;
        agent.push_transition(t);
    }
}

/// Benchmark one agent; returns (metric name, mean seconds) rows.
fn bench_agent(tag: &str, agent: &mut SacAgent, b: &mut Bencher) -> Vec<(String, f64)> {
    let mut rng = Rng::new(99);
    populate_replay(agent, &mut rng);
    let mut rows = Vec::new();
    let s = [0.3f32; SAC_STATE_DIM];

    let t = b
        .bench(&format!("[{tag}] actor_fwd b=1 (policy latency)"), || {
            agent.act(&s, true, &mut rng).unwrap()
        })
        .mean_s();
    rows.push(("actor_b1_s".to_string(), t));

    let t = b
        .bench(&format!("[{tag}] sac_update (B=256 fused)"), || {
            agent.update(&mut rng).unwrap()
        })
        .mean_s();
    rows.push(("sac_update_s".to_string(), t));

    let t = b
        .bench(&format!("[{tag}] wm_update (B=256)"), || {
            agent.train_world_model(&mut rng).unwrap()
        })
        .mean_s();
    rows.push(("wm_update_s".to_string(), t));

    let t = b
        .bench(&format!("[{tag}] sur_update (B=256)"), || {
            agent.train_surrogate(&mut rng).unwrap()
        })
        .mean_s();
    rows.push(("sur_update_s".to_string(), t));

    // the MPC planner's surrogate scoring: ONE forward per candidate set
    let k = agent.mpc_batch();
    let states: Vec<f32> = (0..k * SAC_STATE_DIM).map(|i| (i % 13) as f32 * 0.05).collect();
    let actions: Vec<f32> = (0..k * ACT_DIM).map(|i| (i % 7) as f32 * 0.1 - 0.3).collect();
    let t = b
        .bench(&format!("[{tag}] sur_fwd batch K={k} (MPC scoring)"), || {
            agent.backend.sur_fwd(&agent.store, &states, &actions).unwrap().len()
        })
        .mean_s();
    rows.push(("sur_batch_s".to_string(), t));

    let base = agent.act(&s, false, &mut rng).unwrap();
    let t = b
        .bench(&format!("[{tag}] mpc_refine (K={k}, H=5)"), || {
            agent.mpc_refine(&s, &base, None, &mut rng).unwrap()
        })
        .mean_s();
    rows.push(("mpc_refine_s".to_string(), t));
    rows
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    let mut b = Bencher::default();
    if smoke {
        b.warmup = std::time::Duration::from_millis(50);
        b.budget = std::time::Duration::from_millis(800);
        b.max_samples = 20;
    }
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let artifacts_dir = dir.to_string_lossy().to_string();
    let cfg = RunConfig::default().rl;

    println!("== bench_runtime: agent-loop NN backends ==");

    // ---- native: always available (no artifacts needed); scalar kernels
    kernels::set_global(KernelSel::Scalar);
    let be = backend::load(&artifacts_dir, BackendSel::Native).expect("native backend");
    println!("native backend: {}", be.describe());
    let mut rng = Rng::new(1);
    let mut agent = SacAgent::new(be, cfg, &mut rng).expect("agent");
    let native_rows = bench_agent("native", &mut agent, &mut b);

    // ---- native + SIMD kernels (DESIGN.md §10); skipped on hosts with
    // no vector path so the record never compares simd-resolved-scalar
    let simd_rows = if kernels::detect().is_some() {
        kernels::set_global(KernelSel::Simd);
        let be = backend::load(&artifacts_dir, BackendSel::Native).expect("native backend");
        println!("native+simd:    {}", be.describe());
        let mut rng = Rng::new(1);
        let mut agent = SacAgent::new(be, cfg, &mut rng).expect("agent");
        let rows = bench_agent("native-simd", &mut agent, &mut b);
        kernels::set_global(KernelSel::Scalar);
        Some(rows)
    } else {
        println!("native+simd:    no vector path detected — scalar rows only");
        None
    };

    // ---- pjrt: only when artifacts are built and the runtime is linked
    let pjrt_rows = if dir.join("manifest.json").exists() && runtime::backend_available() {
        let be = backend::load(&artifacts_dir, BackendSel::Pjrt).expect("pjrt backend");
        println!("pjrt backend:   {}", be.describe());
        let mut rng = Rng::new(1);
        let mut agent = SacAgent::new(be, cfg, &mut rng).expect("agent");
        Some(bench_agent("pjrt", &mut agent, &mut b))
    } else {
        println!("pjrt backend:   unavailable (no artifacts or offline stub) — native only");
        None
    };

    // ---- perf record
    let to_obj = |rows: &[(String, f64)]| {
        json::obj(rows.iter().map(|(k, v)| (k.as_str(), json::num(*v))).collect())
    };
    let mut record = vec![
        ("bench", json::s("bench_runtime")),
        ("smoke", json::num(if smoke { 1.0 } else { 0.0 })),
        (
            "kernels_detected",
            json::s(kernels::detect().map(|p| p.name()).unwrap_or("none")),
        ),
        ("native", to_obj(&native_rows)),
    ];
    if let Some(simd) = &simd_rows {
        record.push(("native_simd", to_obj(simd)));
        let speedups: Vec<(&str, json::Json)> = native_rows
            .iter()
            .zip(simd)
            .map(|((k, s), (_, v))| (k.as_str(), json::num(s / v.max(1e-12))))
            .collect();
        record.push(("simd_speedup", json::obj(speedups)));
        println!(
            "\nsimd speedup over scalar: actor b=1 {:.2}x, sac_update {:.2}x",
            native_rows[0].1 / simd[0].1.max(1e-12),
            native_rows[1].1 / simd[1].1.max(1e-12)
        );
    }
    if let Some(pjrt) = &pjrt_rows {
        record.push(("pjrt", to_obj(pjrt)));
        let speedups: Vec<(&str, json::Json)> = native_rows
            .iter()
            .zip(pjrt)
            .map(|((k, n), (_, p))| (k.as_str(), json::num(p / n.max(1e-12))))
            .collect();
        record.push(("native_speedup_over_pjrt", json::obj(speedups)));
        let actor_speedup = pjrt[0].1 / native_rows[0].1.max(1e-12);
        println!("\nnative speedup over pjrt (actor b=1): {actor_speedup:.1}x");
    } else {
        println!(
            "\nnative actor b=1: {:.1} µs (acceptance: < 50 µs without PJRT)",
            native_rows[0].1 * 1e6
        );
    }
    let record = json::obj(record);
    if let Err(e) = std::fs::create_dir_all("out/bench") {
        eprintln!("out/bench: {e}");
    }
    let _ = silicon_rl::util::fsio::atomic_write_str(
        "out/bench/BENCH_agent.json",
        &record.to_string_pretty(),
    );
    b.write_csv("out/bench/bench_runtime.csv");
    println!("records: out/bench/BENCH_agent.json, out/bench/bench_runtime.csv");
}
