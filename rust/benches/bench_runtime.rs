//! L2/L1 runtime benchmarks (EXPERIMENTS.md §Perf): latency/throughput of
//! the AOT-compiled HLO entrypoints through the PJRT CPU client — actor
//! inference (B=1), the fused SAC update (B=256, ~30 Pallas-kernel
//! instances fwd+bwd), world-model rollout (B=64) and a full MPC refine
//! (K×H = 64×5 forwards). Skips cleanly when artifacts are not built.

use std::path::Path;

use silicon_rl::config::RunConfig;
use silicon_rl::env::SAC_STATE_DIM;
use silicon_rl::rl::{SacAgent, Transition};
use silicon_rl::runtime::Runtime;
use silicon_rl::util::bench::Bencher;
use silicon_rl::util::Rng;

fn main() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("bench_runtime: artifacts not built (run `make artifacts`); skipping");
        return;
    }
    if !silicon_rl::runtime::backend_available() {
        println!("bench_runtime: PJRT backend unavailable (offline xla stub); skipping");
        return;
    }
    let runtime = Runtime::load(&dir).expect("runtime");
    let mut rng = Rng::new(1);
    let cfg = RunConfig::default().rl;
    let mut agent = SacAgent::new(runtime, cfg, &mut rng).expect("agent");

    // populate replay so update/wm/sur paths have data
    for i in 0..300 {
        let mut t = Transition {
            s: [0.0; SAC_STATE_DIM],
            a_cont: [0.0; 30],
            a_disc: [0.0; 20],
            r: (i % 5) as f32 * 0.2,
            s2: [0.0; SAC_STATE_DIM],
            done: 0.0,
            ppa: [0.4, 0.5, 0.3],
        };
        for v in t.s.iter_mut().chain(t.s2.iter_mut()) {
            *v = rng.uniform() as f32;
        }
        for v in t.a_cont.iter_mut() {
            *v = rng.uniform_in(-0.9, 0.9) as f32;
        }
        t.a_disc[rng.below(5)] = 1.0;
        agent.push_transition(t);
    }

    let mut b = Bencher::default();
    println!("== bench_runtime: PJRT entrypoint performance ==");

    let s = [0.3f32; SAC_STATE_DIM];
    b.bench("actor_fwd_b1 (policy latency)", || {
        agent.act(&s, true, &mut rng).unwrap()
    });

    b.bench("sac_update (B=256 fused HLO)", || {
        agent.update(&mut rng).unwrap()
    });

    b.bench("wm_update (B=256)", || {
        agent.train_world_model(&mut rng).unwrap()
    });

    b.bench("sur_update (B=256)", || {
        agent.train_surrogate(&mut rng).unwrap()
    });

    let base = agent.act(&s, false, &mut rng).unwrap();
    b.bench("mpc_refine (K=64, H=5)", || {
        agent.mpc_refine(&s, &base, None, &mut rng).unwrap()
    });

    b.write_csv("out/bench/bench_runtime.csv");
    println!("csv: out/bench/bench_runtime.csv");
}
