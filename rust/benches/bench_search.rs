//! Table 21 — search-strategy comparison at 3nm: SAC (ours) vs random
//! search vs grid search under the same episode budget and evaluation
//! pipeline. The paper's claim shape: SAC finds a better score, much
//! higher throughput, and many more feasible configurations.
//!
//! Budget: SILICON_RL_BENCH_EPISODES (default 1000; paper used ~4,600).

use std::path::Path;

use silicon_rl::config::RunConfig;
use silicon_rl::report;
use silicon_rl::rl::{self, baselines, SacAgent};
use silicon_rl::runtime::Runtime;
use silicon_rl::util::Rng;

fn main() -> anyhow::Result<()> {
    let eps = std::env::var("SILICON_RL_BENCH_EPISODES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1000);
    let mut cfg = RunConfig::default();
    cfg.rl.episodes_per_node = eps;
    cfg.rl.warmup_steps = 256.min(eps / 2 + 1);
    let nm = 3;

    println!("== bench_search: Table 21 at {nm}nm, {eps} episodes each ==");
    let mut rng = Rng::new(cfg.seed);

    let t0 = std::time::Instant::now();
    let rand_r = baselines::random_search(&cfg, nm, &mut rng.fork(1));
    println!("random search: {:.1}s", t0.elapsed().as_secs_f64());

    let t0 = std::time::Instant::now();
    let grid_r = baselines::grid_search(&cfg, nm, &mut rng.fork(2));
    println!("grid search:   {:.1}s", t0.elapsed().as_secs_f64());

    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let sac_r = if dir.join("manifest.json").exists() {
        let runtime = Runtime::load(&dir)?;
        let mut agent = SacAgent::new(runtime, cfg.rl, &mut rng)?;
        let t0 = std::time::Instant::now();
        let r = rl::run_node(&cfg, nm, &mut agent, &mut rng)?;
        println!("SAC:           {:.1}s", t0.elapsed().as_secs_f64());
        Some(r)
    } else {
        println!("SAC: skipped (artifacts not built)");
        None
    };

    let mut entries: Vec<(&str, &rl::NodeResult)> =
        vec![("Random Search", &rand_r), ("Grid Search", &grid_r)];
    if let Some(r) = &sac_r {
        entries.push(("SAC (ours)", r));
    }
    let t = report::search_comparison(&entries);
    println!("\n{}", t.to_text());
    std::fs::create_dir_all("out/bench")?;
    t.write_csv(Path::new("out/bench/table21_search.csv"))?;

    if let Some(sac) = &sac_r {
        let sac_tok = sac.best.as_ref().map(|b| b.outcome.ppa.tokens_per_s).unwrap_or(0.0);
        let rand_tok =
            rand_r.best.as_ref().map(|b| b.outcome.ppa.tokens_per_s).unwrap_or(1.0);
        println!(
            "SAC vs random: {:.2}x throughput, {:.2}x feasible configs (paper: 3.5x, 9.1x)",
            sac_tok / rand_tok,
            sac.feasible_count as f64 / rand_r.feasible_count.max(1) as f64
        );
    }
    Ok(())
}
