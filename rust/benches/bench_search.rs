//! Table 21 — search-strategy comparison at 3nm: SAC (ours) vs random
//! search vs grid search under the same episode budget and evaluation
//! pipeline — plus the evaluation-layer scaling case: a 7-node ×
//! multi-seed random-search sweep driven serially and in parallel, with
//! a bit-identical-results check (the paper's claim shape for SAC: a
//! better score, much higher throughput, many more feasible configs).
//!
//! Budget: SILICON_RL_BENCH_EPISODES (default 1000; paper used ~4,600).
//! Sweep budget: SILICON_RL_BENCH_SWEEP_EPISODES (default 60/node/seed).
//! `BENCH_SMOKE=1` shrinks every budget to a CI-sized short mode; the
//! vec-env lane sweep always emits `out/bench/BENCH_vecenv.json`, the
//! actor-learner mode sweep `out/bench/BENCH_learner.json`, and the
//! atlas reuse sweep `out/bench/BENCH_atlas.json`.

use std::path::Path;
use std::time::{Duration, Instant};

use silicon_rl::config::RunConfig;
use silicon_rl::env::SAC_STATE_DIM;
use silicon_rl::error::Result;
use silicon_rl::eval::parallel;
use silicon_rl::nn::backend::{self, Backend, BackendSel};
use silicon_rl::nn::kernels::{self, KernelSel};
use silicon_rl::nn::policy;
use silicon_rl::report;
use silicon_rl::rl::{self, baselines, SacAgent, Transition};
use silicon_rl::util::bench::Bencher;
use silicon_rl::util::{json, Rng};

fn main() -> Result<()> {
    let smoke = std::env::var("BENCH_SMOKE").ok().as_deref() == Some("1");
    let eps = std::env::var("SILICON_RL_BENCH_EPISODES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if smoke { 80 } else { 1000 });
    let mut cfg = RunConfig::default();
    cfg.rl.episodes_per_node = eps;
    cfg.rl.warmup_steps = 256.min(eps / 2 + 1);
    let nm = 3;

    println!("== bench_search: Table 21 at {nm}nm, {eps} episodes each ==");
    let mut rng = Rng::new(cfg.seed);

    let t0 = std::time::Instant::now();
    let rand_r = baselines::random_search(&cfg, nm, &mut rng.fork(1));
    println!("random search: {:.1}s", t0.elapsed().as_secs_f64());

    let t0 = std::time::Instant::now();
    let grid_r = baselines::grid_search(&cfg, nm, &mut rng.fork(2));
    println!("grid search:   {:.1}s", t0.elapsed().as_secs_f64());

    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let sac_r = {
        // strict evaluation-count parity with the baselines: disable the
        // MPC real-eval re-ranking so every strategy performs exactly one
        // evaluation per budgeted episode
        let mut sac_cfg = cfg.clone();
        sac_cfg.rl.mpc_rerank = 0;
        sac_cfg.artifacts_dir = dir.to_string_lossy().to_string();
        let be = backend::load(&sac_cfg.artifacts_dir, sac_cfg.backend)?;
        println!("SAC backend:   {}", be.describe());
        let mut agent = SacAgent::new(be, sac_cfg.rl, &mut rng)?;
        let t0 = std::time::Instant::now();
        let r = rl::run_node(&sac_cfg, nm, &mut agent, &mut rng)?;
        println!("SAC:           {:.1}s", t0.elapsed().as_secs_f64());
        Some(r)
    };

    let mut entries: Vec<(&str, &rl::NodeResult)> =
        vec![("Random Search", &rand_r), ("Grid Search", &grid_r)];
    if let Some(r) = &sac_r {
        entries.push(("SAC (ours)", r));
    }
    let t = report::search_comparison(&entries);
    println!("\n{}", t.to_text());
    std::fs::create_dir_all("out/bench")?;
    t.write_csv(Path::new("out/bench/table21_search.csv"))?;

    if let Some(sac) = &sac_r {
        let sac_tok = sac.best.as_ref().map(|b| b.outcome.ppa.tokens_per_s).unwrap_or(0.0);
        let rand_tok =
            rand_r.best.as_ref().map(|b| b.outcome.ppa.tokens_per_s).unwrap_or(1.0);
        println!(
            "SAC vs random: {:.2}x throughput, {:.2}x feasible configs (paper: 3.5x, 9.1x)",
            sac_tok / rand_tok,
            sac.feasible_count as f64 / rand_r.feasible_count.max(1) as f64
        );
    }

    node_sweep_scaling(smoke)?;
    vecenv_lane_sweep(smoke)?;
    learner_mode_sweep(smoke)?;
    atlas_sweep(smoke)?;
    Ok(())
}

/// Evaluation-layer scaling case: the full 7-node sweep × multi-seed
/// random search, serial (1 worker) vs parallel (all workers). Asserts
/// the two produce bit-identical statistics, then reports wall-clock
/// speedup (expect ≳3× on a 4-core machine: seeds × candidate sets both
/// fan out through the same stateless evaluator).
fn node_sweep_scaling(smoke: bool) -> Result<()> {
    let sweep_eps = std::env::var("SILICON_RL_BENCH_SWEEP_EPISODES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if smoke { 16 } else { 60 });
    let n_seeds = 4;
    let workers = parallel::num_threads();
    let mut cfg = RunConfig::default();
    cfg.rl.episodes_per_node = sweep_eps;

    println!(
        "\n== bench_search: 7-node x {n_seeds}-seed sweep, {sweep_eps} episodes \
         (1 vs {workers} workers) =="
    );

    let run = |threads: usize| -> (Vec<rl::MultiSeedResult>, f64) {
        let t0 = std::time::Instant::now();
        let results: Vec<rl::MultiSeedResult> = cfg
            .nodes_nm
            .iter()
            .map(|&nm| {
                rl::run_seeds_t(&cfg, nm, n_seeds, threads, |c, nm, rng| {
                    baselines::random_search_t(c, nm, rng, 1)
                })
            })
            .collect();
        (results, t0.elapsed().as_secs_f64())
    };

    let (serial, dt_serial) = run(1);
    let (par, dt_par) = run(workers);

    // determinism: the parallel driver must reproduce the serial sweep
    // bit-for-bit
    for (s, p) in serial.iter().zip(&par) {
        assert_eq!(s.seeds, p.seeds, "{}nm: seed derivation diverged", s.nm);
        assert_eq!(
            s.score.mean.to_bits(),
            p.score.mean.to_bits(),
            "{}nm: best-score mean diverged between serial and parallel",
            s.nm
        );
        assert_eq!(
            s.tokens_per_s.mean.to_bits(),
            p.tokens_per_s.mean.to_bits(),
            "{}nm: throughput mean diverged",
            s.nm
        );
        assert_eq!(s.pareto.len(), p.pareto.len(), "{}nm: frontier diverged", s.nm);
    }
    println!("determinism: serial and parallel sweeps bit-identical across 7 nodes");

    let t = rl::seeds_table(&par);
    println!("{}", t.to_text());
    std::fs::create_dir_all("out/bench")?;
    t.write_csv(Path::new("out/bench/multiseed_sweep.csv"))?;
    println!(
        "sweep wall-clock: serial {dt_serial:.1}s, parallel {dt_par:.1}s -> {:.2}x \
         speedup on {workers} workers",
        dt_serial / dt_par.max(1e-9)
    );
    Ok(())
}

/// Fill the replay past the minibatch size so live-update runs train
/// from the first vec-step at every lane count (fair amortization
/// comparison).
fn prefill_replay(agent: &mut SacAgent, rng: &mut Rng) {
    for i in 0..320 {
        // one-hot per discrete head, the same encoding real transitions
        // carry through policy::onehot_from_deltas
        let deltas: [i32; 4] = std::array::from_fn(|_| rng.below(5) as i32 - 2);
        let mut t = Transition {
            s: [0.0; SAC_STATE_DIM],
            a_cont: [0.0; 30],
            a_disc: policy::onehot_from_deltas(&deltas),
            r: (i % 7) as f32 * 0.1 - 0.3,
            s2: [0.0; SAC_STATE_DIM],
            done: 0.0,
            ppa: [0.4, 0.5, 0.3],
        };
        for v in t.s.iter_mut().chain(t.s2.iter_mut()) {
            *v = rng.uniform() as f32;
        }
        for v in t.a_cont.iter_mut() {
            *v = rng.uniform_in(-0.9, 0.9) as f32;
        }
        agent.push_transition(t);
    }
}

/// Vec-env lane sweep (DESIGN.md §9): lane-steps/sec at lanes ∈
/// {1, 4, 8, 16} over the native backend, in two modes — pure rollout
/// (batched actor forward + parallel env fan-out) and live updates
/// (adds the shared-step-counter amortization of SAC/wm/sur training) —
/// plus the raw batched actor-forward efficiency. Emits
/// `out/bench/BENCH_vecenv.json` in both normal and `BENCH_SMOKE` modes.
fn vecenv_lane_sweep(smoke: bool) -> Result<()> {
    let lane_counts = [1usize, 4, 8, 16];
    let threads = parallel::num_threads();
    let rollout_eps = if smoke { 20 } else { 96 };
    let live_eps = if smoke { 12 } else { 48 };

    println!(
        "\n== bench_search: vec-env lane sweep (native backend, {threads} workers) =="
    );

    let run_mode = |label: &str, episodes: usize, live: bool| -> Result<Vec<(String, f64)>> {
        let mut rows = Vec::new();
        for &lanes in &lane_counts {
            let mut cfg = RunConfig::default();
            cfg.backend = BackendSel::Native;
            cfg.artifacts_dir = "/nonexistent-artifacts".into();
            cfg.rl.episodes_per_node = episodes;
            cfg.rl.warmup_steps = if live { 1 } else { 10_000 };
            let be = backend::load(&cfg.artifacts_dir, cfg.backend)?;
            let mut rng = Rng::new(42);
            let mut agent = SacAgent::new(be, cfg.rl, &mut rng)?;
            if live {
                prefill_replay(&mut agent, &mut rng);
            }
            let jobs: Vec<rl::LaneSpec> = (0..lanes)
                .map(|i| rl::LaneSpec {
                    nm: 7,
                    seed: rl::multiseed::derive_seed(cfg.seed, i),
                })
                .collect();
            let t0 = Instant::now();
            let results = rl::run_jobs(&cfg, &jobs, lanes, &mut agent, threads)?;
            let dt = t0.elapsed().as_secs_f64();
            let sps = (lanes * episodes) as f64 / dt.max(1e-9);
            let rs = rl::vecenv::reward_stats(&results);
            println!(
                "  [{label:<7}] lanes={lanes:<2} {sps:>8.1} lane-steps/s \
                 ({dt:>6.2}s, {} episodes, reward mean {:.3})",
                rs.count(),
                rs.mean()
            );
            rows.push((format!("{label}_steps_per_s_lanes{lanes}"), sps));
        }
        Ok(rows)
    };

    let rollout = run_mode("rollout", rollout_eps, false)?;
    let live = run_mode("live", live_eps, true)?;

    // the same sweep under `kernels=simd` (DESIGN.md §10) — the
    // acceptance case is a step-rate gain at lanes ≥ 8, where the
    // batched actor forward amortizes into wide matmuls; skipped on
    // hosts with no vector path so simd rows never alias scalar ones
    let simd_sweeps = if kernels::detect().is_some() {
        kernels::set_global(KernelSel::Simd);
        let r = run_mode("rollout+simd", rollout_eps, false)?;
        let l = run_mode("live+simd", live_eps, true)?;
        kernels::set_global(KernelSel::Scalar);
        Some((r, l))
    } else {
        println!("  [simd   ] no vector path detected — scalar sweep only");
        None
    };

    // batched actor-forward efficiency: t(B=1)·B / t(B), measured on the
    // raw backend (efficiency 1.0 = batching is free linear scaling)
    let mut bench = Bencher {
        warmup: Duration::from_millis(50),
        budget: Duration::from_millis(if smoke { 250 } else { 1000 }),
        max_samples: 2000,
        results: Vec::new(),
    };
    let mut agent = {
        let be = backend::load("/nonexistent-artifacts", BackendSel::Native)?;
        SacAgent::new(be, RunConfig::default().rl, &mut Rng::new(42))?
    };
    let states: Vec<f32> = (0..16 * SAC_STATE_DIM)
        .map(|j| ((j * 37 % 23) as f32 - 11.0) / 12.0)
        .collect();
    let t1 = bench
        .bench("actor_fwd b=1", || {
            agent.backend.actor_fwd(&agent.store, &states[..SAC_STATE_DIM]).unwrap();
        })
        .min_s();
    let mut eff_rows: Vec<(String, f64)> = Vec::new();
    for b in [4usize, 8, 16] {
        let tb = bench
            .bench(&format!("actor_fwd b={b}"), || {
                agent
                    .backend
                    .actor_fwd(&agent.store, &states[..b * SAC_STATE_DIM])
                    .unwrap();
            })
            .min_s();
        eff_rows.push((format!("actor_fwd_batch_eff_b{b}"), t1 * b as f64 / tb.max(1e-12)));
    }

    let val = |rows: &[(String, f64)], suffix: &str| {
        rows.iter().find(|(k, _)| k.ends_with(suffix)).map(|(_, v)| *v).unwrap_or(f64::NAN)
    };
    let rollout_8v1 = val(&rollout, "lanes8") / val(&rollout, "lanes1").max(1e-12);
    let live_8v1 = val(&live, "lanes8") / val(&live, "lanes1").max(1e-12);
    println!(
        "vec-env speedup lanes=8 vs lanes=1: rollout {rollout_8v1:.2}x, live \
         {live_8v1:.2}x"
    );
    let simd_gain = simd_sweeps.as_ref().map(|(r, l)| {
        let rg = val(r, "lanes8") / val(&rollout, "lanes8").max(1e-12);
        let lg = val(l, "lanes8") / val(&live, "lanes8").max(1e-12);
        println!("simd step-rate gain at lanes=8: rollout {rg:.2}x, live {lg:.2}x");
        (rg, lg)
    });

    let section = |rows: &[(String, f64)]| {
        json::obj(rows.iter().map(|(k, v)| (k.as_str(), json::num(*v))).collect())
    };
    let mut fields = vec![
        ("bench", json::s("bench_vecenv")),
        ("smoke", json::num(if smoke { 1.0 } else { 0.0 })),
        ("workers", json::num(threads as f64)),
        (
            "kernels_detected",
            json::s(kernels::detect().map(|p| p.name()).unwrap_or("none")),
        ),
        ("rollout_episodes", json::num(rollout_eps as f64)),
        ("live_episodes", json::num(live_eps as f64)),
        ("rollout", section(&rollout)),
        ("live", section(&live)),
        ("actor_fwd", section(&eff_rows)),
        ("rollout_speedup_lanes8_vs_1", json::num(rollout_8v1)),
        ("live_speedup_lanes8_vs_1", json::num(live_8v1)),
    ];
    if let Some((r, l)) = &simd_sweeps {
        fields.push(("rollout_simd", section(r)));
        fields.push(("live_simd", section(l)));
    }
    if let Some((rg, lg)) = simd_gain {
        fields.push(("simd_rollout_gain_lanes8", json::num(rg)));
        fields.push(("simd_live_gain_lanes8", json::num(lg)));
    }
    let record = json::obj(fields);
    std::fs::create_dir_all("out/bench")?;
    silicon_rl::util::fsio::atomic_write_str(
        "out/bench/BENCH_vecenv.json",
        &record.to_string_pretty(),
    )?;
    println!("record: out/bench/BENCH_vecenv.json");

    // acceptance gate: ≥2× lane-steps/sec at lanes=8 vs lanes=1 on the
    // native backend. Checked after the record is written (the artifact
    // survives a failure), and only in full-budget runs with parallel
    // headroom — the CI smoke's tiny budgets make wall-clock ratios too
    // noisy to gate a pipeline on (the JSON still records them).
    if !smoke && threads >= 4 {
        let best = rollout_8v1.max(live_8v1);
        assert!(
            best >= 2.0,
            "vec-env lanes=8 speedup {best:.2}x < 2x on {threads} workers \
             (rollout {rollout_8v1:.2}x, live {live_8v1:.2}x)"
        );
    }
    Ok(())
}

/// Actor-learner mode sweep (DESIGN.md §11): live-update lane-steps/sec,
/// `learner=async` head-to-head against `learner=inline` at lanes ∈
/// {4, 8, 16} — the async learner moves the SAC/wm/sur update work off
/// the rollout's critical path onto its reserved core, so the rollout
/// step rate should rise wherever update time was a visible step-time
/// share. Emits `out/bench/BENCH_learner.json` (rates, gains and the
/// learner's own counters) in both normal and `BENCH_SMOKE` modes.
fn learner_mode_sweep(smoke: bool) -> Result<()> {
    let lane_counts = [4usize, 8, 16];
    let episodes = if smoke { 12 } else { 48 };
    let total = parallel::num_threads();

    println!(
        "\n== bench_search: actor-learner mode sweep (native backend, {total} \
         cores, live updates) =="
    );

    let run_mode = |learner: &str, lanes: usize| -> Result<(f64, Option<rl::LearnerReport>)> {
        let mut cfg = RunConfig::default();
        cfg.backend = BackendSel::Native;
        cfg.artifacts_dir = "/nonexistent-artifacts".into();
        cfg.rl.episodes_per_node = episodes;
        cfg.rl.warmup_steps = 1; // prefilled replay: updates from step 0
        cfg.apply("learner", learner).map_err(silicon_rl::error::Error::msg)?;
        let be = backend::load(&cfg.artifacts_dir, cfg.backend)?;
        let mut rng = Rng::new(42);
        let mut agent = SacAgent::new(be, cfg.rl, &mut rng)?;
        prefill_replay(&mut agent, &mut rng);
        let jobs: Vec<rl::LaneSpec> = (0..lanes)
            .map(|i| rl::LaneSpec { nm: 7, seed: rl::multiseed::derive_seed(cfg.seed, i) })
            .collect();
        // the async/pinned runs give up one rollout core to the learner —
        // that cost is part of what's being measured
        let threads = cfg.rollout_threads();
        let t0 = Instant::now();
        let (results, report) =
            rl::run_jobs_stats(&cfg, &jobs, lanes, &mut agent, threads)?;
        let dt = t0.elapsed().as_secs_f64();
        let sps = (lanes * episodes) as f64 / dt.max(1e-9);
        let rs = rl::vecenv::reward_stats(&results);
        let counters = report
            .as_ref()
            .map(|r| {
                format!(
                    ", {} updates, hw {}, behind {:.1}",
                    r.sac_updates, r.queue_highwater, r.mean_lanes_behind
                )
            })
            .unwrap_or_default();
        println!(
            "  [{learner:<6}] lanes={lanes:<2} {sps:>8.1} lane-steps/s ({dt:>6.2}s, \
             {} episodes{counters})",
            rs.count()
        );
        Ok((sps, report))
    };

    let mut rows: Vec<(String, f64)> = Vec::new();
    let mut gains: Vec<(String, f64)> = Vec::new();
    let mut counter_fields: Vec<(String, json::Json)> = Vec::new();
    for &lanes in &lane_counts {
        let (inline_sps, _) = run_mode("inline", lanes)?;
        let (async_sps, report) = run_mode("async", lanes)?;
        rows.push((format!("inline_steps_per_s_lanes{lanes}"), inline_sps));
        rows.push((format!("async_steps_per_s_lanes{lanes}"), async_sps));
        gains.push((
            format!("async_gain_lanes{lanes}"),
            async_sps / inline_sps.max(1e-12),
        ));
        if let Some(r) = report {
            counter_fields.push((
                format!("async_lanes{lanes}"),
                json::obj(vec![
                    ("steps", json::num(r.steps as f64)),
                    ("sac_updates", json::num(r.sac_updates as f64)),
                    ("wm_updates", json::num(r.wm_updates as f64)),
                    ("sur_updates", json::num(r.sur_updates as f64)),
                    ("snapshots", json::num(r.snapshots as f64)),
                    ("queue_highwater", json::num(r.queue_highwater as f64)),
                    ("mean_lanes_behind", json::num(r.mean_lanes_behind)),
                ]),
            ));
        }
    }
    for (k, v) in &gains {
        println!("  {k}: {v:.2}x");
    }

    let section = |rows: &[(String, f64)]| {
        json::obj(rows.iter().map(|(k, v)| (k.as_str(), json::num(*v))).collect())
    };
    let mut fields = vec![
        ("bench", json::s("bench_learner")),
        ("smoke", json::num(if smoke { 1.0 } else { 0.0 })),
        ("cores", json::num(total as f64)),
        ("episodes", json::num(episodes as f64)),
        ("rates", section(&rows)),
        ("gains", section(&gains)),
    ];
    let counter_fields: Vec<(&str, json::Json)> =
        counter_fields.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
    fields.extend(counter_fields);
    let record = json::obj(fields);
    std::fs::create_dir_all("out/bench")?;
    silicon_rl::util::fsio::atomic_write_str(
        "out/bench/BENCH_learner.json",
        &record.to_string_pretty(),
    )?;
    println!("record: out/bench/BENCH_learner.json");

    // acceptance gate: a measurable async step-rate gain at lanes ≥ 8.
    // Full-budget runs with real parallel headroom only — smoke budgets
    // and starved hosts make wall-clock ratios noise (the JSON records
    // them regardless).
    if !smoke && total >= 8 {
        let best = gains
            .iter()
            .filter(|(k, _)| k.ends_with("lanes8") || k.ends_with("lanes16"))
            .map(|(_, v)| *v)
            .fold(f64::NAN, f64::max);
        assert!(
            best >= 1.05,
            "async learner gain {best:.2}x < 1.05x at lanes >= 8 on {total} cores"
        );
    }
    Ok(())
}

/// Atlas sweep reuse case (DESIGN.md §12): a reduced scenario grid —
/// 1 workload × 2 nodes × decode × 1 seq_len × batches {1, 2, 4, 8} —
/// swept twice: the no-reuse baseline (`atlas_prune=off atlas_warm=off`,
/// every point an independent cold search) against the full reuse stack
/// (roofline dominance pruning + shared outcome/geometry caches + warm
/// agents + wave scheduling). Emits `out/bench/BENCH_atlas.json` in both
/// normal and `BENCH_SMOKE` modes; acceptance is ≥2× wall-clock with
/// nonzero prune and cache-reuse counters.
fn atlas_sweep(smoke: bool) -> Result<()> {
    let episodes = if smoke { 8 } else { 24 };
    let threads = parallel::num_threads();

    println!(
        "\n== bench_search: atlas sweep — reuse stack vs no-reuse baseline \
         ({threads} workers) =="
    );

    let run = |prune: bool, warm: bool| -> Result<(rl::AtlasResult, f64)> {
        let mut cfg = RunConfig::default();
        cfg.backend = BackendSel::Native;
        cfg.artifacts_dir = "/nonexistent-artifacts".into();
        cfg.rl.episodes_per_node = episodes;
        // rollout-only lanes: the bench measures search reuse, not
        // update throughput
        cfg.rl.warmup_steps = 10_000;
        cfg.nodes_nm = vec![7, 22];
        cfg.atlas.workloads = vec!["llama-3.2-1b".into()];
        cfg.atlas.phases = vec![silicon_rl::ir::Phase::Decode];
        cfg.atlas.seq_lens = vec![2048];
        cfg.atlas.batches = vec![1, 2, 4, 8];
        cfg.atlas.prune = prune;
        cfg.atlas.warm = warm;
        cfg.atlas.shrink = 0;
        let t0 = Instant::now();
        let res = rl::atlas::run(&cfg)?;
        Ok((res, t0.elapsed().as_secs_f64()))
    };

    let (base, dt_base) = run(false, false)?;
    let (reuse, dt_reuse) = run(true, true)?;
    let speedup = dt_base / dt_reuse.max(1e-9);

    let c = &reuse.counters;
    println!(
        "  baseline: {dt_base:>6.2}s ({} episodes over {} points)",
        base.counters.episodes_run, base.counters.points
    );
    println!(
        "  reuse:    {dt_reuse:>6.2}s ({} episodes, {} pruned: {} fast / {} \
         amortized) -> {speedup:.2}x",
        c.episodes_run,
        c.pruned(),
        c.prune_fast,
        c.prune_amortized
    );
    println!(
        "  shared state: {} cache hits / {} misses, {} geometry tables shared",
        reuse.eval_stats.outcome_hits,
        reuse.eval_stats.outcome_misses,
        reuse.eval_stats.geom_shared
    );

    let frontier_points =
        |r: &rl::AtlasResult| r.points.iter().map(|p| p.frontier.len() as f64).sum::<f64>();
    let record = json::obj(vec![
        ("bench", json::s("bench_atlas")),
        ("smoke", json::num(if smoke { 1.0 } else { 0.0 })),
        ("workers", json::num(threads as f64)),
        ("episodes_per_point", json::num(episodes as f64)),
        ("grid_points", json::num(base.counters.points as f64)),
        ("baseline_s", json::num(dt_base)),
        ("reuse_s", json::num(dt_reuse)),
        ("speedup", json::num(speedup)),
        ("baseline_episodes", json::num(base.counters.episodes_run as f64)),
        ("reuse_episodes", json::num(c.episodes_run as f64)),
        ("pruned", json::num(c.pruned() as f64)),
        ("prune_fast", json::num(c.prune_fast as f64)),
        ("prune_amortized", json::num(c.prune_amortized as f64)),
        ("cache_hits", json::num(reuse.eval_stats.outcome_hits as f64)),
        ("cache_misses", json::num(reuse.eval_stats.outcome_misses as f64)),
        ("geom_shared", json::num(reuse.eval_stats.geom_shared as f64)),
        ("baseline_frontier_points", json::num(frontier_points(&base))),
        ("reuse_frontier_points", json::num(frontier_points(&reuse))),
    ]);
    std::fs::create_dir_all("out/bench")?;
    silicon_rl::util::fsio::atomic_write_str(
        "out/bench/BENCH_atlas.json",
        &record.to_string_pretty(),
    )?;
    println!("record: out/bench/BENCH_atlas.json");

    // acceptance gate: ≥2× wall-clock from the reuse stack with nonzero
    // prune and cache-reuse counters. Checked after the record is written
    // (the artifact survives a failure) and only in full-budget runs with
    // parallel headroom — smoke budgets make wall-clock ratios noise (the
    // JSON still records them).
    if !smoke && threads >= 4 {
        assert!(c.pruned() > 0, "atlas reuse run pruned no points");
        assert!(
            reuse.eval_stats.outcome_hits + reuse.eval_stats.geom_shared > 0,
            "atlas reuse run shows no cache/geometry reuse"
        );
        assert!(
            speedup >= 2.0,
            "atlas reuse speedup {speedup:.2}x < 2x on {threads} workers \
             (baseline {dt_base:.2}s vs reuse {dt_reuse:.2}s)"
        );
    }
    Ok(())
}
