//! Table 21 — search-strategy comparison at 3nm: SAC (ours) vs random
//! search vs grid search under the same episode budget and evaluation
//! pipeline — plus the evaluation-layer scaling case: a 7-node ×
//! multi-seed random-search sweep driven serially and in parallel, with
//! a bit-identical-results check (the paper's claim shape for SAC: a
//! better score, much higher throughput, many more feasible configs).
//!
//! Budget: SILICON_RL_BENCH_EPISODES (default 1000; paper used ~4,600).
//! Sweep budget: SILICON_RL_BENCH_SWEEP_EPISODES (default 60/node/seed).

use std::path::Path;

use silicon_rl::config::RunConfig;
use silicon_rl::error::Result;
use silicon_rl::eval::parallel;
use silicon_rl::nn::backend;
use silicon_rl::report;
use silicon_rl::rl::{self, baselines, SacAgent};
use silicon_rl::util::Rng;

fn main() -> Result<()> {
    let eps = std::env::var("SILICON_RL_BENCH_EPISODES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1000);
    let mut cfg = RunConfig::default();
    cfg.rl.episodes_per_node = eps;
    cfg.rl.warmup_steps = 256.min(eps / 2 + 1);
    let nm = 3;

    println!("== bench_search: Table 21 at {nm}nm, {eps} episodes each ==");
    let mut rng = Rng::new(cfg.seed);

    let t0 = std::time::Instant::now();
    let rand_r = baselines::random_search(&cfg, nm, &mut rng.fork(1));
    println!("random search: {:.1}s", t0.elapsed().as_secs_f64());

    let t0 = std::time::Instant::now();
    let grid_r = baselines::grid_search(&cfg, nm, &mut rng.fork(2));
    println!("grid search:   {:.1}s", t0.elapsed().as_secs_f64());

    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let sac_r = {
        // strict evaluation-count parity with the baselines: disable the
        // MPC real-eval re-ranking so every strategy performs exactly one
        // evaluation per budgeted episode
        let mut sac_cfg = cfg.clone();
        sac_cfg.rl.mpc_rerank = 0;
        sac_cfg.artifacts_dir = dir.to_string_lossy().to_string();
        let be = backend::load(&sac_cfg.artifacts_dir, sac_cfg.backend)?;
        println!("SAC backend:   {}", be.describe());
        let mut agent = SacAgent::new(be, sac_cfg.rl, &mut rng)?;
        let t0 = std::time::Instant::now();
        let r = rl::run_node(&sac_cfg, nm, &mut agent, &mut rng)?;
        println!("SAC:           {:.1}s", t0.elapsed().as_secs_f64());
        Some(r)
    };

    let mut entries: Vec<(&str, &rl::NodeResult)> =
        vec![("Random Search", &rand_r), ("Grid Search", &grid_r)];
    if let Some(r) = &sac_r {
        entries.push(("SAC (ours)", r));
    }
    let t = report::search_comparison(&entries);
    println!("\n{}", t.to_text());
    std::fs::create_dir_all("out/bench")?;
    t.write_csv(Path::new("out/bench/table21_search.csv"))?;

    if let Some(sac) = &sac_r {
        let sac_tok = sac.best.as_ref().map(|b| b.outcome.ppa.tokens_per_s).unwrap_or(0.0);
        let rand_tok =
            rand_r.best.as_ref().map(|b| b.outcome.ppa.tokens_per_s).unwrap_or(1.0);
        println!(
            "SAC vs random: {:.2}x throughput, {:.2}x feasible configs (paper: 3.5x, 9.1x)",
            sac_tok / rand_tok,
            sac.feasible_count as f64 / rand_r.feasible_count.max(1) as f64
        );
    }

    node_sweep_scaling()?;
    Ok(())
}

/// Evaluation-layer scaling case: the full 7-node sweep × multi-seed
/// random search, serial (1 worker) vs parallel (all workers). Asserts
/// the two produce bit-identical statistics, then reports wall-clock
/// speedup (expect ≳3× on a 4-core machine: seeds × candidate sets both
/// fan out through the same stateless evaluator).
fn node_sweep_scaling() -> Result<()> {
    let sweep_eps = std::env::var("SILICON_RL_BENCH_SWEEP_EPISODES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(60);
    let n_seeds = 4;
    let workers = parallel::num_threads();
    let mut cfg = RunConfig::default();
    cfg.rl.episodes_per_node = sweep_eps;

    println!(
        "\n== bench_search: 7-node x {n_seeds}-seed sweep, {sweep_eps} episodes \
         (1 vs {workers} workers) =="
    );

    let run = |threads: usize| -> (Vec<rl::MultiSeedResult>, f64) {
        let t0 = std::time::Instant::now();
        let results: Vec<rl::MultiSeedResult> = cfg
            .nodes_nm
            .iter()
            .map(|&nm| {
                rl::run_seeds_t(&cfg, nm, n_seeds, threads, |c, nm, rng| {
                    baselines::random_search_t(c, nm, rng, 1)
                })
            })
            .collect();
        (results, t0.elapsed().as_secs_f64())
    };

    let (serial, dt_serial) = run(1);
    let (par, dt_par) = run(workers);

    // determinism: the parallel driver must reproduce the serial sweep
    // bit-for-bit
    for (s, p) in serial.iter().zip(&par) {
        assert_eq!(s.seeds, p.seeds, "{}nm: seed derivation diverged", s.nm);
        assert_eq!(
            s.score.mean.to_bits(),
            p.score.mean.to_bits(),
            "{}nm: best-score mean diverged between serial and parallel",
            s.nm
        );
        assert_eq!(
            s.tokens_per_s.mean.to_bits(),
            p.tokens_per_s.mean.to_bits(),
            "{}nm: throughput mean diverged",
            s.nm
        );
        assert_eq!(s.pareto.len(), p.pareto.len(), "{}nm: frontier diverged", s.nm);
    }
    println!("determinism: serial and parallel sweeps bit-identical across 7 nodes");

    let t = rl::seeds_table(&par);
    println!("{}", t.to_text());
    std::fs::create_dir_all("out/bench")?;
    t.write_csv(Path::new("out/bench/multiseed_sweep.csv"))?;
    println!(
        "sweep wall-clock: serial {dt_serial:.1}s, parallel {dt_par:.1}s -> {:.2}x \
         speedup on {workers} workers",
        dt_serial / dt_par.max(1e-9)
    );
    Ok(())
}
