//! Fuzz-harness throughput (DESIGN.md §14).
//!
//! Times the randomized equivalence sweep per oracle class — cases per
//! second of generate → pair-execute → compare — so regressions in the
//! paired-execution cost (an evaluator slowdown, an accidental
//! quadratic in the diff walk) show up in the perf record. Only the
//! evaluator-layer classes are timed: the engine classes (vec-serial,
//! crash-resume, pinned-inline) run full searches and belong to the
//! checkpoint/runtime benches; `simd-scalar` flips process-global
//! kernel dispatch and is CLI-only by repo convention.
//!
//! Every timed case must also come back clean, so the bench doubles as
//! a larger randomized sweep than the tier-1 smoke. Results land in
//! `out/bench/BENCH_fuzz.json`; `BENCH_SMOKE=1` shrinks the budget to
//! CI size.

use std::time::Instant;

use silicon_rl::error::Result;
use silicon_rl::rl::fuzz::{self, CaseGen};
use silicon_rl::util::{fsio, json};

const CLASSES: [&str; 4] =
    ["serial-parallel", "staged-fresh", "pruned-exact", "cache-nocache"];

fn main() -> Result<()> {
    let smoke = std::env::var("BENCH_SMOKE").ok().as_deref() == Some("1");
    let iters: usize = std::env::var("SILICON_RL_BENCH_FUZZ_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if smoke { 4 } else { 16 });

    println!("== bench_fuzz: {iters} cases per class ==");

    let mut fields = vec![
        ("bench", json::s("fuzz")),
        ("smoke", json::Json::Bool(smoke)),
        ("iters_per_class", json::num(iters as f64)),
    ];

    let mut total_cases = 0usize;
    let mut total_s = 0.0f64;
    for class in CLASSES {
        let mut casegen = CaseGen::new(42, &[class])?;
        let t0 = Instant::now();
        for i in 0..iters {
            let case = casegen.next_case();
            if let Some(m) = fuzz::run_case(&case)? {
                panic!("case {i} ({}) violated its contract: {m}", case.cmd_line());
            }
        }
        let t = t0.elapsed().as_secs_f64();
        let rate = iters as f64 / t.max(1e-9);
        println!("{class:>16}: {t:.2}s ({rate:.1} cases/s)");
        // json keys want '_' over '-' for downstream tooling
        let key: &'static str = match class {
            "serial-parallel" => "serial_parallel_s",
            "staged-fresh" => "staged_fresh_s",
            "pruned-exact" => "pruned_exact_s",
            _ => "cache_nocache_s",
        };
        fields.push((key, json::num(t)));
        total_cases += iters;
        total_s += t;
    }

    println!(
        "total: {total_cases} cases in {total_s:.2}s ({:.1} cases/s)",
        total_cases as f64 / total_s.max(1e-9)
    );
    fields.push(("total_s", json::num(total_s)));
    fields.push(("cases_per_s", json::num(total_cases as f64 / total_s.max(1e-9))));

    let record = json::obj(fields);
    std::fs::create_dir_all("out/bench")?;
    fsio::atomic_write_str("out/bench/BENCH_fuzz.json", &record.to_string_pretty())?;
    println!("record: out/bench/BENCH_fuzz.json");
    Ok(())
}
