//! Regenerates the paper's per-node evaluation — Tables 10/11/12/13/15/16
//! /17/18/19 and the Fig 3–12 data series — by running the full
//! Algorithm 1 (SAC over the configured NN backend: PJRT artifacts when
//! built, the native kernels otherwise) per process node for both
//! workloads, at a CI-scale episode budget.
//!
//! Episode budget: SILICON_RL_BENCH_EPISODES (default 1000; the paper used
//! 4,613/node — pass the full budget for a faithful run). Shape, not
//! absolute tok/s, is the claim at reduced budgets.

use std::path::Path;

use silicon_rl::config::RunConfig;
use silicon_rl::error::Result;
use silicon_rl::nn::backend;
use silicon_rl::report::{self, NodeSummary};
use silicon_rl::rl::{self, SacAgent};
use silicon_rl::util::Rng;

fn episodes() -> usize {
    std::env::var("SILICON_RL_BENCH_EPISODES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1000)
}

fn main() -> Result<()> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let artifacts_dir = dir.to_string_lossy().to_string();
    let out = Path::new("out/bench");
    std::fs::create_dir_all(out)?;
    let eps = episodes();

    // ---------------- Llama 3.1 8B, high-performance (Tables 10-18)
    let mut cfg = RunConfig::default();
    cfg.rl.episodes_per_node = eps;
    cfg.rl.warmup_steps = 256.min(eps / 2 + 1);
    cfg.artifacts_dir = artifacts_dir.clone();
    let be = backend::load(&cfg.artifacts_dir, cfg.backend)?;
    println!("backend: {}", be.describe());
    let mut rng = Rng::new(cfg.seed);
    let mut agent = SacAgent::new(be, cfg.rl, &mut rng)?;

    println!("== bench_nodes: Llama 3.1 8B high-performance, {eps} episodes/node ==");
    let mut results = Vec::new();
    for &nm in &cfg.nodes_nm.clone() {
        let t0 = std::time::Instant::now();
        let r = rl::run_node(&cfg, nm, &mut agent, &mut rng)?;
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "  {nm:>2}nm done in {dt:>6.1}s ({:.1} ms/episode, {} feasible)",
            dt * 1000.0 / eps as f64,
            r.feasible_count
        );
        report::convergence_csv(&r.episodes)
            .write_csv(&out.join(format!("fig3_convergence_{nm}nm.csv")))?;
        results.push(r);
    }

    let rows: Vec<NodeSummary> =
        results.iter().filter_map(NodeSummary::from_result).collect();
    let t10 = report::nodes_table(&rows);
    let t12 = report::power_breakdown(&rows);
    let t13 = report::scaling_analysis(&rows);
    let t18 = report::efficiency_table(&rows);
    println!("\n{}", t10.to_text());
    println!("{}", t12.to_text());
    println!("{}", t13.to_text());
    println!("{}", t18.to_text());
    t10.write_csv(&out.join("table10_nodes.csv"))?;
    t12.write_csv(&out.join("table12_power.csv"))?;
    t13.write_csv(&out.join("table13_scaling.csv"))?;
    t18.write_csv(&out.join("table18_efficiency.csv"))?;

    if let Some(best) = results.iter().filter(|r| r.best.is_some()).min_by(|a, b| {
        a.best_outcome().reward.score.total_cmp(&b.best_outcome().reward.score)
    }) {
        let o = best.best_outcome();
        let t15 = report::tile_regions(&o.decoded.mesh, &o.tiles);
        let t16 = report::tile_param_summary(&o.tiles);
        println!("{}", t15.to_text());
        println!("{}", t16.to_text());
        t15.write_csv(&out.join("table15_regions.csv"))?;
        t16.write_csv(&out.join("table16_tiles.csv"))?;
    }
    if rows.len() >= 2 {
        // high-performance mode compares the highest-throughput node
        // (3nm in the paper) against the oldest node
        let best = rows
            .iter()
            .max_by(|a, b| a.tokens_per_s.total_cmp(&b.tokens_per_s))
            .unwrap();
        let worst = rows.iter().max_by_key(|r| r.nm).unwrap();
        let t17 = report::cross_node_compare(best, worst);
        println!("{}", t17.to_text());
        t17.write_csv(&out.join("table17_compare.csv"))?;
    }
    println!("{}", report::industry_comparison(rows.first()).to_text());

    // ---------------- SmolVLM, low-power (Table 19)
    let mut cfg_lp = RunConfig::smolvlm_low_power();
    cfg_lp.rl.episodes_per_node = eps;
    cfg_lp.rl.warmup_steps = 256.min(eps / 2 + 1);
    cfg_lp.artifacts_dir = artifacts_dir;
    let be = backend::load(&cfg_lp.artifacts_dir, cfg_lp.backend)?;
    let mut agent = SacAgent::new(be, cfg_lp.rl, &mut rng)?;
    println!("== bench_nodes: SmolVLM low-power, {eps} episodes/node ==");
    let mut lp_results = Vec::new();
    for &nm in &cfg_lp.nodes_nm.clone() {
        let r = rl::run_node(&cfg_lp, nm, &mut agent, &mut rng)?;
        lp_results.push(r);
    }
    let lp_rows: Vec<NodeSummary> =
        lp_results.iter().filter_map(NodeSummary::from_result).collect();
    let t19 = report::nodes_table(&lp_rows);
    println!("\n{}", t19.to_text());
    t19.write_csv(&out.join("table19_smolvlm.csv"))?;
    let under13 = lp_rows.iter().filter(|r| r.power.total() < 13.0).count();
    println!(
        "SmolVLM: {under13}/{} nodes under 13 mW (paper: 7/7)",
        lp_rows.len()
    );
    println!("CSVs in {}", out.display());
    Ok(())
}
