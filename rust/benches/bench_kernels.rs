//! Per-kernel scalar-vs-SIMD throughput (DESIGN.md §10): the seven
//! dispatched NN kernels at the SAC hot-loop shapes (B=1 actor forward,
//! K=64 MPC surrogate batch, B=256 fused update), plus the f64
//! placement-scoring kernel the evaluator dispatches. Reports ns/op and
//! GFLOP/s per kernel per mode and emits `out/bench/BENCH_kernels.json`
//! in both normal and `BENCH_SMOKE=1` modes.
//!
//! The bench binary is its own process, so it may flip the process-
//! global kernel path freely (the same rule the `kernel_parity` test
//! binary relies on); each measurement installs its mode up front.

use silicon_rl::arch::MeshConfig;
use silicon_rl::nn::kernels::{self, KernelSel};
use silicon_rl::nn::math::{self, AdamStep};
use silicon_rl::noc::{MeshGeom, ScoreParams};
use silicon_rl::util::bench::Bencher;
use silicon_rl::util::{json, Rng};

/// (m, k, n) matmul shapes of Algorithm 1's NN hot loop.
const MM_SHAPES: [(usize, usize, usize); 5] = [
    (1, 52, 256),    // actor forward, B=1 (policy latency)
    (1, 256, 256),   // hidden layer, B=1
    (64, 82, 256),   // MPC surrogate scoring, K=64
    (256, 256, 256), // fused SAC update, hidden
    (256, 256, 120), // fused SAC update, joint-action head
];

fn filled(len: usize, rng: &mut Rng, lo: f64, hi: f64) -> Vec<f32> {
    (0..len).map(|_| rng.uniform_in(lo, hi) as f32).collect()
}

/// One full pass over every kernel in mode `sel`; returns
/// (metric name, mean seconds, flops per op) rows.
fn bench_mode(sel: KernelSel, b: &mut Bencher) -> Vec<(String, f64, f64)> {
    kernels::set_global(sel);
    let tag = kernels::active().name();
    let mut rng = Rng::new(7);
    let mut rows = Vec::new();

    for (m, k, n) in MM_SHAPES {
        let x = filled(m * k, &mut rng, -1.0, 1.0);
        let w = filled(k * n, &mut rng, -0.5, 0.5);
        let bias = filled(n, &mut rng, -0.2, 0.2);
        let dy = filled(m * n, &mut rng, -1.0, 1.0);
        let mut y = vec![0.0f32; m * n];
        let mut dx = vec![0.0f32; m * k];
        let mut dw = vec![0.0f32; k * n];
        let mut db = vec![0.0f32; n];
        let flops = 2.0 * (m * k * n) as f64;

        let t = b
            .bench(&format!("[{tag}] matmul_bias {m}x{k}x{n}"), || {
                math::matmul_bias(&x, &w, &bias, &mut y, m, k, n)
            })
            .mean_s();
        rows.push((format!("matmul_bias_{m}x{k}x{n}_s"), t, flops));
        let t = b
            .bench(&format!("[{tag}] matmul_wt {m}x{k}x{n}"), || {
                math::matmul_wt(&dy, &w, &mut dx, m, k, n)
            })
            .mean_s();
        rows.push((format!("matmul_wt_{m}x{k}x{n}_s"), t, flops));
        let t = b
            .bench(&format!("[{tag}] grad_w_b {m}x{k}x{n}"), || {
                math::grad_w_b(&x, &dy, &mut dw, &mut db, m, k, n)
            })
            .mean_s();
        rows.push((format!("grad_w_b_{m}x{k}x{n}_s"), t, flops));
    }

    // elementwise kernels at the fused-update activation size (B=256 x HID)
    let len = 256 * 256;
    let z = filled(len, &mut rng, -4.0, 4.0);
    let mut h = vec![0.0f32; len];
    let t = b
        .bench(&format!("[{tag}] gelu_map {len}"), || math::gelu_map(&z, &mut h))
        .mean_s();
    rows.push((format!("gelu_map_{len}_s"), t, len as f64));
    let mut g = filled(len, &mut rng, -1.0, 1.0);
    let t = b
        .bench(&format!("[{tag}] gelu_bwd {len}"), || math::gelu_bwd_inplace(&mut g, &z))
        .mean_s();
    rows.push((format!("gelu_bwd_{len}_s"), t, len as f64));

    // softmax over the 5-way discrete heads, B=256 rows
    let logits = filled(256 * 20, &mut rng, -6.0, 6.0);
    let mut sm = logits.clone();
    let t = b
        .bench(&format!("[{tag}] softmax_rows 256x20"), || {
            sm.copy_from_slice(&logits);
            math::softmax_rows(&mut sm, 20)
        })
        .mean_s();
    rows.push(("softmax_rows_256x20_s".into(), t, (256 * 20) as f64));

    // one Adam step over a hidden weight matrix
    let gr = filled(len, &mut rng, -0.1, 0.1);
    let mut p = filled(len, &mut rng, -1.0, 1.0);
    let mut m1 = vec![0.0f32; len];
    let mut v1 = vec![0.001f32; len];
    let a = AdamStep::new(3e-4, 0.9, 0.999, 1e-8, 10.0);
    let t = b
        .bench(&format!("[{tag}] adam_apply {len}"), || {
            a.apply(&mut p, &gr, &mut m1, &mut v1)
        })
        .mean_s();
    rows.push((format!("adam_apply_{len}_s"), t, len as f64));

    // f64 placement scoring on a 12x12 mesh (the evaluator's inner loop)
    let geom = MeshGeom::build(&MeshConfig::new(12, 12));
    let nt = geom.xy.len();
    let flops_t: Vec<f64> = (0..nt).map(|t| (t * 13 % 29) as f64 * 3.7e7).collect();
    let weights_t: Vec<f64> = (0..nt).map(|t| (t * 7 % 17) as f64 * 1.1e5).collect();
    let act_t: Vec<f64> = (0..nt).map(|t| (t * 5 % 11) as f64 * 2048.0).collect();
    let params = ScoreParams {
        wl: 1.3,
        inv_mean_f: 1.0 / 3.7e7,
        inv_mean_w: 1.0 / 1.1e5,
        mean_f: 3.7e7,
        inv_span: 1.0 / 24.0,
        central_w: 0.3,
        prod_xy: Some(geom.xy[nt / 2]),
    };
    let mut out = vec![0.0f64; nt];
    let t = b
        .bench(&format!("[{tag}] score_tiles 12x12"), || {
            geom.score_tiles(&params, &flops_t, &weights_t, &act_t, &mut out)
        })
        .mean_s();
    rows.push(("score_tiles_12x12_s".into(), t, (nt * 10) as f64));

    kernels::set_global(KernelSel::Scalar);
    rows
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    let mut b = Bencher::default();
    if smoke {
        b.warmup = std::time::Duration::from_millis(20);
        b.budget = std::time::Duration::from_millis(120);
        b.max_samples = 30;
    }

    println!("== bench_kernels: scalar vs SIMD NN/scoring kernels ==");
    println!("dispatch: {}", kernels::describe(KernelSel::Auto));

    let scalar_rows = bench_mode(KernelSel::Scalar, &mut b);
    let simd_rows = kernels::detect().map(|_| bench_mode(KernelSel::Simd, &mut b));

    println!("\n{:<34} {:>12} {:>10}", "kernel", "ns/op", "GFLOP/s");
    let gflops = |t: f64, flops: f64| flops / t.max(1e-12) / 1e9;
    for (name, t, flops) in &scalar_rows {
        print!("{:<34} {:>12.0} {:>10.2}", format!("scalar {name}"), t * 1e9, gflops(*t, *flops));
        if let Some(simd) = &simd_rows {
            let (_, ts, _) = &simd[scalar_rows.iter().position(|(n, _, _)| n == name).unwrap()];
            print!("   simd {:>10.0} ns ({:.2}x)", ts * 1e9, t / ts.max(1e-12));
        }
        println!();
    }

    let section = |rows: &[(String, f64, f64)]| {
        json::obj(rows.iter().map(|(k, v, _)| (k.as_str(), json::num(*v))).collect())
    };
    let mut record = vec![
        ("bench", json::s("bench_kernels")),
        ("smoke", json::num(if smoke { 1.0 } else { 0.0 })),
        (
            "detected",
            json::s(kernels::detect().map(|p| p.name()).unwrap_or("none")),
        ),
        ("scalar", section(&scalar_rows)),
    ];
    if let Some(simd) = &simd_rows {
        record.push(("simd", section(simd)));
        let speedups: Vec<(&str, json::Json)> = scalar_rows
            .iter()
            .zip(simd)
            .map(|((k, s, _), (_, v, _))| (k.as_str(), json::num(s / v.max(1e-12))))
            .collect();
        record.push(("simd_speedup", json::obj(speedups)));
    } else {
        println!("\nno SIMD path on this host — scalar rows only");
    }
    let record = json::obj(record);
    if let Err(e) = std::fs::create_dir_all("out/bench") {
        eprintln!("out/bench: {e}");
    }
    let _ = silicon_rl::util::fsio::atomic_write_str(
        "out/bench/BENCH_kernels.json",
        &record.to_string_pretty(),
    );
    b.write_csv("out/bench/bench_kernels.csv");
    println!("records: out/bench/BENCH_kernels.json, out/bench/bench_kernels.csv");
}
