//! Checkpoint overhead and kill-and-resume timing (DESIGN.md §13).
//!
//! Runs the same 4-lane vec-env search three times — no checkpointing,
//! `checkpoint_every=8`, and the pathological `checkpoint_every=1` — and
//! records the wall-clock overhead of periodic snapshots, the on-disk
//! generation size, and the cost of a resume (newest-generation load +
//! replayed tail). Results land in `out/bench/BENCH_checkpoint.json` for
//! the report pipeline; `BENCH_SMOKE=1` shrinks the budget to CI size.
//!
//! The bit-identity of the resumed results is asserted here too — the
//! bench doubles as an end-to-end kill-and-resume smoke on a realistic
//! episode budget (the fine-grained contract lives in
//! `tests/checkpoint.rs`).

use std::path::Path;
use std::time::Instant;

use silicon_rl::config::RunConfig;
use silicon_rl::error::Result;
use silicon_rl::nn::backend::{self, BackendSel};
use silicon_rl::rl::checkpoint::INJECTED_CRASH_MSG;
use silicon_rl::rl::{self, LaneSpec, NodeResult, SacAgent};
use silicon_rl::util::{fsio, json, Rng};

const SPECS: [LaneSpec; 4] = [
    LaneSpec { nm: 7, seed: 7 },
    LaneSpec { nm: 7, seed: 42 },
    LaneSpec { nm: 28, seed: 7 },
    LaneSpec { nm: 28, seed: 42 },
];

fn base_cfg(episodes: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.backend = BackendSel::Native;
    cfg.artifacts_dir = "/nonexistent-artifacts".into();
    cfg.rl.episodes_per_node = episodes;
    cfg.rl.warmup_steps = 8;
    cfg
}

fn fresh_agent(cfg: &RunConfig) -> Result<SacAgent> {
    let be = backend::load(&cfg.artifacts_dir, cfg.backend)?;
    SacAgent::new(be, cfg.rl, &mut Rng::new(42))
}

fn timed_run(cfg: &RunConfig) -> Result<(Vec<NodeResult>, SacAgent, f64)> {
    let mut agent = fresh_agent(cfg)?;
    let t0 = Instant::now();
    let (results, _) = rl::run_jobs_stats(cfg, &SPECS, SPECS.len(), &mut agent, 2)?;
    Ok((results, agent, t0.elapsed().as_secs_f64()))
}

fn main() -> Result<()> {
    let smoke = std::env::var("BENCH_SMOKE").ok().as_deref() == Some("1");
    let eps = std::env::var("SILICON_RL_BENCH_CKPT_EPISODES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if smoke { 48 } else { 200 });
    let scratch = "out/bench/ckpt_scratch";
    let _ = std::fs::remove_dir_all(scratch);

    println!("== bench_checkpoint: 4 lanes x {eps} episodes ==");

    // baseline: no checkpointing
    let cfg0 = base_cfg(eps);
    let (base_results, base_agent, t_base) = timed_run(&cfg0)?;
    println!("checkpoint_every=0: {t_base:.2}s");

    // periodic snapshots at two cadences
    let mut t_every = Vec::new();
    for every in [8usize, 1] {
        let mut cfg = cfg0.clone();
        cfg.out_dir = format!("{scratch}/every{every}");
        cfg.rl.checkpoint_every = every;
        let (_, _, t) = timed_run(&cfg)?;
        println!(
            "checkpoint_every={every}: {t:.2}s ({:+.1}% vs baseline)",
            (t / t_base - 1.0) * 100.0
        );
        t_every.push((every, t));
    }

    // generation size on disk (newest slot of the every=8 run)
    let ckpt_bytes = ["ckpt-a.bin", "ckpt-b.bin"]
        .iter()
        .filter_map(|f| {
            std::fs::metadata(Path::new(scratch).join("every8/ckpt").join(f)).ok()
        })
        .map(|m| m.len())
        .max()
        .unwrap_or(0);
    println!("generation size: {:.1} KiB", ckpt_bytes as f64 / 1024.0);

    // kill-and-resume: die on the last step's first probe, resume the tail
    let mut ccfg = cfg0.clone();
    ccfg.out_dir = format!("{scratch}/resume");
    ccfg.rl.checkpoint_every = 8;
    ccfg.rl.crash_after = (3 * (eps as u64 - 1)) + 1;
    let crash = timed_run(&ccfg);
    let err = crash.err().expect("injected crash did not fire");
    assert!(format!("{err:#}").contains(INJECTED_CRASH_MSG), "{err:#}");

    let mut rcfg = ccfg.clone();
    rcfg.rl.crash_after = 0;
    rcfg.resume = Some(ccfg.out_dir.clone());
    let (res_results, res_agent, t_resume) = timed_run(&rcfg)?;
    println!("resume (load + replayed tail): {t_resume:.2}s");

    // the resumed end state must be bit-identical to the baseline's
    for (lane, (a, b)) in base_results.iter().zip(&res_results).enumerate() {
        assert_eq!(a.episodes.len(), b.episodes.len(), "lane {lane}: episode count");
        for (x, y) in a.episodes.iter().zip(&b.episodes) {
            assert_eq!(
                x.reward.to_bits(),
                y.reward.to_bits(),
                "lane {lane} ep {}: resume diverged",
                x.episode
            );
        }
        assert_eq!(
            a.pareto.frontier().len(),
            b.pareto.frontier().len(),
            "lane {lane}: frontier size"
        );
    }
    assert_eq!(base_agent.buffer.len(), res_agent.buffer.len(), "replay length");
    println!("resume bit-identity: OK");

    let record = json::obj(vec![
        ("bench", json::s("checkpoint")),
        ("smoke", json::Json::Bool(smoke)),
        ("episodes", json::num(eps as f64)),
        ("lanes", json::num(SPECS.len() as f64)),
        ("baseline_s", json::num(t_base)),
        ("every8_s", json::num(t_every[0].1)),
        ("every1_s", json::num(t_every[1].1)),
        ("overhead_every8_pct", json::num((t_every[0].1 / t_base - 1.0) * 100.0)),
        ("overhead_every1_pct", json::num((t_every[1].1 / t_base - 1.0) * 100.0)),
        ("generation_bytes", json::num(ckpt_bytes as f64)),
        ("resume_s", json::num(t_resume)),
    ]);
    std::fs::create_dir_all("out/bench")?;
    fsio::atomic_write_str("out/bench/BENCH_checkpoint.json", &record.to_string_pretty())?;
    println!("record: out/bench/BENCH_checkpoint.json");
    let _ = std::fs::remove_dir_all(scratch);
    Ok(())
}
