//! Small shared utilities: deterministic RNG, statistics helpers, CSV
//! emission. No external randomness — every stochastic component in the
//! optimizer draws from [`rng::Rng`] so runs are reproducible from a single
//! seed.

pub mod bench;
pub mod csv;
pub mod fsio;
pub mod json;
pub mod rng;
pub mod stats;

pub use rng::Rng;

/// Round `v` up to the next multiple of `m`.
pub fn round_up(v: usize, m: usize) -> usize {
    v.div_ceil(m) * m
}

/// Clamp helper mirroring the paper's `clip(x, lo, hi)` notation.
pub fn clip(x: f64, lo: f64, hi: f64) -> f64 {
    x.max(lo).min(hi)
}

/// Linear interpolation of `x` from `[a0, a1]` onto `[b0, b1]`.
pub fn lerp(x: f64, a0: f64, a1: f64, b0: f64, b1: f64) -> f64 {
    if (a1 - a0).abs() < 1e-12 {
        return b0;
    }
    b0 + (x - a0) * (b1 - b0) / (a1 - a0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn clip_bounds() {
        assert_eq!(clip(5.0, 0.0, 1.0), 1.0);
        assert_eq!(clip(-5.0, 0.0, 1.0), 0.0);
        assert_eq!(clip(0.5, 0.0, 1.0), 0.5);
    }

    #[test]
    fn lerp_endpoints() {
        assert!((lerp(3.0, 3.0, 28.0, 1.0, 0.1) - 1.0).abs() < 1e-12);
        assert!((lerp(28.0, 3.0, 28.0, 1.0, 0.1) - 0.1).abs() < 1e-12);
        // degenerate interval returns b0
        assert_eq!(lerp(1.0, 2.0, 2.0, 7.0, 9.0), 7.0);
    }
}
