//! Tiny criterion-style benchmark harness.
//!
//! criterion is not vendored in this image, so `cargo bench` targets
//! (declared with `harness = false`) use this module: warmup, repeated
//! timed samples, mean/stddev/min reporting, and optional CSV emission so
//! the report pipeline can import bench numbers.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<f64>, // seconds per iteration
}

impl BenchResult {
    pub fn mean_s(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn std_s(&self) -> f64 {
        let m = self.mean_s();
        (self.samples.iter().map(|s| (s - m) * (s - m)).sum::<f64>()
            / self.samples.len() as f64)
            .sqrt()
    }

    pub fn min_s(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} mean {:>12}  std {:>10}  min {:>12}  ({} samples)",
            self.name,
            fmt_dur(self.mean_s()),
            fmt_dur(self.std_s()),
            fmt_dur(self.min_s()),
            self.samples.len()
        )
    }
}

pub fn fmt_dur(s: f64) -> String {
    if s >= 1.0 {
        format!("{:.3} s", s)
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Benchmark runner with a wall-clock budget per benchmark.
pub struct Bencher {
    pub warmup: Duration,
    pub budget: Duration,
    pub max_samples: usize,
    pub results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(3),
            max_samples: 50,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    /// Time `f`, which performs ONE iteration of the benchmarked work and
    /// returns a value kept alive to prevent dead-code elimination.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        // warmup
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // sampling
        let mut samples = Vec::new();
        let b0 = Instant::now();
        while b0.elapsed() < self.budget && samples.len() < self.max_samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        if samples.is_empty() {
            samples.push(f64::NAN);
        }
        let r = BenchResult { name: name.to_string(), samples };
        println!("{}", r.report());
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Emit all results as CSV (name, mean_s, std_s, min_s, samples).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("name,mean_s,std_s,min_s,samples\n");
        for r in &self.results {
            out.push_str(&format!(
                "{},{},{},{},{}\n",
                r.name,
                r.mean_s(),
                r.std_s(),
                r.min_s(),
                r.samples.len()
            ));
        }
        out
    }

    pub fn write_csv(&self, path: &str) {
        if let Some(dir) = std::path::Path::new(path).parent() {
            let _ = std::fs::create_dir_all(dir);
        }
        // atomic: bench CSVs feed the report pipeline; never leave a
        // half-written file behind
        let _ = crate::util::fsio::atomic_write_str(path, &self.to_csv());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher {
            warmup: Duration::from_millis(1),
            budget: Duration::from_millis(20),
            max_samples: 5,
            results: vec![],
        };
        b.bench("noop-ish", || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        let r = &b.results[0];
        assert!(!r.samples.is_empty());
        assert!(r.mean_s() >= 0.0);
        assert!(r.min_s() <= r.mean_s());
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut b = Bencher {
            warmup: Duration::from_millis(1),
            budget: Duration::from_millis(5),
            max_samples: 2,
            results: vec![],
        };
        b.bench("a", || 1);
        let csv = b.to_csv();
        assert!(csv.starts_with("name,mean_s"));
        assert_eq!(csv.lines().count(), 2);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_dur(2.0), "2.000 s");
        assert!(fmt_dur(0.002).ends_with("ms"));
        assert!(fmt_dur(2e-6).ends_with("µs"));
        assert!(fmt_dur(2e-9).ends_with("ns"));
    }
}
