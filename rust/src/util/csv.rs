//! Minimal CSV + aligned-text table emission for the report pipeline
//! (§5.4: "all reported tables and figures are generated from compilation
//! artifacts through an automated pipeline that imports CSV ... directly").

use std::fmt::Write as _;
use std::io;
use std::path::Path;

/// A simple in-memory table: header + rows of stringified cells.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells);
        self
    }

    /// RFC-4180-ish CSV (quotes cells containing comma/quote/newline).
    pub fn to_csv(&self) -> String {
        let quote = |s: &str| {
            if s.contains([',', '"', '\n']) {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.header.iter().map(|c| quote(c)).collect::<Vec<_>>().join(",")
        );
        for r in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                r.iter().map(|c| quote(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    /// Column-aligned text rendering for terminal output.
    pub fn to_text(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "== {} ==", self.title);
        }
        let _ = writeln!(out, "{}", fmt_row(&self.header));
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for r in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(r));
        }
        out
    }

    pub fn write_csv(&self, path: &Path) -> io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        // atomic (temp + fsync + rename): a crash mid-emit must never
        // leave a torn report table on disk (DESIGN.md §13)
        crate::util::fsio::atomic_write_str(path, &self.to_csv())
    }
}

/// Format a float with `d` decimals, trimming to integer display when d=0.
pub fn fnum(v: f64, d: usize) -> String {
    format!("{:.*}", d, v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_round_trip_basic() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into(), "x,y".into()]);
        let csv = t.to_csv();
        assert_eq!(csv, "a,b\n1,\"x,y\"\n");
    }

    #[test]
    fn text_alignment() {
        let mut t = Table::new("demo", &["node", "power"]);
        t.row(vec!["3nm".into(), "51366".into()]);
        t.row(vec!["28nm".into(), "3780".into()]);
        let txt = t.to_text();
        assert!(txt.contains("== demo =="));
        assert!(txt.lines().count() >= 4);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("t", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }
}
