//! Minimal JSON parser + serializer.
//!
//! The image vendors only the `xla` crate's dependency closure (no
//! serde_json), so the artifact manifest and the per-TCC JSON artifacts
//! go through this ~300-line implementation. Supports the full JSON value
//! model; numbers are f64 (adequate: the manifest holds shapes and small
//! scalars).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |n: usize| "  ".repeat(n);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&pad(indent + 1));
                    }
                    e.write(out, indent + 1, pretty);
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&pad(indent));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&pad(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&pad(indent));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 char
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8")?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

/// Convenience builders.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic_document() {
        let j = Json::parse(r#"{"a": 1, "b": [true, null, "x"], "c": {"d": -2.5e1}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("b").unwrap().idx(2).unwrap().as_str(), Some("x"));
        assert_eq!(j.get("c").unwrap().get("d").unwrap().as_f64(), Some(-25.0));
    }

    #[test]
    fn round_trip() {
        let src = r#"{"name":"t\"est","vals":[1,2.5,-3],"nested":{"ok":true},"z":null}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string_pretty();
        let j2 = Json::parse(&out).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn escapes() {
        let j = Json::parse(r#""line\nbreak A""#).unwrap();
        assert_eq!(j.as_str(), Some("line\nbreak A"));
        let out = Json::Str("a\"b\\c\n".into()).to_string_pretty();
        assert_eq!(Json::parse(&out).unwrap().as_str(), Some("a\"b\\c\n"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(num(5.0).to_string_pretty(), "5");
        assert_eq!(num(5.5).to_string_pretty(), "5.5");
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let j = Json::parse(&text).expect("manifest parses");
            assert!(j.get("entrypoints").is_some());
            assert!(j.get("stores").is_some());
        }
    }
}
