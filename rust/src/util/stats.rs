//! Statistics used by the paper's evaluation section: summary stats
//! (Table 16), Pearson correlation (Fig 8 / Table 13), log-log power-law
//! fits (Eq 73–74 / Fig 9 / Table 13), Gini coefficient + Lorenz curve
//! (Fig 11c), and percentile thresholds (Fig 12a P50/P90).

/// Summary statistics over a sample (Table 16 columns).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub median: f64,
    pub std_dev: f64,
    pub unique: usize,
}

pub fn summary(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty(), "summary of empty sample");
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let median = percentile_sorted(&sorted, 50.0);
    let mut unique = 1;
    for w in sorted.windows(2) {
        if (w[1] - w[0]).abs() > 1e-9 {
            unique += 1;
        }
    }
    Summary {
        min: sorted[0],
        max: *sorted.last().unwrap(),
        mean,
        median,
        std_dev: var.sqrt(),
        unique,
    }
}

/// Percentile (nearest-rank interpolated) of a pre-sorted sample.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    percentile_sorted(&sorted, p)
}

/// Order-stable running statistics (Welford's algorithm, f64 throughout).
///
/// The vec-env accumulates cross-lane reward/throughput traces through
/// this — always in lane-major order — so aggregate statistics depend
/// only on the sequence of pushed values, never on worker count or on
/// how lanes were grouped into waves (pinned by `tests/vecenv.rs`).
/// All accumulation is f64: summing episode rewards in f32 would make
/// the aggregate drift with lane count once traces get long.
#[derive(Debug, Clone, Copy)]
pub struct RunningStat {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Default for RunningStat {
    fn default() -> Self {
        RunningStat::new()
    }
}

impl RunningStat {
    pub fn new() -> RunningStat {
        RunningStat { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Population variance.
    pub fn var(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Pearson correlation coefficient (Fig 8, Table 13 lower half).
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (a, b) in x.iter().zip(y) {
        sxy += (a - mx) * (b - my);
        sxx += (a - mx) * (a - mx);
        syy += (b - my) * (b - my);
    }
    if sxx <= 0.0 || syy <= 0.0 {
        return 0.0;
    }
    sxy / (sxx * syy).sqrt()
}

/// Log-log power-law fit y = c · n^k (Eq 73). Returns (k, c, r²).
pub fn loglog_fit(n: &[f64], y: &[f64]) -> (f64, f64, f64) {
    assert_eq!(n.len(), y.len());
    let lx: Vec<f64> = n.iter().map(|v| v.ln()).collect();
    let ly: Vec<f64> = y.iter().map(|v| v.ln()).collect();
    let (k, logc) = linfit(&lx, &ly);
    // R² in log space (Eq 74)
    let my = ly.iter().sum::<f64>() / ly.len() as f64;
    let ss_res: f64 = lx
        .iter()
        .zip(&ly)
        .map(|(x, yv)| {
            let pred = logc + k * x;
            (yv - pred) * (yv - pred)
        })
        .sum();
    let ss_tot: f64 = ly.iter().map(|v| (v - my) * (v - my)).sum();
    let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 1.0 };
    (k, logc.exp(), r2)
}

/// Ordinary least squares y = a·x + b → (a, b).
pub fn linfit(x: &[f64], y: &[f64]) -> (f64, f64) {
    let n = x.len() as f64;
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    for (a, b) in x.iter().zip(y) {
        sxy += (a - mx) * (b - my);
        sxx += (a - mx) * (a - mx);
    }
    let slope = if sxx > 0.0 { sxy / sxx } else { 0.0 };
    (slope, my - slope * mx)
}

/// Gini coefficient of a non-negative allocation (Fig 11c).
pub fn gini(xs: &[f64]) -> f64 {
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let n = sorted.len() as f64;
    let total: f64 = sorted.iter().sum();
    if total <= 0.0 {
        return 0.0;
    }
    let mut cum = 0.0;
    let mut b = 0.0; // area under Lorenz curve (trapezoid)
    for &x in &sorted {
        let prev = cum;
        cum += x / total;
        b += (prev + cum) / 2.0;
    }
    1.0 - 2.0 * b / n
}

/// Lorenz curve points (cumulative share) for plotting, ascending.
pub fn lorenz(xs: &[f64]) -> Vec<(f64, f64)> {
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let total: f64 = sorted.iter().sum();
    let n = sorted.len() as f64;
    let mut cum = 0.0;
    let mut out = vec![(0.0, 0.0)];
    for (i, &x) in sorted.iter().enumerate() {
        cum += x;
        out.push(((i + 1) as f64 / n, if total > 0.0 { cum / total } else { 0.0 }));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summary(&[1.0, 2.0, 3.0, 4.0, 4.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.mean - 2.8).abs() < 1e-12);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.unique, 4);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y: Vec<f64> = x.iter().map(|v| 2.0 * v + 1.0).collect();
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let z: Vec<f64> = x.iter().map(|v| -v).collect();
        assert!((pearson(&x, &z) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn loglog_fit_recovers_power_law() {
        let n: [f64; 7] = [3.0, 5.0, 7.0, 10.0, 14.0, 22.0, 28.0];
        let y: Vec<f64> = n.iter().map(|v| 100.0 * v.powf(-1.33)).collect();
        let (k, c, r2) = loglog_fit(&n, &y);
        assert!((k + 1.33).abs() < 1e-9, "k {k}");
        assert!((c - 100.0).abs() < 1e-6, "c {c}");
        assert!(r2 > 0.999999);
    }

    #[test]
    fn gini_uniform_is_zero_concentrated_near_one() {
        assert!(gini(&[1.0; 100]).abs() < 1e-9);
        let mut xs = vec![0.0; 99];
        xs.push(100.0);
        assert!(gini(&xs) > 0.95);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert!((percentile(&xs, 50.0) - 50.0).abs() < 1e-9);
        assert!((percentile(&xs, 90.0) - 90.0).abs() < 1e-9);
    }

    #[test]
    fn running_stat_matches_batch_summary() {
        let xs = [3.0, -1.0, 4.0, 1.5, -9.0, 2.6];
        let mut r = RunningStat::new();
        for &x in &xs {
            r.push(x);
        }
        let s = summary(&xs);
        assert_eq!(r.count(), xs.len() as u64);
        assert!((r.mean() - s.mean).abs() < 1e-12);
        assert!((r.std() - s.std_dev).abs() < 1e-12);
        assert_eq!((r.min(), r.max()), (s.min, s.max));
        assert!(RunningStat::new().mean().is_nan());
    }

    #[test]
    fn lorenz_ends_at_one() {
        let pts = lorenz(&[5.0, 1.0, 3.0]);
        assert_eq!(pts[0], (0.0, 0.0));
        let last = pts.last().unwrap();
        assert!((last.0 - 1.0).abs() < 1e-12 && (last.1 - 1.0).abs() < 1e-12);
    }
}
