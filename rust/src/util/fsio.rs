//! Crash-safe file IO primitives shared by the checkpoint subsystem
//! (`rl::checkpoint`, DESIGN.md §13) and every artifact emitter.
//!
//! Three layers:
//!
//! * [`atomic_write`] — write-temp/fsync/rename commits. A reader never
//!   observes a torn file: it sees either the previous contents or the
//!   complete new contents, even across a crash mid-write.
//! * [`ByteWriter`] / [`ByteReader`] — a hand-rolled little-endian
//!   binary codec (no external serialization crates; the repo is
//!   std-only). Floats are encoded via `to_bits`, so a round-trip is
//!   bit-exact including NaN payloads and signed zeros — the property
//!   the resume-determinism contract rests on.
//! * [`seal_record`] / [`open_record`] — a checksummed envelope (magic,
//!   format version, kind tag, payload length, FNV-1a-64 checksum) so
//!   truncated or corrupted checkpoint slots are *detected* rather than
//!   half-parsed.

use std::fs::{self, File};
use std::io::{self, Write};
use std::path::Path;

/// FNV-1a 64-bit over a byte slice (same constants as the eval-cache
/// hasher; duplicated here so `util` stays dependency-free).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Atomically replace `path` with `bytes`: write a sibling `<name>.tmp`,
/// fsync it, then `rename` over the target (atomic on POSIX). Parent
/// directories are created as needed; after the rename the parent
/// directory is fsynced best-effort so the new directory entry is
/// durable too.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> io::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            fs::create_dir_all(dir)?;
        }
    }
    let mut tmp_name = path
        .file_name()
        .ok_or_else(|| {
            io::Error::new(io::ErrorKind::InvalidInput, "atomic_write: path has no file name")
        })?
        .to_os_string();
    tmp_name.push(".tmp");
    let tmp = path.with_file_name(tmp_name);
    {
        let mut f = File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    fs::rename(&tmp, path)?;
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            // Directory fsync is not supported everywhere; the rename
            // itself is already atomic, so failure here is non-fatal.
            let _ = File::open(dir).and_then(|d| d.sync_all());
        }
    }
    Ok(())
}

/// [`atomic_write`] for text artifacts (CSV tables, JSON records).
pub fn atomic_write_str(path: impl AsRef<Path>, text: &str) -> io::Result<()> {
    atomic_write(path.as_ref(), text.as_bytes())
}

/// Little-endian binary encoder. Collection lengths are written as u64
/// so the format is identical across platforms.
#[derive(Default)]
pub struct ByteWriter {
    pub buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> ByteWriter {
        ByteWriter::default()
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    pub fn bool(&mut self, v: bool) {
        self.u8(v as u8);
    }

    pub fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }

    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    pub fn opt_f64(&mut self, v: Option<f64>) {
        match v {
            Some(x) => {
                self.bool(true);
                self.f64(x);
            }
            None => self.bool(false),
        }
    }

    pub fn bytes(&mut self, v: &[u8]) {
        self.usize(v.len());
        self.buf.extend_from_slice(v);
    }

    pub fn str(&mut self, v: &str) {
        self.bytes(v.as_bytes());
    }

    pub fn f32s(&mut self, v: &[f32]) {
        self.usize(v.len());
        for &x in v {
            self.f32(x);
        }
    }

    pub fn f64s(&mut self, v: &[f64]) {
        self.usize(v.len());
        for &x in v {
            self.f64(x);
        }
    }
}

fn eof(what: &str) -> io::Error {
    io::Error::new(io::ErrorKind::UnexpectedEof, format!("checkpoint payload truncated at {what}"))
}

/// Little-endian binary decoder over a borrowed payload. Every accessor
/// returns `UnexpectedEof` on truncation instead of panicking, so a
/// torn slot degrades to "corrupt, fall back" rather than aborting.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> ByteReader<'a> {
        ByteReader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> io::Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(eof("field"));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> io::Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> io::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> io::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn i64(&mut self) -> io::Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn usize(&mut self) -> io::Result<usize> {
        let v = self.u64()?;
        usize::try_from(v)
            .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "length overflows usize"))
    }

    pub fn bool(&mut self) -> io::Result<bool> {
        Ok(self.u8()? != 0)
    }

    pub fn f32(&mut self) -> io::Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    pub fn f64(&mut self) -> io::Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub fn opt_f64(&mut self) -> io::Result<Option<f64>> {
        Ok(if self.bool()? { Some(self.f64()?) } else { None })
    }

    /// Read a length prefix that is about to drive a `Vec` preallocation
    /// or an element loop; bounded by the remaining payload so corrupt
    /// lengths cannot trigger huge allocations.
    pub fn len(&mut self, elem_size: usize) -> io::Result<usize> {
        let n = self.usize()?;
        if n.saturating_mul(elem_size.max(1)) > self.remaining() {
            return Err(eof("collection"));
        }
        Ok(n)
    }

    pub fn bytes(&mut self) -> io::Result<&'a [u8]> {
        let n = self.len(1)?;
        self.take(n)
    }

    pub fn str(&mut self) -> io::Result<String> {
        let b = self.bytes()?;
        String::from_utf8(b.to_vec())
            .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "invalid utf-8 string"))
    }

    pub fn f32s(&mut self) -> io::Result<Vec<f32>> {
        let n = self.len(4)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.f32()?);
        }
        Ok(v)
    }

    pub fn f64s(&mut self) -> io::Result<Vec<f64>> {
        let n = self.len(8)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.f64()?);
        }
        Ok(v)
    }
}

/// Magic prefix of every sealed record (`SIL`icon `CKPT` format `1`).
pub const RECORD_MAGIC: [u8; 8] = *b"SILCKPT1";
/// Bumped on any incompatible payload-layout change.
pub const RECORD_VERSION: u32 = 1;

const RECORD_HEADER_LEN: usize = 8 + 4 + 1 + 8 + 8;

/// Wrap `payload` in a checksummed envelope: magic, version, kind tag,
/// payload length, FNV-1a-64 of the payload, then the payload itself.
pub fn seal_record(kind: u8, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(RECORD_HEADER_LEN + payload.len());
    out.extend_from_slice(&RECORD_MAGIC);
    out.extend_from_slice(&RECORD_VERSION.to_le_bytes());
    out.push(kind);
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(&fnv1a64(payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

fn bad(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.to_string())
}

/// Verify a sealed record end to end (magic, version, declared length,
/// checksum) and return `(kind, payload)`. Truncation surfaces as
/// `UnexpectedEof`, any header/checksum mismatch as `InvalidData` — the
/// checkpoint loader treats both as "this slot is corrupt".
pub fn open_record(bytes: &[u8]) -> io::Result<(u8, &[u8])> {
    if bytes.len() < RECORD_HEADER_LEN {
        return Err(eof("record header"));
    }
    if bytes[..8] != RECORD_MAGIC {
        return Err(bad("bad record magic"));
    }
    let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
    if version != RECORD_VERSION {
        return Err(bad("unsupported record version"));
    }
    let kind = bytes[12];
    let plen = u64::from_le_bytes(bytes[13..21].try_into().unwrap());
    let sum = u64::from_le_bytes(bytes[21..29].try_into().unwrap());
    let payload = &bytes[RECORD_HEADER_LEN..];
    if plen != payload.len() as u64 {
        return Err(eof("record payload"));
    }
    if fnv1a64(payload) != sum {
        return Err(bad("record checksum mismatch"));
    }
    Ok((kind, payload))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codec_round_trips_bit_exact() {
        let mut w = ByteWriter::new();
        w.u8(7);
        w.u32(0xdead_beef);
        w.u64(u64::MAX);
        w.i64(-42);
        w.usize(12345);
        w.bool(true);
        w.f32(-0.0);
        w.f64(f64::NAN);
        w.opt_f64(Some(1.5));
        w.opt_f64(None);
        w.str("hello σ");
        w.f32s(&[1.0, f32::INFINITY, -3.25]);
        w.f64s(&[2.0, -0.0]);
        let mut r = ByteReader::new(&w.buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xdead_beef);
        assert_eq!(r.u64().unwrap(), u64::MAX);
        assert_eq!(r.i64().unwrap(), -42);
        assert_eq!(r.usize().unwrap(), 12345);
        assert!(r.bool().unwrap());
        assert_eq!(r.f32().unwrap().to_bits(), (-0.0f32).to_bits());
        assert_eq!(r.f64().unwrap().to_bits(), f64::NAN.to_bits());
        assert_eq!(r.opt_f64().unwrap(), Some(1.5));
        assert_eq!(r.opt_f64().unwrap(), None);
        assert_eq!(r.str().unwrap(), "hello σ");
        let xs = r.f32s().unwrap();
        assert_eq!(xs.len(), 3);
        assert_eq!(xs[1], f32::INFINITY);
        assert_eq!(r.f64s().unwrap()[1].to_bits(), (-0.0f64).to_bits());
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn reader_reports_truncation_not_panic() {
        let mut w = ByteWriter::new();
        w.u64(1);
        let mut r = ByteReader::new(&w.buf[..5]);
        assert_eq!(r.u64().unwrap_err().kind(), io::ErrorKind::UnexpectedEof);
        // corrupt collection length can't drive a huge allocation
        let mut w2 = ByteWriter::new();
        w2.usize(usize::MAX / 2);
        let mut r2 = ByteReader::new(&w2.buf);
        assert!(r2.f32s().is_err());
    }

    #[test]
    fn sealed_record_detects_corruption() {
        let rec = seal_record(3, b"payload-bytes");
        let (kind, payload) = open_record(&rec).unwrap();
        assert_eq!(kind, 3);
        assert_eq!(payload, b"payload-bytes");

        // truncated → UnexpectedEof
        let err = open_record(&rec[..rec.len() - 1]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
        // flipped payload byte → checksum mismatch
        let mut bad = rec.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x40;
        assert_eq!(open_record(&bad).unwrap_err().kind(), io::ErrorKind::InvalidData);
        // flipped magic byte → rejected
        let mut badm = rec.clone();
        badm[0] ^= 1;
        assert_eq!(open_record(&badm).unwrap_err().kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn atomic_write_replaces_and_leaves_no_tmp() {
        let dir = std::env::temp_dir().join(format!("fsio-test-{}", std::process::id()));
        let path = dir.join("nested").join("artifact.multi.dot.json");
        atomic_write(&path, b"first").unwrap();
        assert_eq!(fs::read(&path).unwrap(), b"first");
        atomic_write(&path, b"second").unwrap();
        assert_eq!(fs::read(&path).unwrap(), b"second");
        // the temp sibling must not linger
        let entries: Vec<_> = fs::read_dir(path.parent().unwrap())
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .collect();
        assert_eq!(entries, vec!["artifact.multi.dot.json".to_string()]);
        fs::remove_dir_all(&dir).unwrap();
    }
}
