//! Deterministic xoshiro256++ RNG with splitmix64 seeding.
//!
//! All stochastic behaviour in the optimizer — ε-greedy exploration,
//! Gaussian reparameterization noise, categorical mesh-delta sampling, PER
//! stochastic prioritized sampling, parameter init — draws from this one
//! generator type, so a run is fully reproducible from its seed and the
//! paper's "single-seed" caveat (§5.4) can be lifted by sweeping seeds.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller output
    gauss_spare: Option<f64>,
}

/// Serializable snapshot of an [`Rng`]'s full stream position — the
/// xoshiro256++ state words *and* the cached Box-Muller spare, so a
/// restored generator resumes mid-Gaussian-pair without skew
/// (`rl::checkpoint` stores one per lane plus the update stream).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RngState {
    pub s: [u64; 4],
    pub gauss_spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream (e.g. one per subsystem).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Capture the full stream position for checkpointing.
    pub fn state(&self) -> RngState {
        RngState { s: self.s, gauss_spare: self.gauss_spare }
    }

    /// Rebuild a generator at an exact stream position captured by
    /// [`Self::state`]; continues the sequence bit-identically.
    pub fn from_state(st: RngState) -> Rng {
        Rng { s: st.s, gauss_spare: st.gauss_spare }
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.uniform() * n as f64) as usize % n
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        let (mut u1, u2) = (self.uniform(), self.uniform());
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Fill a f32 buffer with standard normals (reparameterization noise).
    pub fn fill_gaussian_f32(&mut self, buf: &mut [f32]) {
        for v in buf.iter_mut() {
            *v = self.gaussian() as f32;
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut u = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(4);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(5);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(6);
        for _ in 0..10_000 {
            assert!(r.below(5) < 5);
        }
    }

    #[test]
    fn state_round_trip_resumes_stream_exactly() {
        let mut a = Rng::new(11);
        // advance into a Gaussian pair so the spare is populated
        let _ = a.gaussian();
        let mut b = Rng::from_state(a.state());
        for _ in 0..16 {
            assert_eq!(a.gaussian().to_bits(), b.gaussian().to_bits());
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
