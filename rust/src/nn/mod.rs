//! Host-side neural-network state: the parameter store (weights, Adam
//! moments, Polyak targets, entropy temperature — all named per the
//! manifest) and the sampling heads that turn actor outputs into actions.
//!
//! All the math (forward passes, gradients, Adam) runs inside the
//! AOT-lowered HLO modules; this module owns the *data* between calls and
//! the RNG-dependent sampling (kept Rust-side so seeds live in one place).

pub mod policy;

use std::collections::BTreeMap;

use crate::bail;
use crate::error::Result;

use crate::runtime::{InitKind, Manifest};
use crate::util::Rng;

/// Named flat-f32 parameter store.
#[derive(Debug, Clone, Default)]
pub struct Store {
    pub data: BTreeMap<String, Vec<f32>>,
    pub shapes: BTreeMap<String, Vec<usize>>,
}

impl Store {
    /// Initialize every entry per the manifest recipes (He for GELU-trunk
    /// weights, zeros for biases/moments, const for log α, copies for the
    /// Polyak targets). Deterministic under `rng`'s seed.
    pub fn from_manifest(m: &Manifest, rng: &mut Rng) -> Result<Store> {
        let mut store = Store::default();
        // two passes: non-copies first so copy sources exist
        for pass in 0..2 {
            for si in &m.stores {
                let is_copy = matches!(si.init, InitKind::Copy(_));
                if (pass == 0) == is_copy {
                    continue;
                }
                let n: usize = si.shape.iter().product::<usize>().max(1);
                let data = match &si.init {
                    InitKind::Zeros => vec![0.0; n],
                    InitKind::Const(c) => vec![*c as f32; n],
                    InitKind::He => {
                        let fan_in = si.shape.first().copied().unwrap_or(1).max(1);
                        let std = (2.0 / fan_in as f64).sqrt();
                        (0..n).map(|_| (rng.gaussian() * std) as f32).collect()
                    }
                    InitKind::Copy(src) => match store.data.get(src) {
                        Some(v) => v.clone(),
                        None => bail!("copy source {src} missing for {}", si.name),
                    },
                };
                store.shapes.insert(si.name.clone(), si.shape.clone());
                store.data.insert(si.name.clone(), data);
            }
        }
        Ok(store)
    }

    pub fn get(&self, name: &str) -> Option<&[f32]> {
        self.data.get(name).map(|v| v.as_slice())
    }

    /// Write back an updated array (size must match the existing entry).
    pub fn set(&mut self, name: &str, data: Vec<f32>) -> Result<()> {
        match self.data.get_mut(name) {
            Some(slot) => {
                if slot.len() != data.len() {
                    bail!("store {name}: size {} != {}", data.len(), slot.len());
                }
                *slot = data;
                Ok(())
            }
            None => bail!("store {name}: unknown entry"),
        }
    }

    /// Resolver closure for runtime calls: maps `state/<k>` to store
    /// entries and everything else to the provided batch map.
    pub fn resolver<'a>(
        &'a self,
        batch: &'a BTreeMap<String, Vec<f32>>,
    ) -> impl FnMut(&str) -> Option<Vec<f32>> + 'a {
        move |name: &str| {
            if let Some(k) = name.strip_prefix("state/") {
                return self.data.get(k).cloned();
            }
            if let Some(k) = name.strip_prefix("batch/") {
                return batch.get(k).cloned().or_else(|| batch.get(name).cloned());
            }
            // pure-forward entrypoints use bare store names + call args
            self.data.get(name).cloned().or_else(|| batch.get(name).cloned())
        }
    }

    /// Apply entrypoint outputs: `state/<k>` entries write back to the
    /// store; the rest (metrics) are returned to the caller.
    pub fn absorb(
        &mut self,
        outputs: Vec<(String, Vec<f32>)>,
    ) -> Result<BTreeMap<String, Vec<f32>>> {
        let mut rest = BTreeMap::new();
        for (name, data) in outputs {
            if let Some(k) = name.strip_prefix("state/") {
                self.set(k, data)?;
            } else {
                rest.insert(name, data);
            }
        }
        Ok(rest)
    }

    /// Total parameter count (diagnostics; paper §5.3 "under 100 K
    /// weights" for the policy network).
    pub fn total_elems(&self) -> usize {
        self.data.values().map(|v| v.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    const SAMPLE: &str = r#"{
      "entrypoints": {},
      "stores": {
        "actor/W1": {"shape": [52, 256], "init": "he"},
        "actor/b1": {"shape": [256], "init": "zeros"},
        "t1/W1": {"shape": [52, 256], "init": "copy:actor/W1"},
        "log_alpha": {"shape": [], "init": "const:-1.6094379"}
      },
      "hyper": {}
    }"#;

    fn store() -> Store {
        let m = Manifest::parse(SAMPLE).unwrap();
        Store::from_manifest(&m, &mut Rng::new(1)).unwrap()
    }

    #[test]
    fn init_recipes_applied() {
        let s = store();
        let w = s.get("actor/W1").unwrap();
        assert_eq!(w.len(), 52 * 256);
        let mean: f32 = w.iter().sum::<f32>() / w.len() as f32;
        assert!(mean.abs() < 0.01, "he mean {mean}");
        // he std ~ sqrt(2/52) = 0.196
        let var: f32 = w.iter().map(|x| x * x).sum::<f32>() / w.len() as f32;
        assert!((var.sqrt() - 0.196).abs() < 0.02, "std {}", var.sqrt());
        assert!(s.get("actor/b1").unwrap().iter().all(|&x| x == 0.0));
        assert_eq!(s.get("t1/W1").unwrap(), s.get("actor/W1").unwrap());
        assert!((s.get("log_alpha").unwrap()[0] - (-1.6094379)).abs() < 1e-6);
    }

    #[test]
    fn init_is_seed_deterministic() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = Store::from_manifest(&m, &mut Rng::new(7)).unwrap();
        let b = Store::from_manifest(&m, &mut Rng::new(7)).unwrap();
        let c = Store::from_manifest(&m, &mut Rng::new(8)).unwrap();
        assert_eq!(a.get("actor/W1"), b.get("actor/W1"));
        assert_ne!(a.get("actor/W1"), c.get("actor/W1"));
    }

    #[test]
    fn resolver_prefix_rules() {
        let s = store();
        let mut batch = BTreeMap::new();
        batch.insert("s".to_string(), vec![1.0f32; 52]);
        let mut r = s.resolver(&batch);
        assert!(r("state/actor/W1").is_some());
        assert!(r("actor/W1").is_some());
        assert!(r("s").is_some());
        assert!(r("state/nope").is_none());
    }

    #[test]
    fn absorb_writes_back_state_and_returns_metrics() {
        let mut s = store();
        let out = vec![
            ("state/actor/b1".to_string(), vec![1.0f32; 256]),
            ("metrics/td_abs".to_string(), vec![0.5f32; 4]),
        ];
        let rest = s.absorb(out).unwrap();
        assert_eq!(s.get("actor/b1").unwrap()[0], 1.0);
        assert_eq!(rest["metrics/td_abs"], vec![0.5f32; 4]);
    }

    #[test]
    fn set_rejects_shape_mismatch() {
        let mut s = store();
        assert!(s.set("actor/b1", vec![0.0; 3]).is_err());
        assert!(s.set("unknown", vec![0.0; 3]).is_err());
    }
}
