//! Host-side neural-network state: the parameter store (weights, Adam
//! moments, Polyak targets, entropy temperature — all named per the
//! manifest) and the sampling heads that turn actor outputs into actions.
//!
//! The math (forward passes, gradients, Adam) runs behind the
//! [`backend::Backend`] trait — either inside AOT-lowered HLO modules via
//! PJRT or in the pure-Rust [`native`] kernels; this module owns the
//! *data* between calls and the RNG-dependent sampling (kept Rust-side so
//! seeds live in one place). The [`Store`] layout is backend-agnostic, so
//! checkpoints are portable between backends.

pub mod backend;
pub mod kernels;
pub mod math;
pub mod native;
pub mod policy;

pub use backend::{Backend, BackendSel, UpdateMetrics};
pub use kernels::KernelSel;
pub use native::NativeBackend;

use std::collections::BTreeMap;

use crate::bail;
use crate::error::Result;

use crate::runtime::{InitKind, Manifest};
use crate::util::Rng;

/// Named flat-f32 parameter store.
#[derive(Debug, Clone, Default)]
pub struct Store {
    pub data: BTreeMap<String, Vec<f32>>,
    pub shapes: BTreeMap<String, Vec<usize>>,
}

impl Store {
    /// Initialize every entry per the manifest recipes (He for GELU-trunk
    /// weights, zeros for biases/moments, const for log α, copies for the
    /// Polyak targets). Deterministic under `rng`'s seed: He draws happen
    /// in manifest store order, copies never consume randomness.
    ///
    /// Copy inits resolve by fixed point, so a copy whose source is
    /// itself a copy appearing *later* in the manifest ordering (chained
    /// copies) still materializes; only a missing or cyclic source
    /// errors.
    pub fn from_manifest(m: &Manifest, rng: &mut Rng) -> Result<Store> {
        let mut store = Store::default();
        // non-copies first, in manifest order (fixes the RNG draw order)
        for si in &m.stores {
            let n: usize = si.shape.iter().product::<usize>().max(1);
            let data = match &si.init {
                InitKind::Copy(_) => continue,
                InitKind::Zeros => vec![0.0; n],
                InitKind::Const(c) => vec![*c as f32; n],
                InitKind::He => {
                    let fan_in = si.shape.first().copied().unwrap_or(1).max(1);
                    let std = (2.0 / fan_in as f64).sqrt();
                    (0..n).map(|_| (rng.gaussian() * std) as f32).collect()
                }
            };
            store.shapes.insert(si.name.clone(), si.shape.clone());
            store.data.insert(si.name.clone(), data);
        }
        // copies to fixed point (each round materializes every copy whose
        // source already exists; no progress ⇒ missing/cyclic sources)
        let mut pending: Vec<&crate::runtime::StoreInit> = m
            .stores
            .iter()
            .filter(|si| matches!(si.init, InitKind::Copy(_)))
            .collect();
        while !pending.is_empty() {
            let before = pending.len();
            pending.retain(|si| {
                let InitKind::Copy(src) = &si.init else { return false };
                match store.data.get(src) {
                    Some(v) => {
                        let data = v.clone();
                        store.shapes.insert(si.name.clone(), si.shape.clone());
                        store.data.insert(si.name.clone(), data);
                        false
                    }
                    None => true,
                }
            });
            if pending.len() == before {
                let stuck: Vec<&str> =
                    pending.iter().map(|si| si.name.as_str()).collect();
                bail!(
                    "copy inits with missing or cyclic sources: {}",
                    stuck.join(", ")
                );
            }
        }
        Ok(store)
    }

    pub fn get(&self, name: &str) -> Option<&[f32]> {
        self.data.get(name).map(|v| v.as_slice())
    }

    /// Serialize every entry (BTreeMap order, so the byte stream is
    /// deterministic) into a checkpoint payload. Floats go through
    /// `to_bits`, making the round-trip bit-exact — together with
    /// [`Self::read_from`] this is the Store half of the resume-
    /// determinism contract (DESIGN.md §13).
    pub fn write_to(&self, w: &mut crate::util::fsio::ByteWriter) {
        w.usize(self.data.len());
        for (name, data) in &self.data {
            w.str(name);
            let shape = self.shapes.get(name).cloned().unwrap_or_default();
            w.usize(shape.len());
            for &d in &shape {
                w.usize(d);
            }
            w.f32s(data);
        }
    }

    /// Decode a store serialized by [`Self::write_to`].
    pub fn read_from(r: &mut crate::util::fsio::ByteReader) -> std::io::Result<Store> {
        let n = r.len(1)?;
        let mut store = Store::default();
        for _ in 0..n {
            let name = r.str()?;
            let rank = r.len(8)?;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(r.usize()?);
            }
            let data = r.f32s()?;
            store.shapes.insert(name.clone(), shape);
            store.data.insert(name, data);
        }
        Ok(store)
    }

    /// Write back an updated array (size must match the existing entry).
    pub fn set(&mut self, name: &str, data: Vec<f32>) -> Result<()> {
        match self.data.get_mut(name) {
            Some(slot) => {
                if slot.len() != data.len() {
                    bail!("store {name}: size {} != {}", data.len(), slot.len());
                }
                *slot = data;
                Ok(())
            }
            None => bail!("store {name}: unknown entry"),
        }
    }

    /// Resolver closure for runtime calls: maps `state/<k>` to store
    /// entries and everything else to the provided batch map.
    pub fn resolver<'a>(
        &'a self,
        batch: &'a BTreeMap<String, Vec<f32>>,
    ) -> impl FnMut(&str) -> Option<Vec<f32>> + 'a {
        move |name: &str| {
            if let Some(k) = name.strip_prefix("state/") {
                return self.data.get(k).cloned();
            }
            if let Some(k) = name.strip_prefix("batch/") {
                return batch.get(k).cloned().or_else(|| batch.get(name).cloned());
            }
            // pure-forward entrypoints use bare store names + call args
            self.data.get(name).cloned().or_else(|| batch.get(name).cloned())
        }
    }

    /// Apply entrypoint outputs: `state/<k>` entries write back to the
    /// store; the rest (metrics) are returned to the caller.
    pub fn absorb(
        &mut self,
        outputs: Vec<(String, Vec<f32>)>,
    ) -> Result<BTreeMap<String, Vec<f32>>> {
        let mut rest = BTreeMap::new();
        for (name, data) in outputs {
            if let Some(k) = name.strip_prefix("state/") {
                self.set(k, data)?;
            } else {
                rest.insert(name, data);
            }
        }
        Ok(rest)
    }

    /// Total parameter count (diagnostics; paper §5.3 "under 100 K
    /// weights" for the policy network).
    pub fn total_elems(&self) -> usize {
        self.data.values().map(|v| v.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    const SAMPLE: &str = r#"{
      "entrypoints": {},
      "stores": {
        "actor/W1": {"shape": [52, 256], "init": "he"},
        "actor/b1": {"shape": [256], "init": "zeros"},
        "t1/W1": {"shape": [52, 256], "init": "copy:actor/W1"},
        "log_alpha": {"shape": [], "init": "const:-1.6094379"}
      },
      "hyper": {}
    }"#;

    fn store() -> Store {
        let m = Manifest::parse(SAMPLE).unwrap();
        Store::from_manifest(&m, &mut Rng::new(1)).unwrap()
    }

    #[test]
    fn init_recipes_applied() {
        let s = store();
        let w = s.get("actor/W1").unwrap();
        assert_eq!(w.len(), 52 * 256);
        let mean: f32 = w.iter().sum::<f32>() / w.len() as f32;
        assert!(mean.abs() < 0.01, "he mean {mean}");
        // he std ~ sqrt(2/52) = 0.196
        let var: f32 = w.iter().map(|x| x * x).sum::<f32>() / w.len() as f32;
        assert!((var.sqrt() - 0.196).abs() < 0.02, "std {}", var.sqrt());
        assert!(s.get("actor/b1").unwrap().iter().all(|&x| x == 0.0));
        assert_eq!(s.get("t1/W1").unwrap(), s.get("actor/W1").unwrap());
        assert!((s.get("log_alpha").unwrap()[0] - (-1.6094379)).abs() < 1e-6);
    }

    #[test]
    fn init_is_seed_deterministic() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = Store::from_manifest(&m, &mut Rng::new(7)).unwrap();
        let b = Store::from_manifest(&m, &mut Rng::new(7)).unwrap();
        let c = Store::from_manifest(&m, &mut Rng::new(8)).unwrap();
        assert_eq!(a.get("actor/W1"), b.get("actor/W1"));
        assert_ne!(a.get("actor/W1"), c.get("actor/W1"));
    }

    #[test]
    fn resolver_prefix_rules() {
        let s = store();
        let mut batch = BTreeMap::new();
        batch.insert("s".to_string(), vec![1.0f32; 52]);
        let mut r = s.resolver(&batch);
        assert!(r("state/actor/W1").is_some());
        assert!(r("actor/W1").is_some());
        assert!(r("s").is_some());
        assert!(r("state/nope").is_none());
    }

    #[test]
    fn absorb_writes_back_state_and_returns_metrics() {
        let mut s = store();
        let out = vec![
            ("state/actor/b1".to_string(), vec![1.0f32; 256]),
            ("metrics/td_abs".to_string(), vec![0.5f32; 4]),
        ];
        let rest = s.absorb(out).unwrap();
        assert_eq!(s.get("actor/b1").unwrap()[0], 1.0);
        assert_eq!(rest["metrics/td_abs"], vec![0.5f32; 4]);
    }

    #[test]
    fn set_rejects_shape_mismatch() {
        let mut s = store();
        assert!(s.set("actor/b1", vec![0.0; 3]).is_err());
        assert!(s.set("unknown", vec![0.0; 3]).is_err());
    }

    #[test]
    fn chained_copy_inits_resolve() {
        // Parsed store order is lexicographic ("b" before "c"), so the
        // copy chain b→c→a only resolves with fixed-point resolution:
        // b's source c is itself a copy appearing later in the pass.
        const CHAIN: &str = r#"{
          "entrypoints": {},
          "stores": {
            "a": {"shape": [4], "init": "he"},
            "b": {"shape": [4], "init": "copy:c"},
            "c": {"shape": [4], "init": "copy:a"}
          },
          "hyper": {}
        }"#;
        let m = Manifest::parse(CHAIN).unwrap();
        let s = Store::from_manifest(&m, &mut Rng::new(3)).unwrap();
        assert_eq!(s.get("b").unwrap(), s.get("a").unwrap());
        assert_eq!(s.get("c").unwrap(), s.get("a").unwrap());
        assert_eq!(s.shapes["b"], vec![4]);
    }

    #[test]
    fn cyclic_or_missing_copy_sources_error() {
        const CYCLE: &str = r#"{
          "entrypoints": {},
          "stores": {
            "x": {"shape": [2], "init": "copy:y"},
            "y": {"shape": [2], "init": "copy:x"}
          },
          "hyper": {}
        }"#;
        let m = Manifest::parse(CYCLE).unwrap();
        let err = Store::from_manifest(&m, &mut Rng::new(1)).unwrap_err();
        assert!(format!("{err}").contains("cyclic"), "{err}");

        const MISSING: &str = r#"{
          "entrypoints": {},
          "stores": {"z": {"shape": [2], "init": "copy:nope"}},
          "hyper": {}
        }"#;
        let m = Manifest::parse(MISSING).unwrap();
        assert!(Store::from_manifest(&m, &mut Rng::new(1)).is_err());
    }

    #[test]
    fn builtin_manifest_initializes_bit_identically_to_parsed_layout() {
        // The builtin manifest is the native backend's store contract;
        // seed-determinism across constructions is what makes native runs
        // reproducible and PJRT checkpoints portable.
        let m = Manifest::builtin();
        let a = Store::from_manifest(&m, &mut Rng::new(42)).unwrap();
        let b = Store::from_manifest(&Manifest::builtin(), &mut Rng::new(42)).unwrap();
        assert_eq!(a.data, b.data);
        assert_eq!(a.get("t1/Wa").unwrap(), a.get("c1/Wa").unwrap());
        assert_eq!(a.get("t2/Wc").unwrap(), a.get("c2/Wc").unwrap());
        assert!((a.get("log_alpha").unwrap()[0] - (-1.6094379)).abs() < 1e-6);
        assert_eq!(a.get("step").unwrap(), &[0.0][..]);
        // paper §5.3: policy network under 100 K weights (actor arrays)
        let actor_elems: usize = a
            .data
            .iter()
            .filter(|(k, _)| k.starts_with("actor/"))
            .map(|(_, v)| v.len())
            .sum();
        assert!(actor_elems < 100_000, "{actor_elems}");
    }
}
