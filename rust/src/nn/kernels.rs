//! Kernel-path selection for the native compute kernels (`kernels=`
//! config key): scalar reference vs runtime-detected SIMD.
//!
//! The scalar kernels in [`super::math`] (and the scalar tile-scoring
//! loop in [`crate::noc`]) are the *bit-exact determinism reference* —
//! every golden pin and the B-lane ≡ B-serial contract (DESIGN.md §9) is
//! defined against them. The SIMD paths (AVX2+FMA on x86_64, NEON on
//! aarch64) trade bit-identity of the f32 NN kernels for throughput and
//! are gated by tolerance-parity tests (`tests/kernel_parity.rs`); the
//! f64 placement-scoring path is written FMA-free in scalar operation
//! order, so it stays bit-identical and argmax selections are preserved
//! (DESIGN.md §10).
//!
//! Selection is process-global (one AtomicU8): the kernels are leaf
//! functions called from deep inside the backend and evaluator hot loops,
//! so threading a handle through every call site would touch dozens of
//! signatures for a knob that is set once at startup. The global defaults
//! to [`KernelPath::Scalar`], so library users and the test suite stay on
//! the bit-exact reference unless they opt in.

use std::sync::atomic::{AtomicU8, Ordering};

/// Requested kernel mode (`kernels=scalar|simd|auto`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelSel {
    /// Use the vectorized path when the CPU supports one, else scalar.
    #[default]
    Auto,
    /// Bit-exact reference kernels (the determinism contract).
    Scalar,
    /// Require the vectorized path; falls back to scalar (with the
    /// fallback visible in [`describe`]) when the CPU lacks support.
    Simd,
}

impl KernelSel {
    pub fn parse(value: &str) -> Result<KernelSel, String> {
        match value {
            "auto" => Ok(KernelSel::Auto),
            "scalar" => Ok(KernelSel::Scalar),
            "simd" => Ok(KernelSel::Simd),
            _ => Err(format!("bad kernels {value} (scalar|simd|auto)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            KernelSel::Auto => "auto",
            KernelSel::Scalar => "scalar",
            KernelSel::Simd => "simd",
        }
    }
}

/// Resolved kernel path actually executed by the dispatching kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelPath {
    Scalar,
    /// x86_64 AVX2 + FMA (8-wide f32, 4-wide f64).
    Avx2,
    /// aarch64 NEON (4-wide f32, 2-wide f64).
    Neon,
}

impl KernelPath {
    pub fn name(&self) -> &'static str {
        match self {
            KernelPath::Scalar => "scalar",
            KernelPath::Avx2 => "avx2+fma",
            KernelPath::Neon => "neon",
        }
    }
}

/// Runtime capability detection: the SIMD path this CPU can run, if any.
/// AVX2 and FMA are required together on x86_64 (the f32 kernels lean on
/// fused multiply-adds); NEON is architecturally guaranteed on aarch64.
pub fn detect() -> Option<KernelPath> {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return Some(KernelPath::Avx2);
        }
        None
    }
    #[cfg(target_arch = "aarch64")]
    {
        Some(KernelPath::Neon)
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        None
    }
}

/// Resolve a requested mode against the detected capability.
pub fn resolve(sel: KernelSel) -> KernelPath {
    match sel {
        KernelSel::Scalar => KernelPath::Scalar,
        KernelSel::Auto | KernelSel::Simd => detect().unwrap_or(KernelPath::Scalar),
    }
}

// Encoding for the process-global active path.
const PATH_SCALAR: u8 = 0;
const PATH_AVX2: u8 = 1;
const PATH_NEON: u8 = 2;

static ACTIVE: AtomicU8 = AtomicU8::new(PATH_SCALAR);

/// The kernel path the dispatching kernels currently execute. Relaxed
/// load: the value is set once at startup (or explicitly by a bench) and
/// carries no data dependencies.
#[inline]
pub fn active() -> KernelPath {
    match ACTIVE.load(Ordering::Relaxed) {
        PATH_AVX2 => KernelPath::Avx2,
        PATH_NEON => KernelPath::Neon,
        _ => KernelPath::Scalar,
    }
}

/// Resolve `sel` and install it as the process-global kernel path,
/// returning what was installed. Call once at startup (the CLI does this
/// from the parsed config) or from a bench. Tests must not race each
/// other through this global: only `tests/kernel_parity.rs` (its own
/// process) flips it, serialized behind a mutex and restoring Scalar.
pub fn set_global(sel: KernelSel) -> KernelPath {
    let path = resolve(sel);
    let code = match path {
        KernelPath::Scalar => PATH_SCALAR,
        KernelPath::Avx2 => PATH_AVX2,
        KernelPath::Neon => PATH_NEON,
    };
    ACTIVE.store(code, Ordering::Relaxed);
    path
}

/// One-line attribution string for run banners / `info` / Table 14:
/// requested mode, detected capability, and the path that would resolve.
pub fn describe(sel: KernelSel) -> String {
    let detected = detect().map(|p| p.name()).unwrap_or("none");
    format!("{} (detected {detected}, resolved {})", sel.name(), resolve(sel).name())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sel_parses() {
        assert_eq!(KernelSel::parse("scalar").unwrap(), KernelSel::Scalar);
        assert_eq!(KernelSel::parse("simd").unwrap(), KernelSel::Simd);
        assert_eq!(KernelSel::parse("auto").unwrap(), KernelSel::Auto);
        assert!(KernelSel::parse("avx512").is_err());
        assert_eq!(KernelSel::default().name(), "auto");
    }

    #[test]
    fn scalar_always_resolves_scalar() {
        assert_eq!(resolve(KernelSel::Scalar), KernelPath::Scalar);
    }

    #[test]
    fn simd_resolution_matches_detection() {
        // Auto and Simd agree with detect(); on a CPU with no SIMD
        // support both fall back to the scalar reference.
        let want = detect().unwrap_or(KernelPath::Scalar);
        assert_eq!(resolve(KernelSel::Auto), want);
        assert_eq!(resolve(KernelSel::Simd), want);
    }

    #[test]
    fn describe_names_all_three_parts() {
        let d = describe(KernelSel::Auto);
        assert!(d.starts_with("auto"), "{d}");
        assert!(d.contains("detected") && d.contains("resolved"), "{d}");
    }

    // NOTE: no test flips the global — `cargo test` runs tests as threads
    // of one process, and the default (Scalar) is what every bit-identity
    // pin in the suite assumes. `tests/kernel_parity.rs` owns the
    // explicit-path coverage.
}
