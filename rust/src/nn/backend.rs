//! The backend layer between the manifest contract and the search loop:
//! a [`Backend`] trait exposing every entrypoint the SAC agent calls with
//! borrowed-slice inputs and outputs (no string-keyed maps, no per-call
//! output cloning), implemented by the PJRT runtime ([`PjrtBackend`]) and
//! the pure-Rust executor ([`super::native::NativeBackend`]).
//!
//! Both backends operate on the same [`Store`] (initialized from the same
//! manifest shapes/inits), so parameters and checkpoints are
//! backend-portable: a store trained under PJRT can be driven by the
//! native kernels and vice versa. Selection (`backend=native|pjrt|auto`)
//! lives in [`BackendSel`]; `auto` prefers PJRT when AOT artifacts are
//! present and executable, and falls back to native otherwise — which is
//! what makes `silicon-rl optimize` runnable with no artifacts at all.

use std::collections::BTreeMap;
use std::path::Path;

use crate::bail;
use crate::error::{Context, Result};
use crate::nn::native::NativeBackend;
use crate::nn::Store;
use crate::runtime::{self, Manifest, Runtime};

/// One batched actor forward's outputs, borrowed from backend scratch
/// (valid until the next backend call).
pub struct ActorOut<'a> {
    /// MoE-mixed continuous means, `[b, ACT_DIM]` (pre-squash).
    pub mu: &'a [f32],
    /// Clamped log-stds, `[b, ACT_DIM]`.
    pub log_std: &'a [f32],
    /// Discrete mesh/SC logits, `[b, 20]`.
    pub disc_logits: &'a [f32],
}

/// One PER minibatch for [`Backend::sac_update`], borrowed from the
/// agent's marshalling buffers.
pub struct SacBatch<'a> {
    pub b: usize,
    pub s: &'a [f32],
    pub a: &'a [f32],
    pub ad: &'a [f32],
    pub r: &'a [f32],
    pub s2: &'a [f32],
    pub done: &'a [f32],
    pub w: &'a [f32],
    pub eps_cur: &'a [f32],
    pub eps_next: &'a [f32],
}

/// Metrics from one SAC update step.
#[derive(Debug, Clone, Copy, Default)]
pub struct UpdateMetrics {
    pub critic_loss: f64,
    pub actor_loss: f64,
    pub alpha_loss: f64,
    pub alpha: f64,
    pub entropy: f64,
}

/// [`Backend::sac_update`] result: metrics plus the |TD| priorities,
/// borrowed from backend scratch.
pub struct SacStepOut<'a> {
    pub metrics: UpdateMetrics,
    pub td_abs: &'a [f32],
}

/// Every NN computation the SAC+MoE search loop performs. Batch sizes are
/// inferred from slice lengths; the native backend accepts any batch,
/// the PJRT backend only the batch sizes baked into the lowered HLO
/// (1, `mpc_batch`, `batch`).
///
/// `Send` because the async actor-learner engine (`rl::learner`) moves a
/// boxed backend into the dedicated learner thread; both implementations
/// are plain owned data (manifests, scratch buffers, the stubbed PJRT
/// client handle).
pub trait Backend: Send {
    /// `"native"` or `"pjrt"`.
    fn kind(&self) -> &'static str;

    /// One-line human description for run banners.
    fn describe(&self) -> String;

    fn manifest(&self) -> &Manifest;

    /// Batched actor forward: `s` is `[b, 52]` row-major.
    fn actor_fwd(&mut self, store: &Store, s: &[f32]) -> Result<ActorOut<'_>>;

    /// World-model forward `ŝ' = s + f_ω([s;a])`: returns `[b, 52]`.
    fn wm_fwd(&mut self, store: &Store, s: &[f32], a: &[f32]) -> Result<&[f32]>;

    /// Surrogate PPA forward: returns `[b, 3]` (power, perf, area).
    fn sur_fwd(&mut self, store: &Store, s: &[f32], a: &[f32]) -> Result<&[f32]>;

    /// Fused SAC update (critics + actor + α + Polyak + Adam), writing
    /// updated parameters back into `store`.
    fn sac_update(&mut self, store: &mut Store, batch: &SacBatch) -> Result<SacStepOut<'_>>;

    /// World-model MSE update; returns the loss.
    fn wm_update(&mut self, store: &mut Store, s: &[f32], a: &[f32], s2: &[f32]) -> Result<f64>;

    /// Surrogate MSE update; returns the loss.
    fn sur_update(&mut self, store: &mut Store, s: &[f32], a: &[f32], ppa: &[f32]) -> Result<f64>;
}

/// Backend selection (`backend=` config key).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendSel {
    /// PJRT when artifacts exist and the PJRT runtime is linked;
    /// native otherwise.
    #[default]
    Auto,
    Native,
    Pjrt,
}

impl BackendSel {
    pub fn parse(value: &str) -> Result<BackendSel, String> {
        match value {
            "auto" => Ok(BackendSel::Auto),
            "native" => Ok(BackendSel::Native),
            "pjrt" => Ok(BackendSel::Pjrt),
            _ => Err(format!("bad backend {value} (native|pjrt|auto)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendSel::Auto => "auto",
            BackendSel::Native => "native",
            BackendSel::Pjrt => "pjrt",
        }
    }
}

/// Resolve a selection against an artifacts directory and construct the
/// backend. The native path prefers the on-disk manifest when one exists
/// (identical hyper/stores to the AOT build) and falls back to the
/// builtin manifest, so `optimize` runs with no artifacts present.
pub fn load(artifacts_dir: &str, sel: BackendSel) -> Result<Box<dyn Backend>> {
    let manifest_path = Path::new(artifacts_dir).join("manifest.json");
    let artifacts = manifest_path.exists();
    match sel {
        BackendSel::Pjrt => {
            if !runtime::backend_available() {
                bail!(
                    "backend=pjrt requested but the PJRT runtime is unavailable \
                     (offline xla stub); use backend=native"
                );
            }
            Ok(Box::new(PjrtBackend::new(Runtime::load(Path::new(artifacts_dir))?)))
        }
        BackendSel::Auto if artifacts && runtime::backend_available() => {
            Ok(Box::new(PjrtBackend::new(Runtime::load(Path::new(artifacts_dir))?)))
        }
        BackendSel::Native | BackendSel::Auto => {
            let manifest = if artifacts {
                let text = std::fs::read_to_string(&manifest_path)
                    .with_context(|| format!("reading {}", manifest_path.display()))?;
                Manifest::parse(&text).map_err(crate::error::Error::msg)?
            } else {
                Manifest::builtin()
            };
            Ok(Box::new(NativeBackend::new(manifest)?))
        }
    }
}

/// Convenience constructor used by tests/benches that already hold a
/// loaded [`Runtime`].
pub fn pjrt(runtime: Runtime) -> Box<dyn Backend> {
    Box::new(PjrtBackend::new(runtime))
}

/// Convenience constructor for the artifact-free native backend.
pub fn native_builtin() -> Result<Box<dyn Backend>> {
    Ok(Box::new(NativeBackend::builtin()?))
}

/// Infer the batch size from a flat tensor length (shared by both
/// backends' input validation).
pub(crate) fn batch_of(len: usize, dim: usize, what: &str) -> Result<usize> {
    if len == 0 || dim == 0 || len % dim != 0 {
        bail!("{what}: length {len} not a positive multiple of {dim}");
    }
    Ok(len / dim)
}

// -------------------------------------------------------------------- PJRT

/// [`Backend`] over the AOT-compiled HLO artifacts. Marshals borrowed
/// slices into the string-keyed form the PJRT runtime expects and keeps
/// per-entrypoint output buffers so callers receive borrowed views with
/// the same shape contract as the native backend.
pub struct PjrtBackend {
    runtime: Runtime,
    state_dim: usize,
    act_dim: usize,
    mu: Vec<f32>,
    log_std: Vec<f32>,
    disc: Vec<f32>,
    fwd_out: Vec<f32>,
    td_abs: Vec<f32>,
}

impl PjrtBackend {
    pub fn new(runtime: Runtime) -> PjrtBackend {
        let state_dim = runtime.manifest.hyper_or("state_dim", 52.0) as usize;
        let act_dim = runtime.manifest.hyper_or("act_dim", 30.0) as usize;
        PjrtBackend {
            runtime,
            state_dim,
            act_dim,
            mu: Vec::new(),
            log_std: Vec::new(),
            disc: Vec::new(),
            fwd_out: Vec::new(),
            td_abs: Vec::new(),
        }
    }

    /// Move one named output out of a call result (no clone).
    fn take_output(outs: &mut Vec<(String, Vec<f32>)>, name: &str) -> Result<Vec<f32>> {
        let idx = outs
            .iter()
            .position(|(n, _)| n == name)
            .with_context(|| format!("entrypoint output {name} missing"))?;
        Ok(outs.swap_remove(idx).1)
    }
}

impl Backend for PjrtBackend {
    fn kind(&self) -> &'static str {
        "pjrt"
    }

    fn describe(&self) -> String {
        format!(
            "pjrt (platform {}, {} entrypoints, {} stores)",
            self.runtime.platform(),
            self.runtime.manifest.entrypoints.len(),
            self.runtime.manifest.stores.len()
        )
    }

    fn manifest(&self) -> &Manifest {
        &self.runtime.manifest
    }

    fn actor_fwd(&mut self, store: &Store, s: &[f32]) -> Result<ActorOut<'_>> {
        let b = batch_of(s.len(), self.state_dim, "actor_fwd state")?;
        let mut call = BTreeMap::new();
        call.insert("s".to_string(), s.to_vec());
        let mut outs =
            self.runtime.call(&format!("actor_fwd_b{b}"), store.resolver(&call))?;
        self.mu = Self::take_output(&mut outs, "mu")?;
        self.log_std = Self::take_output(&mut outs, "log_std")?;
        self.disc = Self::take_output(&mut outs, "disc_logits")?;
        Ok(ActorOut { mu: &self.mu, log_std: &self.log_std, disc_logits: &self.disc })
    }

    fn wm_fwd(&mut self, store: &Store, s: &[f32], a: &[f32]) -> Result<&[f32]> {
        let b = batch_of(s.len(), self.state_dim, "wm_fwd state")?;
        if a.len() != b * self.act_dim {
            bail!("wm_fwd: action batch {} != state batch {b}", a.len() / self.act_dim);
        }
        let mut call = BTreeMap::new();
        call.insert("s".to_string(), s.to_vec());
        call.insert("a".to_string(), a.to_vec());
        let mut outs = self.runtime.call(&format!("wm_fwd_b{b}"), store.resolver(&call))?;
        self.fwd_out = Self::take_output(&mut outs, "s_next")?;
        Ok(&self.fwd_out)
    }

    fn sur_fwd(&mut self, store: &Store, s: &[f32], a: &[f32]) -> Result<&[f32]> {
        let b = batch_of(s.len(), self.state_dim, "sur_fwd state")?;
        if a.len() != b * self.act_dim {
            bail!("sur_fwd: action batch {} != state batch {b}", a.len() / self.act_dim);
        }
        let mut call = BTreeMap::new();
        call.insert("s".to_string(), s.to_vec());
        call.insert("a".to_string(), a.to_vec());
        let mut outs = self.runtime.call(&format!("sur_fwd_b{b}"), store.resolver(&call))?;
        self.fwd_out = Self::take_output(&mut outs, "ppa")?;
        Ok(&self.fwd_out)
    }

    fn sac_update(&mut self, store: &mut Store, batch: &SacBatch) -> Result<SacStepOut<'_>> {
        let mut call = BTreeMap::new();
        call.insert("s".to_string(), batch.s.to_vec());
        call.insert("a".to_string(), batch.a.to_vec());
        call.insert("ad".to_string(), batch.ad.to_vec());
        call.insert("r".to_string(), batch.r.to_vec());
        call.insert("s2".to_string(), batch.s2.to_vec());
        call.insert("done".to_string(), batch.done.to_vec());
        call.insert("w".to_string(), batch.w.to_vec());
        call.insert("eps_cur".to_string(), batch.eps_cur.to_vec());
        call.insert("eps_next".to_string(), batch.eps_next.to_vec());
        let outs = self.runtime.call("sac_update", store.resolver(&call))?;
        let mut metrics = store.absorb(outs)?;
        self.td_abs = metrics.remove("metrics/td_abs").unwrap_or_default();
        let scalar = |k: &str| {
            metrics.get(k).and_then(|v| v.first()).copied().unwrap_or(0.0) as f64
        };
        Ok(SacStepOut {
            metrics: UpdateMetrics {
                critic_loss: scalar("metrics/critic_loss"),
                actor_loss: scalar("metrics/actor_loss"),
                alpha_loss: scalar("metrics/alpha_loss"),
                alpha: scalar("metrics/alpha"),
                entropy: scalar("metrics/entropy"),
            },
            td_abs: &self.td_abs,
        })
    }

    fn wm_update(&mut self, store: &mut Store, s: &[f32], a: &[f32], s2: &[f32]) -> Result<f64> {
        let mut call = BTreeMap::new();
        call.insert("s".to_string(), s.to_vec());
        call.insert("a".to_string(), a.to_vec());
        call.insert("s2".to_string(), s2.to_vec());
        let outs = self.runtime.call("wm_update", store.resolver(&call))?;
        let metrics = store.absorb(outs)?;
        Ok(metrics
            .get("metrics/loss")
            .and_then(|v| v.first())
            .copied()
            .unwrap_or(f32::NAN) as f64)
    }

    fn sur_update(&mut self, store: &mut Store, s: &[f32], a: &[f32], ppa: &[f32]) -> Result<f64> {
        let mut call = BTreeMap::new();
        call.insert("s".to_string(), s.to_vec());
        call.insert("a".to_string(), a.to_vec());
        call.insert("ppa".to_string(), ppa.to_vec());
        let outs = self.runtime.call("sur_update", store.resolver(&call))?;
        let metrics = store.absorb(outs)?;
        Ok(metrics
            .get("metrics/loss")
            .and_then(|v| v.first())
            .copied()
            .unwrap_or(f32::NAN) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_sel_parses() {
        assert_eq!(BackendSel::parse("native").unwrap(), BackendSel::Native);
        assert_eq!(BackendSel::parse("pjrt").unwrap(), BackendSel::Pjrt);
        assert_eq!(BackendSel::parse("auto").unwrap(), BackendSel::Auto);
        assert!(BackendSel::parse("cuda").is_err());
        assert_eq!(BackendSel::default().name(), "auto");
    }

    #[test]
    fn auto_without_artifacts_resolves_native() {
        let b = load("/nonexistent/artifacts-dir", BackendSel::Auto).unwrap();
        assert_eq!(b.kind(), "native");
    }

    #[test]
    fn explicit_pjrt_without_runtime_errors() {
        if runtime::backend_available() {
            return; // real bindings linked: selection would be valid
        }
        assert!(load("/nonexistent/artifacts-dir", BackendSel::Pjrt).is_err());
    }
}
