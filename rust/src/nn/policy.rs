//! Sampling heads (§3.4.1): tanh-squashed Gaussian continuous actions,
//! categorical mesh/SC deltas, and ε-greedy uniform exploration. RNG stays
//! Rust-side; the HLO actor only produces (μ, logσ, discrete logits).

use crate::env::{Action, ACT_DIM, DISC_OPTIONS, N_DISC};
use crate::util::Rng;

/// Sample a = tanh(μ + σ·ε) per continuous dim (Eq 8 analogue with tanh
/// squashing per §3.11).
pub fn sample_continuous(mu: &[f32], log_std: &[f32], rng: &mut Rng) -> [f64; ACT_DIM] {
    debug_assert_eq!(mu.len(), ACT_DIM);
    let mut out = [0.0; ACT_DIM];
    for i in 0..ACT_DIM {
        let std = (log_std[i] as f64).exp();
        out[i] = (mu[i] as f64 + std * rng.gaussian()).tanh();
    }
    out
}

/// Deterministic (exploitation) continuous head: tanh(μ).
pub fn mean_continuous(mu: &[f32]) -> [f64; ACT_DIM] {
    let mut out = [0.0; ACT_DIM];
    for i in 0..ACT_DIM {
        out[i] = (mu[i] as f64).tanh();
    }
    out
}

/// Sample the 4 mesh/SC deltas from categorical distributions over the
/// 20 discrete logits (Eqs 6–7). Returns (deltas, one-hot encoding).
pub fn sample_discrete(logits: &[f32], rng: &mut Rng) -> ([i32; N_DISC], [f32; 20]) {
    debug_assert_eq!(logits.len(), N_DISC * DISC_OPTIONS);
    let mut deltas = [0i32; N_DISC];
    let mut onehot = [0f32; 20];
    for d in 0..N_DISC {
        let ls = &logits[d * DISC_OPTIONS..(d + 1) * DISC_OPTIONS];
        let probs = softmax(ls);
        let opt = rng.categorical(&probs);
        deltas[d] = Action::delta_from_option(opt);
        onehot[d * DISC_OPTIONS + opt] = 1.0;
    }
    (deltas, onehot)
}

/// Greedy (argmax) discrete head.
pub fn argmax_discrete(logits: &[f32]) -> ([i32; N_DISC], [f32; 20]) {
    let mut deltas = [0i32; N_DISC];
    let mut onehot = [0f32; 20];
    for d in 0..N_DISC {
        let ls = &logits[d * DISC_OPTIONS..(d + 1) * DISC_OPTIONS];
        let opt = ls
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(2);
        deltas[d] = Action::delta_from_option(opt);
        onehot[d * DISC_OPTIONS + opt] = 1.0;
    }
    (deltas, onehot)
}

/// Uniform random action (Algorithm 1's ε branch).
pub fn uniform_action(rng: &mut Rng) -> Action {
    let mut a = Action::neutral();
    for v in a.cont.iter_mut() {
        *v = rng.uniform_in(-1.0, 1.0);
    }
    for d in a.deltas.iter_mut() {
        *d = rng.below(DISC_OPTIONS) as i32 - 2;
    }
    a
}

/// One-hot for an already-chosen delta vector (for replay storage).
pub fn onehot_from_deltas(deltas: &[i32; N_DISC]) -> [f32; 20] {
    let mut onehot = [0f32; 20];
    for (d, &delta) in deltas.iter().enumerate() {
        let opt = (delta + 2).clamp(0, 4) as usize;
        onehot[d * DISC_OPTIONS + opt] = 1.0;
    }
    onehot
}

fn softmax(xs: &[f32]) -> Vec<f64> {
    let m = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let exps: Vec<f64> = xs.iter().map(|&x| ((x as f64) - m).exp()).collect();
    let z: f64 = exps.iter().sum();
    exps.into_iter().map(|e| e / z).collect()
}

/// Policy-entropy estimate from log-stds (diagnostic for Fig 3's entropy
/// stabilization trace): Gaussian entropy Σ (logσ + ½log(2πe)).
pub fn gaussian_entropy(log_std: &[f32]) -> f64 {
    let c = 0.5 * (2.0 * std::f64::consts::PI * std::f64::consts::E).ln();
    log_std.iter().map(|&l| l as f64 + c).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn continuous_samples_bounded() {
        let mut rng = Rng::new(1);
        let mu = vec![0.0f32; ACT_DIM];
        let ls = vec![0.0f32; ACT_DIM];
        for _ in 0..100 {
            let a = sample_continuous(&mu, &ls, &mut rng);
            assert!(a.iter().all(|v| v.abs() <= 1.0));
        }
    }

    #[test]
    fn zero_std_recovers_mean() {
        let mut rng = Rng::new(2);
        let mu = vec![0.5f32; ACT_DIM];
        let ls = vec![-20.0f32; ACT_DIM]; // σ ≈ 0
        let a = sample_continuous(&mu, &ls, &mut rng);
        for v in a {
            assert!((v - 0.5f64.tanh()).abs() < 1e-6);
        }
    }

    #[test]
    fn discrete_sampling_respects_logits() {
        let mut rng = Rng::new(3);
        // option 4 (delta +2) overwhelmingly likely for head 0
        let mut logits = vec![0.0f32; 20];
        logits[4] = 20.0;
        let mut count_plus2 = 0;
        for _ in 0..200 {
            let (d, oh) = sample_discrete(&logits, &mut rng);
            if d[0] == 2 {
                count_plus2 += 1;
            }
            // one-hot is valid: exactly one per head
            for h in 0..N_DISC {
                let s: f32 = oh[h * 5..h * 5 + 5].iter().sum();
                assert_eq!(s, 1.0);
            }
        }
        assert!(count_plus2 > 190, "{count_plus2}");
    }

    #[test]
    fn argmax_discrete_deterministic() {
        let mut logits = vec![0.0f32; 20];
        logits[0] = 5.0; // head 0 -> option 0 -> delta -2
        logits[9] = 5.0; // head 1 -> option 4 -> delta +2
        let (d, _) = argmax_discrete(&logits);
        assert_eq!(d[0], -2);
        assert_eq!(d[1], 2);
    }

    #[test]
    fn uniform_action_in_bounds() {
        let mut rng = Rng::new(4);
        for _ in 0..50 {
            let a = uniform_action(&mut rng);
            assert!(a.cont.iter().all(|v| v.abs() <= 1.0));
            assert!(a.deltas.iter().all(|d| (-2..=2).contains(d)));
        }
    }

    #[test]
    fn onehot_round_trip() {
        let deltas = [-2, 0, 1, 2];
        let oh = onehot_from_deltas(&deltas);
        assert_eq!(oh[0], 1.0); // head0 option 0
        assert_eq!(oh[5 + 2], 1.0); // head1 option 2
        assert_eq!(oh[10 + 3], 1.0);
        assert_eq!(oh[15 + 4], 1.0);
        assert_eq!(oh.iter().sum::<f32>(), 4.0);
    }

    #[test]
    fn entropy_monotone_in_sigma() {
        let lo = gaussian_entropy(&vec![-2.0f32; 30]);
        let hi = gaussian_entropy(&vec![0.0f32; 30]);
        assert!(hi > lo);
    }
}
