//! Pure-Rust executor for every manifest entrypoint the SAC agent calls —
//! batched actor/critic forwards, the fused `sac_update` (twin critics,
//! actor with MoE continuous head + discrete REINFORCE term, entropy
//! temperature, Adam, Polyak targets), world-model and surrogate
//! forwards/updates — over the same [`Store`] layout the PJRT path uses,
//! keyed off the same manifest shapes and init recipes, so parameters and
//! checkpoints are bit-compatible between backends.
//!
//! The gradient derivations mirror `python/compile/model.py` exactly and
//! were validated against JAX autodiff in f64 (worst leaf ~1e-12 relative
//! across plain and clip-saturated paths). All buffers live in a
//! preallocated [`Scratch`] that grows to the largest batch seen and is
//! then reused — after warmup the hot loop performs no heap allocation.

#![allow(clippy::needless_range_loop)] // kernel loops index several slices

use crate::bail;
use crate::error::{Context, Result};
use crate::nn::backend::{batch_of, ActorOut, Backend, SacBatch, SacStepOut, UpdateMetrics};
use crate::nn::math::{self, AdamStep};
use crate::nn::Store;
use crate::runtime::Manifest;

// Network dimensions (Table 6; fixed by the lowered HLO shapes and
// validated against the manifest at construction).
const S: usize = 52; // SAC state subset
const A: usize = 30; // continuous action dims
const D: usize = 20; // discrete logits (4 heads x 5 options)
const NH: usize = 4; // discrete heads
const NO: usize = 5; // options per head
const HID: usize = 256; // actor/critic hidden width
const NE: usize = 4; // MoE experts
const KA: usize = NE * A; // per-expert head width (120)
const XC: usize = S + A; // critic / wm / sur input width (82)
const M3H1: usize = 128; // wm/sur hidden 1
const M3H2: usize = 64; // wm/sur hidden 2
const PPA: usize = 3; // surrogate output heads

/// Precomputed store names (param, Adam m, Adam v) in fixed key order —
/// the update paths never build name strings, keeping the hot loop free
/// of heap allocation after warmup.
type PMV = (&'static str, &'static str, &'static str);

const ACTOR_PMV: [PMV; 12] = [
    ("actor/W1", "actor_m/W1", "actor_v/W1"),
    ("actor/b1", "actor_m/b1", "actor_v/b1"),
    ("actor/W5", "actor_m/W5", "actor_v/W5"),
    ("actor/b5", "actor_m/b5", "actor_v/b5"),
    ("actor/W2", "actor_m/W2", "actor_v/W2"),
    ("actor/b2", "actor_m/b2", "actor_v/b2"),
    ("actor/Wg", "actor_m/Wg", "actor_v/Wg"),
    ("actor/bg", "actor_m/bg", "actor_v/bg"),
    ("actor/W3", "actor_m/W3", "actor_v/W3"),
    ("actor/b3", "actor_m/b3", "actor_v/b3"),
    ("actor/W4", "actor_m/W4", "actor_v/W4"),
    ("actor/b4", "actor_m/b4", "actor_v/b4"),
];
const C1_PMV: [PMV; 6] = [
    ("c1/Wa", "c1_m/Wa", "c1_v/Wa"),
    ("c1/ba", "c1_m/ba", "c1_v/ba"),
    ("c1/Wb", "c1_m/Wb", "c1_v/Wb"),
    ("c1/bb", "c1_m/bb", "c1_v/bb"),
    ("c1/Wc", "c1_m/Wc", "c1_v/Wc"),
    ("c1/bc", "c1_m/bc", "c1_v/bc"),
];
const C2_PMV: [PMV; 6] = [
    ("c2/Wa", "c2_m/Wa", "c2_v/Wa"),
    ("c2/ba", "c2_m/ba", "c2_v/ba"),
    ("c2/Wb", "c2_m/Wb", "c2_v/Wb"),
    ("c2/bb", "c2_m/bb", "c2_v/bb"),
    ("c2/Wc", "c2_m/Wc", "c2_v/Wc"),
    ("c2/bc", "c2_m/bc", "c2_v/bc"),
];
const WM_PMV: [PMV; 6] = [
    ("wm/W1", "wm_m/W1", "wm_v/W1"),
    ("wm/b1", "wm_m/b1", "wm_v/b1"),
    ("wm/W2", "wm_m/W2", "wm_v/W2"),
    ("wm/b2", "wm_m/b2", "wm_v/b2"),
    ("wm/W3", "wm_m/W3", "wm_v/W3"),
    ("wm/b3", "wm_m/b3", "wm_v/b3"),
];
const SUR_PMV: [PMV; 6] = [
    ("sur/W1", "sur_m/W1", "sur_v/W1"),
    ("sur/b1", "sur_m/b1", "sur_v/b1"),
    ("sur/W2", "sur_m/W2", "sur_v/W2"),
    ("sur/b2", "sur_m/b2", "sur_v/b2"),
    ("sur/W3", "sur_m/W3", "sur_v/W3"),
    ("sur/b3", "sur_m/b3", "sur_v/b3"),
];
/// Param names only, in `Wa, ba, Wb, bb, Wc, bc` order.
const C1_P: [&str; 6] = ["c1/Wa", "c1/ba", "c1/Wb", "c1/bb", "c1/Wc", "c1/bc"];
const C2_P: [&str; 6] = ["c2/Wa", "c2/ba", "c2/Wb", "c2/bb", "c2/Wc", "c2/bc"];
const T1_P: [&str; 6] = ["t1/Wa", "t1/ba", "t1/Wb", "t1/bb", "t1/Wc", "t1/bc"];
const T2_P: [&str; 6] = ["t2/Wa", "t2/ba", "t2/Wb", "t2/bb", "t2/Wc", "t2/bc"];
/// Param names only, in `W1, b1, W2, b2, W3, b3` order.
const WM_P: [&str; 6] = ["wm/W1", "wm/b1", "wm/W2", "wm/b2", "wm/W3", "wm/b3"];
const SUR_P: [&str; 6] = ["sur/W1", "sur/b1", "sur/W2", "sur/b2", "sur/W3", "sur/b3"];

/// Table-6 hyperparameters, read from the manifest with `model.py`
/// defaults (so the builtin manifest and an AOT-produced one agree).
#[derive(Debug, Clone, Copy)]
struct Hyper {
    lr: f64,
    gamma: f32,
    tau: f32,
    target_entropy: f64,
    logstd_min: f32,
    logstd_max: f32,
    la_min: f32,
    la_max: f32,
    lambda_lb: f32,
    wm_lr: f64,
    sur_lr: f64,
    b1: f64,
    b2: f64,
    eps: f64,
}

impl Hyper {
    fn from_manifest(m: &Manifest) -> Hyper {
        Hyper {
            lr: m.hyper_or("lr", 3e-4),
            gamma: m.hyper_or("gamma", 0.99) as f32,
            tau: m.hyper_or("tau", 0.005) as f32,
            target_entropy: m.hyper_or("target_entropy", -30.0),
            logstd_min: m.hyper_or("logstd_min", -20.0) as f32,
            logstd_max: m.hyper_or("logstd_max", 2.0) as f32,
            la_min: m.hyper_or("log_alpha_min", -10.0) as f32,
            la_max: m.hyper_or("log_alpha_max", 10.0) as f32,
            lambda_lb: m.hyper_or("lambda_lb", 0.01) as f32,
            wm_lr: m.hyper_or("wm_lr", 1.5e-4),
            sur_lr: m.hyper_or("sur_lr", 3e-4),
            b1: m.hyper_or("adam_b1", 0.9),
            b2: m.hyper_or("adam_b2", 0.999),
            eps: m.hyper_or("adam_eps", 1e-8),
        }
    }
}

/// Grow-to-fit slice view over a reusable buffer.
fn ens(v: &mut Vec<f32>, n: usize) -> &mut [f32] {
    if v.len() < n {
        v.resize(n, 0.0);
    }
    &mut v[..n]
}

fn p<'a>(store: &'a Store, name: &str) -> Result<&'a [f32]> {
    store
        .get(name)
        .with_context(|| format!("native backend: store entry {name} missing"))
}

#[derive(Default)]
struct ActorBufs {
    z1: Vec<f32>,
    h1: Vec<f32>,
    z5: Vec<f32>,
    h2: Vec<f32>,
    dl: Vec<f32>,
    gates: Vec<f32>,
    mu_e: Vec<f32>,
    ls_e: Vec<f32>,
    mu: Vec<f32>,
    ls_raw: Vec<f32>,
    ls: Vec<f32>,
}

#[derive(Default)]
struct CriticBufs {
    x: Vec<f32>,
    za: Vec<f32>,
    ha: Vec<f32>,
    zb: Vec<f32>,
    hb: Vec<f32>,
    q: Vec<f32>,
}

#[derive(Default)]
struct CriticGrads {
    wa: Vec<f32>,
    ba: Vec<f32>,
    wb: Vec<f32>,
    bb: Vec<f32>,
    wc: Vec<f32>,
    bc: Vec<f32>,
}

#[derive(Default)]
struct ActorGrads {
    w1: Vec<f32>,
    b1: Vec<f32>,
    w5: Vec<f32>,
    b5: Vec<f32>,
    w2: Vec<f32>,
    b2: Vec<f32>,
    wg: Vec<f32>,
    bg: Vec<f32>,
    w3: Vec<f32>,
    b3: Vec<f32>,
    w4: Vec<f32>,
    b4: Vec<f32>,
}

#[derive(Default)]
struct Mlp3Bufs {
    x: Vec<f32>,
    z1: Vec<f32>,
    h1: Vec<f32>,
    z2: Vec<f32>,
    h2: Vec<f32>,
    out: Vec<f32>,
    g1: Vec<f32>,
    g2: Vec<f32>,
    gout: Vec<f32>,
}

#[derive(Default)]
struct Mlp3Grads {
    w1: Vec<f32>,
    b1: Vec<f32>,
    w2: Vec<f32>,
    b2: Vec<f32>,
    w3: Vec<f32>,
    b3: Vec<f32>,
}

#[derive(Default)]
struct Scratch {
    actor: ActorBufs,
    ca: CriticBufs,
    cb: CriticBufs,
    cg: CriticGrads,
    ag: ActorGrads,
    m3: Mlp3Bufs,
    mg: Mlp3Grads,
    // sampling
    sa: Vec<f32>,
    su: Vec<f32>,
    slogp: Vec<f32>,
    // sac temporaries
    y: Vec<f32>,
    td: Vec<f32>,
    gq: Vec<f32>,
    tq: Vec<f32>,
    t_hid1: Vec<f32>,
    t_hid2: Vec<f32>,
    gx: Vec<f32>,
    g_mu: Vec<f32>,
    g_ls: Vec<f32>,
    g_dl: Vec<f32>,
    g_gates: Vec<f32>,
    g_z3: Vec<f32>,
    g_z4: Vec<f32>,
    g_aq: Vec<f32>,
    fwd_out: Vec<f32>,
}

/// The pure-Rust backend. See module docs; construct via
/// [`NativeBackend::new`] (explicit manifest) or
/// [`NativeBackend::builtin`] (no artifacts needed).
pub struct NativeBackend {
    manifest: Manifest,
    h: Hyper,
    sc: Scratch,
    last_metrics: UpdateMetrics,
}

impl NativeBackend {
    /// Build from a manifest (parsed `manifest.json` or
    /// [`Manifest::builtin`]); validates that every network array the
    /// kernels index has the expected shape.
    pub fn new(manifest: Manifest) -> Result<NativeBackend> {
        validate_shapes(&manifest)?;
        let h = Hyper::from_manifest(&manifest);
        Ok(NativeBackend {
            manifest,
            h,
            sc: Scratch::default(),
            last_metrics: UpdateMetrics::default(),
        })
    }

    /// Backend over the builtin manifest — identical stores/hyper to the
    /// AOT pipeline's `manifest.json`, no artifacts required.
    pub fn builtin() -> Result<NativeBackend> {
        NativeBackend::new(Manifest::builtin())
    }

    /// The fused SAC step (§3.11, Algorithm 1 line 12), mirroring the
    /// lowered `sac_update` op for op: critic target → twin-critic Adam
    /// updates → actor update through the *updated* critics (MoE
    /// continuous head + discrete REINFORCE + load-balance penalty) →
    /// entropy-temperature update → Polyak targets → step counter.
    fn sac_update_impl(&mut self, store: &mut Store, bt: &SacBatch) -> Result<()> {
        let b = bt.b;
        if b == 0 {
            bail!("sac_update: empty batch");
        }
        for (name, len, want) in [
            ("s", bt.s.len(), b * S),
            ("a", bt.a.len(), b * A),
            ("ad", bt.ad.len(), b * D),
            ("r", bt.r.len(), b),
            ("s2", bt.s2.len(), b * S),
            ("done", bt.done.len(), b),
            ("w", bt.w.len(), b),
            ("eps_cur", bt.eps_cur.len(), b * A),
            ("eps_next", bt.eps_next.len(), b * A),
        ] {
            if len != want {
                bail!("sac_update: batch tensor {name} has {len} elems, want {want}");
            }
        }
        let h = self.h;
        let step = p(store, "step")?[0] as f64;
        let alpha = p(store, "log_alpha")?[0].clamp(h.la_min, h.la_max).exp();
        let inv_b = 1.0 / b as f32;
        let ad_step = AdamStep::new(h.lr, h.b1, h.b2, h.eps, step);
        let sc = &mut self.sc;

        // ---- critic target y (Eq 46): clipped double-Q with entropy bonus
        actor_fwd_into(store, bt.s2, b, &mut sc.actor)?;
        clamp_ls(&mut sc.actor, b, h.logstd_min, h.logstd_max);
        sample_squashed(
            &sc.actor.mu[..b * A],
            &sc.actor.ls[..b * A],
            bt.eps_next,
            b,
            &mut sc.sa,
            &mut sc.su,
            &mut sc.slogp,
        );
        critic_fwd_into(store, &T1_P, bt.s2, &sc.sa[..b * A], b, &mut sc.ca)?;
        critic_fwd_into(store, &T2_P, bt.s2, &sc.sa[..b * A], b, &mut sc.cb)?;
        let y = ens(&mut sc.y, b);
        for i in 0..b {
            let qmin = sc.ca.q[i].min(sc.cb.q[i]);
            y[i] = bt.r[i] + h.gamma * (1.0 - bt.done[i]) * (qmin - alpha * sc.slogp[i]);
        }

        // ---- twin-critic updates (Eq 47), PER-weighted; td from c1
        let mut closses = [0.0f64; 2];
        for (ci, (pn, pmv)) in [(&C1_P, &C1_PMV), (&C2_P, &C2_PMV)].into_iter().enumerate() {
            let cbuf = if ci == 0 { &mut sc.ca } else { &mut sc.cb };
            critic_fwd_into(store, pn, bt.s, bt.a, b, cbuf)?;
            let gq = ens(&mut sc.gq, b);
            let mut loss = 0.0f64;
            for i in 0..b {
                let e = cbuf.q[i] - sc.y[i];
                loss += (bt.w[i] * e * e) as f64;
                gq[i] = 2.0 * bt.w[i] * e * inv_b;
            }
            closses[ci] = loss / b as f64;
            if ci == 0 {
                let td = ens(&mut sc.td, b);
                for i in 0..b {
                    td[i] = (cbuf.q[i] - sc.y[i]).abs();
                }
            }
            critic_bwd(
                store,
                pn,
                cbuf,
                &sc.gq[..b],
                b,
                &mut sc.cg,
                &mut sc.t_hid1,
                &mut sc.t_hid2,
                None,
            )?;
            let cg = &sc.cg;
            adam_net(
                store,
                pmv,
                &[
                    &cg.wa[..XC * HID],
                    &cg.ba[..HID],
                    &cg.wb[..HID * HID],
                    &cg.bb[..HID],
                    &cg.wc[..HID],
                    &cg.bc[..1],
                ],
                ad_step,
            )?;
        }

        // ---- actor loss (Eq 58) through the UPDATED critics
        actor_fwd_into(store, bt.s, b, &mut sc.actor)?;
        clamp_ls(&mut sc.actor, b, h.logstd_min, h.logstd_max);
        sample_squashed(
            &sc.actor.mu[..b * A],
            &sc.actor.ls[..b * A],
            bt.eps_cur,
            b,
            &mut sc.sa,
            &mut sc.su,
            &mut sc.slogp,
        );
        critic_fwd_into(store, &C1_P, bt.s, &sc.sa[..b * A], b, &mut sc.ca)?;
        critic_fwd_into(store, &C2_P, bt.s, &sc.sa[..b * A], b, &mut sc.cb)?;
        let mut l_cont = 0.0f64;
        let mut mean_logp = 0.0f64;
        {
            // per-sample min mask; gradient flows through the chosen critic
            let tq1 = ens(&mut sc.gq, b);
            let tq2 = ens(&mut sc.tq, b);
            for i in 0..b {
                let use1 = sc.ca.q[i] <= sc.cb.q[i];
                let qmin = if use1 { sc.ca.q[i] } else { sc.cb.q[i] };
                l_cont += (bt.w[i] * (alpha * sc.slogp[i] - qmin)) as f64;
                mean_logp += sc.slogp[i] as f64;
                let g = -bt.w[i] * inv_b;
                tq1[i] = if use1 { g } else { 0.0 };
                tq2[i] = if use1 { 0.0 } else { g };
            }
        }
        l_cont /= b as f64;
        mean_logp /= b as f64;
        critic_bwd(
            store,
            &C1_P,
            &sc.ca,
            &sc.gq[..b],
            b,
            &mut sc.cg,
            &mut sc.t_hid1,
            &mut sc.t_hid2,
            Some(&mut sc.gx),
        )?;
        {
            let g_aq = ens(&mut sc.g_aq, b * A);
            for i in 0..b {
                g_aq[i * A..(i + 1) * A].copy_from_slice(&sc.gx[i * XC + S..(i + 1) * XC]);
            }
        }
        critic_bwd(
            store,
            &C2_P,
            &sc.cb,
            &sc.tq[..b],
            b,
            &mut sc.cg,
            &mut sc.t_hid1,
            &mut sc.t_hid2,
            Some(&mut sc.gx),
        )?;
        for i in 0..b {
            for j in 0..A {
                sc.g_aq[i * A + j] += sc.gx[i * XC + S + j];
            }
        }

        // discrete head: REINFORCE on batch-mean-baselined reward, with a
        // numerically stable per-head log-softmax
        let mut r_mean = 0.0f64;
        for i in 0..b {
            r_mean += bt.r[i] as f64;
        }
        let r_mean = (r_mean / b as f64) as f32;
        let mut l_disc = 0.0f64;
        {
            let g_dl = ens(&mut sc.g_dl, b * D);
            for i in 0..b {
                let adv = bt.r[i] - r_mean;
                let c = bt.w[i] * adv * inv_b;
                let mut lp_d = 0.0f64;
                for hd in 0..NH {
                    let base = i * D + hd * NO;
                    let row = &sc.actor.dl[base..base + NO];
                    let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                    let mut z = 0.0f32;
                    for &v in row {
                        z += (v - m).exp();
                    }
                    let ln_z = m + z.ln();
                    for o in 0..NO {
                        let prob = (row[o] - m).exp() / z;
                        if bt.ad[base + o] > 0.0 {
                            lp_d += (row[o] - ln_z) as f64;
                        }
                        g_dl[base + o] = c * (prob - bt.ad[base + o]);
                    }
                }
                l_disc += (bt.w[i] * adv) as f64 * lp_d;
            }
            l_disc = -l_disc / b as f64;
        }

        // MoE load balance (Eq 55)
        let mut gbar = [0.0f64; NE];
        for i in 0..b {
            for k in 0..NE {
                gbar[k] += sc.actor.gates[i * NE + k] as f64;
            }
        }
        let gbar: [f32; NE] = std::array::from_fn(|k| (gbar[k] / b as f64) as f32);
        let l_moe = (h.lambda_lb * NE as f32 * gbar.iter().map(|g| g * g).sum::<f32>()) as f64;

        // continuous-head gradients (reparameterized, clip-gated)
        {
            let g_mu = ens(&mut sc.g_mu, b * A);
            let g_ls = ens(&mut sc.g_ls, b * A);
            for i in 0..b {
                let coeff = bt.w[i] * alpha * inv_b;
                for j in 0..A {
                    let idx = i * A + j;
                    let a_v = sc.sa[idx];
                    let sat = if 1.0 - a_v * a_v > 1e-6 { 1.0 } else { 0.0 };
                    let gu = coeff * 2.0 * a_v * sat + sc.g_aq[idx] * (1.0 - a_v * a_v);
                    g_mu[idx] = gu;
                    let raw = sc.actor.ls_raw[idx];
                    g_ls[idx] = if raw > h.logstd_min && raw < h.logstd_max {
                        gu * (sc.su[idx] - sc.actor.mu[idx]) - coeff
                    } else {
                        0.0
                    };
                }
            }
        }

        // MoE combine backward: gates (softmax), expert heads (tanh)
        {
            let g_gates = ens(&mut sc.g_gates, b * NE);
            for i in 0..b {
                for k in 0..NE {
                    let mut acc = 2.0 * h.lambda_lb * NE as f32 * gbar[k] * inv_b;
                    let me = &sc.actor.mu_e[i * KA + k * A..i * KA + (k + 1) * A];
                    let le = &sc.actor.ls_e[i * KA + k * A..i * KA + (k + 1) * A];
                    for a in 0..A {
                        acc += sc.g_mu[i * A + a] * me[a] + sc.g_ls[i * A + a] * le[a];
                    }
                    g_gates[i * NE + k] = acc;
                }
                let mut dot = 0.0f32;
                for k in 0..NE {
                    dot += g_gates[i * NE + k] * sc.actor.gates[i * NE + k];
                }
                for k in 0..NE {
                    g_gates[i * NE + k] = sc.actor.gates[i * NE + k] * (g_gates[i * NE + k] - dot);
                }
            }
            let g_z3 = ens(&mut sc.g_z3, b * KA);
            let g_z4 = ens(&mut sc.g_z4, b * KA);
            for i in 0..b {
                for k in 0..NE {
                    let g = sc.actor.gates[i * NE + k];
                    for a in 0..A {
                        let idx = i * KA + k * A + a;
                        let me = sc.actor.mu_e[idx];
                        g_z3[idx] = sc.g_mu[i * A + a] * g * (1.0 - me * me);
                        g_z4[idx] = sc.g_ls[i * A + a] * g;
                    }
                }
            }
        }

        // heads → trunk → input layers
        {
            let w2 = p(store, "actor/W2")?;
            let w3 = p(store, "actor/W3")?;
            let w4 = p(store, "actor/W4")?;
            let w5 = p(store, "actor/W5")?;
            let t1v = ens(&mut sc.t_hid1, b * HID);
            math::matmul_wt(&sc.g_dl[..b * D], w2, t1v, b, HID, D);
            let t2v = ens(&mut sc.t_hid2, b * HID);
            math::matmul_wt(&sc.g_z3[..b * KA], w3, t2v, b, HID, KA);
            for (x, &v) in sc.t_hid1[..b * HID].iter_mut().zip(&sc.t_hid2[..b * HID]) {
                *x += v;
            }
            math::matmul_wt(&sc.g_z4[..b * KA], w4, &mut sc.t_hid2[..b * HID], b, HID, KA);
            for (x, &v) in sc.t_hid1[..b * HID].iter_mut().zip(&sc.t_hid2[..b * HID]) {
                *x += v;
            }
            let ag = &mut sc.ag;
            math::grad_w_b(
                &sc.actor.h2[..b * HID],
                &sc.g_dl[..b * D],
                ens(&mut ag.w2, HID * D),
                ens(&mut ag.b2, D),
                b,
                HID,
                D,
            );
            math::grad_w_b(
                &sc.actor.h2[..b * HID],
                &sc.g_z3[..b * KA],
                ens(&mut ag.w3, HID * KA),
                ens(&mut ag.b3, KA),
                b,
                HID,
                KA,
            );
            math::grad_w_b(
                &sc.actor.h2[..b * HID],
                &sc.g_z4[..b * KA],
                ens(&mut ag.w4, HID * KA),
                ens(&mut ag.b4, KA),
                b,
                HID,
                KA,
            );
            math::grad_w_b(
                bt.s,
                &sc.g_gates[..b * NE],
                ens(&mut ag.wg, S * NE),
                ens(&mut ag.bg, NE),
                b,
                S,
                NE,
            );
            // g_z5 = g_h2 ⊙ gelu'(z5)
            math::gelu_bwd_inplace(&mut sc.t_hid1[..b * HID], &sc.actor.z5[..b * HID]);
            math::grad_w_b(
                &sc.actor.h1[..b * HID],
                &sc.t_hid1[..b * HID],
                ens(&mut ag.w5, HID * HID),
                ens(&mut ag.b5, HID),
                b,
                HID,
                HID,
            );
            math::matmul_wt(&sc.t_hid1[..b * HID], w5, &mut sc.t_hid2[..b * HID], b, HID, HID);
            math::gelu_bwd_inplace(&mut sc.t_hid2[..b * HID], &sc.actor.z1[..b * HID]);
            math::grad_w_b(
                bt.s,
                &sc.t_hid2[..b * HID],
                ens(&mut ag.w1, S * HID),
                ens(&mut ag.b1, HID),
                b,
                S,
                HID,
            );
        }
        {
            let ag = &sc.ag;
            adam_net(
                store,
                &ACTOR_PMV,
                &[
                    &ag.w1[..S * HID],
                    &ag.b1[..HID],
                    &ag.w5[..HID * HID],
                    &ag.b5[..HID],
                    &ag.w2[..HID * D],
                    &ag.b2[..D],
                    &ag.wg[..S * NE],
                    &ag.bg[..NE],
                    &ag.w3[..HID * KA],
                    &ag.b3[..KA],
                    &ag.w4[..HID * KA],
                    &ag.b4[..KA],
                ],
                ad_step,
            )?;
        }

        // ---- entropy temperature (Eq 45/60), gradient clipped to [-1, 1]
        let mean_term = mean_logp + h.target_entropy;
        let grad_la = (-mean_term).clamp(-1.0, 1.0) as f32;
        {
            let mut m = std::mem::take(store.data.get_mut("la_m").context("store la_m missing")?);
            let mut v = std::mem::take(store.data.get_mut("la_v").context("store la_v missing")?);
            {
                let pv = store.data.get_mut("log_alpha").context("log_alpha missing")?;
                ad_step.apply(pv, &[grad_la], &mut m, &mut v);
            }
            *store.data.get_mut("la_m").unwrap() = m;
            *store.data.get_mut("la_v").unwrap() = v;
        }
        let la_new = {
            let lav = scalar_mut(store, "log_alpha")?;
            *lav = lav.clamp(h.la_min, h.la_max);
            *lav
        };
        let alpha_loss = -(la_new as f64) * mean_term;

        // ---- Polyak targets + shared Adam step counter
        polyak_net(store, &T1_P, &C1_P, h.tau)?;
        polyak_net(store, &T2_P, &C2_P, h.tau)?;
        *scalar_mut(store, "step")? += 1.0;

        self.last_metrics = UpdateMetrics {
            critic_loss: 0.5 * (closses[0] + closses[1]),
            actor_loss: l_cont + l_disc + l_moe,
            alpha_loss,
            alpha: (la_new as f64).exp(),
            entropy: -mean_logp,
        };
        Ok(())
    }
}

/// Check the manifest describes exactly the network this module's fixed
/// loop bounds index (guards against silent drift between `model.py`,
/// the manifest and these kernels).
fn validate_shapes(m: &Manifest) -> Result<()> {
    let expect = Manifest::builtin();
    for want in &expect.stores {
        let got = m
            .stores
            .iter()
            .find(|s| s.name == want.name)
            .with_context(|| format!("manifest missing store {}", want.name))?;
        if got.shape != want.shape {
            bail!(
                "manifest store {} shape {:?} != expected {:?}",
                want.name,
                got.shape,
                want.shape
            );
        }
    }
    for (k, dim) in [("state_dim", S), ("act_dim", A), ("disc_dim", D), ("hidden", HID)] {
        let v = m.hyper_or(k, dim as f64) as usize;
        if v != dim {
            bail!("manifest hyper {k}={v} unsupported (native backend expects {dim})");
        }
    }
    Ok(())
}

// ---------------------------------------------------------------- forward

fn actor_fwd_into(store: &Store, s: &[f32], b: usize, ab: &mut ActorBufs) -> Result<()> {
    let (w1, b1) = (p(store, "actor/W1")?, p(store, "actor/b1")?);
    let (w5, b5) = (p(store, "actor/W5")?, p(store, "actor/b5")?);
    let (w2, b2) = (p(store, "actor/W2")?, p(store, "actor/b2")?);
    let (wg, bg) = (p(store, "actor/Wg")?, p(store, "actor/bg")?);
    let (w3, b3) = (p(store, "actor/W3")?, p(store, "actor/b3")?);
    let (w4, b4) = (p(store, "actor/W4")?, p(store, "actor/b4")?);

    let z1 = ens(&mut ab.z1, b * HID);
    math::matmul_bias(s, w1, b1, z1, b, S, HID);
    let h1 = ens(&mut ab.h1, b * HID);
    math::gelu_map(&ab.z1[..b * HID], h1);
    let z5 = ens(&mut ab.z5, b * HID);
    math::matmul_bias(&ab.h1[..b * HID], w5, b5, z5, b, HID, HID);
    let h2 = ens(&mut ab.h2, b * HID);
    math::gelu_map(&ab.z5[..b * HID], h2);
    let dl = ens(&mut ab.dl, b * D);
    math::matmul_bias(&ab.h2[..b * HID], w2, b2, dl, b, HID, D);
    let gates = ens(&mut ab.gates, b * NE);
    math::matmul_bias(s, wg, bg, gates, b, S, NE);
    math::softmax_rows(&mut ab.gates[..b * NE], NE);
    let mu_e = ens(&mut ab.mu_e, b * KA);
    math::matmul_bias(&ab.h2[..b * HID], w3, b3, mu_e, b, HID, KA);
    for v in ab.mu_e[..b * KA].iter_mut() {
        *v = v.tanh();
    }
    let ls_e = ens(&mut ab.ls_e, b * KA);
    math::matmul_bias(&ab.h2[..b * HID], w4, b4, ls_e, b, HID, KA);

    // MoE combine: mu/ls = Σ_k gates_k · head_k
    let mu = ens(&mut ab.mu, b * A);
    mu.fill(0.0);
    let ls_raw = ens(&mut ab.ls_raw, b * A);
    ls_raw.fill(0.0);
    for i in 0..b {
        for k in 0..NE {
            let g = ab.gates[i * NE + k];
            let me = &ab.mu_e[i * KA + k * A..i * KA + (k + 1) * A];
            let le = &ab.ls_e[i * KA + k * A..i * KA + (k + 1) * A];
            for a in 0..A {
                ab.mu[i * A + a] += g * me[a];
                ab.ls_raw[i * A + a] += g * le[a];
            }
        }
    }
    Ok(())
}

/// `ls = clamp(ls_raw)` — kept separate from the forward so the backward
/// pass can gate on the raw (pre-clip) values.
fn clamp_ls(ab: &mut ActorBufs, b: usize, lo: f32, hi: f32) {
    let ls = ens(&mut ab.ls, b * A);
    for (o, &r) in ls.iter_mut().zip(&ab.ls_raw[..b * A]) {
        *o = r.clamp(lo, hi);
    }
}

/// a = tanh(mu + exp(ls)·eps); logp = Σ per-dim change-of-variables
/// log-prob. Fills `sa` (actions), `su` (pre-squash), `slogp` [b].
fn sample_squashed(
    mu: &[f32],
    ls: &[f32],
    eps: &[f32],
    b: usize,
    sa: &mut Vec<f32>,
    su: &mut Vec<f32>,
    slogp: &mut Vec<f32>,
) {
    const HALF_LN_2PI: f32 = 0.918_938_5;
    let a = ens(sa, b * A);
    let u = ens(su, b * A);
    let lp = ens(slogp, b);
    for i in 0..b {
        let mut acc = 0.0f64;
        for j in 0..A {
            let idx = i * A + j;
            let std = ls[idx].exp();
            let uv = mu[idx] + std * eps[idx];
            let av = uv.tanh();
            u[idx] = uv;
            a[idx] = av;
            let one_m_a2 = (1.0 - av * av).max(1e-6);
            acc += (-0.5 * eps[idx] * eps[idx] - ls[idx] - HALF_LN_2PI - one_m_a2.ln()) as f64;
        }
        lp[i] = acc as f32;
    }
}

/// x = [s ; a] row-interleaved, then the twin-critic body. `pn` is the
/// net's param-name table (`Wa, ba, Wb, bb, Wc, bc` order).
fn critic_fwd_into(
    store: &Store,
    pn: &[&str; 6],
    s: &[f32],
    a: &[f32],
    b: usize,
    cb: &mut CriticBufs,
) -> Result<()> {
    pack_xc(&mut cb.x, s, a, b);
    let (wa, ba) = (p(store, pn[0])?, p(store, pn[1])?);
    let (wb, bb) = (p(store, pn[2])?, p(store, pn[3])?);
    let (wc, bc) = (p(store, pn[4])?, p(store, pn[5])?);
    let za = ens(&mut cb.za, b * HID);
    math::matmul_bias(&cb.x[..b * XC], wa, ba, za, b, XC, HID);
    let ha = ens(&mut cb.ha, b * HID);
    math::gelu_map(&cb.za[..b * HID], ha);
    let zb = ens(&mut cb.zb, b * HID);
    math::matmul_bias(&cb.ha[..b * HID], wb, bb, zb, b, HID, HID);
    let hb = ens(&mut cb.hb, b * HID);
    math::gelu_map(&cb.zb[..b * HID], hb);
    let q = ens(&mut cb.q, b);
    for i in 0..b {
        let mut acc = bc[0];
        let hr = &cb.hb[i * HID..(i + 1) * HID];
        for l in 0..HID {
            acc += hr[l] * wc[l];
        }
        q[i] = acc;
    }
    Ok(())
}

/// Backward through one critic given dL/dq. Writes parameter grads into
/// `gr`; when `dx` is `Some`, also writes dL/dx ([b, XC]).
#[allow(clippy::too_many_arguments)]
fn critic_bwd(
    store: &Store,
    pn: &[&str; 6],
    cb: &CriticBufs,
    gq: &[f32],
    b: usize,
    gr: &mut CriticGrads,
    t1: &mut Vec<f32>,
    t2: &mut Vec<f32>,
    dx: Option<&mut Vec<f32>>,
) -> Result<()> {
    let wb = p(store, pn[2])?;
    let wc = p(store, pn[4])?;
    // g_hb = gq ⊗ Wc ; dWc = hbᵀ·gq ; dbc = Σ gq
    let g_hb = ens(t1, b * HID);
    for i in 0..b {
        let g = gq[i];
        let row = &mut g_hb[i * HID..(i + 1) * HID];
        for l in 0..HID {
            row[l] = g * wc[l];
        }
    }
    let dwc = ens(&mut gr.wc, HID);
    dwc.fill(0.0);
    let mut dbc = 0.0f32;
    for i in 0..b {
        let g = gq[i];
        let hr = &cb.hb[i * HID..(i + 1) * HID];
        for l in 0..HID {
            dwc[l] += hr[l] * g;
        }
        dbc += g;
    }
    ens(&mut gr.bc, 1)[0] = dbc;
    // through gelu(zb)
    math::gelu_bwd_inplace(&mut t1[..b * HID], &cb.zb[..b * HID]);
    let dwb = ens(&mut gr.wb, HID * HID);
    let dbb = ens(&mut gr.bb, HID);
    math::grad_w_b(&cb.ha[..b * HID], &t1[..b * HID], dwb, dbb, b, HID, HID);
    let g_ha = ens(t2, b * HID);
    math::matmul_wt(&t1[..b * HID], wb, g_ha, b, HID, HID);
    math::gelu_bwd_inplace(&mut t2[..b * HID], &cb.za[..b * HID]);
    let dwa = ens(&mut gr.wa, XC * HID);
    let dba = ens(&mut gr.ba, HID);
    math::grad_w_b(&cb.x[..b * XC], &t2[..b * HID], dwa, dba, b, XC, HID);
    if let Some(dxv) = dx {
        let wa = p(store, pn[0])?;
        let dxs = ens(dxv, b * XC);
        math::matmul_wt(&t2[..b * HID], wa, dxs, b, XC, HID);
    }
    Ok(())
}

fn mlp3_fwd_into(
    store: &Store,
    pn: &[&str; 6],
    b: usize,
    out_dim: usize,
    mb: &mut Mlp3Bufs,
) -> Result<()> {
    let (w1, b1) = (p(store, pn[0])?, p(store, pn[1])?);
    let (w2, b2) = (p(store, pn[2])?, p(store, pn[3])?);
    let (w3, b3) = (p(store, pn[4])?, p(store, pn[5])?);
    let z1 = ens(&mut mb.z1, b * M3H1);
    math::matmul_bias(&mb.x[..b * XC], w1, b1, z1, b, XC, M3H1);
    let h1 = ens(&mut mb.h1, b * M3H1);
    math::gelu_map(&mb.z1[..b * M3H1], h1);
    let z2 = ens(&mut mb.z2, b * M3H2);
    math::matmul_bias(&mb.h1[..b * M3H1], w2, b2, z2, b, M3H1, M3H2);
    let h2 = ens(&mut mb.h2, b * M3H2);
    math::gelu_map(&mb.z2[..b * M3H2], h2);
    let out = ens(&mut mb.out, b * out_dim);
    math::matmul_bias(&mb.h2[..b * M3H2], w3, b3, out, b, M3H2, out_dim);
    Ok(())
}

fn mlp3_bwd(
    store: &Store,
    pn: &[&str; 6],
    b: usize,
    out_dim: usize,
    mb: &mut Mlp3Bufs,
    gr: &mut Mlp3Grads,
) -> Result<()> {
    let w2 = p(store, pn[2])?;
    let w3 = p(store, pn[4])?;
    let dw3 = ens(&mut gr.w3, M3H2 * out_dim);
    let db3 = ens(&mut gr.b3, out_dim);
    math::grad_w_b(&mb.h2[..b * M3H2], &mb.gout[..b * out_dim], dw3, db3, b, M3H2, out_dim);
    let g_h2 = ens(&mut mb.g2, b * M3H2);
    math::matmul_wt(&mb.gout[..b * out_dim], w3, g_h2, b, M3H2, out_dim);
    math::gelu_bwd_inplace(&mut mb.g2[..b * M3H2], &mb.z2[..b * M3H2]);
    let dw2 = ens(&mut gr.w2, M3H1 * M3H2);
    let db2 = ens(&mut gr.b2, M3H2);
    math::grad_w_b(&mb.h1[..b * M3H1], &mb.g2[..b * M3H2], dw2, db2, b, M3H1, M3H2);
    let g_h1 = ens(&mut mb.g1, b * M3H1);
    math::matmul_wt(&mb.g2[..b * M3H2], w2, g_h1, b, M3H1, M3H2);
    math::gelu_bwd_inplace(&mut mb.g1[..b * M3H1], &mb.z1[..b * M3H1]);
    let dw1 = ens(&mut gr.w1, XC * M3H1);
    let db1 = ens(&mut gr.b1, M3H1);
    math::grad_w_b(&mb.x[..b * XC], &mb.g1[..b * M3H1], dw1, db1, b, XC, M3H1);
    Ok(())
}

// ----------------------------------------------------------- store update

/// Bias-corrected Adam over store-resident (param, moment) triplets,
/// in place and allocation-free (precomputed names; the moment vectors
/// are moved out and back around the parameter borrow).
fn adam_net(store: &mut Store, pmv: &[PMV], grads: &[&[f32]], ad: AdamStep) -> Result<()> {
    debug_assert_eq!(pmv.len(), grads.len());
    for ((pn, mn, vn), g) in pmv.iter().zip(grads) {
        let mut m = std::mem::take(
            store.data.get_mut(*mn).with_context(|| format!("store {mn} missing"))?,
        );
        let mut v = std::mem::take(
            store.data.get_mut(*vn).with_context(|| format!("store {vn} missing"))?,
        );
        {
            let pv =
                store.data.get_mut(*pn).with_context(|| format!("store {pn} missing"))?;
            if pv.len() != g.len() || m.len() != g.len() || v.len() != g.len() {
                bail!("adam {pn}: length mismatch ({} vs grad {})", pv.len(), g.len());
            }
            ad.apply(pv, g, &mut m, &mut v);
        }
        *store.data.get_mut(*mn).unwrap() = m;
        *store.data.get_mut(*vn).unwrap() = v;
    }
    Ok(())
}

/// Polyak target update: `t ← (1-τ)·t + τ·src` for every critic array.
fn polyak_net(store: &mut Store, tgt: &[&str; 6], src: &[&str; 6], tau: f32) -> Result<()> {
    for (tn, sn) in tgt.iter().zip(src) {
        let sv = std::mem::take(
            store.data.get_mut(*sn).with_context(|| format!("store {sn} missing"))?,
        );
        {
            let tv =
                store.data.get_mut(*tn).with_context(|| format!("store {tn} missing"))?;
            for (t, &s) in tv.iter_mut().zip(&sv) {
                *t = (1.0 - tau) * *t + tau * s;
            }
        }
        *store.data.get_mut(*sn).unwrap() = sv;
    }
    Ok(())
}

fn scalar_mut<'a>(store: &'a mut Store, name: &str) -> Result<&'a mut f32> {
    store
        .data
        .get_mut(name)
        .and_then(|v| v.first_mut())
        .with_context(|| format!("store scalar {name} missing"))
}

// ---------------------------------------------------------------- backend

impl Backend for NativeBackend {
    fn kind(&self) -> &'static str {
        "native"
    }

    fn describe(&self) -> String {
        format!(
            "native (pure Rust, {} kernels, allocation-free after warmup; {} stores, batch {})",
            super::kernels::active().name(),
            self.manifest.stores.len(),
            self.manifest.hyper_or("batch", 256.0) as usize
        )
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn actor_fwd(&mut self, store: &Store, s: &[f32]) -> Result<ActorOut<'_>> {
        let b = batch_of(s.len(), S, "actor_fwd state")?;
        actor_fwd_into(store, s, b, &mut self.sc.actor)?;
        clamp_ls(&mut self.sc.actor, b, self.h.logstd_min, self.h.logstd_max);
        Ok(ActorOut {
            mu: &self.sc.actor.mu[..b * A],
            log_std: &self.sc.actor.ls[..b * A],
            disc_logits: &self.sc.actor.dl[..b * D],
        })
    }

    fn wm_fwd(&mut self, store: &Store, s: &[f32], a: &[f32]) -> Result<&[f32]> {
        let b = batch_of(s.len(), S, "wm_fwd state")?;
        if a.len() != b * A {
            bail!("wm_fwd: action batch {} != state batch {b}", a.len() / A);
        }
        pack_xc(&mut self.sc.m3.x, s, a, b);
        mlp3_fwd_into(store, &WM_P, b, S, &mut self.sc.m3)?;
        let out = ens(&mut self.sc.fwd_out, b * S);
        for (o, (&sv, &dv)) in out.iter_mut().zip(s.iter().zip(&self.sc.m3.out[..b * S])) {
            *o = sv + dv;
        }
        Ok(&self.sc.fwd_out[..b * S])
    }

    fn sur_fwd(&mut self, store: &Store, s: &[f32], a: &[f32]) -> Result<&[f32]> {
        let b = batch_of(s.len(), S, "sur_fwd state")?;
        if a.len() != b * A {
            bail!("sur_fwd: action batch {} != state batch {b}", a.len() / A);
        }
        pack_xc(&mut self.sc.m3.x, s, a, b);
        mlp3_fwd_into(store, &SUR_P, b, PPA, &mut self.sc.m3)?;
        let out = ens(&mut self.sc.fwd_out, b * PPA);
        out.copy_from_slice(&self.sc.m3.out[..b * PPA]);
        Ok(&self.sc.fwd_out[..b * PPA])
    }

    fn sac_update(&mut self, store: &mut Store, bt: &SacBatch) -> Result<SacStepOut<'_>> {
        self.sac_update_impl(store, bt)?;
        let b = bt.b;
        Ok(SacStepOut { metrics: self.last_metrics, td_abs: &self.sc.td[..b] })
    }

    fn wm_update(&mut self, store: &mut Store, s: &[f32], a: &[f32], s2: &[f32]) -> Result<f64> {
        let b = batch_of(s.len(), S, "wm_update state")?;
        if a.len() != b * A || s2.len() != b * S {
            bail!("wm_update: inconsistent batch shapes");
        }
        pack_xc(&mut self.sc.m3.x, s, a, b);
        mlp3_fwd_into(store, &WM_P, b, S, &mut self.sc.m3)?;
        let gout = ens(&mut self.sc.m3.gout, b * S);
        let mut loss = 0.0f64;
        for i in 0..b * S {
            let delta = s2[i] - s[i];
            let diff = self.sc.m3.out[i] - delta;
            loss += (diff as f64) * (diff as f64);
            gout[i] = 2.0 * diff / b as f32;
        }
        loss /= b as f64;
        let step = *scalar_mut(store, "step")? as f64;
        mlp3_bwd(store, &WM_P, b, S, &mut self.sc.m3, &mut self.sc.mg)?;
        let ad = AdamStep::new(self.h.wm_lr, self.h.b1, self.h.b2, self.h.eps, step);
        let mg = &self.sc.mg;
        adam_net(
            store,
            &WM_PMV,
            &[&mg.w1, &mg.b1, &mg.w2, &mg.b2, &mg.w3[..M3H2 * S], &mg.b3[..S]],
            ad,
        )?;
        *scalar_mut(store, "step")? += 1.0;
        Ok(loss)
    }

    fn sur_update(&mut self, store: &mut Store, s: &[f32], a: &[f32], ppa: &[f32]) -> Result<f64> {
        let b = batch_of(s.len(), S, "sur_update state")?;
        if a.len() != b * A || ppa.len() != b * PPA {
            bail!("sur_update: inconsistent batch shapes");
        }
        pack_xc(&mut self.sc.m3.x, s, a, b);
        mlp3_fwd_into(store, &SUR_P, b, PPA, &mut self.sc.m3)?;
        let gout = ens(&mut self.sc.m3.gout, b * PPA);
        let mut loss = 0.0f64;
        for i in 0..b * PPA {
            let diff = self.sc.m3.out[i] - ppa[i];
            loss += (diff as f64) * (diff as f64);
            gout[i] = 2.0 * diff / b as f32;
        }
        loss /= b as f64;
        let step = *scalar_mut(store, "step")? as f64;
        mlp3_bwd(store, &SUR_P, b, PPA, &mut self.sc.m3, &mut self.sc.mg)?;
        let ad = AdamStep::new(self.h.sur_lr, self.h.b1, self.h.b2, self.h.eps, step);
        let mg = &self.sc.mg;
        adam_net(
            store,
            &SUR_PMV,
            &[&mg.w1, &mg.b1, &mg.w2, &mg.b2, &mg.w3[..M3H2 * PPA], &mg.b3[..PPA]],
            ad,
        )?;
        *scalar_mut(store, "step")? += 1.0;
        Ok(loss)
    }
}

/// Pack `[s ; a]` rows into the mlp3 input buffer.
fn pack_xc(x: &mut Vec<f32>, s: &[f32], a: &[f32], b: usize) {
    let xs = ens(x, b * XC);
    for i in 0..b {
        xs[i * XC..i * XC + S].copy_from_slice(&s[i * S..(i + 1) * S]);
        xs[i * XC + S..(i + 1) * XC].copy_from_slice(&a[i * A..(i + 1) * A]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn setup(seed: u64) -> (NativeBackend, Store) {
        let be = NativeBackend::builtin().unwrap();
        let store = Store::from_manifest(be.manifest(), &mut Rng::new(seed)).unwrap();
        (be, store)
    }

    fn uniform(n: usize, seed: u64, lo: f64, hi: f64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.uniform_in(lo, hi) as f32).collect()
    }

    #[test]
    fn actor_forward_shapes_clamps_and_row_consistency() {
        let (mut be, store) = setup(7);
        let s = uniform(3 * S, 1, -1.0, 1.0);
        let (mu, ls, dl) = {
            let out = be.actor_fwd(&store, &s).unwrap();
            assert_eq!(out.mu.len(), 3 * A);
            assert_eq!(out.log_std.len(), 3 * A);
            assert_eq!(out.disc_logits.len(), 3 * D);
            (out.mu.to_vec(), out.log_std.to_vec(), out.disc_logits.to_vec())
        };
        assert!(mu.iter().all(|v| v.is_finite()));
        assert!(dl.iter().all(|v| v.is_finite()));
        assert!(ls.iter().all(|&v| (-20.0..=2.0).contains(&v)));
        // batched row 0 is bit-identical to the B=1 forward (same op order
        // per row) — the property the MPC batching relies on
        let out1 = be.actor_fwd(&store, &s[..S]).unwrap();
        assert_eq!(out1.mu, &mu[..A]);
        assert_eq!(out1.disc_logits, &dl[..D]);
    }

    #[test]
    fn wm_forward_is_residual_at_zero_weights() {
        let (mut be, mut store) = setup(8);
        for name in WM_P {
            let n = store.get(name).unwrap().len();
            store.set(name, vec![0.0; n]).unwrap();
        }
        let s = uniform(2 * S, 2, -1.0, 1.0);
        let a = uniform(2 * A, 3, -1.0, 1.0);
        let out = be.wm_fwd(&store, &s, &a).unwrap();
        assert_eq!(out, &s[..]);
        let ppa = be.sur_fwd(&store, &s, &a).unwrap();
        assert_eq!(ppa.len(), 2 * PPA);
    }

    #[test]
    fn wm_and_sur_losses_decrease_on_fixed_batch() {
        // End-to-end gradient check: Adam on a fixed batch must reduce
        // the MSE. (The gradient math itself was validated against JAX
        // autodiff in f64; this pins the Rust port.)
        let (mut be, mut store) = setup(9);
        let b = 64;
        let s = uniform(b * S, 4, -1.0, 1.0);
        let a = uniform(b * A, 5, -1.0, 1.0);
        let s2 = uniform(b * S, 6, -1.0, 1.0);
        let ppa = uniform(b * PPA, 7, 0.0, 1.0);
        let wm0 = be.wm_update(&mut store, &s, &a, &s2).unwrap();
        let sur0 = be.sur_update(&mut store, &s, &a, &ppa).unwrap();
        let mut wm1 = wm0;
        let mut sur1 = sur0;
        for _ in 0..40 {
            wm1 = be.wm_update(&mut store, &s, &a, &s2).unwrap();
            sur1 = be.sur_update(&mut store, &s, &a, &ppa).unwrap();
        }
        assert!(wm1.is_finite() && wm1 < wm0, "wm {wm0} -> {wm1}");
        assert!(sur1.is_finite() && sur1 < sur0, "sur {sur0} -> {sur1}");
        // shared Adam step counter advanced once per update
        assert_eq!(store.get("step").unwrap()[0], 82.0);
    }

    fn synthetic_sac_batch(b: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        let mut s = vec![0.0f32; b * S];
        let mut a = vec![0.0f32; b * A];
        let mut ad = vec![0.0f32; b * D];
        let mut r = vec![0.0f32; b];
        let mut s2 = vec![0.0f32; b * S];
        let done = vec![0.0f32; b];
        let mut w = vec![0.0f32; b];
        let mut eps_cur = vec![0.0f32; b * A];
        let mut eps_next = vec![0.0f32; b * A];
        for v in s.iter_mut().chain(s2.iter_mut()) {
            *v = rng.uniform() as f32;
        }
        for v in a.iter_mut() {
            *v = rng.uniform_in(-0.95, 0.95) as f32;
        }
        for i in 0..b {
            for h in 0..NH {
                ad[i * D + h * NO + rng.below(NO)] = 1.0;
            }
            r[i] = rng.uniform_in(-1.0, 1.0) as f32;
            w[i] = rng.uniform_in(0.2, 1.5) as f32;
        }
        rng.fill_gaussian_f32(&mut eps_cur);
        rng.fill_gaussian_f32(&mut eps_next);
        vec![s, a, ad, r, s2, done, w, eps_cur, eps_next]
    }

    fn as_batch(v: &[Vec<f32>], b: usize) -> SacBatch<'_> {
        SacBatch {
            b,
            s: &v[0],
            a: &v[1],
            ad: &v[2],
            r: &v[3],
            s2: &v[4],
            done: &v[5],
            w: &v[6],
            eps_cur: &v[7],
            eps_next: &v[8],
        }
    }

    #[test]
    fn sac_update_moves_parameters_with_polyak_invariant() {
        let (mut be, mut store) = setup(10);
        let b = 8;
        let data = synthetic_sac_batch(b, 11);
        let w_before = store.get("actor/W1").unwrap().to_vec();
        let q_before = store.get("c1/Wa").unwrap().to_vec();
        let t_before = store.get("t1/Wa").unwrap().to_vec();
        let (metrics, td) = {
            let out = be.sac_update(&mut store, &as_batch(&data, b)).unwrap();
            (out.metrics, out.td_abs.to_vec())
        };
        assert!(metrics.critic_loss.is_finite() && metrics.actor_loss.is_finite());
        assert!(metrics.alpha > 0.0);
        assert_eq!(td.len(), b);
        assert!(td.iter().all(|v| v.is_finite() && *v >= 0.0));
        let w_after = store.get("actor/W1").unwrap();
        assert!(w_before.iter().zip(w_after).any(|(x, y)| x != y), "actor unchanged");
        // Polyak targets move much less than the online critic (tau=0.005)
        let max_d = |a: &[f32], b: &[f32]| {
            a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max)
        };
        let dq = max_d(store.get("c1/Wa").unwrap(), &q_before);
        let dt = max_d(store.get("t1/Wa").unwrap(), &t_before);
        assert!(dq > 0.0 && dt > 0.0 && dt < dq, "dq {dq} dt {dt}");
        // t1 = (1-tau)*t_before + tau*c1_new exactly
        let c1 = store.get("c1/Wa").unwrap();
        let t1 = store.get("t1/Wa").unwrap();
        for i in 0..8 {
            let want = 0.995 * t_before[i] + 0.005 * c1[i];
            assert!((t1[i] - want).abs() < 1e-6, "{} vs {want}", t1[i]);
        }
        assert_eq!(store.get("step").unwrap()[0], 1.0);
    }

    #[test]
    fn sac_update_is_seed_deterministic() {
        let run = || {
            let (mut be, mut store) = setup(12);
            let data = synthetic_sac_batch(6, 13);
            for _ in 0..3 {
                be.sac_update(&mut store, &as_batch(&data, 6)).unwrap();
            }
            store
        };
        let a = run();
        let b = run();
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn rejects_drifted_manifest() {
        let mut m = Manifest::builtin();
        let idx = m.stores.iter().position(|s| s.name == "actor/W1").unwrap();
        m.stores[idx].shape = vec![52, 128];
        assert!(NativeBackend::new(m).is_err());
        let mut m = Manifest::builtin();
        m.hyper.insert("hidden".into(), 512.0);
        assert!(NativeBackend::new(m).is_err());
    }

    #[test]
    fn batch_shape_validation() {
        let (mut be, mut store) = setup(14);
        assert!(be.actor_fwd(&store, &[0.0; 51]).is_err());
        assert!(be.actor_fwd(&store, &[]).is_err());
        let s = vec![0.0; S];
        assert!(be.wm_fwd(&store, &s, &[0.0; A + 1]).is_err());
        let data = synthetic_sac_batch(4, 15);
        let mut bt = as_batch(&data, 4);
        bt.b = 5; // inconsistent
        assert!(be.sac_update(&mut store, &bt).is_err());
    }
}
