//! f32 MLP kernels for the native backend: cache-blocked matmuls (forward
//! and both backward forms), the tanh-approximate GELU the Pallas kernel
//! bakes into the HLO (`python/compile/kernels/ref.py`), row softmax, and
//! bias-corrected Adam over store slices.
//!
//! Weight layout matches the manifest: `W[k, n]` row-major (`[in, out]`),
//! so the forward inner loop is an axpy over contiguous output rows —
//! auto-vectorizable, and the `LB`-row panel blocking keeps the streamed
//! weight panel resident in L1/L2 across the batch dimension.

#![allow(clippy::needless_range_loop)] // kernel loops index several slices

/// Panel height (rows of `W` per block) for the cache-blocked loops. A
/// 64×256 f32 panel is 64 KiB — comfortably cache-resident while the
/// batch dimension streams past it.
const LB: usize = 64;

/// tanh-approximate GELU constant: sqrt(2/π).
pub const GELU_C: f32 = 0.797_884_56;

#[inline]
pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (GELU_C * (x + 0.044715 * x * x * x)).tanh())
}

/// d/dx of the tanh-approximate GELU (mirrors `gelu_grad_ref`).
#[inline]
pub fn gelu_grad(x: f32) -> f32 {
    let t = (GELU_C * (x + 0.044715 * x * x * x)).tanh();
    let dt = (1.0 - t * t) * GELU_C * (1.0 + 3.0 * 0.044715 * x * x);
    0.5 * (1.0 + t) + 0.5 * x * dt
}

/// `y[m,n] = x[m,k] · w[k,n] + b[n]` (w row-major `[in, out]`).
pub fn matmul_bias(x: &[f32], w: &[f32], b: &[f32], y: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(b.len(), n);
    debug_assert_eq!(y.len(), m * n);
    for row in y.chunks_exact_mut(n) {
        row.copy_from_slice(b);
    }
    let mut l0 = 0;
    while l0 < k {
        let l1 = (l0 + LB).min(k);
        for i in 0..m {
            let xr = &x[i * k..(i + 1) * k];
            let yr = &mut y[i * n..(i + 1) * n];
            for l in l0..l1 {
                let xv = xr[l];
                if xv != 0.0 {
                    let wr = &w[l * n..(l + 1) * n];
                    for j in 0..n {
                        yr[j] += xv * wr[j];
                    }
                }
            }
        }
        l0 = l1;
    }
}

/// `dx[m,k] = g[m,n] · wᵀ` (w row-major `[k, n]`): per-element dot of a
/// `g` row with a `w` row, both contiguous.
pub fn matmul_wt(g: &[f32], w: &[f32], dx: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(g.len(), m * n);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(dx.len(), m * k);
    let mut i0 = 0;
    while i0 < m {
        let i1 = (i0 + LB / 2).min(m);
        for l in 0..k {
            let wr = &w[l * n..(l + 1) * n];
            for i in i0..i1 {
                let gr = &g[i * n..(i + 1) * n];
                let mut acc = 0.0f32;
                for j in 0..n {
                    acc += gr[j] * wr[j];
                }
                dx[i * k + l] = acc;
            }
        }
        i0 = i1;
    }
}

/// `dw[k,n] = xᵀ · g`, `db[n] = Σ_rows g` (overwrites both).
pub fn grad_w_b(
    x: &[f32],
    g: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(g.len(), m * n);
    debug_assert_eq!(dw.len(), k * n);
    debug_assert_eq!(db.len(), n);
    dw.fill(0.0);
    db.fill(0.0);
    let mut l0 = 0;
    while l0 < k {
        let l1 = (l0 + LB).min(k);
        for i in 0..m {
            let gr = &g[i * n..(i + 1) * n];
            for l in l0..l1 {
                let xv = x[i * k + l];
                if xv != 0.0 {
                    let dwr = &mut dw[l * n..(l + 1) * n];
                    for j in 0..n {
                        dwr[j] += xv * gr[j];
                    }
                }
            }
        }
        l0 = l1;
    }
    for gr in g.chunks_exact(n) {
        for j in 0..n {
            db[j] += gr[j];
        }
    }
}

/// `h[i] = gelu(z[i])` (separate buffers so `z` survives for backward).
pub fn gelu_map(z: &[f32], h: &mut [f32]) {
    debug_assert_eq!(z.len(), h.len());
    for (o, &v) in h.iter_mut().zip(z) {
        *o = gelu(v);
    }
}

/// `g[i] *= gelu'(z[i])` — activation backward, in place on the gradient.
pub fn gelu_bwd_inplace(g: &mut [f32], z: &[f32]) {
    debug_assert_eq!(g.len(), z.len());
    for (gv, &zv) in g.iter_mut().zip(z) {
        *gv *= gelu_grad(zv);
    }
}

/// In-place softmax over each `n`-wide row (max-subtracted, like
/// `jax.nn.softmax`).
pub fn softmax_rows(z: &mut [f32], n: usize) {
    debug_assert_eq!(z.len() % n, 0);
    for row in z.chunks_exact_mut(n) {
        let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Adam hyperparameters + the shared bias-correction terms for one step.
/// `corr1/corr2` are computed once per update from the *pre-increment*
/// step counter (`t+1`), exactly as the lowered `adam_step` does.
#[derive(Debug, Clone, Copy)]
pub struct AdamStep {
    pub lr: f32,
    pub b1: f32,
    pub b2: f32,
    pub eps: f32,
    pub corr1: f32,
    pub corr2: f32,
}

impl AdamStep {
    pub fn new(lr: f64, b1: f64, b2: f64, eps: f64, step: f64) -> AdamStep {
        let t = step + 1.0;
        AdamStep {
            lr: lr as f32,
            b1: b1 as f32,
            b2: b2 as f32,
            eps: eps as f32,
            corr1: (1.0 - b1.powf(t)) as f32,
            corr2: (1.0 - b2.powf(t)) as f32,
        }
    }

    /// `m ← β₁m + (1-β₁)g`, `v ← β₂v + (1-β₂)g²`,
    /// `p ← p − lr·(m̂)/(√v̂ + ε)` — all in place.
    pub fn apply(&self, p: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32]) {
        debug_assert_eq!(p.len(), g.len());
        debug_assert_eq!(p.len(), m.len());
        debug_assert_eq!(p.len(), v.len());
        for i in 0..p.len() {
            m[i] = self.b1 * m[i] + (1.0 - self.b1) * g[i];
            v[i] = self.b2 * v[i] + (1.0 - self.b2) * g[i] * g[i];
            p[i] -= self.lr * (m[i] / self.corr1) / ((v[i] / self.corr2).sqrt() + self.eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_matmul(x: &[f32], w: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut y = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = b[j];
                for l in 0..k {
                    acc += x[i * k + l] * w[l * n + j];
                }
                y[i * n + j] = acc;
            }
        }
        y
    }

    fn ramp(n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|i| ((i * 37 % 19) as f32 - 9.0) * scale).collect()
    }

    #[test]
    fn matmul_matches_naive_across_blocking_boundaries() {
        for (m, k, n) in [(1, 52, 256), (3, 82, 256), (7, 256, 120), (2, 130, 5)] {
            let x = ramp(m * k, 0.05);
            let w = ramp(k * n, 0.01);
            let b = ramp(n, 0.1);
            let mut y = vec![0.0f32; m * n];
            matmul_bias(&x, &w, &b, &mut y, m, k, n);
            let want = naive_matmul(&x, &w, &b, m, k, n);
            for (a, e) in y.iter().zip(&want) {
                assert!((a - e).abs() < 1e-4, "{a} vs {e}");
            }
        }
    }

    #[test]
    fn backward_forms_match_naive() {
        let (m, k, n) = (5, 70, 33);
        let x = ramp(m * k, 0.03);
        let w = ramp(k * n, 0.02);
        let g = ramp(m * n, 0.04);
        let mut dx = vec![0.0f32; m * k];
        matmul_wt(&g, &w, &mut dx, m, k, n);
        for i in 0..m {
            for l in 0..k {
                let mut acc = 0.0;
                for j in 0..n {
                    acc += g[i * n + j] * w[l * n + j];
                }
                assert!((dx[i * k + l] - acc).abs() < 1e-4);
            }
        }
        let mut dw = vec![0.0f32; k * n];
        let mut db = vec![0.0f32; n];
        grad_w_b(&x, &g, &mut dw, &mut db, m, k, n);
        for l in 0..k {
            for j in 0..n {
                let mut acc = 0.0;
                for i in 0..m {
                    acc += x[i * k + l] * g[i * n + j];
                }
                assert!((dw[l * n + j] - acc).abs() < 1e-4);
            }
        }
        for j in 0..n {
            let acc: f32 = (0..m).map(|i| g[i * n + j]).sum();
            assert!((db[j] - acc).abs() < 1e-4);
        }
    }

    #[test]
    fn gelu_reference_points() {
        // values from the python oracle (kernels/ref.py, f32)
        assert!((gelu(0.0) - 0.0).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-5);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-5);
        // grad ≈ finite difference
        for &x in &[-2.0f32, -0.5, 0.0, 0.7, 2.5] {
            let h = 1e-3;
            let fd = (gelu(x + h) - gelu(x - h)) / (2.0 * h);
            assert!((gelu_grad(x) - fd).abs() < 1e-3, "x={x}");
        }
    }

    #[test]
    fn softmax_rows_normalized() {
        let mut z = vec![1.0f32, 2.0, 3.0, 4.0, -1.0, 0.0, 1.0, 2.0];
        softmax_rows(&mut z, 4);
        for row in z.chunks_exact(4) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(row.windows(2).all(|w| w[0] < w[1]), "monotone logits");
        }
    }

    #[test]
    fn adam_step_first_iteration() {
        // t=0: corr1=1-0.9=0.1, m=0.1g, m̂=g, v̂=g² → p -= lr·g/(|g|+eps)
        let a = AdamStep::new(3e-4, 0.9, 0.999, 1e-8, 0.0);
        let mut p = vec![1.0f32];
        let mut m = vec![0.0f32];
        let mut v = vec![0.0f32];
        a.apply(&mut p, &[0.5], &mut m, &mut v);
        assert!((p[0] - (1.0 - 3e-4)).abs() < 1e-6, "{}", p[0]);
        assert!((m[0] - 0.05).abs() < 1e-7);
        assert!((v[0] - 0.001 * 0.25).abs() < 1e-9);
    }
}
