//! f32 MLP kernels for the native backend: cache-blocked matmuls (forward
//! and both backward forms), the tanh-approximate GELU the Pallas kernel
//! bakes into the HLO (`python/compile/kernels/ref.py`), row softmax, and
//! bias-corrected Adam over store slices.
//!
//! Weight layout matches the manifest: `W[k, n]` row-major (`[in, out]`),
//! so the forward inner loop is an axpy over contiguous output rows —
//! vectorizable, and the `LB`-row panel blocking keeps the streamed
//! weight panel resident in L1/L2 across the batch dimension.
//!
//! Every public kernel dispatches on the process-global
//! [`super::kernels`] path: [`scalar`] is the bit-exact determinism
//! reference (the default; all golden pins are defined against it), and
//! the [`avx2`] (x86_64) / [`neon`] (aarch64) paths are the
//! tolerance-parity SIMD implementations selected by `kernels=simd|auto`
//! (DESIGN.md §10). SIMD reassociates reductions and evaluates
//! exp/tanh by polynomial, so its outputs are *not* bitwise equal to
//! scalar — `tests/kernel_parity.rs` pins the tolerance contract.

#![allow(clippy::needless_range_loop)] // kernel loops index several slices

use super::kernels::{self, KernelPath};

/// Panel height (rows of `W` per block) for the cache-blocked loops. A
/// 64×256 f32 panel is 64 KiB — comfortably cache-resident while the
/// batch dimension streams past it.
const LB: usize = 64;

/// tanh-approximate GELU constant: sqrt(2/π).
pub const GELU_C: f32 = 0.797_884_56;

/// Cubic coefficient of the tanh-approximate GELU.
pub const GELU_A: f32 = 0.044715;

#[inline]
pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (GELU_C * (x + GELU_A * x * x * x)).tanh())
}

/// d/dx of the tanh-approximate GELU (mirrors `gelu_grad_ref`).
#[inline]
pub fn gelu_grad(x: f32) -> f32 {
    let t = (GELU_C * (x + GELU_A * x * x * x)).tanh();
    let dt = (1.0 - t * t) * GELU_C * (1.0 + 3.0 * GELU_A * x * x);
    0.5 * (1.0 + t) + 0.5 * x * dt
}

/// `y[m,n] = x[m,k] · w[k,n] + b[n]` (w row-major `[in, out]`).
pub fn matmul_bias(x: &[f32], w: &[f32], b: &[f32], y: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(b.len(), n);
    debug_assert_eq!(y.len(), m * n);
    match kernels::active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the global path is Avx2 only when avx2+fma are detected.
        KernelPath::Avx2 => unsafe { avx2::matmul_bias(x, w, b, y, m, k, n) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is architecturally guaranteed on aarch64.
        KernelPath::Neon => unsafe { neon::matmul_bias(x, w, b, y, m, k, n) },
        _ => scalar::matmul_bias(x, w, b, y, m, k, n),
    }
}

/// `dx[m,k] = g[m,n] · wᵀ` (w row-major `[k, n]`): per-element dot of a
/// `g` row with a `w` row, both contiguous.
pub fn matmul_wt(g: &[f32], w: &[f32], dx: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(g.len(), m * n);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(dx.len(), m * k);
    match kernels::active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the global path is Avx2 only when avx2+fma are detected.
        KernelPath::Avx2 => unsafe { avx2::matmul_wt(g, w, dx, m, k, n) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is architecturally guaranteed on aarch64.
        KernelPath::Neon => unsafe { neon::matmul_wt(g, w, dx, m, k, n) },
        _ => scalar::matmul_wt(g, w, dx, m, k, n),
    }
}

/// `dw[k,n] = xᵀ · g`, `db[n] = Σ_rows g` (overwrites both).
pub fn grad_w_b(
    x: &[f32],
    g: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(g.len(), m * n);
    debug_assert_eq!(dw.len(), k * n);
    debug_assert_eq!(db.len(), n);
    match kernels::active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the global path is Avx2 only when avx2+fma are detected.
        KernelPath::Avx2 => unsafe { avx2::grad_w_b(x, g, dw, db, m, k, n) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is architecturally guaranteed on aarch64.
        KernelPath::Neon => unsafe { neon::grad_w_b(x, g, dw, db, m, k, n) },
        _ => scalar::grad_w_b(x, g, dw, db, m, k, n),
    }
}

/// `h[i] = gelu(z[i])` (separate buffers so `z` survives for backward).
pub fn gelu_map(z: &[f32], h: &mut [f32]) {
    debug_assert_eq!(z.len(), h.len());
    match kernels::active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the global path is Avx2 only when avx2+fma are detected.
        KernelPath::Avx2 => unsafe { avx2::gelu_map(z, h) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is architecturally guaranteed on aarch64.
        KernelPath::Neon => unsafe { neon::gelu_map(z, h) },
        _ => scalar::gelu_map(z, h),
    }
}

/// `g[i] *= gelu'(z[i])` — activation backward, in place on the gradient.
pub fn gelu_bwd_inplace(g: &mut [f32], z: &[f32]) {
    debug_assert_eq!(g.len(), z.len());
    match kernels::active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the global path is Avx2 only when avx2+fma are detected.
        KernelPath::Avx2 => unsafe { avx2::gelu_bwd_inplace(g, z) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is architecturally guaranteed on aarch64.
        KernelPath::Neon => unsafe { neon::gelu_bwd_inplace(g, z) },
        _ => scalar::gelu_bwd_inplace(g, z),
    }
}

/// In-place softmax over each `n`-wide row (max-subtracted, like
/// `jax.nn.softmax`).
pub fn softmax_rows(z: &mut [f32], n: usize) {
    debug_assert_eq!(z.len() % n, 0);
    match kernels::active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the global path is Avx2 only when avx2+fma are detected.
        KernelPath::Avx2 => unsafe { avx2::softmax_rows(z, n) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is architecturally guaranteed on aarch64.
        KernelPath::Neon => unsafe { neon::softmax_rows(z, n) },
        _ => scalar::softmax_rows(z, n),
    }
}

/// Adam hyperparameters + the shared bias-correction terms for one step.
/// `corr1/corr2` are computed once per update from the *pre-increment*
/// step counter (`t+1`), exactly as the lowered `adam_step` does.
#[derive(Debug, Clone, Copy)]
pub struct AdamStep {
    pub lr: f32,
    pub b1: f32,
    pub b2: f32,
    pub eps: f32,
    pub corr1: f32,
    pub corr2: f32,
}

impl AdamStep {
    pub fn new(lr: f64, b1: f64, b2: f64, eps: f64, step: f64) -> AdamStep {
        let t = step + 1.0;
        AdamStep {
            lr: lr as f32,
            b1: b1 as f32,
            b2: b2 as f32,
            eps: eps as f32,
            corr1: (1.0 - b1.powf(t)) as f32,
            corr2: (1.0 - b2.powf(t)) as f32,
        }
    }

    /// `m ← β₁m + (1-β₁)g`, `v ← β₂v + (1-β₂)g²`,
    /// `p ← p − lr·(m̂)/(√v̂ + ε)` — all in place.
    pub fn apply(&self, p: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32]) {
        debug_assert_eq!(p.len(), g.len());
        debug_assert_eq!(p.len(), m.len());
        debug_assert_eq!(p.len(), v.len());
        match kernels::active() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: the global path is Avx2 only when avx2+fma are detected.
            KernelPath::Avx2 => unsafe { avx2::adam_apply(self, p, g, m, v) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON is architecturally guaranteed on aarch64.
            KernelPath::Neon => unsafe { neon::adam_apply(self, p, g, m, v) },
            _ => scalar::adam_apply(self, p, g, m, v),
        }
    }
}

// ------------------------------------------------------------- scalar path

/// The bit-exact reference kernels. These bodies are byte-for-byte the
/// pre-SIMD implementations; every golden pin (`tests/native_backend.rs`,
/// `tests/vecenv.rs`) and the B-lane ≡ B-serial contract is defined
/// against them, so they must never change observable arithmetic.
/// Exposed `pub` so parity tests and benches can target this path
/// explicitly without touching the process-global dispatch mode.
pub mod scalar {
    use super::{AdamStep, LB};

    pub fn matmul_bias(
        x: &[f32],
        w: &[f32],
        b: &[f32],
        y: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        for row in y.chunks_exact_mut(n) {
            row.copy_from_slice(b);
        }
        let mut l0 = 0;
        while l0 < k {
            let l1 = (l0 + LB).min(k);
            for i in 0..m {
                let xr = &x[i * k..(i + 1) * k];
                let yr = &mut y[i * n..(i + 1) * n];
                for l in l0..l1 {
                    let xv = xr[l];
                    if xv != 0.0 {
                        let wr = &w[l * n..(l + 1) * n];
                        for j in 0..n {
                            yr[j] += xv * wr[j];
                        }
                    }
                }
            }
            l0 = l1;
        }
    }

    pub fn matmul_wt(g: &[f32], w: &[f32], dx: &mut [f32], m: usize, k: usize, n: usize) {
        let mut i0 = 0;
        while i0 < m {
            let i1 = (i0 + LB / 2).min(m);
            for l in 0..k {
                let wr = &w[l * n..(l + 1) * n];
                for i in i0..i1 {
                    let gr = &g[i * n..(i + 1) * n];
                    let mut acc = 0.0f32;
                    for j in 0..n {
                        acc += gr[j] * wr[j];
                    }
                    dx[i * k + l] = acc;
                }
            }
            i0 = i1;
        }
    }

    pub fn grad_w_b(
        x: &[f32],
        g: &[f32],
        dw: &mut [f32],
        db: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        dw.fill(0.0);
        db.fill(0.0);
        let mut l0 = 0;
        while l0 < k {
            let l1 = (l0 + LB).min(k);
            for i in 0..m {
                let gr = &g[i * n..(i + 1) * n];
                for l in l0..l1 {
                    let xv = x[i * k + l];
                    if xv != 0.0 {
                        let dwr = &mut dw[l * n..(l + 1) * n];
                        for j in 0..n {
                            dwr[j] += xv * gr[j];
                        }
                    }
                }
            }
            l0 = l1;
        }
        for gr in g.chunks_exact(n) {
            for j in 0..n {
                db[j] += gr[j];
            }
        }
    }

    pub fn gelu_map(z: &[f32], h: &mut [f32]) {
        for (o, &v) in h.iter_mut().zip(z) {
            *o = super::gelu(v);
        }
    }

    pub fn gelu_bwd_inplace(g: &mut [f32], z: &[f32]) {
        for (gv, &zv) in g.iter_mut().zip(z) {
            *gv *= super::gelu_grad(zv);
        }
    }

    pub fn softmax_rows(z: &mut [f32], n: usize) {
        for row in z.chunks_exact_mut(n) {
            let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0f32;
            for v in row.iter_mut() {
                *v = (*v - m).exp();
                sum += *v;
            }
            let inv = 1.0 / sum;
            for v in row.iter_mut() {
                *v *= inv;
            }
        }
    }

    pub fn adam_apply(a: &AdamStep, p: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32]) {
        for i in 0..p.len() {
            m[i] = a.b1 * m[i] + (1.0 - a.b1) * g[i];
            v[i] = a.b2 * v[i] + (1.0 - a.b2) * g[i] * g[i];
            p[i] -= a.lr * (m[i] / a.corr1) / ((v[i] / a.corr2).sqrt() + a.eps);
        }
    }
}

// --------------------------------------------------------- AVX2+FMA path

/// x86_64 AVX2+FMA kernels: 8-wide f32 with broadcast-FMA axpy bodies,
/// dot-product reductions with a horizontal sum, and a Cephes-style
/// polynomial `exp` feeding vectorized tanh (GELU) and softmax. Ragged
/// tails (`n % 8`) run the scalar formula per element. All functions
/// require avx2+fma at runtime (enforced by [`super::super::kernels`]
/// detection before dispatch); reductions reassociate, so results are
/// tolerance-equal — not bitwise equal — to [`super::scalar`].
#[cfg(target_arch = "x86_64")]
pub mod avx2 {
    // Safety contract (all fns): caller must ensure avx2+fma are
    // available (kernels::detect() == Some(Avx2)); slice lengths must
    // satisfy the documented m/k/n shapes, as in the dispatching wrappers.
    #![allow(clippy::missing_safety_doc)]

    use super::{AdamStep, GELU_A, GELU_C, LB};
    use core::arch::x86_64::*;

    /// Horizontal sum of the 8 f32 lanes.
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn hsum(v: __m256) -> f32 {
        let s = _mm_add_ps(_mm256_castps256_ps128(v), _mm256_extractf128_ps(v, 1));
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_movehdup_ps(s));
        _mm_cvtss_f32(s)
    }

    /// Horizontal max of the 8 f32 lanes.
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn hmax(v: __m256) -> f32 {
        let s = _mm_max_ps(_mm256_castps256_ps128(v), _mm256_extractf128_ps(v, 1));
        let s = _mm_max_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_max_ss(s, _mm_movehdup_ps(s));
        _mm_cvtss_f32(s)
    }

    /// Cephes-style f32 `exp`: range-reduce `x = n·ln2 + r`, degree-5
    /// polynomial in `r`, scale by `2ⁿ` through the exponent bits.
    /// Matches libm `expf` to ~1 ulp over the clamped domain.
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn exp8(x: __m256) -> __m256 {
        let x = _mm256_max_ps(
            _mm256_min_ps(x, _mm256_set1_ps(88.376_26)),
            _mm256_set1_ps(-88.376_26),
        );
        let log2e = _mm256_set1_ps(std::f32::consts::LOG2_E);
        let fx = _mm256_floor_ps(_mm256_fmadd_ps(x, log2e, _mm256_set1_ps(0.5)));
        let r = _mm256_fnmadd_ps(fx, _mm256_set1_ps(0.693_359_4), x);
        let r = _mm256_fnmadd_ps(fx, _mm256_set1_ps(-2.121_944_4e-4), r);
        let r2 = _mm256_mul_ps(r, r);
        let mut p = _mm256_set1_ps(1.987_569_1e-4);
        p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(1.398_199_9e-3));
        p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(8.333_452e-3));
        p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(4.166_579_6e-2));
        p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(1.666_666_5e-1));
        p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(5.000_000_1e-1));
        let p = _mm256_fmadd_ps(p, r2, _mm256_add_ps(r, _mm256_set1_ps(1.0)));
        let pow2n = _mm256_castsi256_ps(_mm256_slli_epi32::<23>(_mm256_add_epi32(
            _mm256_cvttps_epi32(fx),
            _mm256_set1_epi32(0x7f),
        )));
        _mm256_mul_ps(p, pow2n)
    }

    /// `tanh(y) = 1 − 2/(e^{2y} + 1)`; `exp8`'s clamp saturates the
    /// large-|y| limits correctly.
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn tanh8(y: __m256) -> __m256 {
        let one = _mm256_set1_ps(1.0);
        let e = exp8(_mm256_add_ps(y, y));
        _mm256_sub_ps(one, _mm256_div_ps(_mm256_set1_ps(2.0), _mm256_add_ps(e, one)))
    }

    pub unsafe fn matmul_bias(
        x: &[f32],
        w: &[f32],
        b: &[f32],
        y: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        for row in y.chunks_exact_mut(n) {
            row.copy_from_slice(b);
        }
        let mut l0 = 0;
        while l0 < k {
            let l1 = (l0 + LB).min(k);
            for i in 0..m {
                let xr = &x[i * k..(i + 1) * k];
                let yp = y.as_mut_ptr().add(i * n);
                for l in l0..l1 {
                    let xv = xr[l];
                    if xv != 0.0 {
                        let wp = w.as_ptr().add(l * n);
                        let vx = _mm256_set1_ps(xv);
                        let mut j = 0;
                        while j + 8 <= n {
                            let acc = _mm256_fmadd_ps(
                                vx,
                                _mm256_loadu_ps(wp.add(j)),
                                _mm256_loadu_ps(yp.add(j)),
                            );
                            _mm256_storeu_ps(yp.add(j), acc);
                            j += 8;
                        }
                        while j < n {
                            *yp.add(j) += xv * *wp.add(j);
                            j += 1;
                        }
                    }
                }
            }
            l0 = l1;
        }
    }

    pub unsafe fn matmul_wt(g: &[f32], w: &[f32], dx: &mut [f32], m: usize, k: usize, n: usize) {
        let mut i0 = 0;
        while i0 < m {
            let i1 = (i0 + LB / 2).min(m);
            for l in 0..k {
                let wp = w.as_ptr().add(l * n);
                for i in i0..i1 {
                    let gp = g.as_ptr().add(i * n);
                    let mut acc = _mm256_setzero_ps();
                    let mut j = 0;
                    while j + 8 <= n {
                        acc = _mm256_fmadd_ps(
                            _mm256_loadu_ps(gp.add(j)),
                            _mm256_loadu_ps(wp.add(j)),
                            acc,
                        );
                        j += 8;
                    }
                    let mut tail = 0.0f32;
                    while j < n {
                        tail += *gp.add(j) * *wp.add(j);
                        j += 1;
                    }
                    dx[i * k + l] = hsum(acc) + tail;
                }
            }
            i0 = i1;
        }
    }

    pub unsafe fn grad_w_b(
        x: &[f32],
        g: &[f32],
        dw: &mut [f32],
        db: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        dw.fill(0.0);
        db.fill(0.0);
        let mut l0 = 0;
        while l0 < k {
            let l1 = (l0 + LB).min(k);
            for i in 0..m {
                let gp = g.as_ptr().add(i * n);
                for l in l0..l1 {
                    let xv = x[i * k + l];
                    if xv != 0.0 {
                        let dwp = dw.as_mut_ptr().add(l * n);
                        let vx = _mm256_set1_ps(xv);
                        let mut j = 0;
                        while j + 8 <= n {
                            let acc = _mm256_fmadd_ps(
                                vx,
                                _mm256_loadu_ps(gp.add(j)),
                                _mm256_loadu_ps(dwp.add(j)),
                            );
                            _mm256_storeu_ps(dwp.add(j), acc);
                            j += 8;
                        }
                        while j < n {
                            *dwp.add(j) += xv * *gp.add(j);
                            j += 1;
                        }
                    }
                }
            }
            l0 = l1;
        }
        let dbp = db.as_mut_ptr();
        for i in 0..m {
            let gp = g.as_ptr().add(i * n);
            let mut j = 0;
            while j + 8 <= n {
                let acc = _mm256_add_ps(_mm256_loadu_ps(dbp.add(j)), _mm256_loadu_ps(gp.add(j)));
                _mm256_storeu_ps(dbp.add(j), acc);
                j += 8;
            }
            while j < n {
                *dbp.add(j) += *gp.add(j);
                j += 1;
            }
        }
    }

    pub unsafe fn gelu_map(z: &[f32], h: &mut [f32]) {
        let n = z.len();
        let c = _mm256_set1_ps(GELU_C);
        let a = _mm256_set1_ps(GELU_A);
        let one = _mm256_set1_ps(1.0);
        let half = _mm256_set1_ps(0.5);
        let mut j = 0;
        while j + 8 <= n {
            let x = _mm256_loadu_ps(z.as_ptr().add(j));
            let x2 = _mm256_mul_ps(x, x);
            // y = C·(x + A·x³) = C·x·(1 + A·x²)
            let y = _mm256_mul_ps(c, _mm256_mul_ps(x, _mm256_fmadd_ps(a, x2, one)));
            let t = tanh8(y);
            let out = _mm256_mul_ps(_mm256_mul_ps(half, x), _mm256_add_ps(one, t));
            _mm256_storeu_ps(h.as_mut_ptr().add(j), out);
            j += 8;
        }
        while j < n {
            h[j] = super::gelu(z[j]);
            j += 1;
        }
    }

    pub unsafe fn gelu_bwd_inplace(g: &mut [f32], z: &[f32]) {
        let n = z.len();
        let c = _mm256_set1_ps(GELU_C);
        let a = _mm256_set1_ps(GELU_A);
        let a3 = _mm256_set1_ps(3.0 * GELU_A);
        let one = _mm256_set1_ps(1.0);
        let half = _mm256_set1_ps(0.5);
        let mut j = 0;
        while j + 8 <= n {
            let x = _mm256_loadu_ps(z.as_ptr().add(j));
            let x2 = _mm256_mul_ps(x, x);
            let y = _mm256_mul_ps(c, _mm256_mul_ps(x, _mm256_fmadd_ps(a, x2, one)));
            let t = tanh8(y);
            // dt = (1 − t²)·C·(1 + 3A·x²)
            let dt = _mm256_mul_ps(
                _mm256_fnmadd_ps(t, t, one),
                _mm256_mul_ps(c, _mm256_fmadd_ps(a3, x2, one)),
            );
            // gelu' = ½(1 + t) + ½·x·dt
            let grad = _mm256_fmadd_ps(
                _mm256_mul_ps(half, x),
                dt,
                _mm256_mul_ps(half, _mm256_add_ps(one, t)),
            );
            let gp = g.as_mut_ptr().add(j);
            _mm256_storeu_ps(gp, _mm256_mul_ps(_mm256_loadu_ps(gp), grad));
            j += 8;
        }
        while j < n {
            g[j] *= super::gelu_grad(z[j]);
            j += 1;
        }
    }

    pub unsafe fn softmax_rows(z: &mut [f32], n: usize) {
        if n < 8 {
            // gate/head softmaxes are 4–5 wide; the vector setup would
            // cost more than it saves
            super::scalar::softmax_rows(z, n);
            return;
        }
        for row in z.chunks_exact_mut(n) {
            let rp = row.as_mut_ptr();
            let mut vmax = _mm256_set1_ps(f32::NEG_INFINITY);
            let mut j = 0;
            while j + 8 <= n {
                vmax = _mm256_max_ps(vmax, _mm256_loadu_ps(rp.add(j)));
                j += 8;
            }
            let mut m = hmax(vmax);
            while j < n {
                m = m.max(*rp.add(j));
                j += 1;
            }
            let vm = _mm256_set1_ps(m);
            let mut vsum = _mm256_setzero_ps();
            j = 0;
            while j + 8 <= n {
                let e = exp8(_mm256_sub_ps(_mm256_loadu_ps(rp.add(j)), vm));
                _mm256_storeu_ps(rp.add(j), e);
                vsum = _mm256_add_ps(vsum, e);
                j += 8;
            }
            let mut sum = hsum(vsum);
            while j < n {
                let e = (*rp.add(j) - m).exp();
                *rp.add(j) = e;
                sum += e;
                j += 1;
            }
            let vi = _mm256_set1_ps(1.0 / sum);
            j = 0;
            while j + 8 <= n {
                _mm256_storeu_ps(rp.add(j), _mm256_mul_ps(_mm256_loadu_ps(rp.add(j)), vi));
                j += 8;
            }
            let inv = 1.0 / sum;
            while j < n {
                *rp.add(j) *= inv;
                j += 1;
            }
        }
    }

    pub unsafe fn adam_apply(
        a: &AdamStep,
        p: &mut [f32],
        g: &[f32],
        m: &mut [f32],
        v: &mut [f32],
    ) {
        let n = p.len();
        let vb1 = _mm256_set1_ps(a.b1);
        let vk1 = _mm256_set1_ps(1.0 - a.b1);
        let vb2 = _mm256_set1_ps(a.b2);
        let vk2 = _mm256_set1_ps(1.0 - a.b2);
        let vlr = _mm256_set1_ps(a.lr);
        let vc1 = _mm256_set1_ps(a.corr1);
        let vc2 = _mm256_set1_ps(a.corr2);
        let veps = _mm256_set1_ps(a.eps);
        let mut j = 0;
        while j + 8 <= n {
            let vg = _mm256_loadu_ps(g.as_ptr().add(j));
            let mp = m.as_mut_ptr().add(j);
            let vp_ = v.as_mut_ptr().add(j);
            let pp = p.as_mut_ptr().add(j);
            let vm = _mm256_fmadd_ps(vb1, _mm256_loadu_ps(mp), _mm256_mul_ps(vk1, vg));
            let vv = _mm256_fmadd_ps(
                vb2,
                _mm256_loadu_ps(vp_),
                _mm256_mul_ps(_mm256_mul_ps(vk2, vg), vg),
            );
            _mm256_storeu_ps(mp, vm);
            _mm256_storeu_ps(vp_, vv);
            let num = _mm256_mul_ps(vlr, _mm256_div_ps(vm, vc1));
            let den = _mm256_add_ps(_mm256_sqrt_ps(_mm256_div_ps(vv, vc2)), veps);
            let upd = _mm256_div_ps(num, den);
            _mm256_storeu_ps(pp, _mm256_sub_ps(_mm256_loadu_ps(pp), upd));
            j += 8;
        }
        while j < n {
            m[j] = a.b1 * m[j] + (1.0 - a.b1) * g[j];
            v[j] = a.b2 * v[j] + (1.0 - a.b2) * g[j] * g[j];
            p[j] -= a.lr * (m[j] / a.corr1) / ((v[j] / a.corr2).sqrt() + a.eps);
            j += 1;
        }
    }
}

// -------------------------------------------------------------- NEON path

/// aarch64 NEON kernels: 4-wide f32 analogues of the [`avx2`] bodies
/// (FMLA axpy, `vaddvq` horizontal reductions, the same Cephes `exp`
/// polynomial). NEON is baseline on aarch64, so no runtime detection is
/// needed beyond the dispatch gate.
#[cfg(target_arch = "aarch64")]
pub mod neon {
    // Safety contract (all fns): NEON baseline on aarch64; slice lengths
    // must satisfy the documented m/k/n shapes (dispatcher-checked).
    #![allow(clippy::missing_safety_doc)]

    use super::{AdamStep, GELU_A, GELU_C, LB};
    use core::arch::aarch64::*;

    /// Cephes-style f32 `exp` (same range reduction + degree-5 polynomial
    /// as the AVX2 path).
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn exp4(x: float32x4_t) -> float32x4_t {
        let x = vmaxq_f32(vminq_f32(x, vdupq_n_f32(88.376_26)), vdupq_n_f32(-88.376_26));
        let fx = vrndmq_f32(vfmaq_f32(
            vdupq_n_f32(0.5),
            x,
            vdupq_n_f32(std::f32::consts::LOG2_E),
        ));
        let r = vfmsq_f32(x, fx, vdupq_n_f32(0.693_359_4));
        let r = vfmsq_f32(r, fx, vdupq_n_f32(-2.121_944_4e-4));
        let r2 = vmulq_f32(r, r);
        let mut p = vdupq_n_f32(1.987_569_1e-4);
        p = vfmaq_f32(vdupq_n_f32(1.398_199_9e-3), p, r);
        p = vfmaq_f32(vdupq_n_f32(8.333_452e-3), p, r);
        p = vfmaq_f32(vdupq_n_f32(4.166_579_6e-2), p, r);
        p = vfmaq_f32(vdupq_n_f32(1.666_666_5e-1), p, r);
        p = vfmaq_f32(vdupq_n_f32(5.000_000_1e-1), p, r);
        let p = vfmaq_f32(vaddq_f32(r, vdupq_n_f32(1.0)), p, r2);
        let pow2n = vreinterpretq_f32_s32(vshlq_n_s32::<23>(vaddq_s32(
            vcvtq_s32_f32(fx),
            vdupq_n_s32(0x7f),
        )));
        vmulq_f32(p, pow2n)
    }

    /// `tanh(y) = 1 − 2/(e^{2y} + 1)`.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn tanh4(y: float32x4_t) -> float32x4_t {
        let one = vdupq_n_f32(1.0);
        let e = exp4(vaddq_f32(y, y));
        vsubq_f32(one, vdivq_f32(vdupq_n_f32(2.0), vaddq_f32(e, one)))
    }

    pub unsafe fn matmul_bias(
        x: &[f32],
        w: &[f32],
        b: &[f32],
        y: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        for row in y.chunks_exact_mut(n) {
            row.copy_from_slice(b);
        }
        let mut l0 = 0;
        while l0 < k {
            let l1 = (l0 + LB).min(k);
            for i in 0..m {
                let xr = &x[i * k..(i + 1) * k];
                let yp = y.as_mut_ptr().add(i * n);
                for l in l0..l1 {
                    let xv = xr[l];
                    if xv != 0.0 {
                        let wp = w.as_ptr().add(l * n);
                        let vx = vdupq_n_f32(xv);
                        let mut j = 0;
                        while j + 4 <= n {
                            let acc = vfmaq_f32(vld1q_f32(yp.add(j)), vx, vld1q_f32(wp.add(j)));
                            vst1q_f32(yp.add(j), acc);
                            j += 4;
                        }
                        while j < n {
                            *yp.add(j) += xv * *wp.add(j);
                            j += 1;
                        }
                    }
                }
            }
            l0 = l1;
        }
    }

    pub unsafe fn matmul_wt(g: &[f32], w: &[f32], dx: &mut [f32], m: usize, k: usize, n: usize) {
        let mut i0 = 0;
        while i0 < m {
            let i1 = (i0 + LB / 2).min(m);
            for l in 0..k {
                let wp = w.as_ptr().add(l * n);
                for i in i0..i1 {
                    let gp = g.as_ptr().add(i * n);
                    let mut acc = vdupq_n_f32(0.0);
                    let mut j = 0;
                    while j + 4 <= n {
                        acc = vfmaq_f32(acc, vld1q_f32(gp.add(j)), vld1q_f32(wp.add(j)));
                        j += 4;
                    }
                    let mut tail = 0.0f32;
                    while j < n {
                        tail += *gp.add(j) * *wp.add(j);
                        j += 1;
                    }
                    dx[i * k + l] = vaddvq_f32(acc) + tail;
                }
            }
            i0 = i1;
        }
    }

    pub unsafe fn grad_w_b(
        x: &[f32],
        g: &[f32],
        dw: &mut [f32],
        db: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        dw.fill(0.0);
        db.fill(0.0);
        let mut l0 = 0;
        while l0 < k {
            let l1 = (l0 + LB).min(k);
            for i in 0..m {
                let gp = g.as_ptr().add(i * n);
                for l in l0..l1 {
                    let xv = x[i * k + l];
                    if xv != 0.0 {
                        let dwp = dw.as_mut_ptr().add(l * n);
                        let vx = vdupq_n_f32(xv);
                        let mut j = 0;
                        while j + 4 <= n {
                            let acc = vfmaq_f32(vld1q_f32(dwp.add(j)), vx, vld1q_f32(gp.add(j)));
                            vst1q_f32(dwp.add(j), acc);
                            j += 4;
                        }
                        while j < n {
                            *dwp.add(j) += xv * *gp.add(j);
                            j += 1;
                        }
                    }
                }
            }
            l0 = l1;
        }
        let dbp = db.as_mut_ptr();
        for i in 0..m {
            let gp = g.as_ptr().add(i * n);
            let mut j = 0;
            while j + 4 <= n {
                vst1q_f32(dbp.add(j), vaddq_f32(vld1q_f32(dbp.add(j)), vld1q_f32(gp.add(j))));
                j += 4;
            }
            while j < n {
                *dbp.add(j) += *gp.add(j);
                j += 1;
            }
        }
    }

    pub unsafe fn gelu_map(z: &[f32], h: &mut [f32]) {
        let n = z.len();
        let c = vdupq_n_f32(GELU_C);
        let a = vdupq_n_f32(GELU_A);
        let one = vdupq_n_f32(1.0);
        let half = vdupq_n_f32(0.5);
        let mut j = 0;
        while j + 4 <= n {
            let x = vld1q_f32(z.as_ptr().add(j));
            let x2 = vmulq_f32(x, x);
            let y = vmulq_f32(c, vmulq_f32(x, vfmaq_f32(one, a, x2)));
            let t = tanh4(y);
            let out = vmulq_f32(vmulq_f32(half, x), vaddq_f32(one, t));
            vst1q_f32(h.as_mut_ptr().add(j), out);
            j += 4;
        }
        while j < n {
            h[j] = super::gelu(z[j]);
            j += 1;
        }
    }

    pub unsafe fn gelu_bwd_inplace(g: &mut [f32], z: &[f32]) {
        let n = z.len();
        let c = vdupq_n_f32(GELU_C);
        let a = vdupq_n_f32(GELU_A);
        let a3 = vdupq_n_f32(3.0 * GELU_A);
        let one = vdupq_n_f32(1.0);
        let half = vdupq_n_f32(0.5);
        let mut j = 0;
        while j + 4 <= n {
            let x = vld1q_f32(z.as_ptr().add(j));
            let x2 = vmulq_f32(x, x);
            let y = vmulq_f32(c, vmulq_f32(x, vfmaq_f32(one, a, x2)));
            let t = tanh4(y);
            let dt = vmulq_f32(vfmsq_f32(one, t, t), vmulq_f32(c, vfmaq_f32(one, a3, x2)));
            let grad = vfmaq_f32(vmulq_f32(half, vaddq_f32(one, t)), vmulq_f32(half, x), dt);
            let gp = g.as_mut_ptr().add(j);
            vst1q_f32(gp, vmulq_f32(vld1q_f32(gp), grad));
            j += 4;
        }
        while j < n {
            g[j] *= super::gelu_grad(z[j]);
            j += 1;
        }
    }

    pub unsafe fn softmax_rows(z: &mut [f32], n: usize) {
        if n < 4 {
            super::scalar::softmax_rows(z, n);
            return;
        }
        for row in z.chunks_exact_mut(n) {
            let rp = row.as_mut_ptr();
            let mut vmax = vdupq_n_f32(f32::NEG_INFINITY);
            let mut j = 0;
            while j + 4 <= n {
                vmax = vmaxq_f32(vmax, vld1q_f32(rp.add(j)));
                j += 4;
            }
            let mut m = vmaxvq_f32(vmax);
            while j < n {
                m = m.max(*rp.add(j));
                j += 1;
            }
            let vm = vdupq_n_f32(m);
            let mut vsum = vdupq_n_f32(0.0);
            j = 0;
            while j + 4 <= n {
                let e = exp4(vsubq_f32(vld1q_f32(rp.add(j)), vm));
                vst1q_f32(rp.add(j), e);
                vsum = vaddq_f32(vsum, e);
                j += 4;
            }
            let mut sum = vaddvq_f32(vsum);
            while j < n {
                let e = (*rp.add(j) - m).exp();
                *rp.add(j) = e;
                sum += e;
                j += 1;
            }
            let inv = 1.0 / sum;
            let vi = vdupq_n_f32(inv);
            j = 0;
            while j + 4 <= n {
                vst1q_f32(rp.add(j), vmulq_f32(vld1q_f32(rp.add(j)), vi));
                j += 4;
            }
            while j < n {
                *rp.add(j) *= inv;
                j += 1;
            }
        }
    }

    pub unsafe fn adam_apply(
        a: &AdamStep,
        p: &mut [f32],
        g: &[f32],
        m: &mut [f32],
        v: &mut [f32],
    ) {
        let n = p.len();
        let vb1 = vdupq_n_f32(a.b1);
        let vk1 = vdupq_n_f32(1.0 - a.b1);
        let vb2 = vdupq_n_f32(a.b2);
        let vk2 = vdupq_n_f32(1.0 - a.b2);
        let vlr = vdupq_n_f32(a.lr);
        let vc1 = vdupq_n_f32(a.corr1);
        let vc2 = vdupq_n_f32(a.corr2);
        let veps = vdupq_n_f32(a.eps);
        let mut j = 0;
        while j + 4 <= n {
            let vg = vld1q_f32(g.as_ptr().add(j));
            let mp = m.as_mut_ptr().add(j);
            let vp_ = v.as_mut_ptr().add(j);
            let pp = p.as_mut_ptr().add(j);
            let vm = vfmaq_f32(vmulq_f32(vk1, vg), vb1, vld1q_f32(mp));
            let vv = vfmaq_f32(vmulq_f32(vmulq_f32(vk2, vg), vg), vb2, vld1q_f32(vp_));
            vst1q_f32(mp, vm);
            vst1q_f32(vp_, vv);
            let num = vmulq_f32(vlr, vdivq_f32(vm, vc1));
            let den = vaddq_f32(vsqrtq_f32(vdivq_f32(vv, vc2)), veps);
            vst1q_f32(pp, vsubq_f32(vld1q_f32(pp), vdivq_f32(num, den)));
            j += 4;
        }
        while j < n {
            m[j] = a.b1 * m[j] + (1.0 - a.b1) * g[j];
            v[j] = a.b2 * v[j] + (1.0 - a.b2) * g[j] * g[j];
            p[j] -= a.lr * (m[j] / a.corr1) / ((v[j] / a.corr2).sqrt() + a.eps);
            j += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_matmul(x: &[f32], w: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut y = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = b[j];
                for l in 0..k {
                    acc += x[i * k + l] * w[l * n + j];
                }
                y[i * n + j] = acc;
            }
        }
        y
    }

    fn ramp(n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|i| ((i * 37 % 19) as f32 - 9.0) * scale).collect()
    }

    #[test]
    fn matmul_matches_naive_across_blocking_boundaries() {
        for (m, k, n) in [(1, 52, 256), (3, 82, 256), (7, 256, 120), (2, 130, 5)] {
            let x = ramp(m * k, 0.05);
            let w = ramp(k * n, 0.01);
            let b = ramp(n, 0.1);
            let mut y = vec![0.0f32; m * n];
            matmul_bias(&x, &w, &b, &mut y, m, k, n);
            let want = naive_matmul(&x, &w, &b, m, k, n);
            for (a, e) in y.iter().zip(&want) {
                assert!((a - e).abs() < 1e-4, "{a} vs {e}");
            }
        }
    }

    #[test]
    fn backward_forms_match_naive() {
        let (m, k, n) = (5, 70, 33);
        let x = ramp(m * k, 0.03);
        let w = ramp(k * n, 0.02);
        let g = ramp(m * n, 0.04);
        let mut dx = vec![0.0f32; m * k];
        matmul_wt(&g, &w, &mut dx, m, k, n);
        for i in 0..m {
            for l in 0..k {
                let mut acc = 0.0;
                for j in 0..n {
                    acc += g[i * n + j] * w[l * n + j];
                }
                assert!((dx[i * k + l] - acc).abs() < 1e-4);
            }
        }
        let mut dw = vec![0.0f32; k * n];
        let mut db = vec![0.0f32; n];
        grad_w_b(&x, &g, &mut dw, &mut db, m, k, n);
        for l in 0..k {
            for j in 0..n {
                let mut acc = 0.0;
                for i in 0..m {
                    acc += x[i * k + l] * g[i * n + j];
                }
                assert!((dw[l * n + j] - acc).abs() < 1e-4);
            }
        }
        for j in 0..n {
            let acc: f32 = (0..m).map(|i| g[i * n + j]).sum();
            assert!((db[j] - acc).abs() < 1e-4);
        }
    }

    #[test]
    fn gelu_reference_points() {
        // values from the python oracle (kernels/ref.py, f32)
        assert!((gelu(0.0) - 0.0).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-5);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-5);
        // grad ≈ finite difference
        for &x in &[-2.0f32, -0.5, 0.0, 0.7, 2.5] {
            let h = 1e-3;
            let fd = (gelu(x + h) - gelu(x - h)) / (2.0 * h);
            assert!((gelu_grad(x) - fd).abs() < 1e-3, "x={x}");
        }
    }

    #[test]
    fn softmax_rows_normalized() {
        let mut z = vec![1.0f32, 2.0, 3.0, 4.0, -1.0, 0.0, 1.0, 2.0];
        softmax_rows(&mut z, 4);
        for row in z.chunks_exact(4) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(row.windows(2).all(|w| w[0] < w[1]), "monotone logits");
        }
    }

    #[test]
    fn adam_step_first_iteration() {
        // t=0: corr1=1-0.9=0.1, m=0.1g, m̂=g, v̂=g² → p -= lr·g/(|g|+eps)
        let a = AdamStep::new(3e-4, 0.9, 0.999, 1e-8, 0.0);
        let mut p = vec![1.0f32];
        let mut m = vec![0.0f32];
        let mut v = vec![0.0f32];
        a.apply(&mut p, &[0.5], &mut m, &mut v);
        assert!((p[0] - (1.0 - 3e-4)).abs() < 1e-6, "{}", p[0]);
        assert!((m[0] - 0.05).abs() < 1e-7);
        assert!((v[0] - 0.001 * 0.25).abs() < 1e-9);
    }

    #[test]
    fn default_dispatch_is_bitwise_scalar() {
        // the process-global path defaults to scalar, so the dispatching
        // kernels must be bitwise equal to an explicit scalar call — this
        // is what keeps every golden pin in the suite on the reference
        assert_eq!(kernels::active(), KernelPath::Scalar);
        let (m, k, n) = (3, 82, 120);
        let x = ramp(m * k, 0.05);
        let w = ramp(k * n, 0.01);
        let b = ramp(n, 0.1);
        let mut y1 = vec![0.0f32; m * n];
        let mut y2 = vec![0.0f32; m * n];
        matmul_bias(&x, &w, &b, &mut y1, m, k, n);
        scalar::matmul_bias(&x, &w, &b, &mut y2, m, k, n);
        for (a, e) in y1.iter().zip(&y2) {
            assert_eq!(a.to_bits(), e.to_bits());
        }
    }

    // Inline SIMD smoke checks (full randomized/ragged coverage lives in
    // tests/kernel_parity.rs): call the explicit per-path functions, so
    // the process-global dispatch mode is never touched.

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_smoke_matches_scalar() {
        if super::super::kernels::detect() != Some(KernelPath::Avx2) {
            eprintln!("skipping: avx2+fma not available");
            return;
        }
        let (m, k, n) = (4, 52, 37); // ragged n on purpose
        let x = ramp(m * k, 0.05);
        let w = ramp(k * n, 0.01);
        let b = ramp(n, 0.1);
        let mut ys = vec![0.0f32; m * n];
        let mut yv = vec![0.0f32; m * n];
        scalar::matmul_bias(&x, &w, &b, &mut ys, m, k, n);
        // SAFETY: capability checked above
        unsafe { avx2::matmul_bias(&x, &w, &b, &mut yv, m, k, n) };
        for (a, e) in yv.iter().zip(&ys) {
            assert!((a - e).abs() <= 1e-5 * (1.0 + e.abs()), "{a} vs {e}");
        }
        let z = ramp(67, 0.3);
        let mut hs = vec![0.0f32; 67];
        let mut hv = vec![0.0f32; 67];
        scalar::gelu_map(&z, &mut hs);
        // SAFETY: capability checked above
        unsafe { avx2::gelu_map(&z, &mut hv) };
        for (a, e) in hv.iter().zip(&hs) {
            assert!((a - e).abs() <= 1e-5 * (1.0 + e.abs()), "{a} vs {e}");
        }
    }

    #[cfg(target_arch = "aarch64")]
    #[test]
    fn neon_smoke_matches_scalar() {
        let (m, k, n) = (4, 52, 37);
        let x = ramp(m * k, 0.05);
        let w = ramp(k * n, 0.01);
        let b = ramp(n, 0.1);
        let mut ys = vec![0.0f32; m * n];
        let mut yv = vec![0.0f32; m * n];
        scalar::matmul_bias(&x, &w, &b, &mut ys, m, k, n);
        // SAFETY: NEON is baseline on aarch64
        unsafe { neon::matmul_bias(&x, &w, &b, &mut yv, m, k, n) };
        for (a, e) in yv.iter().zip(&ys) {
            assert!((a - e).abs() <= 1e-5 * (1.0 + e.abs()), "{a} vs {e}");
        }
        let z = ramp(67, 0.3);
        let mut hs = vec![0.0f32; 67];
        let mut hv = vec![0.0f32; 67];
        scalar::gelu_map(&z, &mut hs);
        // SAFETY: NEON is baseline on aarch64
        unsafe { neon::gelu_map(&z, &mut hv) };
        for (a, e) in hv.iter().zip(&hs) {
            assert!((a - e).abs() <= 1e-5 * (1.0 + e.abs()), "{a} vs {e}");
        }
    }
}
