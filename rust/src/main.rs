//! silicon-rl — CLI leader for the RL-driven ASIC architecture explorer.
//!
//! Subcommands:
//!   optimize  [key=value ...]  — run Algorithm 1 over the configured
//!                                nodes; emit per-node design artifacts,
//!                                convergence traces and all report tables
//!   baselines [key=value ...]  — SAC vs random vs grid (Table 21)
//!   atlas     [key=value ...]  — dominance-pruned, cache-warm sweep over
//!                                the full scenario grid (workloads ×
//!                                nodes × phase × seq_len × batch); emits
//!                                the merged Pareto atlas + reuse counters
//!   fuzz      [key=value ...]  — randomized differential equivalence
//!                                harness (DESIGN.md §14): generate valid
//!                                configs, run each equivalence-class
//!                                oracle as paired executions, shrink any
//!                                counterexample to a minimal reproducer
//!   report    [key=value ...]  — workload statistics (Tables 8/9)
//!   workloads                  — registered workload specs (Table 8)
//!   info                       — runtime/platform/manifest diagnostics
//!                                + the workload registry
//!
//! Config keys (see config::RunConfig::apply): workload=<registry name>,
//! phase=prefill|decode, seq_len=N, batch=N, mode=hp|lp, nodes=3,5,...,
//! episodes=N, warmup=N, seed=N, granularity=op|group, kv=...,
//! backend=native|pjrt|auto, kernels=scalar|simd|auto,
//! checkpoint_every=N, resume=DIR, crash_after=N (fault injection),
//! out_dir=..., artifacts_dir=...
//!
//! (The image vendors no CLI crate; parsing is a ~40-line hand-rolled
//! key=value scheme — DESIGN.md §4.)

use std::path::Path;

use silicon_rl::artifacts_out;
use silicon_rl::bail;
use silicon_rl::config::RunConfig;
use silicon_rl::error::{Context, Error, Result};
use silicon_rl::eval::parallel;
use silicon_rl::ir::registry;
use silicon_rl::nn::{backend, kernels};
use silicon_rl::report::{self, NodeSummary};
use silicon_rl::rl::{self, baselines, SacAgent};
use silicon_rl::util::Rng;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_config(args: &[String]) -> Result<RunConfig> {
    let mut cfg = RunConfig::default();
    // allow `mode=lp` to swap the whole profile first
    if args.iter().any(|a| a == "mode=lp" || a == "mode=low-power") {
        cfg = RunConfig::smolvlm_low_power();
    }
    for a in args {
        if a == "--no-prune" {
            // exact fallback for the argmax-only commands that default
            // roofline admission pruning on
            cfg.rl.prune = false;
            cfg.prune_explicit = true;
            continue;
        }
        if let Some(v) = a.strip_prefix("--lanes=") {
            // CLI alias for the `lanes=` config key (vec-env width)
            cfg.apply("lanes", v).map_err(Error::msg)?;
            continue;
        }
        if let Some(path) = a.strip_prefix("config=") {
            cfg.load_file(path).map_err(Error::msg)?;
            continue;
        }
        let (k, v) = a
            .split_once('=')
            .with_context(|| format!("expected key=value, got {a}"))?;
        if k == "mode" {
            continue; // handled above
        }
        cfg.apply(k, v).map_err(Error::msg)?;
    }
    // install the kernel path once, up front: every compute kernel in
    // this process (NN forwards/updates, placement scoring) dispatches on
    // the resolved global from here on
    kernels::set_global(cfg.kernels);
    Ok(cfg)
}

/// Default roofline admission pruning ON for a command where only the
/// argmax matters, unless the user said otherwise (`prune=...` /
/// `--no-prune` on the CLI, or a `prune =` config-file line). The
/// selected designs are bit-identical either way; pruning only removes
/// provably-losing candidates from the full pipeline (and from
/// per-episode logs / Pareto archives).
fn default_prune_on(cfg: &mut RunConfig) {
    if !cfg.prune_explicit {
        cfg.rl.prune = true;
    }
}

fn run(args: &[String]) -> Result<()> {
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "optimize" => optimize(&args[1..]),
        "baselines" => run_baselines(&args[1..]),
        "seeds" => run_multiseed(&args[1..]),
        "atlas" => run_atlas(&args[1..]),
        "fuzz" => run_fuzz(&args[1..]),
        "report" => workload_report(&args[1..]),
        "workloads" => {
            println!("{}", report::workload_registry(registry::all()).to_text());
            Ok(())
        }
        "info" => info(&args[1..]),
        "help" | "--help" | "-h" => {
            println!(
                "silicon-rl — RL-driven ASIC architecture exploration\n\n\
                 usage: silicon-rl <optimize|baselines|seeds|atlas|fuzz|report|workloads|info> [key=value ...]\n\
                 keys:  workload=<name> (see below) mode=hp|lp nodes=3,5,7 episodes=N\n\
                 \u{20}      phase=prefill|decode seq_len=N batch=N (scenario axes)\n\
                 \u{20}      warmup=N seed=N granularity=op|group kv=full|int8|int4|...\n\
                 \u{20}      threads=N candidate_batch=N parallel_nodes=true|false\n\
                 \u{20}      lanes=N | --lanes=N (vec-env width, 0 = auto; seeds also\n\
                 \u{20}      takes search=random|sac — sac drives nodes x seeds as lanes)\n\
                 \u{20}      learner=inline|pinned|async (where SAC/WM/surrogate updates\n\
                 \u{20}      run: inline on the rollout thread, pinned = dedicated thread\n\
                 \u{20}      replaying the exact inline schedule (bit-identical), async =\n\
                 \u{20}      free-running for throughput)\n\
                 \u{20}      updates_per_step=X (async update budget, 0 = uncapped)\n\
                 \u{20}      queue_cap=N (rollout->learner bound in transitions, 0 = auto)\n\
                 \u{20}      prune=true|false (--no-prune = exact argmax fallback)\n\
                 \u{20}      checkpoint_every=N (crash-safe snapshot every N steps,\n\
                 \u{20}      0 = off; double-slot atomic generations in <out_dir>/ckpt)\n\
                 \u{20}      resume=DIR (continue from the newest valid checkpoint in\n\
                 \u{20}      DIR or DIR/ckpt; bit-identical to the uninterrupted run)\n\
                 \u{20}      crash_after=N (fault injection: kill the run at the Nth\n\
                 \u{20}      step-boundary probe) learner_fail_after=N (fault injection:\n\
                 \u{20}      panic the learner thread; run degrades to inline updates)\n\
                 \u{20}      atlas keys: atlas_workloads=a,b (default: all registered)\n\
                 \u{20}      atlas_phases=decode,prefill atlas_seq_lens=512,2048,8192\n\
                 \u{20}      atlas_batches=1,4 atlas_seeds=N (seeds per grid point)\n\
                 \u{20}      atlas_prune=on|off (roofline dominance pruning; off = exact\n\
                 \u{20}      fallback) atlas_warm=on|off (shared caches + warm agents)\n\
                 \u{20}      atlas_shrink=N (0 = skip dominated points, N = episodes/N)\n\
                 \u{20}      fuzz keys: iters=N (cases, default 25) seed=N (generator\n\
                 \u{20}      seed, default 42) classes=a,b (default: all equivalence\n\
                 \u{20}      classes) shrink=on|off budget=N (shrink attempts)\n\
                 \u{20}      out_dir=DIR (repro files) repro=FILE (re-run a saved\n\
                 \u{20}      reproducer) oracle=NAME [key=value ...] (one explicit case)\n\
                 \u{20}      backend=native|pjrt|auto (auto: pjrt when artifacts exist)\n\
                 \u{20}      kernels=scalar|simd|auto (scalar: bit-exact reference;\n\
                 \u{20}      simd: AVX2/NEON, auto-detected)\n\
                 \u{20}      out_dir=DIR artifacts_dir=DIR config=FILE\n"
            );
            println!("{}", report::workload_registry(registry::all()).to_text());
            Ok(())
        }
        other => bail!("unknown command {other} (try `silicon-rl help`)"),
    }
}

/// Full Algorithm 1 run. Default (`lanes=0` auto on a multicore
/// machine): the node sweep runs as lanes of ONE vec-env — a shared
/// agent (Eq 50's cross-node transfer), batched actor forwards, per-lane
/// derived seeds, updates amortized on the shared step counter
/// (DESIGN.md §9). `lanes=1` falls back to the legacy serial loop (one
/// shared agent, sequential nodes, one RNG stream). With
/// `parallel_nodes=true`: one agent per node, nodes fanned across worker
/// threads — deterministic per node, reported in configured node order.
fn optimize(args: &[String]) -> Result<()> {
    let mut cfg = parse_config(args)?;
    // only the MPC rerank argmax prunes here — outputs are identical
    default_prune_on(&mut cfg);
    let cfg = cfg;
    let out_dir = Path::new(&cfg.out_dir);
    std::fs::create_dir_all(out_dir)?;
    let scn = cfg.scenario();
    println!(
        "workload={} phase={} seq_len={} batch={} mode={}",
        cfg.workload.name(),
        scn.phase.name(),
        scn.seq_len,
        scn.batch,
        cfg.mode.name
    );

    let lanes = cfg.resolve_lanes(cfg.nodes_nm.len());
    let mut learner_report = None;
    let results = if cfg.parallel_nodes {
        optimize_nodes_parallel(&cfg)?
    } else if lanes > 1
        || cfg.rl.learner.off_loop()
        || cfg.rl.checkpoint_every > 0
        || cfg.resume.is_some()
    {
        // an off-loop learner always goes through the vec-env driver —
        // it owns the rollout/learner split even at a single lane — and
        // so do checkpointed or resumed runs (the vec-env driver hosts
        // the checkpoint sink, DESIGN.md §13)
        let (r, rep) = optimize_nodes_vec(&cfg, lanes)?;
        learner_report = rep;
        r
    } else {
        optimize_nodes_serial(&cfg)?
    };

    for (nm, result, dt) in &results {
        match &result.best {
            Some(b) => {
                let o = &b.outcome;
                println!(
                    "{nm:>2}nm: best ep {:>5}  mesh {}x{}  {:>9.0} tok/s  {:>8.0} mW  {:>7.0} mm2  score {:.3}  ({:.1}s, {} feasible/{})",
                    b.episode,
                    o.decoded.mesh.width,
                    o.decoded.mesh.height,
                    o.ppa.tokens_per_s,
                    o.ppa.power.total(),
                    o.ppa.area.total(),
                    o.reward.score,
                    dt,
                    result.feasible_count,
                    result.total_episodes,
                );
                artifacts_out::write_node_artifacts(out_dir, *nm, o)?;
            }
            None => println!("{nm:>2}nm: NO feasible configuration found"),
        }
        report::convergence_csv(&result.episodes)
            .write_csv(&out_dir.join(format!("fig3_convergence_{nm}nm.csv")))?;
    }

    let results: Vec<rl::NodeResult> =
        results.into_iter().map(|(_, r, _)| r).collect();
    emit_reports(&cfg, &results, learner_report.as_ref(), out_dir)
}

fn optimize_nodes_serial(cfg: &RunConfig) -> Result<Vec<(u32, rl::NodeResult, f64)>> {
    let be = backend::load(&cfg.artifacts_dir, cfg.backend)?;
    println!("backend: {}", be.describe());
    println!("kernels: {}", kernels::describe(cfg.kernels));
    let mut rng = Rng::new(cfg.seed);
    let mut agent = SacAgent::new(be, cfg.rl, &mut rng)?;
    println!(
        "parameter store: {} arrays, {} elements",
        agent.store.data.len(),
        agent.store.total_elems()
    );

    let mut results = Vec::new();
    for &nm in &cfg.nodes_nm {
        let t0 = std::time::Instant::now();
        let result = rl::run_node(cfg, nm, &mut agent, &mut rng)?;
        results.push((nm, result, t0.elapsed().as_secs_f64()));
    }
    Ok(results)
}

/// Vec-env node sweep: every configured node is one lane of a single
/// vectorized rollout (waves of `lanes`), sharing ONE agent — so the
/// sweep keeps Eq 50's cross-node transfer learning (unlike
/// `parallel_nodes=true`) while the hot loop runs one batched actor
/// forward per step and fans env transitions across cores. Per-lane
/// rollouts are deterministic from their derived seeds; updates are
/// amortized on the shared step counter (DESIGN.md §9).
fn optimize_nodes_vec(
    cfg: &RunConfig,
    lanes: usize,
) -> Result<(Vec<(u32, rl::NodeResult, f64)>, Option<rl::LearnerReport>)> {
    let be = backend::load(&cfg.artifacts_dir, cfg.backend)?;
    println!("backend: {}", be.describe());
    println!("kernels: {}", kernels::describe(cfg.kernels));
    let mut rng = Rng::new(cfg.seed);
    let mut agent = SacAgent::new(be, cfg.rl, &mut rng)?;
    println!(
        "parameter store: {} arrays, {} elements",
        agent.store.data.len(),
        agent.store.total_elems()
    );
    let jobs: Vec<rl::LaneSpec> = cfg
        .nodes_nm
        .iter()
        .enumerate()
        .map(|(i, &nm)| rl::LaneSpec { nm, seed: rl::multiseed::derive_seed(cfg.seed, i) })
        .collect();
    // off-loop learner modes hold one core back for the learner thread
    let threads = cfg.rollout_threads();
    println!(
        "vec-env sweep: {} node lanes in waves of {lanes} (shared agent, {} eval \
         thread(s), learner={})",
        jobs.len(),
        threads,
        cfg.rl.learner.name()
    );
    let t0 = std::time::Instant::now();
    let (results, learner) = rl::run_jobs_stats(cfg, &jobs, lanes, &mut agent, threads)?;
    let dt = t0.elapsed().as_secs_f64();
    let rs = rl::vecenv::reward_stats(&results);
    println!(
        "vec-env: {} lane-episodes in {dt:.1}s ({:.0} steps/s), reward mean {:.3} \
         std {:.3}",
        rs.count(),
        rs.count() as f64 / dt.max(1e-9),
        rs.mean(),
        rs.std()
    );
    if let Some(rep) = &learner {
        println!("{}", rep.banner());
    }
    // wall-clock is shared across concurrently-stepped lanes; report the
    // sweep total per node
    let rows = cfg.nodes_nm.iter().zip(results).map(|(&nm, r)| (nm, r, dt)).collect();
    Ok((rows, learner))
}

fn optimize_nodes_parallel(cfg: &RunConfig) -> Result<Vec<(u32, rl::NodeResult, f64)>> {
    let total = cfg.eval_threads();
    let threads = total.min(cfg.nodes_nm.len()).max(1);
    // split the worker budget between the node fan-out and each node's
    // inner evaluate_many (MPC rerank) so concurrent nodes don't each
    // grab every core
    let mut worker_cfg = cfg.clone();
    worker_cfg.rl.eval_threads = (total / threads).max(1);
    println!(
        "parallel node sweep: {} nodes on {} threads ({} eval thread(s) each, \
         independent agents)",
        cfg.nodes_nm.len(),
        threads,
        worker_cfg.rl.eval_threads
    );
    // per-node RNG streams derived in configured order, so results do not
    // depend on scheduling
    let mut root = Rng::new(cfg.seed);
    let jobs: Vec<(u32, Rng)> =
        cfg.nodes_nm.iter().map(|&nm| (nm, root.fork(nm as u64))).collect();

    let worker_cfg = &worker_cfg;
    let outcomes: Vec<Result<(u32, rl::NodeResult, f64)>> = parallel::scoped_chunk_map(
        &jobs,
        threads,
        || (),
        |_, _i, (nm, rng)| -> Result<(u32, rl::NodeResult, f64)> {
            let t0 = std::time::Instant::now();
            let be = backend::load(&worker_cfg.artifacts_dir, worker_cfg.backend)?;
            let mut rng = rng.clone();
            let mut agent = SacAgent::new(be, worker_cfg.rl, &mut rng)?;
            let result = rl::run_node(worker_cfg, *nm, &mut agent, &mut rng)?;
            Ok((*nm, result, t0.elapsed().as_secs_f64()))
        },
    );
    outcomes.into_iter().collect()
}

fn emit_reports(
    cfg: &RunConfig,
    results: &[rl::NodeResult],
    learner: Option<&rl::LearnerReport>,
    out_dir: &Path,
) -> Result<()> {
    let rows: Vec<NodeSummary> =
        results.iter().filter_map(NodeSummary::from_result).collect();
    if rows.is_empty() {
        bail!("no node produced a feasible design; nothing to report");
    }

    let tables = [
        ("table10_nodes.csv", report::nodes_table(&rows)),
        ("table12_power.csv", report::power_breakdown(&rows)),
        ("table13_scaling.csv", report::scaling_analysis(&rows)),
        ("table18_efficiency.csv", report::efficiency_table(&rows)),
        (
            "table14_run_stats.csv",
            report::run_stats(
                results,
                cfg.mode.name,
                &cfg.scenario(),
                &kernels::describe(cfg.kernels),
                learner,
            ),
        ),
        ("table20_industry.csv", report::industry_comparison(rows.first())),
    ];
    for (file, t) in &tables {
        println!("\n{}", t.to_text());
        t.write_csv(&out_dir.join(file))?;
    }

    // Table 15/16 + Fig 10-12a from the best node's tile artifacts
    if let Some(best) = results
        .iter()
        .filter(|r| r.best.is_some())
        .min_by(|a, b| {
            a.best_outcome().reward.score.total_cmp(&b.best_outcome().reward.score)
        })
    {
        let o = best.best_outcome();
        let t15 = report::tile_regions(&o.decoded.mesh, &o.tiles);
        let t16 = report::tile_param_summary(&o.tiles);
        println!("{}", t15.to_text());
        println!("{}", t16.to_text());
        t15.write_csv(&out_dir.join("table15_regions.csv"))?;
        t16.write_csv(&out_dir.join("table16_tiles.csv"))?;
    }

    // Table 17 / Fig 12b: best (highest-throughput) vs oldest node
    if rows.len() >= 2 {
        let best = rows
            .iter()
            .max_by(|a, b| a.tokens_per_s.total_cmp(&b.tokens_per_s))
            .unwrap();
        let worst = rows.iter().max_by(|a, b| a.nm.cmp(&b.nm)).unwrap();
        let t17 = report::cross_node_compare(best, worst);
        println!("{}", t17.to_text());
        t17.write_csv(&out_dir.join("table17_compare.csv"))?;
    }
    println!("reports written to {}", out_dir.display());
    Ok(())
}

/// Table 21: SAC vs random vs grid under the same episode budget.
fn run_baselines(args: &[String]) -> Result<()> {
    let mut cfg = parse_config(args)?;
    // baseline rounds only need the round argmax: prune by default
    default_prune_on(&mut cfg);
    let cfg = cfg;
    let nm = *cfg.nodes_nm.first().context("need at least one node")?;
    let out_dir = Path::new(&cfg.out_dir);
    std::fs::create_dir_all(out_dir)?;
    if cfg.rl.prune {
        println!("roofline admission pruning: on (--no-prune for the exact path)");
    }

    let mut rng = Rng::new(cfg.seed);
    println!("random search @ {nm}nm ({} episodes)...", cfg.rl.episodes_per_node);
    let rand_r = baselines::random_search(&cfg, nm, &mut rng.fork(1));
    println!("grid search @ {nm}nm...");
    let grid_r = baselines::grid_search(&cfg, nm, &mut rng.fork(2));
    for (name, r) in [("random", &rand_r), ("grid", &grid_r)] {
        let es = &r.eval_stats;
        println!(
            "  {name}: pruned {} of {} candidates, placement-stage hit rate {:.1}%",
            es.pruned,
            es.pruned + es.evaluated,
            es.place_hit_rate() * 100.0
        );
    }

    println!("SAC @ {nm}nm...");
    // Table 21 parity: no MPC real-eval re-ranking, so every strategy
    // spends exactly one evaluation per budgeted episode
    let mut sac_cfg = cfg.clone();
    sac_cfg.rl.mpc_rerank = 0;
    let be = backend::load(&cfg.artifacts_dir, cfg.backend)?;
    println!("backend: {}", be.describe());
    println!("kernels: {}", kernels::describe(cfg.kernels));
    let mut agent = SacAgent::new(be, sac_cfg.rl, &mut rng)?;
    let sac_r = rl::run_node(&sac_cfg, nm, &mut agent, &mut rng)?;

    let t = report::search_comparison(&[
        ("Random Search", &rand_r),
        ("Grid Search", &grid_r),
        ("SAC (ours)", &sac_r),
    ]);
    println!("\n{}", t.to_text());
    t.write_csv(&out_dir.join("table21_search.csv"))?;
    Ok(())
}

/// Repeated-seed evaluation (§5.5 future work): random-search across N
/// derived seeds, reporting mean ± 95% CI per node. (SAC multi-seed runs
/// go through `optimize seed=...` per seed; this gives the fast
/// search-variance picture the paper calls for.)
fn run_multiseed(args: &[String]) -> Result<()> {
    let mut n_seeds = 5usize;
    let mut search = "random".to_string();
    let mut rest = Vec::new();
    for a in args {
        if let Some(v) = a.strip_prefix("n_seeds=") {
            n_seeds = v.parse().context("bad n_seeds")?;
        } else if let Some(v) = a.strip_prefix("search=") {
            search = v.to_string();
        } else {
            rest.push(a.clone());
        }
    }
    let mut cfg = parse_config(&rest)?;
    // the multiseed sweep aggregates per-seed argmaxes: prune by default
    default_prune_on(&mut cfg);
    let cfg = cfg;
    if cfg.rl.prune {
        println!("roofline admission pruning: on (--no-prune for the exact path)");
    }
    let threads = cfg.eval_threads();
    let results = match search.as_str() {
        "random" => {
            if cfg.resume.is_some() || cfg.rl.checkpoint_every > 0 {
                println!(
                    "note: checkpoint/resume applies to the SAC paths only; \
                     search=random re-runs from scratch (it is cheap and \
                     stateless)"
                );
            }
            // seeds fan out across workers; each seed's search runs
            // serially so the machine is not oversubscribed
            let mut rows = Vec::new();
            for &nm in &cfg.nodes_nm {
                rows.push(rl::run_seeds_t(&cfg, nm, n_seeds, threads, |c, nm, rng| {
                    baselines::random_search_t(c, nm, rng, 1)
                }));
            }
            rows
        }
        "sac" => {
            // every (node, seed) point is one lane of a single vec-env:
            // one shared agent, batched actor forwards, waves of `lanes`
            let jobs = cfg.nodes_nm.len() * n_seeds;
            let lanes = cfg.resolve_lanes(jobs);
            let be = backend::load(&cfg.artifacts_dir, cfg.backend)?;
            println!("backend: {}", be.describe());
            println!("kernels: {}", kernels::describe(cfg.kernels));
            println!(
                "vec-env: {jobs} (node, seed) lanes in waves of {lanes} \
                 (learner={})",
                cfg.rl.learner.name()
            );
            println!(
                "note: lanes share one agent (live learning), so per-seed results \
                 are correlated — CI columns are not independent-run variance"
            );
            let mut rng = Rng::new(cfg.seed);
            let mut agent = SacAgent::new(be, cfg.rl, &mut rng)?;
            let (rows, learner) = rl::multiseed::run_seeds_vec(
                &cfg,
                n_seeds,
                &mut agent,
                lanes,
                cfg.rollout_threads(),
            )?;
            if let Some(rep) = &learner {
                println!("{}", rep.banner());
            }
            rows
        }
        other => bail!("bad search {other} (random|sac)"),
    };
    let t = rl::seeds_table(&results);
    println!("{}", t.to_text());
    std::fs::create_dir_all(&cfg.out_dir)?;
    t.write_csv(&Path::new(&cfg.out_dir).join("multiseed.csv"))?;
    Ok(())
}

/// Dominance-pruned, cache-warm sweep over the full scenario grid
/// (DESIGN.md §12): workloads × nodes × phase × seq_len × batch run as
/// waves of vec-env lanes with three stacked reuse layers — cross-point
/// roofline dominance pruning, warm shared state (one outcome memo +
/// geometry registry + agents handed along the curriculum), and
/// dominance-ordered wave scheduling. Emits the merged Pareto atlas
/// (atlas.json + atlas.csv + per-workload tables) with prune/cache/reuse
/// counters; `atlas_prune=off` is the exact fallback.
fn run_atlas(args: &[String]) -> Result<()> {
    let mut cfg = parse_config(args)?;
    default_prune_on(&mut cfg);
    let cfg = cfg;
    let out_dir = Path::new(&cfg.out_dir);
    std::fs::create_dir_all(out_dir)?;
    let workloads = cfg.atlas_grid_workloads();
    println!(
        "atlas sweep: {} workloads x {} nodes x {} phases x {} seq_lens x {} batches \
         (prune={}, warm={}, shrink={}, seeds={})",
        workloads.len(),
        cfg.nodes_nm.len(),
        cfg.atlas.phases.len(),
        cfg.atlas.seq_lens.len(),
        cfg.atlas.batches.len(),
        if cfg.atlas.prune { "on" } else { "off" },
        if cfg.atlas.warm { "on" } else { "off" },
        cfg.atlas.shrink,
        cfg.atlas.n_seeds,
    );
    println!("kernels: {}", kernels::describe(cfg.kernels));

    let res = rl::atlas::run(&cfg)?;

    println!("\n{}", rl::atlas::atlas_table(&res).to_text());
    for (_w, t) in rl::atlas::workload_tables(&res) {
        println!("{}", t.to_text());
    }
    println!("{}", rl::atlas::summary_table(&res).to_text());

    // Table 14 over every solved lane, carrying the shared-cache
    // cross-scenario occupancy block
    let t14 = report::run_stats_with_cache(
        &res.node_results,
        cfg.mode.name,
        &cfg.scenario(),
        &kernels::describe(cfg.kernels),
        None,
        res.occupancy.as_ref(),
    );
    println!("{}", t14.to_text());
    t14.write_csv(&out_dir.join("table14_run_stats.csv"))?;

    rl::atlas::atlas_table(&res).write_csv(&out_dir.join("atlas.csv"))?;
    // atomic: a crash mid-write must never leave a torn atlas.json
    silicon_rl::util::fsio::atomic_write_str(
        out_dir.join("atlas.json"),
        &rl::atlas::atlas_json(&res, &cfg).to_string_pretty(),
    )?;

    let c = &res.counters;
    println!(
        "atlas: {} points, solved: {}, pruned: {} (skipped: {}, shrunk: {}), \
         episodes {} of {} budget, {:.1}s",
        c.points,
        c.solved,
        c.pruned(),
        c.skipped,
        c.shrunk,
        c.episodes_run,
        c.episodes_budget,
        res.elapsed_s
    );
    println!("atlas written to {}", out_dir.display());
    Ok(())
}

/// Randomized differential equivalence harness (`rl::fuzz`,
/// DESIGN.md §14): generate `iters` valid configs with the seeded
/// generator, run each case's equivalence-class oracle as paired
/// executions, and on the first contract violation delta-debug the case
/// to a minimal reproducer — printed as a ready-to-paste command line
/// and saved as a `key = value` repro file under `out_dir`.
fn run_fuzz(args: &[String]) -> Result<()> {
    use silicon_rl::rl::fuzz::{self, FuzzCase};

    let mut iters = 25usize;
    let mut seed = 42u64;
    let mut classes: Vec<String> =
        fuzz::class_names().iter().map(|s| s.to_string()).collect();
    let mut shrink = true;
    let mut budget = 64usize;
    let mut out_dir = "out/fuzz".to_string();
    let mut repro: Option<String> = None;
    let mut oracle: Option<String> = None;
    let mut extra: Vec<(String, String)> = Vec::new();
    for a in args {
        let (k, v) = a
            .split_once('=')
            .with_context(|| format!("expected key=value, got {a}"))?;
        match k {
            "iters" => {
                iters = v.parse().map_err(|_| Error::msg(format!("bad iters {v}")))?
            }
            "seed" => {
                seed = v.parse().map_err(|_| Error::msg(format!("bad seed {v}")))?
            }
            "classes" => {
                classes = v
                    .split(',')
                    .map(|s| s.trim().to_string())
                    .filter(|s| !s.is_empty())
                    .collect()
            }
            "shrink" => {
                shrink = match v {
                    "on" | "true" => true,
                    "off" | "false" => false,
                    _ => bail!("bad shrink {v} (on|off)"),
                }
            }
            "budget" => {
                budget = v.parse().map_err(|_| Error::msg(format!("bad budget {v}")))?
            }
            "out_dir" => out_dir = v.to_string(),
            "repro" => repro = Some(v.to_string()),
            "oracle" => oracle = Some(v.to_string()),
            _ => extra.push((k.to_string(), v.to_string())),
        }
    }
    // every bit-exact oracle pairs against the scalar reference kernels;
    // the simd-scalar oracle flips the process-global path itself and
    // restores scalar afterwards
    kernels::set_global(silicon_rl::nn::KernelSel::Scalar);

    // re-run a saved reproducer
    if let Some(path) = repro {
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading repro file {path}"))?;
        let case = FuzzCase::from_repro(&text)?;
        println!("repro case: {}", case.cmd_line());
        return match fuzz::run_case(&case)? {
            None => {
                println!("contract holds — the reproducer no longer fails");
                Ok(())
            }
            Some(m) => {
                println!("{m}");
                bail!("reproducer still violates the {} contract", m.oracle)
            }
        };
    }

    // one explicit case from the command line
    if let Some(name) = oracle {
        let case = FuzzCase::from_kv(&name, &extra)?;
        println!("case: {}", case.cmd_line());
        return match fuzz::run_case(&case)? {
            None => {
                println!("contract holds at this case");
                Ok(())
            }
            Some(m) => fuzz_failure(&case, m, shrink, budget, &out_dir, 0),
        };
    }
    if let Some((k, _)) = extra.first() {
        bail!("config key {k} needs oracle=NAME (or use repro=FILE)");
    }

    // the randomized sweep
    let class_refs: Vec<&str> = classes.iter().map(String::as_str).collect();
    let mut casegen = fuzz::CaseGen::new(seed, &class_refs)?;
    let mut counts: Vec<(&str, usize)> = class_refs.iter().map(|c| (*c, 0)).collect();
    println!(
        "fuzz: {iters} cases, seed {seed}, classes [{}]",
        class_refs.join(", ")
    );
    for i in 0..iters {
        let case = casegen.next_case();
        let verdict = fuzz::run_case(&case)
            .with_context(|| format!("case {i} errored: {}", case.cmd_line()))?;
        match verdict {
            None => {
                if let Some(c) = counts.iter_mut().find(|(n, _)| *n == case.oracle) {
                    c.1 += 1;
                }
            }
            Some(m) => {
                println!("case {i} FAILED: {}", case.cmd_line());
                return fuzz_failure(&case, m, shrink, budget, &out_dir, i);
            }
        }
    }
    for (name, n) in &counts {
        println!("  {name:>16}: {n} cases, contract held");
    }
    println!("fuzz: all {iters} cases clean");
    Ok(())
}

/// Report a contract violation: shrink the case (unless `shrink=off`),
/// save the minimal reproducer under `out_dir`, print the ready-to-paste
/// command line, and exit non-zero.
fn fuzz_failure(
    case: &silicon_rl::rl::fuzz::FuzzCase,
    mismatch: silicon_rl::rl::Mismatch,
    shrink: bool,
    budget: usize,
    out_dir: &str,
    iter: usize,
) -> Result<()> {
    use silicon_rl::rl::fuzz;
    use silicon_rl::util::fsio;

    println!("{mismatch}");
    let (minimal, final_mismatch) = if shrink {
        match fuzz::shrink(case, budget.max(2))? {
            Some(out) => {
                println!(
                    "shrunk after {} attempts ({} accepted): {}",
                    out.attempts, out.accepted, out.mismatch
                );
                (out.case, out.mismatch)
            }
            // the case passed on re-run (flaky environment); keep the
            // original as the reproducer rather than claiming a minimum
            None => {
                println!("warning: case passed on re-check; saving it unshrunk");
                (case.clone(), mismatch)
            }
        }
    } else {
        (case.clone(), mismatch)
    };
    std::fs::create_dir_all(out_dir)?;
    let path = format!("{out_dir}/repro-{}-{iter}.txt", minimal.oracle);
    fsio::atomic_write_str(&path, &minimal.to_repro())?;
    println!("minimal reproducer saved to {path}");
    println!("re-run with either of:");
    println!("  {}", minimal.cmd_line());
    println!("  silicon-rl fuzz repro={path}");
    bail!("equivalence violation in class {} ({})", minimal.oracle, final_mismatch.artifact)
}

/// Tables 8/9 from the spec-driven builder at the configured scenario
/// (no RL run needed).
fn workload_report(args: &[String]) -> Result<()> {
    let cfg = parse_config(args)?;
    let g = cfg.workload.build_scenario(&cfg.scenario());
    println!("{}", report::model_stats(&g, cfg.kv_strategy).to_text());
    let stats = silicon_rl::ir::stats::compute(&g);
    println!(
        "ilp={:.1} mem_intensity={:.2} vector_util={:.2} matmul_ratio={:.3} rho_comm={:.4}",
        stats.ilp, stats.mem_intensity, stats.vector_util, stats.matmul_ratio, stats.rho_comm
    );
    Ok(())
}

fn info(args: &[String]) -> Result<()> {
    let cfg = parse_config(args)?;
    let be = backend::load(&cfg.artifacts_dir, cfg.backend)?;
    println!("backend: {}", be.describe());
    println!("kernels: {}", kernels::describe(cfg.kernels));
    println!("hyper: {:?}", be.manifest().hyper);
    if be.manifest().entrypoints.is_empty() {
        println!("entrypoints: (native kernels; no lowered HLO needed)");
    }
    for (name, ep) in &be.manifest().entrypoints {
        println!(
            "  {name}: {} inputs, {} outputs ({})",
            ep.inputs.len(),
            ep.outputs.len(),
            ep.file
        );
    }
    println!();
    println!("{}", report::workload_registry(registry::all()).to_text());
    Ok(())
}
