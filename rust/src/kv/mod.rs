//! KV-cache management and compaction (§3.9): footprint (Eqs 25–26), DMEM
//! pressure (Eqs 27–28), quantized / sliding-window / paged compaction
//! (Eqs 29–32), and the throughput-model traffic relief (Eq 33).



use crate::ir::KvConfig;

/// KV compaction strategy selected by the compiler (LLM Config state
/// dims 70–72 carry the chosen strategy + compression).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KvStrategy {
    /// Full-precision contiguous cache.
    Full,
    /// Quantized cache (Eq 29): INT8 or INT4 with per-head scales.
    Quantized { bits: u8 },
    /// Sliding-window eviction (Eq 30) with mean window W̄.
    Window { tokens: u32 },
    /// Quantized + windowed (the κ of Eq 32 multiplies).
    QuantizedWindow { bits: u8, tokens: u32 },
    /// Paged allocation (Eq 31) — same footprint, less fragmentation.
    Paged { page_kb: u32 },
}

impl KvStrategy {
    /// Short config-style label (the `kv=` vocabulary) for reports.
    pub fn label(&self) -> String {
        match self {
            KvStrategy::Full => "full".to_string(),
            KvStrategy::Quantized { bits } => format!("int{bits}"),
            KvStrategy::Window { tokens } => format!("window:{tokens}"),
            KvStrategy::QuantizedWindow { bits, tokens } => {
                format!("int{bits}win:{tokens}")
            }
            KvStrategy::Paged { page_kb } => format!("paged:{page_kb}k"),
        }
    }
}

/// Eq 25: bytes per token = 2 · n_L · n_kv · d_h · elem_bytes.
pub fn bytes_per_token(kv: &KvConfig) -> f64 {
    2.0 * kv.n_layers as f64 * kv.n_kv_heads as f64 * kv.head_dim as f64
        * kv.elem_bytes as f64
}

/// Eq 32: compaction factor κ = (b_orig/b_quant) · (L/W̄).
pub fn compaction_factor(strategy: KvStrategy, seq_len: u32) -> f64 {
    match strategy {
        KvStrategy::Full | KvStrategy::Paged { .. } => 1.0,
        KvStrategy::Quantized { bits } => 16.0 / bits as f64,
        KvStrategy::Window { tokens } => {
            seq_len as f64 / (tokens.min(seq_len) as f64)
        }
        KvStrategy::QuantizedWindow { bits, tokens } => {
            (16.0 / bits as f64) * (seq_len as f64 / tokens.min(seq_len) as f64)
        }
    }
}

/// Eq 26 with compaction: total KV footprint at sequence length L.
pub fn total_bytes(kv: &KvConfig, seq_len: u32, strategy: KvStrategy) -> f64 {
    seq_len as f64 * bytes_per_token(kv) / compaction_factor(strategy, seq_len)
}

/// Eq 26 across `batch` concurrent sequences: each served sequence owns
/// an independent cache at length L, so the resident footprint scales
/// linearly with the scenario's batch axis.
pub fn total_bytes_batched(
    kv: &KvConfig,
    seq_len: u32,
    strategy: KvStrategy,
    batch: u32,
) -> f64 {
    batch.max(1) as f64 * total_bytes(kv, seq_len, strategy)
}

/// Eq 31: page count for paged allocation.
pub fn n_pages(kv: &KvConfig, seq_len: u32, page_kb: u32) -> u64 {
    let total = total_bytes(kv, seq_len, KvStrategy::Full);
    (total / (page_kb as f64 * 1024.0)).ceil() as u64
}

/// Eq 27 LHS: required DMEM-input bytes per KV-hosting tile.
pub fn dmem_in_required(
    kv: &KvConfig,
    seq_len: u32,
    strategy: KvStrategy,
    n_active_tiles: usize,
    act_input_bytes: f64,
) -> f64 {
    total_bytes(kv, seq_len, strategy) / n_active_tiles.max(1) as f64 + act_input_bytes
}

/// Eq 33: per-token memory traffic after compaction.
pub fn compacted_traffic(bytes_per_tok: f64, kv: &KvConfig, strategy: KvStrategy, seq_len: u32) -> f64 {
    let kappa = compaction_factor(strategy, seq_len);
    bytes_per_tok - (1.0 - 1.0 / kappa) * bytes_per_token(kv)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn llama_kv() -> KvConfig {
        KvConfig { n_layers: 32, n_kv_heads: 8, head_dim: 128, elem_bytes: 2 }
    }

    #[test]
    fn eq25_gives_128kb_per_token() {
        assert_eq!(bytes_per_token(&llama_kv()), 131072.0);
    }

    #[test]
    fn eq26_gives_256mb_at_2048() {
        let total = total_bytes(&llama_kv(), 2048, KvStrategy::Full);
        assert_eq!(total, 268_435_456.0); // 256 MiB
    }

    #[test]
    fn int8_halves_int4_quarters() {
        let kv = llama_kv();
        let full = total_bytes(&kv, 2048, KvStrategy::Full);
        assert_eq!(total_bytes(&kv, 2048, KvStrategy::Quantized { bits: 8 }), full / 2.0);
        assert_eq!(total_bytes(&kv, 2048, KvStrategy::Quantized { bits: 4 }), full / 4.0);
    }

    #[test]
    fn paper_example_kappa_4x() {
        // §3.9: INT8 + 1024-token window at L=2048 gives κ=4 (256→64 MB)
        let k = compaction_factor(
            KvStrategy::QuantizedWindow { bits: 8, tokens: 1024 },
            2048,
        );
        assert_eq!(k, 4.0);
        let total = total_bytes(&llama_kv(), 2048, KvStrategy::QuantizedWindow { bits: 8, tokens: 1024 });
        assert_eq!(total, 67_108_864.0); // 64 MiB
    }

    #[test]
    fn window_larger_than_seq_is_noop() {
        assert_eq!(compaction_factor(KvStrategy::Window { tokens: 4096 }, 2048), 1.0);
    }

    #[test]
    fn batched_footprint_scales_linearly() {
        let kv = llama_kv();
        let one = total_bytes(&kv, 2048, KvStrategy::Full);
        assert_eq!(total_bytes_batched(&kv, 2048, KvStrategy::Full, 3), 3.0 * one);
        // batch 0 is clamped to a single sequence
        assert_eq!(total_bytes_batched(&kv, 2048, KvStrategy::Full, 0), one);
    }

    #[test]
    fn paging_preserves_footprint() {
        let kv = llama_kv();
        assert_eq!(
            total_bytes(&kv, 2048, KvStrategy::Paged { page_kb: 64 }),
            total_bytes(&kv, 2048, KvStrategy::Full)
        );
        // 256 MiB / 64 KiB pages = 4096 pages
        assert_eq!(n_pages(&kv, 2048, 64), 4096);
    }

    #[test]
    fn eq33_traffic_relief() {
        let kv = llama_kv();
        let b_tok = 1e6;
        let relieved = compacted_traffic(b_tok, &kv, KvStrategy::Quantized { bits: 8 }, 2048);
        assert!((relieved - (b_tok - 0.5 * 131072.0)).abs() < 1e-6);
        // no compaction => unchanged
        assert_eq!(compacted_traffic(b_tok, &kv, KvStrategy::Full, 2048), b_tok);
    }

    #[test]
    fn dmem_requirement_splits_across_tiles() {
        let kv = llama_kv();
        let req = dmem_in_required(&kv, 2048, KvStrategy::Full, 1024, 8192.0);
        assert!((req - (268_435_456.0 / 1024.0 + 8192.0)).abs() < 1e-6);
    }
}
