//! Report pipeline (§5.4): every table and figure of the paper's
//! evaluation section regenerated from run data as CSV + aligned text.
//! "All reported tables and figures are generated from compilation
//! artifacts through an automated pipeline."
//!
//! Table/figure → function map (DESIGN.md §3):
//!   Table 8/9/14  → [`model_stats`], [`run_stats`]
//!   Table 10/11   → [`nodes_table`]  (+ Fig 4/5/6 CSV series)
//!   Table 12      → [`power_breakdown`]
//!   Table 13      → [`scaling_analysis`] (+ Fig 8/9)
//!   Table 15/16   → [`tile_regions`], [`tile_param_summary`] (+ Fig 10-12a)
//!   Table 17      → [`cross_node_compare`] (+ Fig 12b)
//!   Table 18      → [`efficiency_table`] (+ Fig 7)
//!   Table 19      → [`nodes_table`] on the SmolVLM run
//!   Table 20      → [`industry_comparison`]
//!   Table 21      → [`search_comparison`]
//!   Fig 3         → [`convergence_csv`]

use crate::arch::{region_of, MeshConfig, Region, TileConfig};
use crate::eval::{CacheOccupancy, EvalStats};
use crate::ir::spec::{Scenario, WorkloadSpec};
use crate::ir::Graph;
use crate::ppa::PowerBreakdown;
use crate::rl::{EpisodeLog, NodeResult};
use crate::util::csv::{fnum, Table};
use crate::util::stats;

/// Condensed per-node result (one Table 10/11 row).
#[derive(Debug, Clone)]
pub struct NodeSummary {
    pub nm: u32,
    pub mesh_w: u32,
    pub mesh_h: u32,
    pub freq_mhz: f64,
    pub power: PowerBreakdown,
    pub perf_gops: f64,
    pub area_mm2: f64,
    pub ppa_score: f64,
    pub tokens_per_s: f64,
}

impl NodeSummary {
    pub fn cores(&self) -> usize {
        (self.mesh_w * self.mesh_h) as usize
    }

    pub fn from_result(r: &NodeResult) -> Option<NodeSummary> {
        let b = r.best.as_ref()?;
        let o = &b.outcome;
        Some(NodeSummary {
            nm: r.nm,
            mesh_w: o.decoded.mesh.width,
            mesh_h: o.decoded.mesh.height,
            freq_mhz: o.decoded.avg.clock_mhz,
            power: o.ppa.power,
            perf_gops: o.ppa.perf_gops,
            area_mm2: o.ppa.area.total(),
            ppa_score: o.reward.score,
            tokens_per_s: o.ppa.tokens_per_s,
        })
    }
}

/// Table 8/9: workload characteristics for one run configuration
/// (`kv_strategy` is the run's configured compaction, so the footprint
/// row matches what the evaluator actually models).
pub fn model_stats(g: &Graph, kv_strategy: crate::kv::KvStrategy) -> Table {
    let mut t = Table::new(
        "Table 9 — model characteristics",
        &["characteristic", "value"],
    );
    t.row(vec!["model".into(), g.name.clone()]);
    t.row(vec!["operators".into(), g.ops.len().to_string()]);
    t.row(vec!["weight tensors".into(), g.weight_tensors.to_string()]);
    t.row(vec![
        "total weights (GiB)".into(),
        fnum(g.total_weight_bytes() / (1u64 << 30) as f64, 2),
    ]);
    t.row(vec!["parameters (B)".into(), fnum(g.params / 1e9, 2)]);
    t.row(vec![
        "total instructions (M)".into(),
        fnum(g.total_instrs() / 1e6, 0),
    ]);
    t.row(vec!["graph inputs".into(), g.n_inputs.to_string()]);
    t.row(vec!["graph outputs".into(), g.n_outputs.to_string()]);
    if let Some(kv) = g.kv {
        t.row(vec![
            "KV bytes/token (KB)".into(),
            fnum(crate::kv::bytes_per_token(&kv) / 1024.0, 0),
        ]);
    }
    // scenario axis the graph was built for (phase / context / batch)
    let scn = &g.scenario;
    t.row(vec!["phase".into(), scn.phase.name().into()]);
    t.row(vec!["context length".into(), scn.seq_len.to_string()]);
    t.row(vec!["batch size".into(), scn.batch.to_string()]);
    if let Some(kv) = g.kv {
        let total =
            crate::kv::total_bytes_batched(&kv, scn.seq_len, kv_strategy, scn.batch);
        t.row(vec!["KV strategy".into(), kv_strategy.label()]);
        t.row(vec![
            "KV footprint @ scenario (MiB)".into(),
            fnum(total / (1u64 << 20) as f64, 0),
        ]);
    }
    t
}

/// Registry listing for `help`/`info`: every registered workload with
/// its closed-form Table-8 statistics (no graph build needed).
pub fn workload_registry(specs: &[WorkloadSpec]) -> Table {
    let mut t = Table::new(
        "Registered workloads (Table 8 statistics)",
        &[
            "name", "family", "layers", "d_model", "heads", "d_ffn", "params_B",
            "ops", "tensors", "seq", "batch", "aliases",
        ],
    );
    for s in specs {
        t.row(vec![
            s.name.to_string(),
            s.family.name().to_string(),
            s.dims.n_layers.to_string(),
            s.dims.d_model.to_string(),
            format!("{}/{}", s.dims.n_heads, s.dims.n_kv_heads),
            s.dims.d_ffn.to_string(),
            fnum(s.expected_params() / 1e9, 2),
            s.expected_ops().to_string(),
            s.expected_weight_tensors().to_string(),
            s.default_seq_len.to_string(),
            s.default_batch.to_string(),
            s.aliases.join(","),
        ]);
    }
    t
}

/// Table 10/11 (and Table 19 for the low-power run): per-node results.
/// Also the data series behind Figs 4, 5, 6.
pub fn nodes_table(rows: &[NodeSummary]) -> Table {
    let mut t = Table::new(
        "Table 10/11 — per-node RL results",
        &[
            "node", "mesh", "cores", "scaling", "freq_mhz", "power_mw",
            "perf_gops", "area_mm2", "ppa", "tok_s",
        ],
    );
    let base = rows.first().map(|r| r.cores()).unwrap_or(1) as f64;
    for r in rows {
        t.row(vec![
            format!("{}nm", r.nm),
            format!("{}x{}", r.mesh_w, r.mesh_h),
            r.cores().to_string(),
            format!("{:.2}x", r.cores() as f64 / base),
            fnum(r.freq_mhz, 0),
            fnum(r.power.total(), 0),
            fnum(r.perf_gops, 0),
            fnum(r.area_mm2, 0),
            fnum(r.ppa_score, 3),
            fnum(r.tokens_per_s, 0),
        ]);
    }
    t
}

/// Table 12: dynamic power decomposition per node.
pub fn power_breakdown(rows: &[NodeSummary]) -> Table {
    let mut t = Table::new(
        "Table 12 — power breakdown (mW)",
        &[
            "node", "mesh", "compute", "sram", "rom_rd", "noc", "leak", "total",
            "comp%", "sram%", "rom%", "noc%", "leak%",
        ],
    );
    for r in rows {
        let p = &r.power;
        let sh = p.shares();
        t.row(vec![
            format!("{}nm", r.nm),
            format!("{}x{}", r.mesh_w, r.mesh_h),
            fnum(p.compute, 0),
            fnum(p.sram, 0),
            fnum(p.rom_read, 0),
            fnum(p.noc, 0),
            fnum(p.leakage, 0),
            fnum(p.total(), 0),
            fnum(sh[0] * 100.0, 1),
            fnum(sh[1] * 100.0, 1),
            fnum(sh[2] * 100.0, 1),
            fnum(sh[3] * 100.0, 1),
            fnum(sh[4] * 100.0, 1),
        ]);
    }
    t
}

/// Table 13 + Figs 8/9: log-log power-law fits (Eq 73/74) and node-level
/// Pearson correlations.
pub fn scaling_analysis(rows: &[NodeSummary]) -> Table {
    let nm: Vec<f64> = rows.iter().map(|r| r.nm as f64).collect();
    let perf: Vec<f64> = rows.iter().map(|r| r.perf_gops).collect();
    let power: Vec<f64> = rows.iter().map(|r| r.power.total()).collect();
    let area: Vec<f64> = rows.iter().map(|r| r.area_mm2).collect();
    let ppa: Vec<f64> = rows.iter().map(|r| r.ppa_score).collect();

    let mut t = Table::new(
        "Table 13 — scaling fits + correlations",
        &["analysis", "metric", "slope_or_corr", "const", "r2_or_note"],
    );
    for (name, ys) in [("Performance (GOps/s)", &perf), ("Power (mW)", &power), ("Area (mm2)", &area)] {
        let (k, c, r2) = stats::loglog_fit(&nm, ys);
        t.row(vec![
            "log-log fit".into(),
            name.into(),
            fnum(k, 4),
            fnum(c, 1),
            fnum(r2, 4),
        ]);
    }
    for (name, a, b) in [
        ("Perf vs Power", &perf, &power),
        ("Perf vs Area", &perf, &area),
        ("Perf vs PPA", &perf, &ppa),
        ("Power vs PPA", &power, &ppa),
        ("Area vs PPA", &area, &ppa),
    ] {
        t.row(vec![
            "pearson corr".into(),
            name.into(),
            fnum(stats::pearson(a, b), 4),
            "-".into(),
            "node-level".into(),
        ]);
    }
    t
}

/// Table 15: region-level per-tile configuration summary (Fig 10/11).
pub fn tile_regions(mesh: &MeshConfig, tiles: &[TileConfig]) -> Table {
    let mut t = Table::new(
        "Table 15 — region-level tile configuration",
        &["region", "tiles", "avg_wmem_mb", "avg_dmem_kb", "avg_fetch", "avg_vlen"],
    );
    for want in [Region::Edge, Region::Inner, Region::Center] {
        let sel: Vec<&TileConfig> = tiles
            .iter()
            .filter(|tc| region_of(mesh, tc.tile) == want)
            .collect();
        if sel.is_empty() {
            continue;
        }
        let n = sel.len() as f64;
        let avg = |f: &dyn Fn(&TileConfig) -> f64| sel.iter().map(|tc| f(tc)).sum::<f64>() / n;
        t.row(vec![
            format!("{:?}", want),
            sel.len().to_string(),
            fnum(avg(&|tc| tc.wmem_kb as f64 / 1024.0), 2),
            fnum(avg(&|tc| tc.dmem_kb as f64), 1),
            fnum(avg(&|tc| tc.fetch as f64), 2),
            fnum(avg(&|tc| tc.vlen_bits as f64), 0),
        ]);
    }
    t
}

/// Table 16 + Fig 12a: per-TCC parameter summary statistics (and the
/// WMEM distribution percentiles / Gini of Fig 11c).
pub fn tile_param_summary(tiles: &[TileConfig]) -> Table {
    let mut t = Table::new(
        "Table 16 — per-TCC parameter statistics",
        &["parameter", "min", "max", "mean", "median", "std", "unique"],
    );
    let cols: [(&str, Box<dyn Fn(&TileConfig) -> f64>); 5] = [
        ("FETCH_SIZE", Box::new(|tc| tc.fetch as f64)),
        ("VLEN (bits)", Box::new(|tc| tc.vlen_bits as f64)),
        ("WMEM (KB)", Box::new(|tc| tc.wmem_kb as f64)),
        ("DMEM (KB)", Box::new(|tc| tc.dmem_kb as f64)),
        ("IMEM (KB)", Box::new(|tc| tc.imem_kb as f64)),
    ];
    for (name, f) in &cols {
        let xs: Vec<f64> = tiles.iter().map(|tc| f(tc)).collect();
        let s = stats::summary(&xs);
        t.row(vec![
            name.to_string(),
            fnum(s.min, 0),
            fnum(s.max, 0),
            fnum(s.mean, 1),
            fnum(s.median, 0),
            fnum(s.std_dev, 1),
            s.unique.to_string(),
        ]);
    }
    // Fig 11c/12a extras
    let wmem: Vec<f64> = tiles.iter().map(|tc| tc.wmem_kb as f64).collect();
    t.row(vec![
        "WMEM P50/P90 (KB)".into(),
        fnum(stats::percentile(&wmem, 50.0), 0),
        fnum(stats::percentile(&wmem, 90.0), 0),
        "-".into(),
        "-".into(),
        format!("gini={:.3}", stats::gini(&wmem)),
        "-".into(),
    ]);
    t
}

/// Table 17 / Fig 12b: best-node vs worst-node comparison.
pub fn cross_node_compare(best: &NodeSummary, worst: &NodeSummary) -> Table {
    let mut t = Table::new(
        "Table 17 — cross-node comparison",
        &["node", "power_mw", "perf_gops", "area_mm2", "ppa"],
    );
    for r in [worst, best] {
        t.row(vec![
            format!("{}nm", r.nm),
            fnum(r.power.total(), 0),
            fnum(r.perf_gops, 0),
            fnum(r.area_mm2, 0),
            fnum(r.ppa_score, 3),
        ]);
    }
    t.row(vec![
        format!("{}nm vs {}nm", best.nm, worst.nm),
        format!("{:.2}x", best.power.total() / worst.power.total()),
        format!("{:.2}x", best.perf_gops / worst.perf_gops),
        format!("{:.2}x", best.area_mm2 / worst.area_mm2),
        format!("{:.2}x", best.ppa_score / worst.ppa_score),
    ]);
    t
}

/// Table 18 / Fig 7: derived node-efficiency ratios (Eqs 75–77).
pub fn efficiency_table(rows: &[NodeSummary]) -> Table {
    use crate::ppa::efficiency::*;
    let mut t = Table::new(
        "Table 18 — node efficiency",
        &["node", "gops_per_mw", "tok_s_per_mw", "gops_per_mm2", "ppa"],
    );
    for r in rows {
        t.row(vec![
            format!("{}nm", r.nm),
            fnum(perf_per_power(r.perf_gops, r.power.total()), 3),
            fnum(tok_per_power(r.tokens_per_s, r.power.total()), 4),
            fnum(perf_per_area(r.perf_gops, r.area_mm2), 1),
            fnum(r.ppa_score, 3),
        ]);
    }
    t
}

/// Table 20: industry comparison — published platform numbers (static,
/// from the paper) + our compiler-estimated row.
pub fn industry_comparison(ours: Option<&NodeSummary>) -> Table {
    let mut t = Table::new(
        "Table 20 — industry comparison (Llama 3.1 8B, per-user)",
        &["platform", "tok_s", "power_w", "tok_s_per_w", "notes"],
    );
    let published: [(&str, f64, f64, &str); 6] = [
        ("H200", 230.0, 700.0, "4nm GPU"),
        ("B200", 353.0, 1000.0, "4nm GPU"),
        ("Groq", 594.0, 300.0, "14nm ASIC (sys power est.)"),
        ("SambaNova", 932.0, 300.0, "Dataflow (sys power est.)"),
        ("Cerebras", 1981.0, 15000.0, "7nm wafer (sys power est.)"),
        ("Taalas HC1", 16960.0, 250.0, "6nm, 815mm2 (server power)"),
    ];
    for (name, toks, pw, note) in published {
        t.row(vec![
            name.into(),
            fnum(toks, 0),
            fnum(pw, 0),
            fnum(toks / pw, 1),
            note.into(),
        ]);
    }
    if let Some(r) = ours {
        let pw_w = r.power.total() / 1000.0;
        t.row(vec![
            "Ours".into(),
            fnum(r.tokens_per_s, 0),
            fnum(pw_w, 0),
            fnum(r.tokens_per_s / pw_w, 0),
            format!("{}nm est. (analytical, not silicon)", r.nm),
        ]);
    }
    t
}

/// Table 21: search-strategy comparison at one node.
pub fn search_comparison(rows: &[(&str, &NodeResult)]) -> Table {
    let mut t = Table::new(
        "Table 21 — search strategy comparison",
        &["method", "ppa_score", "tok_s", "power_w", "feasible", "episodes"],
    );
    for (name, r) in rows {
        let (score, toks, pw) = match &r.best {
            Some(b) => (
                b.outcome.reward.score,
                b.outcome.ppa.tokens_per_s,
                b.outcome.ppa.power.total() / 1000.0,
            ),
            None => (f64::NAN, 0.0, 0.0),
        };
        t.row(vec![
            name.to_string(),
            fnum(score, 3),
            fnum(toks, 0),
            fnum(pw, 1),
            format!("{} / {}", r.feasible_count, r.total_episodes),
            r.total_episodes.to_string(),
        ]);
    }
    t
}

/// Fig 3: convergence trace as CSV series (best PPA, reward, ε, entropy,
/// unique configurations per episode).
pub fn convergence_csv(eps: &[EpisodeLog]) -> Table {
    let mut t = Table::new(
        "Fig 3 — RL convergence trace",
        &[
            "episode", "reward", "score", "best_score", "feasible", "tok_s",
            "mesh", "eps", "entropy", "unique_configs",
        ],
    );
    for e in eps {
        t.row(vec![
            e.episode.to_string(),
            fnum(e.reward, 4),
            fnum(e.score, 4),
            fnum(e.best_score, 4),
            (e.feasible as u8).to_string(),
            fnum(e.tokens_per_s, 0),
            format!("{}x{}", e.mesh_w, e.mesh_h),
            fnum(e.eps, 4),
            fnum(e.entropy, 3),
            e.unique_configs.to_string(),
        ]);
    }
    t
}

/// Table 14-style run statistics for one (mode, scenario) run. `kernels`
/// is the kernel-path attribution string (requested mode + detected
/// capability + resolved path — `nn::kernels::describe`), recorded so
/// bench/report artifacts are attributable to the compute path that
/// produced them. `learner` carries the actor-learner engine's counters
/// when the run used `learner=pinned|async` (`None` = inline updates).
pub fn run_stats(
    results: &[NodeResult],
    mode: &str,
    scn: &Scenario,
    kernels: &str,
    learner: Option<&crate::rl::LearnerReport>,
) -> Table {
    run_stats_with_cache(results, mode, scn, kernels, learner, None)
}

/// [`run_stats`] plus the shared-cache occupancy block: when an atlas
/// sweep (or any run sharing one `SharedEvalCache` across scenario
/// points) hands in its [`CacheOccupancy`], Table 14 also reports the
/// cross-scenario residency — total entries, resident scenario salts,
/// entries per salt, and the shared hit rate — alongside the per-lane
/// counters.
pub fn run_stats_with_cache(
    results: &[NodeResult],
    mode: &str,
    scn: &Scenario,
    kernels: &str,
    learner: Option<&crate::rl::LearnerReport>,
    occupancy: Option<&CacheOccupancy>,
) -> Table {
    let mut t = Table::new("Table 14 — run statistics", &["metric", "value"]);
    let best = results
        .iter()
        .filter_map(|r| NodeSummary::from_result(r).map(|s| (r.nm, s)))
        .min_by(|a, b| a.1.ppa_score.total_cmp(&b.1.ppa_score));
    t.row(vec!["evaluated nodes".into(), results.len().to_string()]);
    t.row(vec!["phase".into(), scn.phase.name().into()]);
    t.row(vec!["context length (seq_len)".into(), scn.seq_len.to_string()]);
    t.row(vec!["batch size".into(), scn.batch.to_string()]);
    if let Some((nm, s)) = best {
        t.row(vec!["best node".into(), format!("{nm}nm")]);
        t.row(vec!["best mesh".into(), format!("{}x{}", s.mesh_w, s.mesh_h)]);
        t.row(vec!["best PPA score".into(), fnum(s.ppa_score, 3)]);
        t.row(vec!["best throughput (tok/s)".into(), fnum(s.tokens_per_s, 0)]);
    }
    t.row(vec!["optimization mode".into(), mode.into()]);
    t.row(vec!["kernel path".into(), kernels.into()]);
    t.row(vec![
        "episodes per node".into(),
        results
            .first()
            .map(|r| r.total_episodes.to_string())
            .unwrap_or_default(),
    ]);

    // evaluation-layer counters (memo caches + roofline admission
    // pruning), summed across nodes
    let mut es = EvalStats::default();
    for r in results {
        es.merge(&r.eval_stats);
    }
    t.row(vec![
        "eval cache hits/misses/evicted".into(),
        format!("{}/{}/{}", es.outcome_hits, es.outcome_misses, es.outcome_evictions),
    ]);
    t.row(vec![
        "placement stage hits/misses/evicted".into(),
        format!("{}/{}/{}", es.place_hits, es.place_misses, es.place_evictions),
    ]);
    t.row(vec![
        "placement stage hit rate".into(),
        format!("{:.1}%", es.place_hit_rate() * 100.0),
    ]);
    t.row(vec![
        "candidates pruned (roofline)".into(),
        format!("{} of {}", es.pruned, es.pruned + es.evaluated),
    ]);
    if es.geom_shared > 0 {
        t.row(vec![
            "geometry tables shared (registry)".into(),
            es.geom_shared.to_string(),
        ]);
    }

    // shared-cache cross-scenario occupancy (DESIGN.md §12)
    if let Some(occ) = occupancy {
        t.row(vec!["shared cache entries".into(), occ.entries.to_string()]);
        t.row(vec![
            "shared cache scenario salts".into(),
            occ.salts.len().to_string(),
        ]);
        let per = if occ.salts.is_empty() {
            0.0
        } else {
            occ.entries as f64 / occ.salts.len() as f64
        };
        t.row(vec!["shared cache entries/salt".into(), fnum(per, 1)]);
        t.row(vec![
            "shared cache hit rate".into(),
            format!("{:.1}%", occ.hit_rate() * 100.0),
        ]);
    }

    // actor-learner engine counters (DESIGN.md §11)
    if let Some(lr) = learner {
        t.row(vec!["learner mode".into(), lr.mode.name().into()]);
        t.row(vec![
            "learner updates (sac/wm/sur)".into(),
            format!("{}/{}/{}", lr.sac_updates, lr.wm_updates, lr.sur_updates),
        ]);
        t.row(vec!["learner steps absorbed".into(), lr.steps.to_string()]);
        t.row(vec!["snapshots published".into(), lr.snapshots.to_string()]);
        t.row(vec![
            "queue high-water (transitions)".into(),
            lr.queue_highwater.to_string(),
        ]);
        t.row(vec![
            "mean lanes-behind-latest (versions)".into(),
            fnum(lr.mean_lanes_behind, 2),
        ]);
        // graceful degradation (DESIGN.md §13): a learner-thread panic
        // falls the run back to inline updates; surface it in the table
        if let Some((at, err)) = &lr.degraded {
            t.row(vec![
                "learner DEGRADED to inline at step".into(),
                format!("{at} ({err})"),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ppa::PowerBreakdown;

    fn summary(nm: u32, cores_side: u32, perf: f64, power: f64, area: f64, score: f64) -> NodeSummary {
        NodeSummary {
            nm,
            mesh_w: cores_side,
            mesh_h: cores_side,
            freq_mhz: 1000.0,
            power: PowerBreakdown {
                compute: power * 0.55,
                sram: power * 0.03,
                rom_read: power * 0.05,
                noc: power * 0.32,
                leakage: power * 0.05,
            },
            perf_gops: perf,
            area_mm2: area,
            ppa_score: score,
            tokens_per_s: perf / 15.58,
        }
    }

    fn rows() -> Vec<NodeSummary> {
        vec![
            summary(3, 41, 466364.0, 51366.0, 648.0, 0.974),
            summary(7, 33, 173899.0, 46208.0, 1220.0, 0.996),
            summary(28, 12, 9744.0, 3780.0, 3545.0, 1.019),
        ]
    }

    #[test]
    fn nodes_table_has_scaling_column() {
        let t = nodes_table(&rows());
        assert_eq!(t.rows.len(), 3);
        assert_eq!(t.rows[0][3], "1.00x");
        assert!(t.to_csv().contains("3nm"));
    }

    #[test]
    fn power_breakdown_percentages_sum_100() {
        let t = power_breakdown(&rows());
        for r in &t.rows {
            let total: f64 = r[8..13].iter().map(|v| v.parse::<f64>().unwrap()).sum();
            assert!((total - 100.0).abs() < 0.5, "{total}");
        }
    }

    #[test]
    fn scaling_analysis_recovers_negative_perf_exponent() {
        let t = scaling_analysis(&rows());
        // performance falls with node size: negative exponent (Table 13)
        let perf_row = &t.rows[0];
        let k: f64 = perf_row[2].parse().unwrap();
        assert!(k < -1.0, "k {k}");
        // pearson perf-vs-power strongly positive
        let corr_row = t.rows.iter().find(|r| r[1] == "Perf vs Power").unwrap();
        let c: f64 = corr_row[2].parse().unwrap();
        assert!(c > 0.8, "corr {c}");
    }

    #[test]
    fn cross_node_ratios_match_paper_shape() {
        let rs = rows();
        let t = cross_node_compare(&rs[0], &rs[2]);
        let ratio_row = t.rows.last().unwrap();
        // ~47.9x perf, ~0.18x area (Table 17)
        assert!(ratio_row[2].starts_with("47."));
        assert!(ratio_row[3].starts_with("0.18"));
    }

    #[test]
    fn industry_table_includes_ours() {
        let rs = rows();
        let t = industry_comparison(Some(&rs[0]));
        assert_eq!(t.rows.len(), 7);
        assert!(t.to_text().contains("Taalas"));
        assert!(t.to_text().contains("analytical"));
    }

    #[test]
    fn model_stats_matches_llama() {
        let g = crate::ir::llama::build();
        let t = model_stats(&g, crate::kv::KvStrategy::Full);
        let txt = t.to_text();
        assert!(txt.contains("7489"));
        assert!(txt.contains("291"));
        assert!(txt.contains("14.96"));
        // scenario rows surface the active phase/context/batch (Table 9)
        assert!(txt.contains("decode"));
        assert!(txt.contains("2048"));
        let batch_row = t.rows.iter().find(|r| r[0] == "batch size").unwrap();
        assert_eq!(batch_row[1], "3");
        // footprint row reflects the configured compaction, not Full
        let row = |t: &Table| {
            t.rows
                .iter()
                .find(|r| r[0] == "KV footprint @ scenario (MiB)")
                .unwrap()[1]
                .parse::<f64>()
                .unwrap()
        };
        let full = row(&t);
        let int4 = row(&model_stats(&g, crate::kv::KvStrategy::Quantized { bits: 4 }));
        assert!((full / int4 - 4.0).abs() < 0.1, "full {full} vs int4 {int4}");
    }

    #[test]
    fn run_stats_surfaces_scenario() {
        let scn = Scenario { phase: crate::ir::Phase::Prefill, seq_len: 8192, batch: 2 };
        let t = run_stats(&[], "test", &scn, "scalar (detected none, resolved scalar)", None);
        let txt = t.to_text();
        assert!(txt.contains("prefill"));
        assert!(txt.contains("8192"));
        let batch_row = t.rows.iter().find(|r| r[0] == "batch size").unwrap();
        assert_eq!(batch_row[1], "2");
        let kern_row = t.rows.iter().find(|r| r[0] == "kernel path").unwrap();
        assert!(kern_row[1].contains("resolved scalar"), "{}", kern_row[1]);
        // inline runs carry no learner rows
        assert!(!txt.contains("learner mode"));
    }

    #[test]
    fn run_stats_surfaces_learner_counters() {
        let scn = Scenario { phase: crate::ir::Phase::Decode, seq_len: 2048, batch: 1 };
        let lr = crate::rl::LearnerReport {
            mode: crate::rl::LearnerMode::Async,
            steps: 120,
            sac_updates: 96,
            wm_updates: 48,
            sur_updates: 24,
            snapshots: 96,
            queue_highwater: 32,
            mean_lanes_behind: 1.5,
            degraded: None,
        };
        let t = run_stats(&[], "test", &scn, "scalar", Some(&lr));
        let find = |k: &str| {
            t.rows
                .iter()
                .find(|r| r[0] == k)
                .unwrap_or_else(|| panic!("missing row {k}"))[1]
                .clone()
        };
        assert_eq!(find("learner mode"), "async");
        assert_eq!(find("learner updates (sac/wm/sur)"), "96/48/24");
        assert_eq!(find("learner steps absorbed"), "120");
        assert_eq!(find("snapshots published"), "96");
        assert_eq!(find("queue high-water (transitions)"), "32");
        assert_eq!(find("mean lanes-behind-latest (versions)"), "1.50");
        assert!(lr.banner().contains("96 sac / 48 wm / 24 sur"));
        // no degradation: no DEGRADED row, banner stays clean
        assert!(!t.to_text().contains("DEGRADED"));
        assert!(!lr.banner().contains("DEGRADED"));
    }

    #[test]
    fn run_stats_surfaces_learner_degradation() {
        let scn = Scenario { phase: crate::ir::Phase::Decode, seq_len: 2048, batch: 1 };
        let lr = crate::rl::LearnerReport {
            mode: crate::rl::LearnerMode::Async,
            steps: 120,
            sac_updates: 96,
            wm_updates: 48,
            sur_updates: 24,
            snapshots: 96,
            queue_highwater: 32,
            mean_lanes_behind: 1.5,
            degraded: Some((17, "learner thread panicked".into())),
        };
        let t = run_stats(&[], "test", &scn, "scalar", Some(&lr));
        let row = t
            .rows
            .iter()
            .find(|r| r[0] == "learner DEGRADED to inline at step")
            .expect("missing degraded row");
        assert!(row[1].contains("17"), "{}", row[1]);
        assert!(row[1].contains("learner thread panicked"), "{}", row[1]);
        assert!(lr.banner().contains("DEGRADED"), "{}", lr.banner());
    }

    #[test]
    fn run_stats_surfaces_shared_cache_occupancy() {
        let scn = Scenario { phase: crate::ir::Phase::Decode, seq_len: 2048, batch: 1 };
        let occ = CacheOccupancy {
            entries: 12,
            salts: vec![(0xA, 4), (0xB, 8)],
            hits: 6,
            misses: 18,
        };
        let t = run_stats_with_cache(&[], "test", &scn, "scalar", None, Some(&occ));
        let find = |k: &str| {
            t.rows
                .iter()
                .find(|r| r[0] == k)
                .unwrap_or_else(|| panic!("missing row {k}"))[1]
                .clone()
        };
        assert_eq!(find("shared cache entries"), "12");
        assert_eq!(find("shared cache scenario salts"), "2");
        assert_eq!(find("shared cache entries/salt"), "6.0");
        assert_eq!(find("shared cache hit rate"), "25.0%");
        // plain run_stats stays occupancy-free (bit-compatible Table 14)
        let base = run_stats(&[], "test", &scn, "scalar", None);
        assert!(!base.to_text().contains("shared cache"));
    }

    #[test]
    fn workload_registry_lists_every_spec_with_pins() {
        let t = workload_registry(crate::ir::registry::all());
        assert!(t.rows.len() >= 5);
        let llama = t.rows.iter().find(|r| r[0] == "llama-3.1-8b").unwrap();
        assert_eq!(llama[7], "7489");
        assert_eq!(llama[8], "291");
        assert!(t.to_text().contains("vision-language"));
    }

    #[test]
    fn efficiency_matches_table18_3nm() {
        let t = efficiency_table(&rows());
        let r0 = &t.rows[0];
        let gops_mw: f64 = r0[1].parse().unwrap();
        assert!((gops_mw - 9.078).abs() < 0.01);
    }
}
