//! Run configuration: workload selection (registry-backed), the scenario
//! axis (phase / context length / batch), process nodes, PPA weights and
//! per-node constraint budgets, RL hyperparameters (Table 6 defaults),
//! and execution knobs (placement granularity, episode budget, seed).
//!
//! Configs load from a simple `key = value` text format (the image has no
//! toml crate) and everything has paper defaults, so `RunConfig::default()`
//! reproduces the paper's high-performance Llama setup.

use crate::ir::registry;
use crate::ir::spec::{Phase, Scenario, WorkloadSpec};
use crate::nn::{BackendSel, KernelSel};
use crate::ppa::PpaWeights;

/// The workload graph to optimize for — a handle onto one
/// [`registry`] entry, resolved from `workload=<name>` (canonical name
/// or alias).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Workload {
    name: &'static str,
}

impl Workload {
    /// Llama 3.1 8B Instruct FP16 (the paper's headline workload).
    pub const LLAMA31_8B: Workload = Workload { name: registry::LLAMA31_8B.name };
    /// SmolVLM-256M (the §4.12 low-power validation workload).
    pub const SMOLVLM: Workload = Workload { name: registry::SMOLVLM.name };

    /// Resolve a `workload=` value; the error lists every registered name.
    pub fn parse(value: &str) -> Result<Workload, String> {
        match registry::get(value) {
            Some(spec) => Ok(Workload { name: spec.name }),
            None => Err(format!(
                "unknown workload {value}; registered: {}",
                registry::names().join(", ")
            )),
        }
    }

    /// Canonical registry name.
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// The backing spec.
    pub fn spec(&self) -> &'static WorkloadSpec {
        registry::get(self.name).expect("Workload always holds a registered name")
    }

    /// Build the graph at the workload's default scenario.
    pub fn build(&self) -> crate::ir::Graph {
        self.spec().build_default()
    }

    /// Build the graph for an explicit scenario.
    pub fn build_scenario(&self, scn: &Scenario) -> crate::ir::Graph {
        self.spec().build(scn)
    }

    /// Default evaluation context length (§4.1).
    pub fn seq_len(&self) -> u32 {
        self.spec().default_seq_len
    }
}

/// Placement granularity (DESIGN.md §4): `Op` = all graph operators
/// (paper-faithful O(N_ops × N_cores)); `Group` = per-layer clusters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Granularity {
    Op,
    Group,
}

/// Per-node constraint budgets (Eq 68's C_node and the Eq 35–37
/// normalization ranges are derived from these).
#[derive(Debug, Clone, Copy)]
pub struct NodeBudget {
    pub nm: u32,
    pub power_budget_mw: f64,
    pub area_budget_mm2: f64,
    /// Normalization ceiling for performance (GOps/s).
    pub perf_max_gops: f64,
}

/// Optimization mode: the paper demonstrates high-performance (Llama) and
/// low-power (SmolVLM) profiles (§5.4 "Multi-objective selection").
#[derive(Debug, Clone)]
pub struct ModeConfig {
    pub name: &'static str,
    pub weights: PpaWeights,
    /// High-performance mode pins the clock to the node fmax (§3.15);
    /// otherwise the RL selects it (low-power lands at 10 MHz).
    pub pin_clock_to_fmax: bool,
    /// Fixed clock override (low-power validation uses 10 MHz).
    pub clock_mhz_fixed: Option<f64>,
    /// Speculative-decoding acceleration α_spec (§3.8; ~1.56 in the
    /// paper's high-performance runs, off in low-power mode).
    pub alpha_spec: f64,
    /// Compute/SRAM activity factor (duty cycle); low-power mode runs
    /// bursty inference at ~5%.
    pub activity: f64,
    pub budgets: Vec<NodeBudget>,
}

impl ModeConfig {
    /// Paper high-performance profile. Budgets are the user-facing
    /// constraints C_n of Algorithm 1; set ~5–10% above the paper's
    /// reported operating points so the paper's optima are feasible but
    /// near the constraint surface (DESIGN.md §6).
    ///
    /// Reward weights: Table 14 defines this mode as "Maximize
    /// throughput". The paper quotes (0.4, 0.4, 0.2) under its own
    /// (unpublished) normalization ranges; under our budget-relative
    /// normalization those weights favor small meshes, so we use a
    /// performance-dominant scalarization that reproduces the paper's
    /// observed behaviour (growth to the power-budget surface). The
    /// (0.4, 0.4, 0.2) profile remains available as
    /// [`PpaWeights::HIGH_PERF`] for Pareto-frontier reporting.
    pub fn high_performance() -> Self {
        let b = |nm, p: f64, a: f64, perf: f64| NodeBudget {
            nm,
            power_budget_mw: p * 1.05,
            area_budget_mm2: a * 1.10,
            perf_max_gops: perf * 2.0,
        };
        ModeConfig {
            name: "high-performance",
            weights: PpaWeights { perf: 0.85, power: 0.10, area: 0.05 },
            pin_clock_to_fmax: true,
            clock_mhz_fixed: None,
            alpha_spec: 1.56,
            activity: 1.0,
            budgets: vec![
                b(3, 51_366.0, 648.0, 466_364.0),
                b(5, 57_153.0, 929.0, 338_116.0),
                b(7, 46_208.0, 1_220.0, 173_899.0),
                b(10, 25_134.0, 1_572.0, 99_939.0),
                b(14, 14_161.0, 1_992.0, 51_072.0),
                b(22, 7_093.0, 2_882.0, 18_077.0),
                b(28, 3_780.0, 3_545.0, 9_744.0),
            ],
        }
    }

    /// Paper low-power profile (SmolVLM validation, §4.12).
    pub fn low_power() -> Self {
        let b = |nm, a: f64| NodeBudget {
            nm,
            power_budget_mw: 15.0,
            area_budget_mm2: a * 1.4,
            perf_max_gops: 50.0,
        };
        ModeConfig {
            name: "low-power",
            weights: PpaWeights::LOW_POWER,
            pin_clock_to_fmax: false,
            clock_mhz_fixed: Some(10.0),
            alpha_spec: 1.0,
            activity: 0.05,
            budgets: vec![
                b(3, 17.6),
                b(5, 26.2),
                b(7, 35.0),
                b(10, 46.7),
                b(14, 61.7),
                b(22, 99.2),
                b(28, 124.9),
            ],
        }
    }

    pub fn budget(&self, nm: u32) -> &NodeBudget {
        self.budgets
            .iter()
            .find(|b| b.nm == nm)
            .unwrap_or_else(|| panic!("no budget for {nm}nm"))
    }
}

/// RL hyperparameters (Table 6).
#[derive(Debug, Clone, Copy)]
pub struct RlConfig {
    pub episodes_per_node: usize, // up to 4,613 in the paper
    pub warmup_steps: usize,      // 1,000
    pub batch: usize,             // 256
    pub buffer_capacity: usize,   // 100,000
    pub per_alpha: f64,           // 0.6
    pub per_beta0: f64,           // 0.4 -> 1.0
    pub per_beta_step: f64,       // +0.001 per sample
    pub eps0: f64,                // 0.5
    pub eps_min: f64,             // 0.1
    pub mpc_candidates: usize,    // 64
    pub mpc_horizon: usize,       // 5
    pub mpc_blend: f64,           // 0.7 MPC / 0.3 SAC
    pub mpc_eps_gate: f64,        // MPC activates when eps < 0.15
    pub mpc_noise: f64,           // 0.3
    pub gamma: f64,               // 0.99
    /// Train the world model every k episodes (1 = paper's every step).
    pub wm_train_every: usize,
    /// Train the surrogate every k episodes.
    pub sur_train_every: usize,
    /// Worker threads for the evaluation layer (0 = auto-detect).
    pub eval_threads: usize,
    /// Candidate-set size per baseline search round: proposals are scored
    /// in batches of this size through `Evaluator::evaluate_many`, and the
    /// mesh walks to the round's best candidate. Independent of
    /// `eval_threads`, so results do not depend on the worker count.
    pub candidate_batch: usize,
    /// MPC candidates re-ranked through the real evaluator after the
    /// world-model rollout (0 disables re-ranking).
    pub mpc_rerank: usize,
    /// Memo-cache capacity (design points) for Algorithm 1's episode
    /// loop; 0 disables caching.
    pub eval_cache: usize,
    /// Vec-env width for the SAC drivers (`lanes=` / `--lanes=N`): how
    /// many (node, seed) search lanes step in lockstep per batched actor
    /// forward. 0 = auto (`min(jobs, cores)`); 1 = the serial loop.
    /// Jobs beyond the width run in consecutive waves sharing the agent.
    pub lanes: usize,
    /// Roofline admission pruning on argmax-only batch paths (baseline
    /// candidate rounds, MPC re-ranking, multiseed sweeps): candidates
    /// whose O(1) optimistic bound cannot beat the batch incumbent skip
    /// the full evaluation. The selected design is bit-identical either
    /// way; pruned candidates are absent from episode logs and Pareto
    /// archives, so the library default is the exact path (the CLI's
    /// argmax-only commands enable it, with `--no-prune` as fallback).
    pub prune: bool,
    /// Where SAC/world-model/surrogate updates run (`learner=`):
    /// `inline` on the rollout thread between lockstep steps (default),
    /// `pinned` on a dedicated learner thread replaying the exact inline
    /// schedule (bit-identical, DESIGN.md §11), or `async` free-running
    /// for throughput.
    pub learner: crate::rl::learner::LearnerMode,
    /// `learner=async` update budget: update rounds earned per absorbed
    /// rollout step once warmup passes (fractional okay; `0` = uncapped
    /// free-run). Ignored by `inline`/`pinned`, which are schedule-exact.
    pub updates_per_step: f64,
    /// Rollout→learner queue bound, in transitions (`queue_cap=`);
    /// 0 = auto (8 lockstep steps of backlog, i.e. `8 × lanes`).
    pub queue_cap: usize,
    /// Checkpoint cadence (`checkpoint_every=N`): snapshot the full run
    /// state to `<out_dir>/ckpt` every N lockstep steps (plus wave and
    /// atlas-group boundaries). 0 disables checkpointing (DESIGN.md §13).
    pub checkpoint_every: usize,
    /// Fault-injection hook (`crash_after=N`): abort the run at the N-th
    /// crash probe (step/wave/queue boundaries). 0 disables. Test/CI
    /// only — pins the kill-and-resume contract.
    pub crash_after: u64,
    /// Fault-injection hook (`learner_fail_after=N`): the dedicated
    /// learner thread fails after absorbing N rollout steps, exercising
    /// the graceful inline-fallback degradation path. 0 disables.
    pub learner_fail_after: u64,
}

impl Default for RlConfig {
    fn default() -> Self {
        RlConfig {
            episodes_per_node: 4_613,
            warmup_steps: 1_000,
            batch: 256,
            buffer_capacity: 100_000,
            per_alpha: 0.6,
            per_beta0: 0.4,
            per_beta_step: 0.001,
            eps0: 0.5,
            eps_min: 0.1,
            mpc_candidates: 64,
            mpc_horizon: 5,
            mpc_blend: 0.7,
            mpc_eps_gate: 0.15,
            mpc_noise: 0.3,
            gamma: 0.99,
            wm_train_every: 1,
            sur_train_every: 1,
            eval_threads: 0,
            candidate_batch: 8,
            mpc_rerank: 8,
            eval_cache: 256,
            lanes: 0,
            prune: false,
            learner: crate::rl::learner::LearnerMode::Inline,
            updates_per_step: 1.0,
            queue_cap: 0,
            checkpoint_every: 0,
            crash_after: 0,
            learner_fail_after: 0,
        }
    }
}

/// Parse an `on|off` switch (also accepting the `true|false|1|0|yes|no`
/// forms the boolean keys use).
fn parse_switch(key: &str, value: &str) -> Result<bool, String> {
    match value {
        "on" | "true" | "1" | "yes" => Ok(true),
        "off" | "false" | "0" | "no" => Ok(false),
        _ => Err(format!("bad {key} {value}")),
    }
}

/// Scenario-atlas sweep options (`silicon-rl atlas`, DESIGN.md §12).
#[derive(Debug, Clone)]
pub struct AtlasOptions {
    /// Cross-point roofline dominance pruning (`atlas_prune=on|off`).
    /// `off` is the exact fallback: every grid point runs cold so the
    /// pruned sweep's per-point frontiers can be checked bit-identical.
    pub prune: bool,
    /// Warm shared state (`atlas_warm=on|off`): one shared outcome memo
    /// plus agent stores handed between neighboring points in curriculum
    /// order. `off` gives each point a fresh agent and private caches —
    /// the configuration the pruned≡exact contract is stated under.
    pub warm: bool,
    /// Budget shrink for dominated points (`atlas_shrink=N`): 0 skips
    /// them outright, N ≥ 1 runs them at `episodes / N`.
    pub shrink: u32,
    /// Scenario axes of the grid (`atlas_seq_lens=` / `atlas_batches=` /
    /// `atlas_phases=` comma lists).
    pub seq_lens: Vec<u32>,
    pub batches: Vec<u32>,
    pub phases: Vec<Phase>,
    /// Workloads to sweep (`atlas_workloads=` comma list of registry
    /// names); empty = every registered workload.
    pub workloads: Vec<String>,
    /// Seeds per scenario point (`atlas_seeds=N`), aggregated with the
    /// multiseed machinery when > 1.
    pub n_seeds: usize,
}

impl Default for AtlasOptions {
    fn default() -> Self {
        AtlasOptions {
            prune: true,
            warm: true,
            shrink: 0,
            seq_lens: vec![512, 2048, 8192],
            batches: vec![1, 4],
            phases: vec![Phase::Decode, Phase::Prefill],
            workloads: Vec::new(),
            n_seeds: 1,
        }
    }
}

/// Full run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub workload: Workload,
    /// Scenario axis (§3.8): inference phase. Threaded through the graph
    /// builder (attention span, φ), KV footprint, roofline and
    /// throughput models.
    pub phase: Phase,
    /// Context-length override; `None` = the workload's default.
    pub seq_len: Option<u32>,
    /// Batch-size override; `None` = the workload's default (3 for the
    /// paper's Llama evaluation, 1 elsewhere).
    pub batch: Option<u32>,
    pub nodes_nm: Vec<u32>,
    pub mode: ModeConfig,
    pub rl: RlConfig,
    pub granularity: Granularity,
    pub seed: u64,
    /// KV compaction strategy for the run (§3.9).
    pub kv_strategy: crate::kv::KvStrategy,
    /// NN backend for the SAC agent (`backend=native|pjrt|auto`): `auto`
    /// uses PJRT when AOT artifacts are present and executable, native
    /// otherwise — so `optimize` runs with no artifacts at all.
    pub backend: BackendSel,
    /// Compute-kernel path (`kernels=scalar|simd|auto`): `scalar` is the
    /// bit-exact determinism reference, `simd` the vectorized AVX2/NEON
    /// path (tolerance-parity), `auto` picks SIMD when the CPU supports
    /// it (DESIGN.md §10).
    pub kernels: KernelSel,
    pub artifacts_dir: String,
    pub out_dir: String,
    /// `optimize` driver: run the per-node sweeps concurrently, one agent
    /// per node (forfeits Eq 50's cross-node transfer learning for
    /// wall-clock; results are deterministic per node).
    pub parallel_nodes: bool,
    /// Whether `rl.prune` was explicitly set (CLI `prune=` / `--no-prune`
    /// or a config-file line) — the CLI's argmax-only commands default
    /// pruning on only when the user expressed no preference.
    pub prune_explicit: bool,
    /// Scenario-atlas sweep options (`silicon-rl atlas`).
    pub atlas: AtlasOptions,
    /// Resume from a checkpoint directory (`resume=<dir>`): `<dir>/ckpt`
    /// when present (so `resume=` takes the previous run's `out_dir`),
    /// else `<dir>` itself. `None` = fresh start.
    pub resume: Option<String>,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            workload: Workload::LLAMA31_8B,
            phase: Phase::Decode,
            seq_len: None,
            batch: None,
            nodes_nm: vec![3, 5, 7, 10, 14, 22, 28],
            mode: ModeConfig::high_performance(),
            rl: RlConfig::default(),
            granularity: Granularity::Group,
            seed: 0xA51C,
            kv_strategy: crate::kv::KvStrategy::Full,
            backend: BackendSel::Auto,
            kernels: KernelSel::Auto,
            artifacts_dir: "artifacts".into(),
            out_dir: "out".into(),
            parallel_nodes: false,
            prune_explicit: false,
            atlas: AtlasOptions::default(),
            resume: None,
        }
    }
}

impl RunConfig {
    pub fn smolvlm_low_power() -> Self {
        RunConfig {
            workload: Workload::SMOLVLM,
            mode: ModeConfig::low_power(),
            // tiny on-device VLM: INT4 KV with a short sliding window so
            // the cache fits the compact meshes' DMEM (§3.9 compaction;
            // without it the 8-12 TCC designs of Table 19 cannot hold KV)
            kv_strategy: crate::kv::KvStrategy::QuantizedWindow { bits: 4, tokens: 64 },
            ..Default::default()
        }
    }

    /// Worker threads for the evaluation layer, auto-detect resolved.
    pub fn eval_threads(&self) -> usize {
        crate::eval::parallel::resolve(self.rl.eval_threads)
    }

    /// Resolve the vec-env width for a job list: `lanes=0` (auto) takes
    /// one lane per job up to the worker-thread count — minus one core
    /// reserved for the learner thread when `learner=pinned|async` — and
    /// an explicit width is clamped to the job count (a wave can't be
    /// wider than its jobs).
    pub fn resolve_lanes(&self, jobs: usize) -> usize {
        let width = if self.rl.lanes == 0 {
            crate::eval::parallel::num_threads_reserving(self.learner_reserve())
        } else {
            self.rl.lanes
        };
        width.min(jobs).max(1)
    }

    /// Worker threads for the rollout fan-out: [`Self::eval_threads`]
    /// minus the core reserved for the dedicated learner thread when
    /// `learner=pinned|async`, floored at one.
    pub fn rollout_threads(&self) -> usize {
        self.eval_threads().saturating_sub(self.learner_reserve()).max(1)
    }

    /// Cores to hold back from rollout work for the learner thread.
    fn learner_reserve(&self) -> usize {
        usize::from(self.rl.learner.off_loop())
    }

    /// The resolved evaluation scenario: explicit `phase=` / `seq_len=` /
    /// `batch=` overrides on top of the workload's defaults.
    pub fn scenario(&self) -> Scenario {
        let spec = self.workload.spec();
        Scenario {
            phase: self.phase,
            seq_len: self.seq_len.unwrap_or(spec.default_seq_len).max(1),
            batch: self.batch.unwrap_or(spec.default_batch).max(1),
        }
    }

    /// Apply `key=value` overrides (CLI / config file lines). Supported
    /// keys: episodes, warmup, seed, granularity (op|group), workload
    /// (any registry name/alias), phase (prefill|decode), seq_len, batch,
    /// mode (hp|lp), nodes (comma list), out_dir, artifacts_dir, backend
    /// (native|pjrt|auto), kernels (scalar|simd|auto),
    /// kv (full|int8|int4|window:N|int8win:N),
    /// threads (0 = auto), lanes (vec-env width, 0 = auto),
    /// eval_cache (episode-loop memo capacity in design points, 0 = off),
    /// learner (inline|pinned|async — where SAC/WM/surrogate updates
    /// run), updates_per_step (async update budget, 0 = uncapped),
    /// queue_cap (rollout→learner bound in transitions, 0 = auto),
    /// candidate_batch, parallel_nodes (true|false),
    /// prune (true|false — roofline admission pruning on argmax paths),
    /// and the atlas keys: atlas_prune / atlas_warm (on|off),
    /// atlas_shrink (0 = skip dominated points, N ≥ 1 = episodes/N),
    /// atlas_seq_lens / atlas_batches (comma u32 lists), atlas_phases
    /// (comma prefill|decode list), atlas_workloads (comma registry
    /// names, empty = all), atlas_seeds (seeds per point),
    /// and the robustness keys: checkpoint_every (snapshot cadence in
    /// steps, 0 = off), resume (checkpoint dir or previous out_dir),
    /// crash_after / learner_fail_after (fault-injection hooks, 0 = off).
    pub fn apply(&mut self, key: &str, value: &str) -> Result<(), String> {
        match key {
            "episodes" => {
                self.rl.episodes_per_node =
                    value.parse().map_err(|_| format!("bad episodes {value}"))?
            }
            "warmup" => {
                self.rl.warmup_steps =
                    value.parse().map_err(|_| format!("bad warmup {value}"))?
            }
            "seed" => self.seed = value.parse().map_err(|_| format!("bad seed {value}"))?,
            "granularity" => {
                self.granularity = match value {
                    "op" => Granularity::Op,
                    "group" => Granularity::Group,
                    _ => return Err(format!("bad granularity {value}")),
                }
            }
            "workload" => self.workload = Workload::parse(value)?,
            "phase" => self.phase = Phase::parse(value)?,
            "seq_len" => {
                let n: u32 =
                    value.parse().map_err(|_| format!("bad seq_len {value}"))?;
                if n == 0 {
                    return Err("seq_len must be >= 1".to_string());
                }
                self.seq_len = Some(n);
            }
            "batch" => {
                let n: u32 = value.parse().map_err(|_| format!("bad batch {value}"))?;
                if n == 0 {
                    return Err("batch must be >= 1".to_string());
                }
                self.batch = Some(n);
            }
            "mode" => {
                self.mode = match value {
                    "hp" | "high-performance" => ModeConfig::high_performance(),
                    "lp" | "low-power" => ModeConfig::low_power(),
                    _ => return Err(format!("bad mode {value}")),
                }
            }
            "nodes" => {
                self.nodes_nm = value
                    .split(',')
                    .map(|s| s.trim().parse::<u32>())
                    .collect::<Result<_, _>>()
                    .map_err(|_| format!("bad nodes {value}"))?
            }
            "out_dir" => self.out_dir = value.to_string(),
            "artifacts_dir" => self.artifacts_dir = value.to_string(),
            "backend" => self.backend = BackendSel::parse(value)?,
            "kernels" => self.kernels = KernelSel::parse(value)?,
            "threads" => {
                self.rl.eval_threads =
                    value.parse().map_err(|_| format!("bad threads {value}"))?
            }
            "lanes" => {
                self.rl.lanes =
                    value.parse().map_err(|_| format!("bad lanes {value}"))?
            }
            "eval_cache" => {
                self.rl.eval_cache =
                    value.parse().map_err(|_| format!("bad eval_cache {value}"))?
            }
            "learner" => self.rl.learner = crate::rl::learner::LearnerMode::parse(value)?,
            "updates_per_step" => {
                let n: f64 = value
                    .parse()
                    .map_err(|_| format!("bad updates_per_step {value}"))?;
                if !n.is_finite() || n < 0.0 {
                    return Err("updates_per_step must be finite and >= 0".to_string());
                }
                self.rl.updates_per_step = n;
            }
            "queue_cap" => {
                self.rl.queue_cap =
                    value.parse().map_err(|_| format!("bad queue_cap {value}"))?
            }
            "candidate_batch" => {
                let n: usize =
                    value.parse().map_err(|_| format!("bad candidate_batch {value}"))?;
                if n == 0 {
                    return Err("candidate_batch must be >= 1".to_string());
                }
                self.rl.candidate_batch = n;
            }
            "parallel_nodes" => {
                self.parallel_nodes = match value {
                    "true" | "1" | "yes" => true,
                    "false" | "0" | "no" => false,
                    _ => return Err(format!("bad parallel_nodes {value}")),
                }
            }
            "prune" => {
                self.rl.prune = match value {
                    "true" | "1" | "yes" => true,
                    "false" | "0" | "no" => false,
                    _ => return Err(format!("bad prune {value}")),
                };
                self.prune_explicit = true;
            }
            "atlas_prune" => self.atlas.prune = parse_switch("atlas_prune", value)?,
            "atlas_warm" => self.atlas.warm = parse_switch("atlas_warm", value)?,
            "atlas_shrink" => {
                self.atlas.shrink =
                    value.parse().map_err(|_| format!("bad atlas_shrink {value}"))?
            }
            "atlas_seq_lens" => {
                let lens: Vec<u32> = value
                    .split(',')
                    .map(|s| s.trim().parse::<u32>())
                    .collect::<Result<_, _>>()
                    .map_err(|_| format!("bad atlas_seq_lens {value}"))?;
                if lens.is_empty() || lens.contains(&0) {
                    return Err("atlas_seq_lens needs values >= 1".to_string());
                }
                self.atlas.seq_lens = lens;
            }
            "atlas_batches" => {
                let batches: Vec<u32> = value
                    .split(',')
                    .map(|s| s.trim().parse::<u32>())
                    .collect::<Result<_, _>>()
                    .map_err(|_| format!("bad atlas_batches {value}"))?;
                if batches.is_empty() || batches.contains(&0) {
                    return Err("atlas_batches needs values >= 1".to_string());
                }
                self.atlas.batches = batches;
            }
            "atlas_phases" => {
                self.atlas.phases = value
                    .split(',')
                    .map(|s| Phase::parse(s.trim()))
                    .collect::<Result<_, _>>()?;
                if self.atlas.phases.is_empty() {
                    return Err("atlas_phases needs at least one phase".to_string());
                }
            }
            "atlas_workloads" => {
                self.atlas.workloads = value
                    .split(',')
                    .map(|s| s.trim().to_string())
                    .filter(|s| !s.is_empty())
                    .collect()
            }
            "atlas_seeds" => {
                let n: usize =
                    value.parse().map_err(|_| format!("bad atlas_seeds {value}"))?;
                if n == 0 {
                    return Err("atlas_seeds must be >= 1".to_string());
                }
                self.atlas.n_seeds = n;
            }
            "checkpoint_every" => {
                self.rl.checkpoint_every = value
                    .parse()
                    .map_err(|_| format!("bad checkpoint_every {value}"))?
            }
            "resume" => {
                if value.is_empty() {
                    return Err("resume needs a checkpoint directory".to_string());
                }
                self.resume = Some(value.to_string());
            }
            "crash_after" => {
                self.rl.crash_after =
                    value.parse().map_err(|_| format!("bad crash_after {value}"))?
            }
            "learner_fail_after" => {
                self.rl.learner_fail_after = value
                    .parse()
                    .map_err(|_| format!("bad learner_fail_after {value}"))?
            }
            "kv" => {
                use crate::kv::KvStrategy::*;
                self.kv_strategy = if value == "full" {
                    Full
                } else if value == "int8" {
                    Quantized { bits: 8 }
                } else if value == "int4" {
                    Quantized { bits: 4 }
                } else if let Some(n) = value.strip_prefix("window:") {
                    Window { tokens: n.parse().map_err(|_| "bad window")? }
                } else if let Some(n) = value.strip_prefix("int8win:") {
                    QuantizedWindow {
                        bits: 8,
                        tokens: n.parse().map_err(|_| "bad window")?,
                    }
                } else {
                    return Err(format!("bad kv strategy {value}"));
                }
            }
            _ => return Err(format!("unknown config key {key}")),
        }
        Ok(())
    }

    /// The atlas sweep's workload list: the explicit `atlas_workloads=`
    /// selection, or every registered workload when none was named.
    pub fn atlas_grid_workloads(&self) -> Vec<String> {
        if self.atlas.workloads.is_empty() {
            crate::ir::registry::names().iter().map(|s| s.to_string()).collect()
        } else {
            self.atlas.workloads.clone()
        }
    }

    /// Load `key = value` lines (comments with '#') from a file on top of
    /// the current config.
    pub fn load_file(&mut self, path: &str) -> Result<(), String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        for (i, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap().trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("{path}:{}: expected key = value", i + 1))?;
            self.apply(k.trim(), v.trim())?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_table6() {
        let c = RlConfig::default();
        assert_eq!(c.batch, 256);
        assert_eq!(c.buffer_capacity, 100_000);
        assert_eq!(c.warmup_steps, 1_000);
        assert_eq!(c.mpc_candidates, 64);
        assert_eq!(c.mpc_horizon, 5);
        assert!((c.mpc_blend - 0.7).abs() < 1e-12);
        assert!((c.per_alpha - 0.6).abs() < 1e-12);
        assert!((c.eps0 - 0.5).abs() < 1e-12 && (c.eps_min - 0.1).abs() < 1e-12);
        assert_eq!(RunConfig::default().rl.episodes_per_node, 4_613);
    }

    #[test]
    fn all_seven_nodes_have_budgets() {
        for mode in [ModeConfig::high_performance(), ModeConfig::low_power()] {
            for nm in [3, 5, 7, 10, 14, 22, 28] {
                let b = mode.budget(nm);
                assert!(b.power_budget_mw > 0.0 && b.area_budget_mm2 > 0.0);
            }
        }
    }

    #[test]
    fn apply_overrides() {
        let mut c = RunConfig::default();
        c.apply("episodes", "100").unwrap();
        c.apply("granularity", "op").unwrap();
        c.apply("workload", "smolvlm").unwrap();
        c.apply("nodes", "3,28").unwrap();
        c.apply("kv", "int8win:1024").unwrap();
        c.apply("threads", "4").unwrap();
        c.apply("candidate_batch", "16").unwrap();
        c.apply("parallel_nodes", "true").unwrap();
        assert!(!c.rl.prune && !c.prune_explicit);
        c.apply("prune", "true").unwrap();
        assert!(c.rl.prune && c.prune_explicit);
        assert_eq!(c.rl.episodes_per_node, 100);
        assert_eq!(c.granularity, Granularity::Op);
        assert_eq!(c.workload, Workload::SMOLVLM);
        assert_eq!(c.nodes_nm, vec![3, 28]);
        assert_eq!(c.rl.eval_threads, 4);
        assert_eq!(c.rl.candidate_batch, 16);
        assert!(c.parallel_nodes);
        assert_eq!(c.backend, BackendSel::Auto);
        c.apply("backend", "native").unwrap();
        assert_eq!(c.backend, BackendSel::Native);
        c.apply("backend", "pjrt").unwrap();
        assert_eq!(c.backend, BackendSel::Pjrt);
        c.apply("backend", "auto").unwrap();
        assert_eq!(c.backend, BackendSel::Auto);
        assert!(c.apply("backend", "tpu").is_err());
        assert_eq!(c.kernels, KernelSel::Auto);
        c.apply("kernels", "scalar").unwrap();
        assert_eq!(c.kernels, KernelSel::Scalar);
        c.apply("kernels", "simd").unwrap();
        assert_eq!(c.kernels, KernelSel::Simd);
        c.apply("kernels", "auto").unwrap();
        assert_eq!(c.kernels, KernelSel::Auto);
        assert!(c.apply("kernels", "avx512").is_err());
        assert!(c.apply("bogus", "1").is_err());
        assert!(c.apply("episodes", "xyz").is_err());
        assert!(c.apply("candidate_batch", "0").is_err());
        assert!(c.apply("parallel_nodes", "maybe").is_err());
        assert!(c.apply("prune", "maybe").is_err());
        assert_eq!(c.rl.lanes, 0);
        c.apply("lanes", "4").unwrap();
        assert_eq!(c.rl.lanes, 4);
        assert!(c.apply("lanes", "many").is_err());
        assert_eq!(c.rl.eval_cache, 256);
        c.apply("eval_cache", "0").unwrap();
        assert_eq!(c.rl.eval_cache, 0);
        c.apply("eval_cache", "1024").unwrap();
        assert_eq!(c.rl.eval_cache, 1024);
        assert!(c.apply("eval_cache", "big").is_err());
    }

    #[test]
    fn atlas_keys_apply_and_validate() {
        let mut c = RunConfig::default();
        assert!(c.atlas.prune && c.atlas.warm);
        assert_eq!(c.atlas.shrink, 0);
        assert_eq!(c.atlas.n_seeds, 1);
        assert!(c.atlas.workloads.is_empty());
        // empty selection resolves to the full registry
        assert_eq!(c.atlas_grid_workloads().len(), crate::ir::registry::all().len());
        c.apply("atlas_prune", "off").unwrap();
        c.apply("atlas_warm", "false").unwrap();
        c.apply("atlas_shrink", "4").unwrap();
        c.apply("atlas_seq_lens", "512, 2048").unwrap();
        c.apply("atlas_batches", "1,4,8").unwrap();
        c.apply("atlas_phases", "decode").unwrap();
        c.apply("atlas_workloads", "llama-3.2-1b, qwen2-0.5b").unwrap();
        c.apply("atlas_seeds", "3").unwrap();
        assert!(!c.atlas.prune && !c.atlas.warm);
        assert_eq!(c.atlas.shrink, 4);
        assert_eq!(c.atlas.seq_lens, vec![512, 2048]);
        assert_eq!(c.atlas.batches, vec![1, 4, 8]);
        assert_eq!(c.atlas.phases, vec![Phase::Decode]);
        assert_eq!(c.atlas_grid_workloads(), vec!["llama-3.2-1b", "qwen2-0.5b"]);
        assert_eq!(c.atlas.n_seeds, 3);
        assert!(c.apply("atlas_prune", "maybe").is_err());
        assert!(c.apply("atlas_seq_lens", "0").is_err());
        assert!(c.apply("atlas_batches", "").is_err());
        assert!(c.apply("atlas_phases", "train").is_err());
        assert!(c.apply("atlas_seeds", "0").is_err());
    }

    #[test]
    fn learner_keys_apply_and_validate() {
        use crate::rl::learner::LearnerMode;
        let mut c = RunConfig::default();
        assert_eq!(c.rl.learner, LearnerMode::Inline);
        assert!((c.rl.updates_per_step - 1.0).abs() < 1e-12);
        assert_eq!(c.rl.queue_cap, 0);
        c.apply("learner", "pinned").unwrap();
        assert_eq!(c.rl.learner, LearnerMode::Pinned);
        c.apply("learner", "async").unwrap();
        assert_eq!(c.rl.learner, LearnerMode::Async);
        c.apply("learner", "inline").unwrap();
        assert_eq!(c.rl.learner, LearnerMode::Inline);
        assert!(c.apply("learner", "offline").is_err());
        c.apply("updates_per_step", "0.5").unwrap();
        assert!((c.rl.updates_per_step - 0.5).abs() < 1e-12);
        c.apply("updates_per_step", "0").unwrap();
        assert_eq!(c.rl.updates_per_step, 0.0);
        assert!(c.apply("updates_per_step", "-1").is_err());
        assert!(c.apply("updates_per_step", "inf").is_err());
        assert!(c.apply("updates_per_step", "fast").is_err());
        c.apply("queue_cap", "128").unwrap();
        assert_eq!(c.rl.queue_cap, 128);
        assert!(c.apply("queue_cap", "-3").is_err());
    }

    #[test]
    fn checkpoint_keys_apply_and_validate() {
        let mut c = RunConfig::default();
        assert_eq!(c.rl.checkpoint_every, 0);
        assert_eq!(c.rl.crash_after, 0);
        assert_eq!(c.rl.learner_fail_after, 0);
        assert!(c.resume.is_none());
        c.apply("checkpoint_every", "16").unwrap();
        assert_eq!(c.rl.checkpoint_every, 16);
        c.apply("resume", "out/run1").unwrap();
        assert_eq!(c.resume.as_deref(), Some("out/run1"));
        c.apply("crash_after", "30").unwrap();
        assert_eq!(c.rl.crash_after, 30);
        c.apply("learner_fail_after", "10").unwrap();
        assert_eq!(c.rl.learner_fail_after, 10);
        assert!(c.apply("checkpoint_every", "often").is_err());
        assert!(c.apply("resume", "").is_err());
        assert!(c.apply("crash_after", "-1").is_err());
        assert!(c.apply("learner_fail_after", "soon").is_err());
    }

    #[test]
    fn lanes_resolve_auto_and_clamp() {
        let mut c = RunConfig::default();
        // auto: at least 1, never wider than the job list
        assert_eq!(c.resolve_lanes(1), 1);
        assert!(c.resolve_lanes(64) >= 1);
        c.rl.lanes = 4;
        assert_eq!(c.resolve_lanes(7), 4);
        assert_eq!(c.resolve_lanes(2), 2);
        assert_eq!(c.resolve_lanes(0), 1);
    }

    #[test]
    fn off_loop_learner_reserves_a_rollout_core() {
        use crate::eval::parallel::num_threads;
        let mut c = RunConfig::default();
        let cores = num_threads();
        // auto lane sizing holds one core back for the learner thread
        assert_eq!(c.resolve_lanes(usize::MAX), cores);
        c.apply("learner", "async").unwrap();
        assert_eq!(c.resolve_lanes(usize::MAX), cores.saturating_sub(1).max(1));
        // same reservation in the rollout worker budget
        assert_eq!(c.rollout_threads(), cores.saturating_sub(1).max(1));
        c.apply("learner", "inline").unwrap();
        assert_eq!(c.rollout_threads(), c.eval_threads());
        // explicit lanes= overrides the reservation entirely
        c.apply("learner", "pinned").unwrap();
        c.rl.lanes = 4;
        assert_eq!(c.resolve_lanes(usize::MAX), 4);
    }

    #[test]
    fn scenario_keys_apply_and_resolve() {
        let mut c = RunConfig::default();
        // defaults: decode at the workload's seq_len/batch (llama: 2048/3)
        let scn = c.scenario();
        assert_eq!(scn.phase, Phase::Decode);
        assert_eq!((scn.seq_len, scn.batch), (2048, 3));

        c.apply("phase", "prefill").unwrap();
        c.apply("seq_len", "8192").unwrap();
        c.apply("batch", "2").unwrap();
        let scn = c.scenario();
        assert_eq!(scn.phase, Phase::Prefill);
        assert_eq!((scn.seq_len, scn.batch), (8192, 2));

        // smolvlm defaults: 1024-token context, batch 1
        let mut lp = RunConfig::smolvlm_low_power();
        assert_eq!((lp.scenario().seq_len, lp.scenario().batch), (1024, 1));
        lp.apply("batch", "4").unwrap();
        assert_eq!(lp.scenario().batch, 4);

        assert!(c.apply("seq_len", "0").is_err());
        assert!(c.apply("batch", "0").is_err());
        assert!(c.apply("seq_len", "abc").is_err());
    }

    #[test]
    fn workload_and_phase_errors_list_options() {
        let mut c = RunConfig::default();
        let err = c.apply("workload", "gpt-17").unwrap_err();
        for name in crate::ir::registry::names() {
            assert!(err.contains(name), "error must list {name}: {err}");
        }
        let err = c.apply("phase", "training").unwrap_err();
        assert!(err.contains("prefill") && err.contains("decode"), "{err}");
    }

    #[test]
    fn workload_aliases_resolve_to_canonical() {
        let mut c = RunConfig::default();
        c.apply("workload", "llama").unwrap();
        assert_eq!(c.workload, Workload::LLAMA31_8B);
        c.apply("workload", "llama-3.2-1b").unwrap();
        assert_eq!(c.workload.name(), "llama-3.2-1b");
        assert_eq!(c.workload.seq_len(), 2048);
        c.apply("workload", "vit").unwrap();
        assert_eq!(c.workload.name(), "vit-base");
    }

    #[test]
    fn config_file_round_trip() {
        let path = "/tmp/silicon_rl_test_cfg.txt";
        std::fs::write(
            path,
            "episodes = 42 # comment\nworkload = smolvlm\nphase = prefill\nseq_len = 512\n\n# full line comment\n",
        )
        .unwrap();
        let mut c = RunConfig::default();
        c.load_file(path).unwrap();
        assert_eq!(c.rl.episodes_per_node, 42);
        assert_eq!(c.workload, Workload::SMOLVLM);
        assert_eq!(c.phase, Phase::Prefill);
        assert_eq!(c.seq_len, Some(512));
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn low_power_budget_is_sub_15mw() {
        let m = ModeConfig::low_power();
        assert!(m.budgets.iter().all(|b| b.power_budget_mw <= 15.0));
        assert_eq!(m.clock_mhz_fixed, Some(10.0));
    }
}
