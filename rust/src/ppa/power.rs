//! Dynamic + leakage power model (Eq 62, decomposed per Table 12 into
//! compute / SRAM / ROM-read / NoC / leakage).

use crate::node::NodeSpec;

use super::DesignPoint;

/// Per-component power in mW (Table 12 columns).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PowerBreakdown {
    pub compute: f64,
    pub sram: f64,
    pub rom_read: f64,
    pub noc: f64,
    pub leakage: f64,
}

impl PowerBreakdown {
    pub fn total(&self) -> f64 {
        self.compute + self.sram + self.rom_read + self.noc + self.leakage
    }

    /// Component percentage shares (Table 12's Comp%, SRAM%, ...).
    pub fn shares(&self) -> [f64; 5] {
        let t = self.total().max(1e-12);
        [
            self.compute / t,
            self.sram / t,
            self.rom_read / t,
            self.noc / t,
            self.leakage / t,
        ]
    }
}

/// Evaluate Eq 62 for a design point at `tokens_per_s` realized rate.
pub fn evaluate(d: &DesignPoint, n: &NodeSpec, tokens_per_s: f64) -> PowerBreakdown {
    let f_hz = d.clock_mhz * 1e6;
    let f_ghz = d.clock_mhz / 1000.0;
    let cores = d.mesh.cores() as f64;

    // -- compute: MAC array switching, one MAC/lane/cycle at activity.
    // The speculative-decoding draft predictor (§4.13.1) adds ~15% of
    // compute power at full acceleration (α=1.6) — spec decode is not a
    // free throughput multiplier.
    let draft_overhead = 1.0 + 0.15 * (d.alpha_spec - 1.0) / 0.6;
    let compute =
        d.sum_lanes * f_hz * n.mac_energy_pj * 1e-12 * d.activity * 1e3 * draft_overhead;

    // -- SRAM dynamic: per-core access energy scaled by clock + activity
    let sram = cores * f_ghz * n.sram_dyn_mw_per_core_ghz * d.activity;

    // -- ROM read: W_total · E_dyn(n) · α of Eq 62; scales with f/fmax
    let weight_mb = d.weight_bytes / (1024.0 * 1024.0);
    let rom_read = weight_mb
        * n.rom_read_mw_per_mb_at_fmax
        * (d.clock_mhz / n.fmax_mhz)
        * d.activity;

    // -- NoC: energy ∝ bit-hops/s (cross-tile traffic from the placement)
    let bit_hops_per_s = d.traffic.byte_hops * 8.0 * tokens_per_s;
    let noc = bit_hops_per_s * n.noc_hop_pj_per_bit * 1e-12 * 1e3;

    // -- leakage: SRAM peripheral only (ROM sleep transistors, §3.15)
    let leakage = d.sram_mb * n.sram_leak_mw_per_mb;

    PowerBreakdown { compute, sram, rom_read, noc, leakage }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::MeshConfig;
    use crate::node::NodeTable;
    use crate::noc::TrafficStats;

    fn small_point(activity: f64, clock_mhz: f64) -> DesignPoint {
        DesignPoint {
            mesh: MeshConfig::new(2, 4),
            clock_mhz,
            dflit_bits: 256,
            sum_lanes: 8.0 * 21.0,
            sum_lanes_capped: 8.0 * 21.0,
            sram_mb: 0.25,
            weight_bytes: 0.48 * (1u64 << 30) as f64,
            traffic: TrafficStats::default(),
            eta_parallel: 0.9,
            eta_util: 0.8,
            alpha_spec: 1.0,
            flops_per_token: 2.0 * 0.24e9 * 0.95,
            mem_bytes_per_token: 0.48e9,
            sum_bw_eff: 1e12,
            activity,
        }
    }

    #[test]
    fn smolvlm_3nm_is_leakage_dominated_under_13mw() {
        // §4.12: all nodes < 13 mW at 10 MHz; 97% leakage at 3nm
        let t = NodeTable::paper();
        let p = evaluate(&small_point(0.05, 10.0), t.get(3).unwrap(), 10.0);
        assert!(p.total() < 13.0, "total {} mW", p.total());
        assert!(p.leakage / p.total() > 0.85, "leak share {}", p.leakage / p.total());
    }

    #[test]
    fn leakage_share_lower_at_28nm() {
        let t = NodeTable::paper();
        let p3 = evaluate(&small_point(0.05, 10.0), t.get(3).unwrap(), 10.0);
        let p28 = evaluate(&small_point(0.05, 10.0), t.get(28).unwrap(), 10.0);
        assert!(p28.leakage / p28.total() < p3.leakage / p3.total());
    }

    #[test]
    fn power_scales_with_activity() {
        let t = NodeTable::paper();
        let n = t.get(7).unwrap();
        let lo = evaluate(&small_point(0.1, 570.0), n, 100.0);
        let hi = evaluate(&small_point(1.0, 570.0), n, 100.0);
        assert!(hi.compute > 5.0 * lo.compute);
        // leakage unaffected by activity
        assert_eq!(hi.leakage, lo.leakage);
    }

    #[test]
    fn shares_sum_to_one() {
        let t = NodeTable::paper();
        let p = evaluate(&small_point(1.0, 250.0), t.get(28).unwrap(), 50.0);
        let s: f64 = p.shares().iter().sum();
        assert!((s - 1.0).abs() < 1e-9);
    }
}
