//! Roofline admission bound: an O(1) optimistic PPA envelope for one
//! decoded candidate, computed *before* placement (DESIGN.md §5).
//!
//! Following the roofline-as-admission-filter idea of the hardware
//! co-design scaling-law literature, the bound brackets every quantity
//! the full pipeline can produce for the same [`DecodedAction`]:
//! throughput from above (Eqs 21/22/24 with perfect load balance, zero
//! cross-tile traffic and an unbounded NoC), power and area from below
//! (Eq 62/64 terms that cannot shrink below the decoded configuration).
//! Scalarized through the lower-is-better PPA score, that yields an
//! *admissible* score bound: `bound ≤ true score` for any full
//! evaluation — so on argmax-only paths a candidate whose bound cannot
//! beat the incumbent is provably not the argmax and can skip the
//! O(units × cores) pipeline entirely.
//!
//! The §3.3 heterogeneous derivation brackets make this sound without
//! placement knowledge: per-tile VLEN/DMEM/IMEM are `quantize(avg ·
//! share)` with the compute share clamped to `[0.25, 4].sqrt() = [0.5,
//! 2]` (and the instruction share to `[0.25, 4]`); the [`Quantizer`] is
//! monotone, so quantizing the clamp endpoints brackets every derivable
//! tile.

use crate::arch::ParamRanges;
use crate::env::action::DecodedAction;
use crate::node::NodeSpec;
use crate::ppa::TM_FP16_LANES;
use crate::rl::pareto::ParetoPoint;

/// Optimistic PPA envelope for one decoded candidate: throughput/perf
/// are upper bounds, power/area are lower bounds.
#[derive(Debug, Clone, Copy)]
pub struct RooflineBound {
    pub tokens_per_s: f64,
    pub perf_gops: f64,
    pub power_mw: f64,
    pub area_mm2: f64,
}

impl RooflineBound {
    /// Optimistic energy-per-token floor in mJ: the power floor over the
    /// throughput roof. Every achievable design spends at least its power
    /// floor to emit at most its token roof, so `power_lb / tokens_ub ≤
    /// power / tokens` for any full evaluation this envelope brackets.
    pub fn energy_lb_mj_per_token(&self) -> f64 {
        if self.tokens_per_s <= 0.0 {
            f64::INFINITY
        } else {
            self.power_mw / self.tokens_per_s
        }
    }

    /// Envelope-vs-frontier dominance: does the *achieved* point `p`
    /// dominate this entire optimistic envelope in (perf ↑, mJ/token ↓,
    /// area ↓) space? Every design the envelope brackets has perf ≤
    /// `perf_gops`, energy/token ≥ [`Self::energy_lb_mj_per_token`] and
    /// area ≥ `area_mm2`, so when `p` beats all three bounds it dominates
    /// every achievable point of the bracketed scenario — the whole point
    /// can be skipped without losing anything from a merged frontier
    /// (atlas fast path, DESIGN.md §12).
    pub fn dominated_by(&self, p: &ParetoPoint) -> bool {
        p.perf_gops >= self.perf_gops
            && p.energy_mj_per_token() <= self.energy_lb_mj_per_token()
            && p.area_mm2 <= self.area_mm2
    }

    /// Envelope-vs-envelope weak dominance in (perf ↑, mJ/token ↓, area
    /// ↓) space: `self`'s regime is uniformly at least as favorable as
    /// `other`'s — a higher (or equal) throughput roof with lower (or
    /// equal) energy and area floors. Combined with an identical unit
    /// graph and component-wise smaller per-token traffic this is the
    /// O(1) roofline confirmation behind the atlas's amortization
    /// pruning (DESIGN.md §12).
    pub fn dominates_envelope(&self, other: &RooflineBound) -> bool {
        self.perf_gops >= other.perf_gops
            && self.energy_lb_mj_per_token() <= other.energy_lb_mj_per_token()
            && self.area_mm2 <= other.area_mm2
    }
}

/// Compute the O(1) roofline envelope. `kv_traffic_per_token` is the
/// compacted KV read traffic (Eq 33) for the decoded KV strategy;
/// `weight_bytes` (resident footprint: ROM power/area) and
/// `weight_traffic_per_token` (the scenario-amortized Eq 22 weight
/// sweep, ≤ `weight_bytes`) plus `flops_per_token` are the workload
/// invariants the evaluator hoists. Keeping the traffic term identical
/// to the one the full pipeline uses preserves admissibility under the
/// scenario axis (prefill/batch amortization).
pub fn roofline_bound(
    d: &DecodedAction,
    n: &NodeSpec,
    ranges: &ParamRanges,
    weight_bytes: f64,
    weight_traffic_per_token: f64,
    flops_per_token: f64,
    kv_traffic_per_token: f64,
) -> RooflineBound {
    let cores = d.mesh.cores() as f64;
    let f_hz = d.avg.clock_mhz * 1e6;

    // §3.3 derivation brackets (see module doc).
    let vlen_ub = ranges.vlen_bits.quantize(d.avg.vlen_bits as f64 * 2.0) as f64;
    let vlen_lb = ranges.vlen_bits.quantize(d.avg.vlen_bits as f64 * 0.5) as f64;
    let lanes_ub = vlen_ub / 16.0;
    let lanes_lb = vlen_lb / 16.0;

    // ---- throughput upper bound ----
    // Eq 21 with η_∥ = 1 and every tile at the maximum derivable lane
    // count (capped by TM_FP16 as in the real ceiling).
    let compute_ub = cores * lanes_ub.min(TM_FP16_LANES) * 2.0 * f_hz * d.alpha_spec
        / flops_per_token.max(1.0);
    // Eq 22 with maximum per-tile bandwidth over the minimum possible
    // per-token traffic (cross-tile activation bytes ≥ 0).
    let mem_floor = (weight_traffic_per_token + kv_traffic_per_token).max(1.0);
    let memory_ub = cores * 2.0 * (vlen_ub / 8.0) * f_hz / mem_floor;
    // Eq 23 optimistically unbounded (bisection traffic could be zero).
    let tokens_ub = compute_ub.min(memory_ub);
    let perf_ub = tokens_ub * flops_per_token / 1e9;

    // ---- power lower bound (Eq 62 floor) ----
    // compute switching at the minimum derivable lane count; the draft
    // predictor overhead is exact (α_spec is decoded, not derived)
    let draft_overhead = 1.0 + 0.15 * (d.alpha_spec - 1.0) / 0.6;
    let compute_lb = cores
        * lanes_lb
        * f_hz
        * n.mac_energy_pj
        * 1e-12
        * d.activity
        * 1e3
        * draft_overhead;
    // SRAM-dynamic and ROM-read are exact: they depend only on cores,
    // clock, activity and the (fixed) weight footprint
    let sram_dyn =
        cores * (d.avg.clock_mhz / 1000.0) * n.sram_dyn_mw_per_core_ghz * d.activity;
    let weight_mb = weight_bytes / (1024.0 * 1024.0);
    let rom_read = weight_mb
        * n.rom_read_mw_per_mb_at_fmax
        * (d.avg.clock_mhz / n.fmax_mhz)
        * d.activity;
    // leakage at the minimum derivable per-tile SRAM; NoC power ≥ 0
    let dmem_lb = ranges.dmem_kb.quantize_up(d.avg.dmem_kb as f64 * 0.5) as f64;
    let imem_lb = ranges.imem_kb.quantize(d.avg.imem_kb as f64 * 0.25) as f64;
    let sram_mb_lb = cores * (dmem_lb + imem_lb) / 1024.0;
    let leak_lb = sram_mb_lb * n.sram_leak_mw_per_mb;
    let power_lb = compute_lb + sram_dyn + rom_read + leak_lb;

    // ---- area lower bound (Eq 64 floor: minimum lanes/SRAM, exact ROM)
    let area_lb =
        cores * n.core_logic_mm2(lanes_lb) + n.rom_mm2(weight_mb) + n.sram_mm2(sram_mb_lb);

    RooflineBound {
        tokens_per_s: tokens_ub,
        perf_gops: perf_ub,
        power_mw: power_lb,
        area_mm2: area_lb,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::MeshConfig;
    use crate::config::ModeConfig;
    use crate::env::action::{self, Action};
    use crate::kv::KvStrategy;
    use crate::node::NodeTable;

    fn decode_at(mesh: MeshConfig, a: &Action, nm: u32) -> DecodedAction {
        let table = NodeTable::paper();
        action::decode(
            a,
            &mesh,
            table.get(nm).unwrap(),
            &ModeConfig::high_performance(),
            &ParamRanges::paper(),
            KvStrategy::Full,
            2048,
        )
    }

    #[test]
    fn bound_components_are_finite_and_positive() {
        let d = decode_at(MeshConfig::new(16, 16), &Action::neutral(), 3);
        let t = NodeTable::paper();
        let w = 14.96 * (1u64 << 30) as f64;
        let b = roofline_bound(
            &d,
            t.get(3).unwrap(),
            &ParamRanges::paper(),
            w,
            w,
            2.0 * 8.03e9,
            131_072.0,
        );
        assert!(b.tokens_per_s.is_finite() && b.tokens_per_s > 0.0);
        assert!(b.perf_gops.is_finite() && b.perf_gops > 0.0);
        assert!(b.power_mw.is_finite() && b.power_mw > 0.0);
        assert!(b.area_mm2.is_finite() && b.area_mm2 > 0.0);
    }

    #[test]
    fn amortized_weight_traffic_raises_memory_roof_only() {
        // scenario amortization (batch/prefill) relieves the Eq 22 term
        // but leaves the residency-driven power/area floors untouched
        let d = decode_at(MeshConfig::new(8, 8), &Action::neutral(), 7);
        let t = NodeTable::paper();
        let n = t.get(7).unwrap();
        let r = ParamRanges::paper();
        let w = 2e9;
        let full = roofline_bound(&d, n, &r, w, w, 1e9, 0.0);
        let amort = roofline_bound(&d, n, &r, w, w / 3.0, 1e9, 0.0);
        assert!(amort.tokens_per_s >= full.tokens_per_s);
        assert_eq!(amort.power_mw.to_bits(), full.power_mw.to_bits());
        assert_eq!(amort.area_mm2.to_bits(), full.area_mm2.to_bits());
    }

    fn frontier_point(perf: f64, power: f64, area: f64, tokens: f64) -> ParetoPoint {
        ParetoPoint {
            perf_gops: perf,
            power_mw: power,
            area_mm2: area,
            tokens_per_s: tokens,
            episode: 0,
            tag: 0,
        }
    }

    #[test]
    fn envelope_dominated_only_by_points_beating_every_bound() {
        let env = RooflineBound {
            tokens_per_s: 100.0,
            perf_gops: 200.0,
            power_mw: 50.0,
            area_mm2: 10.0,
        };
        // env floor: 50 mW / 100 tok/s = 0.5 mJ/token
        assert!((env.energy_lb_mj_per_token() - 0.5).abs() < 1e-12);
        // beats perf roof, energy floor and area floor → dominates all
        let strong = frontier_point(250.0, 40.0, 9.0, 400.0); // 0.1 mJ/tok
        assert!(env.dominated_by(&strong));
        // perf short of the roof → some bracketed design might still win
        let slow = frontier_point(150.0, 40.0, 9.0, 400.0);
        assert!(!env.dominated_by(&slow));
        // above the energy floor → a frugal bracketed design might win
        let hungry = frontier_point(250.0, 400.0, 9.0, 400.0); // 1.0 mJ/tok
        assert!(!env.dominated_by(&hungry));
        // above the area floor → a compact bracketed design might win
        let big = frontier_point(250.0, 40.0, 11.0, 400.0);
        assert!(!env.dominated_by(&big));
    }

    #[test]
    fn envelope_vs_envelope_tracks_amortization() {
        let t = NodeTable::paper();
        let n = t.get(7).unwrap();
        let r = ParamRanges::paper();
        let d = decode_at(MeshConfig::new(8, 8), &Action::neutral(), 7);
        let w = 2e9;
        // batch amortization relieves the weight sweep only: the roof
        // rises (or holds) while the power/area floors stay fixed, so the
        // amortized envelope weakly dominates the unamortized one
        let b1 = roofline_bound(&d, n, &r, w, w, 1e9, 0.0);
        let b4 = roofline_bound(&d, n, &r, w, w / 4.0, 1e9, 0.0);
        assert!(b4.dominates_envelope(&b1));
        assert!(b4.dominates_envelope(&b4), "weak dominance admits the exact tie");
        // the harder regime never dominates the easier one unless tied
        if b4.tokens_per_s > b1.tokens_per_s {
            assert!(!b1.dominates_envelope(&b4));
        }
    }

    #[test]
    fn bound_scales_with_mesh() {
        // more cores: higher throughput roof, higher power/area floor
        let t = NodeTable::paper();
        let n = t.get(7).unwrap();
        let r = ParamRanges::paper();
        let w = 1e9;
        let small = decode_at(MeshConfig::new(4, 4), &Action::neutral(), 7);
        let big = decode_at(MeshConfig::new(16, 16), &Action::neutral(), 7);
        let bs = roofline_bound(&small, n, &r, w, w, 1e9, 0.0);
        let bb = roofline_bound(&big, n, &r, w, w, 1e9, 0.0);
        assert!(bb.tokens_per_s > bs.tokens_per_s);
        assert!(bb.power_mw > bs.power_mw);
        assert!(bb.area_mm2 > bs.area_mm2);
    }
}
