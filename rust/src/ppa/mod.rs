//! Analytical PPA models (§3.8, §3.15): power (Eq 62, Table 12
//! decomposition), performance (Eq 63), area (Eq 64), throughput ceilings
//! (Eqs 21–24), node-level efficiency ratios (Eqs 75–77) and the
//! normalized PPA score.
//!
//! All constants live in [`crate::node::NodeTable`]; this module is pure
//! arithmetic over a [`DesignPoint`] so evaluation is allocation-free on
//! the episode hot path.

pub mod area;
pub mod efficiency;
pub mod power;
pub mod roofline;
pub mod score;
pub mod throughput;

use crate::arch::{MeshConfig, TileConfig};
use crate::node::NodeSpec;
use crate::noc::TrafficStats;

pub use area::AreaBreakdown;
pub use power::PowerBreakdown;
pub use roofline::{roofline_bound, RooflineBound};
pub use score::{NormRanges, PpaWeights};
pub use throughput::Ceilings;

/// Everything the analytical models need about one candidate design.
/// Assembled by the environment after partitioning + hetero derivation.
#[derive(Debug, Clone)]
pub struct DesignPoint {
    pub mesh: MeshConfig,
    pub clock_mhz: f64,
    pub dflit_bits: u32,
    /// Per-tile derived configurations (lanes, memories).
    pub sum_lanes: f64,
    /// Σ min(TM_FP16, VLEN_i/16) — effective tensor-multiplier lanes
    /// (Eq 21's M_i already capped).
    pub sum_lanes_capped: f64,
    /// Total SRAM (DMEM+IMEM) across tiles, MB.
    pub sram_mb: f64,
    /// Total weight bytes resident in ROM.
    pub weight_bytes: f64,
    /// Per-token NoC traffic from placement.
    pub traffic: TrafficStats,
    /// Parallel efficiency η_∥ from load balance (Eq 21).
    pub eta_parallel: f64,
    /// Pipeline utilization η_util (Eq 63) from workload/memory pressure.
    pub eta_util: f64,
    /// Speculative-decoding acceleration α_spec ∈ [1, 2] (§3.8).
    pub alpha_spec: f64,
    /// FLOPs per generated token (2·P·φ_decode).
    pub flops_per_token: f64,
    /// Memory bytes touched per token after KV compaction (Eq 33).
    pub mem_bytes_per_token: f64,
    /// Aggregate effective memory bandwidth Σ BW_eff,i (bytes/s, Eq 16).
    pub sum_bw_eff: f64,
    /// Activity factor for compute/SRAM dynamics in [0,1] (1 = streaming
    /// at full rate; low-power mode runs well below).
    pub activity: f64,
}

/// Tensor-multiplier cap TM_FP16 of Eq 21 (lanes per TCC the MXU-like
/// datapath can feed).
pub const TM_FP16_LANES: f64 = 128.0;

impl DesignPoint {
    /// Convenience constructor computing the lane sums from tiles.
    pub fn lane_sums(tiles: &[TileConfig]) -> (f64, f64) {
        let mut sum = 0.0;
        let mut capped = 0.0;
        for t in tiles {
            let l = t.lanes();
            sum += l;
            capped += l.min(TM_FP16_LANES);
        }
        (sum, capped)
    }
}

/// Full evaluation result for one design point.
#[derive(Debug, Clone)]
pub struct PpaResult {
    pub power: PowerBreakdown,
    pub area: AreaBreakdown,
    pub ceilings: Ceilings,
    /// Realized tokens/s (Eq 24: min of the three ceilings).
    pub tokens_per_s: f64,
    /// Performance in GOps/s (Eq 63 realized).
    pub perf_gops: f64,
}

/// Evaluate the analytical models for `d` on node `n`.
pub fn evaluate(d: &DesignPoint, n: &NodeSpec) -> PpaResult {
    let ceilings = throughput::ceilings(d, n);
    let tokens_per_s = ceilings.realized();
    // realized ops/s = tokens/s × FLOPs/token (counting FP16 MACs as the
    // paper does: "GOps/s, counting FP16 multiply-accumulate operations")
    let perf_gops = tokens_per_s * d.flops_per_token / 1e9;
    let power = power::evaluate(d, n, tokens_per_s);
    let area = area::evaluate(d, n);
    PpaResult { power, area, ceilings, tokens_per_s, perf_gops }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::node::NodeTable;

    /// A design point shaped like the paper's 3nm optimum (41×42 mesh,
    /// ~96 mean lanes) — the calibration anchor for Tables 10–12.
    pub(crate) fn paper_3nm_point() -> DesignPoint {
        let mesh = MeshConfig::new(41, 42);
        let cores = mesh.cores() as f64;
        let lanes = 96.45;
        // cross-tile traffic ~ 2·n_L·d_model·2B·sqrt(N) (DESIGN.md §6)
        let cross = 2.0 * 32.0 * 4096.0 * 2.0 * cores.sqrt();
        let traffic = TrafficStats {
            cross_tile_bytes: cross,
            byte_hops: cross * mesh.mean_hops(),
            bisection_bytes: cross * 0.3,
            n_transfers: 7489,
        };
        DesignPoint {
            mesh,
            clock_mhz: 1000.0,
            dflit_bits: 2048,
            sum_lanes: cores * lanes,
            sum_lanes_capped: cores * lanes,
            sram_mb: cores * 0.0685, // 64 KB DMEM + 6.1 KB IMEM per tile
            weight_bytes: 14.96 * (1u64 << 30) as f64,
            traffic,
            eta_parallel: 0.90,
            eta_util: 0.92,
            alpha_spec: 1.56,
            flops_per_token: 2.0 * 8.03e9 * 0.97,
            mem_bytes_per_token: 14.96 * (1u64 << 30) as f64 + 131_072.0,
            sum_bw_eff: cores * 2.0 * 96.0 * 2.0 * 1e9, // 2 ROM ports x vlen
            activity: 1.0,
        }
    }

    #[test]
    fn calibration_3nm_tokens_within_2pct_of_paper() {
        let t = NodeTable::paper();
        let r = evaluate(&paper_3nm_point(), t.get(3).unwrap());
        let err = (r.tokens_per_s - 29_809.0).abs() / 29_809.0;
        assert!(err < 0.02, "tok/s {} (err {:.3})", r.tokens_per_s, err);
    }

    #[test]
    fn calibration_3nm_perf_within_2pct() {
        let t = NodeTable::paper();
        let r = evaluate(&paper_3nm_point(), t.get(3).unwrap());
        let err = (r.perf_gops - 466_364.0).abs() / 466_364.0;
        assert!(err < 0.02, "perf {} GOps (err {:.3})", r.perf_gops, err);
    }

    #[test]
    fn calibration_3nm_power_within_10pct_of_table12() {
        let t = NodeTable::paper();
        let r = evaluate(&paper_3nm_point(), t.get(3).unwrap());
        let total = r.power.total();
        let err = (total - 51_366.0).abs() / 51_366.0;
        assert!(err < 0.10, "power {total} mW (err {err:.3})");
        // compute share 54% +- 8pts, NoC 33% +- 8pts (Table 12)
        assert!((r.power.compute / total - 0.536).abs() < 0.08);
        assert!((r.power.noc / total - 0.333).abs() < 0.08);
    }

    #[test]
    fn calibration_3nm_area_within_10pct() {
        let t = NodeTable::paper();
        let r = evaluate(&paper_3nm_point(), t.get(3).unwrap());
        let err = (r.area.total() - 648.0).abs() / 648.0;
        assert!(err < 0.10, "area {} mm2 (err {err:.3})", r.area.total());
    }

    #[test]
    fn compute_ceiling_binds_for_llama_shape() {
        // §3.8: "the compute ceiling is the active limiter at all nodes"
        let t = NodeTable::paper();
        for n in t.nodes() {
            let mut d = paper_3nm_point();
            d.clock_mhz = n.fmax_mhz;
            let r = evaluate(&d, n);
            assert!(
                r.ceilings.compute <= r.ceilings.memory
                    && r.ceilings.compute <= r.ceilings.noc,
                "{}nm: {:?}",
                n.nm,
                r.ceilings
            );
        }
    }
}
