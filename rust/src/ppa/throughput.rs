//! Inference throughput model (§3.8): compute (Eq 21), memory (Eq 22) and
//! NoC (Eq 23) ceilings; realized tok/s is their minimum (Eq 24). The
//! scenario axis (phase/batch) enters through
//! [`weight_traffic_per_token`], which amortizes the Eq 22 weight sweep.

use crate::ir::spec::Phase;
use crate::node::NodeSpec;

use super::DesignPoint;

/// Per-processed-token weight read traffic for a scenario (the weight
/// term of Eq 22's Bytes_per_token):
///
/// * **decode** — one weight sweep serves the `batch` concurrent
///   sequences' next tokens, so per-token traffic is W / batch;
/// * **prefill** — the prompt is processed in one weight-stationary
///   pass, so the sweep amortizes across all `batch × seq_len` prompt
///   tokens (the idealized chunked-prefill limit).
///
/// The resident footprint (ROM read power, Eq 64 area) stays the full
/// `weight_bytes` either way — only the traffic amortizes.
pub fn weight_traffic_per_token(
    weight_bytes: f64,
    phase: Phase,
    seq_len: u32,
    batch: u32,
) -> f64 {
    let tokens_per_sweep = match phase {
        Phase::Decode => batch.max(1) as f64,
        Phase::Prefill => batch.max(1) as f64 * seq_len.max(1) as f64,
    };
    weight_bytes / tokens_per_sweep
}

/// The three throughput ceilings in tokens/s.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Ceilings {
    pub compute: f64,
    pub memory: f64,
    pub noc: f64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Binding {
    Compute,
    Memory,
    Noc,
}

impl Ceilings {
    /// Eq 24: realized throughput.
    pub fn realized(&self) -> f64 {
        self.compute.min(self.memory).min(self.noc)
    }

    /// Which constraint binds (§4.3 "ceiling analysis").
    pub fn binding(&self) -> Binding {
        if self.compute <= self.memory && self.compute <= self.noc {
            Binding::Compute
        } else if self.memory <= self.noc {
            Binding::Memory
        } else {
            Binding::Noc
        }
    }
}

pub fn ceilings(d: &DesignPoint, _n: &NodeSpec) -> Ceilings {
    let f_hz = d.clock_mhz * 1e6;

    // Eq 21: Tok/s_comp = Σ M_i · 2 · f · η_par · α_spec / FLOPs_per_token
    // (η_util belongs to the Eq 63 surrogate, not the realized ceiling)
    let compute = d.sum_lanes_capped * 2.0 * f_hz * d.eta_parallel * d.alpha_spec
        / d.flops_per_token.max(1.0);

    // Eq 22: Tok/s_mem = Σ BW_eff,i / Bytes_per_token
    let memory = d.sum_bw_eff / d.mem_bytes_per_token.max(1.0);

    // Eq 23: Tok/s_NoC = BW_bisect / CrossTileBytes_bisection_per_token
    let links = d.mesh.width.min(d.mesh.height) as f64;
    let bw_bisect = links * (d.dflit_bits as f64 / 8.0) * f_hz;
    let noc = if d.traffic.bisection_bytes > 0.0 {
        bw_bisect / d.traffic.bisection_bytes
    } else {
        f64::INFINITY
    };

    Ceilings { compute, memory, noc }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::node::NodeTable;
    use crate::ppa::tests::paper_3nm_point;

    #[test]
    fn binding_constraint_detection() {
        let c = Ceilings { compute: 100.0, memory: 200.0, noc: 300.0 };
        assert_eq!(c.binding(), Binding::Compute);
        assert_eq!(c.realized(), 100.0);
        let c2 = Ceilings { compute: 300.0, memory: 200.0, noc: 250.0 };
        assert_eq!(c2.binding(), Binding::Memory);
        let c3 = Ceilings { compute: 300.0, memory: 200.0, noc: 150.0 };
        assert_eq!(c3.binding(), Binding::Noc);
    }

    #[test]
    fn compute_ceiling_linear_in_clock() {
        let t = NodeTable::paper();
        let n = t.get(3).unwrap();
        let mut d = paper_3nm_point();
        let c1 = ceilings(&d, n).compute;
        d.clock_mhz /= 2.0;
        let c2 = ceilings(&d, n).compute;
        assert!((c1 / c2 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn spec_decoding_multiplies_compute_ceiling() {
        let t = NodeTable::paper();
        let n = t.get(3).unwrap();
        let mut d = paper_3nm_point();
        d.alpha_spec = 1.0;
        let base = ceilings(&d, n).compute;
        d.alpha_spec = 2.0;
        assert!((ceilings(&d, n).compute / base - 2.0).abs() < 1e-9);
    }

    #[test]
    fn kv_compaction_raises_memory_ceiling() {
        let t = NodeTable::paper();
        let n = t.get(3).unwrap();
        let mut d = paper_3nm_point();
        let m1 = ceilings(&d, n).memory;
        d.mem_bytes_per_token *= 0.5; // Eq 33 relief
        let m2 = ceilings(&d, n).memory;
        assert!((m2 / m1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn weight_traffic_amortizes_with_batch_and_prefill() {
        let w = 16e9;
        assert_eq!(weight_traffic_per_token(w, Phase::Decode, 2048, 1), w);
        assert_eq!(weight_traffic_per_token(w, Phase::Decode, 2048, 4), w / 4.0);
        assert_eq!(
            weight_traffic_per_token(w, Phase::Prefill, 2048, 1),
            w / 2048.0
        );
        assert_eq!(
            weight_traffic_per_token(w, Phase::Prefill, 2048, 2),
            w / 4096.0
        );
        // degenerate zeros clamp to one token per sweep
        assert_eq!(weight_traffic_per_token(w, Phase::Decode, 2048, 0), w);
    }

    #[test]
    fn zero_bisection_traffic_means_unbounded_noc() {
        let t = NodeTable::paper();
        let mut d = paper_3nm_point();
        d.traffic.bisection_bytes = 0.0;
        assert!(ceilings(&d, t.get(3).unwrap()).noc.is_infinite());
    }
}
