//! Normalized PPA score (§3.10, Table 4 conventions).
//!
//! "PPA scores use a lower-is-better convention (cost function), where 0
//! is ideal and values approaching 1.0 indicate larger power/area or
//! lower performance" (Table 12 note). The score scalarizes normalized
//! metrics with the user PPA weights (Eqs 42–44):
//!
//!   score = α·(1 − P_norm) + β·P_power + γ·A_norm
//!
//! Normalization ranges "are derived from process node characteristics
//! and constraints" — i.e. per-node budgets, not global extremes.



use crate::util::clip;

/// User PPA weights (w_perf, w_power, w_area); Eqs 42–44 normalize them.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PpaWeights {
    pub perf: f64,
    pub power: f64,
    pub area: f64,
}

impl PpaWeights {
    /// Paper's high-performance profile (§3.13).
    pub const HIGH_PERF: PpaWeights = PpaWeights { perf: 0.4, power: 0.4, area: 0.2 };
    /// Paper's low-power profile (§5.4).
    pub const LOW_POWER: PpaWeights = PpaWeights { perf: 0.2, power: 0.6, area: 0.2 };

    /// Eqs 42–44: (α, β, γ).
    pub fn normalized(&self) -> (f64, f64, f64) {
        let s = self.perf + self.power + self.area;
        (self.perf / s, self.power / s, self.area / s)
    }

    /// Eq 48: ∂R/∂w_perf sensitivity at the current weights.
    pub fn perf_sensitivity(&self, p_norm: f64) -> f64 {
        let s = self.perf + self.power + self.area;
        p_norm * (self.power + self.area) / (s * s)
    }
}

/// Per-node normalization ranges (Eqs 35–37 denominators). Derived from
/// the node's constraint budgets (§3.10 "derived from process node
/// characteristics and constraints").
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NormRanges {
    pub perf_min: f64,
    pub perf_max: f64,
    pub power_min: f64,
    pub power_max: f64,
    pub area_min: f64,
    pub area_max: f64,
}

impl NormRanges {
    /// Normalized metrics (P_norm, P_power, A_norm), each clipped to [0,1].
    pub fn normalize(&self, perf: f64, power: f64, area: f64) -> (f64, f64, f64) {
        let nz = |v: f64, lo: f64, hi: f64| clip((v - lo) / (hi - lo).max(1e-12), 0.0, 1.0);
        (
            nz(perf, self.perf_min, self.perf_max),
            nz(power, self.power_min, self.power_max),
            nz(area, self.area_min, self.area_max),
        )
    }
}

/// Lower-is-better composite PPA score.
pub fn ppa_score(
    weights: &PpaWeights,
    ranges: &NormRanges,
    perf: f64,
    power: f64,
    area: f64,
) -> f64 {
    let (alpha, beta, gamma) = weights.normalized();
    let (p_norm, p_pow, a_norm) = ranges.normalize(perf, power, area);
    alpha * (1.0 - p_norm) + beta * p_pow + gamma * a_norm
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ranges() -> NormRanges {
        NormRanges {
            perf_min: 0.0,
            perf_max: 100.0,
            power_min: 0.0,
            power_max: 50.0,
            area_min: 0.0,
            area_max: 1000.0,
        }
    }

    #[test]
    fn weights_normalize_to_unit_sum() {
        let (a, b, g) = PpaWeights::HIGH_PERF.normalized();
        assert!((a + b + g - 1.0).abs() < 1e-12);
        assert!((a - 0.4).abs() < 1e-12 && (b - 0.4).abs() < 1e-12 && (g - 0.2).abs() < 1e-12);
    }

    #[test]
    fn ideal_design_scores_zero() {
        // max perf, zero power, zero area -> score 0 (ideal)
        let s = ppa_score(&PpaWeights::HIGH_PERF, &ranges(), 100.0, 0.0, 0.0);
        assert!(s.abs() < 1e-12);
    }

    #[test]
    fn worst_design_scores_one() {
        let s = ppa_score(&PpaWeights::HIGH_PERF, &ranges(), 0.0, 50.0, 1000.0);
        assert!((s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn better_perf_lowers_score() {
        let w = PpaWeights::HIGH_PERF;
        let lo = ppa_score(&w, &ranges(), 20.0, 25.0, 500.0);
        let hi = ppa_score(&w, &ranges(), 80.0, 25.0, 500.0);
        assert!(hi < lo);
    }

    #[test]
    fn normalization_clips_outside_range() {
        let (p, pw, a) = ranges().normalize(1e9, -5.0, 2e6);
        assert_eq!((p, pw, a), (1.0, 0.0, 1.0));
    }

    #[test]
    fn sensitivity_eq48_positive_when_perf_nonzero() {
        let w = PpaWeights::HIGH_PERF;
        assert!(w.perf_sensitivity(0.5) > 0.0);
        assert_eq!(w.perf_sensitivity(0.0), 0.0);
    }

    #[test]
    fn low_power_profile_weights_power_more() {
        let (_, b_hp, _) = PpaWeights::HIGH_PERF.normalized();
        let (_, b_lp, _) = PpaWeights::LOW_POWER.normalized();
        assert!(b_lp > b_hp);
    }
}
