//! Node-level efficiency ratios (Eqs 75–77, Table 18 / Fig 7).

/// Eq 75: GOps/s per mW.
pub fn perf_per_power(perf_gops: f64, power_mw: f64) -> f64 {
    perf_gops / power_mw.max(1e-12)
}

/// Eq 76: tok/s per mW.
pub fn tok_per_power(tokens_per_s: f64, power_mw: f64) -> f64 {
    tokens_per_s / power_mw.max(1e-12)
}

/// Eq 77: GOps/s per mm².
pub fn perf_per_area(perf_gops: f64, area_mm2: f64) -> f64 {
    perf_gops / area_mm2.max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table18_3nm_row() {
        // 466,364 GOps / 51,366 mW = 9.078 GOps/mW; 29,809/51,366 = 0.5803
        assert!((perf_per_power(466_364.0, 51_366.0) - 9.078).abs() < 0.01);
        assert!((tok_per_power(29_809.0, 51_366.0) - 0.5803).abs() < 0.001);
        assert!((perf_per_area(466_364.0, 648.0) - 719.7).abs() < 0.5);
    }

    #[test]
    fn guards_against_zero_denominators() {
        assert!(perf_per_power(1.0, 0.0).is_finite());
        assert!(tok_per_power(1.0, 0.0).is_finite());
        assert!(perf_per_area(1.0, 0.0).is_finite());
    }
}
