//! Silicon area model (Eq 64): per-core logic + weight ROM + SRAM, all
//! scaled by the node density factor A_scale(n).

use crate::node::NodeSpec;

use super::DesignPoint;

/// Area components in mm² (Eq 64 terms).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct AreaBreakdown {
    pub logic: f64,
    pub rom: f64,
    pub sram: f64,
}

impl AreaBreakdown {
    pub fn total(&self) -> f64 {
        self.logic + self.rom + self.sram
    }
}

pub fn evaluate(d: &DesignPoint, n: &NodeSpec) -> AreaBreakdown {
    let cores = d.mesh.cores() as f64;
    let mean_lanes = if cores > 0.0 { d.sum_lanes / cores } else { 0.0 };
    let logic = cores * n.core_logic_mm2(mean_lanes);
    let rom = n.rom_mm2(d.weight_bytes / (1024.0 * 1024.0));
    let sram = n.sram_mm2(d.sram_mb);
    AreaBreakdown { logic, rom, sram }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::node::NodeTable;
    use crate::ppa::tests::paper_3nm_point;

    #[test]
    fn area_grows_with_node_size_for_same_design() {
        // Table 10: same weights on an older node cost far more area
        let t = NodeTable::paper();
        let d = paper_3nm_point();
        let a3 = evaluate(&d, t.get(3).unwrap()).total();
        let a28 = evaluate(&d, t.get(28).unwrap()).total();
        assert!(a28 > 5.0 * a3, "{a3} vs {a28}");
    }

    #[test]
    fn rom_dominates_at_28nm_for_llama() {
        // the paper's actual 28nm design: 11x12 mesh, 132 cores — ROM is
        // the dominant area term (Table 10: 3,545 mm² total)
        let t = NodeTable::paper();
        let mut d = paper_3nm_point();
        d.mesh = crate::arch::MeshConfig::new(11, 12);
        d.sum_lanes = 132.0 * 105.0;
        d.sum_lanes_capped = d.sum_lanes;
        d.sram_mb = 132.0 * 0.0685;
        let a = evaluate(&d, t.get(28).unwrap());
        assert!(a.rom / a.total() > 0.6, "rom share {}", a.rom / a.total());
        let err = (a.total() - 3545.0) / 3545.0;
        assert!(err.abs() < 0.10, "area {} mm2", a.total());
    }

    #[test]
    fn components_nonnegative() {
        let t = NodeTable::paper();
        let a = evaluate(&paper_3nm_point(), t.get(10).unwrap());
        assert!(a.logic > 0.0 && a.rom > 0.0 && a.sram > 0.0);
    }
}
