//! Llama 3.1 8B Instruct FP16 — the paper's headline workload, now a
//! declarative spec instance ([`crate::ir::registry::LLAMA31_8B`]) of the
//! generic builder in [`crate::ir::spec`].
//!
//! The spec reproduces the paper's Table 8/9 statistics exactly:
//! * 7,489 unified graph operators (32 decoder layers × 233 ops + 33
//!   global ops — the fine-grained ONNX decomposition where every
//!   norm/rope/softmax is a chain of micro-ops plus shape plumbing),
//! * 291 weight tensors (32 × 9 per-layer + embed + final-norm + lm_head),
//! * 14.96 GB FP16 weights / 8.03 B parameters,
//! * 66 graph inputs / 65 outputs (KV-cache in/out per layer + ids/mask),
//! * 597 M total instructions,
//! * GQA: 32 query heads, 8 KV heads, head_dim 128 (Eq 25 ⇒ 128 KB/token).
//!
//! These pins are enforced by the golden suite in `tests/workloads.rs`
//! and the tests below.

use super::registry;
use super::{Graph, WorkloadSpec};

/// Llama 3.1 8B architecture constants (mirrors the registry spec).
pub const N_LAYERS: u32 = 32;
pub const D_MODEL: u64 = 4096;
pub const N_HEADS: u64 = 32;
pub const N_KV_HEADS: u64 = 8;
pub const HEAD_DIM: u64 = 128;
pub const D_FFN: u64 = 14336;
pub const VOCAB: u64 = 128_256;
/// Default evaluation sequence length (§4.1).
pub const SEQ_LEN: u64 = 2048;
/// Paper-reported totals the spec-built graph must reproduce.
pub const PAPER_OPS: usize = 7489;
pub const PAPER_WEIGHT_TENSORS: usize = 291;
pub const PAPER_INSTRS: f64 = 597e6;

/// The registered spec.
pub fn spec() -> &'static WorkloadSpec {
    &registry::LLAMA31_8B
}

/// Build the Llama 3.1 8B decode-step graph at the paper's default
/// scenario (decode, 2,048-token context).
pub fn build() -> Graph {
    spec().build_default()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::OpKind;

    #[test]
    fn spec_constants_match_module_constants() {
        let s = spec();
        assert_eq!(s.dims.n_layers, N_LAYERS);
        assert_eq!(s.dims.d_model, D_MODEL);
        assert_eq!(s.dims.n_heads, N_HEADS);
        assert_eq!(s.dims.n_kv_heads, N_KV_HEADS);
        assert_eq!(s.dims.head_dim, HEAD_DIM);
        assert_eq!(s.dims.d_ffn, D_FFN);
        assert_eq!(s.dims.vocab, VOCAB);
        assert_eq!(s.default_seq_len as u64, SEQ_LEN);
    }

    #[test]
    fn op_count_matches_table8() {
        assert_eq!(build().ops.len(), PAPER_OPS);
    }

    #[test]
    fn weights_match_table8() {
        let g = build();
        let gb = g.total_weight_bytes() / (1u64 << 30) as f64;
        assert!((gb - 14.96).abs() < 0.05, "weights {gb} GiB");
        let params_b = g.params / 1e9;
        assert!((params_b - 8.03).abs() < 0.03, "params {params_b}B");
        assert_eq!(g.weight_tensors, PAPER_WEIGHT_TENSORS);
    }

    #[test]
    fn instrs_match_table9() {
        let g = build();
        assert!((g.total_instrs() - PAPER_INSTRS).abs() / PAPER_INSTRS < 1e-6);
    }

    #[test]
    fn kv_bytes_per_token_is_128kb() {
        // Eq 25: 2 * 32 * 8 * 128 * 2 = 128 KB
        let g = build();
        let kv = g.kv.unwrap();
        let per_tok = 2.0
            * kv.n_layers as f64
            * kv.n_kv_heads as f64
            * kv.head_dim as f64
            * kv.elem_bytes as f64;
        assert_eq!(per_tok, 131072.0);
    }

    #[test]
    fn graph_is_valid_dag() {
        build().validate().unwrap();
    }

    #[test]
    fn flops_per_token_near_2p_phi() {
        let g = build();
        // graph-summed decode FLOPs within 10% of the 2·P·φ model (§3.8)
        let model = g.flops_per_token_model();
        let summed = g.total_flops_per_token();
        assert!(
            (summed - model).abs() / model < 0.10,
            "summed {summed:.3e} vs model {model:.3e}"
        );
    }

    #[test]
    fn interface_tensors_match_table8() {
        let g = build();
        assert_eq!((g.n_inputs, g.n_outputs), (66, 65));
    }

    #[test]
    fn matmul_ops_carry_nearly_all_flops() {
        let g = build();
        let mm: f64 = g
            .ops
            .iter()
            .filter(|o| o.kind == OpKind::MatMul)
            .map(|o| o.flops)
            .sum();
        assert!(mm / g.total_flops_per_token() > 0.98);
    }
}
