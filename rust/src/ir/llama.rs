//! Synthetic Llama 3.1 8B Instruct FP16 graph generator.
//!
//! Reproduces the paper's Table 8/9 statistics exactly:
//! * 7,489 unified graph operators (32 decoder layers × 233 ops + 33
//!   global ops — the fine-grained ONNX decomposition where every
//!   norm/rope/softmax is a chain of micro-ops plus shape plumbing),
//! * 291 weight tensors (32 × 9 per-layer + embed + final-norm + lm_head),
//! * 14.96 GB FP16 weights / 8.03 B parameters,
//! * 66 graph inputs / 65 outputs (KV-cache in/out per layer + ids/mask),
//! * 597 M total instructions,
//! * GQA: 32 query heads, 8 KV heads, head_dim 128 (Eq 25 ⇒ 128 KB/token).

use super::{Graph, KvConfig, Op, OpId, OpKind};

/// Llama 3.1 8B architecture constants.
pub const N_LAYERS: u32 = 32;
pub const D_MODEL: u64 = 4096;
pub const N_HEADS: u64 = 32;
pub const N_KV_HEADS: u64 = 8;
pub const HEAD_DIM: u64 = 128;
pub const D_FFN: u64 = 14336;
pub const VOCAB: u64 = 128_256;
/// Evaluation sequence length (§4.1).
pub const SEQ_LEN: u64 = 2048;
/// Paper-reported totals this generator must reproduce.
pub const PAPER_OPS: usize = 7489;
pub const PAPER_WEIGHT_TENSORS: usize = 291;
pub const PAPER_INSTRS: f64 = 597e6;

const FP16: f64 = 2.0;

struct Builder {
    ops: Vec<Op>,
}

impl Builder {
    fn push(
        &mut self,
        kind: OpKind,
        layer: i32,
        flops: f64,
        weight_bytes: f64,
        out_bytes: f64,
        inputs: Vec<OpId>,
    ) -> OpId {
        let id = self.ops.len() as OpId;
        self.ops.push(Op {
            id,
            kind,
            layer,
            flops,
            weight_bytes,
            out_bytes,
            inputs,
            instrs: 0.0, // filled by calibrate_instrs
        });
        id
    }

    /// Chain of `n` micro-ops of `kind` threading one activation tensor.
    fn chain(&mut self, kind: OpKind, layer: i32, n: usize, bytes: f64, mut prev: OpId) -> OpId {
        for _ in 0..n {
            prev = self.push(kind, layer, bytes / FP16, 0.0, bytes, vec![prev]);
        }
        prev
    }
}

/// Build the Llama 3.1 8B decode-step graph (costs are per generated
/// token at the paper's 2,048-token evaluation context).
pub fn build() -> Graph {
    let mut b = Builder { ops: Vec::with_capacity(PAPER_OPS) };
    let d_bytes = D_MODEL as f64 * FP16; // 8 KB hidden vector
    let kv_dim = (N_KV_HEADS * HEAD_DIM) as f64; // 1024

    // ---- global prologue: token embedding gather (2 ops: ids→gather)
    let ids = b.push(OpKind::Other, -1, 0.0, 0.0, 8.0, vec![]);
    let embed_w = (VOCAB * D_MODEL) as f64 * FP16;
    let mut h = b.push(OpKind::Embed, -1, D_MODEL as f64, embed_w, d_bytes, vec![ids]);

    for layer in 0..N_LAYERS as i32 {
        h = build_layer(&mut b, layer, h, d_bytes, kv_dim);
    }

    // ---- global epilogue: final RMSNorm (7) + lm_head matmul + softmax(5)
    // + argmax/sampling plumbing — 31 ops total with the prologue's 2.
    let norm_w = D_MODEL as f64 * FP16;
    let mut x = b.chain(OpKind::Norm, -1, 6, d_bytes, h);
    x = b.push(OpKind::Norm, -1, D_MODEL as f64, norm_w, d_bytes, vec![x]);
    let head_w = (VOCAB * D_MODEL) as f64 * FP16;
    let logits_bytes = VOCAB as f64 * FP16;
    x = b.push(
        OpKind::MatMul,
        -1,
        2.0 * (VOCAB * D_MODEL) as f64,
        head_w,
        logits_bytes,
        vec![x],
    );
    x = b.chain(OpKind::Softmax, -1, 5, logits_bytes, x);
    x = b.chain(OpKind::Reduce, -1, 2, 8.0, x); // argmax + gather
    let _out = b.chain(OpKind::Other, -1, 16, 8.0, x); // sampling plumbing

    assert_eq!(b.ops.len(), PAPER_OPS, "op count drifted from Table 8");

    let mut g = Graph {
        name: "llama-3.1-8b-fp16".into(),
        ops: b.ops,
        weight_tensors: PAPER_WEIGHT_TENSORS,
        n_inputs: 66,  // ids + mask + 2 KV tensors x 32 layers
        n_outputs: 65, // logits + 2 KV tensors x 32 layers
        kv: Some(KvConfig {
            n_layers: N_LAYERS,
            n_kv_heads: N_KV_HEADS as u32,
            head_dim: HEAD_DIM as u32,
            elem_bytes: 2,
        }),
        params: 0.0,       // set below from weights
        phi_decode: 0.97,  // §3.8 (GQA models)
    };
    g.params = g.total_weight_bytes() / FP16;
    calibrate_instrs(&mut g);
    g
}

/// One decoder layer = exactly 233 operators:
///   rmsnorm(7) + qkv proj(3) + rope(2×10) + kv update(2) + attention(12)
///   + o_proj/resid(2) + rmsnorm(7) + mlp(7) = 60 semantic ops,
///   + 173 shape-infrastructure ops (the Shape/Gather/Unsqueeze/Concat
///   plumbing real ONNX exports carry for dynamic shapes).
fn build_layer(b: &mut Builder, layer: i32, h_in: OpId, d_bytes: f64, kv_dim: f64) -> OpId {
    let norm_w = D_MODEL as f64 * FP16;
    let kv_bytes = kv_dim * FP16;

    // --- input RMSNorm: 6 micro ops + weighted mul (owns the norm tensor)
    let mut x = b.chain(OpKind::Norm, layer, 6, d_bytes, h_in);
    x = b.push(OpKind::Norm, layer, D_MODEL as f64, norm_w, d_bytes, vec![x]);

    // --- Q/K/V projections (Table 2 "attention projections")
    let wq = (D_MODEL * D_MODEL) as f64 * FP16;
    let wkv = (D_MODEL as f64) * kv_dim * FP16;
    let q = b.push(OpKind::MatMul, layer, 2.0 * (D_MODEL * D_MODEL) as f64, wq, d_bytes, vec![x]);
    let k = b.push(OpKind::MatMul, layer, 2.0 * D_MODEL as f64 * kv_dim, wkv, kv_bytes, vec![x]);
    let v = b.push(OpKind::MatMul, layer, 2.0 * D_MODEL as f64 * kv_dim, wkv, kv_bytes, vec![x]);

    // --- RoPE on q and k: 10 micro-ops each (split/neg/concat/cos/sin...)
    let q = b.chain(OpKind::Rope, layer, 10, d_bytes, q);
    let k = b.chain(OpKind::Rope, layer, 10, kv_bytes, k);

    // --- KV cache append (bandwidth-only)
    let k = b.push(OpKind::KvUpdate, layer, 0.0, 0.0, kv_bytes, vec![k]);
    let v = b.push(OpKind::KvUpdate, layer, 0.0, 0.0, kv_bytes, vec![v]);

    // --- attention: scores + scale + softmax(5) + AV + 4 reshape/transpose
    let score_flops = 2.0 * (N_HEADS * HEAD_DIM) as f64 * SEQ_LEN as f64;
    let score_bytes = (N_HEADS * SEQ_LEN) as f64 * FP16;
    let s = b.push(OpKind::MatMul, layer, score_flops, 0.0, score_bytes, vec![q, k]);
    let s = b.push(OpKind::Elementwise, layer, score_bytes / FP16, 0.0, score_bytes, vec![s]);
    let s = b.chain(OpKind::Softmax, layer, 5, score_bytes, s);
    let att = b.push(OpKind::MatMul, layer, score_flops, 0.0, d_bytes, vec![s, v]);
    let att = b.chain(OpKind::Reshape, layer, 4, d_bytes, att);

    // --- output projection + residual
    let wo = (D_MODEL * D_MODEL) as f64 * FP16;
    let o = b.push(OpKind::MatMul, layer, 2.0 * (D_MODEL * D_MODEL) as f64, wo, d_bytes, vec![att]);
    let h1 = b.push(OpKind::Elementwise, layer, D_MODEL as f64, 0.0, d_bytes, vec![h_in, o]);

    // --- post-attention RMSNorm
    let mut y = b.chain(OpKind::Norm, layer, 6, d_bytes, h1);
    y = b.push(OpKind::Norm, layer, D_MODEL as f64, norm_w, d_bytes, vec![y]);

    // --- SwiGLU MLP: gate/up (2 matmul) + silu(2) + mul + down + residual
    let wff = (D_MODEL * D_FFN) as f64 * FP16;
    let ffn_bytes = D_FFN as f64 * FP16;
    let gate = b.push(OpKind::MatMul, layer, 2.0 * (D_MODEL * D_FFN) as f64, wff, ffn_bytes, vec![y]);
    let up = b.push(OpKind::MatMul, layer, 2.0 * (D_MODEL * D_FFN) as f64, wff, ffn_bytes, vec![y]);
    let silu = b.chain(OpKind::Elementwise, layer, 2, ffn_bytes, gate);
    let prod = b.push(OpKind::Elementwise, layer, D_FFN as f64, 0.0, ffn_bytes, vec![silu, up]);
    let down = b.push(OpKind::MatMul, layer, 2.0 * (D_FFN * D_MODEL) as f64, wff, d_bytes, vec![prod]);
    let h2 = b.push(OpKind::Elementwise, layer, D_MODEL as f64, 0.0, d_bytes, vec![h1, down]);

    // --- shape infrastructure: 173 near-zero-cost plumbing ops
    b.chain(OpKind::Reshape, layer, 173, 64.0, h2);
    h2
}

/// Distribute the paper's 597 M static instructions across ops:
/// proportional to FLOPs with a per-op floor (shape ops still decode).
fn calibrate_instrs(g: &mut Graph) {
    let floor = 20.0;
    let total_flops: f64 = g.ops.iter().map(|o| o.flops).sum();
    let budget = PAPER_INSTRS - floor * g.ops.len() as f64;
    for op in &mut g.ops {
        op.instrs = floor + budget * (op.flops / total_flops);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_count_matches_table8() {
        assert_eq!(build().ops.len(), 7489);
    }

    #[test]
    fn weights_match_table8() {
        let g = build();
        let gb = g.total_weight_bytes() / (1u64 << 30) as f64;
        assert!((gb - 14.96).abs() < 0.05, "weights {gb} GiB");
        let params_b = g.params / 1e9;
        assert!((params_b - 8.03).abs() < 0.03, "params {params_b}B");
        assert_eq!(g.weight_tensors, 291);
    }

    #[test]
    fn instrs_match_table9() {
        let g = build();
        assert!((g.total_instrs() - 597e6).abs() / 597e6 < 1e-6);
    }

    #[test]
    fn kv_bytes_per_token_is_128kb() {
        // Eq 25: 2 * 32 * 8 * 128 * 2 = 128 KB
        let g = build();
        let kv = g.kv.unwrap();
        let per_tok = 2.0
            * kv.n_layers as f64
            * kv.n_kv_heads as f64
            * kv.head_dim as f64
            * kv.elem_bytes as f64;
        assert_eq!(per_tok, 131072.0);
    }

    #[test]
    fn graph_is_valid_dag() {
        build().validate().unwrap();
    }

    #[test]
    fn flops_per_token_near_2p_phi() {
        let g = build();
        // graph-summed decode FLOPs within 10% of the 2·P·φ model (§3.8)
        let model = g.flops_per_token_model();
        let summed = g.total_flops_per_token();
        assert!(
            (summed - model).abs() / model < 0.10,
            "summed {summed:.3e} vs model {model:.3e}"
        );
    }

    #[test]
    fn interface_tensors_match_table8() {
        let g = build();
        assert_eq!((g.n_inputs, g.n_outputs), (66, 65));
    }

    #[test]
    fn matmul_ops_carry_nearly_all_flops() {
        let g = build();
        let mm: f64 = g
            .ops
            .iter()
            .filter(|o| o.kind == OpKind::MatMul)
            .map(|o| o.flops)
            .sum();
        assert!(mm / g.total_flops_per_token() > 0.98);
    }
}
