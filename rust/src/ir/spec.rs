//! Declarative workload layer: [`WorkloadSpec`] + the generic
//! transformer-family graph builder.
//!
//! The paper's compiler is workload-agnostic — it ingests an operator
//! graph and optimizes mesh/microarchitecture/placement for any model.
//! Instead of one hand-rolled builder per workload, a workload is a
//! declarative spec: core decoder dimensions (layers, d_model, GQA
//! heads, FFN width, vocab), the micro-op decomposition counts of the
//! ONNX-style export (norm/rope/softmax chains, shape plumbing), an
//! optional vision encoder, the epilogue shape, the KV configuration and
//! the instruction-budget model. [`build_graph`] turns any spec into the
//! fine-grained micro-op graph the partitioner consumes; the Llama 3.1
//! 8B and SmolVLM specs reproduce the former hand-rolled builders
//! op-for-op (golden-pinned by `tests/workloads.rs`).
//!
//! The builder is also parameterized on a [`Scenario`] — the inference
//! phase (prefill vs decode), context length and batch size — so the
//! same spec yields the phase-correct graph: decode attends to the full
//! context per generated token, causal prefill to the running prefix
//! ((L+1)/2 on average), and the decode-active FLOP fraction φ switches
//! between the spec's `phi_decode` and `phi_prefill`.

use super::{Graph, KvConfig, Op, OpId, OpKind};

/// FP16 bytes per element — the weight/activation precision every spec
/// is calibrated at (Table 8 footprints).
pub const FP16_BYTES: f64 = 2.0;

/// Inference phase of the scenario axis (§3.8): autoregressive decode
/// (one generated token per forward pass) or prompt prefill (the whole
/// context in one weight-stationary pass).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Phase {
    Prefill,
    #[default]
    Decode,
}

impl Phase {
    /// Parse a `phase=` config value; the error lists the valid options.
    pub fn parse(value: &str) -> Result<Phase, String> {
        match value {
            "prefill" => Ok(Phase::Prefill),
            "decode" => Ok(Phase::Decode),
            _ => Err(format!("bad phase {value}; expected prefill|decode")),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Phase::Prefill => "prefill",
            Phase::Decode => "decode",
        }
    }
}

/// One evaluation scenario: the (phase, context length, batch) point the
/// graph, KV footprint, roofline and throughput models are built for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Scenario {
    pub phase: Phase,
    pub seq_len: u32,
    /// Concurrent sequences served per step (Table 9's evaluation batch).
    pub batch: u32,
}

impl Scenario {
    /// Decode-phase scenario at batch 1.
    pub fn decode(seq_len: u32) -> Scenario {
        Scenario { phase: Phase::Decode, seq_len, batch: 1 }
    }

    /// Mean attention span per processed token: decode attends to the
    /// full context; causal prefill attends to the running prefix,
    /// (L+1)/2 tokens on average.
    pub fn attn_span(&self) -> f64 {
        match self.phase {
            Phase::Decode => self.seq_len as f64,
            Phase::Prefill => (self.seq_len as f64 + 1.0) / 2.0,
        }
    }
}

/// Workload family — selects the graph skeleton the spec instantiates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    /// Autoregressive text decoder (Llama-style).
    Decoder,
    /// Vision encoder feeding a text decoder (SmolVLM-style).
    VisionLanguage,
    /// Pure vision encoder with a classification head (ViT-style).
    VisionEncoder,
}

impl Family {
    pub fn name(self) -> &'static str {
        match self {
            Family::Decoder => "decoder",
            Family::VisionLanguage => "vision-language",
            Family::VisionEncoder => "vision-encoder",
        }
    }
}

/// Core decoder dimensions (the Table 8 architecture row). For
/// [`Family::VisionEncoder`] specs, `d_model` mirrors the vision width
/// and `vocab` is the classification head size.
#[derive(Debug, Clone, Copy)]
pub struct DecoderDims {
    pub n_layers: u32,
    pub d_model: u64,
    pub n_heads: u64,
    pub n_kv_heads: u64,
    pub head_dim: u64,
    pub d_ffn: u64,
    pub vocab: u64,
}

impl DecoderDims {
    /// Query projection width n_heads · d_head (= d_model for every
    /// registered spec).
    pub fn q_dim(&self) -> u64 {
        self.n_heads * self.head_dim
    }

    /// KV projection width n_kv_heads · d_head (GQA).
    pub fn kv_dim(&self) -> u64 {
        self.n_kv_heads * self.head_dim
    }
}

/// Micro-op decomposition counts: how the ONNX-style export shreds each
/// semantic decoder op into micro-op chains plus shape plumbing.
#[derive(Debug, Clone, Copy)]
pub struct MicroOps {
    /// Unweighted norm micro-ops per normalization site.
    pub norm_chain: usize,
    /// Whether each norm ends in a weighted (γ-owning) micro-op.
    pub norm_weighted: bool,
    /// RoPE micro-ops per rotated tensor (split/neg/concat/cos/sin...).
    pub rope: usize,
    /// Whether attention scores get an explicit scale op.
    pub attn_scale: bool,
    /// Softmax micro-ops inside attention.
    pub softmax: usize,
    /// Reshape/transpose plumbing after the attention output.
    pub attn_reshape: usize,
    /// Activation micro-ops in the gated MLP (SiLU/GELU decomposition).
    pub act_chain: usize,
    /// Near-zero-cost shape-infrastructure ops per layer (the
    /// Shape/Gather/Unsqueeze/Concat plumbing real exports carry).
    pub shape_plumbing: usize,
}

/// Global epilogue after the decoder trunk (lm head side).
#[derive(Debug, Clone, Copy)]
pub struct EpilogueSpec {
    /// Final norm before the head (chain + weighted per [`MicroOps`]).
    pub final_norm: bool,
    /// Softmax micro-ops over the logits.
    pub softmax: usize,
    /// Argmax/gather micro-ops.
    pub argmax_reduce: usize,
    /// Sampling plumbing ops.
    pub sampling_plumbing: usize,
}

/// Vision encoder spec (ViT-style tower).
#[derive(Debug, Clone, Copy)]
pub struct VisionSpec {
    pub n_layers: u32,
    pub d: u64,
    pub d_ffn: u64,
    /// Patch side length (patch embedding conv kernel).
    pub patch: u64,
    pub in_channels: u64,
    /// Vision tokens per image (attention span of the encoder).
    pub tokens: u64,
    /// Vision tokens processed per generated text token (amortization
    /// of the encoder cost onto the per-token graph; 1.0 = every step
    /// runs the full encoder).
    pub amortized: f64,
    pub norm_chain: usize,
    pub softmax: usize,
    pub act_chain: usize,
    /// Input image bytes (graph source tensor).
    pub img_bytes: f64,
}

/// Static-instruction calibration model.
#[derive(Debug, Clone, Copy)]
pub enum InstrModel {
    /// Distribute exactly `total` instructions: per-op floor plus a
    /// FLOPs-proportional share of the remainder (Llama's Table 9 pin).
    ExactTotal { total: f64, floor: f64 },
    /// Per-op floor plus a FLOPs-proportional `budget` on top.
    FloorPlusBudget { floor: f64, budget: f64 },
}

/// A declarative workload: everything the generic builder needs, plus
/// the closed-form totals the property tests and the registry listing
/// derive without building a graph.
#[derive(Debug, Clone, Copy)]
pub struct WorkloadSpec {
    /// Canonical registry name (`workload=<name>`).
    pub name: &'static str,
    /// Accepted `workload=` aliases.
    pub aliases: &'static [&'static str],
    /// Graph display name (Table 9 "model" row).
    pub graph_name: &'static str,
    pub family: Family,
    pub dims: DecoderDims,
    pub vision: Option<VisionSpec>,
    pub micro: MicroOps,
    pub epilogue: EpilogueSpec,
    /// KV-cache element bytes; 0 = no KV cache (encoder family).
    pub kv_elem_bytes: u32,
    /// Decode-active FLOP fraction φ_decode (§3.8).
    pub phi_decode: f64,
    /// Prefill-active FLOP fraction (≈1: every parameter works).
    pub phi_prefill: f64,
    pub instr_model: InstrModel,
    /// Default evaluation context length (§4.1).
    pub default_seq_len: u32,
    /// Default evaluation batch (Table 9; 3 for the paper's Llama run).
    pub default_batch: u32,
}

impl WorkloadSpec {
    /// The spec's default evaluation scenario.
    pub fn default_scenario(&self) -> Scenario {
        Scenario {
            phase: Phase::Decode,
            seq_len: self.default_seq_len,
            batch: self.default_batch,
        }
    }

    /// Build the graph at the default scenario.
    pub fn build_default(&self) -> Graph {
        self.build(&self.default_scenario())
    }

    /// Build the micro-op graph for one scenario.
    pub fn build(&self, scn: &Scenario) -> Graph {
        build_graph(self, scn)
    }

    /// KV-cache architecture constants (Eq 25), if the family carries a
    /// cache.
    pub fn kv_config(&self) -> Option<KvConfig> {
        if self.kv_elem_bytes == 0 || self.family == Family::VisionEncoder {
            return None;
        }
        Some(KvConfig {
            n_layers: self.dims.n_layers,
            n_kv_heads: self.dims.n_kv_heads as u32,
            head_dim: self.dims.head_dim as u32,
            elem_bytes: self.kv_elem_bytes,
        })
    }

    /// Graph interface tensors: ids + mask + per-layer KV in/out for
    /// decoder-bearing families (Table 8's 66/65 for Llama), image →
    /// logits for encoders.
    pub fn interface_tensors(&self) -> (usize, usize) {
        match self.family {
            Family::VisionEncoder => (1, 1),
            Family::Decoder | Family::VisionLanguage => (
                2 + 2 * self.dims.n_layers as usize,
                1 + 2 * self.dims.n_layers as usize,
            ),
        }
    }

    /// Closed-form operator count of one decoder layer.
    pub fn decoder_layer_ops(&self) -> usize {
        let m = &self.micro;
        let norm = m.norm_chain + m.norm_weighted as usize;
        2 * norm                                   // pre/post-attention norms
            + 3                                    // q/k/v projections
            + 2 * m.rope                           // RoPE on q and k
            + 2                                    // KV-cache appends
            + 1                                    // attention scores
            + m.attn_scale as usize
            + m.softmax
            + 1                                    // attention · V
            + m.attn_reshape
            + 2                                    // output proj + residual
            + 2                                    // gate + up projections
            + m.act_chain
            + 1                                    // gate ⊙ up
            + 1                                    // down projection
            + 1                                    // MLP residual
            + m.shape_plumbing
    }

    /// Closed-form operator count of one vision layer.
    pub fn vit_layer_ops(v: &VisionSpec) -> usize {
        2 * v.norm_chain                           // pre/post norms
            + 3                                    // q/k/v
            + 1 + v.softmax + 1                    // scores, softmax, AV
            + 1 + 1                                // output proj + residual
            + 1 + v.act_chain + 1 + 1              // up, act, down, residual
    }

    /// Closed-form total operator count — what [`build_graph`] must emit
    /// (Table 8's 7,489 for Llama 3.1 8B).
    pub fn expected_ops(&self) -> usize {
        match self.family {
            Family::VisionEncoder => {
                let v = self.vision.expect("vision-encoder spec without vision tower");
                2 + v.n_layers as usize * Self::vit_layer_ops(&v)  // img + conv + layers
                    + 2                                            // pool + head
                    + self.epilogue.softmax
            }
            Family::Decoder | Family::VisionLanguage => {
                let vision = match &self.vision {
                    Some(v) => 2 + v.n_layers as usize * Self::vit_layer_ops(v) + 1, // + proj
                    None => 0,
                };
                let trunk = 2 + self.vision.is_some() as usize; // ids + embed (+ fuse)
                let ep = &self.epilogue;
                let final_norm = if ep.final_norm {
                    self.micro.norm_chain + self.micro.norm_weighted as usize
                } else {
                    0
                };
                let epilogue =
                    final_norm + 1 + ep.softmax + ep.argmax_reduce + ep.sampling_plumbing;
                vision
                    + trunk
                    + self.dims.n_layers as usize * self.decoder_layer_ops()
                    + epilogue
            }
        }
    }

    /// Closed-form count of weight-owning operators (Table 8's 291 for
    /// Llama: embed + 9/layer + final norm + head).
    pub fn expected_weight_tensors(&self) -> usize {
        let mut n = 0usize;
        if let Some(v) = &self.vision {
            n += 1 + v.n_layers as usize * 6; // patch conv + q/k/v/o/up/down per layer
            if self.family == Family::VisionLanguage {
                n += 1; // modality projection
            }
        }
        if self.family == Family::VisionEncoder {
            return n + 1; // classification head
        }
        let per_layer = 3 + 1 + 3 + if self.micro.norm_weighted { 2 } else { 0 };
        n += 1 // embedding
            + self.dims.n_layers as usize * per_layer
            + (self.epilogue.final_norm && self.micro.norm_weighted) as usize
            + 1; // lm head
        n
    }

    /// Closed-form total FP16 weight bytes (Table 8's 14.96 GB for Llama).
    pub fn expected_weight_bytes(&self) -> f64 {
        let mut w = 0.0;
        if let Some(v) = &self.vision {
            let per_layer = 4.0 * (v.d * v.d) as f64 + 2.0 * (v.d * v.d_ffn) as f64;
            w += (v.patch * v.patch * v.in_channels * v.d) as f64
                + v.n_layers as f64 * per_layer;
            if self.family == Family::VisionLanguage {
                w += (v.d * self.dims.d_model) as f64;
            }
        }
        let d = &self.dims;
        match self.family {
            Family::VisionEncoder => {
                let v = self.vision.expect("vision-encoder spec without vision tower");
                w += (d.vocab * v.d) as f64; // classification head
            }
            Family::Decoder | Family::VisionLanguage => {
                let dm = d.d_model as f64;
                let norms = if self.micro.norm_weighted { 2.0 * dm } else { 0.0 };
                let per_layer = dm * d.q_dim() as f64      // Wq
                    + 2.0 * dm * d.kv_dim() as f64         // Wk, Wv
                    + dm * d.q_dim() as f64                // Wo
                    + 3.0 * dm * d.d_ffn as f64            // gate/up/down
                    + norms;
                let final_norm = if self.epilogue.final_norm && self.micro.norm_weighted {
                    dm
                } else {
                    0.0
                };
                w += d.n_layers as f64 * per_layer
                    + 2.0 * d.vocab as f64 * dm            // embed + head
                    + final_norm;
            }
        }
        w * FP16_BYTES
    }

    /// Closed-form parameter count.
    pub fn expected_params(&self) -> f64 {
        self.expected_weight_bytes() / FP16_BYTES
    }

    /// Closed-form total static instructions (Table 9's 597 M for Llama).
    pub fn expected_instrs(&self) -> f64 {
        match self.instr_model {
            InstrModel::ExactTotal { total, .. } => total,
            InstrModel::FloorPlusBudget { floor, budget } => {
                floor * self.expected_ops() as f64 + budget
            }
        }
    }
}

/// Incremental graph builder: ops push in topological order by
/// construction (an op's id is its index, inputs are earlier pushes).
struct B {
    ops: Vec<Op>,
}

impl B {
    fn push(
        &mut self,
        kind: OpKind,
        layer: i32,
        flops: f64,
        weight_bytes: f64,
        out_bytes: f64,
        inputs: Vec<OpId>,
    ) -> OpId {
        let id = self.ops.len() as OpId;
        self.ops.push(Op {
            id,
            kind,
            layer,
            flops,
            weight_bytes,
            out_bytes,
            inputs,
            instrs: 0.0, // filled by calibrate_instrs
        });
        id
    }

    /// Chain of `n` micro-ops of `kind` threading one activation tensor.
    fn chain(&mut self, kind: OpKind, layer: i32, n: usize, bytes: f64, mut prev: OpId) -> OpId {
        for _ in 0..n {
            prev = self.push(kind, layer, bytes / FP16_BYTES, 0.0, bytes, vec![prev]);
        }
        prev
    }
}

/// Build the micro-op graph for `spec` at scenario `scn`. Costs are per
/// processed token: a generated token in decode, a prompt token in
/// prefill (attention spanning [`Scenario::attn_span`]).
pub fn build_graph(spec: &WorkloadSpec, scn: &Scenario) -> Graph {
    let mut b = B { ops: Vec::with_capacity(spec.expected_ops()) };
    let d = &spec.dims;
    let d_bytes = d.d_model as f64 * FP16_BYTES;

    // ---- vision tower (VLM prologue or the whole encoder workload)
    let mut vis_feed: Option<OpId> = None;
    if let Some(v) = &spec.vision {
        let vh = build_vision(&mut b, v);
        if spec.family == Family::VisionEncoder {
            // classification epilogue: pool + head + softmax
            let vd = v.d as f64 * FP16_BYTES;
            let logits = d.vocab as f64 * FP16_BYTES;
            let pooled = b.push(OpKind::Reduce, -1, v.d as f64, 0.0, vd, vec![vh]);
            let head_w = (d.vocab * v.d) as f64 * FP16_BYTES;
            let x = b.push(
                OpKind::MatMul,
                -1,
                2.0 * (d.vocab * v.d) as f64,
                head_w,
                logits,
                vec![pooled],
            );
            b.chain(OpKind::Softmax, -1, spec.epilogue.softmax, logits, x);
            return finish(spec, scn, b);
        }
        // modality projection into decoder space
        let proj_w = (v.d * d.d_model) as f64 * FP16_BYTES;
        vis_feed = Some(b.push(
            OpKind::MatMul,
            -1,
            v.amortized * 2.0 * (v.d * d.d_model) as f64,
            proj_w,
            d_bytes,
            vec![vh],
        ));
    }

    // ---- decoder trunk: embedding gather (+ vision fusion for VLMs)
    let embed_w = (d.vocab * d.d_model) as f64 * FP16_BYTES;
    let ids = b.push(OpKind::Other, -1, 0.0, 0.0, 8.0, vec![]);
    let mut h = b.push(OpKind::Embed, -1, d.d_model as f64, embed_w, d_bytes, vec![ids]);
    if let Some(vis) = vis_feed {
        h = b.push(OpKind::Elementwise, -1, d.d_model as f64, 0.0, d_bytes, vec![h, vis]);
    }

    // decoder layers of a VLM are numbered after the encoder's
    let layer_base = if spec.vision.is_some() { 100 } else { 0 };
    for layer in 0..d.n_layers as i32 {
        h = decoder_layer(&mut b, spec, scn, layer_base + layer, h);
    }

    // ---- epilogue: (final norm) + lm head + softmax + sampling
    let mut x = h;
    if spec.epilogue.final_norm {
        x = b.chain(OpKind::Norm, -1, spec.micro.norm_chain, d_bytes, x);
        if spec.micro.norm_weighted {
            let norm_w = d.d_model as f64 * FP16_BYTES;
            x = b.push(OpKind::Norm, -1, d.d_model as f64, norm_w, d_bytes, vec![x]);
        }
    }
    let head_w = (d.vocab * d.d_model) as f64 * FP16_BYTES;
    let logits_bytes = d.vocab as f64 * FP16_BYTES;
    x = b.push(
        OpKind::MatMul,
        -1,
        2.0 * (d.vocab * d.d_model) as f64,
        head_w,
        logits_bytes,
        vec![x],
    );
    x = b.chain(OpKind::Softmax, -1, spec.epilogue.softmax, logits_bytes, x);
    x = b.chain(OpKind::Reduce, -1, spec.epilogue.argmax_reduce, 8.0, x);
    let _out = b.chain(OpKind::Other, -1, spec.epilogue.sampling_plumbing, 8.0, x);

    finish(spec, scn, b)
}

/// One decoder layer: norm → QKV → RoPE → KV append → attention → output
/// proj/residual → norm → gated MLP/residual → shape plumbing, with the
/// micro-op counts taken from the spec.
fn decoder_layer(b: &mut B, spec: &WorkloadSpec, scn: &Scenario, lyr: i32, h_in: OpId) -> OpId {
    let d = &spec.dims;
    let m = &spec.micro;
    let dm = d.d_model as f64;
    let d_bytes = dm * FP16_BYTES;
    let q_dim = d.q_dim() as f64;
    let kv_dim = d.kv_dim() as f64;
    let kv_bytes = kv_dim * FP16_BYTES;
    let norm_w = dm * FP16_BYTES;
    let span = scn.attn_span();

    // --- input norm
    let mut x = b.chain(OpKind::Norm, lyr, m.norm_chain, d_bytes, h_in);
    if m.norm_weighted {
        x = b.push(OpKind::Norm, lyr, dm, norm_w, d_bytes, vec![x]);
    }

    // --- Q/K/V projections (GQA: K/V at kv_dim width)
    let wq = dm * q_dim * FP16_BYTES;
    let wkv = dm * kv_dim * FP16_BYTES;
    let q = b.push(OpKind::MatMul, lyr, 2.0 * dm * q_dim, wq, d_bytes, vec![x]);
    let k = b.push(OpKind::MatMul, lyr, 2.0 * dm * kv_dim, wkv, kv_bytes, vec![x]);
    let v = b.push(OpKind::MatMul, lyr, 2.0 * dm * kv_dim, wkv, kv_bytes, vec![x]);

    // --- RoPE on q and k
    let q = b.chain(OpKind::Rope, lyr, m.rope, d_bytes, q);
    let k = b.chain(OpKind::Rope, lyr, m.rope, kv_bytes, k);

    // --- KV cache append (bandwidth-only)
    let k = b.push(OpKind::KvUpdate, lyr, 0.0, 0.0, kv_bytes, vec![k]);
    let v = b.push(OpKind::KvUpdate, lyr, 0.0, 0.0, kv_bytes, vec![v]);

    // --- attention over the scenario's span
    let score_flops = 2.0 * q_dim * span;
    let score_bytes = d.n_heads as f64 * span * FP16_BYTES;
    let mut s = b.push(OpKind::MatMul, lyr, score_flops, 0.0, score_bytes, vec![q, k]);
    if m.attn_scale {
        s = b.push(
            OpKind::Elementwise,
            lyr,
            score_bytes / FP16_BYTES,
            0.0,
            score_bytes,
            vec![s],
        );
    }
    let s = b.chain(OpKind::Softmax, lyr, m.softmax, score_bytes, s);
    let att = b.push(OpKind::MatMul, lyr, score_flops, 0.0, d_bytes, vec![s, v]);
    let att = b.chain(OpKind::Reshape, lyr, m.attn_reshape, d_bytes, att);

    // --- output projection + residual
    let wo = dm * q_dim * FP16_BYTES;
    let o = b.push(OpKind::MatMul, lyr, 2.0 * dm * q_dim, wo, d_bytes, vec![att]);
    let h1 = b.push(OpKind::Elementwise, lyr, dm, 0.0, d_bytes, vec![h_in, o]);

    // --- post-attention norm
    let mut y = b.chain(OpKind::Norm, lyr, m.norm_chain, d_bytes, h1);
    if m.norm_weighted {
        y = b.push(OpKind::Norm, lyr, dm, norm_w, d_bytes, vec![y]);
    }

    // --- gated MLP: gate/up + act + mul + down + residual
    let d_ffn = d.d_ffn as f64;
    let wff = dm * d_ffn * FP16_BYTES;
    let ffn_bytes = d_ffn * FP16_BYTES;
    let gate = b.push(OpKind::MatMul, lyr, 2.0 * dm * d_ffn, wff, ffn_bytes, vec![y]);
    let up = b.push(OpKind::MatMul, lyr, 2.0 * dm * d_ffn, wff, ffn_bytes, vec![y]);
    let act = b.chain(OpKind::Elementwise, lyr, m.act_chain, ffn_bytes, gate);
    let prod = b.push(OpKind::Elementwise, lyr, d_ffn, 0.0, ffn_bytes, vec![act, up]);
    let down = b.push(OpKind::MatMul, lyr, 2.0 * d_ffn * dm, wff, d_bytes, vec![prod]);
    let h2 = b.push(OpKind::Elementwise, lyr, dm, 0.0, d_bytes, vec![h1, down]);

    // --- shape infrastructure: near-zero-cost plumbing ops
    b.chain(OpKind::Reshape, lyr, m.shape_plumbing, 64.0, h2);
    h2
}

/// Vision tower: patch-embedding conv + ViT layers, costs amortized per
/// generated token by `v.amortized`.
fn build_vision(b: &mut B, v: &VisionSpec) -> OpId {
    let vd = v.d as f64 * FP16_BYTES;
    let patch_in = (v.patch * v.patch * v.in_channels) as f64;
    let patch_w = patch_in * v.d as f64 * FP16_BYTES;
    let img = b.push(OpKind::Other, -1, 0.0, 0.0, v.img_bytes, vec![]);
    let mut h = b.push(
        OpKind::Conv,
        -1,
        v.amortized * 2.0 * patch_in * v.d as f64,
        patch_w,
        vd,
        vec![img],
    );
    for layer in 0..v.n_layers as i32 {
        h = vit_layer(b, v, layer, h);
    }
    h
}

fn vit_layer(b: &mut B, v: &VisionSpec, lyr: i32, h_in: OpId) -> OpId {
    let d = v.d;
    let vd = d as f64 * FP16_BYTES;
    let amort = v.amortized;
    let w_attn = (d * d) as f64 * FP16_BYTES;
    let w_ffn = (d * v.d_ffn) as f64 * FP16_BYTES;
    let mut x = b.chain(OpKind::Norm, lyr, v.norm_chain, vd, h_in);
    let q = b.push(OpKind::MatMul, lyr, amort * 2.0 * (d * d) as f64, w_attn, vd, vec![x]);
    let k = b.push(OpKind::MatMul, lyr, amort * 2.0 * (d * d) as f64, w_attn, vd, vec![x]);
    let vv = b.push(OpKind::MatMul, lyr, amort * 2.0 * (d * d) as f64, w_attn, vd, vec![x]);
    let s = b.push(
        OpKind::MatMul,
        lyr,
        amort * 2.0 * (d * v.tokens) as f64,
        0.0,
        vd,
        vec![q, k],
    );
    let s = b.chain(OpKind::Softmax, lyr, v.softmax, vd, s);
    let a = b.push(
        OpKind::MatMul,
        lyr,
        amort * 2.0 * (d * v.tokens) as f64,
        0.0,
        vd,
        vec![s, vv],
    );
    let o = b.push(OpKind::MatMul, lyr, amort * 2.0 * (d * d) as f64, w_attn, vd, vec![a]);
    let h1 = b.push(OpKind::Elementwise, lyr, d as f64, 0.0, vd, vec![h_in, o]);
    x = b.chain(OpKind::Norm, lyr, v.norm_chain, vd, h1);
    let up = b.push(
        OpKind::MatMul,
        lyr,
        amort * 2.0 * (d * v.d_ffn) as f64,
        w_ffn,
        vd,
        vec![x],
    );
    let g1 = b.chain(OpKind::Elementwise, lyr, v.act_chain, vd, up);
    let dn = b.push(
        OpKind::MatMul,
        lyr,
        amort * 2.0 * (v.d_ffn * d) as f64,
        w_ffn,
        vd,
        vec![g1],
    );
    b.push(OpKind::Elementwise, lyr, d as f64, 0.0, vd, vec![h1, dn])
}

/// Assemble the [`Graph`] from the built ops: interface/KV/φ metadata,
/// parameter count from the weight sweep, instruction calibration.
fn finish(spec: &WorkloadSpec, scn: &Scenario, b: B) -> Graph {
    debug_assert_eq!(
        b.ops.len(),
        spec.expected_ops(),
        "{}: builder drifted from the closed-form op count",
        spec.name
    );
    let weight_tensors = b.ops.iter().filter(|o| o.weight_bytes > 0.0).count();
    let (n_inputs, n_outputs) = spec.interface_tensors();
    let phi = match scn.phase {
        Phase::Decode => spec.phi_decode,
        Phase::Prefill => spec.phi_prefill,
    };
    let mut g = Graph {
        name: spec.graph_name.into(),
        ops: b.ops,
        weight_tensors,
        n_inputs,
        n_outputs,
        kv: spec.kv_config(),
        params: 0.0, // set below from the weight sweep
        phi,
        scenario: *scn,
    };
    g.params = g.total_weight_bytes() / FP16_BYTES;
    calibrate_instrs(&mut g, spec.instr_model);
    g
}

/// Distribute static instructions across ops: a per-op floor (shape ops
/// still decode) plus a FLOPs-proportional share of the budget.
fn calibrate_instrs(g: &mut Graph, model: InstrModel) {
    let total_flops: f64 = g.ops.iter().map(|o| o.flops).sum();
    let (floor, budget) = match model {
        InstrModel::ExactTotal { total, floor } => {
            (floor, total - floor * g.ops.len() as f64)
        }
        InstrModel::FloorPlusBudget { floor, budget } => (floor, budget),
    };
    for op in &mut g.ops {
        op.instrs = floor + budget * (op.flops / total_flops.max(1.0));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_parse_round_trips_and_rejects() {
        assert_eq!(Phase::parse("prefill").unwrap(), Phase::Prefill);
        assert_eq!(Phase::parse("decode").unwrap(), Phase::Decode);
        let err = Phase::parse("training").unwrap_err();
        assert!(err.contains("prefill") && err.contains("decode"), "{err}");
        assert_eq!(Phase::default(), Phase::Decode);
    }

    #[test]
    fn attn_span_decode_vs_prefill() {
        let d = Scenario::decode(2048);
        assert_eq!(d.attn_span(), 2048.0);
        let p = Scenario { phase: Phase::Prefill, seq_len: 2048, batch: 1 };
        assert_eq!(p.attn_span(), 1024.5);
        assert!(p.attn_span() < d.attn_span());
    }

    #[test]
    fn seq_len_scales_attention_flops_only() {
        let spec = crate::ir::registry::get("llama-3.1-8b").unwrap();
        let short = spec.build(&Scenario::decode(1024));
        let long = spec.build(&Scenario::decode(8192));
        assert_eq!(short.ops.len(), long.ops.len());
        assert!(
            (long.total_weight_bytes() - short.total_weight_bytes()).abs() < 1.0,
            "weights must not depend on context length"
        );
        assert!(long.total_flops_per_token() > short.total_flops_per_token());
    }

    #[test]
    fn prefill_uses_phi_prefill_and_shorter_span() {
        let spec = crate::ir::registry::get("llama-3.1-8b").unwrap();
        let dec = spec.build(&Scenario::decode(2048));
        let pre = spec.build(&Scenario { phase: Phase::Prefill, seq_len: 2048, batch: 1 });
        assert_eq!(dec.phi, spec.phi_decode);
        assert_eq!(pre.phi, spec.phi_prefill);
        // shorter average span ⇒ fewer attention FLOPs per token
        assert!(pre.total_flops_per_token() < dec.total_flops_per_token());
    }

    #[test]
    fn batch_does_not_change_the_graph() {
        let spec = crate::ir::registry::get("llama-3.1-8b").unwrap();
        let b1 = spec.build(&Scenario { phase: Phase::Decode, seq_len: 2048, batch: 1 });
        let b8 = spec.build(&Scenario { phase: Phase::Decode, seq_len: 2048, batch: 8 });
        assert_eq!(b1.ops.len(), b8.ops.len());
        assert_eq!(
            b1.total_flops_per_token().to_bits(),
            b8.total_flops_per_token().to_bits()
        );
        assert_eq!(b8.scenario.batch, 8);
    }
}
