//! Aggregate workload statistics feeding the state vector (Table 2 dims
//! 0–4, 59–66) and the model-characteristics report (Table 9).

use super::{Graph, OpKind, PartitionClass};

#[derive(Debug, Clone)]
pub struct WorkloadStats {
    /// Total static instruction count (Table 9: 597 M for Llama).
    pub instr_count: f64,
    /// Instruction-level parallelism estimate: ops per critical-path step.
    pub ilp: f64,
    /// Memory intensity: bytes moved per FLOP.
    pub mem_intensity: f64,
    /// Vector utilization: vector instruction fraction weighted by instrs.
    pub vector_util: f64,
    /// Fraction of FLOPs in MatMul ops (state dim 4).
    pub matmul_ratio: f64,
    /// Comm-to-computation ratio ρ_comm (Eq 20).
    pub rho_comm: f64,
    /// FLOP share per partition class (drives Eq 10 effectiveness).
    pub class_flops: [f64; 3],
    /// Scalar/vector instruction ratios (state dims 65–66).
    pub scalar_ratio: f64,
    pub vector_ratio: f64,
}

/// Critical-path length (longest chain) via one topological sweep.
pub fn critical_path_len(g: &Graph) -> usize {
    let mut depth = vec![0usize; g.ops.len()];
    let mut max_d = 0;
    for op in &g.ops {
        let d = op
            .inputs
            .iter()
            .map(|&i| depth[i as usize] + 1)
            .max()
            .unwrap_or(0);
        depth[op.id as usize] = d;
        max_d = max_d.max(d);
    }
    max_d + 1
}

pub fn compute(g: &Graph) -> WorkloadStats {
    let instr_count = g.total_instrs();
    let total_flops = g.total_flops_per_token().max(1.0);
    let total_bytes: f64 = g
        .ops
        .iter()
        .map(|o| o.out_bytes + o.weight_bytes.min(o.weight_bytes)) // weights read once/token
        .sum();
    let cp = critical_path_len(g).max(1);
    let ilp = g.ops.len() as f64 / cp as f64;

    let mut vec_instr = 0.0;
    let mut class_flops = [0.0f64; 3];
    let mut edge_bytes = 0.0;
    for op in &g.ops {
        vec_instr += op.instrs * op.kind.vector_fraction();
        let c = match op.kind.partition_class() {
            PartitionClass::MatMul => 0,
            PartitionClass::Conv => 1,
            PartitionClass::General => 2,
        };
        class_flops[c] += op.flops;
        // Eq 20 numerator: tensor bytes crossing graph edges
        edge_bytes += op.out_bytes * op.inputs.len().max(1) as f64;
    }
    let matmul_flops: f64 = g
        .ops
        .iter()
        .filter(|o| o.kind == OpKind::MatMul)
        .map(|o| o.flops)
        .sum();

    WorkloadStats {
        instr_count,
        ilp,
        mem_intensity: total_bytes / total_flops,
        vector_util: vec_instr / instr_count.max(1.0),
        matmul_ratio: matmul_flops / total_flops,
        rho_comm: edge_bytes / total_flops,
        class_flops,
        scalar_ratio: 1.0 - vec_instr / instr_count.max(1.0),
        vector_ratio: vec_instr / instr_count.max(1.0),
    }
}

#[cfg(test)]
mod tests {
    use crate::ir::{llama, smolvlm};

    #[test]
    fn llama_stats_shape() {
        let g = llama::build();
        let s = super::compute(&g);
        assert!(s.matmul_ratio > 0.9, "matmul ratio {}", s.matmul_ratio);
        assert!(s.ilp > 1.0, "ilp {}", s.ilp);
        assert!(s.vector_util > 0.3 && s.vector_util < 1.0);
        assert!(s.rho_comm > 0.0 && s.rho_comm < 1.0);
        assert!((s.scalar_ratio + s.vector_ratio - 1.0).abs() < 1e-9);
    }

    #[test]
    fn llama_is_memory_dominated() {
        // §4.3: "strongly memory-dominated" — weight bytes per token are
        // on the same order as FLOPs (FP16 read per MAC pair).
        let g = llama::build();
        let s = super::compute(&g);
        assert!(s.mem_intensity > 0.5, "intensity {}", s.mem_intensity);
    }

    #[test]
    fn smolvlm_has_conv_flops() {
        let g = smolvlm::build();
        let s = super::compute(&g);
        assert!(s.class_flops[1] > 0.0, "conv flops missing");
    }

    #[test]
    fn critical_path_is_reasonable() {
        let g = llama::build();
        let cp = super::critical_path_len(&g);
        // 32 layers x ~50 sequential micro-ops each
        assert!(cp > 500 && cp < 7489, "cp {cp}");
    }
}
