//! Workload IR — the operator graph the compiler partitions onto the mesh.
//!
//! The paper ingests ONNX (Llama 3.1 8B Instruct FP16: 7,489 graph
//! operators, 291 weight tensors, 14.96 GB; SmolVLM: 0.48 GB). We have no
//! ONNX models in this environment, so graphs are generated from
//! declarative [`spec::WorkloadSpec`]s with the paper's exact statistics
//! (DESIGN.md §4) — the optimizer only consumes per-op
//! FLOPs/bytes/dependencies and aggregate statistics, all of which are
//! architecture-derived. [`registry`] holds every selectable spec;
//! [`llama`] and [`smolvlm`] re-export the paper's two pinned instances.

pub mod llama;
pub mod registry;
pub mod smolvlm;
pub mod spec;
pub mod stats;

pub use spec::{Phase, Scenario, WorkloadSpec};



/// Operator kind; determines the partitioning class of §3.5 (Eq 10) and
/// the instruction mix used for hazard statistics.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Dense matrix multiply (projections, attention scores, LM head).
    MatMul,
    /// Convolution (vision encoders).
    Conv,
    /// Normalization (RMSNorm / LayerNorm micro-ops).
    Norm,
    Softmax,
    /// Rotary position embedding micro-ops.
    Rope,
    /// Pointwise arithmetic (add/mul/silu/gelu...).
    Elementwise,
    /// Shape plumbing (reshape/transpose/concat/split); ~zero FLOPs.
    Reshape,
    /// KV-cache append (bandwidth, no FLOPs).
    KvUpdate,
    /// Embedding gather.
    Embed,
    Reduce,
    Other,
}

impl OpKind {
    /// Partitioning class of Eq 10: MatMul / Conv / general.
    pub fn partition_class(self) -> PartitionClass {
        match self {
            OpKind::MatMul => PartitionClass::MatMul,
            OpKind::Conv => PartitionClass::Conv,
            _ => PartitionClass::General,
        }
    }

    /// Fraction of this op's instructions that are vector (vs scalar);
    /// feeds state dims 65–66 (Table 2 "Instruction Type").
    pub fn vector_fraction(self) -> f64 {
        match self {
            OpKind::MatMul | OpKind::Conv => 0.95,
            OpKind::Norm | OpKind::Softmax | OpKind::Reduce => 0.80,
            OpKind::Elementwise | OpKind::Rope => 0.85,
            OpKind::KvUpdate | OpKind::Embed => 0.60,
            OpKind::Reshape | OpKind::Other => 0.10,
        }
    }
}

/// §3.5 operation classes for the RL-controlled partitioning ratios.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PartitionClass {
    MatMul,
    Conv,
    General,
}

pub type OpId = u32;

/// One graph operator with per-decoded-token costs.
#[derive(Debug, Clone)]
pub struct Op {
    pub id: OpId,
    pub kind: OpKind,
    /// Transformer layer index, or -1 for global (embed/head) ops.
    pub layer: i32,
    /// FLOPs per decoded token (multiply-accumulate = 2 FLOPs).
    pub flops: f64,
    /// Resident weight bytes (FP16) this op owns in WMEM.
    pub weight_bytes: f64,
    /// Activation bytes produced per token (tensor-interface pressure).
    pub out_bytes: f64,
    /// Producer operators whose outputs this op consumes.
    pub inputs: Vec<OpId>,
    /// Static instruction count estimate (for hazard/IMEM modeling).
    pub instrs: f64,
}

/// A whole workload graph.
#[derive(Debug, Clone)]
pub struct Graph {
    pub name: String,
    pub ops: Vec<Op>,
    /// Number of distinct weight (initializer) tensors — Table 8's 291.
    pub weight_tensors: usize,
    /// Graph interface tensors (Table 8's 66 / 65).
    pub n_inputs: usize,
    pub n_outputs: usize,
    /// Transformer config needed by the KV model (Eq 25).
    pub kv: Option<KvConfig>,
    /// Total parameter count (for FLOPs-per-token, Eq 21 denominator).
    pub params: f64,
    /// Active FLOP fraction φ for the built scenario's phase (≈0.97 in
    /// decode for GQA models, ≈1.0 in prefill).
    pub phi: f64,
    /// The (phase, context length, batch) point this graph was built for.
    pub scenario: Scenario,
}

/// KV-cache relevant architecture constants (Eq 25).
#[derive(Debug, Clone, Copy)]
pub struct KvConfig {
    pub n_layers: u32,
    pub n_kv_heads: u32,
    pub head_dim: u32,
    /// Bytes per element of the KV cache (2 for FP16).
    pub elem_bytes: u32,
}

impl Graph {
    pub fn total_weight_bytes(&self) -> f64 {
        self.ops.iter().map(|o| o.weight_bytes).sum()
    }

    pub fn total_flops_per_token(&self) -> f64 {
        self.ops.iter().map(|o| o.flops).sum()
    }

    pub fn total_instrs(&self) -> f64 {
        self.ops.iter().map(|o| o.instrs).sum()
    }

    /// FLOPs per processed token per the paper's throughput model:
    /// 2 · P_total · φ (§3.8).
    pub fn flops_per_token_model(&self) -> f64 {
        2.0 * self.params * self.phi
    }

    /// Validate structural invariants (DAG, edges in range, costs finite).
    pub fn validate(&self) -> Result<(), String> {
        for op in &self.ops {
            for &inp in &op.inputs {
                if inp >= op.id {
                    return Err(format!(
                        "op {} consumes {} (not topologically ordered)",
                        op.id, inp
                    ));
                }
            }
            if !op.flops.is_finite() || op.flops < 0.0 {
                return Err(format!("op {} has bad flops {}", op.id, op.flops));
            }
            if !op.weight_bytes.is_finite() || op.weight_bytes < 0.0 {
                return Err(format!("op {} has bad weight bytes", op.id));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_classes() {
        assert_eq!(OpKind::MatMul.partition_class(), PartitionClass::MatMul);
        assert_eq!(OpKind::Conv.partition_class(), PartitionClass::Conv);
        assert_eq!(OpKind::Softmax.partition_class(), PartitionClass::General);
    }

    #[test]
    fn vector_fraction_in_unit_interval() {
        for k in [
            OpKind::MatMul,
            OpKind::Conv,
            OpKind::Norm,
            OpKind::Softmax,
            OpKind::Rope,
            OpKind::Elementwise,
            OpKind::Reshape,
            OpKind::KvUpdate,
            OpKind::Embed,
            OpKind::Reduce,
            OpKind::Other,
        ] {
            let f = k.vector_fraction();
            assert!((0.0..=1.0).contains(&f));
        }
    }

    #[test]
    fn validate_catches_forward_edges() {
        let g = Graph {
            name: "bad".into(),
            ops: vec![Op {
                id: 0,
                kind: OpKind::Other,
                layer: -1,
                flops: 0.0,
                weight_bytes: 0.0,
                out_bytes: 0.0,
                inputs: vec![5],
                instrs: 0.0,
            }],
            weight_tensors: 0,
            n_inputs: 0,
            n_outputs: 0,
            kv: None,
            params: 0.0,
            phi: 1.0,
            scenario: Scenario::decode(1),
        };
        assert!(g.validate().is_err());
    }
}
