//! Workload registry: every [`WorkloadSpec`] the CLI can select with
//! `workload=<name>`, with alias resolution and the closed-form Table-8
//! statistics the `help`/`info` listings print.
//!
//! Adding a workload is adding one ~30-line spec constant to
//! [`REGISTRY`]; the generic builder ([`crate::ir::spec::build_graph`]),
//! the partitioner, the scenario axis and every report table pick it up
//! unchanged. The Llama 3.1 8B and SmolVLM entries reproduce the paper's
//! Table 8/9 pins exactly (golden tests in `tests/workloads.rs`).

use super::spec::{
    DecoderDims, EpilogueSpec, Family, InstrModel, MicroOps, VisionSpec, WorkloadSpec,
};

/// Llama-style micro-op decomposition: RMSNorm as a 6-op chain plus a
/// weighted γ op, 10-op RoPE, scaled 5-op softmax attention with 4
/// reshape ops, SwiGLU with a 2-op SiLU, and the 173 shape-plumbing ops
/// per layer real ONNX exports carry for dynamic shapes.
const LLAMA_MICRO: MicroOps = MicroOps {
    norm_chain: 6,
    norm_weighted: true,
    rope: 10,
    attn_scale: true,
    softmax: 5,
    attn_reshape: 4,
    act_chain: 2,
    shape_plumbing: 173,
};

/// Full sampling epilogue: final norm, lm head, 5-op softmax, argmax +
/// gather, 16 sampling-plumbing ops.
const LLAMA_EPILOGUE: EpilogueSpec =
    EpilogueSpec { final_norm: true, softmax: 5, argmax_reduce: 2, sampling_plumbing: 16 };

/// Compact-export decomposition (SmolVLM-style): 4-op norms without a
/// weighted γ op, 6-op RoPE, unscaled 4-op softmax, no reshape/plumbing.
const COMPACT_MICRO: MicroOps = MicroOps {
    norm_chain: 4,
    norm_weighted: false,
    rope: 6,
    attn_scale: false,
    softmax: 4,
    attn_reshape: 0,
    act_chain: 2,
    shape_plumbing: 0,
};

/// Head-only epilogue (logits out, no sampling ops in the export).
const COMPACT_EPILOGUE: EpilogueSpec =
    EpilogueSpec { final_norm: false, softmax: 5, argmax_reduce: 0, sampling_plumbing: 0 };

/// Llama 3.1 8B Instruct FP16 — the paper's headline workload. Table 8/9
/// pins: 7,489 operators, 291 weight tensors, 14.96 GB / 8.03 B params,
/// 66/65 interface tensors, 597 M instructions, Eq 25 ⇒ 128 KB/token KV.
pub const LLAMA31_8B: WorkloadSpec = WorkloadSpec {
    name: "llama-3.1-8b",
    aliases: &["llama", "llama31-8b", "llama-8b"],
    graph_name: "llama-3.1-8b-fp16",
    family: Family::Decoder,
    dims: DecoderDims {
        n_layers: 32,
        d_model: 4096,
        n_heads: 32,
        n_kv_heads: 8,
        head_dim: 128,
        d_ffn: 14336,
        vocab: 128_256,
    },
    vision: None,
    micro: LLAMA_MICRO,
    epilogue: LLAMA_EPILOGUE,
    kv_elem_bytes: 2,
    phi_decode: 0.97,
    phi_prefill: 1.0,
    instr_model: InstrModel::ExactTotal { total: 597e6, floor: 20.0 },
    default_seq_len: 2048,
    default_batch: 3, // the paper's Llama evaluation batch (Table 9)
};

/// SmolVLM-256M-style encoder-decoder VLM (§4.12 low-power validation):
/// a SigLIP-style vision encoder feeding a compact 30-layer decoder;
/// FP16 footprint calibrated to the paper's 0.48 GB.
pub const SMOLVLM: WorkloadSpec = WorkloadSpec {
    name: "smolvlm-256m",
    aliases: &["smolvlm", "smolvlm-256"],
    graph_name: "smolvlm",
    family: Family::VisionLanguage,
    dims: DecoderDims {
        n_layers: 30,
        d_model: 576,
        n_heads: 9,
        n_kv_heads: 3,
        head_dim: 64,
        d_ffn: 1536,
        vocab: 49_152,
    },
    vision: Some(VisionSpec {
        n_layers: 12,
        d: 768,
        d_ffn: 3072,
        patch: 14,
        in_channels: 3,
        tokens: 729,
        amortized: 0.25, // vision tokens processed per generated text token
        norm_chain: 4,
        softmax: 3,
        act_chain: 2,
        img_bytes: 150_528.0,
    }),
    micro: COMPACT_MICRO,
    epilogue: COMPACT_EPILOGUE,
    kv_elem_bytes: 2,
    phi_decode: 0.95,
    phi_prefill: 1.0,
    instr_model: InstrModel::FloorPlusBudget { floor: 20.0, budget: 12e6 },
    default_seq_len: 1024,
    default_batch: 1,
};

/// Llama 3.2 1B — the small on-device decoder of the same family
/// (16 layers, d=2048, 32/8 GQA heads at d_head=64, FFN 8192).
pub const LLAMA32_1B: WorkloadSpec = WorkloadSpec {
    name: "llama-3.2-1b",
    aliases: &["llama-1b", "llama32-1b"],
    graph_name: "llama-3.2-1b-fp16",
    family: Family::Decoder,
    dims: DecoderDims {
        n_layers: 16,
        d_model: 2048,
        n_heads: 32,
        n_kv_heads: 8,
        head_dim: 64,
        d_ffn: 8192,
        vocab: 128_256,
    },
    vision: None,
    micro: LLAMA_MICRO,
    epilogue: LLAMA_EPILOGUE,
    kv_elem_bytes: 2,
    phi_decode: 0.97,
    phi_prefill: 1.0,
    instr_model: InstrModel::FloorPlusBudget { floor: 20.0, budget: 110e6 },
    default_seq_len: 2048,
    default_batch: 1,
};

/// Llama 3.2 3B (28 layers, d=3072, 24/8 GQA heads at d_head=128,
/// FFN 8192).
pub const LLAMA32_3B: WorkloadSpec = WorkloadSpec {
    name: "llama-3.2-3b",
    aliases: &["llama-3b", "llama32-3b"],
    graph_name: "llama-3.2-3b-fp16",
    family: Family::Decoder,
    dims: DecoderDims {
        n_layers: 28,
        d_model: 3072,
        n_heads: 24,
        n_kv_heads: 8,
        head_dim: 128,
        d_ffn: 8192,
        vocab: 128_256,
    },
    vision: None,
    micro: LLAMA_MICRO,
    epilogue: LLAMA_EPILOGUE,
    kv_elem_bytes: 2,
    phi_decode: 0.97,
    phi_prefill: 1.0,
    instr_model: InstrModel::FloorPlusBudget { floor: 20.0, budget: 260e6 },
    default_seq_len: 2048,
    default_batch: 1,
};

/// Qwen2-style 0.5B decoder (24 layers, d=896, 14/2 GQA heads at
/// d_head=64, FFN 4864, 152K vocab; untied embeddings, compact export).
pub const QWEN2_0_5B: WorkloadSpec = WorkloadSpec {
    name: "qwen2-0.5b",
    aliases: &["qwen", "qwen-0.5b", "qwen2-05b"],
    graph_name: "qwen2-0.5b-fp16",
    family: Family::Decoder,
    dims: DecoderDims {
        n_layers: 24,
        d_model: 896,
        n_heads: 14,
        n_kv_heads: 2,
        head_dim: 64,
        d_ffn: 4864,
        vocab: 151_936,
    },
    vision: None,
    micro: COMPACT_MICRO,
    epilogue: COMPACT_EPILOGUE,
    kv_elem_bytes: 2,
    phi_decode: 0.96,
    phi_prefill: 1.0,
    instr_model: InstrModel::FloorPlusBudget { floor: 20.0, budget: 55e6 },
    default_seq_len: 4096,
    default_batch: 1,
};

/// ViT-Base image encoder (12 layers, d=768, 196 patch tokens at patch
/// 16, 1000-class head) — a pure vision workload: Conv-heavy partition
/// classes, no KV cache, every step runs the full image.
pub const VIT_BASE: WorkloadSpec = WorkloadSpec {
    name: "vit-base",
    aliases: &["vit", "vit-b16"],
    graph_name: "vit-base-patch16-fp16",
    family: Family::VisionEncoder,
    dims: DecoderDims {
        // d_model mirrors the vision width; vocab is the class head
        n_layers: 12,
        d_model: 768,
        n_heads: 12,
        n_kv_heads: 12,
        head_dim: 64,
        d_ffn: 3072,
        vocab: 1000,
    },
    vision: Some(VisionSpec {
        n_layers: 12,
        d: 768,
        d_ffn: 3072,
        patch: 16,
        in_channels: 3,
        tokens: 196,
        amortized: 1.0, // every inference processes the full image
        norm_chain: 4,
        softmax: 3,
        act_chain: 2,
        img_bytes: 150_528.0, // 224 × 224 × 3
    }),
    micro: COMPACT_MICRO,
    epilogue: COMPACT_EPILOGUE,
    kv_elem_bytes: 0, // no KV cache
    phi_decode: 1.0,
    phi_prefill: 1.0,
    instr_model: InstrModel::FloorPlusBudget { floor: 20.0, budget: 9e6 },
    default_seq_len: 196,
    default_batch: 1,
};

/// Every registered workload, in listing order.
pub static REGISTRY: &[WorkloadSpec] =
    &[LLAMA31_8B, SMOLVLM, LLAMA32_1B, LLAMA32_3B, QWEN2_0_5B, VIT_BASE];

/// All registered specs.
pub fn all() -> &'static [WorkloadSpec] {
    REGISTRY
}

/// Resolve a `workload=` value against canonical names and aliases.
pub fn get(name: &str) -> Option<&'static WorkloadSpec> {
    REGISTRY
        .iter()
        .find(|s| s.name == name || s.aliases.contains(&name))
}

/// Canonical workload names, in listing order (for error messages and
/// the CLI listing).
pub fn names() -> Vec<&'static str> {
    REGISTRY.iter().map(|s| s.name).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_names_and_aliases_resolve() {
        assert_eq!(get("llama-3.1-8b").unwrap().name, "llama-3.1-8b");
        assert_eq!(get("llama").unwrap().name, "llama-3.1-8b");
        assert_eq!(get("smolvlm").unwrap().name, "smolvlm-256m");
        assert_eq!(get("qwen").unwrap().name, "qwen2-0.5b");
        assert_eq!(get("vit").unwrap().name, "vit-base");
        assert!(get("gpt-17").is_none());
    }

    #[test]
    fn names_are_unique_including_aliases() {
        let mut seen = std::collections::HashSet::new();
        for s in all() {
            assert!(seen.insert(s.name), "duplicate name {}", s.name);
            for a in s.aliases {
                assert!(seen.insert(*a), "duplicate alias {a}");
            }
        }
        assert!(names().len() >= 5, "registry must hold ≥5 workloads");
    }

    #[test]
    fn llama_closed_forms_hit_table8() {
        let s = &LLAMA31_8B;
        assert_eq!(s.expected_ops(), 7489);
        assert_eq!(s.expected_weight_tensors(), 291);
        assert_eq!(s.expected_instrs(), 597e6);
        let gb = s.expected_weight_bytes() / (1u64 << 30) as f64;
        assert!((gb - 14.96).abs() < 0.05, "weights {gb} GiB");
        assert_eq!(s.interface_tensors(), (66, 65));
    }

    #[test]
    fn new_specs_have_plausible_scale() {
        // untied embeddings, so the 1B/3B land slightly above the tied
        // checkpoint sizes (1.24B/3.21B)
        let b = |s: &WorkloadSpec| s.expected_params() / 1e9;
        assert!((1.3..1.7).contains(&b(&LLAMA32_1B)), "1B params {}", b(&LLAMA32_1B));
        assert!((3.3..3.9).contains(&b(&LLAMA32_3B)), "3B params {}", b(&LLAMA32_3B));
        assert!((0.4..0.8).contains(&b(&QWEN2_0_5B)), "qwen params {}", b(&QWEN2_0_5B));
        assert!((0.07..0.11).contains(&b(&VIT_BASE)), "vit params {}", b(&VIT_BASE));
    }

    #[test]
    fn vit_has_no_kv_and_single_interface() {
        assert!(VIT_BASE.kv_config().is_none());
        assert_eq!(VIT_BASE.interface_tensors(), (1, 1));
        assert!(LLAMA31_8B.kv_config().is_some());
    }
}
