//! SmolVLM graph (§4.12 low-power validation) — a declarative spec
//! instance ([`crate::ir::registry::SMOLVLM`]) of the generic builder in
//! [`crate::ir::spec`].
//!
//! SmolVLM-256M-style encoder-decoder VLM: a SigLIP-style vision encoder
//! (12 ViT layers, d=768, patch-embedding conv) feeding a compact decoder
//! (30 layers, d=576, GQA). Total FP16 weight footprint calibrated to the
//! paper's 0.48 GB; pins enforced by `tests/workloads.rs` and below.

use super::registry;
use super::{Graph, WorkloadSpec};

/// Architecture constants (mirror the registry spec).
pub const VIT_LAYERS: u32 = 12;
pub const VIT_D: u64 = 768;
pub const VIT_FFN: u64 = 3072;
pub const DEC_LAYERS: u32 = 30;
pub const DEC_D: u64 = 576;
pub const DEC_FFN: u64 = 1536;
pub const DEC_HEADS: u64 = 9;
pub const DEC_KV_HEADS: u64 = 3;
pub const DEC_HEAD_DIM: u64 = 64;
pub const VOCAB: u64 = 49_152;
pub const SEQ_LEN: u64 = 1024;
/// Vision tokens processed per generated text token (amortized).
pub const VIS_TOKENS_AMORTIZED: f64 = 0.25;

/// The registered spec.
pub fn spec() -> &'static WorkloadSpec {
    &registry::SMOLVLM
}

/// Build the SmolVLM graph at its default scenario (decode, 1,024-token
/// context).
pub fn build() -> Graph {
    spec().build_default()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::OpKind;

    #[test]
    fn spec_constants_match_module_constants() {
        let s = spec();
        let v = s.vision.unwrap();
        assert_eq!(v.n_layers, VIT_LAYERS);
        assert_eq!(v.d, VIT_D);
        assert_eq!(v.d_ffn, VIT_FFN);
        assert_eq!(v.amortized, VIS_TOKENS_AMORTIZED);
        assert_eq!(s.dims.n_layers, DEC_LAYERS);
        assert_eq!(s.dims.d_model, DEC_D);
        assert_eq!(s.dims.d_ffn, DEC_FFN);
        assert_eq!(s.dims.n_heads, DEC_HEADS);
        assert_eq!(s.dims.n_kv_heads, DEC_KV_HEADS);
        assert_eq!(s.dims.head_dim, DEC_HEAD_DIM);
        assert_eq!(s.dims.vocab, VOCAB);
        assert_eq!(s.default_seq_len as u64, SEQ_LEN);
    }

    #[test]
    fn weight_footprint_near_0p48_gb() {
        let g = build();
        let gb = g.total_weight_bytes() / (1u64 << 30) as f64;
        assert!((gb - 0.48).abs() < 0.08, "weights {gb} GiB");
    }

    #[test]
    fn graph_is_valid_dag() {
        build().validate().unwrap();
    }

    #[test]
    fn has_conv_for_vision_patches() {
        let g = build();
        assert!(g.ops.iter().any(|o| o.kind == OpKind::Conv));
    }

    #[test]
    fn decoder_layers_numbered_after_encoder() {
        // decoder layer ids start at 100 so per-layer grouping keeps the
        // vision tower and the text trunk apart
        let g = build();
        assert!(g.ops.iter().any(|o| o.layer >= 100));
        assert!(g.ops.iter().any(|o| (0..100).contains(&o.layer)));
    }

    #[test]
    fn kv_per_token_much_smaller_than_llama() {
        let g = build();
        let kv = g.kv.unwrap();
        let per_tok = 2.0
            * kv.n_layers as f64
            * kv.n_kv_heads as f64
            * kv.head_dim as f64
            * kv.elem_bytes as f64;
        assert!(per_tok < 131072.0 / 4.0, "kv/token {per_tok}");
    }
}
