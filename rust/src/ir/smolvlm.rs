//! Synthetic SmolVLM graph generator (§4.12 low-power validation).
//!
//! SmolVLM-256M-style encoder-decoder VLM: a SigLIP-style vision encoder
//! (12 ViT layers, d=768, patch-embedding conv) feeding a compact decoder
//! (30 layers, d=576, GQA). Total FP16 weight footprint calibrated to the
//! paper's 0.48 GB.

use super::{Graph, KvConfig, Op, OpId, OpKind};

pub const VIT_LAYERS: u32 = 12;
pub const VIT_D: u64 = 768;
pub const VIT_FFN: u64 = 3072;
pub const DEC_LAYERS: u32 = 30;
pub const DEC_D: u64 = 576;
pub const DEC_FFN: u64 = 1536;
pub const DEC_HEADS: u64 = 9;
pub const DEC_KV_HEADS: u64 = 3;
pub const DEC_HEAD_DIM: u64 = 64;
pub const VOCAB: u64 = 49_152;
pub const SEQ_LEN: u64 = 1024;
/// Vision tokens processed per generated text token (amortized).
pub const VIS_TOKENS_AMORTIZED: f64 = 0.25;

const FP16: f64 = 2.0;

struct B {
    ops: Vec<Op>,
}

impl B {
    fn push(
        &mut self,
        kind: OpKind,
        layer: i32,
        flops: f64,
        w: f64,
        out: f64,
        inputs: Vec<OpId>,
    ) -> OpId {
        let id = self.ops.len() as OpId;
        self.ops.push(Op { id, kind, layer, flops, weight_bytes: w, out_bytes: out, inputs, instrs: 0.0 });
        id
    }

    fn chain(&mut self, kind: OpKind, layer: i32, n: usize, bytes: f64, mut prev: OpId) -> OpId {
        for _ in 0..n {
            prev = self.push(kind, layer, bytes / FP16, 0.0, bytes, vec![prev]);
        }
        prev
    }
}

pub fn build() -> Graph {
    let mut b = B { ops: Vec::new() };

    // ---- vision encoder (amortized per generated token)
    let vd = VIT_D as f64 * FP16;
    let amort = VIS_TOKENS_AMORTIZED;
    // patch embedding conv: 14x14x3 -> 768
    let patch_w = 14.0 * 14.0 * 3.0 * VIT_D as f64 * FP16;
    let img = b.push(OpKind::Other, -1, 0.0, 0.0, 150528.0, vec![]);
    let mut h = b.push(
        OpKind::Conv,
        -1,
        amort * 2.0 * 14.0 * 14.0 * 3.0 * VIT_D as f64,
        patch_w,
        vd,
        vec![img],
    );
    for layer in 0..VIT_LAYERS as i32 {
        h = vit_layer(&mut b, layer, h, vd, amort);
    }
    // modality projection into decoder space
    let proj_w = (VIT_D * DEC_D) as f64 * FP16;
    let dd = DEC_D as f64 * FP16;
    let vis = b.push(
        OpKind::MatMul,
        -1,
        amort * 2.0 * (VIT_D * DEC_D) as f64,
        proj_w,
        dd,
        vec![h],
    );

    // ---- text decoder
    let embed_w = (VOCAB * DEC_D) as f64 * FP16;
    let ids = b.push(OpKind::Other, -1, 0.0, 0.0, 8.0, vec![]);
    let mut t = b.push(OpKind::Embed, -1, DEC_D as f64, embed_w, dd, vec![ids]);
    // fuse vision tokens at layer 0 input
    t = b.push(OpKind::Elementwise, -1, DEC_D as f64, 0.0, dd, vec![t, vis]);
    for layer in 0..DEC_LAYERS as i32 {
        t = dec_layer(&mut b, layer, t, dd);
    }
    let head_w = (VOCAB * DEC_D) as f64 * FP16;
    let t = b.push(
        OpKind::MatMul,
        -1,
        2.0 * (VOCAB * DEC_D) as f64,
        head_w,
        VOCAB as f64 * FP16,
        vec![t],
    );
    b.chain(OpKind::Softmax, -1, 5, VOCAB as f64 * FP16, t);

    let n_weight_tensors = b
        .ops
        .iter()
        .filter(|o| o.weight_bytes > 0.0)
        .count();
    let mut g = Graph {
        name: "smolvlm".into(),
        ops: b.ops,
        weight_tensors: n_weight_tensors,
        n_inputs: 2 + 2 * DEC_LAYERS as usize,
        n_outputs: 1 + 2 * DEC_LAYERS as usize,
        kv: Some(KvConfig {
            n_layers: DEC_LAYERS,
            n_kv_heads: DEC_KV_HEADS as u32,
            head_dim: DEC_HEAD_DIM as u32,
            elem_bytes: 2,
        }),
        params: 0.0,
        phi_decode: 0.95,
    };
    g.params = g.total_weight_bytes() / FP16;
    // spread a plausible static instruction budget (~12M for 240M params)
    let total_flops: f64 = g.ops.iter().map(|o| o.flops).sum();
    for op in &mut g.ops {
        op.instrs = 20.0 + 12e6 * (op.flops / total_flops);
    }
    g
}

fn vit_layer(b: &mut B, layer: i32, h_in: OpId, vd: f64, amort: f64) -> OpId {
    let d = VIT_D;
    let w_attn = (d * d) as f64 * FP16;
    let w_ffn = (d * VIT_FFN) as f64 * FP16;
    let mut x = b.chain(OpKind::Norm, layer, 4, vd, h_in);
    let q = b.push(OpKind::MatMul, layer, amort * 2.0 * (d * d) as f64, w_attn, vd, vec![x]);
    let k = b.push(OpKind::MatMul, layer, amort * 2.0 * (d * d) as f64, w_attn, vd, vec![x]);
    let v = b.push(OpKind::MatMul, layer, amort * 2.0 * (d * d) as f64, w_attn, vd, vec![x]);
    let s = b.push(OpKind::MatMul, layer, amort * 2.0 * (d * 729) as f64, 0.0, vd, vec![q, k]);
    let s = b.chain(OpKind::Softmax, layer, 3, vd, s);
    let a = b.push(OpKind::MatMul, layer, amort * 2.0 * (d * 729) as f64, 0.0, vd, vec![s, v]);
    let o = b.push(OpKind::MatMul, layer, amort * 2.0 * (d * d) as f64, w_attn, vd, vec![a]);
    let h1 = b.push(OpKind::Elementwise, layer, d as f64, 0.0, vd, vec![h_in, o]);
    x = b.chain(OpKind::Norm, layer, 4, vd, h1);
    let up = b.push(OpKind::MatMul, layer, amort * 2.0 * (d * VIT_FFN) as f64, w_ffn, vd, vec![x]);
    let g1 = b.chain(OpKind::Elementwise, layer, 2, vd, up);
    let dn = b.push(OpKind::MatMul, layer, amort * 2.0 * (VIT_FFN * d) as f64, w_ffn, vd, vec![g1]);
    b.push(OpKind::Elementwise, layer, d as f64, 0.0, vd, vec![h1, dn])
}

fn dec_layer(b: &mut B, layer: i32, h_in: OpId, dd: f64) -> OpId {
    let d = DEC_D;
    let lyr = 100 + layer; // decoder layers numbered after encoder
    let kv_dim = (DEC_KV_HEADS * DEC_HEAD_DIM) as f64;
    let w_q = (d * d) as f64 * FP16;
    let w_kv = d as f64 * kv_dim * FP16;
    let w_ffn = (d * DEC_FFN) as f64 * FP16;
    let mut x = b.chain(OpKind::Norm, lyr, 4, dd, h_in);
    let q = b.push(OpKind::MatMul, lyr, 2.0 * (d * d) as f64, w_q, dd, vec![x]);
    let k = b.push(OpKind::MatMul, lyr, 2.0 * d as f64 * kv_dim, w_kv, kv_dim * FP16, vec![x]);
    let v = b.push(OpKind::MatMul, lyr, 2.0 * d as f64 * kv_dim, w_kv, kv_dim * FP16, vec![x]);
    let q = b.chain(OpKind::Rope, lyr, 6, dd, q);
    let k = b.chain(OpKind::Rope, lyr, 6, kv_dim * FP16, k);
    let k = b.push(OpKind::KvUpdate, lyr, 0.0, 0.0, kv_dim * FP16, vec![k]);
    let v = b.push(OpKind::KvUpdate, lyr, 0.0, 0.0, kv_dim * FP16, vec![v]);
    let sc = 2.0 * (DEC_HEADS * DEC_HEAD_DIM) as f64 * SEQ_LEN as f64;
    let s = b.push(OpKind::MatMul, lyr, sc, 0.0, (DEC_HEADS * SEQ_LEN) as f64 * FP16, vec![q, k]);
    let s = b.chain(OpKind::Softmax, lyr, 4, (DEC_HEADS * SEQ_LEN) as f64 * FP16, s);
    let a = b.push(OpKind::MatMul, lyr, sc, 0.0, dd, vec![s, v]);
    let o = b.push(OpKind::MatMul, lyr, 2.0 * (d * d) as f64, w_q, dd, vec![a]);
    let h1 = b.push(OpKind::Elementwise, lyr, d as f64, 0.0, dd, vec![h_in, o]);
    x = b.chain(OpKind::Norm, lyr, 4, dd, h1);
    let gate = b.push(OpKind::MatMul, lyr, 2.0 * (d * DEC_FFN) as f64, w_ffn, DEC_FFN as f64 * FP16, vec![x]);
    let up = b.push(OpKind::MatMul, lyr, 2.0 * (d * DEC_FFN) as f64, w_ffn, DEC_FFN as f64 * FP16, vec![x]);
    let si = b.chain(OpKind::Elementwise, lyr, 2, DEC_FFN as f64 * FP16, gate);
    let pr = b.push(OpKind::Elementwise, lyr, DEC_FFN as f64, 0.0, DEC_FFN as f64 * FP16, vec![si, up]);
    let dn = b.push(OpKind::MatMul, lyr, 2.0 * (DEC_FFN * d) as f64, w_ffn, dd, vec![pr]);
    b.push(OpKind::Elementwise, lyr, d as f64, 0.0, dd, vec![h1, dn])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weight_footprint_near_0p48_gb() {
        let g = build();
        let gb = g.total_weight_bytes() / (1u64 << 30) as f64;
        assert!((gb - 0.48).abs() < 0.08, "weights {gb} GiB");
    }

    #[test]
    fn graph_is_valid_dag() {
        build().validate().unwrap();
    }

    #[test]
    fn has_conv_for_vision_patches() {
        let g = build();
        assert!(g.ops.iter().any(|o| o.kind == OpKind::Conv));
    }

    #[test]
    fn kv_per_token_much_smaller_than_llama() {
        let g = build();
        let kv = g.kv.unwrap();
        let per_tok = 2.0
            * kv.n_layers as f64
            * kv.n_kv_heads as f64
            * kv.head_dim as f64
            * kv.elem_bytes as f64;
        assert!(per_tok < 131072.0 / 4.0, "kv/token {per_tok}");
    }
}
