//! Operation-level partitioning (§3.5).
//!
//! For every (partitionable) operator:
//!   1. classify (MatMul / Conv / general),
//!   2. ρ_class = clip(ρ_base + Δ_class) from the RL action (Eqs 10–13),
//!   3. N_cores = ⌈ρ · N_total⌉,
//!   4. communication-graph-aware placement: per-TCC composite score =
//!      current load + NoC hop distance to producers + imbalance penalty
//!      + mesh centrality; pick the lowest-scoring TCCs,
//!   5. split the workload across the selected cores.
//!
//! The placement also accumulates the NoC traffic statistics (Eq 62's
//! energy integral, Eq 23's bisection bytes), per-tile loads for the
//! heterogeneous derivation (§3.3), hazard statistics (state dims 37–44),
//! and the load-distribution features (state dims 29–32).

pub mod groups;

use crate::arch::{MeshConfig, TileLoad};
use crate::hazard::{self, HazardStats, Mitigation};
use crate::ir::{Graph, PartitionClass};
use crate::noc::{GeomCache, ScoreParams, TrafficStats};
use crate::util::clip;

/// RL-controlled partitioning knobs (action groups: Op-Partition
/// Controls, Memory/Load Partition, Streaming, Workload Partition).
#[derive(Debug, Clone, Copy)]
pub struct PartitionKnobs {
    /// ρ_base of Eqs 11–13 (paper default 0.3).
    pub rho_base: f64,
    pub d_matmul: f64,
    pub d_conv: f64,
    pub d_general: f64,
    /// Placement-score weight on current load vs the other terms
    /// (load-balance controls of the Memory/Load Partition group).
    pub w_load: f64,
    /// Input/output streaming ratios (Table 3 dims 26–27): fraction of
    /// split-broadcast traffic avoided by streaming directly from
    /// producers.
    pub streaming_in: f64,
    pub streaming_out: f64,
    /// Sub-matmul partition control (Table 3 dim 28): extra split factor
    /// for the largest matmuls.
    pub sub_matmul: f64,
    /// All-reduce fraction (Table 3 dim 29): share of split outputs that
    /// must be reduced across the split set.
    pub allreduce_frac: f64,
}

impl Default for PartitionKnobs {
    fn default() -> Self {
        PartitionKnobs {
            rho_base: 0.3,
            d_matmul: 0.0,
            d_conv: 0.0,
            d_general: -0.25,
            w_load: 1.0,
            streaming_in: 0.5,
            streaming_out: 0.5,
            sub_matmul: 0.5,
            allreduce_frac: 0.3,
        }
    }
}

impl PartitionKnobs {
    /// Eqs 11–13.
    pub fn rho(&self, class: PartitionClass) -> f64 {
        let d = match class {
            PartitionClass::MatMul => self.d_matmul,
            PartitionClass::Conv => self.d_conv,
            PartitionClass::General => self.d_general,
        };
        clip(self.rho_base + d, 0.0, 1.0)
    }
}

/// Load-distribution features (state dims 29–32).
#[derive(Debug, Clone, Copy, Default)]
pub struct LoadStats {
    pub variance: f64,
    pub max_min_ratio: f64,
    /// Balance score = mean/max ∈ (0,1]; also used for η_∥ (Eq 21).
    pub balance: f64,
    pub mean: f64,
}

/// A placement result for one candidate configuration.
#[derive(Debug, Clone)]
pub struct Placement {
    pub loads: Vec<TileLoad>,
    pub traffic: TrafficStats,
    pub load_stats: LoadStats,
    pub hazards: HazardStats,
    /// Per-class realized partition ratios (state dims 33–36).
    pub class_rho: [f64; 3],
    /// Number of placement units (ops or groups) placed.
    pub n_units: usize,
}

impl Placement {
    /// Parallel efficiency η_∥ for Eq 21: load balance discounted by
    /// communication overhead.
    pub fn eta_parallel(&self) -> f64 {
        let comm_penalty = (self.traffic.mean_hops() * 0.002).min(0.08);
        (self.load_stats.balance * (1.0 - comm_penalty)).clamp(0.05, 1.0)
    }
}

/// One schedulable unit (an operator, or an operator group in `group`
/// granularity — see [`groups`]).
#[derive(Debug, Clone)]
pub struct Unit {
    pub class: PartitionClass,
    pub flops: f64,
    pub weight_bytes: f64,
    pub out_bytes: f64,
    pub instrs: f64,
    /// Indices of producer units.
    pub inputs: Vec<u32>,
    pub kind: crate::ir::OpKind,
}

/// Reusable working state for [`place_units_with`], struct-of-arrays so
/// the O(units × cores) scoring loop streams over contiguous f64 lanes
/// (the episode hot path — EXPERIMENTS.md §Perf L3). Owning one per
/// worker thread keeps repeated placements allocation-free; an
/// [`crate::eval::EvalScratch`] embeds one.
#[derive(Debug, Default)]
pub struct PlaceScratch {
    flops: Vec<f64>,
    weights: Vec<f64>,
    act: Vec<f64>,
    instrs: Vec<f64>,
    /// Raw per-tile scores written by the (kernel-dispatched) scoring
    /// loop, before pairing with tile indices for selection.
    score_vals: Vec<f64>,
    /// Per-tile composite placement scores for the current unit.
    scores: Vec<(f64, u32)>,
    /// Primary (traffic-anchor) tile per already-placed unit.
    primary: Vec<u32>,
    /// Precomputed per-mesh-dims geometry (tile coordinates, centrality
    /// penalties, bisection masks) — built once per (width, height) and
    /// reused across placements instead of being recomputed on every
    /// reset. The full all-pairs hop table stays uncached (too big); hop
    /// distances come from the coordinate table.
    pub geom: GeomCache,
}

impl PlaceScratch {
    fn reset(&mut self, mesh: &MeshConfig) {
        let n = mesh.cores();
        for buf in [
            &mut self.flops,
            &mut self.weights,
            &mut self.act,
            &mut self.instrs,
            &mut self.score_vals,
        ] {
            buf.clear();
            buf.resize(n, 0.0);
        }
        self.scores.clear();
        self.scores.resize(n, (0.0, 0));
        self.primary.clear();
    }
}

/// Flops below which an op is never split (placement overhead dominates).
const SPLIT_FLOOR_FLOPS: f64 = 1e5;

/// Weight footprint above which an op is sharded regardless of class —
/// embedding/LM-head tables cannot live in one tile's WMEM (Table 7 cap).
const WEIGHT_SHARD_BYTES: f64 = 32.0 * 1024.0 * 1024.0;

/// Place `units` onto the mesh with a one-shot scratch. Prefer
/// [`place_units_with`] on hot paths to reuse the working buffers.
pub fn place_units(
    units: &[Unit],
    mesh: &MeshConfig,
    knobs: &PartitionKnobs,
    mit: &Mitigation,
) -> Placement {
    place_units_with(units, mesh, knobs, mit, &mut PlaceScratch::default())
}

/// Place `units` onto the mesh. `mit` carries the microarchitectural
/// hazard mitigation of the RL-selected average TCC parameters. The
/// scratch is reset on entry; results are independent of its prior
/// contents.
pub fn place_units_with(
    units: &[Unit],
    mesh: &MeshConfig,
    knobs: &PartitionKnobs,
    mit: &Mitigation,
    scratch: &mut PlaceScratch,
) -> Placement {
    let n = mesh.cores();
    scratch.reset(mesh);
    let PlaceScratch {
        flops: tiles_flops,
        weights: tiles_weights,
        act: tiles_act,
        instrs: tiles_instrs,
        score_vals,
        scores,
        primary,
        geom,
    } = scratch;
    let geom = geom.get(mesh);
    let xy = &geom.xy;
    let mut traffic = TrafficStats::default();
    let mut hazards = HazardStats::default();
    // running totals for normalizing the load term of the composite score
    let mut total_flops_placed = 1.0f64;
    let mut total_weights_placed = 1.0f64;

    for (ui, u) in units.iter().enumerate() {
        let rho = knobs.rho(u.class);
        // Step 3: target core count. Tiny or general ops are never split.
        let splittable = u.flops >= SPLIT_FLOOR_FLOPS
            && !matches!(u.class, PartitionClass::General);
        let mut k = if splittable {
            ((rho * n as f64).ceil() as usize).max(1)
        } else {
            1
        };
        // sub-matmul control splits the biggest units further (dim 28)
        if splittable && u.flops > 1e8 {
            k = ((k as f64 * (1.0 + knobs.sub_matmul)).ceil() as usize).min(n);
        }
        // giant weight tables (embeddings, LM head) shard by rows so the
        // footprint fits per-tile WMEM even when ρ is small
        if u.weight_bytes > WEIGHT_SHARD_BYTES {
            k = k.max((u.weight_bytes / WEIGHT_SHARD_BYTES).ceil() as usize);
        }
        k = k.min(n);

        // Step 4: composite placement score per tile. Hot loop: all
        // per-unit constants are hoisted into ScoreParams and the
        // kernel-dispatched `MeshGeom::score_tiles` streams over the SoA
        // tile state (scalar or SIMD f64 — bit-identical either way, so
        // the selection below never depends on the kernel mode).
        let prod_tile = u.inputs.first().map(|&p| primary[p as usize]);
        let params = ScoreParams {
            wl: knobs.w_load,
            inv_mean_f: n as f64 / total_flops_placed,
            inv_mean_w: n as f64 / total_weights_placed,
            mean_f: total_flops_placed / n as f64,
            inv_span: 1.0 / (mesh.width + mesh.height) as f64,
            central_w: if u.inputs.len() > 1 { 0.3 } else { 0.05 },
            prod_xy: prod_tile.map(|p| xy[p as usize]),
        };
        let prim = if k == n {
            // whole-mesh split: the uniform shares make the composite
            // ordering irrelevant — skip scoring, pick the least-loaded
            // tile as the traffic anchor, select all tiles
            let mut best = (f64::INFINITY, 0u32);
            for (t, &f) in tiles_flops.iter().enumerate() {
                if f < best.0 {
                    best = (f, t as u32);
                }
                scores[t] = (0.0, t as u32);
            }
            best.1
        } else {
            // load + hop + imbalance + centrality per tile (the centrality
            // term is what pushes weight-resident ops outward — §4.10's
            // edge-heavy WMEM pattern emerges from it)
            geom.score_tiles(&params, tiles_flops, tiles_weights, tiles_act, score_vals);
            for (t, &s) in score_vals.iter().enumerate() {
                scores[t] = (s, t as u32);
            }
            // pick the k lowest-scoring tiles (k=1: plain argmin swap —
            // no partition pass needed)
            if k == 1 {
                let best = scores
                    .iter()
                    .enumerate()
                    .min_by(|a, b| a.1 .0.total_cmp(&b.1 .0))
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                scores.swap(0, best);
                scores[0].1
            } else {
                scores.select_nth_unstable_by(k - 1, |a, b| a.0.total_cmp(&b.0));
                scores[..k]
                    .iter()
                    .min_by(|a, b| a.0.total_cmp(&b.0))
                    .map(|&(_, t)| t)
                    .unwrap_or(0)
            }
        };
        let selected = &scores[..k];
        primary.push(prim);

        // Step 5: split workload evenly over the selected cores.
        let kf = k as f64;
        for &(_, t) in selected {
            let t = t as usize;
            tiles_flops[t] += u.flops / kf;
            tiles_weights[t] += u.weight_bytes / kf;
            // activation working set: the largest double-buffered live
            // tensor slice (activations are transient, not all-resident)
            tiles_act[t] = tiles_act[t].max(2.0 * u.out_bytes / kf);
            tiles_instrs[t] += u.instrs / kf;
        }
        total_flops_placed += u.flops;
        total_weights_placed += u.weight_bytes;

        // ---- traffic accounting
        // producer -> primary tile edges
        for &inp in &u.inputs {
            let p = primary[inp as usize] as usize;
            let hops = geom.hop(p, prim as usize);
            traffic.record(u.out_bytes, hops, geom.crosses(p, prim as usize));
        }
        // split broadcast (input multicast tree over the split set: a
        // row+column tree on a 2D mesh replicates ~√k times, not k−1) +
        // all-reduce of partial outputs (~log₂k exchange rounds)
        if k > 1 {
            let intra_hops = (kf.sqrt() as u32).max(1);
            // streaming hides at most 80% of the replication traffic —
            // the first multicast copy always traverses the mesh
            let bcast = u.out_bytes * kf.sqrt() * (1.0 - 0.8 * knobs.streaming_in);
            traffic.record(bcast, intra_hops, false);
            let reduce = u.out_bytes
                * kf.log2()
                * knobs.allreduce_frac
                * (1.0 - 0.8 * knobs.streaming_out);
            traffic.record(reduce, intra_hops, false);
        }

        // ---- hazards (instruction-mix model)
        let op_proxy = crate::ir::Op {
            id: ui as u32,
            kind: u.kind,
            layer: 0,
            flops: u.flops,
            weight_bytes: u.weight_bytes,
            out_bytes: u.out_bytes,
            inputs: vec![],
            instrs: u.instrs,
        };
        hazards.accumulate(&hazard::estimate_op(&op_proxy, mit));
    }

    // ---- per-tile loads + hazard densities
    let global_density = hazards.density();
    let loads: Vec<TileLoad> = (0..n)
        .map(|t| TileLoad {
            flops: tiles_flops[t],
            weight_bytes: tiles_weights[t],
            act_bytes: tiles_act[t],
            kv_bytes: 0.0, // filled by distribute_kv
            instrs: tiles_instrs[t],
            hazard_density: global_density,
        })
        .collect();

    let load_stats = compute_load_stats(&loads);
    let class_rho = [
        knobs.rho(PartitionClass::MatMul),
        knobs.rho(PartitionClass::Conv),
        knobs.rho(PartitionClass::General),
    ];
    Placement { loads, traffic, load_stats, hazards, class_rho, n_units: units.len() }
}

fn compute_load_stats(loads: &[TileLoad]) -> LoadStats {
    let n = loads.len() as f64;
    let mean = loads.iter().map(|l| l.flops).sum::<f64>() / n;
    let var = loads.iter().map(|l| (l.flops - mean).powi(2)).sum::<f64>() / n;
    let max = loads.iter().map(|l| l.flops).fold(0.0f64, f64::max);
    let min = loads.iter().map(|l| l.flops).fold(f64::INFINITY, f64::min);
    LoadStats {
        variance: var,
        max_min_ratio: if min > 0.0 { max / min } else { f64::INFINITY },
        balance: if max > 0.0 { (mean / max).clamp(0.0, 1.0) } else { 1.0 },
        mean,
    }
}

/// Convert every op of a graph into a placement unit (op granularity —
/// the paper's full O(N_ops × N_cores) path).
pub fn units_from_ops(g: &Graph) -> Vec<Unit> {
    g.ops
        .iter()
        .map(|o| Unit {
            class: o.kind.partition_class(),
            flops: o.flops,
            weight_bytes: o.weight_bytes,
            out_bytes: o.out_bytes,
            instrs: o.instrs,
            inputs: o.inputs.clone(),
            kind: o.kind,
        })
        .collect()
}

/// Distribute the KV cache across active tiles (Eq 27): records each
/// active tile's KV slice; the memory model decides whether it fits DMEM
/// or spills to WMEM (§3.9 "KV-cache pressure on DMEM").
pub fn distribute_kv(loads: &mut [TileLoad], kv_total_bytes: f64) {
    let active: usize = loads.iter().filter(|l| l.flops > 0.0).count().max(1);
    let share = kv_total_bytes / active as f64;
    for l in loads.iter_mut() {
        if l.flops > 0.0 {
            l.kv_bytes += share;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{llama, OpKind};

    fn mit() -> Mitigation {
        Mitigation { stanum: 4, fetch: 4, xr_wp: 2, vr_wp: 2 }
    }

    fn place_llama_groups(mesh: MeshConfig, knobs: PartitionKnobs) -> Placement {
        let g = llama::build();
        let units = groups::units_from_groups(&g);
        place_units(&units, &mesh, &knobs, &mit())
    }

    #[test]
    fn rho_clipping_eq11_13() {
        let mut k = PartitionKnobs::default();
        k.rho_base = 0.3;
        k.d_matmul = 0.9;
        assert_eq!(k.rho(PartitionClass::MatMul), 1.0);
        k.d_general = -0.9;
        assert_eq!(k.rho(PartitionClass::General), 0.0);
    }

    #[test]
    fn all_flops_conserved_by_placement() {
        let g = llama::build();
        let units = groups::units_from_groups(&g);
        let total: f64 = units.iter().map(|u| u.flops).sum();
        let p = place_units(&units, &MeshConfig::new(8, 8), &PartitionKnobs::default(), &mit());
        let placed: f64 = p.loads.iter().map(|l| l.flops).sum();
        assert!((placed - total).abs() / total < 1e-9);
    }

    #[test]
    fn weights_conserved_by_placement() {
        let g = llama::build();
        let units = groups::units_from_groups(&g);
        let total: f64 = units.iter().map(|u| u.weight_bytes).sum();
        let p = place_units(&units, &MeshConfig::new(10, 10), &PartitionKnobs::default(), &mit());
        let placed: f64 = p.loads.iter().map(|l| l.weight_bytes).sum();
        assert!((placed - total).abs() / total < 1e-9);
    }

    #[test]
    fn higher_rho_spreads_load_better() {
        let lo = PartitionKnobs { rho_base: 0.05, sub_matmul: 0.0, ..Default::default() };
        let hi = PartitionKnobs { rho_base: 0.9, sub_matmul: 0.0, ..Default::default() };
        let mesh = MeshConfig::new(12, 12);
        let p_lo = place_llama_groups(mesh, lo);
        let p_hi = place_llama_groups(mesh, hi);
        assert!(
            p_hi.load_stats.balance > p_lo.load_stats.balance,
            "{} vs {}",
            p_hi.load_stats.balance,
            p_lo.load_stats.balance
        );
    }

    #[test]
    fn splitting_generates_traffic() {
        let mesh = MeshConfig::new(12, 12);
        let no_split = PartitionKnobs {
            rho_base: 0.0,
            d_matmul: 0.0,
            sub_matmul: 0.0,
            ..Default::default()
        };
        let split = PartitionKnobs::default();
        let p0 = place_llama_groups(mesh, no_split);
        let p1 = place_llama_groups(mesh, split);
        assert!(p1.traffic.cross_tile_bytes > p0.traffic.cross_tile_bytes);
    }

    #[test]
    fn kv_distribution_only_hits_active_tiles() {
        let mut loads = vec![
            TileLoad { flops: 1.0, ..Default::default() },
            TileLoad { flops: 0.0, ..Default::default() },
            TileLoad { flops: 2.0, ..Default::default() },
        ];
        distribute_kv(&mut loads, 1000.0);
        assert_eq!(loads[0].kv_bytes, 500.0);
        assert_eq!(loads[1].kv_bytes, 0.0);
        assert_eq!(loads[2].kv_bytes, 500.0);
    }

    #[test]
    fn eta_parallel_in_unit_range() {
        let p = place_llama_groups(MeshConfig::new(6, 7), PartitionKnobs::default());
        let eta = p.eta_parallel();
        assert!(eta > 0.0 && eta <= 1.0, "eta {eta}");
    }

    #[test]
    fn scratch_reuse_is_bit_identical() {
        let g = llama::build();
        let units = groups::units_from_groups(&g);
        let knobs = PartitionKnobs::default();
        let mut scratch = PlaceScratch::default();
        // reuse the scratch across different mesh sizes; every placement
        // must equal a fresh-scratch run exactly
        for side in [4u32, 12, 6] {
            let mesh = MeshConfig::new(side, side);
            let reused = place_units_with(&units, &mesh, &knobs, &mit(), &mut scratch);
            let fresh = place_units(&units, &mesh, &knobs, &mit());
            assert_eq!(reused.loads.len(), fresh.loads.len());
            for (a, b) in reused.loads.iter().zip(&fresh.loads) {
                assert_eq!(a.flops.to_bits(), b.flops.to_bits());
                assert_eq!(a.weight_bytes.to_bits(), b.weight_bytes.to_bits());
                assert_eq!(a.act_bytes.to_bits(), b.act_bytes.to_bits());
            }
            assert_eq!(
                reused.traffic.cross_tile_bytes.to_bits(),
                fresh.traffic.cross_tile_bytes.to_bits()
            );
            assert_eq!(
                reused.load_stats.balance.to_bits(),
                fresh.load_stats.balance.to_bits()
            );
        }
    }

    #[test]
    fn general_ops_stay_unsplit() {
        let units = vec![Unit {
            class: PartitionClass::General,
            flops: 1e9,
            weight_bytes: 0.0,
            out_bytes: 8192.0,
            instrs: 100.0,
            inputs: vec![],
            kind: OpKind::Softmax,
        }];
        let p = place_units(&units, &MeshConfig::new(4, 4), &PartitionKnobs::default(), &mit());
        let occupied = p.loads.iter().filter(|l| l.flops > 0.0).count();
        assert_eq!(occupied, 1);
    }
}
