//! Operator-group placement granularity.
//!
//! The paper's full evaluation places all 7,489 operators individually
//! (O(N_ops × N_cores), ~10 ms per episode on the authors' machine). For
//! single-core CI runs we offer a `group` granularity that clusters each
//! layer's operators by partition behaviour (one group per (layer,
//! cluster-kind)), preserving per-class FLOP/weight/traffic totals while
//! cutting placement cost ~25×. DESIGN.md §4 documents the substitution;
//! the `op` granularity remains available and is exercised by the
//! full-fidelity example + benches.

use std::collections::HashMap;

use super::Unit;
use crate::ir::{Graph, OpKind, PartitionClass};

/// Cluster key: ops in the same layer with the same placement behaviour.
/// MatMul/Conv ops stay individual (they are the split targets with
/// distinct weights); everything else in a layer merges per kind-class.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum ClusterKind {
    /// Non-partitionable glue: norms, softmax, rope, elementwise.
    Glue,
    /// Shape plumbing (zero-flop).
    Shape,
    /// KV + embedding style bandwidth ops.
    Bandwidth,
}

fn cluster_kind(kind: OpKind) -> Option<ClusterKind> {
    match kind {
        OpKind::MatMul | OpKind::Conv => None, // kept individual
        OpKind::Reshape | OpKind::Other => Some(ClusterKind::Shape),
        OpKind::KvUpdate | OpKind::Embed => Some(ClusterKind::Bandwidth),
        _ => Some(ClusterKind::Glue),
    }
}

/// Build placement units by clustering the graph's operators.
pub fn units_from_groups(g: &Graph) -> Vec<Unit> {
    // op id -> unit index, for remapping dependency edges
    let mut op_to_unit: Vec<u32> = vec![0; g.ops.len()];
    let mut units: Vec<Unit> = Vec::new();
    let mut cluster_index: HashMap<(i32, ClusterKind), u32> = HashMap::new();

    for op in &g.ops {
        match cluster_kind(op.kind) {
            None => {
                let uid = units.len() as u32;
                op_to_unit[op.id as usize] = uid;
                units.push(Unit {
                    class: op.kind.partition_class(),
                    flops: op.flops,
                    weight_bytes: op.weight_bytes,
                    out_bytes: op.out_bytes,
                    instrs: op.instrs,
                    inputs: Vec::new(), // filled in second pass
                    kind: op.kind,
                });
            }
            Some(ck) => {
                let key = (op.layer, ck);
                let uid = *cluster_index.entry(key).or_insert_with(|| {
                    let uid = units.len() as u32;
                    units.push(Unit {
                        class: PartitionClass::General,
                        flops: 0.0,
                        weight_bytes: 0.0,
                        out_bytes: 0.0,
                        instrs: 0.0,
                        inputs: Vec::new(),
                        kind: op.kind,
                    });
                    uid
                });
                let u = &mut units[uid as usize];
                u.flops += op.flops;
                u.weight_bytes += op.weight_bytes;
                // out_bytes: keep the max single-tensor interface (the
                // group is a fused region; only its boundary tensor moves)
                u.out_bytes = u.out_bytes.max(op.out_bytes);
                u.instrs += op.instrs;
                op_to_unit[op.id as usize] = uid;
            }
        }
    }

    // second pass: remap dependency edges, dropping intra-group edges
    for op in &g.ops {
        let uid = op_to_unit[op.id as usize];
        for &inp in &op.inputs {
            let pid = op_to_unit[inp as usize];
            if pid != uid && pid < uid && !units[uid as usize].inputs.contains(&pid) {
                units[uid as usize].inputs.push(pid);
            }
        }
    }
    units
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::llama;

    #[test]
    fn grouping_preserves_totals() {
        let g = llama::build();
        let units = units_from_groups(&g);
        let uf: f64 = units.iter().map(|u| u.flops).sum();
        let uw: f64 = units.iter().map(|u| u.weight_bytes).sum();
        let ui: f64 = units.iter().map(|u| u.instrs).sum();
        assert!((uf - g.total_flops_per_token()).abs() / uf < 1e-9);
        assert!((uw - g.total_weight_bytes()).abs() / uw < 1e-9);
        assert!((ui - g.total_instrs()).abs() / ui < 1e-9);
    }

    #[test]
    fn grouping_is_much_smaller_than_op_count() {
        let g = llama::build();
        let units = units_from_groups(&g);
        // 9 matmuls x 32 layers + ~3 clusters x 33 layers + globals
        assert!(units.len() < 600, "{} units", units.len());
        assert!(units.len() > 200, "{} units", units.len());
    }

    #[test]
    fn matmuls_stay_individual() {
        let g = llama::build();
        let units = units_from_groups(&g);
        let n_mm_units = units.iter().filter(|u| u.kind == OpKind::MatMul).count();
        let n_mm_ops = g.ops.iter().filter(|o| o.kind == OpKind::MatMul).count();
        assert_eq!(n_mm_units, n_mm_ops);
    }

    #[test]
    fn edges_are_topologically_ordered() {
        let g = llama::build();
        let units = units_from_groups(&g);
        for (i, u) in units.iter().enumerate() {
            for &p in &u.inputs {
                assert!((p as usize) < i, "unit {i} depends on later unit {p}");
            }
        }
    }
}
