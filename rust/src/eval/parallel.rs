//! Deterministic scoped-thread fan-out for the evaluation layer.
//!
//! The primitive here is [`scoped_chunk_map`]: split an item slice into at
//! most `threads` contiguous chunks, give each worker its own per-thread
//! state (an [`super::EvalScratch`], an RNG, …), and write results into
//! the output slot matching each item's input index. Because outputs are
//! identified by input position — never by completion order — a parallel
//! run produces *bit-identical* results to a serial run of the same
//! items, which the determinism tests in `tests/eval_parallel.rs` pin.

/// Worker count from the environment (`SILICON_RL_THREADS`) or the
/// machine (`available_parallelism`), never zero.
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("SILICON_RL_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// [`num_threads`] with `reserve` threads held back for dedicated
/// non-worker duty (the async actor-learner's update thread), floored at
/// one worker — the rollout fan-out must keep at least one lane stepping
/// even on a single-core budget.
pub fn num_threads_reserving(reserve: usize) -> usize {
    num_threads().saturating_sub(reserve).max(1)
}

/// Resolve a configured thread count: 0 means "auto" ([`num_threads`]).
pub fn resolve(configured: usize) -> usize {
    if configured == 0 {
        num_threads()
    } else {
        configured
    }
}

/// Map `f` over `items` with up to `threads` workers, preserving input
/// order in the output. `init` builds one per-worker state reused across
/// that worker's chunk (scratch buffers stay allocation-free on the hot
/// path). `f` receives `(state, item_index, item)`.
///
/// `threads <= 1` (or a single item) runs serially on the caller's thread
/// with the exact same item order — the serial and parallel paths are the
/// same code over the same indices, so results are identical.
pub fn scoped_chunk_map<T, R, S, I, F>(
    items: &[T],
    threads: usize,
    init: I,
    f: F,
) -> Vec<R>
where
    T: Sync,
    R: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize, &T) -> R + Sync,
{
    if items.is_empty() {
        return Vec::new();
    }
    if threads <= 1 || items.len() == 1 {
        let mut state = init();
        return items.iter().enumerate().map(|(i, t)| f(&mut state, i, t)).collect();
    }

    let chunk = items.len().div_ceil(threads.min(items.len()));
    let mut out: Vec<Option<R>> = Vec::with_capacity(items.len());
    out.resize_with(items.len(), || None);

    std::thread::scope(|scope| {
        for (ci, (in_chunk, out_chunk)) in
            items.chunks(chunk).zip(out.chunks_mut(chunk)).enumerate()
        {
            let base = ci * chunk;
            let init = &init;
            let f = &f;
            scope.spawn(move || {
                let mut state = init();
                for (j, (item, slot)) in
                    in_chunk.iter().zip(out_chunk.iter_mut()).enumerate()
                {
                    *slot = Some(f(&mut state, base + j, item));
                }
            });
        }
    });

    out.into_iter().map(|o| o.expect("worker filled every slot")).collect()
}

/// [`scoped_chunk_map`] with caller-owned per-worker states: the worker
/// count is `states.len()`, and each worker's state persists across calls
/// — so stage memos and scratch buffers stay warm across rounds (the
/// batched-baseline and MPC-rerank shapes). Results are bit-identical to
/// [`scoped_chunk_map`] for any state history because every consumer's
/// per-item work is a pure function of the item (caches replay, never
/// alter, results).
pub fn scoped_chunk_map_with<T, R, S, F>(items: &[T], states: &mut [S], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    S: Send,
    F: Fn(&mut S, usize, &T) -> R + Sync,
{
    assert!(!states.is_empty(), "scoped_chunk_map_with needs at least one state");
    if items.is_empty() {
        return Vec::new();
    }
    let threads = states.len();
    if threads == 1 || items.len() == 1 {
        let state = &mut states[0];
        return items.iter().enumerate().map(|(i, t)| f(&mut *state, i, t)).collect();
    }

    let chunk = items.len().div_ceil(threads.min(items.len()));
    let mut out: Vec<Option<R>> = Vec::with_capacity(items.len());
    out.resize_with(items.len(), || None);

    std::thread::scope(|scope| {
        for (ci, ((in_chunk, out_chunk), state)) in items
            .chunks(chunk)
            .zip(out.chunks_mut(chunk))
            .zip(states.iter_mut())
            .enumerate()
        {
            let base = ci * chunk;
            let f = &f;
            scope.spawn(move || {
                for (j, (item, slot)) in
                    in_chunk.iter().zip(out_chunk.iter_mut()).enumerate()
                {
                    *slot = Some(f(&mut *state, base + j, item));
                }
            });
        }
    });

    out.into_iter().map(|o| o.expect("worker filled every slot")).collect()
}

/// Map `f` over *mutable* items with up to `threads` workers, preserving
/// input order in the output — the vec-env shape: each item is one lane
/// owning its own scratch/cache/RNG state, mutated in place while a
/// result is collected. `f` receives `(item_index, item)`. Chunking is
/// contiguous and outputs are written by input position, so results (and
/// all per-item state mutations) are bit-identical to the `threads <= 1`
/// serial loop — per-item work must not depend on other items, which
/// `&mut` disjointness already enforces at compile time.
pub fn scoped_chunk_map_mut<T, R, F>(items: &mut [T], threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, &mut T) -> R + Sync,
{
    if items.is_empty() {
        return Vec::new();
    }
    if threads <= 1 || items.len() == 1 {
        return items.iter_mut().enumerate().map(|(i, t)| f(i, t)).collect();
    }

    let chunk = items.len().div_ceil(threads.min(items.len()));
    let n = items.len();
    let mut out: Vec<Option<R>> = Vec::with_capacity(n);
    out.resize_with(n, || None);

    std::thread::scope(|scope| {
        for (ci, (in_chunk, out_chunk)) in
            items.chunks_mut(chunk).zip(out.chunks_mut(chunk)).enumerate()
        {
            let base = ci * chunk;
            let f = &f;
            scope.spawn(move || {
                for (j, (item, slot)) in
                    in_chunk.iter_mut().zip(out_chunk.iter_mut()).enumerate()
                {
                    *slot = Some(f(base + j, item));
                }
            });
        }
    });

    out.into_iter().map(|o| o.expect("worker filled every slot")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let items: Vec<usize> = (0..37).collect();
        let serial = scoped_chunk_map(&items, 1, || (), |_, i, &x| x * 10 + i);
        let parallel = scoped_chunk_map(&items, 4, || (), |_, i, &x| x * 10 + i);
        assert_eq!(serial, parallel);
        assert_eq!(serial[5], 55);
    }

    #[test]
    fn per_worker_state_is_reused_within_a_chunk() {
        let items = [1u64, 2, 3, 4, 5, 6, 7, 8];
        // state counts items seen by this worker; with 2 threads and 8
        // items each worker sees its chunk in order
        let counts = scoped_chunk_map(
            &items,
            2,
            || 0u64,
            |seen, _, _| {
                *seen += 1;
                *seen
            },
        );
        assert_eq!(counts, vec![1, 2, 3, 4, 1, 2, 3, 4]);
    }

    #[test]
    fn handles_empty_and_oversubscribed() {
        let empty: Vec<u32> = vec![];
        assert!(scoped_chunk_map(&empty, 8, || (), |_, _, &x| x).is_empty());
        let one = [9u32];
        assert_eq!(scoped_chunk_map(&one, 16, || (), |_, _, &x| x), vec![9]);
        let items: Vec<u32> = (0..3).collect();
        assert_eq!(
            scoped_chunk_map(&items, 64, || (), |_, _, &x| x + 1),
            vec![1, 2, 3]
        );
    }

    #[test]
    fn resolve_zero_is_auto() {
        assert!(resolve(0) >= 1);
        assert_eq!(resolve(3), 3);
    }

    #[test]
    fn reserving_floors_at_one_worker() {
        let all = num_threads();
        assert_eq!(num_threads_reserving(0), all);
        assert_eq!(num_threads_reserving(1), all.saturating_sub(1).max(1));
        // even absurd reservations leave one rollout worker
        assert_eq!(num_threads_reserving(usize::MAX), 1);
    }

    #[test]
    fn mut_variant_matches_serial_and_mutates_items() {
        let mut serial: Vec<u64> = (0..23).collect();
        let mut par = serial.clone();
        let r_s = scoped_chunk_map_mut(&mut serial, 1, |i, x| {
            *x += 100;
            *x * 10 + i as u64
        });
        let r_p = scoped_chunk_map_mut(&mut par, 4, |i, x| {
            *x += 100;
            *x * 10 + i as u64
        });
        assert_eq!(r_s, r_p);
        assert_eq!(serial, par);
        assert_eq!(serial[3], 103);
        let mut empty: Vec<u8> = vec![];
        assert!(scoped_chunk_map_mut(&mut empty, 4, |_, _| ()).is_empty());
    }

    #[test]
    fn with_states_matches_init_variant_and_persists() {
        let items: Vec<usize> = (0..23).collect();
        let fresh = scoped_chunk_map(&items, 4, || (), |_, i, &x| x * 10 + i);
        let mut states = vec![(), (), (), ()];
        let kept = scoped_chunk_map_with(&items, &mut states, |_, i, &x| x * 10 + i);
        assert_eq!(fresh, kept);

        // states persist across calls: each worker keeps counting
        let mut counters = vec![0u64, 0];
        let items8 = [0u8; 8];
        let first = scoped_chunk_map_with(&items8, &mut counters, |c, _, _| {
            *c += 1;
            *c
        });
        assert_eq!(first, vec![1, 2, 3, 4, 1, 2, 3, 4]);
        let second = scoped_chunk_map_with(&items8, &mut counters, |c, _, _| {
            *c += 1;
            *c
        });
        assert_eq!(second, vec![5, 6, 7, 8, 5, 6, 7, 8]);
    }
}
