//! Fingerprint-keyed memoization of [`EvalOutcome`]s.
//!
//! [`Evaluator::evaluate`] is pure in `(mesh, action)`, so an outcome can
//! be replayed from a cache keyed on exactly those inputs. Algorithm 1
//! revisits design points often — deterministic exploitation actions at a
//! converged policy, grid-search lattice recycling, the MPC candidate
//! blend collapsing to the SAC mean — and each hit skips the ~10 ms
//! codegen+simulation step the paper quotes.
//!
//! Keys hash the *raw inputs* (mesh fields, the exact f64 bits of the 30
//! continuous dims, the 4 discrete deltas) with FNV-1a, not the decoded
//! configuration: two different raw actions that decode identically are
//! separate entries, but one raw action always maps to one entry — a hit
//! can never return a different design than recomputation would.

use std::collections::HashMap;

use crate::arch::MeshConfig;
use crate::env::Action;
use crate::eval::{EvalOutcome, EvalScratch, Evaluator};

/// FNV-1a fingerprint of an evaluation input `(mesh, action)`.
pub fn input_key(mesh: &MeshConfig, a: &Action) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut mix = |v: u64| {
        h ^= v;
        h = h.wrapping_mul(0x100000001b3);
    };
    mix(mesh.width as u64);
    mix(mesh.height as u64);
    mix(mesh.sc_x as u64);
    mix(mesh.sc_y as u64);
    for &c in &a.cont {
        mix(c.to_bits());
    }
    for &d in &a.deltas {
        mix(d as u64);
    }
    h
}

/// Bounded memo cache over evaluation outcomes.
#[derive(Debug, Default)]
pub struct EvalCache {
    map: HashMap<u64, EvalOutcome>,
    capacity: usize,
    pub hits: u64,
    pub misses: u64,
}

impl EvalCache {
    /// `capacity` bounds resident outcomes (each holds per-tile vectors —
    /// tens of KB at large meshes). 0 disables caching entirely.
    pub fn new(capacity: usize) -> EvalCache {
        EvalCache { map: HashMap::new(), capacity, hits: 0, misses: 0 }
    }

    /// Evaluate through the cache: replay a stored outcome when the exact
    /// `(mesh, action)` input has been scored before, else compute and
    /// store. When full, the cache resets wholesale — a deterministic
    /// eviction policy (no clock, no access order) so cached and
    /// uncached runs stay reproducible.
    pub fn evaluate(
        &mut self,
        ev: &Evaluator,
        mesh: &MeshConfig,
        a: &Action,
        scratch: &mut EvalScratch,
    ) -> EvalOutcome {
        if self.capacity == 0 {
            return ev.evaluate(mesh, a, scratch);
        }
        let key = input_key(mesh, a);
        if let Some(out) = self.map.get(&key) {
            self.hits += 1;
            return out.clone();
        }
        self.misses += 1;
        let out = ev.evaluate(mesh, a, scratch);
        if self.map.len() >= self.capacity {
            self.map.clear();
        }
        self.map.insert(key, out.clone());
        out
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Granularity, RunConfig};

    fn evaluator() -> Evaluator {
        let mut c = RunConfig::default();
        c.granularity = Granularity::Group;
        Evaluator::new(&c, 3)
    }

    #[test]
    fn keys_separate_inputs() {
        let m = MeshConfig::new(8, 8);
        let a = Action::neutral();
        let mut b = Action::neutral();
        b.cont[0] = 1e-12; // tiniest perturbation still re-keys
        assert_ne!(input_key(&m, &a), input_key(&m, &b));
        assert_ne!(input_key(&m, &a), input_key(&MeshConfig::new(8, 9), &a));
        assert_eq!(input_key(&m, &a), input_key(&m, &Action::neutral()));
    }

    #[test]
    fn hit_equals_recomputation() {
        let ev = evaluator();
        let mesh = ev.initial_mesh();
        let mut scratch = EvalScratch::default();
        let mut cache = EvalCache::new(16);

        let first = cache.evaluate(&ev, &mesh, &Action::neutral(), &mut scratch);
        assert_eq!((cache.hits, cache.misses), (0, 1));
        let hit = cache.evaluate(&ev, &mesh, &Action::neutral(), &mut scratch);
        assert_eq!((cache.hits, cache.misses), (1, 1));
        let fresh = ev.evaluate(&mesh, &Action::neutral(), &mut scratch);

        for (a, b) in [(&first, &hit), (&hit, &fresh)] {
            assert_eq!(a.reward.total.to_bits(), b.reward.total.to_bits());
            assert_eq!(a.reward.score.to_bits(), b.reward.score.to_bits());
            assert_eq!(a.ppa.tokens_per_s.to_bits(), b.ppa.tokens_per_s.to_bits());
            assert_eq!(a.decoded.mesh, b.decoded.mesh);
            assert_eq!(a.tiles.len(), b.tiles.len());
        }
        assert!(cache.hit_rate() > 0.0);
    }

    #[test]
    fn capacity_bounds_and_zero_disables() {
        let ev = evaluator();
        let mesh = ev.initial_mesh();
        let mut scratch = EvalScratch::default();

        let mut tiny = EvalCache::new(2);
        for i in 0..5 {
            let mut a = Action::neutral();
            a.cont[0] = i as f64 * 0.1;
            tiny.evaluate(&ev, &mesh, &a, &mut scratch);
        }
        assert!(tiny.len() <= 2);

        let mut off = EvalCache::new(0);
        off.evaluate(&ev, &mesh, &Action::neutral(), &mut scratch);
        off.evaluate(&ev, &mesh, &Action::neutral(), &mut scratch);
        assert_eq!(off.len(), 0);
        assert_eq!((off.hits, off.misses), (0, 0));
    }
}
