//! Fingerprint-keyed memoization for the evaluation layer: the
//! whole-outcome memo ([`EvalCache`]) plus the per-stage placement memo
//! ([`StageCache`]) of the stage-split pipeline (DESIGN.md §5).
//!
//! [`Evaluator::evaluate`] is pure in `(mesh, action)`, so an outcome can
//! be replayed from a cache keyed on exactly those inputs. Algorithm 1
//! revisits design points often — deterministic exploitation actions at a
//! converged policy, grid-search lattice recycling, the MPC candidate
//! blend collapsing to the SAC mean — and each hit skips the ~10 ms
//! codegen+simulation step the paper quotes.
//!
//! Keys hash the *raw inputs* (mesh fields, the exact f64 bits of the
//! continuous dims, the discrete deltas, and the dimensionality of both)
//! with FNV-1a, not the decoded configuration: two different raw actions
//! that decode identically are separate entries, but one raw action
//! always maps to one entry — a hit can never return a different design
//! than recomputation would. Mixing the lengths prevents actions of
//! differing dimensionality from aliasing to the same key (a `[x]`
//! continuous vector with an empty delta list must not collide with an
//! empty vector whose first delta carries the same bits).
//!
//! The stage memo exploits that placement (§3.5) reads only the mesh
//! dims, the partition knobs and the hazard mitigation — not the
//! clock/voltage/memory dims — so continuous-knob-only perturbations (the
//! common SAC case) reuse the expensive O(units × cores) placement and
//! re-run only the cheap PPA + reward stages.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::arch::MeshConfig;
use crate::config::{ModeConfig, NodeBudget};
use crate::env::Action;
use crate::eval::{EvalOutcome, EvalScratch, Evaluator};
use crate::hazard::Mitigation;
use crate::ir::spec::{Phase, Scenario};
use crate::kv::KvStrategy;
use crate::partition::{self, PartitionKnobs, PlaceScratch, Placement, Unit};

/// FNV-1a accumulator — the one hash implementation behind every memo
/// key in the evaluation layer ([`fingerprint_parts`], [`units_key`],
/// [`place_key`], [`crate::eval::config_key`]).
#[derive(Debug)]
pub struct Fnv(u64);

impl Default for Fnv {
    fn default() -> Self {
        Fnv::new()
    }
}

impl Fnv {
    pub fn new() -> Fnv {
        Fnv(0xcbf29ce484222325)
    }

    pub fn mix(&mut self, v: u64) {
        self.0 ^= v;
        self.0 = self.0.wrapping_mul(0x100000001b3);
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// FNV-1a over raw evaluation-input parts. `cont`/`deltas` lengths are
/// mixed before their payloads so differing dimensionalities cannot alias.
pub fn fingerprint_parts(mesh: &MeshConfig, cont: &[f64], deltas: &[i32]) -> u64 {
    let mut h = Fnv::new();
    h.mix(mesh.width as u64);
    h.mix(mesh.height as u64);
    h.mix(mesh.sc_x as u64);
    h.mix(mesh.sc_y as u64);
    h.mix(cont.len() as u64);
    for &c in cont {
        h.mix(c.to_bits());
    }
    h.mix(deltas.len() as u64);
    for &d in deltas {
        h.mix(d as u64);
    }
    h.finish()
}

/// FNV-1a fingerprint of an evaluation input `(mesh, action)`.
pub fn input_key(mesh: &MeshConfig, a: &Action) -> u64 {
    fingerprint_parts(mesh, &a.cont, &a.deltas)
}

/// [`input_key`] salted with an evaluator identity
/// ([`Evaluator::eval_salt`]) — the [`EvalCache`] key, so a cache shared
/// across evaluators or scenarios can never replay a foreign outcome.
pub fn salted_input_key(salt: u64, mesh: &MeshConfig, a: &Action) -> u64 {
    let mut h = Fnv::new();
    h.mix(salt);
    h.mix(input_key(mesh, a));
    h.finish()
}

/// FNV-1a fingerprint of an evaluation *context*: the unit-list salt
/// plus everything else outcome-relevant that is not part of the raw
/// `(mesh, action)` input — process node, scenario (phase, context
/// length, batch), the base KV strategy, and the optimization mode /
/// node budget (decode reads the mode's clock/α/activity profile, reward
/// reads the weights and budget). Two evaluators agree on this salt only
/// if [`Evaluator::evaluate`] is the same pure function for both, so
/// whole-outcome memo hits can never cross scenarios or modes.
pub fn scenario_salt(
    units_key: u64,
    nm: u32,
    scn: &Scenario,
    kv: KvStrategy,
    mode: &ModeConfig,
    budget: &NodeBudget,
) -> u64 {
    let mut h = Fnv::new();
    h.mix(units_key);
    h.mix(nm as u64);
    h.mix(scn.seq_len as u64);
    h.mix(scn.batch as u64);
    h.mix(match scn.phase {
        Phase::Prefill => 1,
        Phase::Decode => 2,
    });
    let (tag, p0, p1) = match kv {
        KvStrategy::Full => (0u64, 0u64, 0u64),
        KvStrategy::Quantized { bits } => (1, bits as u64, 0),
        KvStrategy::Window { tokens } => (2, tokens as u64, 0),
        KvStrategy::QuantizedWindow { bits, tokens } => (3, bits as u64, tokens as u64),
        KvStrategy::Paged { page_kb } => (4, page_kb as u64, 0),
    };
    h.mix(tag);
    h.mix(p0);
    h.mix(p1);
    // optimization mode: everything decode/reward reads from it
    h.mix(mode.name.len() as u64);
    for b in mode.name.bytes() {
        h.mix(b as u64);
    }
    h.mix(mode.weights.perf.to_bits());
    h.mix(mode.weights.power.to_bits());
    h.mix(mode.weights.area.to_bits());
    h.mix(mode.pin_clock_to_fmax as u64);
    h.mix(match mode.clock_mhz_fixed {
        Some(f) => f.to_bits(),
        None => 1,
    });
    h.mix(mode.alpha_spec.to_bits());
    h.mix(mode.activity.to_bits());
    // node budget (normalization ranges + feasibility surface)
    h.mix(budget.power_budget_mw.to_bits());
    h.mix(budget.area_budget_mm2.to_bits());
    h.mix(budget.perf_max_gops.to_bits());
    h.finish()
}

/// FNV-1a fingerprint of a placement-unit list — the per-Evaluator salt
/// for [`place_key`], so a scratch shared across evaluators of different
/// workloads/granularities can never replay the wrong placement.
pub fn units_key(units: &[Unit]) -> u64 {
    let mut h = Fnv::new();
    h.mix(units.len() as u64);
    for u in units {
        h.mix(u.class as u64);
        h.mix(u.kind as u64);
        h.mix(u.flops.to_bits());
        h.mix(u.weight_bytes.to_bits());
        h.mix(u.out_bytes.to_bits());
        h.mix(u.instrs.to_bits());
        h.mix(u.inputs.len() as u64);
        for &i in &u.inputs {
            h.mix(i as u64);
        }
    }
    h.finish()
}

/// FNV-1a fingerprint of exactly the inputs the placement stage reads:
/// the unit-list salt ([`units_key`], hoisted per Evaluator), mesh dims
/// (the SC overlay does not affect placement), the partition knobs and
/// the hazard mitigation. Clock, voltage and memory dims are
/// deliberately absent — perturbing them must hit.
pub fn place_key(salt: u64, mesh: &MeshConfig, knobs: &PartitionKnobs, mit: &Mitigation) -> u64 {
    let mut h = Fnv::new();
    h.mix(salt);
    h.mix(mesh.width as u64);
    h.mix(mesh.height as u64);
    let knob_bits = [
        knobs.rho_base,
        knobs.d_matmul,
        knobs.d_conv,
        knobs.d_general,
        knobs.w_load,
        knobs.streaming_in,
        knobs.streaming_out,
        knobs.sub_matmul,
        knobs.allreduce_frac,
    ];
    h.mix(knob_bits.len() as u64);
    for k in knob_bits {
        h.mix(k.to_bits());
    }
    h.mix(4);
    h.mix(mit.stanum as u64);
    h.mix(mit.fetch as u64);
    h.mix(mit.xr_wp as u64);
    h.mix(mit.vr_wp as u64);
    h.finish()
}

/// Bounded memo cache over evaluation outcomes.
#[derive(Debug, Default)]
pub struct EvalCache {
    map: HashMap<u64, EvalOutcome>,
    /// Resident entries per [`Evaluator::eval_salt`] — the cross-scenario
    /// occupancy ledger of a cache shared by the atlas sweep. Reset
    /// together with `map` on the wholesale eviction.
    per_salt: HashMap<u64, u64>,
    capacity: usize,
    pub hits: u64,
    pub misses: u64,
    /// Entries dropped by the wholesale capacity reset.
    pub evictions: u64,
}

/// Cross-scenario occupancy snapshot of an [`EvalCache`]: how many
/// outcomes each scenario salt keeps resident, plus lifetime hit/miss
/// counters. Surfaced in Table 14 and the atlas summary so cache-sharing
/// wins are measurable (DESIGN.md §12).
#[derive(Debug, Clone, Default)]
pub struct CacheOccupancy {
    pub entries: usize,
    /// `(eval_salt, resident entries)`, sorted by salt for determinism.
    pub salts: Vec<(u64, u64)>,
    pub hits: u64,
    pub misses: u64,
}

impl CacheOccupancy {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

impl EvalCache {
    /// `capacity` bounds resident outcomes (each holds per-tile vectors —
    /// tens of KB at large meshes). 0 disables caching entirely.
    pub fn new(capacity: usize) -> EvalCache {
        EvalCache {
            map: HashMap::new(),
            per_salt: HashMap::new(),
            capacity,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// Probe half of the memo: replay a stored outcome for `key`
    /// ([`salted_input_key`]), counting the hit or miss. The split
    /// probe/[`Self::admit`] pair lets [`SharedEvalCache`] drop its lock
    /// while the real evaluation runs.
    pub fn lookup(&mut self, key: u64) -> Option<EvalOutcome> {
        if let Some(out) = self.map.get(&key) {
            self.hits += 1;
            return Some(out.clone());
        }
        self.misses += 1;
        None
    }

    /// Store half of the memo: admit a freshly computed outcome under
    /// `key`, whose salt must be the `salt` used to derive it. When full,
    /// the cache resets wholesale — a deterministic eviction policy (no
    /// clock, no access order) so cached and uncached runs stay
    /// reproducible.
    pub fn admit(&mut self, salt: u64, key: u64, out: EvalOutcome) {
        if self.capacity == 0 {
            return;
        }
        if self.map.len() >= self.capacity {
            self.evictions += self.map.len() as u64;
            self.map.clear();
            self.per_salt.clear();
        }
        if self.map.insert(key, out).is_none() {
            *self.per_salt.entry(salt).or_insert(0) += 1;
        }
    }

    /// Evaluate through the cache: replay a stored outcome when the exact
    /// `(mesh, action)` input has been scored before *by an equivalent
    /// evaluator* (keys carry [`Evaluator::eval_salt`], so entries never
    /// leak across workloads, nodes, scenarios or KV strategies), else
    /// compute and store.
    pub fn evaluate(
        &mut self,
        ev: &Evaluator,
        mesh: &MeshConfig,
        a: &Action,
        scratch: &mut EvalScratch,
    ) -> EvalOutcome {
        if self.capacity == 0 {
            return ev.evaluate(mesh, a, scratch);
        }
        let salt = ev.eval_salt();
        let key = salted_input_key(salt, mesh, a);
        if let Some(out) = self.lookup(key) {
            return out;
        }
        let out = ev.evaluate(mesh, a, scratch);
        self.admit(salt, key, out.clone());
        out
    }

    /// Cross-scenario occupancy snapshot (entries per salt + counters).
    pub fn occupancy(&self) -> CacheOccupancy {
        let mut salts: Vec<(u64, u64)> =
            self.per_salt.iter().map(|(&s, &n)| (s, n)).collect();
        salts.sort_unstable();
        CacheOccupancy { entries: self.map.len(), salts, hits: self.hits, misses: self.misses }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// One process-wide [`EvalCache`] shared by every lane and scenario point
/// of an atlas sweep. Safe because keys carry [`Evaluator::eval_salt`]
/// (no cross-scenario replay — pinned by
/// `eval_cache_never_replays_across_scenarios`) and a replayed outcome is
/// bit-identical to recomputation, so sharing never perturbs lane
/// determinism. Locking is two-phase: probe under the lock, run the real
/// evaluation *outside* it, admit under the lock — concurrent lanes never
/// serialize on the expensive pipeline. A lost race means both lanes
/// compute the same pure outcome and the second admit overwrites it with
/// identical bits.
#[derive(Debug, Clone)]
pub struct SharedEvalCache(Arc<Mutex<EvalCache>>);

impl SharedEvalCache {
    pub fn new(capacity: usize) -> SharedEvalCache {
        SharedEvalCache(Arc::new(Mutex::new(EvalCache::new(capacity))))
    }

    /// Evaluate through the shared memo (see type docs for the locking
    /// discipline).
    pub fn evaluate(
        &self,
        ev: &Evaluator,
        mesh: &MeshConfig,
        a: &Action,
        scratch: &mut EvalScratch,
    ) -> EvalOutcome {
        let salt = ev.eval_salt();
        let key = salted_input_key(salt, mesh, a);
        {
            let mut c = self.0.lock().unwrap();
            if c.capacity == 0 {
                drop(c);
                return ev.evaluate(mesh, a, scratch);
            }
            if let Some(out) = c.lookup(key) {
                return out;
            }
        }
        let out = ev.evaluate(mesh, a, scratch);
        self.0.lock().unwrap().admit(salt, key, out.clone());
        out
    }

    /// Cross-scenario occupancy snapshot (entries per salt + counters).
    pub fn occupancy(&self) -> CacheOccupancy {
        self.0.lock().unwrap().occupancy()
    }

    /// Lifetime `(hits, misses)` — the atlas diffs consecutive snapshots
    /// to attribute a hit rate to each scenario point.
    pub fn counters(&self) -> (u64, u64) {
        let c = self.0.lock().unwrap();
        (c.hits, c.misses)
    }

    /// Fold the shared counters into run stats (the shared cache outlives
    /// every lane, so this runs once at the end of a sweep).
    pub fn absorb_into(&self, stats: &mut EvalStats) {
        let c = self.0.lock().unwrap();
        stats.outcome_hits += c.hits;
        stats.outcome_misses += c.misses;
        stats.outcome_evictions += c.evictions;
    }
}

/// Per-stage memo for the placement stage of the split pipeline. Keyed by
/// [`place_key`] — only the inputs placement actually reads — and bounded
/// with the same deterministic wholesale reset as [`EvalCache`]. Owned by
/// an [`EvalScratch`], so each worker thread memoizes independently (no
/// locks on the hot path) and a cached run stays bit-identical to an
/// uncached one (placement is a pure function of the key inputs).
///
/// Entries hold the placement *before* KV distribution (Eq 27): the KV
/// slice depends on the KV strategy, which is not part of the key, so the
/// caller re-applies [`partition::distribute_kv`] on a clone per hit.
#[derive(Debug)]
pub struct StageCache {
    map: HashMap<u64, Placement>,
    capacity: usize,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

/// Default placement-memo capacity per scratch (a 23×23-mesh placement is
/// ~25 KB; 64 entries keep a worker well under 2 MB at typical scales).
pub const DEFAULT_STAGE_CAPACITY: usize = 64;

impl Default for StageCache {
    fn default() -> Self {
        StageCache::new(DEFAULT_STAGE_CAPACITY)
    }
}

impl StageCache {
    /// `capacity` bounds resident placements; 0 disables the stage memo.
    pub fn new(capacity: usize) -> StageCache {
        StageCache { map: HashMap::new(), capacity, hits: 0, misses: 0, evictions: 0 }
    }

    /// Place `units` through the memo: replay when the (units salt, mesh
    /// dims, knobs, mitigation) key has been placed before, else run the
    /// real placement and store. Returns the pre-KV placement either way.
    /// `salt` must be [`units_key`]`(units)` (the evaluator hoists it).
    pub fn place(
        &mut self,
        salt: u64,
        units: &[Unit],
        mesh: &MeshConfig,
        knobs: &PartitionKnobs,
        mit: &Mitigation,
        scratch: &mut PlaceScratch,
    ) -> Placement {
        if self.capacity == 0 {
            return partition::place_units_with(units, mesh, knobs, mit, scratch);
        }
        let key = place_key(salt, mesh, knobs, mit);
        if let Some(p) = self.map.get(&key) {
            self.hits += 1;
            return p.clone();
        }
        self.misses += 1;
        let p = partition::place_units_with(units, mesh, knobs, mit, scratch);
        if self.map.len() >= self.capacity {
            self.evictions += self.map.len() as u64;
            self.map.clear();
        }
        self.map.insert(key, p.clone());
        p
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Aggregated evaluation-layer counters for the run report: whole-outcome
/// memo, placement-stage memo, mesh-geometry cache and roofline admission
/// pruning.
#[derive(Debug, Clone, Copy, Default)]
pub struct EvalStats {
    pub outcome_hits: u64,
    pub outcome_misses: u64,
    pub outcome_evictions: u64,
    pub place_hits: u64,
    pub place_misses: u64,
    pub place_evictions: u64,
    pub geom_hits: u64,
    pub geom_misses: u64,
    /// Geometry tables served from the process-wide shared registry
    /// instead of being rebuilt (one table per mesh-dims across all
    /// lanes and scenario points).
    pub geom_shared: u64,
    /// Candidates rejected by the roofline admission bound without a full
    /// evaluation.
    pub pruned: u64,
    /// Candidates that went through the full pipeline on pruning paths.
    pub evaluated: u64,
}

impl EvalStats {
    pub fn merge(&mut self, o: &EvalStats) {
        self.outcome_hits += o.outcome_hits;
        self.outcome_misses += o.outcome_misses;
        self.outcome_evictions += o.outcome_evictions;
        self.place_hits += o.place_hits;
        self.place_misses += o.place_misses;
        self.place_evictions += o.place_evictions;
        self.geom_hits += o.geom_hits;
        self.geom_misses += o.geom_misses;
        self.geom_shared += o.geom_shared;
        self.pruned += o.pruned;
        self.evaluated += o.evaluated;
    }

    /// Fold in the counters of a whole-outcome memo.
    pub fn absorb_outcome_cache(&mut self, c: &EvalCache) {
        self.outcome_hits += c.hits;
        self.outcome_misses += c.misses;
        self.outcome_evictions += c.evictions;
    }

    /// Fold in the stage-memo + geometry counters of one worker scratch.
    pub fn absorb_scratch(&mut self, s: &EvalScratch) {
        self.place_hits += s.stages.hits;
        self.place_misses += s.stages.misses;
        self.place_evictions += s.stages.evictions;
        self.geom_hits += s.place.geom.hits;
        self.geom_misses += s.place.geom.misses;
        self.geom_shared += s.place.geom.shared;
    }

    fn rate(hits: u64, misses: u64) -> f64 {
        let total = hits + misses;
        if total == 0 {
            0.0
        } else {
            hits as f64 / total as f64
        }
    }

    pub fn outcome_hit_rate(&self) -> f64 {
        Self::rate(self.outcome_hits, self.outcome_misses)
    }

    pub fn place_hit_rate(&self) -> f64 {
        Self::rate(self.place_hits, self.place_misses)
    }

    /// Fraction of batch candidates rejected by the admission bound.
    pub fn prune_rate(&self) -> f64 {
        Self::rate(self.pruned, self.evaluated)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Granularity, RunConfig};

    fn evaluator() -> Evaluator {
        let mut c = RunConfig::default();
        c.granularity = Granularity::Group;
        Evaluator::new(&c, 3)
    }

    #[test]
    fn keys_separate_inputs() {
        let m = MeshConfig::new(8, 8);
        let a = Action::neutral();
        let mut b = Action::neutral();
        b.cont[0] = 1e-12; // tiniest perturbation still re-keys
        assert_ne!(input_key(&m, &a), input_key(&m, &b));
        assert_ne!(input_key(&m, &a), input_key(&MeshConfig::new(8, 9), &a));
        assert_eq!(input_key(&m, &a), input_key(&m, &Action::neutral()));
    }

    #[test]
    fn fingerprint_mixes_dimensionality() {
        // without length mixing these alias: a lone 0.0 continuous dim
        // hashes the same bits as a lone 0 delta
        let m = MeshConfig::new(8, 8);
        assert_ne!(
            fingerprint_parts(&m, &[0.0], &[]),
            fingerprint_parts(&m, &[], &[0])
        );
        // moving the boundary between the two sections must re-key even
        // when the payload bit stream is unchanged
        assert_ne!(
            fingerprint_parts(&m, &[0.0, 0.0], &[1]),
            fingerprint_parts(&m, &[0.0], &[0, 1])
        );
        assert_eq!(
            fingerprint_parts(&m, &[0.5], &[1, -1]),
            fingerprint_parts(&m, &[0.5], &[1, -1])
        );
    }

    #[test]
    fn place_key_ignores_non_placement_dims() {
        let mit = Mitigation { stanum: 4, fetch: 4, xr_wp: 2, vr_wp: 2 };
        let knobs = PartitionKnobs::default();
        let m = MeshConfig::new(8, 8);
        // SC overlay is not read by placement: same key
        let mut m_sc = m;
        m_sc.sc_x = 8;
        m_sc.sc_y = 1;
        assert_eq!(place_key(0, &m, &knobs, &mit), place_key(0, &m_sc, &knobs, &mit));
        // unit salt, mesh dims, knobs and mitigation all re-key
        assert_ne!(place_key(0, &m, &knobs, &mit), place_key(1, &m, &knobs, &mit));
        assert_ne!(
            place_key(0, &m, &knobs, &mit),
            place_key(0, &MeshConfig::new(8, 9), &knobs, &mit)
        );
        let mut k2 = knobs;
        k2.sub_matmul += 1e-12;
        assert_ne!(place_key(0, &m, &knobs, &mit), place_key(0, &m, &k2, &mit));
        let mit2 = Mitigation { stanum: 5, ..mit };
        assert_ne!(place_key(0, &m, &knobs, &mit), place_key(0, &m, &knobs, &mit2));
    }

    #[test]
    fn stage_cache_is_safe_across_evaluators() {
        // a scratch shared between evaluators of different workloads must
        // never replay the other workload's placement, even when mesh
        // dims, knobs and mitigation coincide — the units salt re-keys
        let ev_a = evaluator(); // llama, group granularity
        let mut c = RunConfig::smolvlm_low_power();
        c.granularity = Granularity::Group;
        let ev_b = Evaluator::new(&c, 3);

        let m = MeshConfig::new(4, 4);
        let (da, _) = ev_a.stage_decode(&m, &Action::neutral());
        let (db, _) = ev_b.stage_decode(&m, &Action::neutral());

        let mut shared = EvalScratch::default();
        let pa = ev_a.stage_place(&da, &mut shared);
        let pb = ev_b.stage_place(&db, &mut shared);
        let pb_fresh = ev_b.stage_place(&db, &mut EvalScratch::default());
        for (x, y) in pb.loads.iter().zip(&pb_fresh.loads) {
            assert_eq!(x.flops.to_bits(), y.flops.to_bits());
            assert_eq!(x.weight_bytes.to_bits(), y.weight_bytes.to_bits());
        }
        // and the two workloads genuinely place differently
        assert!(pa
            .loads
            .iter()
            .zip(&pb.loads)
            .any(|(x, y)| x.flops.to_bits() != y.flops.to_bits()));
    }

    #[test]
    fn eval_cache_never_replays_across_scenarios() {
        // same raw (mesh, action), different scenario axes: the salted
        // keys must miss, and the outcomes must genuinely differ
        let base = {
            let mut c = RunConfig::default();
            c.granularity = Granularity::Group;
            c
        };
        let mut long_ctx = base.clone();
        long_ctx.seq_len = Some(8192);
        let mut single = base.clone();
        single.batch = Some(1);
        let mut prefill = base.clone();
        prefill.phase = crate::ir::Phase::Prefill;

        let ev = Evaluator::new(&base, 3);
        for other_cfg in [&long_ctx, &single, &prefill] {
            let other = Evaluator::new(other_cfg, 3);
            assert_ne!(ev.eval_salt(), other.eval_salt());
        }
        // and a different node or optimization mode re-salts too (decode
        // and reward read the mode's clock/α/weights and the budget)
        assert_ne!(ev.eval_salt(), Evaluator::new(&base, 7).eval_salt());
        let mut lp_mode = base.clone();
        lp_mode.mode = ModeConfig::low_power();
        assert_ne!(ev.eval_salt(), Evaluator::new(&lp_mode, 3).eval_salt());

        let mesh = MeshConfig::new(8, 8);
        let mut cache = EvalCache::new(16);
        let mut scratch = EvalScratch::default();
        let a = Action::neutral();
        let o_base = cache.evaluate(&ev, &mesh, &a, &mut scratch);
        let ev_batch1 = Evaluator::new(&single, 3);
        let o_b1 = cache.evaluate(&ev_batch1, &mesh, &a, &mut scratch);
        assert_eq!((cache.hits, cache.misses), (0, 2), "scenario replayed");
        // batch amortization moves the memory ceiling (Eq 22)
        assert!(o_b1.ppa.ceilings.memory < o_base.ppa.ceilings.memory);
        // identical evaluator context still hits
        let again = cache.evaluate(&ev, &mesh, &a, &mut scratch);
        assert_eq!(cache.hits, 1);
        assert_eq!(
            again.reward.score.to_bits(),
            o_base.reward.score.to_bits()
        );
    }

    #[test]
    fn hit_equals_recomputation() {
        let ev = evaluator();
        let mesh = ev.initial_mesh();
        let mut scratch = EvalScratch::default();
        let mut cache = EvalCache::new(16);

        let first = cache.evaluate(&ev, &mesh, &Action::neutral(), &mut scratch);
        assert_eq!((cache.hits, cache.misses), (0, 1));
        let hit = cache.evaluate(&ev, &mesh, &Action::neutral(), &mut scratch);
        assert_eq!((cache.hits, cache.misses), (1, 1));
        let fresh = ev.evaluate(&mesh, &Action::neutral(), &mut scratch);

        for (a, b) in [(&first, &hit), (&hit, &fresh)] {
            assert_eq!(a.reward.total.to_bits(), b.reward.total.to_bits());
            assert_eq!(a.reward.score.to_bits(), b.reward.score.to_bits());
            assert_eq!(a.ppa.tokens_per_s.to_bits(), b.ppa.tokens_per_s.to_bits());
            assert_eq!(a.decoded.mesh, b.decoded.mesh);
            assert_eq!(a.tiles.len(), b.tiles.len());
        }
        assert!(cache.hit_rate() > 0.0);
    }

    #[test]
    fn capacity_bounds_and_zero_disables() {
        let ev = evaluator();
        let mesh = ev.initial_mesh();
        let mut scratch = EvalScratch::default();

        let mut tiny = EvalCache::new(2);
        for i in 0..5 {
            let mut a = Action::neutral();
            a.cont[0] = i as f64 * 0.1;
            tiny.evaluate(&ev, &mesh, &a, &mut scratch);
        }
        assert!(tiny.len() <= 2);
        assert!(tiny.evictions > 0);

        let mut off = EvalCache::new(0);
        off.evaluate(&ev, &mesh, &Action::neutral(), &mut scratch);
        off.evaluate(&ev, &mesh, &Action::neutral(), &mut scratch);
        assert_eq!(off.len(), 0);
        assert_eq!((off.hits, off.misses), (0, 0));
    }

    #[test]
    fn stage_cache_hits_on_continuous_knob_perturbations() {
        // the common SAC case: a decoded design differing only in
        // non-placement dims (VLEN here) keeps the placement key, so the
        // expensive stage replays; a knob/mitigation change re-places
        let ev = evaluator();
        let mesh = ev.initial_mesh();
        let (d1, _) = ev.stage_decode(&mesh, &Action::neutral());
        let mut d2 = d1.clone();
        d2.avg.vlen_bits *= 2; // memory/compute dim: not in the key

        let mut scratch = EvalScratch::default();
        let p1 = ev.stage_place(&d1, &mut scratch);
        assert_eq!((scratch.stages.hits, scratch.stages.misses), (0, 1));
        let p2 = ev.stage_place(&d2, &mut scratch);
        assert_eq!((scratch.stages.hits, scratch.stages.misses), (1, 1));
        // the replayed placement is the same pure result
        for (a, b) in p1.loads.iter().zip(&p2.loads) {
            assert_eq!(a.flops.to_bits(), b.flops.to_bits());
        }
        // downstream stages still see the VLEN change
        let t1 = ev.stage_tiles(&d1, &p1);
        let t2 = ev.stage_tiles(&d2, &p2);
        assert!(t1.iter().zip(&t2).any(|(a, b)| a.vlen_bits != b.vlen_bits));

        // a partition knob change re-keys and re-places
        let mut d3 = d1.clone();
        d3.knobs.sub_matmul += 0.1;
        ev.stage_place(&d3, &mut scratch);
        assert_eq!(scratch.stages.misses, 2);
        // so does a mitigation (STANUM) change
        let mut d4 = d1.clone();
        d4.avg.stanum += 1;
        ev.stage_place(&d4, &mut scratch);
        assert_eq!(scratch.stages.misses, 3);
    }

    #[test]
    fn stage_cache_zero_capacity_disables() {
        let ev = evaluator();
        let mesh = ev.initial_mesh();
        let mut scratch = EvalScratch::default();
        scratch.stages = StageCache::new(0);
        ev.evaluate(&mesh, &Action::neutral(), &mut scratch);
        ev.evaluate(&mesh, &Action::neutral(), &mut scratch);
        assert_eq!(scratch.stages.len(), 0);
        assert_eq!((scratch.stages.hits, scratch.stages.misses), (0, 0));
    }

    #[test]
    fn occupancy_tracks_entries_per_salt() {
        let base = {
            let mut c = RunConfig::default();
            c.granularity = Granularity::Group;
            c
        };
        let mut batched = base.clone();
        batched.batch = Some(4);
        let ev_a = Evaluator::new(&base, 3);
        let ev_b = Evaluator::new(&batched, 3);
        let mesh = MeshConfig::new(8, 8);
        let mut scratch = EvalScratch::default();
        let mut cache = EvalCache::new(16);
        for i in 0..3 {
            let mut a = Action::neutral();
            a.cont[0] = i as f64 * 0.1;
            cache.evaluate(&ev_a, &mesh, &a, &mut scratch);
        }
        cache.evaluate(&ev_b, &mesh, &Action::neutral(), &mut scratch);
        cache.evaluate(&ev_b, &mesh, &Action::neutral(), &mut scratch); // hit
        let occ = cache.occupancy();
        assert_eq!(occ.entries, 4);
        assert_eq!(occ.salts.len(), 2);
        let mut counts: Vec<u64> = occ.salts.iter().map(|&(_, n)| n).collect();
        counts.sort_unstable();
        assert_eq!(counts, vec![1, 3]);
        assert_eq!((occ.hits, occ.misses), (1, 4));
        assert!((occ.hit_rate() - 0.2).abs() < 1e-12);
        // the wholesale reset clears the ledger with the map
        let mut tiny = EvalCache::new(2);
        for i in 0..5 {
            let mut a = Action::neutral();
            a.cont[0] = i as f64 * 0.1;
            tiny.evaluate(&ev_a, &mesh, &a, &mut scratch);
        }
        let tocc = tiny.occupancy();
        assert_eq!(
            tocc.entries as u64,
            tocc.salts.iter().map(|&(_, n)| n).sum::<u64>()
        );
    }

    #[test]
    fn shared_cache_replays_bit_identically() {
        let ev = evaluator();
        let mesh = ev.initial_mesh();
        let mut scratch = EvalScratch::default();
        let shared = SharedEvalCache::new(16);
        let first = shared.evaluate(&ev, &mesh, &Action::neutral(), &mut scratch);
        let hit = shared.evaluate(&ev, &mesh, &Action::neutral(), &mut scratch);
        let fresh = ev.evaluate(&mesh, &Action::neutral(), &mut scratch);
        for (a, b) in [(&first, &hit), (&hit, &fresh)] {
            assert_eq!(a.reward.score.to_bits(), b.reward.score.to_bits());
            assert_eq!(a.ppa.tokens_per_s.to_bits(), b.ppa.tokens_per_s.to_bits());
            assert_eq!(a.decoded.mesh, b.decoded.mesh);
        }
        assert_eq!(shared.counters(), (1, 1));
        let mut stats = EvalStats::default();
        shared.absorb_into(&mut stats);
        assert_eq!((stats.outcome_hits, stats.outcome_misses), (1, 1));
        // a clone is the same cache, and zero capacity disables cleanly
        let alias = shared.clone();
        alias.evaluate(&ev, &mesh, &Action::neutral(), &mut scratch);
        assert_eq!(shared.counters(), (2, 1));
        let off = SharedEvalCache::new(0);
        off.evaluate(&ev, &mesh, &Action::neutral(), &mut scratch);
        assert_eq!(off.counters(), (0, 0));
    }

    #[test]
    fn eval_stats_merge_and_rates() {
        let s = EvalStats { pruned: 3, evaluated: 1, ..Default::default() };
        let mut t = EvalStats { outcome_hits: 2, outcome_misses: 2, ..Default::default() };
        t.merge(&s);
        assert_eq!(t.pruned, 3);
        assert!((t.outcome_hit_rate() - 0.5).abs() < 1e-12);
        assert!((t.prune_rate() - 0.75).abs() < 1e-12);
        assert_eq!(EvalStats::default().place_hit_rate(), 0.0);
    }
}
