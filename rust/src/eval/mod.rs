//! The stateless evaluation layer: everything between a raw [`Action`]
//! and its [`EvalOutcome`] — decode + constrained projection (Eq 68),
//! operator partitioning (§3.5), KV distribution (Eq 27), heterogeneous
//! per-TCC derivation (§3.3), analytical PPA (Eqs 21–24, 62–64) and
//! reward (Eqs 34–44) — factored out of the MDP environment so it can fan
//! out across cores.
//!
//! Design (DESIGN.md §5):
//! * [`Evaluator`] owns the *immutable* per-(workload, node) context:
//!   graph, placement units, workload stats, node spec, budget, ranges.
//!   [`Evaluator::evaluate`] is a pure function of `(mesh, action)` — no
//!   interior mutability, no RNG — so the same inputs always produce the
//!   same outcome, on any thread.
//! * [`EvalScratch`] carries the reusable working buffers (placement
//!   tile state, score heap, overflow accumulators) so the ~10 ms hot
//!   path stays allocation-free; each worker thread owns one.
//! * [`Evaluator::evaluate_many`] scores a candidate set via scoped-
//!   thread fan-out ([`parallel`]), preserving input order — serial and
//!   parallel runs are bit-identical.
//! * [`cache::EvalCache`] memoizes outcomes keyed by a fingerprint of
//!   `(mesh, action)`, so repeated design points skip re-evaluation.
//!
//! The environment ([`crate::env::Env`]) shrinks to a thin wrapper owning
//! only the walking mesh of Algorithm 1.

pub mod cache;
pub mod parallel;

pub use cache::EvalCache;

use crate::arch::{self, MeshConfig, ParamRanges, TileConfig};
use crate::config::{Granularity, ModeConfig, NodeBudget, RunConfig};
use crate::env::action::{self, Action, DecodedAction};
use crate::env::reward::{self, RewardTerms};
use crate::env::state::{self, FULL_STATE_DIM};
use crate::hazard::Mitigation;
use crate::ir::stats::WorkloadStats;
use crate::ir::Graph;
use crate::kv::{self, KvStrategy};
use crate::node::{NodeSpec, NodeTable};
use crate::partition::{self, PlaceScratch, Placement, Unit};
use crate::ppa::{self, DesignPoint, PpaResult};

/// Full outcome of evaluating one action (one episode body).
#[derive(Debug, Clone)]
pub struct EvalOutcome {
    pub decoded: DecodedAction,
    pub tiles: Vec<TileConfig>,
    pub placement: Placement,
    pub ppa: PpaResult,
    pub reward: RewardTerms,
    pub full_state: [f64; FULL_STATE_DIM],
    /// Constraint-projection shrink steps applied (Eq 68).
    pub proj_steps: u32,
}

/// Reusable per-thread working buffers for the evaluation hot path.
#[derive(Debug, Default)]
pub struct EvalScratch {
    pub place: PlaceScratch,
    /// Per-tile used-WMEM accumulator for the overflow check (Eq 14).
    used_wmem: Vec<f64>,
}

/// Immutable per-(workload, process-node) evaluation context. Shared by
/// reference across worker threads (`&Evaluator` is `Sync`: every field
/// is plain data).
pub struct Evaluator {
    pub graph: Graph,
    pub units: Vec<Unit>,
    pub wstats: WorkloadStats,
    pub node: NodeSpec,
    pub budget: NodeBudget,
    pub mode: ModeConfig,
    pub ranges: ParamRanges,
    pub kv_strategy: KvStrategy,
    pub seq_len: u32,
    pub batch_size: u32,
    /// Σ weight bytes of the graph, hoisted off the per-episode path.
    total_weights: f64,
    /// Model FLOPs per generated token, hoisted off the per-episode path.
    flops_per_token: f64,
}

impl Evaluator {
    pub fn new(cfg: &RunConfig, nm: u32) -> Self {
        let graph = cfg.workload.build();
        let units = match cfg.granularity {
            Granularity::Op => partition::units_from_ops(&graph),
            Granularity::Group => partition::groups::units_from_groups(&graph),
        };
        let wstats = crate::ir::stats::compute(&graph);
        let table = NodeTable::paper();
        let node =
            table.get(nm).unwrap_or_else(|| panic!("unknown node {nm}nm")).clone();
        let budget = *cfg.mode.budget(nm);
        let total_weights = graph.total_weight_bytes();
        let flops_per_token = graph.flops_per_token_model();
        Evaluator {
            graph,
            units,
            wstats,
            node,
            budget,
            mode: cfg.mode.clone(),
            ranges: ParamRanges::paper(),
            kv_strategy: cfg.kv_strategy,
            seq_len: cfg.workload.seq_len(),
            batch_size: 3, // paper's Llama evaluation batch (Table 9)
            total_weights,
            flops_per_token,
        }
    }

    /// Initial mesh m₀(n) of Algorithm 1 for this workload/mode.
    pub fn initial_mesh(&self) -> MeshConfig {
        initial_mesh(&self.graph, &self.mode)
    }

    /// Evaluate a raw action against `mesh`: the full §3.5 + §3.6–3.9 +
    /// §3.10 pipeline. Pure: does not advance any mesh — the caller owns
    /// the Algorithm 1 walk (see [`crate::env::Env::eval_action`]).
    pub fn evaluate(
        &self,
        mesh: &MeshConfig,
        a: &Action,
        scratch: &mut EvalScratch,
    ) -> EvalOutcome {
        // 1. decode + constraint projection (Eq 68)
        let decoded = action::decode(
            a,
            mesh,
            &self.node,
            &self.mode,
            &self.ranges,
            self.kv_strategy,
            self.seq_len,
        );
        let (decoded, proj_steps) =
            action::project(decoded, &self.node, &self.budget, self.total_weights);

        // 2. operator partitioning + placement (§3.5)
        let mit = Mitigation {
            stanum: decoded.avg.stanum,
            fetch: decoded.avg.fetch,
            xr_wp: decoded.avg.xr_wp,
            vr_wp: decoded.avg.vr_wp,
        };
        let mut placement = partition::place_units_with(
            &self.units,
            &decoded.mesh,
            &decoded.knobs,
            &mit,
            &mut scratch.place,
        );

        // 3. KV-cache distribution across active tiles (Eq 27)
        let kv_total = match self.graph.kv {
            Some(kvc) => kv::total_bytes(&kvc, self.seq_len, decoded.kv_strategy),
            None => 0.0,
        };
        partition::distribute_kv(&mut placement.loads, kv_total);

        // 4. heterogeneous per-TCC derivation (§3.3)
        let tiles =
            arch::derive_tiles(&decoded.mesh, &decoded.avg, &placement.loads, &self.ranges);

        // 5. assemble the design point for the analytical models
        let d = self.design_point(&decoded, &placement, &tiles);

        // 6. analytical PPA (Eqs 21-24, 62-64)
        let ppa_result = ppa::evaluate(&d, &self.node);

        // 7. feasibility + reward (Eqs 34-44)
        let mem_overflow =
            wmem_overflow(&tiles, &placement, &mut scratch.used_wmem);
        let dmem_ok = dmem_feasible(&tiles, &placement, &decoded);
        let rterms = reward::compute(
            &self.mode.weights,
            &self.budget,
            &reward::RewardInputs {
                perf_gops: ppa_result.perf_gops,
                power_mw: ppa_result.power.total(),
                area_mm2: ppa_result.area.total(),
                mem_overflow_bytes: mem_overflow,
                dmem_ok,
                hazard_score: placement.hazards.score(),
            },
        );

        // 8. next state (Table 2)
        let full_state = state::encode_full(&state::StateInputs {
            workload: &self.wstats,
            mesh: &decoded.mesh,
            avg: &decoded.avg,
            node: &self.node,
            budget: &self.budget,
            placement: &placement,
            dmem_split: &decoded.dmem_split,
            ppa: Some(&ppa_result),
            hazards: &placement.hazards,
            kv_strategy: decoded.kv_strategy,
            seq_len: self.seq_len,
            weight_total_bytes: self.total_weights,
            batch_size: self.batch_size,
        });

        EvalOutcome {
            decoded,
            tiles,
            placement,
            ppa: ppa_result,
            reward: rterms,
            full_state,
            proj_steps,
        }
    }

    /// Score a candidate set against one base mesh with up to `threads`
    /// workers, each owning its own [`EvalScratch`]. Output order matches
    /// `actions` order; results are bit-identical to a serial loop (the
    /// determinism contract of `tests/eval_parallel.rs`).
    pub fn evaluate_many(
        &self,
        mesh: &MeshConfig,
        actions: &[Action],
        threads: usize,
    ) -> Vec<EvalOutcome> {
        parallel::scoped_chunk_map(
            actions,
            threads,
            EvalScratch::default,
            |scratch, _i, a| self.evaluate(mesh, a, scratch),
        )
    }

    fn design_point(
        &self,
        decoded: &DecodedAction,
        placement: &Placement,
        tiles: &[TileConfig],
    ) -> DesignPoint {
        let (sum_lanes, sum_lanes_capped) = DesignPoint::lane_sums(tiles);
        let sram_mb: f64 = tiles.iter().map(|t| t.sram_mb()).sum();

        // pipeline utilization η_util (Eq 63): hazards + memory pressure
        // + KV spill-to-WMEM latency (§3.9)
        let hazard = placement.hazards.density();
        let pressure_excess = mean_pressure_excess(tiles, placement);
        let spill = kv_spill_fraction(tiles, placement, decoded);
        let eta_util =
            (1.0 - 0.35 * hazard - 0.15 * pressure_excess - 0.2 * spill).clamp(0.3, 1.0);

        // per-token memory traffic: full weight sweep + compacted KV
        // (Eq 33) + cross-tile activations
        let kv_traffic = match self.graph.kv {
            Some(kvc) => kv::bytes_per_token(&kvc)
                / kv::compaction_factor(decoded.kv_strategy, self.seq_len),
            None => 0.0,
        };
        let mem_bytes_per_token =
            self.total_weights + kv_traffic + placement.traffic.cross_tile_bytes;

        // aggregate bandwidth: two ROM/SRAM ports of VLEN width per tile
        let f_hz = decoded.avg.clock_mhz * 1e6;
        let sum_bw_eff: f64 = tiles
            .iter()
            .map(|t| 2.0 * (t.vlen_bits as f64 / 8.0) * f_hz)
            .sum();

        DesignPoint {
            mesh: decoded.mesh,
            clock_mhz: decoded.avg.clock_mhz,
            dflit_bits: decoded.avg.dflit_bits,
            sum_lanes,
            sum_lanes_capped,
            sram_mb,
            weight_bytes: self.total_weights,
            traffic: placement.traffic.clone(),
            eta_parallel: placement.eta_parallel(),
            eta_util,
            alpha_spec: decoded.alpha_spec,
            flops_per_token: self.flops_per_token,
            mem_bytes_per_token,
            sum_bw_eff,
            activity: decoded.activity,
        }
    }
}

/// Initial mesh m₀(n) of Algorithm 1: sized so the model's weights fit at
/// mid-range WMEM, clamped to sensible walk-start bounds.
pub fn initial_mesh(graph: &Graph, mode: &ModeConfig) -> MeshConfig {
    let weights_mb = graph.total_weight_bytes() / (1024.0 * 1024.0);
    if mode.clock_mhz_fixed.is_some() {
        // low-power: start tiny
        return MeshConfig { width: 2, height: 2, sc_x: 1, sc_y: 1 };
    }
    // high-performance: start with ~16 MB of weights per tile
    let cores = (weights_mb / 16.0).ceil().max(4.0);
    let side = (cores.sqrt().ceil() as u32).clamp(2, 64);
    MeshConfig::new(side, side)
}

/// Configuration fingerprint over the *decoded* design point (Fig 3's
/// unique-configs trace; formerly private to `rl::loop_`).
pub fn config_key(out: &EvalOutcome) -> u64 {
    let d = &out.decoded;
    let mut h: u64 = 0xcbf29ce484222325;
    let mut mix = |v: u64| {
        h ^= v;
        h = h.wrapping_mul(0x100000001b3);
    };
    mix(d.mesh.width as u64);
    mix(d.mesh.height as u64);
    mix(d.avg.fetch as u64);
    mix(d.avg.stanum as u64);
    mix(d.avg.vlen_bits as u64);
    mix(d.avg.dmem_kb as u64);
    mix(d.avg.dflit_bits as u64);
    mix((d.avg.clock_mhz * 10.0) as u64);
    h
}

fn wmem_overflow(
    tiles: &[TileConfig],
    placement: &Placement,
    used: &mut Vec<f64>,
) -> f64 {
    used.clear();
    used.extend(placement.loads.iter().map(|l| l.weight_bytes));
    crate::mem::wmem_overflow_bytes(tiles, used)
}

/// Eq 27 feasibility: activation working sets must fit the DMEM
/// input+scratch partitions (≤5% violating tiles tolerated). KV overflow
/// is NOT an infeasibility — it spills to WMEM at a latency cost (§3.9),
/// handled by [`kv_spill_fraction`] throttling η_util.
fn dmem_feasible(tiles: &[TileConfig], placement: &Placement, d: &DecodedAction) -> bool {
    let mut violations = 0usize;
    let mut active = 0usize;
    for (t, l) in tiles.iter().zip(&placement.loads) {
        if l.flops <= 0.0 {
            continue;
        }
        active += 1;
        let dmem_bytes = t.dmem_kb as f64 * 1024.0;
        let usable = dmem_bytes * (d.dmem_split.input_frac + d.dmem_split.scratch_frac());
        // 4x headroom: moderate overflow streams from producers at a
        // latency cost (η_util pressure); only hopeless tiles violate
        if l.act_bytes > usable * 4.0 {
            violations += 1;
        }
    }
    active == 0 || (violations as f64) / (active as f64) <= 0.05
}

/// Fraction of active tiles whose KV slice does not fit the DMEM input
/// partition next to the activations — those slices spill to WMEM and pay
/// the slower-tier latency (§3.9), throttling η_util.
fn kv_spill_fraction(tiles: &[TileConfig], placement: &Placement, d: &DecodedAction) -> f64 {
    let mut spilled = 0usize;
    let mut active = 0usize;
    for (t, l) in tiles.iter().zip(&placement.loads) {
        if l.flops <= 0.0 {
            continue;
        }
        active += 1;
        let dmem_in = t.dmem_kb as f64 * 1024.0 * d.dmem_split.input_frac;
        if l.kv_bytes + l.act_bytes * 0.5 > dmem_in {
            spilled += 1;
        }
    }
    if active == 0 {
        0.0
    } else {
        spilled as f64 / active as f64
    }
}

fn mean_pressure_excess(tiles: &[TileConfig], placement: &Placement) -> f64 {
    let mut sum = 0.0;
    let mut n = 0usize;
    for (t, l) in tiles.iter().zip(&placement.loads) {
        if l.flops <= 0.0 {
            continue;
        }
        let p = crate::mem::pressure(
            l.weight_bytes,
            t.wmem_kb as f64 * 1024.0,
            l.act_bytes + l.kv_bytes,
            t.dmem_kb as f64 * 1024.0,
        );
        sum += (p - 1.0).max(0.0);
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        (sum / n as f64).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::util::Rng;

    fn small_cfg() -> RunConfig {
        let mut c = RunConfig::default();
        c.granularity = Granularity::Group;
        c
    }

    fn random_action(rng: &mut Rng) -> Action {
        let mut a = Action::neutral();
        for v in a.cont.iter_mut() {
            *v = rng.uniform_in(-1.0, 1.0);
        }
        for d in a.deltas.iter_mut() {
            *d = rng.below(5) as i32 - 2;
        }
        a
    }

    fn outcomes_equal(a: &EvalOutcome, b: &EvalOutcome) -> bool {
        a.reward.total.to_bits() == b.reward.total.to_bits()
            && a.reward.score.to_bits() == b.reward.score.to_bits()
            && a.ppa.tokens_per_s.to_bits() == b.ppa.tokens_per_s.to_bits()
            && a.decoded.mesh == b.decoded.mesh
            && a.proj_steps == b.proj_steps
            && a
                .full_state
                .iter()
                .zip(&b.full_state)
                .all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn evaluate_is_pure_and_scratch_independent() {
        let ev = Evaluator::new(&small_cfg(), 3);
        let mesh = ev.initial_mesh();
        let a = Action::neutral();
        let mut s1 = EvalScratch::default();
        let o1 = ev.evaluate(&mesh, &a, &mut s1);
        // reuse the dirty scratch; then a fresh one
        let o2 = ev.evaluate(&mesh, &a, &mut s1);
        let o3 = ev.evaluate(&mesh, &a, &mut EvalScratch::default());
        assert!(outcomes_equal(&o1, &o2));
        assert!(outcomes_equal(&o1, &o3));
    }

    #[test]
    fn evaluate_many_matches_serial_in_order() {
        let ev = Evaluator::new(&small_cfg(), 7);
        let mesh = ev.initial_mesh();
        let mut rng = Rng::new(17);
        let actions: Vec<Action> = (0..9).map(|_| random_action(&mut rng)).collect();
        let serial = ev.evaluate_many(&mesh, &actions, 1);
        let par = ev.evaluate_many(&mesh, &actions, 4);
        assert_eq!(serial.len(), par.len());
        let mut scratch = EvalScratch::default();
        for i in 0..actions.len() {
            assert!(outcomes_equal(&serial[i], &par[i]), "index {i} diverged");
            let direct = ev.evaluate(&mesh, &actions[i], &mut scratch);
            assert!(
                outcomes_equal(&par[i], &direct),
                "index {i} not aligned with its input action"
            );
        }
    }

    #[test]
    fn config_key_separates_meshes() {
        let ev = Evaluator::new(&small_cfg(), 3);
        let mut scratch = EvalScratch::default();
        let m1 = MeshConfig::new(8, 8);
        let m2 = MeshConfig::new(12, 12);
        let o1 = ev.evaluate(&m1, &Action::neutral(), &mut scratch);
        let o2 = ev.evaluate(&m2, &Action::neutral(), &mut scratch);
        assert_ne!(config_key(&o1), config_key(&o2));
        let o1b = ev.evaluate(&m1, &Action::neutral(), &mut scratch);
        assert_eq!(config_key(&o1), config_key(&o1b));
    }
}
