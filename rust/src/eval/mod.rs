//! The stateless evaluation layer: everything between a raw [`Action`]
//! and its [`EvalOutcome`] — decode + constrained projection (Eq 68),
//! operator partitioning (§3.5), KV distribution (Eq 27), heterogeneous
//! per-TCC derivation (§3.3), analytical PPA (Eqs 21–24, 62–64) and
//! reward (Eqs 34–44) — factored out of the MDP environment so it can fan
//! out across cores.
//!
//! Design (DESIGN.md §5):
//! * [`Evaluator`] owns the *immutable* per-(workload, node) context:
//!   graph, placement units, workload stats, node spec, budget, ranges.
//!   [`Evaluator::evaluate`] is a pure function of `(mesh, action)` — no
//!   interior mutability, no RNG — so the same inputs always produce the
//!   same outcome, on any thread.
//! * The pipeline is **stage-split** with explicit keys: decode/projection
//!   ([`Evaluator::stage_decode`]) → partition/placement
//!   ([`Evaluator::stage_place`], memoized per scratch on only the inputs
//!   placement reads) → heterogeneous derivation
//!   ([`Evaluator::stage_tiles`]) → PPA ([`Evaluator::stage_ppa`]) →
//!   reward/state. Continuous-knob-only perturbations (the common SAC
//!   case) replay the expensive placement and re-run only PPA + reward.
//! * [`EvalScratch`] carries the reusable working buffers (placement
//!   tile state, score heap, overflow accumulators) plus the per-worker
//!   [`StageCache`]; each worker thread owns one.
//! * [`Evaluator::evaluate_many`] scores a candidate set via scoped-
//!   thread fan-out ([`parallel`]), preserving input order — serial and
//!   parallel runs are bit-identical. [`Evaluator::evaluate_best`] adds
//!   roofline admission pruning for argmax-only paths: candidates whose
//!   O(1) optimistic bound ([`Evaluator::admission_bound`]) cannot beat
//!   the batch incumbent skip the full pipeline, and the selected outcome
//!   is provably bit-identical to the exact scan.
//! * [`cache::EvalCache`] memoizes whole outcomes keyed by a fingerprint
//!   of `(mesh, action)`, so repeated design points skip re-evaluation.
//!
//! The environment ([`crate::env::Env`]) shrinks to a thin wrapper owning
//! only the walking mesh of Algorithm 1.

pub mod cache;
pub mod parallel;

pub use cache::{CacheOccupancy, EvalCache, EvalStats, SharedEvalCache, StageCache};

use crate::arch::{self, MeshConfig, ParamRanges, TileConfig};
use crate::config::{Granularity, ModeConfig, NodeBudget, RunConfig};
use crate::env::action::{self, Action, DecodedAction};
use crate::env::reward::{self, RewardTerms};
use crate::env::state::{self, FULL_STATE_DIM};
use crate::hazard::Mitigation;
use crate::ir::spec::{Family, Phase, Scenario};
use crate::ir::stats::WorkloadStats;
use crate::ir::Graph;
use crate::kv::{self, KvStrategy};
use crate::node::{NodeSpec, NodeTable};
use crate::partition::{self, PlaceScratch, Placement, Unit};
use crate::ppa::{self, DesignPoint, PpaResult};

/// Full outcome of evaluating one action (one episode body).
#[derive(Debug, Clone)]
pub struct EvalOutcome {
    pub decoded: DecodedAction,
    pub tiles: Vec<TileConfig>,
    pub placement: Placement,
    pub ppa: PpaResult,
    pub reward: RewardTerms,
    pub full_state: [f64; FULL_STATE_DIM],
    /// Constraint-projection shrink steps applied (Eq 68).
    pub proj_steps: u32,
}

/// One candidate batch scored for its argmax, possibly under roofline
/// admission pruning ([`Evaluator::evaluate_best_with`]).
#[derive(Debug)]
pub struct BatchEval {
    /// Per-candidate outcome in input order; `None` means the candidate
    /// was pruned (its admission bound proved it cannot beat the batch
    /// incumbent, so it is not the argmax).
    pub outcomes: Vec<Option<EvalOutcome>>,
    /// Index of the selected candidate — always `Some` in `outcomes`,
    /// and identical to the argmax of an unpruned scan.
    pub best: usize,
    /// Candidates skipped by the admission bound.
    pub n_pruned: usize,
}

impl BatchEval {
    /// The selected outcome (the batch argmax).
    pub fn best_outcome(&self) -> &EvalOutcome {
        self.outcomes[self.best].as_ref().expect("best index always evaluated")
    }
}

/// Walk outcomes in input order and pick the earliest optimum under the
/// (feasible first, then lower score) ordering — the same reduction every
/// batch driver uses. Pruned (`None`) entries are never optimal by
/// construction, so skipping them preserves the exact selection.
pub fn select_best(outs: &[Option<EvalOutcome>]) -> usize {
    let mut best: Option<usize> = None;
    for (i, o) in outs.iter().enumerate() {
        let o = match o {
            Some(o) => o,
            None => continue,
        };
        match best {
            None => best = Some(i),
            Some(b) => {
                let cur = &outs[b].as_ref().unwrap().reward;
                let new = &o.reward;
                let better = (new.feasible && !cur.feasible)
                    || (new.feasible == cur.feasible && new.score < cur.score);
                if better {
                    best = Some(i);
                }
            }
        }
    }
    best.expect("at least one evaluated outcome in the batch")
}

/// First diverging field of two outcomes under bit comparison, as
/// `(field, left, right)` — `None` when every compared field is
/// bit-identical. This is the comparator behind the equivalence fuzz
/// harness (`rl::fuzz`, DESIGN.md §14): it checks the reward terms, the
/// realized PPA, the decoded mesh, the projection count, and finally
/// every element of the full state vector, in that order, so a report
/// always names the semantically earliest difference.
pub fn diff_outcomes(a: &EvalOutcome, b: &EvalOutcome) -> Option<(String, f64, f64)> {
    let scalars: [(&str, f64, f64); 12] = [
        ("reward.total", a.reward.total, b.reward.total),
        ("reward.score", a.reward.score, b.reward.score),
        (
            "reward.feasible",
            f64::from(u8::from(a.reward.feasible)),
            f64::from(u8::from(b.reward.feasible)),
        ),
        ("reward.p_norm", a.reward.p_norm, b.reward.p_norm),
        ("reward.p_power", a.reward.p_power, b.reward.p_power),
        ("reward.a_norm", a.reward.a_norm, b.reward.a_norm),
        ("ppa.tokens_per_s", a.ppa.tokens_per_s, b.ppa.tokens_per_s),
        ("ppa.perf_gops", a.ppa.perf_gops, b.ppa.perf_gops),
        ("mesh.width", f64::from(a.decoded.mesh.width), f64::from(b.decoded.mesh.width)),
        (
            "mesh.height",
            f64::from(a.decoded.mesh.height),
            f64::from(b.decoded.mesh.height),
        ),
        ("proj_steps", f64::from(a.proj_steps), f64::from(b.proj_steps)),
        ("tiles.len", a.tiles.len() as f64, b.tiles.len() as f64),
    ];
    for (field, l, r) in scalars {
        if l.to_bits() != r.to_bits() {
            return Some((field.to_string(), l, r));
        }
    }
    for (i, (l, r)) in a.full_state.iter().zip(&b.full_state).enumerate() {
        if l.to_bits() != r.to_bits() {
            return Some((format!("full_state[{i}]"), *l, *r));
        }
    }
    None
}

/// Reusable per-thread working buffers for the evaluation hot path, plus
/// the per-worker stage memo.
#[derive(Debug, Default)]
pub struct EvalScratch {
    pub place: PlaceScratch,
    /// Per-tile used-WMEM accumulator for the overflow check (Eq 14).
    used_wmem: Vec<f64>,
    /// Placement-stage memo (DESIGN.md §5): keyed on exactly the inputs
    /// placement reads, so non-partition continuous perturbations replay.
    pub stages: StageCache,
}

/// Immutable per-(workload, process-node) evaluation context. Shared by
/// reference across worker threads (`&Evaluator` is `Sync`: every field
/// is plain data).
pub struct Evaluator {
    pub graph: Graph,
    pub units: Vec<Unit>,
    pub wstats: WorkloadStats,
    pub node: NodeSpec,
    pub budget: NodeBudget,
    pub mode: ModeConfig,
    pub ranges: ParamRanges,
    pub kv_strategy: KvStrategy,
    /// The resolved evaluation scenario (phase, context length, batch)
    /// the graph, KV footprint and throughput models are built for — the
    /// single source of truth for seq_len/batch.
    pub scenario: Scenario,
    /// Σ weight bytes of the graph, hoisted off the per-episode path.
    total_weights: f64,
    /// Model FLOPs per generated token, hoisted off the per-episode path.
    flops_per_token: f64,
    /// Scenario-amortized per-token weight read traffic (Eq 22's weight
    /// term; equals `total_weights` at decode/batch-1).
    weight_traffic: f64,
    /// [`cache::units_key`] fingerprint of `units` — the placement-memo
    /// salt, so scratches shared across evaluators stay correct.
    units_key: u64,
    /// [`cache::scenario_salt`] over (units, node, scenario, KV
    /// strategy, mode, budget) — the whole-outcome memo salt, so an
    /// [`EvalCache`] can never replay an outcome across scenarios or
    /// optimization modes.
    eval_salt: u64,
}

impl Evaluator {
    pub fn new(cfg: &RunConfig, nm: u32) -> Self {
        let scenario = cfg.scenario();
        let graph = cfg.workload.build_scenario(&scenario);
        let units = match cfg.granularity {
            Granularity::Op => partition::units_from_ops(&graph),
            Granularity::Group => partition::groups::units_from_groups(&graph),
        };
        let wstats = crate::ir::stats::compute(&graph);
        let table = NodeTable::paper();
        let node =
            table.get(nm).unwrap_or_else(|| panic!("unknown node {nm}nm")).clone();
        let budget = *cfg.mode.budget(nm);
        // speculative decoding accelerates the autoregressive decode loop
        // only; prefill scores every prompt token in one pass (§3.8)
        let mut mode = cfg.mode.clone();
        if scenario.phase == Phase::Prefill {
            mode.alpha_spec = 1.0;
        }
        let total_weights = graph.total_weight_bytes();
        let flops_per_token = graph.flops_per_token_model();
        // the prompt axis only exists for decoder-bearing families: an
        // image encoder has no prefill pass to amortize the weight sweep
        // over, so only the batch axis applies there
        let traffic_phase = match cfg.workload.spec().family {
            Family::VisionEncoder => Phase::Decode,
            Family::Decoder | Family::VisionLanguage => scenario.phase,
        };
        let weight_traffic = ppa::throughput::weight_traffic_per_token(
            total_weights,
            traffic_phase,
            scenario.seq_len,
            scenario.batch,
        );
        let units_key = cache::units_key(&units);
        // salt over the *effective* mode (post prefill α override)
        let eval_salt = cache::scenario_salt(
            units_key,
            nm,
            &scenario,
            cfg.kv_strategy,
            &mode,
            &budget,
        );
        Evaluator {
            graph,
            units,
            wstats,
            node,
            budget,
            mode,
            ranges: ParamRanges::paper(),
            kv_strategy: cfg.kv_strategy,
            scenario,
            total_weights,
            flops_per_token,
            weight_traffic,
            units_key,
            eval_salt,
        }
    }

    /// Evaluation context length (the scenario's `seq_len`).
    pub fn seq_len(&self) -> u32 {
        self.scenario.seq_len
    }

    /// Evaluation batch size (the scenario's `batch`).
    pub fn batch_size(&self) -> u32 {
        self.scenario.batch
    }

    /// Whole-outcome memo salt: distinct for any two evaluators that
    /// could produce different outcomes for the same raw `(mesh, action)`
    /// input (different workload/granularity units, node, scenario, KV
    /// strategy, optimization mode or budget).
    pub fn eval_salt(&self) -> u64 {
        self.eval_salt
    }

    /// Initial mesh m₀(n) of Algorithm 1 for this workload/mode.
    pub fn initial_mesh(&self) -> MeshConfig {
        initial_mesh(&self.graph, &self.mode)
    }

    /// Stage 1 — decode + constrained projection (Eq 68). Reads the full
    /// `(mesh, action)` input; O(action dims), no placement.
    pub fn stage_decode(&self, mesh: &MeshConfig, a: &Action) -> (DecodedAction, u32) {
        let decoded = action::decode(
            a,
            mesh,
            &self.node,
            &self.mode,
            &self.ranges,
            self.kv_strategy,
            self.scenario.seq_len,
        );
        action::project(decoded, &self.node, &self.budget, self.total_weights)
    }

    /// Stage 2 — operator partitioning + placement (§3.5) and KV-cache
    /// distribution (Eq 27). The O(units × cores) placement is served
    /// from the scratch's [`StageCache`] when its key — mesh dims,
    /// partition knobs, hazard mitigation; *not* clock/voltage/memory
    /// dims — has been placed before.
    pub fn stage_place(
        &self,
        decoded: &DecodedAction,
        scratch: &mut EvalScratch,
    ) -> Placement {
        let mit = Mitigation {
            stanum: decoded.avg.stanum,
            fetch: decoded.avg.fetch,
            xr_wp: decoded.avg.xr_wp,
            vr_wp: decoded.avg.vr_wp,
        };
        let mut placement = scratch.stages.place(
            self.units_key,
            &self.units,
            &decoded.mesh,
            &decoded.knobs,
            &mit,
            &mut scratch.place,
        );
        let kv_total = match self.graph.kv {
            Some(kvc) => kv::total_bytes_batched(
                &kvc,
                self.scenario.seq_len,
                decoded.kv_strategy,
                self.scenario.batch,
            ),
            None => 0.0,
        };
        partition::distribute_kv(&mut placement.loads, kv_total);
        placement
    }

    /// Stage 3 — heterogeneous per-TCC derivation (§3.3). O(cores).
    pub fn stage_tiles(
        &self,
        decoded: &DecodedAction,
        placement: &Placement,
    ) -> Vec<TileConfig> {
        arch::derive_tiles(&decoded.mesh, &decoded.avg, &placement.loads, &self.ranges)
    }

    /// Stage 4 — analytical PPA (Eqs 21–24, 62–64). Pure arithmetic.
    pub fn stage_ppa(
        &self,
        decoded: &DecodedAction,
        placement: &Placement,
        tiles: &[TileConfig],
    ) -> PpaResult {
        let d = self.design_point(decoded, placement, tiles);
        ppa::evaluate(&d, &self.node)
    }

    /// Evaluate a raw action against `mesh`: the full §3.5 + §3.6–3.9 +
    /// §3.10 pipeline, composed from the explicitly-keyed stages. Pure:
    /// does not advance any mesh — the caller owns the Algorithm 1 walk
    /// (see [`crate::env::Env::eval_action`]). Stage memos in `scratch`
    /// only replay pure results, so outcomes are independent of scratch
    /// history (pinned by `tests/eval_staged.rs`).
    pub fn evaluate(
        &self,
        mesh: &MeshConfig,
        a: &Action,
        scratch: &mut EvalScratch,
    ) -> EvalOutcome {
        // 1. decode + constraint projection (Eq 68)
        let (decoded, proj_steps) = self.stage_decode(mesh, a);

        // 2–3. placement (memoized) + KV distribution
        let placement = self.stage_place(&decoded, scratch);

        // 4. heterogeneous per-TCC derivation (§3.3)
        let tiles = self.stage_tiles(&decoded, &placement);

        // 5–6. design point + analytical PPA (Eqs 21-24, 62-64)
        let ppa_result = self.stage_ppa(&decoded, &placement, &tiles);

        // 7. feasibility + reward (Eqs 34-44)
        let mem_overflow =
            wmem_overflow(&tiles, &placement, &mut scratch.used_wmem);
        let dmem_ok = dmem_feasible(&tiles, &placement, &decoded);
        let rterms = reward::compute(
            &self.mode.weights,
            &self.budget,
            &reward::RewardInputs {
                perf_gops: ppa_result.perf_gops,
                power_mw: ppa_result.power.total(),
                area_mm2: ppa_result.area.total(),
                mem_overflow_bytes: mem_overflow,
                dmem_ok,
                hazard_score: placement.hazards.score(),
            },
        );

        // 8. next state (Table 2)
        let full_state = state::encode_full(&state::StateInputs {
            workload: &self.wstats,
            mesh: &decoded.mesh,
            avg: &decoded.avg,
            node: &self.node,
            budget: &self.budget,
            placement: &placement,
            dmem_split: &decoded.dmem_split,
            ppa: Some(&ppa_result),
            hazards: &placement.hazards,
            kv_strategy: decoded.kv_strategy,
            seq_len: self.scenario.seq_len,
            weight_total_bytes: self.total_weights,
            batch_size: self.scenario.batch,
        });

        EvalOutcome {
            decoded,
            tiles,
            placement,
            ppa: ppa_result,
            reward: rterms,
            full_state,
            proj_steps,
        }
    }

    /// Score a candidate set against one base mesh with up to `threads`
    /// workers, each owning its own [`EvalScratch`]. Output order matches
    /// `actions` order; results are bit-identical to a serial loop (the
    /// determinism contract of `tests/eval_parallel.rs`).
    pub fn evaluate_many(
        &self,
        mesh: &MeshConfig,
        actions: &[Action],
        threads: usize,
    ) -> Vec<EvalOutcome> {
        parallel::scoped_chunk_map(
            actions,
            threads,
            EvalScratch::default,
            |scratch, _i, a| self.evaluate(mesh, a, scratch),
        )
    }

    /// [`Self::evaluate_many`] with caller-owned worker scratches (one
    /// per worker): stage memos stay warm across rounds. Bit-identical to
    /// the fresh-scratch variant for any scratch history.
    pub fn evaluate_many_with(
        &self,
        mesh: &MeshConfig,
        actions: &[Action],
        scratches: &mut [EvalScratch],
    ) -> Vec<EvalOutcome> {
        parallel::scoped_chunk_map_with(actions, scratches, |scratch, _i, a| {
            self.evaluate(mesh, a, scratch)
        })
    }

    /// Admissible lower bound on the composite PPA score (lower is
    /// better) reachable by `decoded`: `admission_bound(d) ≤
    /// outcome.reward.score` for every full evaluation of the same
    /// decoded design (soundness argument in DESIGN.md §5; pinned across
    /// nodes by `tests/eval_staged.rs`). O(1) — no placement.
    pub fn admission_bound(&self, decoded: &DecodedAction) -> f64 {
        let rb = self.roofline_bound_for(decoded);
        let ranges = reward::ranges_from_budget(&self.budget);
        ppa::score::ppa_score(
            &self.mode.weights,
            &ranges,
            rb.perf_gops,
            rb.power_mw,
            rb.area_mm2,
        )
    }

    /// Optimistic roofline bound for one decoded design (the raw PPA
    /// envelope behind [`Self::admission_bound`]'s scalarized score).
    pub fn roofline_bound_for(&self, decoded: &DecodedAction) -> ppa::RooflineBound {
        let kv_traffic = match self.graph.kv {
            Some(kvc) => kv::bytes_per_token(&kvc)
                / kv::compaction_factor(decoded.kv_strategy, self.scenario.seq_len),
            None => 0.0,
        };
        ppa::roofline_bound(
            decoded,
            &self.node,
            &self.ranges,
            self.total_weights,
            self.weight_traffic,
            self.flops_per_token,
            kv_traffic,
        )
    }

    /// Scenario-global optimistic envelope: component-wise best case over
    /// *every* design the Algorithm-1 walk can reach at this scenario
    /// point. Perf/tokens ceilings come from the all-max action corner on
    /// the largest reachable mesh with the most aggressive achievable KV
    /// compaction; power/area floors from the all-min corner on the
    /// smallest mesh. Unprojected corners are sound — projection (Eq 68)
    /// only shrinks the design space. The atlas sweep (`rl::atlas`,
    /// DESIGN.md §12) compares this envelope against solved neighbors'
    /// achieved frontiers to prune whole scenario points.
    pub fn roofline_envelope(&self) -> ppa::RooflineBound {
        let hi = Action { cont: [1.0; action::ACT_DIM], deltas: [0; action::N_DISC] };
        let lo = Action { cont: [-1.0; action::ACT_DIM], deltas: [0; action::N_DISC] };
        let mesh_hi = MeshConfig::new(action::MESH_DIM_MAX, action::MESH_DIM_MAX);
        let mesh_lo = MeshConfig::new(action::MESH_DIM_MIN, action::MESH_DIM_MIN);
        let d_hi = action::decode(
            &hi,
            &mesh_hi,
            &self.node,
            &self.mode,
            &self.ranges,
            self.kv_strategy,
            self.scenario.seq_len,
        );
        let d_lo = action::decode(
            &lo,
            &mesh_lo,
            &self.node,
            &self.mode,
            &self.ranges,
            self.kv_strategy,
            self.scenario.seq_len,
        );
        // KV traffic floor (for the perf ceiling): the strongest
        // compaction decode() can actually select from the base strategy
        // (only Full may be upgraded, to INT8 — see action::decode). The
        // traffic ceiling (for the power floor) keeps base compaction.
        let (kv_floor, kv_ceiling) = match self.graph.kv {
            Some(kvc) => {
                let bytes = kv::bytes_per_token(&kvc);
                let base = kv::compaction_factor(self.kv_strategy, self.scenario.seq_len);
                let best = match self.kv_strategy {
                    KvStrategy::Full => base.max(kv::compaction_factor(
                        KvStrategy::Quantized { bits: 8 },
                        self.scenario.seq_len,
                    )),
                    _ => base,
                };
                (bytes / best, bytes / base)
            }
            None => (0.0, 0.0),
        };
        let hi_b = ppa::roofline_bound(
            &d_hi,
            &self.node,
            &self.ranges,
            self.total_weights,
            self.weight_traffic,
            self.flops_per_token,
            kv_floor,
        );
        let lo_b = ppa::roofline_bound(
            &d_lo,
            &self.node,
            &self.ranges,
            self.total_weights,
            self.weight_traffic,
            self.flops_per_token,
            kv_ceiling,
        );
        ppa::RooflineBound {
            tokens_per_s: hi_b.tokens_per_s,
            perf_gops: hi_b.perf_gops,
            power_mw: lo_b.power_mw,
            area_mm2: lo_b.area_mm2,
        }
    }

    /// The per-token scenario constants the atlas comparability check
    /// needs: `(flops_per_token, weight_traffic_per_token,
    /// kv_traffic_per_token at the base strategy)`. Two scenario points
    /// with equal constants and an identical unit graph expose the same
    /// search space up to reward amortization (DESIGN.md §12).
    pub fn scenario_constants(&self) -> (f64, f64, f64) {
        let kv_traffic = match self.graph.kv {
            Some(kvc) => kv::bytes_per_token(&kvc)
                / kv::compaction_factor(self.kv_strategy, self.scenario.seq_len),
            None => 0.0,
        };
        (self.flops_per_token, self.weight_traffic, kv_traffic)
    }

    /// Score a candidate set for its argmax under roofline admission
    /// pruning ([`Self::evaluate_best_with`] with fresh scratches).
    pub fn evaluate_best(
        &self,
        mesh: &MeshConfig,
        actions: &[Action],
        threads: usize,
        prune: bool,
    ) -> BatchEval {
        let mut scratches: Vec<EvalScratch> =
            (0..threads.max(1)).map(|_| EvalScratch::default()).collect();
        self.evaluate_best_with(mesh, actions, &mut scratches, prune)
    }

    /// Score a candidate set when only the argmax matters (baseline
    /// rounds, MPC re-ranking, multiseed sweeps). With `prune` set, each
    /// candidate first gets its O(1) [`Self::admission_bound`]; the most
    /// promising bound seeds the incumbent, and candidates whose bound
    /// proves they cannot strictly beat it skip the full pipeline. The
    /// selected index/outcome is bit-identical to an exact
    /// [`Self::evaluate_many`] + [`select_best`] scan (the batch optimum
    /// is never prunable — DESIGN.md §5); pruned candidates simply have
    /// no outcome. `prune = false` is the exact fallback.
    pub fn evaluate_best_with(
        &self,
        mesh: &MeshConfig,
        actions: &[Action],
        scratches: &mut [EvalScratch],
        prune: bool,
    ) -> BatchEval {
        assert!(!actions.is_empty(), "evaluate_best needs at least one candidate");
        if !prune || actions.len() < 2 {
            let outs = self.evaluate_many_with(mesh, actions, scratches);
            let outcomes: Vec<Option<EvalOutcome>> = outs.into_iter().map(Some).collect();
            let best = select_best(&outcomes);
            return BatchEval { outcomes, best, n_pruned: 0 };
        }

        // O(1) admission bounds (decode + projection only, no placement)
        let bounds: Vec<f64> = actions
            .iter()
            .map(|a| {
                let (d, _) = self.stage_decode(mesh, a);
                self.admission_bound(&d)
            })
            .collect();

        // seed the incumbent with the most promising bound (earliest tie)
        let mut i0 = 0usize;
        for (i, b) in bounds.iter().enumerate() {
            if *b < bounds[i0] {
                i0 = i;
            }
        }
        let seed_out = self.evaluate(mesh, &actions[i0], &mut scratches[0]);

        // pruning is only sound against a *feasible* incumbent (an
        // infeasible one loses to any feasible candidate regardless of
        // score, and feasibility has no O(1) bound): keep every
        // candidate whose bound could still tie or beat the incumbent
        // score. PRUNE_MARGIN absorbs ulp-level float slop so a
        // borderline candidate is evaluated rather than wrongly dropped.
        const PRUNE_MARGIN: f64 = 1e-9;
        let incumbent =
            if seed_out.reward.feasible { Some(seed_out.reward.score) } else { None };
        let survivors: Vec<usize> = (0..actions.len())
            .filter(|&i| {
                i != i0
                    && match incumbent {
                        Some(s) => bounds[i] <= s + PRUNE_MARGIN,
                        None => true,
                    }
            })
            .collect();

        let evals = parallel::scoped_chunk_map_with(
            &survivors,
            scratches,
            |scratch, _j, &i| self.evaluate(mesh, &actions[i], scratch),
        );

        let mut outcomes: Vec<Option<EvalOutcome>> =
            (0..actions.len()).map(|_| None).collect();
        outcomes[i0] = Some(seed_out);
        for (&i, out) in survivors.iter().zip(evals.into_iter()) {
            outcomes[i] = Some(out);
        }
        let best = select_best(&outcomes);
        let n_pruned = outcomes.iter().filter(|o| o.is_none()).count();
        BatchEval { outcomes, best, n_pruned }
    }

    fn design_point(
        &self,
        decoded: &DecodedAction,
        placement: &Placement,
        tiles: &[TileConfig],
    ) -> DesignPoint {
        let (sum_lanes, sum_lanes_capped) = DesignPoint::lane_sums(tiles);
        let sram_mb: f64 = tiles.iter().map(|t| t.sram_mb()).sum();

        // pipeline utilization η_util (Eq 63): hazards + memory pressure
        // + KV spill-to-WMEM latency (§3.9)
        let hazard = placement.hazards.density();
        let pressure_excess = mean_pressure_excess(tiles, placement);
        let spill = kv_spill_fraction(tiles, placement, decoded);
        let eta_util =
            (1.0 - 0.35 * hazard - 0.15 * pressure_excess - 0.2 * spill).clamp(0.3, 1.0);

        // per-token memory traffic: the scenario-amortized weight sweep
        // (one sweep serves the batch; prefill amortizes over the whole
        // prompt) + compacted KV (Eq 33) + cross-tile activations
        let kv_traffic = match self.graph.kv {
            Some(kvc) => kv::bytes_per_token(&kvc)
                / kv::compaction_factor(decoded.kv_strategy, self.scenario.seq_len),
            None => 0.0,
        };
        let mem_bytes_per_token =
            self.weight_traffic + kv_traffic + placement.traffic.cross_tile_bytes;

        // aggregate bandwidth: two ROM/SRAM ports of VLEN width per tile
        let f_hz = decoded.avg.clock_mhz * 1e6;
        let sum_bw_eff: f64 = tiles
            .iter()
            .map(|t| 2.0 * (t.vlen_bits as f64 / 8.0) * f_hz)
            .sum();

        DesignPoint {
            mesh: decoded.mesh,
            clock_mhz: decoded.avg.clock_mhz,
            dflit_bits: decoded.avg.dflit_bits,
            sum_lanes,
            sum_lanes_capped,
            sram_mb,
            weight_bytes: self.total_weights,
            traffic: placement.traffic.clone(),
            eta_parallel: placement.eta_parallel(),
            eta_util,
            alpha_spec: decoded.alpha_spec,
            flops_per_token: self.flops_per_token,
            mem_bytes_per_token,
            sum_bw_eff,
            activity: decoded.activity,
        }
    }
}

/// Initial mesh m₀(n) of Algorithm 1: sized so the model's weights fit at
/// mid-range WMEM, clamped to sensible walk-start bounds.
pub fn initial_mesh(graph: &Graph, mode: &ModeConfig) -> MeshConfig {
    let weights_mb = graph.total_weight_bytes() / (1024.0 * 1024.0);
    if mode.clock_mhz_fixed.is_some() {
        // low-power: start tiny
        return MeshConfig { width: 2, height: 2, sc_x: 1, sc_y: 1 };
    }
    // high-performance: start with ~16 MB of weights per tile
    let cores = (weights_mb / 16.0).ceil().max(4.0);
    let side = (cores.sqrt().ceil() as u32).clamp(2, 64);
    MeshConfig::new(side, side)
}

/// Configuration fingerprint over the *decoded* design point (Fig 3's
/// unique-configs trace; formerly private to `rl::loop_`).
pub fn config_key(out: &EvalOutcome) -> u64 {
    let d = &out.decoded;
    let mut h = cache::Fnv::new();
    h.mix(d.mesh.width as u64);
    h.mix(d.mesh.height as u64);
    h.mix(d.avg.fetch as u64);
    h.mix(d.avg.stanum as u64);
    h.mix(d.avg.vlen_bits as u64);
    h.mix(d.avg.dmem_kb as u64);
    h.mix(d.avg.dflit_bits as u64);
    h.mix((d.avg.clock_mhz * 10.0) as u64);
    h.finish()
}

fn wmem_overflow(
    tiles: &[TileConfig],
    placement: &Placement,
    used: &mut Vec<f64>,
) -> f64 {
    used.clear();
    used.extend(placement.loads.iter().map(|l| l.weight_bytes));
    crate::mem::wmem_overflow_bytes(tiles, used)
}

/// Eq 27 feasibility: activation working sets must fit the DMEM
/// input+scratch partitions (≤5% violating tiles tolerated). KV overflow
/// is NOT an infeasibility — it spills to WMEM at a latency cost (§3.9),
/// handled by [`kv_spill_fraction`] throttling η_util.
fn dmem_feasible(tiles: &[TileConfig], placement: &Placement, d: &DecodedAction) -> bool {
    let mut violations = 0usize;
    let mut active = 0usize;
    for (t, l) in tiles.iter().zip(&placement.loads) {
        if l.flops <= 0.0 {
            continue;
        }
        active += 1;
        let dmem_bytes = t.dmem_kb as f64 * 1024.0;
        let usable = dmem_bytes * (d.dmem_split.input_frac + d.dmem_split.scratch_frac());
        // 4x headroom: moderate overflow streams from producers at a
        // latency cost (η_util pressure); only hopeless tiles violate
        if l.act_bytes > usable * 4.0 {
            violations += 1;
        }
    }
    active == 0 || (violations as f64) / (active as f64) <= 0.05
}

/// Fraction of active tiles whose KV slice does not fit the DMEM input
/// partition next to the activations — those slices spill to WMEM and pay
/// the slower-tier latency (§3.9), throttling η_util.
fn kv_spill_fraction(tiles: &[TileConfig], placement: &Placement, d: &DecodedAction) -> f64 {
    let mut spilled = 0usize;
    let mut active = 0usize;
    for (t, l) in tiles.iter().zip(&placement.loads) {
        if l.flops <= 0.0 {
            continue;
        }
        active += 1;
        let dmem_in = t.dmem_kb as f64 * 1024.0 * d.dmem_split.input_frac;
        if l.kv_bytes + l.act_bytes * 0.5 > dmem_in {
            spilled += 1;
        }
    }
    if active == 0 {
        0.0
    } else {
        spilled as f64 / active as f64
    }
}

fn mean_pressure_excess(tiles: &[TileConfig], placement: &Placement) -> f64 {
    let mut sum = 0.0;
    let mut n = 0usize;
    for (t, l) in tiles.iter().zip(&placement.loads) {
        if l.flops <= 0.0 {
            continue;
        }
        let p = crate::mem::pressure(
            l.weight_bytes,
            t.wmem_kb as f64 * 1024.0,
            l.act_bytes + l.kv_bytes,
            t.dmem_kb as f64 * 1024.0,
        );
        sum += (p - 1.0).max(0.0);
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        (sum / n as f64).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::util::Rng;

    fn small_cfg() -> RunConfig {
        let mut c = RunConfig::default();
        c.granularity = Granularity::Group;
        c
    }

    fn random_action(rng: &mut Rng) -> Action {
        let mut a = Action::neutral();
        for v in a.cont.iter_mut() {
            *v = rng.uniform_in(-1.0, 1.0);
        }
        for d in a.deltas.iter_mut() {
            *d = rng.below(5) as i32 - 2;
        }
        a
    }

    fn outcomes_equal(a: &EvalOutcome, b: &EvalOutcome) -> bool {
        a.reward.total.to_bits() == b.reward.total.to_bits()
            && a.reward.score.to_bits() == b.reward.score.to_bits()
            && a.ppa.tokens_per_s.to_bits() == b.ppa.tokens_per_s.to_bits()
            && a.decoded.mesh == b.decoded.mesh
            && a.proj_steps == b.proj_steps
            && a
                .full_state
                .iter()
                .zip(&b.full_state)
                .all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn evaluate_is_pure_and_scratch_independent() {
        let ev = Evaluator::new(&small_cfg(), 3);
        let mesh = ev.initial_mesh();
        let a = Action::neutral();
        let mut s1 = EvalScratch::default();
        let o1 = ev.evaluate(&mesh, &a, &mut s1);
        // reuse the dirty scratch; then a fresh one
        let o2 = ev.evaluate(&mesh, &a, &mut s1);
        let o3 = ev.evaluate(&mesh, &a, &mut EvalScratch::default());
        assert!(outcomes_equal(&o1, &o2));
        assert!(outcomes_equal(&o1, &o3));
    }

    #[test]
    fn evaluate_many_matches_serial_in_order() {
        let ev = Evaluator::new(&small_cfg(), 7);
        let mesh = ev.initial_mesh();
        let mut rng = Rng::new(17);
        let actions: Vec<Action> = (0..9).map(|_| random_action(&mut rng)).collect();
        let serial = ev.evaluate_many(&mesh, &actions, 1);
        let par = ev.evaluate_many(&mesh, &actions, 4);
        assert_eq!(serial.len(), par.len());
        let mut scratch = EvalScratch::default();
        for i in 0..actions.len() {
            assert!(outcomes_equal(&serial[i], &par[i]), "index {i} diverged");
            let direct = ev.evaluate(&mesh, &actions[i], &mut scratch);
            assert!(
                outcomes_equal(&par[i], &direct),
                "index {i} not aligned with its input action"
            );
        }
    }

    #[test]
    fn evaluate_best_matches_exact_argmax() {
        let ev = Evaluator::new(&small_cfg(), 7);
        let mesh = ev.initial_mesh();
        let mut rng = Rng::new(23);
        let actions: Vec<Action> = (0..10).map(|_| random_action(&mut rng)).collect();
        let exact = ev.evaluate_best(&mesh, &actions, 2, false);
        let pruned = ev.evaluate_best(&mesh, &actions, 2, true);
        assert_eq!(exact.n_pruned, 0);
        assert_eq!(exact.best, pruned.best, "pruning changed the selection");
        assert!(outcomes_equal(exact.best_outcome(), pruned.best_outcome()));
    }

    #[test]
    fn admission_bound_is_admissible_for_neutral_action() {
        let ev = Evaluator::new(&small_cfg(), 3);
        let mesh = ev.initial_mesh();
        let (decoded, _) = ev.stage_decode(&mesh, &Action::neutral());
        let bound = ev.admission_bound(&decoded);
        let out = ev.evaluate(&mesh, &Action::neutral(), &mut EvalScratch::default());
        assert!(
            bound <= out.reward.score + 1e-9,
            "bound {bound} exceeds true score {}",
            out.reward.score
        );
    }

    #[test]
    fn envelope_brackets_sampled_designs() {
        // The scenario-global envelope must bound every reachable design:
        // per-design roofline bounds and full evaluations alike stay
        // inside (perf ≤ ceiling, power/area ≥ floors).
        for nm in [3u32, 14] {
            let ev = Evaluator::new(&small_cfg(), nm);
            let env = ev.roofline_envelope();
            let mut mesh = ev.initial_mesh();
            let mut rng = Rng::new(0x0A71A5 + nm as u64);
            let mut scratch = EvalScratch::default();
            for i in 0..24 {
                let a = random_action(&mut rng);
                let (decoded, _) = ev.stage_decode(&mesh, &a);
                let rb = ev.roofline_bound_for(&decoded);
                assert!(
                    rb.perf_gops <= env.perf_gops * (1.0 + 1e-12),
                    "nm={nm} step {i}: design perf roof {} exceeds envelope {}",
                    rb.perf_gops,
                    env.perf_gops
                );
                assert!(
                    rb.tokens_per_s <= env.tokens_per_s * (1.0 + 1e-12),
                    "nm={nm} step {i}: tokens roof above envelope"
                );
                let out = ev.evaluate(&mesh, &a, &mut scratch);
                assert!(
                    out.ppa.perf_gops <= env.perf_gops * (1.0 + 1e-12),
                    "nm={nm} step {i}: achieved perf above envelope"
                );
                assert!(
                    out.ppa.power.total() >= env.power_mw * (1.0 - 1e-12),
                    "nm={nm} step {i}: achieved power {} under floor {}",
                    out.ppa.power.total(),
                    env.power_mw
                );
                assert!(
                    out.ppa.area.total() >= env.area_mm2 * (1.0 - 1e-12),
                    "nm={nm} step {i}: achieved area under floor"
                );
                mesh = out.decoded.mesh;
            }
        }
    }

    #[test]
    fn scenario_constants_track_batch_amortization() {
        let base = small_cfg();
        let mut batched = small_cfg();
        batched.batch = Some(4);
        let (f1, w1, k1) = Evaluator::new(&base, 7).scenario_constants();
        let (f4, w4, k4) = Evaluator::new(&batched, 7).scenario_constants();
        // batch leaves the graph (flops, kv) untouched and divides the
        // per-token weight traffic — the atlas comparability invariant.
        assert_eq!(f1.to_bits(), f4.to_bits());
        assert_eq!(k1.to_bits(), k4.to_bits());
        assert!((w1 / 4.0 - w4).abs() < 1e-9 * w1);
    }

    #[test]
    fn config_key_separates_meshes() {
        let ev = Evaluator::new(&small_cfg(), 3);
        let mut scratch = EvalScratch::default();
        let m1 = MeshConfig::new(8, 8);
        let m2 = MeshConfig::new(12, 12);
        let o1 = ev.evaluate(&m1, &Action::neutral(), &mut scratch);
        let o2 = ev.evaluate(&m2, &Action::neutral(), &mut scratch);
        assert_ne!(config_key(&o1), config_key(&o2));
        let o1b = ev.evaluate(&m1, &Action::neutral(), &mut scratch);
        assert_eq!(config_key(&o1), config_key(&o1b));
    }
}
