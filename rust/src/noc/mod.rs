//! Network-on-Chip model (§3.7): bisection bandwidth (Eq 18), hop/latency
//! model (Eq 19), communication-to-computation ratio (Eq 20), and the
//! per-token cross-tile traffic accounting the partitioner feeds into the
//! power and throughput models.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use crate::arch::MeshConfig;
use crate::nn::kernels::{self, KernelPath};

/// NoC-level configuration + derived metrics for one candidate design.
#[derive(Debug, Clone)]
pub struct NocModel {
    pub mesh: MeshConfig,
    pub dflit_bits: u32,
    pub clock_mhz: f64,
}

/// Per-hop latency in cycles and routing setup overhead (Eq 19 constants).
pub const L_HOP_CYCLES: f64 = 1.0;
pub const L_SETUP_CYCLES: f64 = 3.0;

impl NocModel {
    /// Bisection bandwidth in bytes/s (Eq 18):
    /// BW = min(M,N) · W_DFLIT · f_node.
    pub fn bisection_bw_bytes(&self) -> f64 {
        let links = self.mesh.width.min(self.mesh.height) as f64;
        links * (self.dflit_bits as f64 / 8.0) * self.clock_mhz * 1e6
    }

    /// Mean hop count h̄ = (M+N)/3 (Eq 19). Sub-cluster express links
    /// shorten long paths: effective hops divide by the SC overlay factor
    /// for the inter-cluster fraction of the route.
    pub fn mean_hops_effective(&self) -> f64 {
        let base = self.mesh.mean_hops();
        let sc = (self.mesh.sc_x.max(1) * self.mesh.sc_y.max(1)) as f64;
        // express links cover ~half of an average route when SC > 1
        if sc > 1.0 {
            base * (0.5 + 0.5 / sc.sqrt())
        } else {
            base
        }
    }

    /// Mean NoC transfer latency in seconds for one flit-sized message
    /// (Eq 19: L = h̄ · L_hop + L_setup).
    pub fn mean_latency_s(&self) -> f64 {
        let cycles = self.mean_hops_effective() * L_HOP_CYCLES + L_SETUP_CYCLES;
        cycles / (self.clock_mhz * 1e6)
    }

    /// Per-link bandwidth (bytes/s) — used for hot-link saturation checks.
    pub fn link_bw_bytes(&self) -> f64 {
        (self.dflit_bits as f64 / 8.0) * self.clock_mhz * 1e6
    }
}

/// Cross-tile traffic accounting accumulated during placement.
#[derive(Debug, Clone, Default)]
pub struct TrafficStats {
    /// Total tensor bytes crossing tile boundaries per token.
    pub cross_tile_bytes: f64,
    /// Bytes × hops per token (energy integral for Eq 62's NoC term).
    pub byte_hops: f64,
    /// Bytes crossing the mesh bisection per token (Eq 23 denominator).
    pub bisection_bytes: f64,
    /// Number of cross-tile tensor transfers.
    pub n_transfers: u64,
}

impl TrafficStats {
    /// Record a `bytes`-sized transfer over `hops` mesh hops, of which
    /// `crosses_bisection` says whether the route crosses the mesh midline.
    pub fn record(&mut self, bytes: f64, hops: u32, crosses_bisection: bool) {
        if hops == 0 {
            return; // same-tile: stays in DMEM
        }
        self.cross_tile_bytes += bytes;
        self.byte_hops += bytes * hops as f64;
        if crosses_bisection {
            self.bisection_bytes += bytes;
        }
        self.n_transfers += 1;
    }

    pub fn mean_hops(&self) -> f64 {
        if self.cross_tile_bytes <= 0.0 {
            0.0
        } else {
            self.byte_hops / self.cross_tile_bytes
        }
    }
}

/// Communication-to-computation ratio ρ_comm (Eq 20).
pub fn rho_comm(edge_tensor_bytes: f64, total_flops: f64) -> f64 {
    edge_tensor_bytes / total_flops.max(1.0)
}

/// Precomputed per-mesh-dims geometry (DESIGN.md §5): tile coordinates,
/// centrality penalties and the bisection-half mask, built once per
/// `(width, height)` and cached across placements so the O(units × cores)
/// scoring loop and the traffic accounting never recompute div/mod,
/// centrality or the bisection test per (operator, tile) pair.
///
/// Every accessor is bit-identical to the corresponding on-the-fly
/// [`MeshConfig`] computation (pinned by `geom_matches_mesh_config`), so
/// cached and uncached placements produce identical results.
#[derive(Debug, Clone)]
pub struct MeshGeom {
    pub width: u32,
    pub height: u32,
    /// (x, y) per tile index.
    pub xy: Vec<(u16, u16)>,
    /// Tile coordinates as f64 SoA lanes for the vectorized scoring loop.
    /// Coordinates are < 2¹⁶, so f64 subtract/abs on them is exact and
    /// bit-identical to the integer `abs_diff` path.
    pub xf: Vec<f64>,
    pub yf: Vec<f64>,
    /// 1 − centrality(t) per tile (§3.5 step 4 score term).
    pub central_penalty: Vec<f64>,
    /// Whether the tile lies west of the vertical bisection (x < width/2).
    west: Vec<bool>,
}

impl MeshGeom {
    pub fn build(mesh: &MeshConfig) -> MeshGeom {
        let n = mesh.cores();
        let half = mesh.width / 2;
        let mut xy = Vec::with_capacity(n);
        let mut xf = Vec::with_capacity(n);
        let mut yf = Vec::with_capacity(n);
        let mut central_penalty = Vec::with_capacity(n);
        let mut west = Vec::with_capacity(n);
        for t in 0..n {
            let x = t as u32 % mesh.width;
            let y = t as u32 / mesh.width;
            xy.push((x as u16, y as u16));
            xf.push(x as f64);
            yf.push(y as f64);
            central_penalty.push(1.0 - mesh.centrality(t));
            west.push(x < half);
        }
        MeshGeom { width: mesh.width, height: mesh.height, xy, xf, yf, central_penalty, west }
    }

    /// Does this table describe `mesh`'s dimensions? (SC overlay does not
    /// affect geometry, so it is not part of the key.)
    pub fn matches(&self, mesh: &MeshConfig) -> bool {
        self.width == mesh.width && self.height == mesh.height
    }

    /// Manhattan hop distance via the coordinate table — bit-identical to
    /// [`MeshConfig::hop_distance`].
    #[inline]
    pub fn hop(&self, a: usize, b: usize) -> u32 {
        let (ax, ay) = self.xy[a];
        let (bx, by) = self.xy[b];
        (ax.abs_diff(bx) + ay.abs_diff(by)) as u32
    }

    /// Bisection-crossing test via the half mask — bit-identical to
    /// [`crosses_bisection`].
    #[inline]
    pub fn crosses(&self, a: usize, b: usize) -> bool {
        self.west[a] != self.west[b]
    }

    /// §3.5 step-4 composite placement score for every tile at once:
    /// `score(t) = w_load·load(t) + 0.8·hop(t) + 0.5·imbalance(t) +
    /// central_w·(1 − centrality(t))`, written into `out`.
    ///
    /// This is the O(units × cores) inner loop of the placement
    /// (`partition::place_units_with`). The SIMD paths (AVX2 4-wide f64,
    /// NEON 2-wide f64) are written **FMA-free in exactly the scalar
    /// expression tree and operation order**, so every lane is
    /// bit-identical to the scalar reference — the `evaluate_best`
    /// pruned≡exact pin rides on the scores' argmin, and bit-identity is
    /// what guarantees the selected design never changes with the kernel
    /// mode (DESIGN.md §10).
    pub fn score_tiles(
        &self,
        p: &ScoreParams,
        flops: &[f64],
        weights: &[f64],
        act: &[f64],
        out: &mut [f64],
    ) {
        self.score_tiles_with(kernels::active(), p, flops, weights, act, out)
    }

    /// [`score_tiles`](Self::score_tiles) on an explicit kernel path —
    /// used by the parity tests and benches so they never have to touch
    /// the process-global dispatch mode. Panics if `path` is a SIMD path
    /// the CPU does not support.
    pub fn score_tiles_with(
        &self,
        path: KernelPath,
        p: &ScoreParams,
        flops: &[f64],
        weights: &[f64],
        act: &[f64],
        out: &mut [f64],
    ) {
        let n = self.xy.len();
        debug_assert_eq!(flops.len(), n);
        debug_assert_eq!(weights.len(), n);
        debug_assert_eq!(act.len(), n);
        debug_assert_eq!(out.len(), n);
        match path {
            #[cfg(target_arch = "x86_64")]
            KernelPath::Avx2 => {
                assert_eq!(kernels::detect(), Some(KernelPath::Avx2), "avx2 not available");
                // SAFETY: capability asserted above (std caches the check)
                unsafe { score_avx2(self, p, flops, weights, act, out) }
            }
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON is architecturally guaranteed on aarch64.
            KernelPath::Neon => unsafe { score_neon(self, p, flops, weights, act, out) },
            _ => score_scalar(self, p, flops, weights, act, out),
        }
    }
}

/// Hoisted per-unit constants of the composite placement score (computed
/// once per placement unit by `partition::place_units_with`, consumed by
/// [`MeshGeom::score_tiles`] for all tiles).
#[derive(Debug, Clone, Copy)]
pub struct ScoreParams {
    /// `knobs.w_load` — weight of the load term.
    pub wl: f64,
    /// `n_tiles / total_flops_placed` (load + imbalance normalizer).
    pub inv_mean_f: f64,
    /// `n_tiles / total_weights_placed`.
    pub inv_mean_w: f64,
    /// `total_flops_placed / n_tiles`.
    pub mean_f: f64,
    /// `1 / (width + height)` — hop-distance normalizer.
    pub inv_span: f64,
    /// Centrality-term weight (fan-in dependent).
    pub central_w: f64,
    /// Producer-tile coordinates anchoring the hop term, if the unit has
    /// a producer; `None` zeroes the hop term exactly like the scalar
    /// reference does.
    pub prod_xy: Option<(u16, u16)>,
}

/// Activation-bytes normalizer of the load term (1/64 KiB).
const INV_64K: f64 = 1.0 / (64.0 * 1024.0);

/// The scalar reference body of [`MeshGeom::score_tiles`] — byte-for-byte
/// the arithmetic the placement loop inlined before kernel dispatch
/// existed (the float `(pxf − xf).abs()` hop equals the old integer
/// `abs_diff as f64` exactly: coordinates are < 2¹⁶ so the subtraction
/// is exact).
fn score_scalar(
    g: &MeshGeom,
    p: &ScoreParams,
    flops: &[f64],
    weights: &[f64],
    act: &[f64],
    out: &mut [f64],
) {
    let n = g.xy.len();
    let (pxf, pyf) = match p.prod_xy {
        Some((px, py)) => (px as f64, py as f64),
        None => (0.0, 0.0),
    };
    let has_prod = p.prod_xy.is_some();
    for t in 0..n {
        let f = flops[t];
        let load = p.wl
            * (f * p.inv_mean_f
                + 0.3 * (weights[t] * p.inv_mean_w)
                + 0.1 * act[t] * INV_64K);
        let hop = if has_prod {
            ((pxf - g.xf[t]).abs() + (pyf - g.yf[t]).abs()) * p.inv_span
        } else {
            0.0
        };
        let imb = ((f - p.mean_f) * p.inv_mean_f).max(0.0);
        out[t] = load + 0.8 * hop + 0.5 * imb + p.central_w * g.central_penalty[t];
    }
}

/// AVX 4-wide f64 scoring: the same expression tree as [`score_scalar`]
/// with no FMA contraction, so each lane performs the identical IEEE-754
/// operation sequence → bit-identical scores. (`abs` is a sign-bit
/// `andnot`; `max_pd(x, 0)` matches `f64::max(x, 0.0)` because an
/// exactly-zero imbalance is `+0.0` here — `f − mean_f` cannot produce
/// `−0.0` under round-to-nearest.)
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn score_avx2(
    g: &MeshGeom,
    p: &ScoreParams,
    flops: &[f64],
    weights: &[f64],
    act: &[f64],
    out: &mut [f64],
) {
    use core::arch::x86_64::*;
    let n = g.xy.len();
    let vwl = _mm256_set1_pd(p.wl);
    let vimf = _mm256_set1_pd(p.inv_mean_f);
    let vimw = _mm256_set1_pd(p.inv_mean_w);
    let vmf = _mm256_set1_pd(p.mean_f);
    let vspan = _mm256_set1_pd(p.inv_span);
    let vcw = _mm256_set1_pd(p.central_w);
    let v03 = _mm256_set1_pd(0.3);
    let v01 = _mm256_set1_pd(0.1);
    let v05 = _mm256_set1_pd(0.5);
    let v08 = _mm256_set1_pd(0.8);
    let v64k = _mm256_set1_pd(INV_64K);
    let vzero = _mm256_setzero_pd();
    let sign = _mm256_set1_pd(-0.0);
    let (pxf, pyf) = match p.prod_xy {
        Some((px, py)) => (px as f64, py as f64),
        None => (0.0, 0.0),
    };
    let has_prod = p.prod_xy.is_some();
    let vpx = _mm256_set1_pd(pxf);
    let vpy = _mm256_set1_pd(pyf);
    let mut t = 0;
    while t + 4 <= n {
        let vf = _mm256_loadu_pd(flops.as_ptr().add(t));
        let vw = _mm256_loadu_pd(weights.as_ptr().add(t));
        let va = _mm256_loadu_pd(act.as_ptr().add(t));
        // load = wl·((f·imf + 0.3·(w·imw)) + (0.1·a)·inv64k)
        let s1 = _mm256_add_pd(
            _mm256_mul_pd(vf, vimf),
            _mm256_mul_pd(v03, _mm256_mul_pd(vw, vimw)),
        );
        let load =
            _mm256_mul_pd(vwl, _mm256_add_pd(s1, _mm256_mul_pd(_mm256_mul_pd(v01, va), v64k)));
        let hop = if has_prod {
            let dx = _mm256_andnot_pd(
                sign,
                _mm256_sub_pd(vpx, _mm256_loadu_pd(g.xf.as_ptr().add(t))),
            );
            let dy = _mm256_andnot_pd(
                sign,
                _mm256_sub_pd(vpy, _mm256_loadu_pd(g.yf.as_ptr().add(t))),
            );
            _mm256_mul_pd(_mm256_add_pd(dx, dy), vspan)
        } else {
            vzero
        };
        let imb = _mm256_max_pd(_mm256_mul_pd(_mm256_sub_pd(vf, vmf), vimf), vzero);
        let score = _mm256_add_pd(
            _mm256_add_pd(_mm256_add_pd(load, _mm256_mul_pd(v08, hop)), _mm256_mul_pd(v05, imb)),
            _mm256_mul_pd(vcw, _mm256_loadu_pd(g.central_penalty.as_ptr().add(t))),
        );
        _mm256_storeu_pd(out.as_mut_ptr().add(t), score);
        t += 4;
    }
    // ragged tail: the scalar expression verbatim
    while t < n {
        let f = flops[t];
        let load = p.wl
            * (f * p.inv_mean_f
                + 0.3 * (weights[t] * p.inv_mean_w)
                + 0.1 * act[t] * INV_64K);
        let hop = if has_prod {
            ((pxf - g.xf[t]).abs() + (pyf - g.yf[t]).abs()) * p.inv_span
        } else {
            0.0
        };
        let imb = ((f - p.mean_f) * p.inv_mean_f).max(0.0);
        out[t] = load + 0.8 * hop + 0.5 * imb + p.central_w * g.central_penalty[t];
        t += 1;
    }
}

/// NEON 2-wide f64 scoring — same bit-identity contract as [`score_avx2`]
/// (`vabsq_f64`/`vmaxq_f64` are exact sign-bit/IEEE max operations; no
/// FMA contraction is used).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn score_neon(
    g: &MeshGeom,
    p: &ScoreParams,
    flops: &[f64],
    weights: &[f64],
    act: &[f64],
    out: &mut [f64],
) {
    use core::arch::aarch64::*;
    let n = g.xy.len();
    let vwl = vdupq_n_f64(p.wl);
    let vimf = vdupq_n_f64(p.inv_mean_f);
    let vimw = vdupq_n_f64(p.inv_mean_w);
    let vmf = vdupq_n_f64(p.mean_f);
    let vspan = vdupq_n_f64(p.inv_span);
    let vcw = vdupq_n_f64(p.central_w);
    let v03 = vdupq_n_f64(0.3);
    let v01 = vdupq_n_f64(0.1);
    let v05 = vdupq_n_f64(0.5);
    let v08 = vdupq_n_f64(0.8);
    let v64k = vdupq_n_f64(INV_64K);
    let vzero = vdupq_n_f64(0.0);
    let (pxf, pyf) = match p.prod_xy {
        Some((px, py)) => (px as f64, py as f64),
        None => (0.0, 0.0),
    };
    let has_prod = p.prod_xy.is_some();
    let vpx = vdupq_n_f64(pxf);
    let vpy = vdupq_n_f64(pyf);
    let mut t = 0;
    while t + 2 <= n {
        let vf = vld1q_f64(flops.as_ptr().add(t));
        let vw = vld1q_f64(weights.as_ptr().add(t));
        let va = vld1q_f64(act.as_ptr().add(t));
        let s1 = vaddq_f64(vmulq_f64(vf, vimf), vmulq_f64(v03, vmulq_f64(vw, vimw)));
        let load = vmulq_f64(vwl, vaddq_f64(s1, vmulq_f64(vmulq_f64(v01, va), v64k)));
        let hop = if has_prod {
            let dx = vabsq_f64(vsubq_f64(vpx, vld1q_f64(g.xf.as_ptr().add(t))));
            let dy = vabsq_f64(vsubq_f64(vpy, vld1q_f64(g.yf.as_ptr().add(t))));
            vmulq_f64(vaddq_f64(dx, dy), vspan)
        } else {
            vzero
        };
        let imb = vmaxq_f64(vmulq_f64(vsubq_f64(vf, vmf), vimf), vzero);
        let score = vaddq_f64(
            vaddq_f64(vaddq_f64(load, vmulq_f64(v08, hop)), vmulq_f64(v05, imb)),
            vmulq_f64(vcw, vld1q_f64(g.central_penalty.as_ptr().add(t))),
        );
        vst1q_f64(out.as_mut_ptr().add(t), score);
        t += 2;
    }
    while t < n {
        let f = flops[t];
        let load = p.wl
            * (f * p.inv_mean_f
                + 0.3 * (weights[t] * p.inv_mean_w)
                + 0.1 * act[t] * INV_64K);
        let hop = if has_prod {
            ((pxf - g.xf[t]).abs() + (pyf - g.yf[t]).abs()) * p.inv_span
        } else {
            0.0
        };
        let imb = ((f - p.mean_f) * p.inv_mean_f).max(0.0);
        out[t] = load + 0.8 * hop + 0.5 * imb + p.central_w * g.central_penalty[t];
        t += 1;
    }
}

/// A small cache of [`MeshGeom`] tables keyed by mesh dims. The Algorithm
/// 1 walk revisits a handful of dimensions, so a bounded linear-scan store
/// with wholesale reset (deterministic, like [`crate::eval::EvalCache`])
/// is enough.
#[derive(Debug, Default)]
pub struct GeomCache {
    geoms: Vec<Arc<MeshGeom>>,
    pub hits: u64,
    pub misses: u64,
    /// Local misses served from the process-wide registry instead of a
    /// rebuild (the cross-lane/cross-scenario reuse counter).
    pub shared: u64,
}

/// Process-wide registry of read-only geometry tables, one per mesh
/// dims. [`MeshGeom::build`] is a pure function of the dims, so every
/// lane, scenario point and worker thread can share one immutable table
/// behind an `Arc` — a local [`GeomCache`] miss consults the registry
/// before rebuilding, and publishes what it builds. Bounded: past
/// [`GEOM_REGISTRY_CAP`] dims the registry stops admitting (lookups keep
/// working), so a pathological sweep cannot pin unbounded memory.
static GEOM_REGISTRY: OnceLock<Mutex<HashMap<(u32, u32), Arc<MeshGeom>>>> = OnceLock::new();

/// Distinct mesh dims the shared registry keeps resident (a 64×64 table
/// is ~100 KB; 64 tables stay well under 10 MB).
pub const GEOM_REGISTRY_CAP: usize = 64;

fn geom_registry() -> &'static Mutex<HashMap<(u32, u32), Arc<MeshGeom>>> {
    GEOM_REGISTRY.get_or_init(|| Mutex::new(HashMap::new()))
}

impl GeomCache {
    /// Resident geometry tables (a 64×64 table is ~100 KB).
    const CAP: usize = 16;

    pub fn get(&mut self, mesh: &MeshConfig) -> &MeshGeom {
        let pos = self.geoms.iter().position(|g| g.matches(mesh));
        match pos {
            Some(i) => {
                self.hits += 1;
                self.geoms[i].as_ref()
            }
            None => {
                self.misses += 1;
                if self.geoms.len() >= Self::CAP {
                    self.geoms.clear();
                }
                let dims = (mesh.width, mesh.height);
                let mut reg = geom_registry().lock().unwrap();
                let table = match reg.get(&dims) {
                    Some(shared) => {
                        self.shared += 1;
                        Arc::clone(shared)
                    }
                    None => {
                        let built = Arc::new(MeshGeom::build(mesh));
                        if reg.len() < GEOM_REGISTRY_CAP {
                            reg.insert(dims, Arc::clone(&built));
                        }
                        built
                    }
                };
                drop(reg);
                self.geoms.push(table);
                self.geoms.last().unwrap().as_ref()
            }
        }
    }
}

/// Does the route between tiles `a` and `b` cross the vertical bisection
/// of the mesh (for Eq 23's cross-bisection byte counting)?
pub fn crosses_bisection(mesh: &MeshConfig, a: usize, b: usize) -> bool {
    let half = mesh.width / 2;
    let ax = a as u32 % mesh.width;
    let bx = b as u32 % mesh.width;
    (ax < half) != (bx < half)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bisection_bw_eq18() {
        let noc = NocModel {
            mesh: MeshConfig::new(41, 42),
            dflit_bits: 2048,
            clock_mhz: 1000.0,
        };
        // min(41,42) * 256 B * 1e9 Hz = 10.496 TB/s
        let bw = noc.bisection_bw_bytes();
        assert!((bw - 41.0 * 256.0 * 1e9).abs() / bw < 1e-12);
    }

    #[test]
    fn latency_grows_with_mesh() {
        let small = NocModel { mesh: MeshConfig::new(4, 4), dflit_bits: 512, clock_mhz: 500.0 };
        let big = NocModel { mesh: MeshConfig::new(40, 40), dflit_bits: 512, clock_mhz: 500.0 };
        assert!(big.mean_latency_s() > small.mean_latency_s());
    }

    #[test]
    fn sc_overlay_reduces_hops() {
        let mut m = MeshConfig::new(30, 30);
        m.sc_x = 1;
        m.sc_y = 1;
        let flat = NocModel { mesh: m, dflit_bits: 512, clock_mhz: 500.0 };
        let mut m2 = MeshConfig::new(30, 30);
        m2.sc_x = 4;
        m2.sc_y = 4;
        let clustered = NocModel { mesh: m2, dflit_bits: 512, clock_mhz: 500.0 };
        assert!(clustered.mean_hops_effective() < flat.mean_hops_effective());
        assert!(clustered.mean_hops_effective() >= flat.mean_hops_effective() * 0.5);
    }

    #[test]
    fn traffic_accounting() {
        let mut t = TrafficStats::default();
        t.record(100.0, 0, false); // same tile: ignored
        t.record(100.0, 2, false);
        t.record(50.0, 4, true);
        assert_eq!(t.cross_tile_bytes, 150.0);
        assert_eq!(t.byte_hops, 400.0);
        assert_eq!(t.bisection_bytes, 50.0);
        assert_eq!(t.n_transfers, 2);
        assert!((t.mean_hops() - 400.0 / 150.0).abs() < 1e-12);
    }

    #[test]
    fn bisection_detection() {
        let mesh = MeshConfig::new(4, 4);
        assert!(crosses_bisection(&mesh, 0, 3)); // x=0 -> x=3
        assert!(!crosses_bisection(&mesh, 0, 1)); // x=0 -> x=1 (same half)
        assert!(!crosses_bisection(&mesh, 2, 3));
    }

    #[test]
    fn rho_comm_eq20() {
        assert!((rho_comm(1e6, 1e9) - 1e-3).abs() < 1e-15);
    }

    #[test]
    fn geom_matches_mesh_config() {
        // every precomputed accessor must agree bit-for-bit with the
        // on-the-fly MeshConfig computation the placement loop used before
        for (w, h) in [(2u32, 2u32), (4, 4), (5, 7), (41, 42)] {
            let mesh = MeshConfig::new(w, h);
            let g = MeshGeom::build(&mesh);
            assert!(g.matches(&mesh));
            for t in 0..mesh.cores() {
                let (x, y) = g.xy[t];
                assert_eq!(x as u32, t as u32 % w);
                assert_eq!(y as u32, t as u32 / w);
                assert_eq!(g.xf[t], x as f64);
                assert_eq!(g.yf[t], y as f64);
                assert_eq!(
                    g.central_penalty[t].to_bits(),
                    (1.0 - mesh.centrality(t)).to_bits()
                );
            }
            for (a, b) in [(0usize, mesh.cores() - 1), (1, 2), (0, 0)] {
                assert_eq!(g.hop(a, b), mesh.hop_distance(a, b));
                assert_eq!(g.crosses(a, b), crosses_bisection(&mesh, a, b));
            }
        }
    }

    /// Synthetic-but-representative tile state + per-unit constants for
    /// the scoring parity tests (sizes deliberately not multiples of the
    /// f64 vector widths 2 and 4).
    fn score_fixture(w: u32, h: u32) -> (MeshGeom, ScoreParams, Vec<f64>, Vec<f64>, Vec<f64>) {
        let mesh = MeshConfig::new(w, h);
        let g = MeshGeom::build(&mesh);
        let n = mesh.cores();
        let flops: Vec<f64> = (0..n).map(|t| ((t * 13 % 29) as f64) * 3.7e7).collect();
        let weights: Vec<f64> = (0..n).map(|t| ((t * 7 % 17) as f64) * 1.1e5).collect();
        let act: Vec<f64> = (0..n).map(|t| ((t * 5 % 11) as f64) * 2048.0).collect();
        let total_f: f64 = 1.0 + flops.iter().sum::<f64>();
        let total_w: f64 = 1.0 + weights.iter().sum::<f64>();
        let p = ScoreParams {
            wl: 1.3,
            inv_mean_f: n as f64 / total_f,
            inv_mean_w: n as f64 / total_w,
            mean_f: total_f / n as f64,
            inv_span: 1.0 / (w + h) as f64,
            central_w: 0.3,
            prod_xy: Some(g.xy[n / 2]),
        };
        (g, p, flops, weights, act)
    }

    #[test]
    fn score_tiles_scalar_matches_inline_reference() {
        // the extracted scalar body must reproduce the pre-extraction
        // inline placement-loop arithmetic bit-for-bit, including the
        // integer-abs_diff hop term and the zeroed no-producer hop
        for prod in [true, false] {
            let (g, mut p, flops, weights, act) = score_fixture(7, 5);
            if !prod {
                p.prod_xy = None;
            }
            let n = flops.len();
            let mut got = vec![0.0f64; n];
            g.score_tiles_with(KernelPath::Scalar, &p, &flops, &weights, &act, &mut got);
            const INV_64K: f64 = 1.0 / (64.0 * 1024.0);
            for t in 0..n {
                let f = flops[t];
                let load = p.wl
                    * (f * p.inv_mean_f
                        + 0.3 * (weights[t] * p.inv_mean_w)
                        + 0.1 * act[t] * INV_64K);
                let hop = match p.prod_xy {
                    Some((px, py)) => {
                        let (tx, ty) = g.xy[t];
                        (px.abs_diff(tx) as f64 + py.abs_diff(ty) as f64) * p.inv_span
                    }
                    None => 0.0,
                };
                let imb = ((f - p.mean_f) * p.inv_mean_f).max(0.0);
                let want = load + 0.8 * hop + 0.5 * imb + p.central_w * g.central_penalty[t];
                assert_eq!(got[t].to_bits(), want.to_bits(), "tile {t} prod={prod}");
            }
        }
    }

    #[test]
    fn score_tiles_simd_is_bit_identical_to_scalar() {
        // the determinism contract of the f64 scoring path: SIMD lanes
        // perform the identical operation sequence, so scores (and hence
        // every argmin/argmax selection built on them) never change with
        // the kernel mode — including ragged tails
        let Some(path) = kernels::detect() else {
            eprintln!("skipping: no SIMD path on this CPU");
            return;
        };
        for (w, h) in [(2u32, 2u32), (5, 7), (9, 3), (12, 12)] {
            for prod in [true, false] {
                let (g, mut p, flops, weights, act) = score_fixture(w, h);
                if !prod {
                    p.prod_xy = None;
                }
                let n = flops.len();
                let mut scalar = vec![0.0f64; n];
                let mut simd = vec![0.0f64; n];
                g.score_tiles_with(KernelPath::Scalar, &p, &flops, &weights, &act, &mut scalar);
                g.score_tiles_with(path, &p, &flops, &weights, &act, &mut simd);
                for t in 0..n {
                    assert_eq!(
                        simd[t].to_bits(),
                        scalar[t].to_bits(),
                        "{w}x{h} tile {t} prod={prod}: {} vs {}",
                        simd[t],
                        scalar[t]
                    );
                }
            }
        }
    }

    #[test]
    fn geom_cache_hits_on_revisit() {
        let mut c = GeomCache::default();
        let m1 = MeshConfig::new(8, 8);
        let m2 = MeshConfig::new(8, 9);
        c.get(&m1);
        c.get(&m2);
        c.get(&m1);
        assert_eq!((c.hits, c.misses), (1, 2));
        // SC overlay changes do not re-key (geometry is dims-only)
        let mut m1_sc = m1;
        m1_sc.sc_x = 4;
        c.get(&m1_sc);
        assert_eq!((c.hits, c.misses), (2, 2));
    }

    #[test]
    fn geom_registry_shares_tables_across_caches() {
        // distinctive dims so parallel tests can't have seeded them via
        // another path before cache 1 publishes
        let m = MeshConfig::new(37, 41);
        let mut c1 = GeomCache::default();
        let g1 = c1.get(&m).xy.clone();
        // a *fresh* cache misses locally but is served from the shared
        // registry instead of rebuilding
        let mut c2 = GeomCache::default();
        let g2 = c2.get(&m);
        assert_eq!(c2.misses, 1);
        assert!(c2.shared >= 1, "fresh cache rebuilt a published table");
        assert_eq!(g1, g2.xy, "shared table diverged from the built one");
        // local hits never touch the registry counter
        c2.get(&m);
        assert_eq!((c2.hits, c2.shared), (1, 1));
    }
}
