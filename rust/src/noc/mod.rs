//! Network-on-Chip model (§3.7): bisection bandwidth (Eq 18), hop/latency
//! model (Eq 19), communication-to-computation ratio (Eq 20), and the
//! per-token cross-tile traffic accounting the partitioner feeds into the
//! power and throughput models.

use crate::arch::MeshConfig;

/// NoC-level configuration + derived metrics for one candidate design.
#[derive(Debug, Clone)]
pub struct NocModel {
    pub mesh: MeshConfig,
    pub dflit_bits: u32,
    pub clock_mhz: f64,
}

/// Per-hop latency in cycles and routing setup overhead (Eq 19 constants).
pub const L_HOP_CYCLES: f64 = 1.0;
pub const L_SETUP_CYCLES: f64 = 3.0;

impl NocModel {
    /// Bisection bandwidth in bytes/s (Eq 18):
    /// BW = min(M,N) · W_DFLIT · f_node.
    pub fn bisection_bw_bytes(&self) -> f64 {
        let links = self.mesh.width.min(self.mesh.height) as f64;
        links * (self.dflit_bits as f64 / 8.0) * self.clock_mhz * 1e6
    }

    /// Mean hop count h̄ = (M+N)/3 (Eq 19). Sub-cluster express links
    /// shorten long paths: effective hops divide by the SC overlay factor
    /// for the inter-cluster fraction of the route.
    pub fn mean_hops_effective(&self) -> f64 {
        let base = self.mesh.mean_hops();
        let sc = (self.mesh.sc_x.max(1) * self.mesh.sc_y.max(1)) as f64;
        // express links cover ~half of an average route when SC > 1
        if sc > 1.0 {
            base * (0.5 + 0.5 / sc.sqrt())
        } else {
            base
        }
    }

    /// Mean NoC transfer latency in seconds for one flit-sized message
    /// (Eq 19: L = h̄ · L_hop + L_setup).
    pub fn mean_latency_s(&self) -> f64 {
        let cycles = self.mean_hops_effective() * L_HOP_CYCLES + L_SETUP_CYCLES;
        cycles / (self.clock_mhz * 1e6)
    }

    /// Per-link bandwidth (bytes/s) — used for hot-link saturation checks.
    pub fn link_bw_bytes(&self) -> f64 {
        (self.dflit_bits as f64 / 8.0) * self.clock_mhz * 1e6
    }
}

/// Cross-tile traffic accounting accumulated during placement.
#[derive(Debug, Clone, Default)]
pub struct TrafficStats {
    /// Total tensor bytes crossing tile boundaries per token.
    pub cross_tile_bytes: f64,
    /// Bytes × hops per token (energy integral for Eq 62's NoC term).
    pub byte_hops: f64,
    /// Bytes crossing the mesh bisection per token (Eq 23 denominator).
    pub bisection_bytes: f64,
    /// Number of cross-tile tensor transfers.
    pub n_transfers: u64,
}

impl TrafficStats {
    /// Record a `bytes`-sized transfer over `hops` mesh hops, of which
    /// `crosses_bisection` says whether the route crosses the mesh midline.
    pub fn record(&mut self, bytes: f64, hops: u32, crosses_bisection: bool) {
        if hops == 0 {
            return; // same-tile: stays in DMEM
        }
        self.cross_tile_bytes += bytes;
        self.byte_hops += bytes * hops as f64;
        if crosses_bisection {
            self.bisection_bytes += bytes;
        }
        self.n_transfers += 1;
    }

    pub fn mean_hops(&self) -> f64 {
        if self.cross_tile_bytes <= 0.0 {
            0.0
        } else {
            self.byte_hops / self.cross_tile_bytes
        }
    }
}

/// Communication-to-computation ratio ρ_comm (Eq 20).
pub fn rho_comm(edge_tensor_bytes: f64, total_flops: f64) -> f64 {
    edge_tensor_bytes / total_flops.max(1.0)
}

/// Precomputed per-mesh-dims geometry (DESIGN.md §5): tile coordinates,
/// centrality penalties and the bisection-half mask, built once per
/// `(width, height)` and cached across placements so the O(units × cores)
/// scoring loop and the traffic accounting never recompute div/mod,
/// centrality or the bisection test per (operator, tile) pair.
///
/// Every accessor is bit-identical to the corresponding on-the-fly
/// [`MeshConfig`] computation (pinned by `geom_matches_mesh_config`), so
/// cached and uncached placements produce identical results.
#[derive(Debug, Clone)]
pub struct MeshGeom {
    pub width: u32,
    pub height: u32,
    /// (x, y) per tile index.
    pub xy: Vec<(u16, u16)>,
    /// 1 − centrality(t) per tile (§3.5 step 4 score term).
    pub central_penalty: Vec<f64>,
    /// Whether the tile lies west of the vertical bisection (x < width/2).
    west: Vec<bool>,
}

impl MeshGeom {
    pub fn build(mesh: &MeshConfig) -> MeshGeom {
        let n = mesh.cores();
        let half = mesh.width / 2;
        let mut xy = Vec::with_capacity(n);
        let mut central_penalty = Vec::with_capacity(n);
        let mut west = Vec::with_capacity(n);
        for t in 0..n {
            let x = t as u32 % mesh.width;
            let y = t as u32 / mesh.width;
            xy.push((x as u16, y as u16));
            central_penalty.push(1.0 - mesh.centrality(t));
            west.push(x < half);
        }
        MeshGeom { width: mesh.width, height: mesh.height, xy, central_penalty, west }
    }

    /// Does this table describe `mesh`'s dimensions? (SC overlay does not
    /// affect geometry, so it is not part of the key.)
    pub fn matches(&self, mesh: &MeshConfig) -> bool {
        self.width == mesh.width && self.height == mesh.height
    }

    /// Manhattan hop distance via the coordinate table — bit-identical to
    /// [`MeshConfig::hop_distance`].
    #[inline]
    pub fn hop(&self, a: usize, b: usize) -> u32 {
        let (ax, ay) = self.xy[a];
        let (bx, by) = self.xy[b];
        (ax.abs_diff(bx) + ay.abs_diff(by)) as u32
    }

    /// Bisection-crossing test via the half mask — bit-identical to
    /// [`crosses_bisection`].
    #[inline]
    pub fn crosses(&self, a: usize, b: usize) -> bool {
        self.west[a] != self.west[b]
    }
}

/// A small cache of [`MeshGeom`] tables keyed by mesh dims. The Algorithm
/// 1 walk revisits a handful of dimensions, so a bounded linear-scan store
/// with wholesale reset (deterministic, like [`crate::eval::EvalCache`])
/// is enough.
#[derive(Debug, Default)]
pub struct GeomCache {
    geoms: Vec<MeshGeom>,
    pub hits: u64,
    pub misses: u64,
}

impl GeomCache {
    /// Resident geometry tables (a 64×64 table is ~100 KB).
    const CAP: usize = 16;

    pub fn get(&mut self, mesh: &MeshConfig) -> &MeshGeom {
        let pos = self.geoms.iter().position(|g| g.matches(mesh));
        match pos {
            Some(i) => {
                self.hits += 1;
                &self.geoms[i]
            }
            None => {
                self.misses += 1;
                if self.geoms.len() >= Self::CAP {
                    self.geoms.clear();
                }
                self.geoms.push(MeshGeom::build(mesh));
                self.geoms.last().unwrap()
            }
        }
    }
}

/// Does the route between tiles `a` and `b` cross the vertical bisection
/// of the mesh (for Eq 23's cross-bisection byte counting)?
pub fn crosses_bisection(mesh: &MeshConfig, a: usize, b: usize) -> bool {
    let half = mesh.width / 2;
    let ax = a as u32 % mesh.width;
    let bx = b as u32 % mesh.width;
    (ax < half) != (bx < half)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bisection_bw_eq18() {
        let noc = NocModel {
            mesh: MeshConfig::new(41, 42),
            dflit_bits: 2048,
            clock_mhz: 1000.0,
        };
        // min(41,42) * 256 B * 1e9 Hz = 10.496 TB/s
        let bw = noc.bisection_bw_bytes();
        assert!((bw - 41.0 * 256.0 * 1e9).abs() / bw < 1e-12);
    }

    #[test]
    fn latency_grows_with_mesh() {
        let small = NocModel { mesh: MeshConfig::new(4, 4), dflit_bits: 512, clock_mhz: 500.0 };
        let big = NocModel { mesh: MeshConfig::new(40, 40), dflit_bits: 512, clock_mhz: 500.0 };
        assert!(big.mean_latency_s() > small.mean_latency_s());
    }

    #[test]
    fn sc_overlay_reduces_hops() {
        let mut m = MeshConfig::new(30, 30);
        m.sc_x = 1;
        m.sc_y = 1;
        let flat = NocModel { mesh: m, dflit_bits: 512, clock_mhz: 500.0 };
        let mut m2 = MeshConfig::new(30, 30);
        m2.sc_x = 4;
        m2.sc_y = 4;
        let clustered = NocModel { mesh: m2, dflit_bits: 512, clock_mhz: 500.0 };
        assert!(clustered.mean_hops_effective() < flat.mean_hops_effective());
        assert!(clustered.mean_hops_effective() >= flat.mean_hops_effective() * 0.5);
    }

    #[test]
    fn traffic_accounting() {
        let mut t = TrafficStats::default();
        t.record(100.0, 0, false); // same tile: ignored
        t.record(100.0, 2, false);
        t.record(50.0, 4, true);
        assert_eq!(t.cross_tile_bytes, 150.0);
        assert_eq!(t.byte_hops, 400.0);
        assert_eq!(t.bisection_bytes, 50.0);
        assert_eq!(t.n_transfers, 2);
        assert!((t.mean_hops() - 400.0 / 150.0).abs() < 1e-12);
    }

    #[test]
    fn bisection_detection() {
        let mesh = MeshConfig::new(4, 4);
        assert!(crosses_bisection(&mesh, 0, 3)); // x=0 -> x=3
        assert!(!crosses_bisection(&mesh, 0, 1)); // x=0 -> x=1 (same half)
        assert!(!crosses_bisection(&mesh, 2, 3));
    }

    #[test]
    fn rho_comm_eq20() {
        assert!((rho_comm(1e6, 1e9) - 1e-3).abs() < 1e-15);
    }

    #[test]
    fn geom_matches_mesh_config() {
        // every precomputed accessor must agree bit-for-bit with the
        // on-the-fly MeshConfig computation the placement loop used before
        for (w, h) in [(2u32, 2u32), (4, 4), (5, 7), (41, 42)] {
            let mesh = MeshConfig::new(w, h);
            let g = MeshGeom::build(&mesh);
            assert!(g.matches(&mesh));
            for t in 0..mesh.cores() {
                let (x, y) = g.xy[t];
                assert_eq!(x as u32, t as u32 % w);
                assert_eq!(y as u32, t as u32 / w);
                assert_eq!(
                    g.central_penalty[t].to_bits(),
                    (1.0 - mesh.centrality(t)).to_bits()
                );
            }
            for (a, b) in [(0usize, mesh.cores() - 1), (1, 2), (0, 0)] {
                assert_eq!(g.hop(a, b), mesh.hop_distance(a, b));
                assert_eq!(g.crosses(a, b), crosses_bisection(&mesh, a, b));
            }
        }
    }

    #[test]
    fn geom_cache_hits_on_revisit() {
        let mut c = GeomCache::default();
        let m1 = MeshConfig::new(8, 8);
        let m2 = MeshConfig::new(8, 9);
        c.get(&m1);
        c.get(&m2);
        c.get(&m1);
        assert_eq!((c.hits, c.misses), (1, 2));
        // SC overlay changes do not re-key (geometry is dims-only)
        let mut m1_sc = m1;
        m1_sc.sc_x = 4;
        c.get(&m1_sc);
        assert_eq!((c.hits, c.misses), (2, 2));
    }
}
