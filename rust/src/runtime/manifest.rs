//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime. Parsed from `artifacts/manifest.json`.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// One tensor in an entrypoint signature (positional order matters).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-lowered computation.
#[derive(Debug, Clone)]
pub struct EntryPoint {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Parameter-store initialization recipe (aot.py `store_inits`).
#[derive(Debug, Clone, PartialEq)]
pub enum InitKind {
    Zeros,
    /// He/Kaiming: N(0, sqrt(2/fan_in)).
    He,
    Const(f64),
    /// Copy from another store entry (Polyak targets start as copies).
    Copy(String),
}

#[derive(Debug, Clone)]
pub struct StoreInit {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: InitKind,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub entrypoints: BTreeMap<String, EntryPoint>,
    pub stores: Vec<StoreInit>,
    pub hyper: BTreeMap<String, f64>,
}

fn parse_specs(arr: &Json) -> Result<Vec<TensorSpec>, String> {
    arr.as_arr()
        .ok_or("specs not an array")?
        .iter()
        .map(|e| {
            let name = e
                .get("name")
                .and_then(Json::as_str)
                .ok_or("spec missing name")?
                .to_string();
            let shape = e
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or("spec missing shape")?
                .iter()
                .map(|d| d.as_usize().ok_or("bad dim"))
                .collect::<Result<Vec<_>, _>>()?;
            Ok(TensorSpec { name, shape })
        })
        .collect()
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest, String> {
        let j = Json::parse(text)?;
        let mut entrypoints = BTreeMap::new();
        for (name, ep) in j
            .get("entrypoints")
            .and_then(Json::as_obj)
            .ok_or("manifest missing entrypoints")?
        {
            entrypoints.insert(
                name.clone(),
                EntryPoint {
                    file: ep
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or("entrypoint missing file")?
                        .to_string(),
                    inputs: parse_specs(ep.get("inputs").ok_or("missing inputs")?)?,
                    outputs: parse_specs(ep.get("outputs").ok_or("missing outputs")?)?,
                },
            );
        }

        let mut stores = Vec::new();
        for (name, st) in j
            .get("stores")
            .and_then(Json::as_obj)
            .ok_or("manifest missing stores")?
        {
            let shape = st
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or("store missing shape")?
                .iter()
                .map(|d| d.as_usize().ok_or("bad dim"))
                .collect::<Result<Vec<_>, _>>()?;
            let init_s = st
                .get("init")
                .and_then(Json::as_str)
                .ok_or("store missing init")?;
            let init = if init_s == "zeros" {
                InitKind::Zeros
            } else if init_s == "he" {
                InitKind::He
            } else if let Some(v) = init_s.strip_prefix("const:") {
                InitKind::Const(v.parse().map_err(|_| format!("bad const {v}"))?)
            } else if let Some(src) = init_s.strip_prefix("copy:") {
                InitKind::Copy(src.to_string())
            } else {
                return Err(format!("unknown init recipe {init_s}"));
            };
            stores.push(StoreInit { name: name.clone(), shape, init });
        }

        let mut hyper = BTreeMap::new();
        if let Some(h) = j.get("hyper").and_then(Json::as_obj) {
            for (k, v) in h {
                if let Some(n) = v.as_f64() {
                    hyper.insert(k.clone(), n);
                }
            }
        }
        Ok(Manifest { entrypoints, stores, hyper })
    }

    pub fn hyper_or(&self, key: &str, default: f64) -> f64 {
        self.hyper.get(key).copied().unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "entrypoints": {
        "f": {
          "file": "f.hlo.txt",
          "inputs": [{"name": "state/w", "shape": [2, 3], "dtype": "f32"},
                     {"name": "batch/x", "shape": [], "dtype": "f32"}],
          "outputs": [{"name": "state/w", "shape": [2, 3], "dtype": "f32"}]
        }
      },
      "stores": {
        "w": {"shape": [2, 3], "init": "he"},
        "w_m": {"shape": [2, 3], "init": "zeros"},
        "t": {"shape": [2, 3], "init": "copy:w"},
        "la": {"shape": [], "init": "const:-1.5"}
      },
      "hyper": {"lr": 0.0003, "batch": 256}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let ep = &m.entrypoints["f"];
        assert_eq!(ep.inputs.len(), 2);
        assert_eq!(ep.inputs[0].elems(), 6);
        assert_eq!(ep.inputs[1].elems(), 1); // scalar
        assert_eq!(m.stores.len(), 4);
        assert!(m
            .stores
            .iter()
            .any(|s| s.init == InitKind::Copy("w".into())));
        assert!(m.stores.iter().any(|s| s.init == InitKind::Const(-1.5)));
        assert_eq!(m.hyper_or("batch", 0.0), 256.0);
        assert_eq!(m.hyper_or("nope", 7.0), 7.0);
    }

    #[test]
    fn rejects_bad_init() {
        let bad = SAMPLE.replace("\"he\"", "\"bogus\"");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn parses_real_manifest_when_built() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = Manifest::parse(&text).unwrap();
            assert!(m.entrypoints.contains_key("sac_update"));
            assert!(m.entrypoints.contains_key("actor_fwd_b1"));
            assert_eq!(m.hyper_or("state_dim", 0.0), 52.0);
            assert_eq!(m.hyper_or("act_dim", 0.0), 30.0);
            // every sac_update state input is initializable
            let names: std::collections::BTreeSet<_> =
                m.stores.iter().map(|s| s.name.clone()).collect();
            for i in &m.entrypoints["sac_update"].inputs {
                if let Some(k) = i.name.strip_prefix("state/") {
                    assert!(names.contains(k), "{k} missing from stores");
                }
            }
        }
    }
}
