//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime. Parsed from `artifacts/manifest.json`.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// One tensor in an entrypoint signature (positional order matters).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-lowered computation.
#[derive(Debug, Clone)]
pub struct EntryPoint {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Parameter-store initialization recipe (aot.py `store_inits`).
#[derive(Debug, Clone, PartialEq)]
pub enum InitKind {
    Zeros,
    /// He/Kaiming: N(0, sqrt(2/fan_in)).
    He,
    Const(f64),
    /// Copy from another store entry (Polyak targets start as copies).
    Copy(String),
}

#[derive(Debug, Clone)]
pub struct StoreInit {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: InitKind,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub entrypoints: BTreeMap<String, EntryPoint>,
    pub stores: Vec<StoreInit>,
    pub hyper: BTreeMap<String, f64>,
}

fn parse_specs(arr: &Json) -> Result<Vec<TensorSpec>, String> {
    arr.as_arr()
        .ok_or("specs not an array")?
        .iter()
        .map(|e| {
            let name = e
                .get("name")
                .and_then(Json::as_str)
                .ok_or("spec missing name")?
                .to_string();
            let shape = e
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or("spec missing shape")?
                .iter()
                .map(|d| d.as_usize().ok_or("bad dim"))
                .collect::<Result<Vec<_>, _>>()?;
            Ok(TensorSpec { name, shape })
        })
        .collect()
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest, String> {
        let j = Json::parse(text)?;
        let mut entrypoints = BTreeMap::new();
        for (name, ep) in j
            .get("entrypoints")
            .and_then(Json::as_obj)
            .ok_or("manifest missing entrypoints")?
        {
            entrypoints.insert(
                name.clone(),
                EntryPoint {
                    file: ep
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or("entrypoint missing file")?
                        .to_string(),
                    inputs: parse_specs(ep.get("inputs").ok_or("missing inputs")?)?,
                    outputs: parse_specs(ep.get("outputs").ok_or("missing outputs")?)?,
                },
            );
        }

        let mut stores = Vec::new();
        for (name, st) in j
            .get("stores")
            .and_then(Json::as_obj)
            .ok_or("manifest missing stores")?
        {
            let shape = st
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or("store missing shape")?
                .iter()
                .map(|d| d.as_usize().ok_or("bad dim"))
                .collect::<Result<Vec<_>, _>>()?;
            let init_s = st
                .get("init")
                .and_then(Json::as_str)
                .ok_or("store missing init")?;
            let init = if init_s == "zeros" {
                InitKind::Zeros
            } else if init_s == "he" {
                InitKind::He
            } else if let Some(v) = init_s.strip_prefix("const:") {
                InitKind::Const(v.parse().map_err(|_| format!("bad const {v}"))?)
            } else if let Some(src) = init_s.strip_prefix("copy:") {
                InitKind::Copy(src.to_string())
            } else {
                return Err(format!("unknown init recipe {init_s}"));
            };
            stores.push(StoreInit { name: name.clone(), shape, init });
        }

        let mut hyper = BTreeMap::new();
        if let Some(h) = j.get("hyper").and_then(Json::as_obj) {
            for (k, v) in h {
                if let Some(n) = v.as_f64() {
                    hyper.insert(k.clone(), n);
                }
            }
        }
        Ok(Manifest { entrypoints, stores, hyper })
    }

    pub fn hyper_or(&self, key: &str, default: f64) -> f64 {
        self.hyper.get(key).copied().unwrap_or(default)
    }

    /// The builtin manifest: the same stores (shapes + init recipes) and
    /// Table-6 hyperparameters `python/compile/aot.py` writes into
    /// `artifacts/manifest.json`, constructed without artifacts. Store
    /// order matches a parsed manifest (lexicographic — aot.py dumps with
    /// `sort_keys=True` and [`Json`] objects are `BTreeMap`s), so
    /// [`crate::nn::Store::from_manifest`] draws He-init values in the
    /// same RNG order and produces bit-identical parameters either way.
    /// `entrypoints` is empty: the native backend needs no lowered HLO.
    pub fn builtin() -> Manifest {
        fn scalar(name: &str, init: InitKind) -> StoreInit {
            StoreInit { name: name.to_string(), shape: vec![], init }
        }
        fn net(
            stores: &mut Vec<StoreInit>,
            prefix: &str,
            shapes: &[(&str, &[usize])],
        ) {
            for (k, shape) in shapes {
                let init =
                    if k.starts_with('W') { InitKind::He } else { InitKind::Zeros };
                stores.push(StoreInit {
                    name: format!("{prefix}/{k}"),
                    shape: shape.to_vec(),
                    init,
                });
                for moment in ["m", "v"] {
                    stores.push(StoreInit {
                        name: format!("{prefix}_{moment}/{k}"),
                        shape: shape.to_vec(),
                        init: InitKind::Zeros,
                    });
                }
            }
        }

        let actor: [(&str, &[usize]); 12] = [
            ("W1", &[52, 256]),
            ("b1", &[256]),
            ("W5", &[256, 256]),
            ("b5", &[256]),
            ("W2", &[256, 20]),
            ("b2", &[20]),
            ("Wg", &[52, 4]),
            ("bg", &[4]),
            ("W3", &[256, 120]),
            ("b3", &[120]),
            ("W4", &[256, 120]),
            ("b4", &[120]),
        ];
        let critic: [(&str, &[usize]); 6] = [
            ("Wa", &[82, 256]),
            ("ba", &[256]),
            ("Wb", &[256, 256]),
            ("bb", &[256]),
            ("Wc", &[256, 1]),
            ("bc", &[1]),
        ];
        let wm: [(&str, &[usize]); 6] = [
            ("W1", &[82, 128]),
            ("b1", &[128]),
            ("W2", &[128, 64]),
            ("b2", &[64]),
            ("W3", &[64, 52]),
            ("b3", &[52]),
        ];
        let sur: [(&str, &[usize]); 6] = [
            ("W1", &[82, 128]),
            ("b1", &[128]),
            ("W2", &[128, 64]),
            ("b2", &[64]),
            ("W3", &[64, 3]),
            ("b3", &[3]),
        ];

        let mut stores = Vec::new();
        net(&mut stores, "actor", &actor);
        net(&mut stores, "c1", &critic);
        net(&mut stores, "c2", &critic);
        for (tgt, src) in [("t1", "c1"), ("t2", "c2")] {
            for (k, shape) in &critic {
                stores.push(StoreInit {
                    name: format!("{tgt}/{k}"),
                    shape: shape.to_vec(),
                    init: InitKind::Copy(format!("{src}/{k}")),
                });
            }
        }
        // log α starts at ln(0.2): initial entropy coefficient (Table 6)
        stores.push(scalar("log_alpha", InitKind::Const(-1.6094379)));
        stores.push(scalar("la_m", InitKind::Zeros));
        stores.push(scalar("la_v", InitKind::Zeros));
        stores.push(scalar("step", InitKind::Zeros));
        net(&mut stores, "wm", &wm);
        net(&mut stores, "sur", &sur);
        stores.sort_by(|a, b| a.name.cmp(&b.name));

        let hyper: BTreeMap<String, f64> = [
            ("state_dim", 52.0),
            ("full_state_dim", 73.0),
            ("act_dim", 30.0),
            ("disc_dim", 20.0),
            ("hidden", 256.0),
            ("n_experts", 4.0),
            ("lr", 3e-4),
            ("gamma", 0.99),
            ("tau", 0.005),
            ("target_entropy", -30.0),
            ("logstd_min", -20.0),
            ("logstd_max", 2.0),
            ("log_alpha_min", -10.0),
            ("log_alpha_max", 10.0),
            ("lambda_lb", 0.01),
            ("wm_lr", 1.5e-4),
            ("sur_lr", 3e-4),
            ("batch", 256.0),
            ("mpc_batch", 64.0),
            ("adam_b1", 0.9),
            ("adam_b2", 0.999),
            ("adam_eps", 1e-8),
        ]
        .into_iter()
        .map(|(k, v)| (k.to_string(), v))
        .collect();

        Manifest { entrypoints: BTreeMap::new(), stores, hyper }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "entrypoints": {
        "f": {
          "file": "f.hlo.txt",
          "inputs": [{"name": "state/w", "shape": [2, 3], "dtype": "f32"},
                     {"name": "batch/x", "shape": [], "dtype": "f32"}],
          "outputs": [{"name": "state/w", "shape": [2, 3], "dtype": "f32"}]
        }
      },
      "stores": {
        "w": {"shape": [2, 3], "init": "he"},
        "w_m": {"shape": [2, 3], "init": "zeros"},
        "t": {"shape": [2, 3], "init": "copy:w"},
        "la": {"shape": [], "init": "const:-1.5"}
      },
      "hyper": {"lr": 0.0003, "batch": 256}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let ep = &m.entrypoints["f"];
        assert_eq!(ep.inputs.len(), 2);
        assert_eq!(ep.inputs[0].elems(), 6);
        assert_eq!(ep.inputs[1].elems(), 1); // scalar
        assert_eq!(m.stores.len(), 4);
        assert!(m
            .stores
            .iter()
            .any(|s| s.init == InitKind::Copy("w".into())));
        assert!(m.stores.iter().any(|s| s.init == InitKind::Const(-1.5)));
        assert_eq!(m.hyper_or("batch", 0.0), 256.0);
        assert_eq!(m.hyper_or("nope", 7.0), 7.0);
    }

    #[test]
    fn rejects_bad_init() {
        let bad = SAMPLE.replace("\"he\"", "\"bogus\"");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn builtin_manifest_is_sorted_and_complete() {
        let m = Manifest::builtin();
        // sorted like a parsed manifest.json (He-draw order contract)
        assert!(m.stores.windows(2).all(|w| w[0].name < w[1].name));
        // 3 nets with Adam moments + 2 targets + alpha/step scalars + 2 mlp3s
        assert_eq!(m.stores.len(), 12 * 3 + 6 * 3 * 2 + 6 * 2 + 4 + 6 * 3 * 2);
        let find = |n: &str| m.stores.iter().find(|s| s.name == n).unwrap();
        assert_eq!(find("actor/W1").shape, vec![52, 256]);
        assert_eq!(find("actor/W1").init, InitKind::He);
        assert_eq!(find("actor_m/W1").init, InitKind::Zeros);
        assert_eq!(find("t1/Wa").init, InitKind::Copy("c1/Wa".into()));
        assert_eq!(find("log_alpha").shape, Vec::<usize>::new());
        assert_eq!(m.hyper_or("batch", 0.0), 256.0);
        assert_eq!(m.hyper_or("state_dim", 0.0), 52.0);
        assert!(m.entrypoints.is_empty());
        // every sac state array has both Adam moments or is a target/scalar
        for s in &m.stores {
            assert!(!s.shape.iter().any(|&d| d == 0), "{} empty dim", s.name);
        }
    }

    #[test]
    fn builtin_matches_real_manifest_when_built() {
        // When the AOT artifacts exist, the builtin manifest must agree
        // with them exactly (names, shapes, init recipes, hyper): this is
        // the backend-portability contract for checkpoints.
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        let Ok(text) = std::fs::read_to_string(path) else { return };
        let real = Manifest::parse(&text).unwrap();
        let builtin = Manifest::builtin();
        assert_eq!(real.stores.len(), builtin.stores.len());
        for (r, b) in real.stores.iter().zip(&builtin.stores) {
            assert_eq!(r.name, b.name);
            assert_eq!(r.shape, b.shape, "{}", r.name);
            assert_eq!(r.init, b.init, "{}", r.name);
        }
        for (k, v) in &builtin.hyper {
            assert_eq!(real.hyper_or(k, f64::NAN), *v, "hyper {k}");
        }
    }

    #[test]
    fn parses_real_manifest_when_built() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = Manifest::parse(&text).unwrap();
            assert!(m.entrypoints.contains_key("sac_update"));
            assert!(m.entrypoints.contains_key("actor_fwd_b1"));
            assert_eq!(m.hyper_or("state_dim", 0.0), 52.0);
            assert_eq!(m.hyper_or("act_dim", 0.0), 30.0);
            // every sac_update state input is initializable
            let names: std::collections::BTreeSet<_> =
                m.stores.iter().map(|s| s.name.clone()).collect();
            for i in &m.entrypoints["sac_update"].inputs {
                if let Some(k) = i.name.strip_prefix("state/") {
                    assert!(names.contains(k), "{k} missing from stores");
                }
            }
        }
    }
}
