//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the optimization loop.
//!
//! Interchange is HLO *text* (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md). Python never
//! runs at optimization time — the manifest makes this module fully
//! table-driven.

pub mod manifest;

pub use manifest::{EntryPoint, InitKind, Manifest, StoreInit, TensorSpec};

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::bail;
use crate::error::{Context, Error, Result};

/// True when the PJRT backend can actually execute HLO. False under the
/// offline `xla` stub — artifact-dependent tests and benches gate on
/// this and skip with a clear message.
pub fn backend_available() -> bool {
    xla::backend_available()
}

/// Lazily-compiling executor over the artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Executed-call counter per entrypoint (perf accounting).
    pub call_counts: HashMap<String, u64>,
}

impl Runtime {
    /// Create a CPU PJRT client and parse `<dir>/manifest.json`.
    /// Executables compile lazily on first call.
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {}", dir.display()))?;
        let manifest = Manifest::parse(&text).map_err(Error::msg)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            exes: HashMap::new(),
            call_counts: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Eagerly compile an entrypoint (otherwise compiled on first call).
    pub fn ensure_compiled(&mut self, entry: &str) -> Result<()> {
        if self.exes.contains_key(entry) {
            return Ok(());
        }
        let ep = self
            .manifest
            .entrypoints
            .get(entry)
            .with_context(|| format!("unknown entrypoint {entry}"))?;
        let path = self.dir.join(&ep.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.exes.insert(entry.to_string(), exe);
        Ok(())
    }

    /// Execute `entry`, resolving each manifest input by name through
    /// `resolve` (returning a borrowed flat f32 slice). Returns
    /// (name, flat data) for every output, in manifest order.
    ///
    /// NOTE: goes through `execute_b` with caller-owned `PjRtBuffer`s —
    /// the vendored xla crate's `execute(&[Literal])` path `release()`s
    /// the device buffers it creates for each input and never frees them
    /// (xla_rs.cc `execute`), leaking ~the full input payload per call.
    /// With buffers we own, Drop reclaims them.
    pub fn call(
        &mut self,
        entry: &str,
        mut resolve: impl FnMut(&str) -> Option<Vec<f32>>,
    ) -> Result<Vec<(String, Vec<f32>)>> {
        self.ensure_compiled(entry)?;
        *self.call_counts.entry(entry.to_string()).or_insert(0) += 1;
        let ep = &self.manifest.entrypoints[entry];

        let mut buffers = Vec::with_capacity(ep.inputs.len());
        for spec in &ep.inputs {
            let data = resolve(&spec.name)
                .with_context(|| format!("{entry}: missing input {}", spec.name))?;
            if data.len() != spec.elems() {
                bail!(
                    "{entry}: input {} has {} elems, manifest shape {:?} wants {}",
                    spec.name,
                    data.len(),
                    spec.shape,
                    spec.elems()
                );
            }
            let dims: &[usize] = if spec.shape.is_empty() { &[] } else { &spec.shape };
            buffers.push(self.client.buffer_from_host_buffer::<f32>(
                &data, dims, None,
            )?);
        }

        let exe = self.exes.get(entry).unwrap();
        let result = exe.execute_b::<xla::PjRtBuffer>(&buffers)?[0][0].to_literal_sync()?;
        // lowered with return_tuple=True: unpack the tuple
        let parts = result.to_tuple()?;
        if parts.len() != ep.outputs.len() {
            bail!(
                "{entry}: got {} outputs, manifest lists {}",
                parts.len(),
                ep.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, spec) in parts.into_iter().zip(&ep.outputs) {
            let v = lit.to_vec::<f32>()?;
            if v.len() != spec.elems() {
                bail!(
                    "{entry}: output {} has {} elems, expected {}",
                    spec.name,
                    v.len(),
                    spec.elems()
                );
            }
            out.push((spec.name.clone(), v));
        }
        Ok(out)
    }
}

#[allow(dead_code)] // kept for Literal-path diagnostics + tests
fn make_literal(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    if shape.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    // End-to-end runtime tests (require `make artifacts`) live in
    // rust/tests/runtime_e2e.rs. Here: literal plumbing only.

    #[test]
    fn scalar_literal_round_trip() {
        let l = make_literal(&[2.5], &[]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![2.5]);
    }

    #[test]
    fn shaped_literal_round_trip() {
        let l = make_literal(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }
}
