//! Post-RL heterogeneous per-TCC derivation (§3.3).
//!
//! "The RL agent optimizes *average* TCC parameters. A post-RL derivation
//! step then computes per-TCC heterogeneous values for FETCH_SIZE, VLEN,
//! DMEM, IMEM, and WMEM based on each tile's workload characteristics
//! (compute load, hazard density, weight footprint). Only STANUM and the
//! NoC-level DFLIT_WIDTH remain uniform."
//!
//! Tiles hosting memory-heavy operators (attention projections, MLP
//! layers) receive larger WMEM and wider SIMD; lighter tiles receive
//! smaller allocations to save area and power (§3.3, §4.10.1).

use super::{MeshConfig, ParamRanges, TccParams, TileConfig};

/// Per-tile workload characteristics produced by the partitioner.
#[derive(Debug, Clone, Default)]
pub struct TileLoad {
    /// FLOPs per token assigned to this tile.
    pub flops: f64,
    /// Weight bytes resident on this tile.
    pub weight_bytes: f64,
    /// Activation working set (≈ 2× the largest live tensor slice, for
    /// double buffering) needing DMEM residency.
    pub act_bytes: f64,
    /// KV-cache slice assigned to this tile (Eq 27); spills to WMEM at a
    /// latency cost when it does not fit DMEM (§3.9).
    pub kv_bytes: f64,
    /// Static instructions assigned (IMEM sizing).
    pub instrs: f64,
    /// Hazard density in [0,1] (RAW/WAR/WAW per instruction).
    pub hazard_density: f64,
}

/// Derive quantized per-tile configurations from the RL-selected averages
/// and the placement's per-tile loads.
pub fn derive_tiles(
    mesh: &MeshConfig,
    avg: &TccParams,
    loads: &[TileLoad],
    ranges: &ParamRanges,
) -> Vec<TileConfig> {
    assert_eq!(loads.len(), mesh.cores());
    let n = loads.len() as f64;
    let mean_flops = (loads.iter().map(|l| l.flops).sum::<f64>() / n).max(1.0);
    let mean_instr = (loads.iter().map(|l| l.instrs).sum::<f64>() / n).max(1.0);

    loads
        .iter()
        .enumerate()
        .map(|(t, l)| {
            // compute-share modulation in [0.5, 2.0]: heavier tiles get
            // wider SIMD and deeper fetch
            let share = (l.flops / mean_flops).clamp(0.25, 4.0).sqrt();
            // hazard-heavy tiles get deeper fetch to hide stalls (§5.1
            // "hazard-aware optimization")
            let fetch_mod = share * (1.0 + l.hazard_density);
            let fetch = ranges.fetch.quantize(avg.fetch as f64 * fetch_mod);
            let vlen = ranges.vlen_bits.quantize(avg.vlen_bits as f64 * share);
            // WMEM: the placed weight footprint padded 5% for alignment,
            // rounded UP to the next bank size so capacity holds the
            // placement (Eq 14); the per-tile cap can still force an
            // overflow the reward penalizes (Eq 40)
            let wmem =
                ranges.wmem_kb.quantize_up(l.weight_bytes * 1.05 / 1024.0);
            // DMEM: activation working set (rounded up), at least the RL
            // average scaled by the compute share. Growth is capped at 4x
            // the RL average — activations beyond that stream from
            // producers at a latency cost (η_util pressure term) instead
            // of inflating SRAM leakage.
            let act_kb = (l.act_bytes / 1024.0).min(4.0 * avg.dmem_kb as f64);
            let dmem = ranges
                .dmem_kb
                .quantize_up((avg.dmem_kb as f64 * share).max(act_kb));
            let imem = ranges
                .imem_kb
                .quantize(avg.imem_kb as f64 * (l.instrs / mean_instr).clamp(0.25, 4.0));
            TileConfig {
                tile: t,
                x: t as u32 % mesh.width,
                y: t as u32 / mesh.width,
                fetch,
                vlen_bits: vlen,
                stanum: avg.stanum, // uniform by design (§3.3)
                dmem_kb: dmem,
                wmem_kb: wmem,
                imem_kb: imem,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ParamRanges;

    fn mk_loads(n: usize) -> Vec<TileLoad> {
        (0..n)
            .map(|i| TileLoad {
                flops: 1e6 * (1.0 + (i % 7) as f64),
                weight_bytes: 4.0e6 * (1.0 + (i % 3) as f64),
                act_bytes: 32.0 * 1024.0,
                kv_bytes: 0.0,
                instrs: 1000.0 * (1.0 + (i % 5) as f64),
                hazard_density: 0.1,
            })
            .collect()
    }

    #[test]
    fn heavier_tiles_get_wider_simd() {
        let mesh = MeshConfig::new(4, 4);
        let avg = TccParams::default_for(1000.0);
        let mut loads = mk_loads(16);
        loads[3].flops = 1e9; // hot tile
        loads[5].flops = 1e3; // cold tile
        let tiles = derive_tiles(&mesh, &avg, &loads, &ParamRanges::paper());
        assert!(tiles[3].vlen_bits > tiles[5].vlen_bits);
        assert!(tiles[3].fetch >= tiles[5].fetch);
    }

    #[test]
    fn wmem_tracks_placed_weights() {
        let mesh = MeshConfig::new(2, 2);
        let avg = TccParams::default_for(1000.0);
        let mut loads = mk_loads(4);
        loads[0].weight_bytes = 64.0 * 1024.0 * 1024.0; // 64 MB
        loads[1].weight_bytes = 1.0 * 1024.0 * 1024.0;
        let tiles = derive_tiles(&mesh, &avg, &loads, &ParamRanges::paper());
        assert!(tiles[0].wmem_kb >= 64 * 1024);
        assert!(tiles[1].wmem_kb < tiles[0].wmem_kb);
        // floor respected
        assert!(tiles.iter().all(|t| t.wmem_kb >= 256));
    }

    #[test]
    fn stanum_uniform_across_tiles() {
        let mesh = MeshConfig::new(3, 3);
        let avg = TccParams::default_for(500.0);
        let tiles = derive_tiles(&mesh, &avg, &mk_loads(9), &ParamRanges::paper());
        assert!(tiles.iter().all(|t| t.stanum == avg.stanum));
    }

    #[test]
    fn all_values_quantized_within_table7() {
        let mesh = MeshConfig::new(5, 4);
        let avg = TccParams::default_for(250.0);
        let tiles = derive_tiles(&mesh, &avg, &mk_loads(20), &ParamRanges::paper());
        for t in &tiles {
            assert!(t.fetch.is_power_of_two() && (1..=16).contains(&t.fetch));
            assert!(t.vlen_bits.is_power_of_two());
            assert!((128..=2048).contains(&t.vlen_bits));
            assert!(t.dmem_kb.is_power_of_two());
            assert!(t.imem_kb.is_power_of_two());
        }
    }

    #[test]
    fn variation_emerges_from_nonuniform_load() {
        // §3.3: FETCH/VLEN vary up to 93.8% across tiles
        let mesh = MeshConfig::new(6, 6);
        let avg = TccParams::default_for(1000.0);
        let mut loads = mk_loads(36);
        for (i, l) in loads.iter_mut().enumerate() {
            l.flops = 1e5 * (1.0 + i as f64).powi(2);
        }
        let tiles = derive_tiles(&mesh, &avg, &loads, &ParamRanges::paper());
        let vmin = tiles.iter().map(|t| t.vlen_bits).min().unwrap();
        let vmax = tiles.iter().map(|t| t.vlen_bits).max().unwrap();
        assert!(vmax >= 4 * vmin, "vlen spread {vmin}..{vmax}");
    }
}
