//! Architecture configuration: the 2D TCC mesh, per-TCC microarchitecture
//! parameters (Table 7), hardware quantization, and the post-RL
//! heterogeneous per-tile derivation of §3.3.

pub mod hetero;
pub mod ranges;



pub use hetero::{derive_tiles, TileLoad};
pub use ranges::{ParamRanges, Quantizer};

/// Mesh / sub-cluster topology (discrete action targets, Table 3 group 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MeshConfig {
    pub width: u32,
    pub height: u32,
    /// Sub-cluster grid overlay (SC topology, Table 2 dims 67–69):
    /// tiles are grouped into sc_x × sc_y clusters with express links
    /// between cluster routers.
    pub sc_x: u32,
    pub sc_y: u32,
}

impl MeshConfig {
    pub fn new(width: u32, height: u32) -> Self {
        MeshConfig { width, height, sc_x: 2, sc_y: 2 }
    }

    pub fn cores(&self) -> usize {
        (self.width * self.height) as usize
    }

    /// Mean hop count h̄ = (M+N)/3 (Eq 19).
    pub fn mean_hops(&self) -> f64 {
        (self.width + self.height) as f64 / 3.0
    }

    /// Manhattan distance between two tile indices.
    pub fn hop_distance(&self, a: usize, b: usize) -> u32 {
        let (ax, ay) = (a as u32 % self.width, a as u32 / self.width);
        let (bx, by) = (b as u32 % self.width, b as u32 / self.width);
        ax.abs_diff(bx) + ay.abs_diff(by)
    }

    /// Mesh centrality of a tile in [0,1]: 1 at the exact center,
    /// 0 at the corners (placement score term, §3.5 step 4).
    pub fn centrality(&self, tile: usize) -> f64 {
        let (x, y) = (tile as u32 % self.width, tile as u32 / self.width);
        let cx = (self.width - 1) as f64 / 2.0;
        let cy = (self.height - 1) as f64 / 2.0;
        let d = (x as f64 - cx).abs() + (y as f64 - cy).abs();
        let dmax = cx + cy;
        if dmax <= 0.0 { 1.0 } else { 1.0 - d / dmax }
    }
}

/// Average (mesh-wide) TCC parameters selected by the RL agent — the
/// "Continuous TCC Params" action group (Table 3 dims 4–18). Values are
/// already quantized to hardware-supported points.
#[derive(Debug, Clone, PartialEq)]
pub struct TccParams {
    pub fetch: u32,
    pub stanum: u32,
    pub vlen_bits: u32,
    pub dmem_kb: u32,
    pub wmem_kb: u32,
    pub imem_kb: u32,
    /// NoC flit width (chip-level uniform, Table 7).
    pub dflit_bits: u32,
    pub xr_wp: u32,
    pub vr_wp: u32,
    pub xdpnum: u32,
    pub vdpnum: u32,
    pub clock_mhz: f64,
    /// Weight/activation precision: 0 = FP16 (paper's evaluated setting),
    /// 1 = INT8 (doubles effective lanes).
    pub precision: Precision,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    Fp16,
    Int8,
}

impl TccParams {
    /// FP16 vector lanes = VLEN/16 (Eq 21's VLEN_i/16 term).
    pub fn lanes(&self) -> f64 {
        let base = self.vlen_bits as f64 / 16.0;
        match self.precision {
            Precision::Fp16 => base,
            Precision::Int8 => base * 2.0,
        }
    }

    /// A throughput-reasonable default (mid-range Table 7).
    pub fn default_for(clock_mhz: f64) -> Self {
        TccParams {
            fetch: 4,
            stanum: 4,
            vlen_bits: 1024,
            dmem_kb: 64,
            wmem_kb: 8192,
            imem_kb: 8,
            dflit_bits: 2048,
            xr_wp: 2,
            vr_wp: 2,
            xdpnum: 2,
            vdpnum: 2,
            clock_mhz,
            precision: Precision::Fp16,
        }
    }
}

/// Fully derived per-tile configuration (§3.3 heterogeneous derivation;
/// the JSON artifacts of §4.10 serialize these).
#[derive(Debug, Clone, PartialEq)]
pub struct TileConfig {
    pub tile: usize,
    pub x: u32,
    pub y: u32,
    pub fetch: u32,
    pub vlen_bits: u32,
    pub stanum: u32,
    pub dmem_kb: u32,
    pub wmem_kb: u32,
    pub imem_kb: u32,
}

impl TileConfig {
    pub fn lanes(&self) -> f64 {
        self.vlen_bits as f64 / 16.0
    }

    pub fn sram_mb(&self) -> f64 {
        (self.dmem_kb + self.imem_kb) as f64 / 1024.0
    }
}

/// Mesh region classification used by Table 15 / Fig 11.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Region {
    Edge,
    Inner,
    Center,
}

pub fn region_of(mesh: &MeshConfig, tile: usize) -> Region {
    let (x, y) = (tile as u32 % mesh.width, tile as u32 / mesh.width);
    let on_edge = x == 0 || y == 0 || x == mesh.width - 1 || y == mesh.height - 1;
    if on_edge {
        return Region::Edge;
    }
    let c = mesh.centrality(tile);
    if c >= 0.7 {
        Region::Center
    } else {
        Region::Inner
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mesh_geometry() {
        let m = MeshConfig::new(41, 42);
        assert_eq!(m.cores(), 1722);
        assert!((m.mean_hops() - 83.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn hop_distance_manhattan() {
        let m = MeshConfig::new(4, 4);
        assert_eq!(m.hop_distance(0, 15), 6); // (0,0) -> (3,3)
        assert_eq!(m.hop_distance(5, 5), 0);
        assert_eq!(m.hop_distance(1, 2), 1);
    }

    #[test]
    fn centrality_center_vs_corner() {
        let m = MeshConfig::new(5, 5);
        assert!((m.centrality(12) - 1.0).abs() < 1e-12); // (2,2)
        assert!(m.centrality(0) < 0.01); // corner
    }

    #[test]
    fn lanes_fp16_vs_int8() {
        let mut p = TccParams::default_for(1000.0);
        p.vlen_bits = 2048;
        assert_eq!(p.lanes(), 128.0);
        p.precision = Precision::Int8;
        assert_eq!(p.lanes(), 256.0);
    }

    #[test]
    fn regions_partition_the_mesh() {
        let m = MeshConfig::new(10, 10);
        let mut counts = [0usize; 3];
        for t in 0..m.cores() {
            match region_of(&m, t) {
                Region::Edge => counts[0] += 1,
                Region::Inner => counts[1] += 1,
                Region::Center => counts[2] += 1,
            }
        }
        assert_eq!(counts.iter().sum::<usize>(), 100);
        assert_eq!(counts[0], 36); // perimeter of 10x10
        assert!(counts[2] > 0);
    }
}
