//! Table 7 — per-TCC parameter ranges and hardware quantization.
//!
//! "Bounds are architectural limits; the RL agent selects continuous
//! values within these bounds, which are then quantized to
//! hardware-supported discrete values."



use crate::util::clip;

/// Closed range with a quantization policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Quantizer {
    pub min: f64,
    pub max: f64,
    pub policy: QuantPolicy,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantPolicy {
    /// Round to nearest integer.
    Integer,
    /// Round to the nearest power of two (memory banks, VLEN, flits).
    PowerOfTwo,
}

impl Quantizer {
    pub const fn new(min: f64, max: f64, policy: QuantPolicy) -> Self {
        Quantizer { min, max, policy }
    }

    /// Map a normalized action value in [-1, 1] onto the range
    /// (log-uniform for power-of-two parameters) and quantize.
    pub fn from_unit(&self, u: f64) -> u32 {
        let u = clip(u, -1.0, 1.0) * 0.5 + 0.5; // -> [0,1]
        let v = match self.policy {
            QuantPolicy::Integer => self.min + u * (self.max - self.min),
            QuantPolicy::PowerOfTwo => {
                (self.min.ln() + u * (self.max.ln() - self.min.ln())).exp()
            }
        };
        self.quantize(v)
    }

    /// Quantize an absolute value UP to the next hardware point (for
    /// capacity sizing: memory must hold what placement assigned).
    pub fn quantize_up(&self, v: f64) -> u32 {
        let v = clip(v, self.min, self.max);
        match self.policy {
            QuantPolicy::Integer => v.ceil() as u32,
            QuantPolicy::PowerOfTwo => {
                let p = (v.ln() / 2f64.ln()).ceil();
                clip(2f64.powf(p), self.min, self.max) as u32
            }
        }
    }

    /// Quantize an absolute value to the nearest hardware point.
    pub fn quantize(&self, v: f64) -> u32 {
        let v = clip(v, self.min, self.max);
        match self.policy {
            QuantPolicy::Integer => v.round() as u32,
            QuantPolicy::PowerOfTwo => {
                let l = v.ln() / 2f64.ln();
                let p = l.round();
                let q = 2f64.powf(p);
                clip(q, self.min, self.max) as u32
            }
        }
    }
}

/// The full Table 7 range set.
#[derive(Debug, Clone, Copy)]
pub struct ParamRanges {
    pub fetch: Quantizer,
    pub stanum: Quantizer,
    pub vlen_bits: Quantizer,
    pub dmem_kb: Quantizer,
    /// WMEM is "256 – adaptive"; the max here is a generous per-tile cap
    /// (Table 16 observes up to ~72 MB on weight-heavy tiles).
    pub wmem_kb: Quantizer,
    pub imem_kb: Quantizer,
    pub dflit_bits: Quantizer,
    pub xr_wp: Quantizer,
    pub vr_wp: Quantizer,
    pub xdpnum: Quantizer,
    pub vdpnum: Quantizer,
}

impl ParamRanges {
    pub fn paper() -> Self {
        use QuantPolicy::*;
        ParamRanges {
            fetch: Quantizer::new(1.0, 16.0, PowerOfTwo),
            stanum: Quantizer::new(1.0, 32.0, Integer),
            vlen_bits: Quantizer::new(128.0, 2048.0, PowerOfTwo),
            // Table 7 says 16–512 KB but Table 16 reports 1024 KB tiles;
            // we honour the observed artifact range.
            dmem_kb: Quantizer::new(16.0, 1024.0, PowerOfTwo),
            wmem_kb: Quantizer::new(256.0, 131_072.0, PowerOfTwo),
            imem_kb: Quantizer::new(1.0, 128.0, PowerOfTwo),
            dflit_bits: Quantizer::new(64.0, 8192.0, PowerOfTwo),
            xr_wp: Quantizer::new(1.0, 16.0, Integer),
            vr_wp: Quantizer::new(1.0, 16.0, Integer),
            xdpnum: Quantizer::new(1.0, 16.0, Integer),
            vdpnum: Quantizer::new(1.0, 16.0, Integer),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pow2_quantization_hits_hardware_points() {
        let q = Quantizer::new(128.0, 2048.0, QuantPolicy::PowerOfTwo);
        assert_eq!(q.quantize(1000.0), 1024);
        assert_eq!(q.quantize(1536.0), 2048); // ln-space midpoint rounds up
        assert_eq!(q.quantize(120.0), 128);
        assert_eq!(q.quantize(9999.0), 2048);
    }

    #[test]
    fn integer_quantization_clamps() {
        let q = Quantizer::new(1.0, 32.0, QuantPolicy::Integer);
        assert_eq!(q.quantize(0.2), 1);
        assert_eq!(q.quantize(31.7), 32);
        assert_eq!(q.quantize(100.0), 32);
        assert_eq!(q.quantize(7.4), 7);
    }

    #[test]
    fn from_unit_covers_range_ends() {
        let q = Quantizer::new(1.0, 16.0, QuantPolicy::PowerOfTwo);
        assert_eq!(q.from_unit(-1.0), 1);
        assert_eq!(q.from_unit(1.0), 16);
        // midpoint of log range [1,16] is 4
        assert_eq!(q.from_unit(0.0), 4);
    }

    #[test]
    fn paper_ranges_match_table7() {
        let r = ParamRanges::paper();
        assert_eq!((r.fetch.min, r.fetch.max), (1.0, 16.0));
        assert_eq!((r.stanum.min, r.stanum.max), (1.0, 32.0));
        assert_eq!((r.vlen_bits.min, r.vlen_bits.max), (128.0, 2048.0));
        assert_eq!((r.imem_kb.min, r.imem_kb.max), (1.0, 128.0));
        assert_eq!((r.dflit_bits.min, r.dflit_bits.max), (64.0, 8192.0));
        assert_eq!(r.wmem_kb.min, 256.0);
    }
}
