//! Generated design artifacts (§4.10: "This section uses only generated
//! artifact data ... rendered directly from the same JSON files").
//!
//! For each optimized node we emit:
//! * `tcc_config_<nm>nm.json` — per-TCC heterogeneous configurations
//!   (the paper's per-tile JSON artifacts feeding Fig 10/11/12a),
//! * `run_<nm>nm.json` — the selected configuration + PPA summary
//!   (stand-in for RTL emission: the paper's own §4.10 analysis consumes
//!   exactly these JSON artifacts, not the RTL).

use std::path::Path;

use crate::arch::{region_of, MeshConfig, TileConfig};
use crate::error::Result;
use crate::eval::EvalOutcome;
use crate::util::json::{arr, num, obj, s, Json};

/// Serialize per-TCC configurations.
pub fn tiles_to_json(mesh: &MeshConfig, tiles: &[TileConfig]) -> Json {
    let tiles_json: Vec<Json> = tiles
        .iter()
        .map(|t| {
            obj(vec![
                ("tile", num(t.tile as f64)),
                ("x", num(t.x as f64)),
                ("y", num(t.y as f64)),
                ("region", s(&format!("{:?}", region_of(mesh, t.tile)))),
                ("fetch", num(t.fetch as f64)),
                ("vlen_bits", num(t.vlen_bits as f64)),
                ("stanum", num(t.stanum as f64)),
                ("dmem_kb", num(t.dmem_kb as f64)),
                ("wmem_kb", num(t.wmem_kb as f64)),
                ("imem_kb", num(t.imem_kb as f64)),
            ])
        })
        .collect();
    obj(vec![
        ("mesh_width", num(mesh.width as f64)),
        ("mesh_height", num(mesh.height as f64)),
        ("sc_x", num(mesh.sc_x as f64)),
        ("sc_y", num(mesh.sc_y as f64)),
        ("tiles", arr(tiles_json)),
    ])
}

/// Serialize the selected configuration + PPA summary for one node.
pub fn outcome_to_json(nm: u32, out: &EvalOutcome) -> Json {
    let p = &out.ppa.power;
    obj(vec![
        ("node_nm", num(nm as f64)),
        ("mesh", s(&format!("{}x{}", out.decoded.mesh.width, out.decoded.mesh.height))),
        ("cores", num(out.decoded.mesh.cores() as f64)),
        ("clock_mhz", num(out.decoded.avg.clock_mhz)),
        ("tokens_per_s", num(out.ppa.tokens_per_s)),
        ("perf_gops", num(out.ppa.perf_gops)),
        ("area_mm2", num(out.ppa.area.total())),
        ("ppa_score", num(out.reward.score)),
        ("feasible", Json::Bool(out.reward.feasible)),
        (
            "power_mw",
            obj(vec![
                ("compute", num(p.compute)),
                ("sram", num(p.sram)),
                ("rom_read", num(p.rom_read)),
                ("noc", num(p.noc)),
                ("leakage", num(p.leakage)),
                ("total", num(p.total())),
            ]),
        ),
        (
            "ceilings_tok_s",
            obj(vec![
                ("compute", num(out.ppa.ceilings.compute)),
                ("memory", num(out.ppa.ceilings.memory)),
                ("noc", num(finite_or(out.ppa.ceilings.noc, -1.0))),
            ]),
        ),
    ])
}

fn finite_or(v: f64, fallback: f64) -> f64 {
    if v.is_finite() {
        v
    } else {
        fallback
    }
}

/// Write both artifacts for one optimized node into `dir`.
///
/// Both writes are atomic (temp + fsync + rename, DESIGN.md §13): a
/// crash mid-emit leaves either the previous artifact or the new one on
/// disk, never a torn JSON file.
pub fn write_node_artifacts(dir: &Path, nm: u32, out: &EvalOutcome) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let tiles = tiles_to_json(&out.decoded.mesh, &out.tiles);
    crate::util::fsio::atomic_write_str(
        dir.join(format!("tcc_config_{nm}nm.json")),
        &tiles.to_string_pretty(),
    )?;
    crate::util::fsio::atomic_write_str(
        dir.join(format!("run_{nm}nm.json")),
        &outcome_to_json(nm, out).to_string_pretty(),
    )?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Granularity, RunConfig};
    use crate::env::{Action, Env};

    fn outcome() -> EvalOutcome {
        let mut cfg = RunConfig::default();
        cfg.granularity = Granularity::Group;
        let mut env = Env::new(&cfg, 3);
        env.eval_action(&Action::neutral())
    }

    #[test]
    fn tile_json_round_trips() {
        let out = outcome();
        let j = tiles_to_json(&out.decoded.mesh, &out.tiles);
        let text = j.to_string_pretty();
        let parsed = Json::parse(&text).unwrap();
        let tiles = parsed.get("tiles").unwrap().as_arr().unwrap();
        assert_eq!(tiles.len(), out.decoded.mesh.cores());
        assert!(tiles[0].get("wmem_kb").unwrap().as_f64().unwrap() > 0.0);
        assert!(tiles[0].get("region").unwrap().as_str().is_some());
    }

    #[test]
    fn run_json_has_ppa_fields() {
        let out = outcome();
        let j = outcome_to_json(3, &out);
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed.get("node_nm").unwrap().as_f64(), Some(3.0));
        assert!(parsed.get("power_mw").unwrap().get("total").unwrap().as_f64().unwrap() > 0.0);
        assert!(parsed.get("ceilings_tok_s").unwrap().get("compute").is_some());
    }

    #[test]
    fn artifacts_written_to_disk() {
        let out = outcome();
        let dir = std::env::temp_dir().join("silicon_rl_artifact_test");
        write_node_artifacts(&dir, 3, &out).unwrap();
        assert!(dir.join("tcc_config_3nm.json").exists());
        assert!(dir.join("run_3nm.json").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
