//! Data-hazard modeling (state dims 37–44, reward term Eq 41).
//!
//! The paper feeds global and per-TCC RAW/WAR/WAW statistics into the
//! state vector so the policy is biased "away from stall-heavy
//! configurations" (§5.1). We estimate hazard densities from each op's
//! instruction mix and the microarchitecture's capacity to hide them:
//! reservation stations (STANUM) resolve RAW chains, register write
//! ports relieve WAR/WAW pressure, and deeper FETCH exposes more
//! in-flight instructions (slightly raising all three).

use crate::ir::{Op, OpKind};

/// Raw per-kind hazard propensities (hazards per instruction before
/// microarchitectural mitigation). Long dependent chains (norm, softmax,
/// rope) are RAW-heavy; matmuls with many independent MACs are not.
fn base_rates(kind: OpKind) -> (f64, f64, f64) {
    match kind {
        OpKind::MatMul | OpKind::Conv => (0.08, 0.03, 0.02),
        OpKind::Norm | OpKind::Softmax | OpKind::Reduce => (0.35, 0.08, 0.05),
        OpKind::Rope | OpKind::Elementwise => (0.25, 0.06, 0.04),
        OpKind::KvUpdate | OpKind::Embed => (0.12, 0.10, 0.08),
        OpKind::Reshape | OpKind::Other => (0.05, 0.02, 0.02),
    }
}

/// RAW/WAR/WAW statistics for one instruction stream.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct HazardStats {
    pub raw: f64,
    pub war: f64,
    pub waw: f64,
    /// Instructions the stats were accumulated over.
    pub instrs: f64,
}

impl HazardStats {
    pub fn accumulate(&mut self, other: &HazardStats) {
        self.raw += other.raw;
        self.war += other.war;
        self.waw += other.waw;
        self.instrs += other.instrs;
    }

    /// Hazards per instruction in [0,1] — the density used by the
    /// heterogeneous FETCH derivation and the state encoder.
    pub fn density(&self) -> f64 {
        if self.instrs <= 0.0 {
            return 0.0;
        }
        ((self.raw + self.war + self.waw) / self.instrs).min(1.0)
    }

    /// TotalHazardScore of Eq 41, normalized to [0,1].
    pub fn score(&self) -> f64 {
        self.density()
    }
}

/// Microarchitecture parameters that mitigate hazards.
#[derive(Debug, Clone, Copy)]
pub struct Mitigation {
    pub stanum: u32,
    pub fetch: u32,
    pub xr_wp: u32,
    pub vr_wp: u32,
}

/// Estimate hazards for `op` on a TCC with the given mitigation.
pub fn estimate_op(op: &Op, m: &Mitigation) -> HazardStats {
    let (raw0, war0, waw0) = base_rates(op.kind);
    // reservation stations hide RAW latency: 1 station leaves it all,
    // 32 stations hide ~90%
    let raw_hide = 1.0 / (1.0 + (m.stanum as f64 - 1.0) * 0.28);
    // write ports relieve WAR/WAW (renaming pressure)
    let ports = (m.xr_wp + m.vr_wp) as f64;
    let wx_hide = 1.0 / (1.0 + (ports - 2.0).max(0.0) * 0.20);
    // wider fetch exposes more in-flight hazards
    let fetch_amp = 1.0 + (m.fetch as f64).log2() * 0.06;
    HazardStats {
        raw: op.instrs * raw0 * raw_hide * fetch_amp,
        war: op.instrs * war0 * wx_hide * fetch_amp,
        waw: op.instrs * waw0 * wx_hide * fetch_amp,
        instrs: op.instrs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Op;

    fn op(kind: OpKind, instrs: f64) -> Op {
        Op {
            id: 0,
            kind,
            layer: 0,
            flops: 0.0,
            weight_bytes: 0.0,
            out_bytes: 0.0,
            inputs: vec![],
            instrs,
        }
    }

    fn mit(stanum: u32, fetch: u32, ports: u32) -> Mitigation {
        Mitigation { stanum, fetch, xr_wp: ports, vr_wp: ports }
    }

    #[test]
    fn more_stations_fewer_raw_hazards() {
        let o = op(OpKind::Norm, 1000.0);
        let few = estimate_op(&o, &mit(1, 4, 2));
        let many = estimate_op(&o, &mit(32, 4, 2));
        assert!(many.raw < few.raw * 0.25, "{} vs {}", many.raw, few.raw);
    }

    #[test]
    fn more_ports_fewer_war_waw() {
        let o = op(OpKind::KvUpdate, 1000.0);
        let few = estimate_op(&o, &mit(4, 4, 1));
        let many = estimate_op(&o, &mit(4, 4, 8));
        assert!(many.war < few.war);
        assert!(many.waw < few.waw);
    }

    #[test]
    fn wider_fetch_amplifies() {
        let o = op(OpKind::Elementwise, 1000.0);
        let narrow = estimate_op(&o, &mit(4, 1, 2));
        let wide = estimate_op(&o, &mit(4, 16, 2));
        assert!(wide.raw > narrow.raw);
    }

    #[test]
    fn chain_ops_hazard_heavier_than_matmul() {
        let m = mit(4, 4, 2);
        let mm = estimate_op(&op(OpKind::MatMul, 1000.0), &m);
        let norm = estimate_op(&op(OpKind::Norm, 1000.0), &m);
        assert!(norm.density() > mm.density());
    }

    #[test]
    fn density_bounded_unit() {
        let m = mit(1, 16, 1);
        let s = estimate_op(&op(OpKind::Softmax, 10.0), &m);
        assert!(s.density() <= 1.0 && s.density() >= 0.0);
    }

    #[test]
    fn accumulate_sums() {
        let m = mit(4, 4, 2);
        let mut acc = HazardStats::default();
        acc.accumulate(&estimate_op(&op(OpKind::Norm, 500.0), &m));
        acc.accumulate(&estimate_op(&op(OpKind::MatMul, 500.0), &m));
        assert_eq!(acc.instrs, 1000.0);
        assert!(acc.raw > 0.0);
    }
}
