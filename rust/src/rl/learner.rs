//! Async actor-learner engine (DESIGN.md §11): decouple SAC / world-model
//! / surrogate updates from the vec-env rollout lanes.
//!
//! The rollout side ([`crate::rl::vecenv`]) pushes each lockstep step's
//! transitions into a bounded MPSC [`TransitionQueue`] feeding a
//! dedicated learner thread. The learner owns the PER replay buffer and
//! its own native [`Backend`] instance (built from the rollout agent's
//! manifest, so parameters stay layout-compatible), runs the update
//! schedule continuously, and publishes **versioned parameter snapshots**
//! — `Arc<Store>` views behind a [`SnapshotSlot`] with a monotone version
//! counter — which the lanes pick up at episode (lockstep-step)
//! boundaries.
//!
//! ## Determinism contract
//!
//! * `learner=pinned` replays the exact inline schedule: the rollout
//!   blocks at the top of step `t+1` until the learner has processed
//!   every step sent so far (one [`update_tick`] per step, drawing from
//!   the same `fork(0x0ECE)` update stream the inline driver owns), then
//!   swaps in the latest snapshot. Store state at every action selection
//!   is therefore bit-identical to the inline run — episode logs, replay
//!   contents and Pareto frontiers match to the bit (`tests/learner.rs`).
//! * `learner=async` free-runs: lanes never wait for updates (only for
//!   queue backpressure) and act on whatever snapshot was last published;
//!   the learner drains the queue and spends update credits accumulated
//!   at `updates_per_step` per rollout step (`0` = uncapped free-run).
//!   Throughput mode — seed-reproducibility is *not* guaranteed because
//!   snapshot pickup depends on thread timing.
//!
//! The queue is bounded in **transitions** and never drops or reorders:
//! a single producer (the lockstep rollout) pushes lane-major batches,
//! FIFO pops feed the buffer in the exact inline insertion order.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::bail;
use crate::config::{RlConfig, RunConfig};
use crate::error::{Context, Result};
use crate::nn::backend::Backend;
use crate::nn::native::NativeBackend;
use crate::nn::Store;
use crate::rl::agent::SacAgent;
use crate::rl::checkpoint::LearnerState;
use crate::rl::loop_::update_tick;
use crate::rl::per::{PerBuffer, Transition};
use crate::util::rng::RngState;
use crate::util::Rng;

/// Tag of the dedicated update RNG stream (`Rng::new(seed).fork(TAG)`),
/// shared with the inline driver in [`crate::rl::vecenv::run_jobs_stats`]
/// so pinned mode replays the identical noise sequence.
pub(crate) const UPDATE_STREAM_TAG: u64 = 0x0ECE;

/// Tag of the update stream a degraded run falls back onto after a
/// learner-thread failure: the original stream position died with the
/// thread, so the inline tail forks a fresh, deterministic stream that
/// overlaps neither the rollout nor the learner streams.
pub(crate) const DEGRADE_STREAM_TAG: u64 = 0x0DE6;

/// Where updates run (`learner=` config key).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LearnerMode {
    /// Updates run inline on the rollout thread between lockstep steps
    /// (the legacy engine; the determinism reference).
    #[default]
    Inline,
    /// Dedicated learner thread replaying the exact inline schedule —
    /// bit-identical to `inline`, pinned by `tests/learner.rs`.
    Pinned,
    /// Dedicated learner thread free-running for throughput; lanes adopt
    /// snapshots at step boundaries without waiting.
    Async,
}

impl LearnerMode {
    pub fn parse(value: &str) -> std::result::Result<LearnerMode, String> {
        match value {
            "inline" => Ok(LearnerMode::Inline),
            "pinned" => Ok(LearnerMode::Pinned),
            "async" => Ok(LearnerMode::Async),
            _ => Err(format!("bad learner {value} (inline|pinned|async)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            LearnerMode::Inline => "inline",
            LearnerMode::Pinned => "pinned",
            LearnerMode::Async => "async",
        }
    }

    /// Updates run on a dedicated thread (a thread must be reserved in
    /// the rollout worker budget).
    pub fn off_loop(&self) -> bool {
        !matches!(self, LearnerMode::Inline)
    }
}

/// One lockstep step's transitions, lane-major — the queue's unit of
/// transfer. `t` is the wave-local step index, which drives the wm/sur
/// training cadences exactly like the inline driver's loop counter.
struct StepMsg {
    t: usize,
    rows: Vec<Transition>,
}

/// Unit of queue transfer: a step batch, or a checkpoint quiesce marker.
enum QueueMsg {
    /// One lockstep step's transitions.
    Step(StepMsg),
    /// Checkpoint quiesce request. The queue is FIFO, so when the
    /// learner pops this marker every step sent before it has been
    /// absorbed — it captures its complete state into the [`StateSlot`].
    /// Not acked and not counted as a step.
    StateReq,
}

impl QueueMsg {
    fn rows_len(&self) -> usize {
        match self {
            QueueMsg::Step(m) => m.rows.len(),
            QueueMsg::StateReq => 0,
        }
    }
}

/// Result of a queue pop.
enum Popped {
    Msg(QueueMsg),
    /// Nothing queued right now (only `try_pop` returns this).
    Empty,
    /// Closed *and* fully drained — the learner's termination signal.
    Closed,
}

struct QueueState {
    q: VecDeque<QueueMsg>,
    /// Queued transitions (the bound is in transitions, not messages).
    len: usize,
    highwater: usize,
    closed: bool,
}

/// Bounded single-producer queue of step batches: FIFO, never drops,
/// blocks the producer when full (backpressure) and the consumer when
/// empty. `Mutex<VecDeque>` + two condvars — the std-only substitute for
/// a crossbeam channel; one lock round-trip per *step* (not per
/// transition), which is noise next to a lockstep step's env work.
struct TransitionQueue {
    cap: usize,
    state: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
}

impl TransitionQueue {
    fn new(cap: usize) -> TransitionQueue {
        TransitionQueue {
            cap: cap.max(1),
            state: Mutex::new(QueueState {
                q: VecDeque::new(),
                len: 0,
                highwater: 0,
                closed: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        }
    }

    /// Blocking bounded push. A batch wider than the whole capacity is
    /// admitted once the queue is empty, so an oversized lane count can
    /// stall but never deadlock. Pushing after `close` is a no-op (the
    /// run is being torn down).
    fn push(&self, msg: QueueMsg) {
        let mut st = self.state.lock().unwrap();
        while !st.closed && st.len > 0 && st.len + msg.rows_len() > self.cap {
            st = self.not_full.wait(st).unwrap();
        }
        if st.closed {
            return;
        }
        st.len += msg.rows_len();
        st.highwater = st.highwater.max(st.len);
        st.q.push_back(msg);
        self.not_empty.notify_one();
    }

    fn pop_locked(&self, st: &mut QueueState) -> Option<QueueMsg> {
        let msg = st.q.pop_front()?;
        st.len -= msg.rows_len();
        self.not_full.notify_one();
        Some(msg)
    }

    /// Non-blocking pop; `Closed` only after the queue is fully drained.
    fn try_pop(&self) -> Popped {
        let mut st = self.state.lock().unwrap();
        match self.pop_locked(&mut st) {
            Some(m) => Popped::Msg(m),
            None if st.closed => Popped::Closed,
            None => Popped::Empty,
        }
    }

    /// Blocking pop: waits for a message or for close-and-drained.
    fn pop(&self) -> Popped {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(m) = self.pop_locked(&mut st) {
                return Popped::Msg(m);
            }
            if st.closed {
                return Popped::Closed;
            }
            st = self.not_empty.wait(st).unwrap();
        }
    }

    fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    fn highwater(&self) -> usize {
        self.state.lock().unwrap().highwater
    }
}

/// One published parameter view: the `Arc<Store>` plus the agent flags
/// the rollout side needs to mirror (the MPC planner gates on
/// `wm_trained` / `sur_trained`).
#[derive(Clone)]
pub struct Snapshot {
    pub store: Arc<Store>,
    pub version: u64,
    pub wm_trained: bool,
    pub sur_trained: bool,
}

/// Single-writer snapshot slot — the std-only arc-swap: a lock-free
/// `AtomicU64` version fast-path over a mutexed `Arc` clone. The learner
/// publishes with strictly increasing versions (monotonicity pinned by
/// tests); readers pay an atomic load per step and a mutex + Arc bump
/// only when something new was actually published.
pub struct SnapshotSlot {
    version: AtomicU64,
    latest: Mutex<Snapshot>,
}

impl SnapshotSlot {
    fn new(initial: Snapshot) -> SnapshotSlot {
        let v = initial.version;
        SnapshotSlot { version: AtomicU64::new(v), latest: Mutex::new(initial) }
    }

    /// Latest published version (0 = nothing newer than the initial
    /// parameters).
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    fn publish(&self, snap: Snapshot) {
        debug_assert!(snap.version > self.version(), "snapshot versions are monotone");
        let v = snap.version;
        *self.latest.lock().unwrap() = snap;
        self.version.store(v, Ordering::Release);
    }

    /// The latest snapshot if anything newer than `have` was published.
    pub fn read_newer(&self, have: u64) -> Option<Snapshot> {
        if self.version() <= have {
            return None;
        }
        let snap = self.latest.lock().unwrap().clone();
        if snap.version > have {
            Some(snap)
        } else {
            None
        }
    }
}

/// Rollout ↔ learner coordination: the processed-steps ack counter that
/// pinned mode's lockstep waits on, and the failure flag that releases
/// those waits when the learner thread errors.
struct Control {
    acked: Mutex<u64>,
    acked_cv: Condvar,
    failed: AtomicBool,
}

impl Control {
    fn new() -> Control {
        Control { acked: Mutex::new(0), acked_cv: Condvar::new(), failed: AtomicBool::new(false) }
    }

    fn ack(&self) {
        let mut a = self.acked.lock().unwrap();
        *a += 1;
        self.acked_cv.notify_all();
    }

    fn fail(&self) {
        self.failed.store(true, Ordering::Release);
        self.acked_cv.notify_all();
    }

    fn failed(&self) -> bool {
        self.failed.load(Ordering::Acquire)
    }

    /// Block until `target` steps are processed; `false` on learner
    /// failure.
    fn wait_acked(&self, target: u64) -> bool {
        let mut a = self.acked.lock().unwrap();
        while *a < target && !self.failed() {
            a = self.acked_cv.wait(a).unwrap();
        }
        !self.failed()
    }
}

/// Rendezvous for checkpoint state capture: the learner publishes its
/// quiesced [`LearnerState`] here in response to a
/// [`QueueMsg::StateReq`]; the rollout side waits with a timeout loop so
/// a learner death mid-capture degrades instead of deadlocking.
struct StateSlot {
    m: Mutex<Option<Box<LearnerState>>>,
    cv: Condvar,
}

impl StateSlot {
    fn new() -> StateSlot {
        StateSlot { m: Mutex::new(None), cv: Condvar::new() }
    }

    fn publish(&self, st: Box<LearnerState>) {
        *self.m.lock().unwrap() = Some(st);
        self.cv.notify_all();
    }

    fn take_wait(&self, ctrl: &Control) -> Option<Box<LearnerState>> {
        let mut g = self.m.lock().unwrap();
        loop {
            if let Some(st) = g.take() {
                return Some(st);
            }
            if ctrl.failed() {
                return None;
            }
            let (ng, _) =
                self.cv.wait_timeout(g, std::time::Duration::from_millis(50)).unwrap();
            g = ng;
        }
    }
}

/// Snapshot the learner's complete state for a checkpoint: parameters,
/// replay buffer, update-stream position and counters.
fn capture_state(agent: &SacAgent, urng: &Rng, c: &Counters) -> Box<LearnerState> {
    Box::new(LearnerState {
        store: (*agent.store).clone(),
        per: agent.buffer.export_state(),
        rng: urng.state(),
        updates_done: agent.updates_done,
        wm_trained: agent.wm_trained,
        sur_trained: agent.sur_trained,
        steps: c.steps,
        sac: c.sac,
        wm: c.wm,
        sur: c.sur,
        snapshots: c.snapshots,
        version: c.version,
    })
}

/// Learner-side counters folded into the [`LearnerReport`].
#[derive(Debug, Clone, Copy, Default)]
struct Counters {
    steps: u64,
    sac: u64,
    wm: u64,
    sur: u64,
    snapshots: u64,
    version: u64,
}

/// What the learner thread hands back on shutdown: its agent (final
/// store, replay buffer and training flags, folded back into the
/// caller's agent so wave boundaries and follow-up runs continue exactly
/// as if the updates had run inline) plus the counters.
struct LearnerOut {
    agent: SacAgent,
    c: Counters,
}

/// Observability counters for the run banner, Table 14 and
/// `BENCH_learner.json`.
#[derive(Debug, Clone, Default)]
pub struct LearnerReport {
    pub mode: LearnerMode,
    /// Lockstep steps the learner absorbed into the replay buffer.
    pub steps: u64,
    pub sac_updates: u64,
    pub wm_updates: u64,
    pub sur_updates: u64,
    /// Snapshot versions published (== the final version counter).
    pub snapshots: u64,
    /// Queue high-water mark, in transitions.
    pub queue_highwater: usize,
    /// Mean snapshot-version gap between the latest published parameters
    /// and what the lanes were acting on, sampled at every pickup point
    /// (0 = lanes always saw the newest snapshot; pinned mode hovers
    /// near its one-step publish cadence).
    pub mean_lanes_behind: f64,
    /// `Some((sent_steps_at_failure, error))` when the learner thread
    /// died mid-run and the client fell back to inline updates for the
    /// remainder (graceful degradation). Surfaced in the run banner and
    /// Table 14.
    pub degraded: Option<(u64, String)>,
}

impl LearnerReport {
    /// One-line summary for run banners.
    pub fn banner(&self) -> String {
        let mut s = format!(
            "learner: {} — {} sac / {} wm / {} sur updates over {} steps, \
             {} snapshots, queue high-water {} transitions, \
             mean lanes-behind {:.2} versions",
            self.mode.name(),
            self.sac_updates,
            self.wm_updates,
            self.sur_updates,
            self.steps,
            self.snapshots,
            self.queue_highwater,
            self.mean_lanes_behind
        );
        if let Some((at, err)) = &self.degraded {
            s.push_str(&format!(" — DEGRADED to inline after step {at}: {err}"));
        }
        s
    }
}

/// Inline-fallback state after a learner-thread failure: the client
/// absorbs every subsequent step on the rollout thread, drawing update
/// noise from a fresh deterministic stream (the learner's stream
/// position died with the thread).
struct DegradedTail {
    update_rng: Rng,
    error: String,
    /// Steps that had been sent to the learner when it failed.
    at_step: u64,
    /// Steps absorbed inline since the failure.
    steps: u64,
    sac: u64,
    wm: u64,
    sur: u64,
}

/// Rollout-side handle onto the learner thread, owned by
/// [`crate::rl::vecenv::run_jobs_stats`] for the whole job list (the
/// update RNG stream and ack counter span waves, exactly like the inline
/// driver's update RNG).
pub struct LearnerClient {
    mode: LearnerMode,
    rl: RlConfig,
    seed: u64,
    queue: Arc<TransitionQueue>,
    slot: Arc<SnapshotSlot>,
    state: Arc<StateSlot>,
    ctrl: Arc<Control>,
    handle: Option<JoinHandle<Result<LearnerOut>>>,
    /// Steps sent so far — pinned mode's ack target.
    sent: u64,
    /// Snapshot version the rollout agent currently runs on.
    have: u64,
    staleness_sum: f64,
    staleness_n: u64,
    degraded: Option<DegradedTail>,
}

impl LearnerClient {
    /// Spawn the learner thread for a run over waves of `lanes` lanes.
    ///
    /// The replay buffer **moves** out of `agent` into the learner (the
    /// rollout side keeps a capacity-1 placeholder; it no longer pushes
    /// transitions directly), the parameter store is shared via `Arc`
    /// clone, and the learner gets its own [`NativeBackend`] built from
    /// the rollout backend's manifest — same shapes and hyperparameters,
    /// so stores stay interchangeable. Update randomness is
    /// `Rng::new(cfg.seed).fork(0x0ECE)`, the inline driver's stream.
    ///
    /// `resume` transplants a checkpointed [`LearnerState`] into the
    /// learner before it starts: parameters, replay buffer, update-stream
    /// position and counters all continue from the snapshot, so a pinned
    /// resume replays the uninterrupted run's update schedule exactly.
    pub fn spawn(
        cfg: &RunConfig,
        agent: &mut SacAgent,
        lanes: usize,
        resume: Option<Box<LearnerState>>,
    ) -> Result<LearnerClient> {
        let mode = cfg.rl.learner;
        debug_assert!(mode.off_loop(), "LearnerClient::spawn with learner=inline");
        let rl = cfg.rl;
        let seed = cfg.seed;

        // learner backend: native, from the rollout agent's manifest —
        // constructed on the caller thread so setup errors surface here
        let be: Box<dyn Backend> = Box::new(NativeBackend::new(agent.backend.manifest().clone())?);
        let mut larva = Rng::new(seed);
        let mut lagent = SacAgent::new(be, rl, &mut larva)?;
        lagent.store = agent.store.clone();
        lagent.buffer = std::mem::replace(
            &mut agent.buffer,
            PerBuffer::new(1, rl.per_alpha, rl.per_beta0, rl.per_beta_step),
        );
        lagent.updates_done = agent.updates_done;
        lagent.wm_trained = agent.wm_trained;
        lagent.sur_trained = agent.sur_trained;
        let mut init: Option<(RngState, Counters)> = None;
        if let Some(st) = resume {
            let st = *st;
            lagent.store = Arc::new(st.store);
            lagent.buffer =
                PerBuffer::from_state(rl.buffer_capacity, rl.per_alpha, rl.per_beta_step, st.per);
            lagent.updates_done = st.updates_done;
            lagent.wm_trained = st.wm_trained;
            lagent.sur_trained = st.sur_trained;
            init = Some((
                st.rng,
                Counters {
                    steps: st.steps,
                    sac: st.sac,
                    wm: st.wm,
                    sur: st.sur,
                    snapshots: st.snapshots,
                    version: st.version,
                },
            ));
        }

        // queue bound: explicit `queue_cap=` in transitions, auto = 8
        // lockstep steps of backlog
        let cap = if rl.queue_cap == 0 { 8 * lanes.max(1) } else { rl.queue_cap };
        let queue = Arc::new(TransitionQueue::new(cap));
        let slot = Arc::new(SnapshotSlot::new(Snapshot {
            store: lagent.store.clone(),
            version: 0,
            wm_trained: lagent.wm_trained,
            sur_trained: lagent.sur_trained,
        }));
        let state = Arc::new(StateSlot::new());
        let ctrl = Arc::new(Control::new());

        let sh = LearnerShared {
            queue: queue.clone(),
            slot: slot.clone(),
            state: state.clone(),
            ctrl: ctrl.clone(),
        };
        let handle = std::thread::Builder::new()
            .name("learner".into())
            .spawn(move || {
                // A panic in the update math must degrade, not abort the
                // whole search: catch it, flag the control block (so
                // pinned waiters unblock) and surface it as an error.
                let flag = sh.ctrl.clone();
                let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
                    learner_main(lagent, rl, seed, mode, init, sh)
                }));
                res.unwrap_or_else(|_| {
                    flag.fail();
                    Err(crate::error::Error::msg("learner thread panicked"))
                })
            })
            .context("spawning learner thread")?;

        Ok(LearnerClient {
            mode,
            rl,
            seed,
            queue,
            slot,
            state,
            ctrl,
            handle: Some(handle),
            sent: 0,
            have: 0,
            staleness_sum: 0.0,
            staleness_n: 0,
            degraded: None,
        })
    }

    /// Called at the top of every lockstep step, before action selection:
    /// pinned mode first waits until every step sent so far has been
    /// processed (so step `t+1` acts on the store state the inline run
    /// would have), then both modes adopt the newest published snapshot.
    /// A learner-side failure degrades to inline instead of erroring.
    pub fn sync(&mut self, agent: &mut SacAgent) -> Result<()> {
        if self.degraded.is_some() {
            return Ok(());
        }
        let failed = (self.mode == LearnerMode::Pinned && !self.ctrl.wait_acked(self.sent))
            || self.ctrl.failed();
        if failed {
            self.degrade(agent);
            return Ok(());
        }
        let latest = self.slot.version();
        self.staleness_sum += latest.saturating_sub(self.have) as f64;
        self.staleness_n += 1;
        if let Some(snap) = self.slot.read_newer(self.have) {
            self.have = snap.version;
            agent.store = snap.store;
            agent.wm_trained = snap.wm_trained;
            agent.sur_trained = snap.sur_trained;
        }
        Ok(())
    }

    /// Send one lockstep step's lane-major transitions (blocking on queue
    /// backpressure). After a learner failure the step is absorbed inline
    /// on the rollout thread instead: push into the rebuilt replay buffer
    /// and run the shared [`update_tick`] schedule.
    pub fn send_step(&mut self, agent: &mut SacAgent, t: usize, rows: Vec<Transition>) -> Result<()> {
        if self.degraded.is_none() && self.ctrl.failed() {
            self.degrade(agent);
        }
        if self.degraded.is_some() {
            let rl = self.rl;
            let tail = self.degraded.as_mut().expect("just checked");
            agent.buffer.push_batch(rows);
            let tick = update_tick(agent, rl, t, &mut tail.update_rng)?;
            tail.steps += 1;
            if tick.ran {
                tail.sac += 1;
                tail.wm += u64::from(tick.wm);
                tail.sur += u64::from(tick.sur);
            }
            return Ok(());
        }
        self.queue.push(QueueMsg::Step(StepMsg { t, rows }));
        self.sent += 1;
        Ok(())
    }

    /// True once the client has fallen back to inline updates.
    pub fn is_degraded(&self) -> bool {
        self.degraded.is_some()
    }

    /// Quiesce the learner and capture its complete state for a
    /// checkpoint: enqueue a [`QueueMsg::StateReq`] (FIFO ⇒ the captured
    /// state reflects every step sent so far) and wait for the slot.
    /// `None` when the learner has failed or the client is degraded —
    /// the caller skips that checkpoint.
    pub(crate) fn request_state(&mut self) -> Option<Box<LearnerState>> {
        if self.degraded.is_some() || self.ctrl.failed() {
            return None;
        }
        self.queue.push(QueueMsg::StateReq);
        self.state.take_wait(&self.ctrl)
    }

    /// Drain the learner and fold its final state back into `agent`
    /// (store, replay buffer, update counters, training flags), so
    /// whatever runs next on this agent continues exactly as if the
    /// updates had been inline. Returns the run's [`LearnerReport`].
    pub fn finish(mut self, agent: &mut SacAgent) -> Result<LearnerReport> {
        let behind = if self.staleness_n > 0 {
            self.staleness_sum / self.staleness_n as f64
        } else {
            0.0
        };
        if let Some(tail) = self.degraded.take() {
            return Ok(LearnerReport {
                mode: self.mode,
                steps: tail.at_step + tail.steps,
                sac_updates: tail.sac,
                wm_updates: tail.wm,
                sur_updates: tail.sur,
                snapshots: 0,
                queue_highwater: self.queue.highwater(),
                mean_lanes_behind: behind,
                degraded: Some((tail.at_step, tail.error)),
            });
        }
        self.queue.close();
        let handle = self.handle.take().expect("finish consumes the handle");
        let out = match handle.join() {
            Ok(r) => r?,
            Err(_) => bail!("learner thread panicked"),
        };
        let LearnerOut { agent: lagent, c } = out;
        agent.store = lagent.store;
        agent.buffer = lagent.buffer;
        agent.updates_done = lagent.updates_done;
        agent.wm_trained = lagent.wm_trained;
        agent.sur_trained = lagent.sur_trained;
        Ok(LearnerReport {
            mode: self.mode,
            steps: c.steps,
            sac_updates: c.sac,
            wm_updates: c.wm,
            sur_updates: c.sur,
            snapshots: c.snapshots,
            queue_highwater: self.queue.highwater(),
            mean_lanes_behind: behind,
            degraded: None,
        })
    }

    /// Graceful degradation after a learner-thread failure: join the
    /// thread to capture its error, rebuild a config-shaped replay
    /// buffer on the rollout agent (the learner-held contents died with
    /// the thread), drain whatever steps were still queued into it (FIFO
    /// — no sent step is silently lost), and switch to inline updates
    /// for the remainder of the run.
    fn degrade(&mut self, agent: &mut SacAgent) {
        self.queue.close();
        let mut err = "learner thread failed".to_string();
        if let Some(h) = self.handle.take() {
            match h.join() {
                Ok(Err(e)) => err = e.to_string(),
                Ok(Ok(_)) => {}
                Err(_) => err = "learner thread panicked".to_string(),
            }
        }
        agent.buffer = PerBuffer::new(
            self.rl.buffer_capacity,
            self.rl.per_alpha,
            self.rl.per_beta0,
            self.rl.per_beta_step,
        );
        loop {
            match self.queue.try_pop() {
                Popped::Msg(QueueMsg::Step(m)) => agent.buffer.push_batch(m.rows),
                Popped::Msg(QueueMsg::StateReq) => {}
                Popped::Empty | Popped::Closed => break,
            }
        }
        let at_step = self.sent;
        eprintln!(
            "warning: learner thread failed after {at_step} sent steps ({err}); \
             falling back to learner=inline for the remainder of the run"
        );
        self.degraded = Some(DegradedTail {
            update_rng: Rng::new(self.seed).fork(DEGRADE_STREAM_TAG),
            error: err,
            at_step,
            steps: 0,
            sac: 0,
            wm: 0,
            sur: 0,
        });
    }
}

impl Drop for LearnerClient {
    /// Error-path teardown (e.g. the rollout side bailed mid-wave): close
    /// the queue so the learner drains and exits, then join it. `finish`
    /// takes the handle first on the normal path, making this a no-op.
    fn drop(&mut self) {
        if let Some(h) = self.handle.take() {
            self.queue.close();
            let _ = h.join();
        }
    }
}

/// The shared-state bundle handed to the learner thread.
struct LearnerShared {
    queue: Arc<TransitionQueue>,
    slot: Arc<SnapshotSlot>,
    state: Arc<StateSlot>,
    ctrl: Arc<Control>,
}

/// Learner thread body: run the mode's loop, flag the control block on
/// error (so pinned waiters unblock), and hand the agent back. `init`
/// resumes the update-stream position and counters from a checkpoint.
fn learner_main(
    mut agent: SacAgent,
    rl: RlConfig,
    seed: u64,
    mode: LearnerMode,
    init: Option<(RngState, Counters)>,
    sh: LearnerShared,
) -> Result<LearnerOut> {
    let (mut urng, mut c) = match init {
        Some((rng_st, counters)) => (Rng::from_state(rng_st), counters),
        None => (Rng::new(seed).fork(UPDATE_STREAM_TAG), Counters::default()),
    };
    let res = match mode {
        LearnerMode::Pinned => pinned_loop(&mut agent, rl, &sh, &mut urng, &mut c),
        LearnerMode::Async => async_loop(&mut agent, rl, &sh, &mut urng, &mut c),
        LearnerMode::Inline => Ok(()), // unreachable by construction
    };
    match res {
        Ok(()) => Ok(LearnerOut { agent, c }),
        Err(e) => {
            sh.ctrl.fail();
            Err(e)
        }
    }
}

/// Publish the agent's current parameters as the next snapshot version.
fn publish(agent: &SacAgent, slot: &SnapshotSlot, c: &mut Counters) {
    c.version += 1;
    c.snapshots += 1;
    slot.publish(Snapshot {
        store: agent.store.clone(),
        version: c.version,
        wm_trained: agent.wm_trained,
        sur_trained: agent.sur_trained,
    });
}

/// Pinned mode: one [`update_tick`] per received step, acked so the
/// rollout's lockstep can wait — the inline schedule, verbatim, on
/// another thread. [`QueueMsg::StateReq`] markers publish a quiesced
/// state capture without counting or acking.
fn pinned_loop(
    agent: &mut SacAgent,
    rl: RlConfig,
    sh: &LearnerShared,
    urng: &mut Rng,
    c: &mut Counters,
) -> Result<()> {
    let mut seen = 0u64;
    loop {
        let msg = match sh.queue.pop() {
            Popped::Msg(QueueMsg::Step(m)) => m,
            Popped::Msg(QueueMsg::StateReq) => {
                sh.state.publish(capture_state(agent, urng, c));
                continue;
            }
            Popped::Closed => return Ok(()),
            Popped::Empty => continue, // pop() blocks; not reachable
        };
        seen += 1;
        if rl.learner_fail_after > 0 && seen >= rl.learner_fail_after {
            bail!("injected learner failure (learner_fail_after={})", rl.learner_fail_after);
        }
        c.steps += 1;
        agent.buffer.push_batch(msg.rows);
        let tick = update_tick(agent, rl, msg.t, urng)?;
        if tick.ran {
            c.sac += 1;
            c.wm += u64::from(tick.wm);
            c.sur += u64::from(tick.sur);
            publish(agent, &sh.slot, c);
        }
        sh.ctrl.ack();
    }
}

/// Async mode: drain whatever is queued, then spend update credits
/// (accumulated at `updates_per_step` per post-warmup step; `0` =
/// uncapped free-run). The wm/sur cadences run on the learner's own
/// update counter. Blocks only when there is neither queued data nor
/// update work.
fn async_loop(
    agent: &mut SacAgent,
    rl: RlConfig,
    sh: &LearnerShared,
    urng: &mut Rng,
    c: &mut Counters,
) -> Result<()> {
    let ups = rl.updates_per_step;
    let uncapped = ups <= 0.0;
    let mut credits = 0.0f64;
    let mut seen = 0u64;
    let gate = |agent: &SacAgent| agent.buffer.len() >= rl.warmup_steps.max(agent.batch());

    let mut absorb = |agent: &mut SacAgent,
                      m: StepMsg,
                      credits: &mut f64,
                      c: &mut Counters,
                      seen: &mut u64|
     -> Result<()> {
        *seen += 1;
        if rl.learner_fail_after > 0 && *seen >= rl.learner_fail_after {
            bail!("injected learner failure (learner_fail_after={})", rl.learner_fail_after);
        }
        c.steps += 1;
        agent.buffer.push_batch(m.rows);
        if gate(agent) {
            *credits += ups;
        }
        Ok(())
    };

    let mut closed = false;
    while !closed {
        // 1) drain everything currently queued without blocking
        loop {
            match sh.queue.try_pop() {
                Popped::Msg(QueueMsg::Step(m)) => absorb(agent, m, &mut credits, c, &mut seen)?,
                Popped::Msg(QueueMsg::StateReq) => {
                    sh.state.publish(capture_state(agent, urng, c));
                }
                Popped::Empty => break,
                Popped::Closed => {
                    closed = true;
                    break;
                }
            }
        }
        if closed {
            break;
        }
        // 2) one update round if allowed, else block for the next step
        if gate(agent) && (uncapped || credits >= 1.0) {
            if !uncapped {
                credits -= 1.0;
            }
            update_round(agent, rl, &sh.slot, urng, c)?;
        } else {
            match sh.queue.pop() {
                Popped::Msg(QueueMsg::Step(m)) => absorb(agent, m, &mut credits, c, &mut seen)?,
                Popped::Msg(QueueMsg::StateReq) => {
                    sh.state.publish(capture_state(agent, urng, c));
                }
                Popped::Closed => closed = true,
                Popped::Empty => {}
            }
        }
    }
    // settle remaining credits after close (capped mode only — an
    // uncapped learner would otherwise never terminate), so a capped
    // async run performs the same update count as the inline schedule
    if !uncapped {
        while credits >= 1.0 && gate(agent) {
            credits -= 1.0;
            update_round(agent, rl, &sh.slot, urng, c)?;
        }
    }
    Ok(())
}

/// One async update round: SAC update plus wm/sur at their cadences on
/// the learner's update counter, then a snapshot publish.
fn update_round(
    agent: &mut SacAgent,
    rl: RlConfig,
    slot: &SnapshotSlot,
    urng: &mut Rng,
    c: &mut Counters,
) -> Result<()> {
    let t = c.sac as usize;
    agent.update(urng)?;
    c.sac += 1;
    if t % rl.wm_train_every == 0 {
        agent.train_world_model(urng)?;
        c.wm += 1;
    }
    if t % rl.sur_train_every == 0 {
        agent.train_surrogate(urng)?;
        c.sur += 1;
    }
    publish(agent, slot, c);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::{ACT_DIM, SAC_STATE_DIM};

    fn row(tag: f32) -> Transition {
        Transition {
            s: [tag; SAC_STATE_DIM],
            a_cont: [0.0; ACT_DIM],
            a_disc: [0.0; 20],
            r: tag,
            s2: [0.0; SAC_STATE_DIM],
            done: 0.0,
            ppa: [0.0; 3],
        }
    }

    #[test]
    fn queue_is_fifo_and_close_drains() {
        let q = TransitionQueue::new(64);
        for i in 0..5 {
            q.push(QueueMsg::Step(StepMsg { t: i, rows: vec![row(i as f32); 2] }));
        }
        q.close();
        let mut seen = Vec::new();
        loop {
            match q.pop() {
                Popped::Msg(QueueMsg::Step(m)) => {
                    assert_eq!(m.rows.len(), 2);
                    assert_eq!(m.rows[0].r, m.t as f32);
                    seen.push(m.t);
                }
                Popped::Msg(QueueMsg::StateReq) => panic!("no state request queued"),
                Popped::Closed => break,
                Popped::Empty => unreachable!("blocking pop never returns Empty"),
            }
        }
        assert_eq!(seen, vec![0, 1, 2, 3, 4], "FIFO order, nothing dropped");
        assert_eq!(q.highwater(), 10);
    }

    #[test]
    fn state_requests_keep_fifo_position_and_cost_no_capacity() {
        let q = TransitionQueue::new(4);
        q.push(QueueMsg::Step(StepMsg { t: 0, rows: vec![row(0.0); 2] }));
        q.push(QueueMsg::StateReq);
        q.push(QueueMsg::Step(StepMsg { t: 1, rows: vec![row(1.0); 2] }));
        q.close();
        // the marker sits between the two steps and adds no transitions
        assert!(matches!(q.pop(), Popped::Msg(QueueMsg::Step(m)) if m.t == 0));
        assert!(matches!(q.pop(), Popped::Msg(QueueMsg::StateReq)));
        assert!(matches!(q.pop(), Popped::Msg(QueueMsg::Step(m)) if m.t == 1));
        assert!(matches!(q.pop(), Popped::Closed));
        assert_eq!(q.highwater(), 4, "StateReq contributes zero transitions");
    }

    #[test]
    fn queue_backpressure_blocks_producer_without_loss() {
        // capacity 6 transitions; 40 steps × 3 transitions forces the
        // producer to block on backpressure repeatedly
        let q = Arc::new(TransitionQueue::new(6));
        let steps = 40usize;
        let prod = {
            let q = q.clone();
            std::thread::spawn(move || {
                for i in 0..steps {
                    q.push(QueueMsg::Step(StepMsg { t: i, rows: vec![row(i as f32); 3] }));
                }
                q.close();
            })
        };
        let mut got = Vec::new();
        loop {
            match q.pop() {
                Popped::Msg(QueueMsg::Step(m)) => {
                    // consumer is slower than the producer
                    std::thread::sleep(std::time::Duration::from_micros(200));
                    got.push(m.t);
                }
                Popped::Msg(QueueMsg::StateReq) => unreachable!(),
                Popped::Closed => break,
                Popped::Empty => unreachable!(),
            }
        }
        prod.join().unwrap();
        assert_eq!(got, (0..steps).collect::<Vec<_>>(), "no drops, no reordering");
        assert!(q.highwater() <= 6, "bound respected: {}", q.highwater());
    }

    #[test]
    fn oversized_batch_is_admitted_when_empty() {
        let q = TransitionQueue::new(2);
        // 5 > cap: must not deadlock the (single-threaded) producer
        q.push(QueueMsg::Step(StepMsg { t: 0, rows: vec![row(0.0); 5] }));
        match q.try_pop() {
            Popped::Msg(QueueMsg::Step(m)) => assert_eq!(m.rows.len(), 5),
            _ => panic!("oversized batch lost"),
        }
    }

    #[test]
    fn push_after_close_is_dropped_quietly() {
        let q = TransitionQueue::new(4);
        q.close();
        q.push(QueueMsg::Step(StepMsg { t: 0, rows: vec![row(1.0)] }));
        assert!(matches!(q.try_pop(), Popped::Closed));
    }

    #[test]
    fn snapshot_slot_versions_are_monotone() {
        let store = Arc::new(Store::default());
        let snap = |v: u64| Snapshot {
            store: store.clone(),
            version: v,
            wm_trained: false,
            sur_trained: false,
        };
        let slot = SnapshotSlot::new(snap(0));
        assert_eq!(slot.version(), 0);
        assert!(slot.read_newer(0).is_none(), "nothing published yet");
        let mut last = 0;
        for v in 1..=9u64 {
            slot.publish(snap(v));
            assert!(slot.version() > last, "version must strictly increase");
            last = slot.version();
            assert_eq!(last, v);
        }
        // stale readers see the newest, current readers see nothing new
        assert_eq!(slot.read_newer(3).unwrap().version, 9);
        assert!(slot.read_newer(9).is_none());
    }

    #[test]
    fn control_acks_release_waiters_and_failure_unblocks() {
        let ctrl = Arc::new(Control::new());
        assert!(ctrl.wait_acked(0), "zero target never blocks");
        let waiter = {
            let ctrl = ctrl.clone();
            std::thread::spawn(move || ctrl.wait_acked(3))
        };
        ctrl.ack();
        ctrl.ack();
        ctrl.ack();
        assert!(waiter.join().unwrap());
        // failure releases even unreachable targets
        let stuck = {
            let ctrl = ctrl.clone();
            std::thread::spawn(move || ctrl.wait_acked(1_000))
        };
        ctrl.fail();
        assert!(!stuck.join().unwrap());
    }

    #[test]
    fn learner_mode_parses_and_names() {
        assert_eq!(LearnerMode::parse("inline").unwrap(), LearnerMode::Inline);
        assert_eq!(LearnerMode::parse("pinned").unwrap(), LearnerMode::Pinned);
        assert_eq!(LearnerMode::parse("async").unwrap(), LearnerMode::Async);
        assert!(LearnerMode::parse("offline").is_err());
        assert_eq!(LearnerMode::default(), LearnerMode::Inline);
        assert!(!LearnerMode::Inline.off_loop());
        assert!(LearnerMode::Pinned.off_loop() && LearnerMode::Async.off_loop());
        assert_eq!(LearnerMode::Async.name(), "async");
    }
}
