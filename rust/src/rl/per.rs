//! Prioritized experience replay (§3.11): 100 K-capacity ring buffer with
//! a sum-tree for O(log n) stochastic prioritized sampling, priority
//! exponent α=0.6, importance-sampling exponent β annealed 0.4 → 1.0,
//! priorities p_i = (|δ_i| + 1e-6)^0.6.

use crate::env::{ACT_DIM, SAC_STATE_DIM};
use crate::util::Rng;

/// One stored transition.
#[derive(Debug, Clone)]
pub struct Transition {
    pub s: [f32; SAC_STATE_DIM],
    pub a_cont: [f32; ACT_DIM],
    pub a_disc: [f32; 20],
    pub r: f32,
    pub s2: [f32; SAC_STATE_DIM],
    pub done: f32,
    /// Normalized (power, perf, area) observation — surrogate targets.
    pub ppa: [f32; 3],
}

/// Flat binary sum-tree over capacity leaves.
struct SumTree {
    n: usize,
    tree: Vec<f64>,
}

impl SumTree {
    fn new(n: usize) -> Self {
        SumTree { n, tree: vec![0.0; 2 * n] }
    }

    fn set(&mut self, i: usize, v: f64) {
        let mut idx = self.n + i;
        self.tree[idx] = v;
        while idx > 1 {
            idx /= 2;
            self.tree[idx] = self.tree[2 * idx] + self.tree[2 * idx + 1];
        }
    }

    fn get(&self, i: usize) -> f64 {
        self.tree[self.n + i]
    }

    fn total(&self) -> f64 {
        self.tree[1]
    }

    /// Find the leaf where the prefix sum crosses `u` ∈ [0, total).
    fn find(&self, mut u: f64) -> usize {
        let mut idx = 1;
        while idx < self.n {
            let left = self.tree[2 * idx];
            if u < left {
                idx *= 2;
            } else {
                u -= left;
                idx = 2 * idx + 1;
            }
        }
        (idx - self.n).min(self.n - 1)
    }
}

/// Serializable snapshot of a [`PerBuffer`]'s full sampling state:
/// contents in storage order, the ring-write cursor, every leaf priority
/// and the annealing position. Restoring through
/// [`PerBuffer::from_state`] rebuilds the sum-tree exactly, so the next
/// stochastic sample draws the same indices as the uninterrupted run.
#[derive(Debug, Clone)]
pub struct PerState {
    pub data: Vec<Transition>,
    pub write: usize,
    pub priorities: Vec<f64>,
    pub max_priority: f64,
    pub beta: f64,
}

pub struct PerBuffer {
    capacity: usize,
    data: Vec<Transition>,
    write: usize,
    tree: SumTree,
    max_priority: f64,
    pub alpha: f64,
    pub beta: f64,
    beta_step: f64,
}

impl PerBuffer {
    pub fn new(capacity: usize, alpha: f64, beta0: f64, beta_step: f64) -> Self {
        PerBuffer {
            capacity,
            data: Vec::with_capacity(capacity.min(4096)),
            write: 0,
            tree: SumTree::new(capacity),
            max_priority: 1.0,
            alpha,
            beta: beta0,
            beta_step,
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Insert with max priority (new experience is always worth a look).
    pub fn push(&mut self, t: Transition) {
        if self.data.len() < self.capacity {
            self.data.push(t);
            let i = self.data.len() - 1;
            self.tree.set(i, self.max_priority);
        } else {
            self.data[self.write] = t;
            self.tree.set(self.write, self.max_priority);
            self.write = (self.write + 1) % self.capacity;
        }
    }

    /// Batched insert, consuming `ts` in iteration order. The vec-env
    /// inserts one step's transitions lane-major through this, so the
    /// buffer contents of a B-lane run interleave the B serial runs'
    /// streams in a fixed, lane-count-independent order.
    pub fn push_batch(&mut self, ts: impl IntoIterator<Item = Transition>) {
        for t in ts {
            self.push(t);
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Current priority of slot `i` (test/diagnostic accessor).
    pub fn priority(&self, i: usize) -> f64 {
        self.tree.get(i)
    }

    /// Root of the sum-tree: Σ of every stored priority. Invariant pinned
    /// by `tests/proptests.rs`: equals the leaf sum after any interleaving
    /// of batched inserts, priority updates and samples.
    pub fn priority_total(&self) -> f64 {
        self.tree.total()
    }

    /// Stochastic prioritized sample of `k` transitions. Returns indices
    /// and normalized importance-sampling weights (max weight = 1).
    /// Anneals β by `beta_step` per sampled transition.
    pub fn sample(&mut self, k: usize, rng: &mut Rng) -> (Vec<usize>, Vec<f32>) {
        assert!(!self.is_empty(), "sampling from empty buffer");
        let total = self.tree.total().max(1e-12);
        let n = self.data.len() as f64;
        let mut idxs = Vec::with_capacity(k);
        let mut weights = Vec::with_capacity(k);
        let mut wmax = 0.0f64;
        for j in 0..k {
            // stratified sampling over the priority mass
            let seg = total / k as f64;
            let u = seg * (j as f64 + rng.uniform());
            let i = self.tree.find(u);
            let p = self.tree.get(i) / total;
            let w = (n * p).powf(-self.beta);
            wmax = wmax.max(w);
            idxs.push(i);
            weights.push(w);
        }
        self.beta = (self.beta + self.beta_step * k as f64).min(1.0);
        let weights = weights.into_iter().map(|w| (w / wmax) as f32).collect();
        (idxs, weights)
    }

    /// Update priorities from TD errors: p = (|δ| + 1e-6)^α.
    pub fn update_priorities(&mut self, idxs: &[usize], td_abs: &[f32]) {
        for (&i, &d) in idxs.iter().zip(td_abs) {
            let p = ((d.abs() as f64) + 1e-6).powf(self.alpha);
            self.max_priority = self.max_priority.max(p);
            self.tree.set(i, p);
        }
    }

    pub fn get(&self, i: usize) -> &Transition {
        &self.data[i]
    }

    /// Capture the full sampling state for checkpointing.
    pub fn export_state(&self) -> PerState {
        PerState {
            data: self.data.clone(),
            write: self.write,
            priorities: (0..self.data.len()).map(|i| self.tree.get(i)).collect(),
            max_priority: self.max_priority,
            beta: self.beta,
        }
    }

    /// Rebuild a buffer from [`Self::export_state`]. `capacity`, `alpha`
    /// and `beta_step` come from the run config (they are not part of the
    /// snapshot); the sum-tree is reconstructed leaf by leaf.
    pub fn from_state(capacity: usize, alpha: f64, beta_step: f64, st: PerState) -> PerBuffer {
        let mut b = PerBuffer::new(capacity, alpha, st.beta, beta_step);
        let n = st.data.len().min(capacity);
        b.data = st.data;
        b.data.truncate(n);
        b.write = st.write.min(capacity.saturating_sub(1));
        for (i, &p) in st.priorities.iter().take(n).enumerate() {
            b.tree.set(i, p);
        }
        b.max_priority = st.max_priority;
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(r: f32) -> Transition {
        Transition {
            s: [0.0; SAC_STATE_DIM],
            a_cont: [0.0; ACT_DIM],
            a_disc: [0.0; 20],
            r,
            s2: [0.0; SAC_STATE_DIM],
            done: 0.0,
            ppa: [0.0; 3],
        }
    }

    #[test]
    fn ring_buffer_wraps() {
        let mut b = PerBuffer::new(4, 0.6, 0.4, 0.001);
        for i in 0..6 {
            b.push(t(i as f32));
        }
        assert_eq!(b.len(), 4);
        // oldest (0,1) overwritten by (4,5)
        let rs: Vec<f32> = (0..4).map(|i| b.get(i).r).collect();
        assert!(rs.contains(&4.0) && rs.contains(&5.0));
        assert!(!rs.contains(&0.0));
    }

    #[test]
    fn prioritized_sampling_prefers_high_td() {
        let mut b = PerBuffer::new(128, 0.6, 0.4, 0.0);
        for i in 0..100 {
            b.push(t(i as f32));
        }
        // give index 7 a huge priority
        let idxs: Vec<usize> = (0..100).collect();
        let mut tds = vec![0.01f32; 100];
        tds[7] = 100.0;
        b.update_priorities(&idxs, &tds);
        let mut rng = Rng::new(1);
        let mut hits = 0;
        for _ in 0..50 {
            let (ix, _) = b.sample(16, &mut rng);
            hits += ix.iter().filter(|&&i| i == 7).count();
        }
        assert!(hits > 200, "high-priority index sampled {hits}/800");
    }

    #[test]
    fn importance_weights_normalized() {
        let mut b = PerBuffer::new(64, 0.6, 0.4, 0.001);
        for i in 0..32 {
            b.push(t(i as f32));
        }
        let mut rng = Rng::new(2);
        let (_, w) = b.sample(16, &mut rng);
        assert!(w.iter().all(|&x| x > 0.0 && x <= 1.0 + 1e-6));
        assert!(w.iter().any(|&x| (x - 1.0).abs() < 1e-6));
    }

    #[test]
    fn beta_anneals_to_one() {
        let mut b = PerBuffer::new(64, 0.6, 0.4, 0.001);
        for _ in 0..8 {
            b.push(t(0.0));
        }
        let mut rng = Rng::new(3);
        for _ in 0..10 {
            b.sample(256, &mut rng);
        }
        assert!((b.beta - 1.0).abs() < 1e-9);
    }

    #[test]
    fn sum_tree_prefix_find() {
        let mut st = SumTree::new(8);
        for i in 0..8 {
            st.set(i, 1.0);
        }
        assert_eq!(st.total(), 8.0);
        assert_eq!(st.find(0.5), 0);
        assert_eq!(st.find(7.5), 7);
        st.set(3, 100.0);
        assert_eq!(st.find(50.0), 3);
    }
}
