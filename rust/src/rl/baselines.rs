//! Search-strategy baselines (§4.14, Table 21): random search and grid
//! search under the same episode budget and the same evaluation pipeline
//! as SAC — only the proposal mechanism differs. (Caveat for strict
//! evaluation-count parity: once SAC's MPC gate opens it performs up to
//! `rl.mpc_rerank` additional real evaluations per exploitation episode
//! that are not counted against the episode budget; set `mpc_rerank=0`
//! for a same-evaluation-count comparison.)
//!
//! Both baselines score proposals in candidate *sets* of
//! `cfg.rl.candidate_batch` through [`Evaluator::evaluate_best_with`]
//! (optionally under roofline admission pruning), fanning each set across
//! worker threads. The mesh then walks to the round's best candidate
//! (feasible first, then score, ties to the earliest proposal). The batch
//! size — not the thread count — shapes the search trajectory, so a run
//! is bit-identical whether it executes on 1 thread or 16 (pinned by
//! `tests/eval_parallel.rs` and `tests/eval_staged.rs`).

use crate::config::RunConfig;
use crate::env::{Action, ACT_DIM};
use crate::eval::{parallel, EvalScratch, Evaluator};
use crate::nn::policy;
use crate::rl::loop_::{EpisodeTracker, NodeResult};
use crate::util::Rng;

/// Shared round-loop skeleton for proposal-driven baselines: propose a
/// candidate set, score it in parallel (per-worker scratches — and their
/// stage memos — persist across rounds), log every evaluated candidate in
/// proposal order, walk the mesh to the round's best.
///
/// With `cfg.rl.prune`, each round runs under roofline admission pruning:
/// candidates whose O(1) bound cannot beat the round incumbent skip the
/// full pipeline. The walk and the best-design tracking are bit-identical
/// to the exact path (the optimum is never prunable — DESIGN.md §5);
/// pruned candidates still consume episode budget but are absent from the
/// per-episode log and the Pareto archive — and from `feasible_count`, so
/// feasibility statistics (`feasible_count / total_episodes`, the seeds
/// table's `feas_frac`) are *lower bounds* under pruning, not comparable
/// to the exact `--no-prune` path (pinned by
/// `tests/eval_staged.rs::pruned_random_search_walks_and_ranks_identically`).
fn run_with_proposals(
    cfg: &RunConfig,
    nm: u32,
    mut propose: impl FnMut(usize, &mut Rng) -> Action,
    rng: &mut Rng,
    threads: usize,
) -> NodeResult {
    let eval = Evaluator::new(cfg, nm);
    let mut mesh = eval.initial_mesh();
    let episodes_budget = cfg.rl.episodes_per_node;
    let set_size = cfg.rl.candidate_batch.max(1);
    let prune = cfg.rl.prune;
    let mut tracker = EpisodeTracker::new(episodes_budget);
    let mut scratches: Vec<EvalScratch> =
        (0..threads.max(1)).map(|_| EvalScratch::default()).collect();
    let mut pruned_total = 0u64;
    let mut evaluated_total = 0u64;

    let mut t = 0usize;
    while t < episodes_budget {
        let k = set_size.min(episodes_budget - t);
        // proposals consume the RNG in episode order, independent of the
        // worker count
        let actions: Vec<Action> = (0..k).map(|j| propose(t + j, rng)).collect();
        let batch = eval.evaluate_best_with(&mesh, &actions, &mut scratches, prune);

        // deterministic reduction: iterate candidates in proposal order
        for (j, out) in batch.outcomes.iter().enumerate() {
            if let Some(out) = out {
                tracker.record(t + j, out, 1.0, 0.0);
            }
        }
        pruned_total += batch.n_pruned as u64;
        evaluated_total += (k - batch.n_pruned) as u64;
        mesh = batch.best_outcome().decoded.mesh;
        t += k;
    }
    let mut result = tracker.finish(nm, episodes_budget);
    for s in &scratches {
        result.eval_stats.absorb_scratch(s);
    }
    result.eval_stats.pruned += pruned_total;
    result.eval_stats.evaluated += evaluated_total;
    result
}

/// Pure random search: uniform actions every episode.
pub fn random_search(cfg: &RunConfig, nm: u32, rng: &mut Rng) -> NodeResult {
    random_search_t(cfg, nm, rng, parallel::resolve(cfg.rl.eval_threads))
}

/// [`random_search`] with an explicit worker count (1 = fully serial).
/// Results are identical for any `threads`.
pub fn random_search_t(
    cfg: &RunConfig,
    nm: u32,
    rng: &mut Rng,
    threads: usize,
) -> NodeResult {
    run_with_proposals(cfg, nm, |_, rng| policy::uniform_action(rng), rng, threads)
}

/// Grid search: a deterministic lattice over the most influential dims
/// (mesh side via deltas, VLEN, DMEM, ρ_matmul, DFLIT), neutral elsewhere.
/// Enumerates lexicographically, recycling with jitter once exhausted.
pub fn grid_search(cfg: &RunConfig, nm: u32, rng: &mut Rng) -> NodeResult {
    grid_search_t(cfg, nm, rng, parallel::resolve(cfg.rl.eval_threads))
}

/// [`grid_search`] with an explicit worker count (1 = fully serial).
pub fn grid_search_t(cfg: &RunConfig, nm: u32, rng: &mut Rng, threads: usize) -> NodeResult {
    const LEVELS: [f64; 5] = [-1.0, -0.5, 0.0, 0.5, 1.0];
    let mesh_deltas: [i32; 3] = [-2, 0, 2];
    run_with_proposals(
        cfg,
        nm,
        move |t, rng| {
            let mut a = Action::neutral();
            let mut k = t;
            let vlen = LEVELS[k % 5];
            k /= 5;
            let dmem = LEVELS[k % 5];
            k /= 5;
            let rho = LEVELS[k % 5];
            k /= 5;
            let dflit = LEVELS[k % 5];
            k /= 5;
            let md = mesh_deltas[k % 3];
            k /= 3;
            a.cont[2] = vlen;
            a.cont[3] = dmem;
            a.cont[19] = rho;
            a.cont[6] = dflit;
            a.deltas = [md, md, 0, 0];
            if k > 0 {
                // grid exhausted: jitter to keep exploring
                for i in 0..ACT_DIM {
                    a.cont[i] = (a.cont[i] + 0.1 * rng.gaussian()).clamp(-1.0, 1.0);
                }
            }
            a
        },
        rng,
        threads,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Granularity, RunConfig};

    fn tiny_cfg() -> RunConfig {
        let mut c = RunConfig::default();
        c.rl.episodes_per_node = 12;
        c.granularity = Granularity::Group;
        c
    }

    #[test]
    fn random_search_completes_and_logs() {
        let mut rng = Rng::new(1);
        let r = random_search(&tiny_cfg(), 3, &mut rng);
        assert_eq!(r.episodes.len(), 12);
        assert!(r.episodes.iter().all(|e| e.reward.is_finite()));
    }

    #[test]
    fn grid_search_is_deterministic_early() {
        let mut rng1 = Rng::new(2);
        let mut rng2 = Rng::new(99);
        let a = grid_search(&tiny_cfg(), 7, &mut rng1);
        let b = grid_search(&tiny_cfg(), 7, &mut rng2);
        // first 12 grid points don't use the rng: identical traces
        for (x, y) in a.episodes.iter().zip(&b.episodes) {
            assert_eq!(x.mesh_w, y.mesh_w);
            assert!((x.score - y.score).abs() < 1e-12);
        }
    }

    #[test]
    fn best_score_monotonically_improves() {
        let mut rng = Rng::new(3);
        let r = random_search(&tiny_cfg(), 14, &mut rng);
        for w in r.episodes.windows(2) {
            assert!(w[1].best_score <= w[0].best_score + 1e-12);
        }
    }

    #[test]
    fn pareto_archive_only_holds_feasible() {
        let mut rng = Rng::new(4);
        let r = random_search(&tiny_cfg(), 28, &mut rng);
        assert!(r.pareto.len() <= r.feasible_count.max(1));
    }

    #[test]
    fn pruned_search_keeps_the_same_best_design() {
        let mut exact_cfg = tiny_cfg();
        exact_cfg.rl.episodes_per_node = 24;
        let mut pruned_cfg = exact_cfg.clone();
        pruned_cfg.rl.prune = true;
        let exact = random_search_t(&exact_cfg, 7, &mut Rng::new(11), 2);
        let pruned = random_search_t(&pruned_cfg, 7, &mut Rng::new(11), 2);
        match (&exact.best, &pruned.best) {
            (Some(a), Some(b)) => {
                assert_eq!(a.episode, b.episode);
                assert_eq!(
                    a.outcome.reward.score.to_bits(),
                    b.outcome.reward.score.to_bits()
                );
                assert_eq!(a.outcome.decoded.mesh, b.outcome.decoded.mesh);
            }
            (None, None) => {}
            _ => panic!("best presence diverged under pruning"),
        }
        // pruned candidates are absent from the log but still counted
        // against the episode budget
        assert!(pruned.episodes.len() <= exact.episodes.len());
        assert_eq!(pruned.total_episodes, exact.total_episodes);
        assert_eq!(
            pruned.eval_stats.pruned + pruned.eval_stats.evaluated,
            24
        );
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let mut cfg = tiny_cfg();
        cfg.rl.episodes_per_node = 24;
        let serial = random_search_t(&cfg, 7, &mut Rng::new(11), 1);
        let par = random_search_t(&cfg, 7, &mut Rng::new(11), 4);
        assert_eq!(serial.feasible_count, par.feasible_count);
        for (a, b) in serial.episodes.iter().zip(&par.episodes) {
            assert_eq!(a.reward.to_bits(), b.reward.to_bits());
            assert_eq!(a.best_score.to_bits(), b.best_score.to_bits());
            assert_eq!((a.mesh_w, a.mesh_h), (b.mesh_w, b.mesh_h));
        }
    }
}
