//! Search-strategy baselines (§4.14, Table 21): random search and grid
//! search under the same episode budget and the same evaluation pipeline
//! as SAC — only the proposal mechanism differs.

use crate::config::RunConfig;
use crate::env::{Action, Env, ACT_DIM};
use crate::nn::policy;
use crate::rl::loop_::{BestConfig, EpisodeLog, NodeResult};
use crate::rl::pareto::{ParetoArchive, ParetoPoint};
use crate::util::Rng;

/// Shared episode-loop skeleton for proposal-driven baselines.
fn run_with_proposals(
    cfg: &RunConfig,
    nm: u32,
    mut propose: impl FnMut(usize, &mut Env, &mut Rng) -> Action,
    rng: &mut Rng,
) -> NodeResult {
    let mut env = Env::new(cfg, nm);
    let episodes_budget = cfg.rl.episodes_per_node;
    let mut pareto = ParetoArchive::new();
    let mut episodes = Vec::with_capacity(episodes_budget);
    let mut best: Option<BestConfig> = None;
    let mut best_score = f64::INFINITY;
    let mut feasible_count = 0usize;
    let mut seen = std::collections::HashSet::new();

    for t in 0..episodes_budget {
        let action = propose(t, &mut env, rng);
        let out = env.eval_action(&action);
        if out.reward.feasible {
            feasible_count += 1;
            pareto.insert(ParetoPoint {
                perf_gops: out.ppa.perf_gops,
                power_mw: out.ppa.power.total(),
                area_mm2: out.ppa.area.total(),
                tokens_per_s: out.ppa.tokens_per_s,
                episode: t,
                tag: t,
            });
            if out.reward.score < best_score {
                best_score = out.reward.score;
                best = Some(BestConfig { episode: t, outcome: out.clone() });
            }
        }
        let mut h: u64 = out.decoded.mesh.width as u64;
        h = h.wrapping_mul(1315423911) ^ out.decoded.avg.vlen_bits as u64;
        seen.insert(h ^ (out.decoded.avg.dmem_kb as u64) << 24);
        episodes.push(EpisodeLog {
            episode: t,
            reward: out.reward.total,
            score: out.reward.score,
            best_score,
            feasible: out.reward.feasible,
            tokens_per_s: out.ppa.tokens_per_s,
            power_mw: out.ppa.power.total(),
            perf_gops: out.ppa.perf_gops,
            area_mm2: out.ppa.area.total(),
            mesh_w: out.decoded.mesh.width,
            mesh_h: out.decoded.mesh.height,
            eps: 1.0,
            entropy: 0.0,
            unique_configs: seen.len(),
        });
    }
    NodeResult {
        nm,
        best,
        episodes,
        pareto,
        feasible_count,
        total_episodes: episodes_budget,
    }
}

/// Pure random search: uniform actions every episode.
pub fn random_search(cfg: &RunConfig, nm: u32, rng: &mut Rng) -> NodeResult {
    run_with_proposals(cfg, nm, |_, _, rng| policy::uniform_action(rng), rng)
}

/// Grid search: a deterministic lattice over the most influential dims
/// (mesh side via deltas, VLEN, DMEM, ρ_matmul, DFLIT), neutral elsewhere.
/// Enumerates lexicographically, recycling with jitter once exhausted.
pub fn grid_search(cfg: &RunConfig, nm: u32, rng: &mut Rng) -> NodeResult {
    const LEVELS: [f64; 5] = [-1.0, -0.5, 0.0, 0.5, 1.0];
    let mesh_deltas: [i32; 3] = [-2, 0, 2];
    run_with_proposals(
        cfg,
        nm,
        move |t, _, rng| {
            let mut a = Action::neutral();
            let mut k = t;
            let vlen = LEVELS[k % 5];
            k /= 5;
            let dmem = LEVELS[k % 5];
            k /= 5;
            let rho = LEVELS[k % 5];
            k /= 5;
            let dflit = LEVELS[k % 5];
            k /= 5;
            let md = mesh_deltas[k % 3];
            k /= 3;
            a.cont[2] = vlen;
            a.cont[3] = dmem;
            a.cont[19] = rho;
            a.cont[6] = dflit;
            a.deltas = [md, md, 0, 0];
            if k > 0 {
                // grid exhausted: jitter to keep exploring
                for i in 0..ACT_DIM {
                    a.cont[i] = (a.cont[i] + 0.1 * rng.gaussian()).clamp(-1.0, 1.0);
                }
            }
            a
        },
        rng,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Granularity, RunConfig};

    fn tiny_cfg() -> RunConfig {
        let mut c = RunConfig::default();
        c.rl.episodes_per_node = 12;
        c.granularity = Granularity::Group;
        c
    }

    #[test]
    fn random_search_completes_and_logs() {
        let mut rng = Rng::new(1);
        let r = random_search(&tiny_cfg(), 3, &mut rng);
        assert_eq!(r.episodes.len(), 12);
        assert!(r.episodes.iter().all(|e| e.reward.is_finite()));
    }

    #[test]
    fn grid_search_is_deterministic_early() {
        let mut rng1 = Rng::new(2);
        let mut rng2 = Rng::new(99);
        let a = grid_search(&tiny_cfg(), 7, &mut rng1);
        let b = grid_search(&tiny_cfg(), 7, &mut rng2);
        // first 12 grid points don't use the rng: identical traces
        for (x, y) in a.episodes.iter().zip(&b.episodes) {
            assert_eq!(x.mesh_w, y.mesh_w);
            assert!((x.score - y.score).abs() < 1e-12);
        }
    }

    #[test]
    fn best_score_monotonically_improves() {
        let mut rng = Rng::new(3);
        let r = random_search(&tiny_cfg(), 14, &mut rng);
        for w in r.episodes.windows(2) {
            assert!(w[1].best_score <= w[0].best_score + 1e-12);
        }
    }

    #[test]
    fn pareto_archive_only_holds_feasible(){
        let mut rng = Rng::new(4);
        let r = random_search(&tiny_cfg(), 28, &mut rng);
        assert!(r.pareto.len() <= r.feasible_count.max(1));
    }
}
