//! Scenario-atlas sweep engine (DESIGN.md §12): dominance-pruned,
//! cache-warm search over the full scenario grid — workloads × process
//! nodes × phase × seq_len × batch — run as waves of vec-env lanes.
//!
//! Sweeping the grid as N independent `optimize` runs costs N full cold
//! searches. The atlas makes it superlinearly cheaper with three stacked
//! reuse layers:
//!
//! 1. **Cross-point roofline dominance pruning.** Before a point runs,
//!    its O(1) scenario-global envelope
//!    ([`Evaluator::roofline_envelope`]) is compared against already
//!    solved neighbors (same workload and node). Two prune paths:
//!    the *fast path* skips a point whose entire envelope is dominated
//!    by one achieved frontier point ([`RooflineBound::dominated_by`] —
//!    sound for any solved neighbor, since the dominating point already
//!    sits in the merged atlas); the *amortization path* skips a point
//!    whose scenario is the same graph under strictly-harder per-token
//!    traffic (same phase/seq_len, smaller batch — graph invariance is
//!    pinned by `batch_does_not_change_the_graph`) when the solved
//!    neighbor's envelope weakly dominates
//!    ([`RooflineBound::dominates_envelope`]). Dominance is stated in
//!    (perf ↑, energy mJ/token ↓, area ↓) space: raw power is not
//!    monotone under batch amortization (the NoC term scales with
//!    tokens/s) but energy per token is. `atlas_prune=off` is the exact
//!    fallback — the pruned sweep emits bit-identical per-point
//!    frontiers for every non-skipped point (pinned by
//!    `tests/atlas.rs`).
//! 2. **Warm shared state** (`atlas_warm=on`): one process-wide
//!    [`SharedEvalCache`] spans every lane and scenario point (salted
//!    keys make cross-scenario replay impossible), the read-only
//!    geometry registry shares one `MeshGeom` per mesh-dims across the
//!    whole process, and one SAC agent is handed between neighboring
//!    points in curriculum order instead of per-point cold starts.
//! 3. **Wave scheduling.** Points are ordered by the dominance graph:
//!    within a (workload, phase, seq_len) slab the largest batch — the
//!    easiest, most-amortized regime, whose envelope weakly dominates
//!    every smaller batch — runs first, so pruning decisions always see
//!    the freshest neighbor frontiers. Each runnable (workload,
//!    scenario) group becomes one vec-env call with nodes × seeds as
//!    lanes.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use crate::config::RunConfig;
use crate::error::{Error, Result};
use crate::eval::{CacheOccupancy, EvalStats, Evaluator, SharedEvalCache};
use crate::ir::registry;
use crate::ir::spec::{Phase, Scenario};
use crate::nn::backend;
use crate::ppa::RooflineBound;
use crate::rl::checkpoint::{self, CheckpointDir, FaultPlan, RunCtx, KIND_ATLAS};
use crate::rl::multiseed::{self, derive_seed};
use crate::rl::pareto::{ParetoArchive, ParetoPoint};
use crate::rl::vecenv::{self, LaneSpec};
use crate::rl::{NodeResult, SacAgent};
use crate::util::csv::{fnum, Table};
use crate::util::fsio::{self, ByteReader, ByteWriter};
use crate::util::json::{self, Json};
use crate::util::Rng;

/// Which prune path justified skipping/shrinking a point.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PruneKind {
    /// A single achieved neighbor point dominates the whole envelope.
    Fast,
    /// Same graph, harder per-token traffic than a solved neighbor whose
    /// envelope weakly dominates (the batch-amortization path).
    Amortized,
}

impl PruneKind {
    pub fn name(&self) -> &'static str {
        match self {
            PruneKind::Fast => "fast",
            PruneKind::Amortized => "amortized",
        }
    }
}

/// What happened to one grid point.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PointStatus {
    /// Ran at the full episode budget.
    Solved,
    /// Dominated, but `atlas_shrink=N` ran it at `episodes / N`.
    Shrunk { by: usize, kind: PruneKind },
    /// Dominated and skipped outright (`by` is the justifying point's
    /// grid index).
    Skipped { by: usize, kind: PruneKind },
}

impl PointStatus {
    pub fn name(&self) -> &'static str {
        match self {
            PointStatus::Solved => "solved",
            PointStatus::Shrunk { .. } => "shrunk",
            PointStatus::Skipped { .. } => "skipped",
        }
    }
}

/// One scenario-grid point's record in the atlas.
#[derive(Debug, Clone)]
pub struct AtlasPoint {
    /// Stable index in the canonical full-grid enumeration — identical
    /// for `atlas_prune=on` and `off`. Seeds derive from its
    /// batch-collapsed projection (the stream index), so they never move
    /// with the prune setting either.
    pub grid_index: usize,
    pub workload: String,
    pub nm: u32,
    pub scenario: Scenario,
    pub envelope: RooflineBound,
    pub status: PointStatus,
    /// Merged-across-seeds frontier; empty when skipped.
    pub frontier: ParetoArchive,
    /// Episodes actually spent (all seeds).
    pub episodes: u64,
    /// Shared-cache hit rate over this point's vec-env group (warm mode
    /// attributes the group delta to each member point).
    pub cache_hit_rate: f64,
}

/// Sweep-level counters (the prune/cache/reuse evidence).
#[derive(Debug, Clone, Copy, Default)]
pub struct AtlasCounters {
    pub points: u64,
    pub solved: u64,
    pub skipped: u64,
    pub shrunk: u64,
    pub prune_fast: u64,
    pub prune_amortized: u64,
    /// Episodes actually run vs what a no-reuse sweep would spend.
    pub episodes_run: u64,
    pub episodes_budget: u64,
}

impl AtlasCounters {
    pub fn pruned(&self) -> u64 {
        self.skipped + self.shrunk
    }
}

/// Result of one atlas sweep.
pub struct AtlasResult {
    /// Every grid point in canonical grid order.
    pub points: Vec<AtlasPoint>,
    pub counters: AtlasCounters,
    /// Shared-cache occupancy (warm mode only).
    pub occupancy: Option<CacheOccupancy>,
    /// Evaluation-layer counters summed over every lane (plus the shared
    /// cache, folded once).
    pub eval_stats: EvalStats,
    /// Raw per-lane results of the solved/shrunk points, in run order
    /// (feeds Table 14).
    pub node_results: Vec<NodeResult>,
    /// Merged energy-space frontier per (workload, nm).
    pub atlas: BTreeMap<(String, u32), Vec<ParetoPoint>>,
    pub elapsed_s: f64,
}

/// One enumerated grid point (pre-run).
#[derive(Debug, Clone)]
struct GridPoint {
    grid_index: usize,
    /// Grid index with the batch axis collapsed: identical for every
    /// batch of the same (workload, phase, seq_len, node). Seeds derive
    /// from this, so batch-axis neighbors replay the *same* rollout
    /// action stream — together with batch-invariant decode/projection,
    /// this is what lets a larger-batch run provably visit every design
    /// a smaller-batch run would have visited (the amortization prune
    /// path's coverage argument).
    stream_index: usize,
    workload: String,
    nm: u32,
    scenario: Scenario,
}

/// A solved (or shrunk) point's dominance evidence.
struct Solved {
    grid_index: usize,
    workload: String,
    nm: u32,
    scenario: Scenario,
    envelope: RooflineBound,
    /// `(flops_per_token, weight_traffic_per_token, kv_traffic_per_token)`.
    constants: (f64, f64, f64),
    frontier: ParetoArchive,
}

/// Enumerate the full grid in canonical nested order: workload → phase →
/// seq_len → batch → node. The enumeration (and therefore every
/// `grid_index`) is a pure function of the config — independent of
/// pruning, warm state and curriculum order.
fn enumerate_grid(cfg: &RunConfig) -> Result<Vec<GridPoint>> {
    let mut grid = Vec::new();
    let mut idx = 0usize;
    let (n_phase, n_seq, n_node) =
        (cfg.atlas.phases.len(), cfg.atlas.seq_lens.len(), cfg.nodes_nm.len());
    for (wi, name) in cfg.atlas_grid_workloads().iter().enumerate() {
        let spec = registry::get(name)
            .ok_or_else(|| Error::msg(format!("unknown atlas workload {name}")))?;
        for (pi, &phase) in cfg.atlas.phases.iter().enumerate() {
            for (si, &seq_len) in cfg.atlas.seq_lens.iter().enumerate() {
                for &batch in &cfg.atlas.batches {
                    for (ni, &nm) in cfg.nodes_nm.iter().enumerate() {
                        grid.push(GridPoint {
                            grid_index: idx,
                            stream_index: ((wi * n_phase + pi) * n_seq + si) * n_node + ni,
                            workload: spec.name.to_string(),
                            nm,
                            scenario: Scenario { phase, seq_len, batch },
                        });
                        idx += 1;
                    }
                }
            }
        }
    }
    Ok(grid)
}

/// Curriculum order: a stable sort of the canonical grid that runs the
/// largest batch of each (workload, phase, seq_len) slab first — a
/// topological order of the batch-amortization dominance edges (larger
/// batch ⇒ weakly-dominating envelope), so dominators are always solved
/// before the points they can prune.
fn curriculum(grid: &[GridPoint]) -> Vec<usize> {
    // one (workload, phase, seq_len) slab is a contiguous run of
    // batches × nodes entries in the canonical enumeration; every slab
    // shares that shape, so measure it once off the head of the grid
    let first = &grid[0];
    let nodes = grid
        .iter()
        .take_while(|g| {
            g.workload == first.workload
                && g.scenario.phase == first.scenario.phase
                && g.scenario.seq_len == first.scenario.seq_len
                && g.scenario.batch == first.scenario.batch
        })
        .count();
    let slab = grid
        .iter()
        .take_while(|g| {
            g.workload == first.workload
                && g.scenario.phase == first.scenario.phase
                && g.scenario.seq_len == first.scenario.seq_len
        })
        .count()
        .max(nodes.max(1));
    let mut order: Vec<usize> = (0..grid.len()).collect();
    order.sort_by(|&a, &b| {
        let (pa, pb) = (&grid[a], &grid[b]);
        // slabs keep enumeration order; inside a slab the batch descends
        (pa.grid_index / slab)
            .cmp(&(pb.grid_index / slab))
            .then(pb.scenario.batch.cmp(&pa.scenario.batch))
            .then(pa.grid_index.cmp(&pb.grid_index))
    });
    order
}

/// Per-point config: the base config with the point's workload, scenario
/// and node applied.
fn point_cfg(cfg: &RunConfig, gp: &GridPoint) -> Result<RunConfig> {
    let mut c = cfg.clone();
    c.apply("workload", &gp.workload).map_err(Error::msg)?;
    c.phase = gp.scenario.phase;
    c.seq_len = Some(gp.scenario.seq_len);
    c.batch = Some(gp.scenario.batch);
    c.nodes_nm = vec![gp.nm];
    Ok(c)
}

/// The point's per-lane seeds: derived from the canonical
/// *batch-collapsed* stream index (never the curriculum position), so
/// (a) a point's rollout streams are identical under `atlas_prune=on|off`
/// — the precondition of the pruned≡exact frontier contract — and (b)
/// batch-axis neighbors share one action stream, so a solved
/// larger-batch point has evaluated every design its smaller-batch
/// neighbors would reach (the amortization path's coverage argument).
fn point_seeds(cfg: &RunConfig, gp: &GridPoint) -> Vec<u64> {
    let point_seed = derive_seed(cfg.seed, gp.stream_index);
    (0..cfg.atlas.n_seeds).map(|k| derive_seed(point_seed, k)).collect()
}

/// Try every solved neighbor (same workload and node) against `gp`'s
/// envelope. Returns the justifying point's grid index and the path that
/// fired.
fn find_dominator(
    gp: &GridPoint,
    env: &RooflineBound,
    constants: (f64, f64, f64),
    solved: &[Solved],
) -> Option<(usize, PruneKind)> {
    for q in solved {
        if q.workload != gp.workload || q.nm != gp.nm || q.frontier.is_empty() {
            continue;
        }
        // fast path: one achieved point beats the whole envelope —
        // scenario-agnostic (the dominating point is already in this
        // (workload, nm) atlas slab, so nothing p could achieve would
        // survive the merge)
        if q.frontier.frontier().iter().any(|p| env.dominated_by(p)) {
            return Some((q.grid_index, PruneKind::Fast));
        }
        // amortization path: identical graph (same phase/seq_len; batch
        // never changes the graph), component-wise easier-or-equal
        // per-token traffic at q, and q's envelope weakly dominates —
        // every design reachable at p exists at q in a uniformly more
        // favorable regime
        if q.scenario.phase == gp.scenario.phase
            && q.scenario.seq_len == gp.scenario.seq_len
            && gp.scenario.batch <= q.scenario.batch
            && constants.0.to_bits() == q.constants.0.to_bits()
            && constants.2.to_bits() == q.constants.2.to_bits()
            && constants.1 >= q.constants.1
            && q.envelope.dominates_envelope(env)
        {
            return Some((q.grid_index, PruneKind::Amortized));
        }
    }
    None
}

/// Insert into an energy-space frontier: reject anything covered
/// (dominated or exactly tied) by a resident point, evict anything the
/// newcomer covers. Deterministic in insertion order.
fn energy_insert(front: &mut Vec<ParetoPoint>, p: ParetoPoint) {
    if front.iter().any(|q| q.covers_energy(&p)) {
        return;
    }
    front.retain(|q| !p.covers_energy(q));
    front.push(p);
}

// ---------------------------------------------------------------------------
// sweep-level checkpointing (DESIGN.md §13)

/// Fingerprint of everything an atlas checkpoint's validity depends on:
/// the grid axes (and therefore the canonical enumeration), the seed
/// derivation inputs and the reuse switches. Envelopes, constants and
/// point metadata are deliberately *not* stored in the checkpoint — they
/// are recomputed from the grid on resume, so the fingerprint only needs
/// to pin the grid itself.
fn fingerprint_atlas(cfg: &RunConfig) -> u64 {
    let mut w = ByteWriter::new();
    w.str("atlas");
    w.u64(cfg.seed);
    w.usize(cfg.rl.episodes_per_node);
    w.usize(cfg.rl.warmup_steps);
    w.usize(cfg.rl.buffer_capacity);
    w.str(cfg.rl.learner.name());
    w.usize(cfg.atlas.n_seeds);
    w.bool(cfg.atlas.prune);
    w.bool(cfg.atlas.warm);
    w.u32(cfg.atlas.shrink);
    let ws = cfg.atlas_grid_workloads();
    w.usize(ws.len());
    for name in &ws {
        w.str(name);
    }
    w.usize(cfg.atlas.phases.len());
    for &p in &cfg.atlas.phases {
        w.u8(match p {
            Phase::Prefill => 0,
            Phase::Decode => 1,
        });
    }
    w.usize(cfg.atlas.seq_lens.len());
    for &s in &cfg.atlas.seq_lens {
        w.u32(s);
    }
    w.usize(cfg.atlas.batches.len());
    for &b in &cfg.atlas.batches {
        w.u32(b);
    }
    w.usize(cfg.nodes_nm.len());
    for &n in &cfg.nodes_nm {
        w.u32(n);
    }
    fsio::fnv1a64(&w.buf)
}

fn write_status(w: &mut ByteWriter, st: &PointStatus) {
    let kind_tag = |k: PruneKind| match k {
        PruneKind::Fast => 0u8,
        PruneKind::Amortized => 1,
    };
    match st {
        PointStatus::Solved => w.u8(0),
        PointStatus::Shrunk { by, kind } => {
            w.u8(1);
            w.usize(*by);
            w.u8(kind_tag(*kind));
        }
        PointStatus::Skipped { by, kind } => {
            w.u8(2);
            w.usize(*by);
            w.u8(kind_tag(*kind));
        }
    }
}

fn read_status(rd: &mut ByteReader) -> Result<PointStatus> {
    let tag = rd.u8()?;
    if tag == 0 {
        return Ok(PointStatus::Solved);
    }
    let by = rd.usize()?;
    let kind = match rd.u8()? {
        0 => PruneKind::Fast,
        1 => PruneKind::Amortized,
        k => return Err(Error::msg(format!("unknown prune kind tag {k}"))),
    };
    match tag {
        1 => Ok(PointStatus::Shrunk { by, kind }),
        2 => Ok(PointStatus::Skipped { by, kind }),
        k => Err(Error::msg(format!("unknown point status tag {k}"))),
    }
}

fn write_frontier(w: &mut ByteWriter, a: &ParetoArchive) {
    let f = a.frontier();
    w.usize(f.len());
    for p in f {
        checkpoint::write_point(w, p);
    }
}

fn read_frontier(rd: &mut ByteReader) -> Result<ParetoArchive> {
    let n = rd.len(48)?; // 4×f64 + 2×u64 per serialized point
    let mut pts = Vec::with_capacity(n);
    for _ in 0..n {
        pts.push(checkpoint::read_point(rd)?);
    }
    Ok(ParetoArchive::from_points(pts))
}

/// Borrowed view of the sweep state at a group boundary.
struct SweepView<'a> {
    cursor: usize,
    counters: &'a AtlasCounters,
    eval_stats: &'a EvalStats,
    points: &'a [Option<AtlasPoint>],
    solved: &'a [Solved],
    node_results: &'a [NodeResult],
    node_gis: &'a [usize],
    warm_agent: Option<&'a SacAgent>,
}

/// Atlas checkpoint payload: the curriculum cursor, the sweep counters,
/// per-point records (status + frontier only — metadata and envelopes are
/// recomputed from the grid on resume), the dominance evidence, the raw
/// per-lane results tagged with their grid index (so each best config
/// re-evaluates under the right point config) and, in warm mode, the
/// shared agent with its replay buffer.
fn encode_atlas(v: &SweepView) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.usize(v.cursor);
    let c = v.counters;
    for x in [
        c.points,
        c.solved,
        c.skipped,
        c.shrunk,
        c.prune_fast,
        c.prune_amortized,
        c.episodes_run,
        c.episodes_budget,
    ] {
        w.u64(x);
    }
    checkpoint::write_stats(&mut w, v.eval_stats);
    w.usize(v.points.len());
    for p in v.points {
        match p {
            Some(pt) => {
                w.bool(true);
                write_status(&mut w, &pt.status);
                write_frontier(&mut w, &pt.frontier);
                w.u64(pt.episodes);
                w.f64(pt.cache_hit_rate);
            }
            None => w.bool(false),
        }
    }
    w.usize(v.solved.len());
    for s in v.solved {
        w.usize(s.grid_index);
        write_frontier(&mut w, &s.frontier);
    }
    debug_assert_eq!(v.node_results.len(), v.node_gis.len());
    w.usize(v.node_results.len());
    for (nr, &gi) in v.node_results.iter().zip(v.node_gis) {
        w.usize(gi);
        checkpoint::write_node_result(&mut w, nr);
    }
    match v.warm_agent {
        Some(a) => {
            w.bool(true);
            checkpoint::write_agent(&mut w, a, true);
        }
        None => w.bool(false),
    }
    w.buf
}

/// Owned restore image of [`encode_atlas`]'s payload; warm-agent state is
/// applied to `warm_agent` in place during decode.
struct SweepResume {
    cursor: usize,
    counters: AtlasCounters,
    eval_stats: EvalStats,
    points: Vec<Option<AtlasPoint>>,
    solved: Vec<Solved>,
    node_results: Vec<NodeResult>,
    node_gis: Vec<usize>,
}

fn decode_atlas(
    payload: &[u8],
    cfg: &RunConfig,
    grid: &[GridPoint],
    warm_agent: &mut Option<SacAgent>,
) -> Result<SweepResume> {
    let mut rd = ByteReader::new(payload);
    let cursor = rd.usize()?;
    let counters = AtlasCounters {
        points: rd.u64()?,
        solved: rd.u64()?,
        skipped: rd.u64()?,
        shrunk: rd.u64()?,
        prune_fast: rd.u64()?,
        prune_amortized: rd.u64()?,
        episodes_run: rd.u64()?,
        episodes_budget: rd.u64()?,
    };
    let eval_stats = checkpoint::read_stats(&mut rd)?;
    let np = rd.len(1)?;
    if np != grid.len() {
        return Err(Error::msg(format!(
            "atlas checkpoint covers {np} grid points, config enumerates {}",
            grid.len()
        )));
    }
    let mut points: Vec<Option<AtlasPoint>> = Vec::with_capacity(np);
    for (gi, gp) in grid.iter().enumerate() {
        if !rd.bool()? {
            points.push(None);
            continue;
        }
        let status = read_status(&mut rd)?;
        let frontier = read_frontier(&mut rd)?;
        let episodes = rd.u64()?;
        let cache_hit_rate = rd.f64()?;
        let pc = point_cfg(cfg, gp)?;
        let ev = Evaluator::new(&pc, gp.nm);
        points.push(Some(AtlasPoint {
            grid_index: gi,
            workload: gp.workload.clone(),
            nm: gp.nm,
            scenario: gp.scenario,
            envelope: ev.roofline_envelope(),
            status,
            frontier,
            episodes,
            cache_hit_rate,
        }));
    }
    let ns = rd.len(8)?;
    let mut solved = Vec::with_capacity(ns);
    for _ in 0..ns {
        let gi = rd.usize()?;
        let frontier = read_frontier(&mut rd)?;
        let gp = grid
            .get(gi)
            .ok_or_else(|| Error::msg("atlas checkpoint: solved grid index out of range"))?;
        let pc = point_cfg(cfg, gp)?;
        let ev = Evaluator::new(&pc, gp.nm);
        solved.push(Solved {
            grid_index: gi,
            workload: gp.workload.clone(),
            nm: gp.nm,
            scenario: gp.scenario,
            envelope: ev.roofline_envelope(),
            constants: ev.scenario_constants(),
            frontier,
        });
    }
    let nr = rd.len(8)?;
    let mut node_results = Vec::with_capacity(nr);
    let mut node_gis = Vec::with_capacity(nr);
    for _ in 0..nr {
        let gi = rd.usize()?;
        let gp = grid
            .get(gi)
            .ok_or_else(|| Error::msg("atlas checkpoint: result grid index out of range"))?;
        let pc = point_cfg(cfg, gp)?;
        node_results.push(checkpoint::read_node_result(&mut rd, &pc)?);
        node_gis.push(gi);
    }
    if rd.bool()? {
        match warm_agent {
            Some(agent) => checkpoint::read_agent(&mut rd, cfg.rl, agent)?,
            None => {
                return Err(Error::msg(
                    "atlas checkpoint carries a warm agent but atlas_warm=off",
                ))
            }
        }
    }
    if rd.remaining() != 0 {
        return Err(Error::msg("trailing bytes in atlas checkpoint payload"));
    }
    Ok(SweepResume { cursor, counters, eval_stats, points, solved, node_results, node_gis })
}

/// Run the atlas sweep. See the module doc for the three reuse layers;
/// `cfg.atlas` carries the grid axes and the prune/warm/shrink switches.
///
/// Robustness (DESIGN.md §13): with `checkpoint_every > 0` the sweep
/// commits one checkpoint generation per completed curriculum group —
/// the natural quiesce point (no live lanes, learner drained, warm agent
/// self-contained) — and `resume=<dir>` restores the newest valid
/// generation, re-running at most one interrupted group. One cumulative
/// fault-probe counter spans every inner vec-env call, so
/// `crash_after=<N>` sweeps interruption points across the whole grid.
pub fn run(cfg: &RunConfig) -> Result<AtlasResult> {
    let t0 = Instant::now();
    let grid = enumerate_grid(cfg)?;
    if grid.is_empty() {
        return Err(Error::msg("atlas grid is empty"));
    }
    let order = curriculum(&grid);

    let fp = fingerprint_atlas(cfg);
    let mut ckpt_dir = if cfg.rl.checkpoint_every > 0 {
        Some(CheckpointDir::create(Path::new(&cfg.out_dir).join("ckpt"))?)
    } else {
        None
    };
    // one fault-probe counter spans every inner vec-env call, so
    // crash_after sweeps interruption points across the whole grid; the
    // inner calls never open their own sink or resume — the sweep owns
    // both at group granularity
    let mut vec_ctx = RunCtx::passthrough();
    vec_ctx.fault = FaultPlan::new(cfg.rl.crash_after);

    let shared = if cfg.atlas.warm {
        Some(SharedEvalCache::new(cfg.rl.eval_cache))
    } else {
        None
    };
    // warm mode: ONE agent spans the sweep — curriculum neighbors hand
    // their policy/replay state forward instead of cold-starting
    let mut warm_agent: Option<SacAgent> = if cfg.atlas.warm {
        let be = backend::load(&cfg.artifacts_dir, cfg.backend)?;
        Some(SacAgent::new(be, cfg.rl, &mut Rng::new(cfg.seed))?)
    } else {
        None
    };

    let threads = cfg.rollout_threads();
    let full_eps = cfg.rl.episodes_per_node as u64;
    let shrink_eps = if cfg.atlas.shrink > 0 {
        (cfg.rl.episodes_per_node / cfg.atlas.shrink as usize).max(1) as u64
    } else {
        0
    };

    let mut solved: Vec<Solved> = Vec::new();
    let mut points: Vec<Option<AtlasPoint>> = vec![None; grid.len()];
    let mut counters = AtlasCounters { points: grid.len() as u64, ..Default::default() };
    let mut eval_stats = EvalStats::default();
    let mut node_results: Vec<NodeResult> = Vec::new();
    let mut node_gis: Vec<usize> = Vec::new();
    let mut start = 0usize;
    if let Some(spec) = &cfg.resume {
        let dir = checkpoint::resolve_resume_dir(spec);
        match CheckpointDir::load(&dir, KIND_ATLAS, fp)? {
            Some((seq, payload)) => {
                eprintln!(
                    "note: resuming atlas from checkpoint generation {seq} in {}",
                    dir.display()
                );
                let r = decode_atlas(&payload, cfg, &grid, &mut warm_agent)?;
                if r.cursor > order.len() {
                    return Err(Error::msg("atlas checkpoint cursor out of range"));
                }
                start = r.cursor;
                counters = r.counters;
                eval_stats = r.eval_stats;
                points = r.points;
                solved = r.solved;
                node_results = r.node_results;
                node_gis = r.node_gis;
            }
            None => {
                eprintln!("note: no usable atlas checkpoint in {}; starting fresh", dir.display());
            }
        }
    }

    // walk the curriculum as (workload, scenario) groups: every node of a
    // group that survives pruning becomes n_seeds lanes of one vec-env
    // call, so pruning decisions at the next group always see this
    // group's frontiers
    let mut i = start;
    while i < order.len() {
        // group = consecutive curriculum entries sharing (workload, scenario)
        let head = &grid[order[i]];
        let mut group = Vec::new();
        while i < order.len() {
            let gp = &grid[order[i]];
            if gp.workload != head.workload || gp.scenario != head.scenario {
                break;
            }
            group.push(order[i]);
            i += 1;
        }

        // classify each member against the solved set
        let mut runnable: Vec<(usize, u64)> = Vec::new(); // (grid idx, episodes)
        for &gi in &group {
            let gp = &grid[gi];
            let pc = point_cfg(cfg, gp)?;
            let ev = Evaluator::new(&pc, gp.nm);
            let env = ev.roofline_envelope();
            let constants = ev.scenario_constants();
            let dominator = if cfg.atlas.prune {
                find_dominator(gp, &env, constants, &solved)
            } else {
                None
            };
            match dominator {
                Some((by, kind)) => {
                    match kind {
                        PruneKind::Fast => counters.prune_fast += 1,
                        PruneKind::Amortized => counters.prune_amortized += 1,
                    }
                    if shrink_eps > 0 {
                        counters.shrunk += 1;
                        points[gi] = Some(AtlasPoint {
                            grid_index: gi,
                            workload: gp.workload.clone(),
                            nm: gp.nm,
                            scenario: gp.scenario,
                            envelope: env,
                            status: PointStatus::Shrunk { by, kind },
                            frontier: ParetoArchive::new(),
                            episodes: 0,
                            cache_hit_rate: 0.0,
                        });
                        runnable.push((gi, shrink_eps));
                    } else {
                        counters.skipped += 1;
                        points[gi] = Some(AtlasPoint {
                            grid_index: gi,
                            workload: gp.workload.clone(),
                            nm: gp.nm,
                            scenario: gp.scenario,
                            envelope: env,
                            status: PointStatus::Skipped { by, kind },
                            frontier: ParetoArchive::new(),
                            episodes: 0,
                            cache_hit_rate: 0.0,
                        });
                    }
                }
                None => {
                    counters.solved += 1;
                    points[gi] = Some(AtlasPoint {
                        grid_index: gi,
                        workload: gp.workload.clone(),
                        nm: gp.nm,
                        scenario: gp.scenario,
                        envelope: env,
                        status: PointStatus::Solved,
                        frontier: ParetoArchive::new(),
                        episodes: 0,
                        cache_hit_rate: 0.0,
                    });
                    runnable.push((gi, full_eps));
                }
            }
            counters.episodes_budget += full_eps * cfg.atlas.n_seeds as u64;
        }

        // episode budgets are per vec-env call, so full and shrunk points
        // go in separate calls. Warm mode fuses each budget class into
        // one call with nodes × seeds as lanes (the wave); cold mode runs
        // every point in its own call with an agent seeded from the
        // point's batch-collapsed stream index — the precondition of the
        // prune=on ≡ prune=off bit-identity contract
        let mut calls: Vec<(Vec<usize>, u64)> = Vec::new();
        let budgets: &[u64] =
            if shrink_eps == full_eps { &[full_eps] } else { &[full_eps, shrink_eps] };
        for &budget in budgets {
            if budget == 0 {
                continue;
            }
            let members: Vec<usize> = runnable
                .iter()
                .filter(|&&(_, b)| b == budget)
                .map(|&(gi, _)| gi)
                .collect();
            if members.is_empty() {
                continue;
            }
            if cfg.atlas.warm {
                calls.push((members, budget));
            } else {
                calls.extend(members.into_iter().map(|gi| (vec![gi], budget)));
            }
        }
        for (batch, budget) in calls {
            let mut run_cfg = point_cfg(cfg, &grid[batch[0]])?;
            run_cfg.rl.episodes_per_node = budget as usize;
            let jobs: Vec<LaneSpec> = batch
                .iter()
                .flat_map(|&gi| {
                    let gp = &grid[gi];
                    point_seeds(cfg, gp)
                        .into_iter()
                        .map(move |seed| LaneSpec { nm: gp.nm, seed })
                })
                .collect();
            let lanes = cfg.resolve_lanes(jobs.len());
            let cache_before = shared.as_ref().map(|c| c.counters());

            let results = match (&mut warm_agent, &shared) {
                (Some(agent), sh) => {
                    vecenv::run_jobs_ckpt(
                        &run_cfg, &jobs, lanes, agent, threads, sh.as_ref(), &mut vec_ctx,
                    )?
                    .0
                }
                (None, _) => {
                    // cold: a fresh agent per point, seeded from the
                    // batch-collapsed stream index so prune=on|off (and
                    // batch-axis neighbors) replay the same stream
                    let be = backend::load(&run_cfg.artifacts_dir, run_cfg.backend)?;
                    let mut rng = Rng::new(derive_seed(cfg.seed, grid[batch[0]].stream_index));
                    let mut agent = SacAgent::new(be, run_cfg.rl, &mut rng)?;
                    vecenv::run_jobs_ckpt(
                        &run_cfg, &jobs, lanes, &mut agent, threads, None, &mut vec_ctx,
                    )?
                    .0
                }
            };

            let hit_rate = match (&shared, cache_before) {
                (Some(c), Some((h0, m0))) => {
                    let (h1, m1) = c.counters();
                    let total = (h1 - h0) + (m1 - m0);
                    if total == 0 {
                        0.0
                    } else {
                        (h1 - h0) as f64 / total as f64
                    }
                }
                _ => {
                    // cold: every lane memo is private; fold their rates
                    let (h, m) = results.iter().fold((0, 0), |(h, m), r| {
                        (h + r.eval_stats.outcome_hits, m + r.eval_stats.outcome_misses)
                    });
                    if h + m == 0 {
                        0.0
                    } else {
                        h as f64 / (h + m) as f64
                    }
                }
            };

            // fold results back per point, in jobs order (results are
            // consumed by value: NodeResult is move-only)
            let n_seeds = cfg.atlas.n_seeds.max(1);
            let mut rest = results;
            for &gi in &batch {
                let take = n_seeds.min(rest.len());
                let chunk: Vec<NodeResult> = rest.drain(..take).collect();
                let gp = &grid[gi];
                let frontier = if n_seeds == 1 {
                    chunk[0].pareto.clone()
                } else {
                    multiseed::aggregate(gp.nm, point_seeds(cfg, gp), &chunk).pareto
                };
                let pt = points[gi].as_mut().expect("classified above");
                pt.frontier = frontier.clone();
                pt.episodes = budget * chunk.len() as u64;
                pt.cache_hit_rate = hit_rate;
                counters.episodes_run += pt.episodes;
                let pc = point_cfg(cfg, gp)?;
                let ev = Evaluator::new(&pc, gp.nm);
                solved.push(Solved {
                    grid_index: gi,
                    workload: gp.workload.clone(),
                    nm: gp.nm,
                    scenario: gp.scenario,
                    envelope: ev.roofline_envelope(),
                    constants: ev.scenario_constants(),
                    frontier,
                });
                for r in &chunk {
                    eval_stats.merge(&r.eval_stats);
                }
                node_gis.extend(std::iter::repeat(gi).take(chunk.len()));
                node_results.extend(chunk);
            }
        }

        // group boundary: one checkpoint generation per completed group
        if let Some(dir) = &mut ckpt_dir {
            let view = SweepView {
                cursor: i,
                counters: &counters,
                eval_stats: &eval_stats,
                points: &points,
                solved: &solved,
                node_results: &node_results,
                node_gis: &node_gis,
                warm_agent: warm_agent.as_ref(),
            };
            if let Err(e) = dir.save(KIND_ATLAS, fp, &encode_atlas(&view)) {
                eprintln!("warning: atlas checkpoint save failed: {e} (run continues)");
            }
        }
    }

    if let Some(c) = &shared {
        c.absorb_into(&mut eval_stats);
    }

    // merged energy-space atlas per (workload, nm), in grid order
    let points: Vec<AtlasPoint> = points.into_iter().map(|p| p.expect("all visited")).collect();
    let mut atlas: BTreeMap<(String, u32), Vec<ParetoPoint>> = BTreeMap::new();
    for pt in &points {
        let slab = atlas.entry((pt.workload.clone(), pt.nm)).or_default();
        for p in pt.frontier.frontier() {
            energy_insert(slab, p.clone());
        }
    }

    Ok(AtlasResult {
        points,
        counters,
        occupancy: shared.as_ref().map(|c| c.occupancy()),
        eval_stats,
        node_results,
        atlas,
        elapsed_s: t0.elapsed().as_secs_f64(),
    })
}

/// Per-point CSV/console table (one row per grid point, grid order).
pub fn atlas_table(res: &AtlasResult) -> Table {
    let mut t = Table::new(
        "scenario atlas — per-point results",
        &[
            "idx", "workload", "node", "phase", "seq", "batch", "status", "by",
            "frontier", "tok_s_best", "mj_per_tok_min", "episodes", "cache_hit",
        ],
    );
    for p in &res.points {
        let (by, _kind) = match p.status {
            PointStatus::Skipped { by, kind } | PointStatus::Shrunk { by, kind } => {
                (by as i64, Some(kind))
            }
            PointStatus::Solved => (-1, None),
        };
        let best_tok = p
            .frontier
            .frontier()
            .iter()
            .map(|q| q.tokens_per_s)
            .fold(f64::NAN, f64::max);
        let min_energy = p
            .frontier
            .frontier()
            .iter()
            .map(|q| q.energy_mj_per_token())
            .fold(f64::NAN, f64::min);
        t.row(vec![
            p.grid_index.to_string(),
            p.workload.clone(),
            format!("{}nm", p.nm),
            p.scenario.phase.name().to_string(),
            p.scenario.seq_len.to_string(),
            p.scenario.batch.to_string(),
            p.status.name().to_string(),
            if by < 0 { "-".to_string() } else { by.to_string() },
            p.frontier.len().to_string(),
            if best_tok.is_nan() { "-".into() } else { fnum(best_tok, 0) },
            if min_energy.is_nan() { "-".into() } else { fnum(min_energy, 3) },
            p.episodes.to_string(),
            format!("{:.0}%", p.cache_hit_rate * 100.0),
        ]);
    }
    t
}

/// Per-workload merged-atlas tables: the energy-space frontier of every
/// (workload, nm) slab.
pub fn workload_tables(res: &AtlasResult) -> Vec<(String, Table)> {
    let mut by_workload: BTreeMap<&String, Vec<(&u32, &Vec<ParetoPoint>)>> = BTreeMap::new();
    for ((w, nm), front) in &res.atlas {
        by_workload.entry(w).or_default().push((nm, front));
    }
    by_workload
        .into_iter()
        .map(|(w, slabs)| {
            let mut t = Table::new(
                &format!("atlas — {w} merged energy frontier"),
                &["node", "points", "tok_s_max", "mj_per_tok_min", "area_mm2_min"],
            );
            for (nm, front) in slabs {
                let tok = front.iter().map(|p| p.tokens_per_s).fold(f64::NAN, f64::max);
                let mj = front
                    .iter()
                    .map(|p| p.energy_mj_per_token())
                    .fold(f64::NAN, f64::min);
                let area = front.iter().map(|p| p.area_mm2).fold(f64::NAN, f64::min);
                t.row(vec![
                    format!("{nm}nm"),
                    front.len().to_string(),
                    if tok.is_nan() { "-".into() } else { fnum(tok, 0) },
                    if mj.is_nan() { "-".into() } else { fnum(mj, 3) },
                    if area.is_nan() { "-".into() } else { fnum(area, 1) },
                ]);
            }
            (w.clone(), t)
        })
        .collect()
}

/// Sweep summary: counters, reuse evidence and shared-cache occupancy.
pub fn summary_table(res: &AtlasResult) -> Table {
    let c = &res.counters;
    let mut t = Table::new("atlas summary", &["metric", "value"]);
    let mut kv = |k: &str, v: String| {
        t.row(vec![k.to_string(), v]);
    };
    kv("grid points", c.points.to_string());
    kv("solved", c.solved.to_string());
    kv("skipped (pruned)", c.skipped.to_string());
    kv("shrunk (pruned)", c.shrunk.to_string());
    kv("prune path: fast", c.prune_fast.to_string());
    kv("prune path: amortized", c.prune_amortized.to_string());
    kv("episodes run", c.episodes_run.to_string());
    kv("episodes budget (no reuse)", c.episodes_budget.to_string());
    kv(
        "episodes saved",
        c.episodes_budget.saturating_sub(c.episodes_run).to_string(),
    );
    kv(
        "eval cache hits / misses",
        format!("{} / {}", res.eval_stats.outcome_hits, res.eval_stats.outcome_misses),
    );
    kv(
        "geometry tables shared",
        res.eval_stats.geom_shared.to_string(),
    );
    if let Some(occ) = &res.occupancy {
        kv("shared cache entries", occ.entries.to_string());
        kv("shared cache resident salts", occ.salts.len().to_string());
        let per = if occ.salts.is_empty() {
            0.0
        } else {
            occ.entries as f64 / occ.salts.len() as f64
        };
        kv("shared cache entries/salt", fnum(per, 1));
        kv("shared cache hit rate", format!("{:.1}%", occ.hit_rate() * 100.0));
    }
    kv("wall clock (s)", fnum(res.elapsed_s, 1));
    t
}

/// The machine-readable atlas record (out/atlas.json).
pub fn atlas_json(res: &AtlasResult, cfg: &RunConfig) -> Json {
    let point_json = |p: &AtlasPoint| {
        let frontier = p
            .frontier
            .frontier()
            .iter()
            .map(|q| {
                json::obj(vec![
                    ("perf_gops", json::num(q.perf_gops)),
                    ("power_mw", json::num(q.power_mw)),
                    ("area_mm2", json::num(q.area_mm2)),
                    ("tokens_per_s", json::num(q.tokens_per_s)),
                    ("mj_per_token", json::num(q.energy_mj_per_token())),
                ])
            })
            .collect();
        let (by, kind) = match p.status {
            PointStatus::Skipped { by, kind } | PointStatus::Shrunk { by, kind } => {
                (json::num(by as f64), json::s(kind.name()))
            }
            PointStatus::Solved => (Json::Null, Json::Null),
        };
        json::obj(vec![
            ("grid_index", json::num(p.grid_index as f64)),
            ("workload", json::s(&p.workload)),
            ("nm", json::num(p.nm as f64)),
            ("phase", json::s(p.scenario.phase.name())),
            ("seq_len", json::num(p.scenario.seq_len as f64)),
            ("batch", json::num(p.scenario.batch as f64)),
            ("status", json::s(p.status.name())),
            ("pruned_by", by),
            ("prune_kind", kind),
            ("episodes", json::num(p.episodes as f64)),
            ("cache_hit_rate", json::num(p.cache_hit_rate)),
            ("envelope_perf_gops", json::num(p.envelope.perf_gops)),
            ("envelope_mj_per_token_lb", json::num(p.envelope.energy_lb_mj_per_token())),
            ("envelope_area_mm2_lb", json::num(p.envelope.area_mm2)),
            ("frontier", json::arr(frontier)),
        ])
    };
    let c = &res.counters;
    let counters = json::obj(vec![
        ("points", json::num(c.points as f64)),
        ("solved", json::num(c.solved as f64)),
        ("skipped", json::num(c.skipped as f64)),
        ("shrunk", json::num(c.shrunk as f64)),
        ("prune_fast", json::num(c.prune_fast as f64)),
        ("prune_amortized", json::num(c.prune_amortized as f64)),
        ("episodes_run", json::num(c.episodes_run as f64)),
        ("episodes_budget", json::num(c.episodes_budget as f64)),
    ]);
    let occupancy = match &res.occupancy {
        Some(occ) => json::obj(vec![
            ("entries", json::num(occ.entries as f64)),
            ("salts", json::num(occ.salts.len() as f64)),
            ("hits", json::num(occ.hits as f64)),
            ("misses", json::num(occ.misses as f64)),
            ("hit_rate", json::num(occ.hit_rate())),
        ]),
        None => Json::Null,
    };
    json::obj(vec![
        ("workloads", json::arr(cfg.atlas_grid_workloads().iter().map(|w| json::s(w)).collect())),
        ("nodes_nm", json::arr(cfg.nodes_nm.iter().map(|&n| json::num(n as f64)).collect())),
        ("prune", json::s(if cfg.atlas.prune { "on" } else { "off" })),
        ("warm", json::s(if cfg.atlas.warm { "on" } else { "off" })),
        ("shrink", json::num(cfg.atlas.shrink as f64)),
        ("n_seeds", json::num(cfg.atlas.n_seeds as f64)),
        ("elapsed_s", json::num(res.elapsed_s)),
        ("counters", counters),
        ("occupancy", occupancy),
        ("points", json::arr(res.points.iter().map(point_json).collect())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Granularity;
    use crate::ir::spec::Phase;

    fn tiny_atlas_cfg() -> RunConfig {
        let mut c = RunConfig::default();
        c.granularity = Granularity::Group;
        c.rl.episodes_per_node = 4;
        c.rl.warmup_steps = 10_000;
        c.backend = crate::nn::BackendSel::Native;
        c.atlas.workloads = vec!["llama-3.2-1b".into()];
        c.atlas.phases = vec![Phase::Decode];
        c.atlas.seq_lens = vec![2048];
        c.atlas.batches = vec![1, 4];
        c.nodes_nm = vec![7];
        c
    }

    #[test]
    fn grid_enumeration_is_canonical_and_stable() {
        let mut cfg = tiny_atlas_cfg();
        cfg.atlas.batches = vec![1, 4];
        cfg.nodes_nm = vec![7, 22];
        let grid = enumerate_grid(&cfg).unwrap();
        assert_eq!(grid.len(), 4);
        // canonical order: batch-major over nodes
        assert_eq!(
            grid.iter().map(|g| (g.scenario.batch, g.nm)).collect::<Vec<_>>(),
            vec![(1, 7), (1, 22), (4, 7), (4, 22)]
        );
        for (i, g) in grid.iter().enumerate() {
            assert_eq!(g.grid_index, i);
        }
        // curriculum runs the largest batch first, nodes in config order
        let order = curriculum(&grid);
        assert_eq!(
            order.iter().map(|&i| (grid[i].scenario.batch, grid[i].nm)).collect::<Vec<_>>(),
            vec![(4, 7), (4, 22), (1, 7), (1, 22)]
        );
        // prune settings never move seeds: derived from stream_index only
        let s_on = point_seeds(&cfg, &grid[2]);
        let mut cfg_off = cfg.clone();
        cfg_off.atlas.prune = false;
        assert_eq!(s_on, point_seeds(&cfg_off, &grid[2]));
        // the batch axis collapses out of the stream index: (1,7) and
        // (4,7) replay one action stream, (1,22)/(4,22) another
        assert_eq!(grid[0].stream_index, grid[2].stream_index);
        assert_eq!(grid[1].stream_index, grid[3].stream_index);
        assert_ne!(grid[0].stream_index, grid[1].stream_index);
        assert_eq!(point_seeds(&cfg, &grid[0]), point_seeds(&cfg, &grid[2]));
        assert_ne!(point_seeds(&cfg, &grid[0]), point_seeds(&cfg, &grid[1]));
    }

    #[test]
    fn batch_axis_amortization_dominates() {
        // the batch=4 point's envelope must weakly dominate batch=1 at
        // the same (workload, node, phase, seq) — the edge the curriculum
        // and the amortization prune path are built on
        let cfg = tiny_atlas_cfg();
        let grid = enumerate_grid(&cfg).unwrap();
        let (p1, p4) = (&grid[0], &grid[1]);
        assert_eq!((p1.scenario.batch, p4.scenario.batch), (1, 4));
        let ev1 = Evaluator::new(&point_cfg(&cfg, p1).unwrap(), p1.nm);
        let ev4 = Evaluator::new(&point_cfg(&cfg, p4).unwrap(), p4.nm);
        let (e1, e4) = (ev1.roofline_envelope(), ev4.roofline_envelope());
        assert!(e4.dominates_envelope(&e1));
        let (c1, c4) = (ev1.scenario_constants(), ev4.scenario_constants());
        assert_eq!(c1.0.to_bits(), c4.0.to_bits());
        assert_eq!(c1.2.to_bits(), c4.2.to_bits());
        assert!(c1.1 >= c4.1);
        // so a solved batch=4 point prunes batch=1 via the amortized path
        let solved = vec![Solved {
            grid_index: p4.grid_index,
            workload: p4.workload.clone(),
            nm: p4.nm,
            scenario: p4.scenario,
            envelope: e4,
            constants: c4,
            frontier: {
                let mut a = ParetoArchive::new();
                a.insert(ParetoPoint {
                    perf_gops: 1.0,
                    power_mw: 1.0,
                    area_mm2: 1.0,
                    tokens_per_s: 1.0,
                    episode: 0,
                    tag: 0,
                });
                a
            },
        }];
        let hit = find_dominator(p1, &e1, c1, &solved);
        assert_eq!(hit, Some((p4.grid_index, PruneKind::Amortized)));
        // but never across nodes
        let mut other = grid[0].clone();
        other.nm = 22;
        assert!(find_dominator(&other, &e1, c1, &solved).is_none());
    }

    #[test]
    fn energy_frontier_merge_is_deterministic() {
        let p = |perf: f64, tok: f64, power: f64, area: f64| ParetoPoint {
            perf_gops: perf,
            power_mw: power,
            area_mm2: area,
            tokens_per_s: tok,
            episode: 0,
            tag: 0,
        };
        let mut front = Vec::new();
        energy_insert(&mut front, p(100.0, 100.0, 50.0, 10.0)); // 0.5 mJ/tok
        energy_insert(&mut front, p(100.0, 100.0, 50.0, 10.0)); // exact tie: rejected
        assert_eq!(front.len(), 1);
        energy_insert(&mut front, p(100.0, 200.0, 50.0, 10.0)); // 0.25 mJ/tok: evicts
        assert_eq!(front.len(), 1);
        assert!((front[0].energy_mj_per_token() - 0.25).abs() < 1e-12);
        energy_insert(&mut front, p(50.0, 400.0, 50.0, 10.0)); // trade-off: kept
        assert_eq!(front.len(), 2);
    }
}
