//! Algorithm 1 — the unified RL-based hardware-aware compilation loop.
//!
//! Per node: encode → ε-greedy action (uniform | SAC policy, MPC-refined
//! during exploitation) → constrained projection → mesh/TCC update →
//! operator partitioning → PPA reward → PER store → SAC + world-model +
//! surrogate updates → ε decay → Pareto archive → best tracking.

use anyhow::Result;

use crate::config::RunConfig;
use crate::env::{state, Action, Env, EvalOutcome};
use crate::nn::policy;
use crate::rl::agent::SacAgent;
use crate::rl::explore::EpsSchedule;
use crate::rl::pareto::{ParetoArchive, ParetoPoint};
use crate::rl::per::Transition;
use crate::util::Rng;

/// Per-episode log row (Fig 3 convergence trace + report inputs).
#[derive(Debug, Clone)]
pub struct EpisodeLog {
    pub episode: usize,
    pub reward: f64,
    pub score: f64,
    pub best_score: f64,
    pub feasible: bool,
    pub tokens_per_s: f64,
    pub power_mw: f64,
    pub perf_gops: f64,
    pub area_mm2: f64,
    pub mesh_w: u32,
    pub mesh_h: u32,
    pub eps: f64,
    pub entropy: f64,
    pub unique_configs: usize,
}

/// Best configuration found for one node (Table 10/11 row).
#[derive(Debug, Clone)]
pub struct BestConfig {
    pub episode: usize,
    pub outcome: EvalOutcome,
}

/// Result of optimizing one process node.
pub struct NodeResult {
    pub nm: u32,
    pub best: Option<BestConfig>,
    pub episodes: Vec<EpisodeLog>,
    pub pareto: ParetoArchive,
    pub feasible_count: usize,
    pub total_episodes: usize,
}

impl NodeResult {
    pub fn best_outcome(&self) -> &EvalOutcome {
        &self.best.as_ref().expect("no feasible configuration found").outcome
    }
}

/// Configuration fingerprint for the unique-configs trace (Fig 3).
fn config_key(out: &EvalOutcome) -> u64 {
    let d = &out.decoded;
    let mut h: u64 = 0xcbf29ce484222325;
    let mut mix = |v: u64| {
        h ^= v;
        h = h.wrapping_mul(0x100000001b3);
    };
    mix(d.mesh.width as u64);
    mix(d.mesh.height as u64);
    mix(d.avg.fetch as u64);
    mix(d.avg.stanum as u64);
    mix(d.avg.vlen_bits as u64);
    mix(d.avg.dmem_kb as u64);
    mix(d.avg.dflit_bits as u64);
    mix((d.avg.clock_mhz * 10.0) as u64);
    h
}

/// Run Algorithm 1 for one node with the SAC agent.
pub fn run_node(
    cfg: &RunConfig,
    nm: u32,
    agent: &mut SacAgent,
    rng: &mut Rng,
) -> Result<NodeResult> {
    let mut env = Env::new(cfg, nm);
    let rl = &cfg.rl;
    let mut eps = EpsSchedule::new(rl.eps0, rl.eps_min, rl.episodes_per_node);

    // bootstrap: evaluate the neutral action to get s₀
    let mut prev = env.eval_action(&Action::neutral());
    let mut s = state::sac_subset(&prev.full_state);

    let mut pareto = ParetoArchive::new();
    let mut episodes = Vec::with_capacity(rl.episodes_per_node);
    let mut best: Option<BestConfig> = None;
    let mut best_score = f64::INFINITY;
    let mut feasible_count = 0usize;
    let mut seen = std::collections::HashSet::new();

    for t in 0..rl.episodes_per_node {
        // ---- action selection (Algorithm 1 line 6)
        let action = if rng.uniform() < eps.eps {
            policy::uniform_action(rng)
        } else {
            let a = agent.act(&s, true, rng)?;
            if eps.eps < rl.mpc_eps_gate {
                agent.mpc_refine(&s, &a, rng)? // line 14
            } else {
                a
            }
        };

        // ---- evaluate (projection Π + partition + PPA + reward)
        let out = env.eval_action(&action);
        let s2 = state::sac_subset(&out.full_state);

        // ---- store transition
        let a_cont: [f32; 30] = std::array::from_fn(|i| action.cont[i] as f32);
        let a_disc = policy::onehot_from_deltas(&action.deltas);
        agent.push_transition(Transition {
            s,
            a_cont,
            a_disc,
            r: out.reward.total as f32,
            s2,
            done: 0.0,
            ppa: [
                out.reward.p_power as f32,
                out.reward.p_norm as f32,
                out.reward.a_norm as f32,
            ],
        });

        // ---- learning (after warmup)
        if agent.buffer.len() >= rl.warmup_steps.max(agent_batch(agent)) {
            agent.update(rng)?;
            if t % rl.wm_train_every == 0 {
                agent.train_world_model(rng)?;
            }
            if t % rl.sur_train_every == 0 {
                agent.train_surrogate(rng)?;
            }
        }

        // ---- bookkeeping
        if out.reward.feasible {
            feasible_count += 1;
            pareto.insert(ParetoPoint {
                perf_gops: out.ppa.perf_gops,
                power_mw: out.ppa.power.total(),
                area_mm2: out.ppa.area.total(),
                tokens_per_s: out.ppa.tokens_per_s,
                episode: t,
                tag: t,
            });
            if out.reward.score < best_score {
                best_score = out.reward.score;
                best = Some(BestConfig { episode: t, outcome: out.clone() });
            }
        }
        seen.insert(config_key(&out));
        eps.step(feasible_count > 0);

        episodes.push(EpisodeLog {
            episode: t,
            reward: out.reward.total,
            score: out.reward.score,
            best_score,
            feasible: out.reward.feasible,
            tokens_per_s: out.ppa.tokens_per_s,
            power_mw: out.ppa.power.total(),
            perf_gops: out.ppa.perf_gops,
            area_mm2: out.ppa.area.total(),
            mesh_w: out.decoded.mesh.width,
            mesh_h: out.decoded.mesh.height,
            eps: eps.eps,
            entropy: agent.last_entropy,
            unique_configs: seen.len(),
        });

        prev = out;
        s = s2;
    }
    let _ = prev;

    Ok(NodeResult {
        nm,
        best,
        episodes,
        pareto,
        feasible_count,
        total_episodes: rl.episodes_per_node,
    })
}

fn agent_batch(agent: &SacAgent) -> usize {
    agent.runtime.manifest.hyper_or("batch", 256.0) as usize
}

#[cfg(test)]
mod tests {
    // run_node requires compiled artifacts; exercised by
    // rust/tests/runtime_e2e.rs and the benches.
}
