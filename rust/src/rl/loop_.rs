//! Algorithm 1 — the unified RL-based hardware-aware compilation loop.
//!
//! Per node: encode → ε-greedy action (uniform | SAC policy, MPC-refined
//! during exploitation) → constrained projection → mesh/TCC update →
//! operator partitioning → PPA reward → PER store → SAC + world-model +
//! surrogate updates → ε decay → Pareto archive → best tracking.
//!
//! Evaluation goes through the stateless [`Evaluator`] with a
//! fingerprint-keyed [`EvalCache`]: revisited design points replay their
//! memoized outcome instead of re-running the ~10 ms pipeline, and the
//! MPC refinement re-ranks its candidate set with real (parallel)
//! evaluations instead of trusting the surrogate alone.

use crate::config::RunConfig;
use crate::env::{state, Action};
use crate::error::Result;
use crate::eval::{config_key, EvalCache, EvalOutcome, EvalScratch, EvalStats, Evaluator};
use crate::nn::policy;
use crate::rl::agent::SacAgent;
use crate::rl::explore::EpsSchedule;
use crate::rl::pareto::{ParetoArchive, ParetoPoint};
use crate::rl::per::Transition;
use crate::util::Rng;

/// Per-episode log row (Fig 3 convergence trace + report inputs).
#[derive(Debug, Clone)]
pub struct EpisodeLog {
    pub episode: usize,
    pub reward: f64,
    pub score: f64,
    pub best_score: f64,
    pub feasible: bool,
    pub tokens_per_s: f64,
    pub power_mw: f64,
    pub perf_gops: f64,
    pub area_mm2: f64,
    pub mesh_w: u32,
    pub mesh_h: u32,
    pub eps: f64,
    pub entropy: f64,
    pub unique_configs: usize,
}

/// Best configuration found for one node (Table 10/11 row).
#[derive(Debug, Clone)]
pub struct BestConfig {
    pub episode: usize,
    pub outcome: EvalOutcome,
}

/// Result of optimizing one process node.
pub struct NodeResult {
    pub nm: u32,
    pub best: Option<BestConfig>,
    pub episodes: Vec<EpisodeLog>,
    pub pareto: ParetoArchive,
    pub feasible_count: usize,
    pub total_episodes: usize,
    /// Evaluation-layer counters (memo caches + admission pruning) for
    /// the run report.
    pub eval_stats: EvalStats,
    /// Reproduction recipe for `best`: the pre-step mesh and the action
    /// that produced it. The checkpoint codec serializes this instead of
    /// the full [`EvalOutcome`] and re-evaluates on resume — the
    /// evaluator is pure, so the recomputed outcome is bit-identical
    /// (`None` for the baseline searches, which never checkpoint).
    pub best_repro: Option<(crate::arch::MeshConfig, Action)>,
}

impl NodeResult {
    pub fn best_outcome(&self) -> &EvalOutcome {
        &self.best.as_ref().expect("no feasible configuration found").outcome
    }
}

/// Shared episode bookkeeping: Pareto archive, best tracking, unique
/// configs, per-episode log rows. Used by both the SAC loop and the
/// baseline searches so their reductions are identical (and, for the
/// batched baselines, deterministic in input order).
pub(crate) struct EpisodeTracker {
    pub pareto: ParetoArchive,
    pub episodes: Vec<EpisodeLog>,
    pub best: Option<BestConfig>,
    pub best_score: f64,
    pub feasible_count: usize,
    pub seen: std::collections::HashSet<u64>,
    /// (pre-step mesh, action) behind `best` — set by drivers that
    /// checkpoint (see [`NodeResult::best_repro`]).
    pub best_repro: Option<(crate::arch::MeshConfig, Action)>,
}

impl EpisodeTracker {
    pub fn new(capacity: usize) -> Self {
        EpisodeTracker {
            pareto: ParetoArchive::new(),
            episodes: Vec::with_capacity(capacity),
            best: None,
            best_score: f64::INFINITY,
            feasible_count: 0,
            seen: std::collections::HashSet::new(),
            best_repro: None,
        }
    }

    /// Record one evaluated episode; `eps`/`entropy` are the exploration
    /// trace values for the log row. Returns true when this episode
    /// became the new best (so checkpointing drivers can stash the
    /// (mesh, action) reproduction recipe alongside).
    pub fn record(&mut self, t: usize, out: &EvalOutcome, eps: f64, entropy: f64) -> bool {
        let mut became_best = false;
        if out.reward.feasible {
            self.feasible_count += 1;
            self.pareto.insert(ParetoPoint {
                perf_gops: out.ppa.perf_gops,
                power_mw: out.ppa.power.total(),
                area_mm2: out.ppa.area.total(),
                tokens_per_s: out.ppa.tokens_per_s,
                episode: t,
                tag: t,
            });
            if out.reward.score < self.best_score {
                self.best_score = out.reward.score;
                self.best = Some(BestConfig { episode: t, outcome: out.clone() });
                became_best = true;
            }
        }
        self.seen.insert(config_key(out));
        self.episodes.push(EpisodeLog {
            episode: t,
            reward: out.reward.total,
            score: out.reward.score,
            best_score: self.best_score,
            feasible: out.reward.feasible,
            tokens_per_s: out.ppa.tokens_per_s,
            power_mw: out.ppa.power.total(),
            perf_gops: out.ppa.perf_gops,
            area_mm2: out.ppa.area.total(),
            mesh_w: out.decoded.mesh.width,
            mesh_h: out.decoded.mesh.height,
            eps,
            entropy,
            unique_configs: self.seen.len(),
        });
        became_best
    }

    pub fn finish(self, nm: u32, total_episodes: usize) -> NodeResult {
        NodeResult {
            nm,
            best: self.best,
            episodes: self.episodes,
            pareto: self.pareto,
            feasible_count: self.feasible_count,
            total_episodes,
            eval_stats: EvalStats::default(),
            best_repro: self.best_repro,
        }
    }
}

/// Marshal one evaluated episode into a replay [`Transition`] — shared by
/// the serial loop and the vec-env so a lane's stored transitions are
/// field-for-field the ones a serial run would store.
pub(crate) fn make_transition(
    s: [f32; crate::env::SAC_STATE_DIM],
    action: &Action,
    out: &crate::eval::EvalOutcome,
    s2: [f32; crate::env::SAC_STATE_DIM],
) -> Transition {
    let a_cont: [f32; 30] = std::array::from_fn(|i| action.cont[i] as f32);
    let a_disc = policy::onehot_from_deltas(&action.deltas);
    Transition {
        s,
        a_cont,
        a_disc,
        r: out.reward.total as f32,
        s2,
        done: 0.0,
        ppa: [
            out.reward.p_power as f32,
            out.reward.p_norm as f32,
            out.reward.a_norm as f32,
        ],
    }
}

/// What one [`update_tick`] actually ran — the pinned learner uses this
/// to keep its counters and decide whether a new parameter snapshot must
/// be published.
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct TickOutcome {
    /// The warmup gate was open: one SAC update ran.
    pub ran: bool,
    /// The world model trained this tick (`t % wm_train_every == 0`).
    pub wm: bool,
    /// The surrogate heads trained this tick (`t % sur_train_every == 0`).
    pub sur: bool,
}

/// Algorithm 1's post-store learning gate, shared verbatim by the serial
/// loop, the vec-env's inline driver and the pinned learner thread
/// (DESIGN.md §11): once the replay buffer covers `max(warmup_steps,
/// minibatch)`, one SAC update per step plus world-model / surrogate
/// updates at their per-step cadences, all drawing from `rng` in this
/// exact order. Keeping the schedule in one function is what makes the
/// pinned-mode bit-identity contract a structural property instead of a
/// convention.
pub(crate) fn update_tick(
    agent: &mut SacAgent,
    rl: crate::config::RlConfig,
    t: usize,
    rng: &mut Rng,
) -> Result<TickOutcome> {
    if agent.buffer.len() < rl.warmup_steps.max(agent.batch()) {
        return Ok(TickOutcome::default());
    }
    let mut tick = TickOutcome { ran: true, wm: false, sur: false };
    agent.update(rng)?;
    if t % rl.wm_train_every == 0 {
        agent.train_world_model(rng)?;
        tick.wm = true;
    }
    if t % rl.sur_train_every == 0 {
        agent.train_surrogate(rng)?;
        tick.sur = true;
    }
    Ok(tick)
}

/// Run Algorithm 1 for one node with the SAC agent.
pub fn run_node(
    cfg: &RunConfig,
    nm: u32,
    agent: &mut SacAgent,
    rng: &mut Rng,
) -> Result<NodeResult> {
    let eval = Evaluator::new(cfg, nm);
    let mut mesh = eval.initial_mesh();
    let mut scratch = EvalScratch::default();
    let mut cache = EvalCache::new(cfg.rl.eval_cache);
    let rl = &cfg.rl;
    let mut eps = EpsSchedule::new(rl.eps0, rl.eps_min, rl.episodes_per_node);

    // bootstrap: evaluate the neutral action to get s₀
    let prev = cache.evaluate(&eval, &mesh, &Action::neutral(), &mut scratch);
    mesh = prev.decoded.mesh;
    let mut s = state::sac_subset(&prev.full_state);

    let mut tracker = EpisodeTracker::new(rl.episodes_per_node);

    for t in 0..rl.episodes_per_node {
        // ---- action selection (Algorithm 1 line 6)
        let action = if rng.uniform() < eps.eps {
            policy::uniform_action(rng)
        } else {
            let a = agent.act(&s, true, rng)?;
            if eps.eps < rl.mpc_eps_gate {
                agent.mpc_refine(&s, &a, Some((&eval, &mesh)), rng)? // line 14
            } else {
                a
            }
        };

        // ---- evaluate (projection Π + partition + PPA + reward), walk
        let mesh_before = mesh;
        let out = cache.evaluate(&eval, &mesh, &action, &mut scratch);
        mesh = out.decoded.mesh;
        let s2 = state::sac_subset(&out.full_state);

        // ---- store transition
        agent.push_transition(make_transition(s, &action, &out, s2));

        // ---- learning (after warmup; schedule shared with the vec-env
        // and the pinned learner)
        update_tick(agent, *rl, t, rng)?;

        // ---- bookkeeping
        eps.step(tracker.feasible_count > 0 || out.reward.feasible);
        if tracker.record(t, &out, eps.eps, agent.last_entropy) {
            tracker.best_repro = Some((mesh_before, action.clone()));
        }

        s = s2;
    }

    let mut result = tracker.finish(nm, rl.episodes_per_node);
    result.eval_stats.absorb_outcome_cache(&cache);
    result.eval_stats.absorb_scratch(&scratch);
    result.eval_stats.merge(&agent.take_eval_stats());
    Ok(result)
}

#[cfg(test)]
mod tests {
    // run_node over the artifact-free native backend is exercised by
    // rust/tests/native_backend.rs (short runs, seed determinism); the
    // PJRT path by rust/tests/runtime_e2e.rs when artifacts are built.
    // The evaluation layer it drives is covered in eval::* and
    // tests/eval_parallel.rs.
}
