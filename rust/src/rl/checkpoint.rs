//! Crash-safe checkpoint/resume for long optimize and atlas runs
//! (DESIGN.md §13).
//!
//! The subsystem periodically snapshots the *complete* search state —
//! parameter [`Store`], PER buffer contents + priorities, Pareto
//! frontiers, per-lane RNG stream positions, step/episode counters and
//! (for the atlas) grid progress — so a run killed at an arbitrary step
//! boundary resumes and produces episode logs and frontiers bit-identical
//! to the uninterrupted run.
//!
//! Storage is a double-slot generation scheme in `<out_dir>/ckpt`:
//! `ckpt-a.bin` / `ckpt-b.bin`, alternating by sequence number, each an
//! atomically-committed sealed record ([`fsio::seal_record`]) whose
//! payload opens with the sequence number and a run-configuration
//! fingerprint. The loader picks the highest-sequence parseable slot; a
//! torn or corrupted newest slot falls back to the previous generation,
//! and a valid-but-foreign fingerprint is a hard error rather than a
//! silent wrong-run resume.
//!
//! Fault injection rides alongside: `crash_after=<N>` arms a
//! [`FaultPlan`] whose probes sit at the step boundaries a real crash
//! would hit — top-of-step, mid-wave after the env fan-out, and after
//! the replay insert/send (when the async learner queue is non-empty).

use std::io;
use std::path::{Path, PathBuf};

use crate::arch::MeshConfig;
use crate::config::{RlConfig, RunConfig};
use crate::env::Action;
use crate::error::Result;
use crate::eval::{EvalScratch, EvalStats, Evaluator};
use crate::ir::spec::Phase;
use crate::nn::Store;
use crate::rl::agent::SacAgent;
use crate::rl::explore::EpsSchedule;
use crate::rl::loop_::{BestConfig, EpisodeLog, EpisodeTracker, NodeResult};
use crate::rl::pareto::{ParetoArchive, ParetoPoint};
use crate::rl::per::{PerBuffer, PerState, Transition};
use crate::rl::vecenv::LaneSpec;
use crate::util::fsio::{self, ByteReader, ByteWriter};
use crate::util::rng::RngState;

/// Record kind tag for vec-env (optimize / seeds) checkpoints.
pub const KIND_VEC: u8 = 1;
/// Record kind tag for atlas sweep checkpoints.
pub const KIND_ATLAS: u8 = 2;

/// Error-message prefix of an injected crash; the fault-injection tests
/// and the CI kill-and-resume smoke match on it to tell a planned kill
/// from a real failure.
pub const INJECTED_CRASH_MSG: &str = "injected crash (crash_after)";

// ---------------------------------------------------------------------------
// fault injection

/// Deterministic kill switch: `crash_after=<N>` trips the N-th probe.
/// Probes are placed at the boundaries a real crash would hit and the
/// counter is cumulative across waves and atlas points, so N sweeps the
/// whole space of interruption points as it grows.
#[derive(Debug, Clone, Copy, Default)]
pub struct FaultPlan {
    crash_after: u64,
    hits: u64,
}

impl FaultPlan {
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn new(crash_after: u64) -> FaultPlan {
        FaultPlan { crash_after, hits: 0 }
    }

    /// Count one crash site; error out when the plan says to die here.
    pub fn probe(&mut self) -> Result<()> {
        if self.crash_after == 0 {
            return Ok(());
        }
        self.hits += 1;
        if self.hits >= self.crash_after {
            crate::bail!("{INJECTED_CRASH_MSG} at probe {}", self.hits);
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// double-slot generation store

/// A checkpoint directory holding two alternating generation slots.
pub struct CheckpointDir {
    dir: PathBuf,
    seq: u64,
}

impl CheckpointDir {
    fn slot_paths(dir: &Path) -> [PathBuf; 2] {
        [dir.join("ckpt-a.bin"), dir.join("ckpt-b.bin")]
    }

    /// Open (creating if needed) a checkpoint directory for writing; the
    /// next sequence number continues past whatever valid generations are
    /// already present, so an in-place resume never overwrites the
    /// generation it was restored from on its first save.
    pub fn create(dir: impl Into<PathBuf>) -> io::Result<CheckpointDir> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        let mut seq = 0;
        for p in Self::slot_paths(&dir) {
            if let Ok(Some((s, ..))) = Self::read_slot(&p) {
                seq = seq.max(s + 1);
            }
        }
        Ok(CheckpointDir { dir, seq })
    }

    /// Parse one slot: `Ok(None)` when absent, `Err` when torn/corrupt,
    /// else `(seq, fingerprint, kind, payload)`.
    fn read_slot(path: &Path) -> io::Result<Option<(u64, u64, u8, Vec<u8>)>> {
        let bytes = match std::fs::read(path) {
            Ok(b) => b,
            Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(e),
        };
        let (kind, payload) = fsio::open_record(&bytes)?;
        let mut rd = ByteReader::new(payload);
        let seq = rd.u64()?;
        let fp = rd.u64()?;
        Ok(Some((seq, fp, kind, payload[16..].to_vec())))
    }

    /// Commit one generation: seal `(seq, fingerprint, payload)` and
    /// atomically replace the slot `seq` alternates onto. The previous
    /// generation lives in the other slot until the *next* save, which is
    /// what makes a crash mid-commit recoverable.
    pub fn save(&mut self, kind: u8, fingerprint: u64, payload: &[u8]) -> io::Result<()> {
        let mut w = ByteWriter::new();
        w.u64(self.seq);
        w.u64(fingerprint);
        w.buf.extend_from_slice(payload);
        let rec = fsio::seal_record(kind, &w.buf);
        let slot = Self::slot_paths(&self.dir)[(self.seq % 2) as usize].clone();
        fsio::atomic_write(&slot, &rec)?;
        self.seq += 1;
        Ok(())
    }

    /// Load the newest valid generation of `kind`. Corrupt or truncated
    /// slots are skipped with a note (falling back to the previous
    /// generation); a valid newest slot whose fingerprint does not match
    /// is a hard error; no parseable slot at all is `Ok(None)` (fresh
    /// start).
    pub fn load(dir: &Path, kind: u8, fingerprint: u64) -> Result<Option<(u64, Vec<u8>)>> {
        let mut newest: Option<(u64, u64, Vec<u8>)> = None;
        for p in Self::slot_paths(dir) {
            match Self::read_slot(&p) {
                Ok(Some((seq, fp, k, payload))) if k == kind => {
                    if newest.as_ref().map_or(true, |(s, ..)| seq > *s) {
                        newest = Some((seq, fp, payload));
                    }
                }
                Ok(_) => {}
                Err(e) => {
                    eprintln!("note: skipping corrupt checkpoint slot {}: {e}", p.display());
                }
            }
        }
        match newest {
            Some((seq, fp, payload)) => {
                if fp != fingerprint {
                    crate::bail!(
                        "checkpoint in {} was written by a different run configuration \
                         (fingerprint {fp:#018x}, expected {fingerprint:#018x}); \
                         refusing to resume",
                        dir.display()
                    );
                }
                Ok(Some((seq, payload)))
            }
            None => Ok(None),
        }
    }
}

/// `resume=<dir>` accepts either the run's out dir or its `ckpt` subdir.
pub fn resolve_resume_dir(spec: &str) -> PathBuf {
    let p = Path::new(spec);
    let c = p.join("ckpt");
    if c.is_dir() {
        c
    } else {
        p.to_path_buf()
    }
}

/// Fingerprint of everything a vec-env checkpoint's validity depends on:
/// seed, episode/warmup/replay shape, scenario, learner mode, lane width
/// and the exact job list. Two runs agree on the fingerprint iff a
/// checkpoint of one is a semantically valid resume point for the other.
pub(crate) fn fingerprint_vec(cfg: &RunConfig, jobs: &[LaneSpec], lanes: usize) -> u64 {
    let mut w = ByteWriter::new();
    w.str("vec");
    w.u64(cfg.seed);
    w.usize(cfg.rl.episodes_per_node);
    w.usize(cfg.rl.warmup_steps);
    w.usize(cfg.rl.buffer_capacity);
    w.str(cfg.workload.name());
    let scn = cfg.scenario();
    w.u8(match scn.phase {
        Phase::Prefill => 0,
        Phase::Decode => 1,
    });
    w.u32(scn.seq_len);
    w.u32(scn.batch);
    w.str(cfg.rl.learner.name());
    w.usize(lanes);
    w.usize(jobs.len());
    for j in jobs {
        w.u32(j.nm);
        w.u64(j.seed);
    }
    fsio::fnv1a64(&w.buf)
}

// ---------------------------------------------------------------------------
// run context threaded through the drivers

/// Periodic-save half of a [`RunCtx`].
pub(crate) struct CheckpointSink {
    dir: CheckpointDir,
    pub every: usize,
    fingerprint: u64,
}

/// Everything the robustness layer threads through a driver: the fault
/// plan (shared across waves and atlas points so probe counts are
/// cumulative), the optional periodic-save sink, and the decoded-pending
/// resume payload.
pub(crate) struct RunCtx {
    pub fault: FaultPlan,
    pub sink: Option<CheckpointSink>,
    pub resume: Option<Vec<u8>>,
    skip_noted: bool,
}

impl RunCtx {
    /// A context that neither checkpoints nor injects faults — the
    /// default for short runs and for callers that manage their own
    /// checkpointing (the atlas passes this to its inner vec-env calls).
    pub fn passthrough() -> RunCtx {
        RunCtx { fault: FaultPlan::none(), sink: None, resume: None, skip_noted: false }
    }

    /// Build the context for a vec-env run from the config's robustness
    /// keys: arm `crash_after`, open the save sink when
    /// `checkpoint_every > 0`, and load the newest valid generation when
    /// `resume=` is set (a missing/unusable checkpoint starts fresh with
    /// a note; a fingerprint mismatch is a hard error).
    pub fn for_vec(cfg: &RunConfig, jobs: &[LaneSpec], lanes: usize) -> Result<RunCtx> {
        let fp = fingerprint_vec(cfg, jobs, lanes);
        let mut ctx = RunCtx::passthrough();
        ctx.fault = FaultPlan::new(cfg.rl.crash_after);
        if let Some(spec) = &cfg.resume {
            let dir = resolve_resume_dir(spec);
            match CheckpointDir::load(&dir, KIND_VEC, fp)? {
                Some((seq, payload)) => {
                    eprintln!(
                        "note: resuming from checkpoint generation {seq} in {}",
                        dir.display()
                    );
                    ctx.resume = Some(payload);
                }
                None => {
                    eprintln!("note: no usable checkpoint in {}; starting fresh", dir.display());
                }
            }
        }
        if cfg.rl.checkpoint_every > 0 {
            let dir = Path::new(&cfg.out_dir).join("ckpt");
            ctx.sink = Some(CheckpointSink {
                dir: CheckpointDir::create(dir)?,
                every: cfg.rl.checkpoint_every,
                fingerprint: fp,
            });
        }
        Ok(ctx)
    }

    /// Periodic-save predicate. Skips `t == t0`: the step a resume
    /// restarts on was already saved by the interrupted run, and saving
    /// it again would shift the generation parity between the resumed and
    /// uninterrupted timelines.
    pub fn should_save(&self, t: usize, t0: usize) -> bool {
        self.sink.as_ref().is_some_and(|s| t > 0 && t != t0 && t % s.every == 0)
    }

    /// Commit one generation through the sink. Save failures (disk full,
    /// permissions) warn and keep running — losing checkpoint coverage is
    /// strictly better than losing the search.
    pub fn save(&mut self, kind: u8, payload: &[u8]) {
        if let Some(s) = &mut self.sink {
            if let Err(e) = s.dir.save(kind, s.fingerprint, payload) {
                eprintln!("warning: checkpoint save failed: {e} (run continues)");
            }
        }
    }

    /// One-time note that checkpointing stopped (degraded learner: the
    /// thread that owned the quiesceable state is gone).
    pub fn note_skip(&mut self) {
        if !self.skip_noted {
            eprintln!(
                "note: learner state unavailable; checkpointing disabled for the rest of the run"
            );
            self.skip_noted = true;
        }
    }
}

// ---------------------------------------------------------------------------
// primitive codecs

fn badfmt(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("checkpoint payload: {msg}"))
}

fn arr<const N: usize>(rd: &mut ByteReader) -> io::Result<[f32; N]> {
    rd.f32s()?.try_into().map_err(|_| badfmt("fixed array length mismatch"))
}

pub(crate) fn write_rng(w: &mut ByteWriter, st: &RngState) {
    for &x in &st.s {
        w.u64(x);
    }
    w.opt_f64(st.gauss_spare);
}

pub(crate) fn read_rng(rd: &mut ByteReader) -> io::Result<RngState> {
    let mut s = [0u64; 4];
    for x in &mut s {
        *x = rd.u64()?;
    }
    Ok(RngState { s, gauss_spare: rd.opt_f64()? })
}

fn write_mesh(w: &mut ByteWriter, m: &MeshConfig) {
    w.u32(m.width);
    w.u32(m.height);
    w.u32(m.sc_x);
    w.u32(m.sc_y);
}

fn read_mesh(rd: &mut ByteReader) -> io::Result<MeshConfig> {
    Ok(MeshConfig { width: rd.u32()?, height: rd.u32()?, sc_x: rd.u32()?, sc_y: rd.u32()? })
}

fn write_action(w: &mut ByteWriter, a: &Action) {
    w.f64s(&a.cont);
    w.usize(a.deltas.len());
    for &d in &a.deltas {
        w.i64(d as i64);
    }
}

fn read_action(rd: &mut ByteReader) -> io::Result<Action> {
    let cont = rd.f64s()?;
    let mut a = Action::neutral();
    if cont.len() != a.cont.len() {
        return Err(badfmt("action cont length"));
    }
    a.cont.copy_from_slice(&cont);
    let n = rd.len(8)?;
    if n != a.deltas.len() {
        return Err(badfmt("action deltas length"));
    }
    for d in a.deltas.iter_mut() {
        *d = rd.i64()? as i32;
    }
    Ok(a)
}

fn write_eps(w: &mut ByteWriter, e: &EpsSchedule) {
    w.f64(e.eps);
    w.f64(e.eps_min);
    w.f64(e.d);
}

fn read_eps(rd: &mut ByteReader) -> io::Result<EpsSchedule> {
    Ok(EpsSchedule { eps: rd.f64()?, eps_min: rd.f64()?, d: rd.f64()? })
}

pub(crate) fn write_stats(w: &mut ByteWriter, s: &EvalStats) {
    for v in [
        s.outcome_hits,
        s.outcome_misses,
        s.outcome_evictions,
        s.place_hits,
        s.place_misses,
        s.place_evictions,
        s.geom_hits,
        s.geom_misses,
        s.geom_shared,
        s.pruned,
        s.evaluated,
    ] {
        w.u64(v);
    }
}

pub(crate) fn read_stats(rd: &mut ByteReader) -> io::Result<EvalStats> {
    Ok(EvalStats {
        outcome_hits: rd.u64()?,
        outcome_misses: rd.u64()?,
        outcome_evictions: rd.u64()?,
        place_hits: rd.u64()?,
        place_misses: rd.u64()?,
        place_evictions: rd.u64()?,
        geom_hits: rd.u64()?,
        geom_misses: rd.u64()?,
        geom_shared: rd.u64()?,
        pruned: rd.u64()?,
        evaluated: rd.u64()?,
    })
}

pub(crate) fn write_point(w: &mut ByteWriter, p: &ParetoPoint) {
    w.f64(p.perf_gops);
    w.f64(p.power_mw);
    w.f64(p.area_mm2);
    w.f64(p.tokens_per_s);
    w.usize(p.episode);
    w.usize(p.tag);
}

pub(crate) fn read_point(rd: &mut ByteReader) -> io::Result<ParetoPoint> {
    Ok(ParetoPoint {
        perf_gops: rd.f64()?,
        power_mw: rd.f64()?,
        area_mm2: rd.f64()?,
        tokens_per_s: rd.f64()?,
        episode: rd.usize()?,
        tag: rd.usize()?,
    })
}

fn write_episode(w: &mut ByteWriter, e: &EpisodeLog) {
    w.usize(e.episode);
    w.f64(e.reward);
    w.f64(e.score);
    w.f64(e.best_score);
    w.bool(e.feasible);
    w.f64(e.tokens_per_s);
    w.f64(e.power_mw);
    w.f64(e.perf_gops);
    w.f64(e.area_mm2);
    w.u32(e.mesh_w);
    w.u32(e.mesh_h);
    w.f64(e.eps);
    w.f64(e.entropy);
    w.usize(e.unique_configs);
}

fn read_episode(rd: &mut ByteReader) -> io::Result<EpisodeLog> {
    Ok(EpisodeLog {
        episode: rd.usize()?,
        reward: rd.f64()?,
        score: rd.f64()?,
        best_score: rd.f64()?,
        feasible: rd.bool()?,
        tokens_per_s: rd.f64()?,
        power_mw: rd.f64()?,
        perf_gops: rd.f64()?,
        area_mm2: rd.f64()?,
        mesh_w: rd.u32()?,
        mesh_h: rd.u32()?,
        eps: rd.f64()?,
        entropy: rd.f64()?,
        unique_configs: rd.usize()?,
    })
}

fn write_transition(w: &mut ByteWriter, t: &Transition) {
    w.f32s(&t.s);
    w.f32s(&t.a_cont);
    w.f32s(&t.a_disc);
    w.f32(t.r);
    w.f32s(&t.s2);
    w.f32(t.done);
    w.f32s(&t.ppa);
}

fn read_transition(rd: &mut ByteReader) -> io::Result<Transition> {
    Ok(Transition {
        s: arr(rd)?,
        a_cont: arr(rd)?,
        a_disc: arr(rd)?,
        r: rd.f32()?,
        s2: arr(rd)?,
        done: rd.f32()?,
        ppa: arr(rd)?,
    })
}

pub(crate) fn write_per(w: &mut ByteWriter, st: &PerState) {
    w.usize(st.data.len());
    for t in &st.data {
        write_transition(w, t);
    }
    w.usize(st.write);
    w.f64s(&st.priorities);
    w.f64(st.max_priority);
    w.f64(st.beta);
}

pub(crate) fn read_per(rd: &mut ByteReader) -> io::Result<PerState> {
    let n = rd.len(16)?;
    let mut data = Vec::with_capacity(n);
    for _ in 0..n {
        data.push(read_transition(rd)?);
    }
    Ok(PerState {
        data,
        write: rd.usize()?,
        priorities: rd.f64s()?,
        max_priority: rd.f64()?,
        beta: rd.f64()?,
    })
}

// ---------------------------------------------------------------------------
// composite codecs: tracker, node result, agent, learner state

/// The tracker serializes its best configuration as a *reproduction
/// recipe* — `(episode, pre-step mesh, action)` — rather than the full
/// [`EvalOutcome`]. The evaluator is pure, so re-evaluating the recipe on
/// decode rebuilds the outcome bit-identically at a fraction of the
/// snapshot size.
fn write_tracker(w: &mut ByteWriter, tr: &EpisodeTracker) {
    w.usize(tr.episodes.len());
    for e in &tr.episodes {
        write_episode(w, e);
    }
    w.usize(tr.pareto.frontier().len());
    for p in tr.pareto.frontier() {
        write_point(w, p);
    }
    w.f64(tr.best_score);
    w.usize(tr.feasible_count);
    let mut seen: Vec<u64> = tr.seen.iter().copied().collect();
    seen.sort_unstable();
    w.usize(seen.len());
    for k in seen {
        w.u64(k);
    }
    debug_assert_eq!(tr.best.is_some(), tr.best_repro.is_some());
    match (&tr.best, &tr.best_repro) {
        (Some(b), Some((mesh, action))) => {
            w.bool(true);
            w.usize(b.episode);
            write_mesh(w, mesh);
            write_action(w, action);
        }
        _ => w.bool(false),
    }
}

fn read_tracker(rd: &mut ByteReader, cfg: &RunConfig, nm: u32) -> Result<EpisodeTracker> {
    let ne = rd.len(1)?;
    let mut episodes = Vec::with_capacity(ne);
    for _ in 0..ne {
        episodes.push(read_episode(rd)?);
    }
    let np = rd.len(1)?;
    let mut points = Vec::with_capacity(np);
    for _ in 0..np {
        points.push(read_point(rd)?);
    }
    let best_score = rd.f64()?;
    let feasible_count = rd.usize()?;
    let ns = rd.len(8)?;
    let mut seen = std::collections::HashSet::with_capacity(ns);
    for _ in 0..ns {
        seen.insert(rd.u64()?);
    }
    let (best, best_repro) = if rd.bool()? {
        let episode = rd.usize()?;
        let mesh = read_mesh(rd)?;
        let action = read_action(rd)?;
        let ev = Evaluator::new(cfg, nm);
        let outcome = ev.evaluate(&mesh, &action, &mut EvalScratch::default());
        (Some(BestConfig { episode, outcome }), Some((mesh, action)))
    } else {
        (None, None)
    };
    Ok(EpisodeTracker {
        pareto: ParetoArchive::from_points(points),
        episodes,
        best,
        best_score,
        feasible_count,
        seen,
        best_repro,
    })
}

pub(crate) fn write_node_result(w: &mut ByteWriter, nr: &NodeResult) {
    w.u32(nr.nm);
    w.usize(nr.total_episodes);
    w.usize(nr.feasible_count);
    write_stats(w, &nr.eval_stats);
    w.usize(nr.episodes.len());
    for e in &nr.episodes {
        write_episode(w, e);
    }
    w.usize(nr.pareto.frontier().len());
    for p in nr.pareto.frontier() {
        write_point(w, p);
    }
    debug_assert_eq!(nr.best.is_some(), nr.best_repro.is_some());
    match (&nr.best, &nr.best_repro) {
        (Some(b), Some((mesh, action))) => {
            w.bool(true);
            w.usize(b.episode);
            write_mesh(w, mesh);
            write_action(w, action);
        }
        _ => w.bool(false),
    }
}

pub(crate) fn read_node_result(rd: &mut ByteReader, cfg: &RunConfig) -> Result<NodeResult> {
    let nm = rd.u32()?;
    let total_episodes = rd.usize()?;
    let feasible_count = rd.usize()?;
    let eval_stats = read_stats(rd)?;
    let ne = rd.len(1)?;
    let mut episodes = Vec::with_capacity(ne);
    for _ in 0..ne {
        episodes.push(read_episode(rd)?);
    }
    let np = rd.len(1)?;
    let mut points = Vec::with_capacity(np);
    for _ in 0..np {
        points.push(read_point(rd)?);
    }
    let (best, best_repro) = if rd.bool()? {
        let episode = rd.usize()?;
        let mesh = read_mesh(rd)?;
        let action = read_action(rd)?;
        let ev = Evaluator::new(cfg, nm);
        let outcome = ev.evaluate(&mesh, &action, &mut EvalScratch::default());
        (Some(BestConfig { episode, outcome }), Some((mesh, action)))
    } else {
        (None, None)
    };
    Ok(NodeResult {
        nm,
        best,
        episodes,
        pareto: ParetoArchive::from_points(points),
        feasible_count,
        total_episodes,
        eval_stats,
        best_repro,
    })
}

/// Rollout-agent snapshot: parameters, entropy trace, update counters and
/// (inline mode only) the replay buffer. Off-loop modes keep the buffer
/// inside [`LearnerState`] instead — the rollout copy is a placeholder.
pub(crate) fn write_agent(w: &mut ByteWriter, agent: &SacAgent, with_buffer: bool) {
    agent.store.write_to(w);
    w.f64(agent.last_entropy);
    w.usize(agent.updates_done);
    w.bool(agent.wm_trained);
    w.bool(agent.sur_trained);
    w.bool(with_buffer);
    if with_buffer {
        write_per(w, &agent.buffer.export_state());
    }
}

pub(crate) fn read_agent(rd: &mut ByteReader, rl: RlConfig, agent: &mut SacAgent) -> Result<()> {
    let store = Store::read_from(rd)?;
    agent.store = std::sync::Arc::new(store);
    agent.last_entropy = rd.f64()?;
    agent.updates_done = rd.usize()?;
    agent.wm_trained = rd.bool()?;
    agent.sur_trained = rd.bool()?;
    if rd.bool()? {
        let st = read_per(rd)?;
        agent.buffer = PerBuffer::from_state(rl.buffer_capacity, rl.per_alpha, rl.per_beta_step, st);
    }
    Ok(())
}

/// The learner thread's complete quiesced state, captured through the
/// FIFO transition queue so every step sent before the capture request is
/// reflected (see `rl::learner`).
pub struct LearnerState {
    pub store: Store,
    pub per: PerState,
    pub rng: RngState,
    pub updates_done: usize,
    pub wm_trained: bool,
    pub sur_trained: bool,
    pub steps: u64,
    pub sac: u64,
    pub wm: u64,
    pub sur: u64,
    pub snapshots: u64,
    pub version: u64,
}

fn write_learner_state(w: &mut ByteWriter, st: &LearnerState) {
    st.store.write_to(w);
    write_per(w, &st.per);
    write_rng(w, &st.rng);
    w.usize(st.updates_done);
    w.bool(st.wm_trained);
    w.bool(st.sur_trained);
    for v in [st.steps, st.sac, st.wm, st.sur, st.snapshots, st.version] {
        w.u64(v);
    }
}

fn read_learner_state(rd: &mut ByteReader) -> io::Result<LearnerState> {
    Ok(LearnerState {
        store: Store::read_from(rd)?,
        per: read_per(rd)?,
        rng: read_rng(rd)?,
        updates_done: rd.usize()?,
        wm_trained: rd.bool()?,
        sur_trained: rd.bool()?,
        steps: rd.u64()?,
        sac: rd.u64()?,
        wm: rd.u64()?,
        sur: rd.u64()?,
        snapshots: rd.u64()?,
        version: rd.u64()?,
    })
}

/// Update-side state of a vec-env checkpoint: the inline update stream
/// position, or the full quiesced learner-thread state.
pub(crate) enum SinkCkpt {
    Inline { rng: RngState },
    Learner(Box<LearnerState>),
}

fn write_sink(w: &mut ByteWriter, s: &SinkCkpt) {
    match s {
        SinkCkpt::Inline { rng } => {
            w.u8(0);
            write_rng(w, rng);
        }
        SinkCkpt::Learner(st) => {
            w.u8(1);
            write_learner_state(w, st);
        }
    }
}

fn read_sink(rd: &mut ByteReader) -> io::Result<SinkCkpt> {
    match rd.u8()? {
        0 => Ok(SinkCkpt::Inline { rng: read_rng(rd)? }),
        1 => Ok(SinkCkpt::Learner(Box::new(read_learner_state(rd)?))),
        _ => Err(badfmt("unknown sink tag")),
    }
}

// ---------------------------------------------------------------------------
// vec-env checkpoint payload

/// Borrowed view of one live lane at a checkpoint boundary.
pub(crate) struct LaneView<'a> {
    pub nm: u32,
    pub mesh: MeshConfig,
    pub s: &'a [f32; crate::env::SAC_STATE_DIM],
    pub last_entropy: f64,
    pub eps: &'a EpsSchedule,
    pub tracker: &'a EpisodeTracker,
    pub stats: EvalStats,
    pub rng: RngState,
}

/// Owned restore image of one lane.
pub(crate) struct LaneCkpt {
    pub nm: u32,
    pub mesh: MeshConfig,
    pub s: [f32; crate::env::SAC_STATE_DIM],
    pub last_entropy: f64,
    pub eps: EpsSchedule,
    pub tracker: EpisodeTracker,
    pub stats: EvalStats,
    pub rng: RngState,
}

fn write_lane(w: &mut ByteWriter, lv: &LaneView) {
    w.u32(lv.nm);
    write_mesh(w, &lv.mesh);
    w.f32s(lv.s);
    w.f64(lv.last_entropy);
    write_eps(w, lv.eps);
    write_stats(w, &lv.stats);
    write_rng(w, &lv.rng);
    write_tracker(w, lv.tracker);
}

fn read_lane(rd: &mut ByteReader, cfg: &RunConfig) -> Result<LaneCkpt> {
    let nm = rd.u32()?;
    let mesh = read_mesh(rd)?;
    let s = arr(rd)?;
    let last_entropy = rd.f64()?;
    let eps = read_eps(rd)?;
    let stats = read_stats(rd)?;
    let rng = read_rng(rd)?;
    let tracker = read_tracker(rd, cfg, nm)?;
    Ok(LaneCkpt { nm, mesh, s, last_entropy, eps, tracker, stats, rng })
}

/// Decoded vec-env checkpoint: wave/step cursor, completed-wave results,
/// mid-wave lane images and the update-side state. The agent restore
/// (parameters, counters, inline replay buffer) is applied to `agent` by
/// [`decode_vec`] directly.
pub(crate) struct VecCkpt {
    pub wave: usize,
    pub step: usize,
    pub done: Vec<NodeResult>,
    pub lanes: Vec<LaneCkpt>,
    pub sink: SinkCkpt,
}

pub(crate) fn encode_vec(
    wave: usize,
    step: usize,
    agent: &SacAgent,
    with_buffer: bool,
    sink: &SinkCkpt,
    done: &[NodeResult],
    lanes: &[LaneView],
) -> Vec<u8> {
    let mut w = ByteWriter::new();
    write_sink(&mut w, sink);
    write_agent(&mut w, agent, with_buffer);
    w.usize(wave);
    w.usize(step);
    w.usize(done.len());
    for nr in done {
        write_node_result(&mut w, nr);
    }
    w.usize(lanes.len());
    for lv in lanes {
        write_lane(&mut w, lv);
    }
    w.buf
}

pub(crate) fn decode_vec(payload: &[u8], cfg: &RunConfig, agent: &mut SacAgent) -> Result<VecCkpt> {
    let mut rd = ByteReader::new(payload);
    let sink = read_sink(&mut rd)?;
    read_agent(&mut rd, cfg.rl, agent)?;
    let wave = rd.usize()?;
    let step = rd.usize()?;
    let nd = rd.len(1)?;
    let mut done = Vec::with_capacity(nd);
    for _ in 0..nd {
        done.push(read_node_result(&mut rd, cfg)?);
    }
    let nl = rd.len(1)?;
    let mut lanes = Vec::with_capacity(nl);
    for _ in 0..nl {
        lanes.push(read_lane(&mut rd, cfg)?);
    }
    if rd.remaining() != 0 {
        crate::bail!("trailing bytes in vec checkpoint payload");
    }
    Ok(VecCkpt { wave, step, done, lanes, sink })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("silckpt-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn generations_alternate_and_newest_wins() {
        let dir = tmp_dir("gen");
        let mut cd = CheckpointDir::create(&dir).unwrap();
        cd.save(KIND_VEC, 99, b"gen-0").unwrap();
        cd.save(KIND_VEC, 99, b"gen-1").unwrap();
        cd.save(KIND_VEC, 99, b"gen-2").unwrap();
        // two slot files only, newest generation loads
        let entries = std::fs::read_dir(&dir).unwrap().count();
        assert_eq!(entries, 2);
        let (seq, payload) = CheckpointDir::load(&dir, KIND_VEC, 99).unwrap().unwrap();
        assert_eq!(seq, 2);
        assert_eq!(payload, b"gen-2");
        // a fresh writer continues the sequence past existing generations
        let mut cd2 = CheckpointDir::create(&dir).unwrap();
        cd2.save(KIND_VEC, 99, b"gen-3").unwrap();
        let (seq, payload) = CheckpointDir::load(&dir, KIND_VEC, 99).unwrap().unwrap();
        assert_eq!(seq, 3);
        assert_eq!(payload, b"gen-3");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_newest_falls_back_to_previous_generation() {
        let dir = tmp_dir("corrupt");
        let mut cd = CheckpointDir::create(&dir).unwrap();
        cd.save(KIND_VEC, 7, b"old").unwrap(); // slot a, seq 0
        cd.save(KIND_VEC, 7, b"new").unwrap(); // slot b, seq 1
        let slot_b = dir.join("ckpt-b.bin");

        // truncated newest → previous generation loads
        let full = std::fs::read(&slot_b).unwrap();
        std::fs::write(&slot_b, &full[..full.len() / 2]).unwrap();
        let (seq, payload) = CheckpointDir::load(&dir, KIND_VEC, 7).unwrap().unwrap();
        assert_eq!((seq, payload.as_slice()), (0, &b"old"[..]));

        // bit-flipped newest → previous generation loads
        let mut flipped = full.clone();
        let last = flipped.len() - 1;
        flipped[last] ^= 0x01;
        std::fs::write(&slot_b, &flipped).unwrap();
        let (seq, _) = CheckpointDir::load(&dir, KIND_VEC, 7).unwrap().unwrap();
        assert_eq!(seq, 0);

        // both corrupt → fresh start, not an error
        let a = dir.join("ckpt-a.bin");
        let abytes = std::fs::read(&a).unwrap();
        std::fs::write(&a, &abytes[..10]).unwrap();
        assert!(CheckpointDir::load(&dir, KIND_VEC, 7).unwrap().is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fingerprint_mismatch_is_hard_error() {
        let dir = tmp_dir("fp");
        let mut cd = CheckpointDir::create(&dir).unwrap();
        cd.save(KIND_VEC, 1234, b"payload").unwrap();
        let err = CheckpointDir::load(&dir, KIND_VEC, 5678).unwrap_err();
        assert!(err.to_string().contains("different run configuration"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn kind_tag_separates_vec_and_atlas_records() {
        let dir = tmp_dir("kind");
        let mut cd = CheckpointDir::create(&dir).unwrap();
        cd.save(KIND_ATLAS, 3, b"atlas").unwrap();
        assert!(CheckpointDir::load(&dir, KIND_VEC, 3).unwrap().is_none());
        let (_, p) = CheckpointDir::load(&dir, KIND_ATLAS, 3).unwrap().unwrap();
        assert_eq!(p, b"atlas");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fault_plan_trips_at_exactly_n() {
        let mut f = FaultPlan::new(3);
        assert!(f.probe().is_ok());
        assert!(f.probe().is_ok());
        let err = f.probe().unwrap_err();
        assert!(err.to_string().contains(INJECTED_CRASH_MSG), "{err}");
        // disarmed plan never trips
        let mut none = FaultPlan::none();
        for _ in 0..1000 {
            assert!(none.probe().is_ok());
        }
    }

    #[test]
    fn primitive_codecs_round_trip() {
        let mut w = ByteWriter::new();
        let rng_st = RngState { s: [1, 2, u64::MAX, 4], gauss_spare: Some(-0.5) };
        write_rng(&mut w, &rng_st);
        let mesh = MeshConfig { width: 6, height: 7, sc_x: 3, sc_y: 2 };
        write_mesh(&mut w, &mesh);
        let mut a = Action::neutral();
        a.cont[0] = -1.25;
        a.deltas[1] = -2;
        write_action(&mut w, &a);
        let eps = EpsSchedule { eps: 0.31, eps_min: 0.05, d: 0.998 };
        write_eps(&mut w, &eps);
        let stats = EvalStats { pruned: 11, geom_shared: 5, ..Default::default() };
        write_stats(&mut w, &stats);

        let mut rd = ByteReader::new(&w.buf);
        assert_eq!(read_rng(&mut rd).unwrap(), rng_st);
        let m2 = read_mesh(&mut rd).unwrap();
        assert_eq!((m2.width, m2.height, m2.sc_x, m2.sc_y), (6, 7, 3, 2));
        let a2 = read_action(&mut rd).unwrap();
        assert_eq!(a2.cont, a.cont);
        assert_eq!(a2.deltas, a.deltas);
        let e2 = read_eps(&mut rd).unwrap();
        assert_eq!((e2.eps, e2.eps_min, e2.d), (0.31, 0.05, 0.998));
        let s2 = read_stats(&mut rd).unwrap();
        assert_eq!((s2.pruned, s2.geom_shared), (11, 5));
        assert_eq!(rd.remaining(), 0);
    }

    #[test]
    fn per_state_codec_round_trips() {
        let mut b = PerBuffer::new(8, 0.6, 0.4, 0.001);
        for i in 0..5 {
            let mut t = Transition {
                s: [0.0; crate::env::SAC_STATE_DIM],
                a_cont: [0.0; crate::env::ACT_DIM],
                a_disc: [0.0; 20],
                r: i as f32,
                s2: [0.0; crate::env::SAC_STATE_DIM],
                done: 0.0,
                ppa: [0.1, 0.2, 0.3],
            };
            t.s[0] = i as f32 * 0.5;
            b.push(t);
        }
        b.update_priorities(&[1, 3], &[2.5, 0.125]);
        let st = b.export_state();
        let mut w = ByteWriter::new();
        write_per(&mut w, &st);
        let mut rd = ByteReader::new(&w.buf);
        let st2 = read_per(&mut rd).unwrap();
        assert_eq!(st2.data.len(), 5);
        assert_eq!(st2.write, st.write);
        assert_eq!(st2.priorities, st.priorities);
        assert_eq!(st2.max_priority, st.max_priority);
        assert_eq!(st2.beta, st.beta);
        assert_eq!(st2.data[3].r, 3.0);
        let b2 = PerBuffer::from_state(8, 0.6, 0.001, st2);
        assert_eq!(b2.len(), 5);
        assert_eq!(b2.priority_total(), b.priority_total());
    }

    #[test]
    fn resume_dir_resolution_prefers_ckpt_subdir() {
        let dir = tmp_dir("resolve");
        std::fs::create_dir_all(dir.join("ckpt")).unwrap();
        let spec = dir.to_str().unwrap();
        assert_eq!(resolve_resume_dir(spec), dir.join("ckpt"));
        assert_eq!(
            resolve_resume_dir(dir.join("ckpt").to_str().unwrap()),
            dir.join("ckpt")
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
