//! SAC agent driver: owns the parameter [`Store`] and drives the NN
//! [`Backend`] (native kernels or AOT HLO via PJRT) for actor forwards,
//! the fused `sac_update`, world-model rollouts and surrogate scoring.
//! Also hosts the MPC planner (§3.16).
//!
//! The division of labour: the backend does ALL differentiable math; this
//! module does batching (through reusable marshalling buffers — no
//! per-step heap traffic), RNG (noise tensors are inputs), priority
//! bookkeeping and the MPC candidate search.

use std::sync::Arc;

use crate::arch::MeshConfig;
use crate::config::RlConfig;
use crate::env::state::subset_index;
use crate::env::{Action, ACT_DIM, DISC_DIM, SAC_STATE_DIM};
use crate::error::Result;
use crate::eval::{parallel, EvalScratch, EvalStats, Evaluator};
use crate::nn::backend::{Backend, SacBatch};
use crate::nn::{policy, Store};
use crate::rl::per::{PerBuffer, Transition};
use crate::util::Rng;

pub use crate::nn::UpdateMetrics;

/// Reusable minibatch marshalling buffers (cleared and refilled each
/// update; never reallocated after the first full batch).
#[derive(Default)]
struct BatchBufs {
    s: Vec<f32>,
    a: Vec<f32>,
    ad: Vec<f32>,
    r: Vec<f32>,
    s2: Vec<f32>,
    done: Vec<f32>,
    ppa: Vec<f32>,
    eps_cur: Vec<f32>,
    eps_next: Vec<f32>,
}

/// One lane's action-selection branch for [`SacAgent::act_lanes`]: the
/// ε-greedy coin is drawn by the rollout engine (from the lane's RNG,
/// before the batched forward) so the per-lane RNG stream matches the
/// serial loop's draw order exactly.
#[derive(Debug, Clone, Copy)]
pub struct LaneDecision {
    /// ε-branch: uniform action from the lane RNG, no policy sampling.
    pub explore: bool,
}

/// Which replay tensors a backend update consumes (the rest are not
/// marshalled).
#[derive(Clone, Copy)]
enum GatherSet {
    Sac,
    WorldModel,
    Surrogate,
}

impl BatchBufs {
    fn clear(&mut self) {
        self.s.clear();
        self.a.clear();
        self.ad.clear();
        self.r.clear();
        self.s2.clear();
        self.done.clear();
        self.ppa.clear();
    }
}

pub struct SacAgent {
    pub backend: Box<dyn Backend>,
    /// Parameter store behind an `Arc` so the learner thread can publish
    /// versioned snapshots as O(1) pointer swaps (`rl::learner`). On the
    /// update paths `Arc::make_mut` mutates in place while the store is
    /// uniquely owned — the inline path never pays a deep copy — and
    /// copies-on-write only when a rollout side still holds the previous
    /// snapshot. Reads auto-deref, so `&agent.store` keeps working.
    pub store: Arc<Store>,
    pub buffer: PerBuffer,
    pub cfg: RlConfig,
    batch: usize,
    mpc_batch: usize,
    /// Last actor log-std head output (policy-entropy trace for Fig 3).
    pub last_entropy: f64,
    pub updates_done: usize,
    pub wm_trained: bool,
    /// Surrogate heads trained at least once — gates the batched
    /// surrogate scoring term in [`Self::mpc_refine`].
    pub sur_trained: bool,
    /// MPC rerank admission-pruning counters since the last
    /// [`Self::take_eval_stats`]: (pruned, fully evaluated).
    prune_counters: (u64, u64),
    /// Per-worker scratches for the rerank fan-out — persistent so the
    /// placement-stage memos stay warm across exploitation episodes (the
    /// common SAC case the stage split targets).
    rerank_scratches: Vec<EvalScratch>,
    bb: BatchBufs,
}

impl SacAgent {
    pub fn new(backend: Box<dyn Backend>, cfg: RlConfig, rng: &mut Rng) -> Result<Self> {
        let store = Arc::new(Store::from_manifest(backend.manifest(), rng)?);
        let batch = backend.manifest().hyper_or("batch", 256.0) as usize;
        let mpc_batch = backend.manifest().hyper_or("mpc_batch", 64.0) as usize;
        let buffer =
            PerBuffer::new(cfg.buffer_capacity, cfg.per_alpha, cfg.per_beta0, cfg.per_beta_step);
        Ok(SacAgent {
            backend,
            store,
            buffer,
            cfg,
            batch,
            mpc_batch,
            last_entropy: 0.0,
            updates_done: 0,
            wm_trained: false,
            sur_trained: false,
            prune_counters: (0, 0),
            rerank_scratches: Vec::new(),
            bb: BatchBufs::default(),
        })
    }

    /// SAC minibatch size (baked into the manifest).
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// MPC candidate-set size K (baked into the manifest).
    pub fn mpc_batch(&self) -> usize {
        self.mpc_batch
    }

    /// Drain the rerank evaluation counters (admission pruning + stage
    /// memos of the persistent rerank scratches) — called by the per-node
    /// driver so counts never leak across nodes. The scratch *contents*
    /// (memoized placements) are kept warm; only the counters reset.
    pub fn take_eval_stats(&mut self) -> EvalStats {
        let mut es = EvalStats::default();
        let (pruned, evaluated) = std::mem::take(&mut self.prune_counters);
        es.pruned = pruned;
        es.evaluated = evaluated;
        for s in &mut self.rerank_scratches {
            es.place_hits += std::mem::take(&mut s.stages.hits);
            es.place_misses += std::mem::take(&mut s.stages.misses);
            es.place_evictions += std::mem::take(&mut s.stages.evictions);
            es.geom_hits += std::mem::take(&mut s.place.geom.hits);
            es.geom_misses += std::mem::take(&mut s.place.geom.misses);
        }
        es
    }

    /// Policy action for one state (B=1 actor forward + Rust sampling).
    /// `stochastic` = sample (training) vs mean/argmax (exploitation).
    /// Output tensors are consumed as borrowed, indexed slices — no
    /// per-step cloning or name lookups.
    pub fn act(
        &mut self,
        s: &[f32; SAC_STATE_DIM],
        stochastic: bool,
        rng: &mut Rng,
    ) -> Result<Action> {
        let out = self.backend.actor_fwd(&self.store, s.as_slice())?;
        self.last_entropy = policy::gaussian_entropy(out.log_std);
        let cont = if stochastic {
            policy::sample_continuous(out.mu, out.log_std, rng)
        } else {
            policy::mean_continuous(out.mu)
        };
        let (deltas, _) = if stochastic {
            policy::sample_discrete(out.disc_logits, rng)
        } else {
            policy::argmax_discrete(out.disc_logits)
        };
        Ok(Action { cont, deltas })
    }

    /// Batched action selection for a vec-env step: ONE actor forward over
    /// all `B` lane states (`states` is `[B, SAC_STATE_DIM]` row-major),
    /// then per-lane sampling in lane order from each lane's own RNG.
    /// Exploring lanes (`decisions[i].explore`) draw a uniform action from
    /// their RNG instead — their forward row is computed but discarded,
    /// which cannot perturb other rows (the native kernels accumulate each
    /// row independently in a fixed order, so row `i` of a `B`-row forward
    /// is bitwise identical to a B=1 forward of that row; pinned by
    /// `tests/vecenv.rs`).
    ///
    /// Outputs are lane-indexed borrowed slices of the backend's batched
    /// tensors — no per-lane marshalling clones. Returns per-lane
    /// `(action, entropy)`, entropy `None` for exploring lanes (the
    /// serial loop's `last_entropy` is only refreshed on policy actions;
    /// callers keep the per-lane stale-entropy bookkeeping).
    pub fn act_lanes(
        &mut self,
        states: &[f32],
        decisions: &[LaneDecision],
        rngs: &mut [Rng],
    ) -> Result<Vec<(Action, Option<f64>)>> {
        let b = decisions.len();
        debug_assert_eq!(states.len(), b * SAC_STATE_DIM);
        debug_assert_eq!(rngs.len(), b);
        let out = self.backend.actor_fwd(&self.store, states)?;
        let mut lanes = Vec::with_capacity(b);
        for (i, (d, rng)) in decisions.iter().zip(rngs.iter_mut()).enumerate() {
            if d.explore {
                lanes.push((policy::uniform_action(rng), None));
                continue;
            }
            let mu = &out.mu[i * ACT_DIM..(i + 1) * ACT_DIM];
            let log_std = &out.log_std[i * ACT_DIM..(i + 1) * ACT_DIM];
            let dl = &out.disc_logits[i * DISC_DIM..(i + 1) * DISC_DIM];
            let entropy = policy::gaussian_entropy(log_std);
            let cont = policy::sample_continuous(mu, log_std, rng);
            let (deltas, _) = policy::sample_discrete(dl, rng);
            lanes.push((Action { cont, deltas }, Some(entropy)));
        }
        Ok(lanes)
    }

    pub fn push_transition(&mut self, t: Transition) {
        self.buffer.push(t);
    }

    /// Fill the marshalling buffers from sampled replay indices — only
    /// the tensors `set`'s update consumes.
    fn gather(&mut self, idxs: &[usize], set: GatherSet) {
        self.bb.clear();
        for &i in idxs {
            let t = self.buffer.get(i);
            self.bb.s.extend_from_slice(&t.s);
            self.bb.a.extend_from_slice(&t.a_cont);
            match set {
                GatherSet::Sac => {
                    self.bb.ad.extend_from_slice(&t.a_disc);
                    self.bb.r.push(t.r);
                    self.bb.s2.extend_from_slice(&t.s2);
                    self.bb.done.push(t.done);
                }
                GatherSet::WorldModel => self.bb.s2.extend_from_slice(&t.s2),
                GatherSet::Surrogate => self.bb.ppa.extend_from_slice(&t.ppa),
            }
        }
    }

    /// One SAC update (Algorithm 1 line 12): PER sample → backend
    /// `sac_update` (critics, actor, α, Polyak targets, Adam — all
    /// inside) → priority refresh.
    pub fn update(&mut self, rng: &mut Rng) -> Result<UpdateMetrics> {
        let b = self.batch;
        if self.buffer.len() < b {
            return Ok(UpdateMetrics::default());
        }
        let (idxs, is_w) = self.buffer.sample(b, rng);
        self.gather(&idxs, GatherSet::Sac);
        if self.bb.eps_cur.len() < b * ACT_DIM {
            self.bb.eps_cur.resize(b * ACT_DIM, 0.0);
            self.bb.eps_next.resize(b * ACT_DIM, 0.0);
        }
        rng.fill_gaussian_f32(&mut self.bb.eps_cur[..b * ACT_DIM]);
        rng.fill_gaussian_f32(&mut self.bb.eps_next[..b * ACT_DIM]);
        let metrics = {
            let bb = &self.bb;
            let batch = SacBatch {
                b,
                s: &bb.s,
                a: &bb.a,
                ad: &bb.ad,
                r: &bb.r,
                s2: &bb.s2,
                done: &bb.done,
                w: &is_w,
                eps_cur: &bb.eps_cur[..b * ACT_DIM],
                eps_next: &bb.eps_next[..b * ACT_DIM],
            };
            let out = self.backend.sac_update(Arc::make_mut(&mut self.store), &batch)?;
            self.buffer.update_priorities(&idxs, out.td_abs);
            out.metrics
        };
        self.updates_done += 1;
        Ok(metrics)
    }

    /// Train the world model on a replay minibatch (§3.16, half critic LR
    /// — baked into the backend's `wm_update`).
    pub fn train_world_model(&mut self, rng: &mut Rng) -> Result<f64> {
        let b = self.batch;
        if self.buffer.len() < b {
            return Ok(f64::NAN);
        }
        let (idxs, _) = self.buffer.sample(b, rng);
        self.gather(&idxs, GatherSet::WorldModel);
        let bb = &self.bb;
        let loss =
            self.backend.wm_update(Arc::make_mut(&mut self.store), &bb.s, &bb.a, &bb.s2)?;
        self.wm_trained = true;
        Ok(loss)
    }

    /// Train the PPA surrogate heads (Eq 65).
    pub fn train_surrogate(&mut self, rng: &mut Rng) -> Result<f64> {
        let b = self.batch;
        if self.buffer.len() < b {
            return Ok(f64::NAN);
        }
        let (idxs, _) = self.buffer.sample(b, rng);
        self.gather(&idxs, GatherSet::Surrogate);
        let bb = &self.bb;
        let loss =
            self.backend.sur_update(Arc::make_mut(&mut self.store), &bb.s, &bb.a, &bb.ppa)?;
        self.sur_trained = true;
        Ok(loss)
    }

    /// MPC refinement (§3.16, Eqs 70–72): K candidate first actions
    /// (policy mean + N(0, 0.3²) noise), scored by ONE batched surrogate
    /// forward over the whole candidate set (Eq 72's r̂ term, when the
    /// surrogate is trained) plus an H-step world-model rollout with the
    /// policy providing future actions; best candidate blended 70/30 with
    /// the SAC action on the TCC-parameter dims (discrete mesh deltas
    /// stay SAC-only).
    ///
    /// With `eval_ctx = Some((evaluator, mesh))`, the surrogate's top
    /// `cfg.mpc_rerank` candidates are re-scored through the *real*
    /// evaluation pipeline in parallel (`evaluate_many`) and the winner
    /// is picked by true reward — the surrogate proposes, the analytical
    /// model disposes. `None` keeps the pure world-model ranking.
    pub fn mpc_refine(
        &mut self,
        s: &[f32; SAC_STATE_DIM],
        sac_action: &Action,
        eval_ctx: Option<(&Evaluator, &MeshConfig)>,
        rng: &mut Rng,
    ) -> Result<Action> {
        if !self.wm_trained {
            return Ok(sac_action.clone());
        }
        // K is baked into the lowered b64 entrypoints on the PJRT path;
        // the native kernels accept any batch
        let k = self.mpc_batch;
        let h = self.cfg.mpc_horizon;
        let gamma = self.cfg.gamma;

        // K candidate first actions
        let mut cand: Vec<[f64; ACT_DIM]> = Vec::with_capacity(k);
        for _ in 0..k {
            let mut c = sac_action.cont;
            for v in c.iter_mut() {
                *v = (*v + self.cfg.mpc_noise * rng.gaussian()).clamp(-1.0, 1.0);
            }
            cand.push(c);
        }

        // batched rollout state/action tensors: [K, 52] / [K, 30]
        let mut states: Vec<f32> = Vec::with_capacity(k * SAC_STATE_DIM);
        for _ in 0..k {
            states.extend_from_slice(s);
        }
        let mut actions: Vec<f32> =
            cand.iter().flat_map(|c| c.iter().map(|&v| v as f32)).collect();
        let mut returns = vec![0.0f64; k];

        // surrogate immediate term (Eq 72): one forward per candidate
        // SET, not per candidate — [K, 3] (power, perf, area) predictions
        if self.sur_trained {
            let ppa = self.backend.sur_fwd(&self.store, &states, &actions)?;
            for (c, ret) in returns.iter_mut().enumerate() {
                let power = ppa[c * 3] as f64;
                let perf = ppa[c * 3 + 1] as f64;
                let area = ppa[c * 3 + 2] as f64;
                *ret += perf - 0.3 * power - 0.2 * area;
            }
        }

        for step in 0..h {
            // ŝ_{k+1} = ŝ_k + f_ω([ŝ_k; a_k])  (Eq 71)
            let next = self.backend.wm_fwd(&self.store, &states, &actions)?;
            states.copy_from_slice(next);

            // surrogate PPA reward from predicted observation dims (Eq 72)
            let pi = subset_index(51).unwrap(); // perf
            let wi = subset_index(50).unwrap(); // power
            let ai = subset_index(52).unwrap(); // area
            for (c, ret) in returns.iter_mut().enumerate() {
                let base = c * SAC_STATE_DIM;
                let r_sur = states[base + pi] as f64
                    - 0.3 * states[base + wi] as f64
                    - 0.2 * states[base + ai] as f64;
                *ret += gamma.powi(step as i32) * r_sur;
            }

            if step + 1 < h {
                // future actions from the policy at predicted states
                let out = self.backend.actor_fwd(&self.store, &states)?;
                for (av, &m) in actions.iter_mut().zip(out.mu) {
                    *av = m.tanh();
                }
            }
        }

        let best = match eval_ctx {
            Some((ev, mesh)) if self.cfg.mpc_rerank > 0 => {
                self.rerank_candidates(&cand, &returns, ev, mesh, sac_action)
            }
            _ => returns
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap_or(0),
        };

        Ok(self.blend(&cand[best], sac_action))
    }

    /// Blend a candidate into the SAC action on the continuous
    /// TCC-parameter dims only (our layout: 0–14); discrete mesh deltas
    /// and the remaining continuous dims stay SAC's.
    fn blend(&self, cand: &[f64; ACT_DIM], sac_action: &Action) -> Action {
        let mut out = sac_action.clone();
        for i in 0..15 {
            out.cont[i] = (self.cfg.mpc_blend * cand[i]
                + (1.0 - self.cfg.mpc_blend) * sac_action.cont[i])
                .clamp(-1.0, 1.0);
        }
        out
    }

    /// Pick the winning MPC candidate by real evaluation: take the
    /// surrogate's top `mpc_rerank` candidates (stable order: return
    /// desc, index asc), evaluate each candidate's *executed form* —
    /// the 70/30 blend with the SAC action that `mpc_refine` would
    /// return for it — across worker threads, and return the candidate
    /// index whose blended action has the best true reward (feasible
    /// first, then score, ties to the higher surrogate rank). Fully
    /// deterministic for a fixed candidate set. With `cfg.prune`, the
    /// roofline admission bound skips candidates that provably cannot
    /// win — the selected index is identical either way (only the
    /// argmax matters here, and the argmax is never prunable).
    fn rerank_candidates(
        &mut self,
        cand: &[[f64; ACT_DIM]],
        returns: &[f64],
        ev: &Evaluator,
        mesh: &MeshConfig,
        sac_action: &Action,
    ) -> usize {
        let mut order: Vec<usize> = (0..cand.len()).collect();
        order.sort_by(|&a, &b| returns[b].total_cmp(&returns[a]).then(a.cmp(&b)));
        order.truncate(self.cfg.mpc_rerank.min(cand.len()));

        // rank what will actually run: the blended action, not the raw
        // candidate (the blend collapses dims 15-29 back to SAC's)
        let actions: Vec<Action> =
            order.iter().map(|&i| self.blend(&cand[i], sac_action)).collect();
        let threads =
            parallel::resolve(self.cfg.eval_threads).min(actions.len()).max(1);
        if self.rerank_scratches.len() < threads {
            self.rerank_scratches.resize_with(threads, EvalScratch::default);
        }
        let batch = ev.evaluate_best_with(
            mesh,
            &actions,
            &mut self.rerank_scratches[..threads],
            self.cfg.prune,
        );
        self.prune_counters.0 += batch.n_pruned as u64;
        self.prune_counters.1 += (actions.len() - batch.n_pruned) as u64;
        order[batch.best]
    }
}

#[cfg(test)]
mod tests {
    // SacAgent paths over the native backend are covered by
    // rust/tests/native_backend.rs (golden, determinism) and, when AOT
    // artifacts exist, by rust/tests/runtime_e2e.rs over PJRT. The pure
    // helpers are tested in nn::policy and rl::per.
}
