//! SAC agent driver: wraps the PJRT runtime + parameter store and drives
//! the AOT-lowered `actor_fwd_*`, `sac_update`, `wm_fwd_*`/`wm_update`
//! and `sur_*` computations. Also hosts the MPC planner (§3.16).
//!
//! The division of labour: HLO does ALL differentiable math; this module
//! does batching, RNG (noise tensors are inputs), priority bookkeeping
//! and the MPC candidate search.

use std::collections::BTreeMap;

use crate::arch::MeshConfig;
use crate::config::RlConfig;
use crate::env::state::subset_index;
use crate::env::{Action, ACT_DIM, SAC_STATE_DIM};
use crate::error::Result;
use crate::eval::{parallel, EvalScratch, EvalStats, Evaluator};
use crate::nn::{policy, Store};
use crate::rl::per::{PerBuffer, Transition};
use crate::runtime::Runtime;
use crate::util::Rng;

/// Metrics from one SAC update step.
#[derive(Debug, Clone, Copy, Default)]
pub struct UpdateMetrics {
    pub critic_loss: f64,
    pub actor_loss: f64,
    pub alpha_loss: f64,
    pub alpha: f64,
    pub entropy: f64,
}

pub struct SacAgent {
    pub runtime: Runtime,
    pub store: Store,
    pub buffer: PerBuffer,
    pub cfg: RlConfig,
    batch: usize,
    /// Last actor log-std head output (policy-entropy trace for Fig 3).
    pub last_entropy: f64,
    pub updates_done: usize,
    pub wm_trained: bool,
    /// MPC rerank admission-pruning counters since the last
    /// [`Self::take_eval_stats`]: (pruned, fully evaluated).
    prune_counters: (u64, u64),
    /// Per-worker scratches for the rerank fan-out — persistent so the
    /// placement-stage memos stay warm across exploitation episodes (the
    /// common SAC case the stage split targets).
    rerank_scratches: Vec<EvalScratch>,
}

impl SacAgent {
    pub fn new(runtime: Runtime, cfg: RlConfig, rng: &mut Rng) -> Result<Self> {
        let store = Store::from_manifest(&runtime.manifest, rng)?;
        let batch = runtime.manifest.hyper_or("batch", 256.0) as usize;
        let buffer =
            PerBuffer::new(cfg.buffer_capacity, cfg.per_alpha, cfg.per_beta0, cfg.per_beta_step);
        Ok(SacAgent {
            runtime,
            store,
            buffer,
            cfg,
            batch,
            last_entropy: 0.0,
            updates_done: 0,
            wm_trained: false,
            prune_counters: (0, 0),
            rerank_scratches: Vec::new(),
        })
    }

    /// Drain the rerank evaluation counters (admission pruning + stage
    /// memos of the persistent rerank scratches) — called by the per-node
    /// driver so counts never leak across nodes. The scratch *contents*
    /// (memoized placements) are kept warm; only the counters reset.
    pub fn take_eval_stats(&mut self) -> EvalStats {
        let mut es = EvalStats::default();
        let (pruned, evaluated) = std::mem::take(&mut self.prune_counters);
        es.pruned = pruned;
        es.evaluated = evaluated;
        for s in &mut self.rerank_scratches {
            es.place_hits += std::mem::take(&mut s.stages.hits);
            es.place_misses += std::mem::take(&mut s.stages.misses);
            es.place_evictions += std::mem::take(&mut s.stages.evictions);
            es.geom_hits += std::mem::take(&mut s.place.geom.hits);
            es.geom_misses += std::mem::take(&mut s.place.geom.misses);
        }
        es
    }

    /// Policy action for one state (B=1 actor forward + Rust sampling).
    /// `stochastic` = sample (training) vs mean/argmax (exploitation).
    pub fn act(&mut self, s: &[f32; SAC_STATE_DIM], stochastic: bool, rng: &mut Rng) -> Result<Action> {
        let mut call_in = BTreeMap::new();
        call_in.insert("s".to_string(), s.to_vec());
        let outs = self.runtime.call("actor_fwd_b1", self.store.resolver(&call_in))?;
        let get = |name: &str| {
            outs.iter()
                .find(|(n, _)| n == name)
                .map(|(_, v)| v.clone())
                .expect("actor output missing")
        };
        let mu = get("mu");
        let log_std = get("log_std");
        let disc = get("disc_logits");
        self.last_entropy = policy::gaussian_entropy(&log_std);
        let cont = if stochastic {
            policy::sample_continuous(&mu, &log_std, rng)
        } else {
            policy::mean_continuous(&mu)
        };
        let (deltas, _) = if stochastic {
            policy::sample_discrete(&disc, rng)
        } else {
            policy::argmax_discrete(&disc)
        };
        Ok(Action { cont, deltas })
    }

    pub fn push_transition(&mut self, t: Transition) {
        self.buffer.push(t);
    }

    /// One SAC update (Algorithm 1 line 12): PER sample → `sac_update`
    /// HLO (critics, actor, α, Polyak targets, Adam — all inside) →
    /// write-back + priority refresh.
    pub fn update(&mut self, rng: &mut Rng) -> Result<UpdateMetrics> {
        let b = self.batch;
        if self.buffer.len() < b {
            return Ok(UpdateMetrics::default());
        }
        let (idxs, is_w) = self.buffer.sample(b, rng);

        let mut s = Vec::with_capacity(b * SAC_STATE_DIM);
        let mut a = Vec::with_capacity(b * ACT_DIM);
        let mut ad = Vec::with_capacity(b * 20);
        let mut r = Vec::with_capacity(b);
        let mut s2 = Vec::with_capacity(b * SAC_STATE_DIM);
        let mut done = Vec::with_capacity(b);
        for &i in &idxs {
            let t = self.buffer.get(i);
            s.extend_from_slice(&t.s);
            a.extend_from_slice(&t.a_cont);
            ad.extend_from_slice(&t.a_disc);
            r.push(t.r);
            s2.extend_from_slice(&t.s2);
            done.push(t.done);
        }
        let mut eps_cur = vec![0f32; b * ACT_DIM];
        let mut eps_next = vec![0f32; b * ACT_DIM];
        rng.fill_gaussian_f32(&mut eps_cur);
        rng.fill_gaussian_f32(&mut eps_next);

        let mut batch = BTreeMap::new();
        batch.insert("s".into(), s);
        batch.insert("a".into(), a);
        batch.insert("ad".into(), ad);
        batch.insert("r".into(), r);
        batch.insert("s2".into(), s2);
        batch.insert("done".into(), done);
        batch.insert("w".into(), is_w);
        batch.insert("eps_cur".into(), eps_cur);
        batch.insert("eps_next".into(), eps_next);

        let outs = self.runtime.call("sac_update", self.store.resolver(&batch))?;
        let metrics = self.store.absorb(outs)?;
        let td_abs = metrics.get("metrics/td_abs").cloned().unwrap_or_default();
        self.buffer.update_priorities(&idxs, &td_abs);
        self.updates_done += 1;

        let scalar = |k: &str| {
            metrics
                .get(k)
                .and_then(|v| v.first())
                .copied()
                .unwrap_or(0.0) as f64
        };
        Ok(UpdateMetrics {
            critic_loss: scalar("metrics/critic_loss"),
            actor_loss: scalar("metrics/actor_loss"),
            alpha_loss: scalar("metrics/alpha_loss"),
            alpha: scalar("metrics/alpha"),
            entropy: scalar("metrics/entropy"),
        })
    }

    /// Train the world model on a replay minibatch (§3.16, half critic LR
    /// — baked into the lowered `wm_update`).
    pub fn train_world_model(&mut self, rng: &mut Rng) -> Result<f64> {
        let b = self.batch;
        if self.buffer.len() < b {
            return Ok(f64::NAN);
        }
        let (idxs, _) = self.buffer.sample(b, rng);
        let mut s = Vec::with_capacity(b * SAC_STATE_DIM);
        let mut a = Vec::with_capacity(b * ACT_DIM);
        let mut s2 = Vec::with_capacity(b * SAC_STATE_DIM);
        for &i in &idxs {
            let t = self.buffer.get(i);
            s.extend_from_slice(&t.s);
            a.extend_from_slice(&t.a_cont);
            s2.extend_from_slice(&t.s2);
        }
        let mut batch = BTreeMap::new();
        batch.insert("s".into(), s);
        batch.insert("a".into(), a);
        batch.insert("s2".into(), s2);
        let outs = self.runtime.call("wm_update", self.store.resolver(&batch))?;
        let metrics = self.store.absorb(outs)?;
        self.wm_trained = true;
        Ok(metrics
            .get("metrics/loss")
            .and_then(|v| v.first())
            .copied()
            .unwrap_or(f32::NAN) as f64)
    }

    /// Train the PPA surrogate heads (Eq 65).
    pub fn train_surrogate(&mut self, rng: &mut Rng) -> Result<f64> {
        let b = self.batch;
        if self.buffer.len() < b {
            return Ok(f64::NAN);
        }
        let (idxs, _) = self.buffer.sample(b, rng);
        let mut s = Vec::with_capacity(b * SAC_STATE_DIM);
        let mut a = Vec::with_capacity(b * ACT_DIM);
        let mut ppa = Vec::with_capacity(b * 3);
        for &i in &idxs {
            let t = self.buffer.get(i);
            s.extend_from_slice(&t.s);
            a.extend_from_slice(&t.a_cont);
            ppa.extend_from_slice(&t.ppa);
        }
        let mut batch = BTreeMap::new();
        batch.insert("s".into(), s);
        batch.insert("a".into(), a);
        batch.insert("ppa".into(), ppa);
        let outs = self.runtime.call("sur_update", self.store.resolver(&batch))?;
        let metrics = self.store.absorb(outs)?;
        Ok(metrics
            .get("metrics/loss")
            .and_then(|v| v.first())
            .copied()
            .unwrap_or(f32::NAN) as f64)
    }

    /// MPC refinement (§3.16, Eqs 70–72): K candidate first actions
    /// (policy mean + N(0, 0.3²) noise), rolled out H steps through the
    /// world model with the policy providing future actions; surrogate
    /// reward read from the predicted PPA-observation dims; best
    /// candidate blended 70/30 with the SAC action on the TCC-parameter
    /// dims (discrete mesh deltas stay SAC-only).
    ///
    /// With `eval_ctx = Some((evaluator, mesh))`, the surrogate's top
    /// `cfg.mpc_rerank` candidates are re-scored through the *real*
    /// evaluation pipeline in parallel (`evaluate_many`) and the winner
    /// is picked by true reward — the surrogate proposes, the analytical
    /// model disposes. `None` keeps the pure world-model ranking.
    pub fn mpc_refine(
        &mut self,
        s: &[f32; SAC_STATE_DIM],
        sac_action: &Action,
        eval_ctx: Option<(&Evaluator, &MeshConfig)>,
        rng: &mut Rng,
    ) -> Result<Action> {
        if !self.wm_trained {
            return Ok(sac_action.clone());
        }
        // K is baked into the lowered wm_fwd_b64/actor_fwd_b64 batch dim
        let k = self.runtime.manifest.hyper_or("mpc_batch", 64.0) as usize;
        let h = self.cfg.mpc_horizon;
        let gamma = self.cfg.gamma;

        // K candidate first actions
        let mut cand: Vec<[f64; ACT_DIM]> = Vec::with_capacity(k);
        for _ in 0..k {
            let mut c = sac_action.cont;
            for v in c.iter_mut() {
                *v = (*v + self.cfg.mpc_noise * rng.gaussian()).clamp(-1.0, 1.0);
            }
            cand.push(c);
        }

        // batched rollout: states [K, 52]
        let mut states: Vec<f32> = Vec::with_capacity(k * SAC_STATE_DIM);
        for _ in 0..k {
            states.extend_from_slice(s);
        }
        let mut actions: Vec<f32> =
            cand.iter().flat_map(|c| c.iter().map(|&v| v as f32)).collect();
        let mut returns = vec![0.0f64; k];

        for step in 0..h {
            // ŝ_{k+1} = ŝ_k + f_ω([ŝ_k; a_k])  (Eq 71)
            let mut call = BTreeMap::new();
            call.insert("s".to_string(), states.clone());
            call.insert("a".to_string(), actions.clone());
            let outs = self.runtime.call("wm_fwd_b64", self.store.resolver(&call))?;
            states = outs.into_iter().next().map(|(_, v)| v).unwrap();

            // surrogate PPA reward from predicted observation dims (Eq 72)
            let pi = subset_index(51).unwrap(); // perf
            let wi = subset_index(50).unwrap(); // power
            let ai = subset_index(52).unwrap(); // area
            for (c, ret) in returns.iter_mut().enumerate() {
                let base = c * SAC_STATE_DIM;
                let r_sur = states[base + pi] as f64
                    - 0.3 * states[base + wi] as f64
                    - 0.2 * states[base + ai] as f64;
                *ret += gamma.powi(step as i32) * r_sur;
            }

            if step + 1 < h {
                // future actions from the policy at predicted states
                let mut call = BTreeMap::new();
                call.insert("s".to_string(), states.clone());
                let outs =
                    self.runtime.call("actor_fwd_b64", self.store.resolver(&call))?;
                let mu = outs
                    .iter()
                    .find(|(n, _)| n == "mu")
                    .map(|(_, v)| v.clone())
                    .unwrap();
                actions = mu.iter().map(|&m| m.tanh()).collect();
            }
        }

        let best = match eval_ctx {
            Some((ev, mesh)) if self.cfg.mpc_rerank > 0 => {
                self.rerank_candidates(&cand, &returns, ev, mesh, sac_action)
            }
            _ => returns
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap_or(0),
        };

        Ok(self.blend(&cand[best], sac_action))
    }

    /// Blend a candidate into the SAC action on the continuous
    /// TCC-parameter dims only (our layout: 0–14); discrete mesh deltas
    /// and the remaining continuous dims stay SAC's.
    fn blend(&self, cand: &[f64; ACT_DIM], sac_action: &Action) -> Action {
        let mut out = sac_action.clone();
        for i in 0..15 {
            out.cont[i] = (self.cfg.mpc_blend * cand[i]
                + (1.0 - self.cfg.mpc_blend) * sac_action.cont[i])
                .clamp(-1.0, 1.0);
        }
        out
    }

    /// Pick the winning MPC candidate by real evaluation: take the
    /// surrogate's top `mpc_rerank` candidates (stable order: return
    /// desc, index asc), evaluate each candidate's *executed form* —
    /// the 70/30 blend with the SAC action that `mpc_refine` would
    /// return for it — across worker threads, and return the candidate
    /// index whose blended action has the best true reward (feasible
    /// first, then score, ties to the higher surrogate rank). Fully
    /// deterministic for a fixed candidate set. With `cfg.prune`, the
    /// roofline admission bound skips candidates that provably cannot
    /// win — the selected index is identical either way (only the
    /// argmax matters here, and the argmax is never prunable).
    fn rerank_candidates(
        &mut self,
        cand: &[[f64; ACT_DIM]],
        returns: &[f64],
        ev: &Evaluator,
        mesh: &MeshConfig,
        sac_action: &Action,
    ) -> usize {
        let mut order: Vec<usize> = (0..cand.len()).collect();
        order.sort_by(|&a, &b| returns[b].total_cmp(&returns[a]).then(a.cmp(&b)));
        order.truncate(self.cfg.mpc_rerank.min(cand.len()));

        // rank what will actually run: the blended action, not the raw
        // candidate (the blend collapses dims 15-29 back to SAC's)
        let actions: Vec<Action> =
            order.iter().map(|&i| self.blend(&cand[i], sac_action)).collect();
        let threads =
            parallel::resolve(self.cfg.eval_threads).min(actions.len()).max(1);
        if self.rerank_scratches.len() < threads {
            self.rerank_scratches.resize_with(threads, EvalScratch::default);
        }
        let batch = ev.evaluate_best_with(
            mesh,
            &actions,
            &mut self.rerank_scratches[..threads],
            self.cfg.prune,
        );
        self.prune_counters.0 += batch.n_pruned as u64;
        self.prune_counters.1 += (actions.len() - batch.n_pruned) as u64;
        order[batch.best]
    }
}

#[cfg(test)]
mod tests {
    // SacAgent requires compiled artifacts; its end-to-end behaviour is
    // covered by rust/tests/runtime_e2e.rs. The pure helpers are tested in
    // nn::policy and rl::per.
}
