//! Repeated-seed evaluation (§5.4 limitation 2 / §5.5 future work).
//!
//! The paper reports single-run results and explicitly calls for
//! "repeated-seed protocols with confidence intervals". This module runs
//! any search strategy across N seeds and reports per-metric mean, std
//! and a normal-approximation 95% confidence interval.

use crate::config::RunConfig;
use crate::rl::NodeResult;
use crate::util::csv::{fnum, Table};
use crate::util::Rng;

/// Aggregated statistics for one metric across seeds.
#[derive(Debug, Clone, Copy)]
pub struct SeedStat {
    pub mean: f64,
    pub std: f64,
    /// Half-width of the normal-approximation 95% CI.
    pub ci95: f64,
    pub n: usize,
}

impl SeedStat {
    pub fn from_samples(xs: &[f64]) -> SeedStat {
        let n = xs.len().max(1);
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let std = var.sqrt();
        SeedStat { mean, std, ci95: 1.96 * std / (n as f64).sqrt(), n }
    }
}

/// Multi-seed summary of a search strategy at one node.
#[derive(Debug, Clone)]
pub struct MultiSeedResult {
    pub nm: u32,
    pub seeds: Vec<u64>,
    pub tokens_per_s: SeedStat,
    pub power_mw: SeedStat,
    pub area_mm2: SeedStat,
    pub score: SeedStat,
    pub feasible_frac: SeedStat,
    /// Seeds that found no feasible configuration.
    pub failures: usize,
}

/// Run `search` across `n_seeds` derived seeds and aggregate.
pub fn run_seeds(
    cfg: &RunConfig,
    nm: u32,
    n_seeds: usize,
    mut search: impl FnMut(&RunConfig, u32, &mut Rng) -> NodeResult,
) -> MultiSeedResult {
    let mut toks = Vec::new();
    let mut power = Vec::new();
    let mut area = Vec::new();
    let mut score = Vec::new();
    let mut feas = Vec::new();
    let mut seeds = Vec::new();
    let mut failures = 0usize;
    for i in 0..n_seeds {
        let seed = cfg.seed.wrapping_add(0x9E37_79B9 * (i as u64 + 1));
        seeds.push(seed);
        let mut rng = Rng::new(seed);
        let r = search(cfg, nm, &mut rng);
        feas.push(r.feasible_count as f64 / r.total_episodes.max(1) as f64);
        match &r.best {
            Some(b) => {
                toks.push(b.outcome.ppa.tokens_per_s);
                power.push(b.outcome.ppa.power.total());
                area.push(b.outcome.ppa.area.total());
                score.push(b.outcome.reward.score);
            }
            None => failures += 1,
        }
    }
    MultiSeedResult {
        nm,
        seeds,
        tokens_per_s: SeedStat::from_samples(&toks),
        power_mw: SeedStat::from_samples(&power),
        area_mm2: SeedStat::from_samples(&area),
        score: SeedStat::from_samples(&score),
        feasible_frac: SeedStat::from_samples(&feas),
        failures,
    }
}

/// Render a multi-seed summary table (mean ± 95% CI).
pub fn seeds_table(results: &[MultiSeedResult]) -> Table {
    let mut t = Table::new(
        "multi-seed evaluation (mean ± 95% CI)",
        &["node", "seeds", "tok_s", "power_mw", "area_mm2", "score", "feas_frac", "failed"],
    );
    let pm = |s: &SeedStat, d: usize| format!("{} ±{}", fnum(s.mean, d), fnum(s.ci95, d));
    for r in results {
        t.row(vec![
            format!("{}nm", r.nm),
            r.seeds.len().to_string(),
            pm(&r.tokens_per_s, 0),
            pm(&r.power_mw, 0),
            pm(&r.area_mm2, 0),
            pm(&r.score, 3),
            pm(&r.feasible_frac, 2),
            r.failures.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Granularity;
    use crate::rl::baselines;

    #[test]
    fn seed_stats_basics() {
        let s = SeedStat::from_samples(&[1.0, 2.0, 3.0]);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert!((s.std - 1.0).abs() < 1e-12);
        assert!(s.ci95 > 0.0 && s.n == 3);
        let single = SeedStat::from_samples(&[5.0]);
        assert_eq!((single.mean, single.std), (5.0, 0.0));
    }

    #[test]
    fn multi_seed_random_search_varies_but_overlaps() {
        let mut cfg = RunConfig::default();
        cfg.rl.episodes_per_node = 20;
        cfg.granularity = Granularity::Group;
        let r = run_seeds(&cfg, 3, 3, |c, nm, rng| {
            baselines::random_search(c, nm, rng)
        });
        assert_eq!(r.seeds.len(), 3);
        // distinct seeds were derived
        assert_ne!(r.seeds[0], r.seeds[1]);
        assert!(r.tokens_per_s.mean > 0.0);
        // seed variance exists but is bounded (same search distribution)
        assert!(r.tokens_per_s.std < r.tokens_per_s.mean);
        let t = seeds_table(&[r]);
        assert!(t.to_text().contains("±"));
    }
}
