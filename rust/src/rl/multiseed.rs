//! Repeated-seed evaluation (§5.4 limitation 2 / §5.5 future work).
//!
//! The paper reports single-run results and explicitly calls for
//! "repeated-seed protocols with confidence intervals". This module runs
//! any search strategy across N seeds — fanning the seeds across worker
//! threads — and reports per-metric mean, std and a normal-approximation
//! 95% confidence interval, plus the merged Pareto frontier.
//!
//! Determinism: seed `i` is derived from the base seed by index, each
//! worker gets its own [`Rng`], and aggregation walks results in seed
//! order (never completion order) — so `run_seeds_t(.., 1, ..)` and
//! `run_seeds_t(.., 16, ..)` produce identical statistics.

use crate::config::RunConfig;
use crate::eval::{parallel, EvalStats};
use crate::rl::pareto::ParetoArchive;
use crate::rl::NodeResult;
use crate::util::csv::{fnum, Table};
use crate::util::Rng;

/// Aggregated statistics for one metric across seeds.
#[derive(Debug, Clone, Copy)]
pub struct SeedStat {
    pub mean: f64,
    pub std: f64,
    /// Half-width of the normal-approximation 95% CI.
    pub ci95: f64,
    pub n: usize,
}

impl SeedStat {
    pub fn from_samples(xs: &[f64]) -> SeedStat {
        let n = xs.len().max(1);
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let std = var.sqrt();
        SeedStat { mean, std, ci95: 1.96 * std / (n as f64).sqrt(), n }
    }
}

/// Multi-seed summary of a search strategy at one node.
#[derive(Debug)]
pub struct MultiSeedResult {
    pub nm: u32,
    pub seeds: Vec<u64>,
    pub tokens_per_s: SeedStat,
    pub power_mw: SeedStat,
    pub area_mm2: SeedStat,
    pub score: SeedStat,
    /// Fraction of budgeted episodes that produced a feasible design.
    /// Under roofline admission pruning only fully-evaluated candidates
    /// count, so this is a *lower bound* — not comparable to an exact
    /// (`--no-prune`) run. The best-design statistics above are identical
    /// either way.
    pub feasible_frac: SeedStat,
    /// Seeds that found no feasible configuration.
    pub failures: usize,
    /// Union frontier across all seeds, merged in seed order.
    pub pareto: ParetoArchive,
    /// Evaluation-layer counters summed across seeds (cache hit rates,
    /// admission-pruning totals).
    pub eval_stats: EvalStats,
}

/// Derive the i-th run seed from the configured base seed.
pub fn derive_seed(base: u64, i: usize) -> u64 {
    base.wrapping_add(0x9E37_79B9u64.wrapping_mul(i as u64 + 1))
}

/// Run `search` across `n_seeds` derived seeds and aggregate
/// ([`run_seeds_t`] with the configured/auto worker count).
pub fn run_seeds(
    cfg: &RunConfig,
    nm: u32,
    n_seeds: usize,
    search: impl Fn(&RunConfig, u32, &mut Rng) -> NodeResult + Sync,
) -> MultiSeedResult {
    run_seeds_t(cfg, nm, n_seeds, parallel::resolve(cfg.rl.eval_threads), search)
}

/// Run `search` across `n_seeds` derived seeds with up to `threads`
/// concurrent workers (1 = fully serial), then aggregate in seed order.
pub fn run_seeds_t(
    cfg: &RunConfig,
    nm: u32,
    n_seeds: usize,
    threads: usize,
    search: impl Fn(&RunConfig, u32, &mut Rng) -> NodeResult + Sync,
) -> MultiSeedResult {
    let seeds: Vec<u64> = (0..n_seeds).map(|i| derive_seed(cfg.seed, i)).collect();

    let results: Vec<NodeResult> = parallel::scoped_chunk_map(
        &seeds,
        threads,
        || (),
        |_, _i, &seed| {
            let mut rng = Rng::new(seed);
            search(cfg, nm, &mut rng)
        },
    );

    aggregate(nm, seeds, &results)
}

/// Deterministic reduction over per-seed results: walk `results` in seed
/// order (never completion order) and fold the Table-style statistics.
/// Shared by the thread fan-out and the vec-env seed driver so both
/// aggregate identically.
pub fn aggregate(nm: u32, seeds: Vec<u64>, results: &[NodeResult]) -> MultiSeedResult {
    let mut toks = Vec::new();
    let mut power = Vec::new();
    let mut area = Vec::new();
    let mut score = Vec::new();
    let mut feas = Vec::new();
    let mut failures = 0usize;
    let mut pareto = ParetoArchive::new();
    let mut eval_stats = EvalStats::default();
    for r in results {
        feas.push(r.feasible_count as f64 / r.total_episodes.max(1) as f64);
        pareto.merge(&r.pareto);
        eval_stats.merge(&r.eval_stats);
        match &r.best {
            Some(b) => {
                toks.push(b.outcome.ppa.tokens_per_s);
                power.push(b.outcome.ppa.power.total());
                area.push(b.outcome.ppa.area.total());
                score.push(b.outcome.reward.score);
            }
            None => failures += 1,
        }
    }
    MultiSeedResult {
        nm,
        seeds,
        tokens_per_s: SeedStat::from_samples(&toks),
        power_mw: SeedStat::from_samples(&power),
        area_mm2: SeedStat::from_samples(&area),
        score: SeedStat::from_samples(&score),
        feasible_frac: SeedStat::from_samples(&feas),
        failures,
        pareto,
        eval_stats,
    }
}

/// Multi-seed SAC evaluation through the vec-env: every configured node ×
/// derived seed becomes one lane of a single vectorized rollout (waves of
/// `lanes`, one shared agent — seeds amortize each other's updates and
/// batched forwards), aggregated per node in (node, seed) order. Seed
/// derivation matches [`run_seeds_t`], so the per-node seed sets are
/// identical to the thread-fan-out driver's.
///
/// Statistical caveat: with live learning the lanes share one policy and
/// replay buffer, so per-seed outcomes are *correlated* — the CI columns
/// of [`seeds_table`] quantify rollout-seed variance under shared
/// learning, NOT independent-run variance, and are not comparable to the
/// independent-seed `search=random` rows. For independent SAC runs, use
/// `optimize seed=…` per seed (or disable updates with a large warmup).
/// Returns one aggregate per configured node, plus the actor-learner
/// engine's counters when `learner=pinned|async` (`None` for inline).
///
/// Checkpoint/resume (DESIGN.md §13): `checkpoint_every=` and `resume=`
/// flow through [`run_jobs_stats`](crate::rl::vecenv::run_jobs_stats)
/// unchanged — the vec-env driver fingerprints the (cfg, jobs, lanes)
/// triple, so a `seeds search=sac` checkpoint can only resume a run with
/// the same node × seed lane layout.
pub fn run_seeds_vec(
    cfg: &RunConfig,
    n_seeds: usize,
    agent: &mut crate::rl::SacAgent,
    lanes: usize,
    threads: usize,
) -> crate::error::Result<(Vec<MultiSeedResult>, Option<crate::rl::LearnerReport>)> {
    let seeds: Vec<u64> = (0..n_seeds).map(|i| derive_seed(cfg.seed, i)).collect();
    let jobs: Vec<crate::rl::LaneSpec> = cfg
        .nodes_nm
        .iter()
        .flat_map(|&nm| seeds.iter().map(move |&seed| crate::rl::LaneSpec { nm, seed }))
        .collect();
    let (results, learner) =
        crate::rl::vecenv::run_jobs_stats(cfg, &jobs, lanes, agent, threads)?;
    let agg = cfg
        .nodes_nm
        .iter()
        .zip(results.chunks(n_seeds.max(1)))
        .map(|(&nm, chunk)| aggregate(nm, seeds.clone(), chunk))
        .collect();
    Ok((agg, learner))
}

/// Render a multi-seed summary table (mean ± 95% CI).
pub fn seeds_table(results: &[MultiSeedResult]) -> Table {
    let mut t = Table::new(
        "multi-seed evaluation (mean ± 95% CI)",
        &[
            "node", "seeds", "tok_s", "power_mw", "area_mm2", "score", "feas_frac",
            "failed", "pruned",
        ],
    );
    let pm = |s: &SeedStat, d: usize| format!("{} ±{}", fnum(s.mean, d), fnum(s.ci95, d));
    for r in results {
        t.row(vec![
            format!("{}nm", r.nm),
            r.seeds.len().to_string(),
            pm(&r.tokens_per_s, 0),
            pm(&r.power_mw, 0),
            pm(&r.area_mm2, 0),
            pm(&r.score, 3),
            pm(&r.feasible_frac, 2),
            r.failures.to_string(),
            format!("{:.0}%", r.eval_stats.prune_rate() * 100.0),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Granularity;
    use crate::rl::baselines;

    #[test]
    fn seed_stats_basics() {
        let s = SeedStat::from_samples(&[1.0, 2.0, 3.0]);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert!((s.std - 1.0).abs() < 1e-12);
        assert!(s.ci95 > 0.0 && s.n == 3);
        let single = SeedStat::from_samples(&[5.0]);
        assert_eq!((single.mean, single.std), (5.0, 0.0));
    }

    #[test]
    fn multi_seed_random_search_varies_but_overlaps() {
        let mut cfg = RunConfig::default();
        cfg.rl.episodes_per_node = 20;
        cfg.granularity = Granularity::Group;
        let r = run_seeds(&cfg, 3, 3, |c, nm, rng| {
            baselines::random_search(c, nm, rng)
        });
        assert_eq!(r.seeds.len(), 3);
        // distinct seeds were derived
        assert_ne!(r.seeds[0], r.seeds[1]);
        assert!(r.tokens_per_s.mean > 0.0);
        // seed variance exists but is bounded (same search distribution)
        assert!(r.tokens_per_s.std < r.tokens_per_s.mean);
        let t = seeds_table(&[r]);
        assert!(t.to_text().contains("±"));
    }

    #[test]
    fn parallel_seeds_match_serial_seeds() {
        let mut cfg = RunConfig::default();
        cfg.rl.episodes_per_node = 16;
        cfg.granularity = Granularity::Group;
        let search = |c: &RunConfig, nm: u32, rng: &mut Rng| {
            baselines::random_search_t(c, nm, rng, 1)
        };
        let serial = run_seeds_t(&cfg, 3, 4, 1, search);
        let par = run_seeds_t(&cfg, 3, 4, 4, search);
        assert_eq!(serial.seeds, par.seeds);
        assert_eq!(serial.failures, par.failures);
        assert_eq!(serial.score.mean.to_bits(), par.score.mean.to_bits());
        assert_eq!(
            serial.tokens_per_s.mean.to_bits(),
            par.tokens_per_s.mean.to_bits()
        );
        assert_eq!(serial.pareto.len(), par.pareto.len());
    }
}
