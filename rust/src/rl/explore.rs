//! Adaptive ε-greedy exploration (§3.4.2, Eq 9).
//!
//! The base decay d is auto-derived from the episode budget so ε reaches
//! ε_min from ε₀ over the run; when no feasible configurations have been
//! discovered recently, decay slows to d' = 1 − (1−d)·0.1, keeping
//! exploration high until the policy finds feasible regions.

#[derive(Debug, Clone)]
pub struct EpsSchedule {
    pub eps: f64,
    pub eps_min: f64,
    /// Base decay d (per episode).
    pub d: f64,
}

impl EpsSchedule {
    /// Auto-derive d so ε₀·d^T = ε_min over `budget` episodes.
    pub fn new(eps0: f64, eps_min: f64, budget: usize) -> Self {
        let t = budget.max(2) as f64;
        let d = (eps_min / eps0).powf(1.0 / t);
        EpsSchedule { eps: eps0, eps_min, d }
    }

    /// Advance one episode (Eq 9). `found_feasible` = whether any
    /// feasible configuration has been discovered so far.
    pub fn step(&mut self, found_feasible: bool) {
        let d = if found_feasible {
            self.d
        } else {
            1.0 - (1.0 - self.d) * 0.1 // d' > d: slower decay when stuck
        };
        self.eps = (self.eps * d).max(self.eps_min);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reaches_eps_min_over_budget() {
        let mut s = EpsSchedule::new(0.5, 0.1, 1000);
        for _ in 0..1000 {
            s.step(true);
        }
        assert!((s.eps - 0.1).abs() < 0.01, "eps {}", s.eps);
    }

    #[test]
    fn never_below_min() {
        let mut s = EpsSchedule::new(0.5, 0.1, 100);
        for _ in 0..10_000 {
            s.step(true);
        }
        assert!(s.eps >= 0.1);
    }

    #[test]
    fn stuck_decays_slower_eq9() {
        let mut fast = EpsSchedule::new(0.5, 0.01, 500);
        let mut slow = fast.clone();
        for _ in 0..200 {
            fast.step(true);
            slow.step(false);
        }
        assert!(slow.eps > fast.eps, "{} vs {}", slow.eps, fast.eps);
        // d' = 1 - (1-d)*0.1 exactly
        let d = fast.d;
        let dp = 1.0 - (1.0 - d) * 0.1;
        assert!((slow.eps - 0.5 * dp.powi(200)).abs() < 1e-9);
    }
}
