//! Vectorized multi-env rollout engine: step B search lanes — across
//! seeds, process nodes and scenario points — in lockstep, with ONE
//! batched actor forward per step instead of B sequential B=1 calls and
//! the env transitions fanned out across worker threads.
//!
//! ## Lane determinism contract (DESIGN.md §9)
//!
//! Each lane owns *all* of its rollout state: RNG (seeded from the lane's
//! own seed), ε schedule, walking mesh, outcome memo ([`EvalCache`]),
//! worker scratch and episode tracker. The shared pieces are exactly the
//! SAC agent's parameter [`crate::nn::Store`] (read by the batched
//! forward) and the PER replay buffer (written lane-major). Because
//!
//! * the native kernels accumulate every output row independently in a
//!   fixed order (row `i` of a `[B, ·]` forward is bitwise identical to a
//!   B=1 forward of that row),
//! * per-lane sampling draws from the lane's RNG in the same order as the
//!   serial loop (ε coin → action sampling → MPC noise), and
//! * env evaluation is a pure per-lane function fanned out by input index,
//!
//! a B-lane run with updates disabled is **bit-identical per lane** to B
//! serial [`crate::rl::run_node`] runs driven by `Rng::new(lane_seed)`
//! against the same initial store — episode logs, Pareto frontiers and
//! the lane-major-interleaved replay contents all match exactly (pinned
//! by `tests/vecenv.rs`).
//!
//! ## Update amortization
//!
//! With live learning, SAC / world-model / surrogate updates run on the
//! **shared vec-step counter**: one SAC update per lockstep step (where B
//! serial runs would perform B), and wm/sur updates at their configured
//! per-step cadences. Update randomness draws from a dedicated update
//! stream owned by the caller — never from lane RNGs — so rollout
//! streams stay serial-identical and the only cross-lane coupling is the
//! (intended) shared learning through the store. A full vec run is still
//! deterministic from `(cfg.seed, lane seeds)` for any worker count.
//!
//! ## Step sinks (DESIGN.md §11)
//!
//! Where each lockstep step's transitions — and the update schedule they
//! trigger — go is abstracted behind [`StepSink`]: `Inline` runs
//! [`update_tick`] on this thread exactly as described above, while
//! `Learner` forwards the step to the dedicated learner thread
//! ([`crate::rl::learner`]) and adopts its published parameter snapshots
//! at the top of each step. `learner=pinned` reproduces the inline
//! schedule bit-for-bit; `learner=async` trades that for throughput.
//!
//! ## Checkpoint/resume and fault injection (DESIGN.md §13)
//!
//! [`run_jobs_ckpt`] threads a [`RunCtx`] through the wave loop:
//! `checkpoint_every=N` snapshots the complete search state every N
//! lockstep steps (top-of-step, after the learner sync and before any RNG
//! draw, so the resumed step replays exactly) and at every wave boundary;
//! `resume=<dir>` restores the newest valid generation and continues from
//! its wave/step cursor. `crash_after=<N>` trips the N-th fault probe —
//! probes sit top-of-step, mid-wave after the env fan-out, and after the
//! replay insert/send — so the kill-and-resume tests sweep every
//! interruption class. Resumed runs are bit-identical to uninterrupted
//! ones in episode logs, frontiers and replay contents; only eval-cache
//! hit/miss counters differ (resumed lanes restart with cold memos).

use crate::config::RunConfig;
use crate::env::{state, Action, SAC_STATE_DIM};
use crate::error::Result;
use crate::eval::{parallel, EvalCache, EvalScratch, EvalStats, Evaluator, SharedEvalCache};
use crate::rl::agent::{LaneDecision, SacAgent};
use crate::rl::checkpoint::{self, LaneCkpt, LaneView, RunCtx, SinkCkpt, KIND_VEC};
use crate::rl::explore::EpsSchedule;
use crate::rl::learner::{LearnerClient, LearnerReport, UPDATE_STREAM_TAG};
use crate::rl::loop_::{make_transition, update_tick, EpisodeTracker};
use crate::rl::NodeResult;
use crate::util::stats::RunningStat;
use crate::util::Rng;

/// One lane's job: which process node to optimize and the seed of its
/// private RNG stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LaneSpec {
    pub nm: u32,
    pub seed: u64,
}

/// One rollout lane: everything Algorithm 1 keeps per (node, seed) run.
/// The lane's RNG lives in a parallel `Vec<Rng>` owned by [`run_vec`] so
/// the batched action selection can borrow all lane RNGs as one slice
/// while the lanes themselves stay untouched.
/// A lane's whole-outcome memo: private per lane (the default), or one
/// process-wide [`SharedEvalCache`] spanning every lane and scenario
/// point of an atlas sweep. Sharing is determinism-neutral — keys are
/// salted per evaluator and a replayed outcome is bit-identical to
/// recomputation — so the lane contract of the module doc holds either
/// way; only the hit/miss *counters* move from the lane to the shared
/// cache.
enum LaneCache {
    Local(EvalCache),
    Shared(SharedEvalCache),
}

impl LaneCache {
    fn evaluate(
        &mut self,
        ev: &Evaluator,
        mesh: &crate::arch::MeshConfig,
        a: &Action,
        scratch: &mut EvalScratch,
    ) -> crate::eval::EvalOutcome {
        match self {
            LaneCache::Local(c) => c.evaluate(ev, mesh, a, scratch),
            LaneCache::Shared(c) => c.evaluate(ev, mesh, a, scratch),
        }
    }
}

struct Lane {
    nm: u32,
    eval: Evaluator,
    mesh: crate::arch::MeshConfig,
    scratch: EvalScratch,
    cache: LaneCache,
    eps: EpsSchedule,
    tracker: EpisodeTracker,
    s: [f32; SAC_STATE_DIM],
    /// Mirrors the serial loop's stale-entropy bookkeeping: refreshed
    /// only when the lane takes a policy action.
    last_entropy: f64,
    /// This lane's share of the shared agent's MPC-rerank counters
    /// (admission pruning + persistent-scratch memos), drained right
    /// after each of the lane's `mpc_refine` calls — so per-node stats
    /// rows never absorb another lane's rerank work.
    stats: EvalStats,
}

impl Lane {
    fn new(cfg: &RunConfig, spec: &LaneSpec, shared: Option<&SharedEvalCache>) -> Lane {
        let eval = Evaluator::new(cfg, spec.nm);
        let mesh0 = eval.initial_mesh();
        let mut scratch = EvalScratch::default();
        let mut cache = match shared {
            Some(c) => LaneCache::Shared(c.clone()),
            None => LaneCache::Local(EvalCache::new(cfg.rl.eval_cache)),
        };
        // bootstrap: evaluate the neutral action to get s₀ (no RNG)
        let prev = cache.evaluate(&eval, &mesh0, &Action::neutral(), &mut scratch);
        let mesh = prev.decoded.mesh;
        let s = state::sac_subset(&prev.full_state);
        Lane {
            nm: spec.nm,
            eval,
            mesh,
            scratch,
            cache,
            eps: EpsSchedule::new(cfg.rl.eps0, cfg.rl.eps_min, cfg.rl.episodes_per_node),
            tracker: EpisodeTracker::new(cfg.rl.episodes_per_node),
            s,
            last_entropy: 0.0,
            stats: EvalStats::default(),
        }
    }

    /// Overwrite the bootstrapped lane with a checkpointed image. The
    /// outcome memo and worker scratch deliberately stay cold: they are
    /// pure memos, so the resumed trajectory is bit-identical — only the
    /// hit/miss counters differ from the uninterrupted run.
    fn restore(&mut self, lc: LaneCkpt) {
        self.mesh = lc.mesh;
        self.s = lc.s;
        self.last_entropy = lc.last_entropy;
        self.eps = lc.eps;
        self.tracker = lc.tracker;
        self.stats = lc.stats;
    }
}

/// Where a lockstep step's transitions — and the updates they trigger —
/// go: inline on this thread (the legacy engine, the determinism
/// reference) or across the queue to the dedicated learner thread.
pub(crate) enum StepSink<'a> {
    /// Push into the agent's own buffer and run [`update_tick`] here,
    /// drawing from the caller-owned update stream.
    Inline { update_rng: &'a mut Rng },
    /// Send each step to the learner thread and pick up published
    /// parameter snapshots at step boundaries.
    Learner(&'a mut LearnerClient),
}

impl StepSink<'_> {
    /// Snapshot the update-side state for a checkpoint: the inline update
    /// stream's position, or the quiesced learner-thread state (captured
    /// through the FIFO queue). `None` when the learner has failed — the
    /// caller skips that checkpoint rather than write a torn image.
    fn capture(&mut self) -> Option<SinkCkpt> {
        match self {
            StepSink::Inline { update_rng } => {
                Some(SinkCkpt::Inline { rng: update_rng.state() })
            }
            StepSink::Learner(client) => client.request_state().map(SinkCkpt::Learner),
        }
    }
}

/// Commit one checkpoint generation at cursor `(wave, step)`: capture the
/// update-side state, snapshot every live lane (empty at wave
/// boundaries) and the completed-wave results, and hand the sealed
/// payload to the [`RunCtx`] sink. The replay buffer rides inside the
/// lane/agent image for inline runs and inside the learner state
/// otherwise.
fn step_save(
    ctx: &mut RunCtx,
    sink: &mut StepSink<'_>,
    agent: &SacAgent,
    cursor: (usize, usize),
    done: &[NodeResult],
    lanes: &[Lane],
    rngs: &[Rng],
) {
    let sc = match sink.capture() {
        Some(sc) => sc,
        None => {
            ctx.note_skip();
            return;
        }
    };
    let views: Vec<LaneView> = lanes
        .iter()
        .zip(rngs)
        .map(|(lane, rng)| LaneView {
            nm: lane.nm,
            mesh: lane.mesh,
            s: &lane.s,
            last_entropy: lane.last_entropy,
            eps: &lane.eps,
            tracker: &lane.tracker,
            stats: lane.stats,
            rng: rng.state(),
        })
        .collect();
    let with_buffer = matches!(sc, SinkCkpt::Inline { .. });
    let payload = checkpoint::encode_vec(cursor.0, cursor.1, agent, with_buffer, &sc, done, &views);
    ctx.save(KIND_VEC, &payload);
}

/// Run Algorithm 1 for every lane of `specs` in lockstep: one batched
/// actor forward per step, env transitions fanned out over up to
/// `threads` workers, replay insertion in lane-major order, updates
/// amortized on the shared step counter (drawing from `update_rng`).
/// Returns one [`NodeResult`] per lane, in `specs` order.
///
/// Evaluation counters are attributed per lane: each lane's outcome
/// memo and worker scratch fold into its own result, and the shared
/// agent's MPC-rerank counters are drained (`take_eval_stats`) right
/// after each lane's `mpc_refine` call — so per-node stats rows (the
/// seeds table, Table 14) never absorb another lane's rerank work.
pub fn run_vec(
    cfg: &RunConfig,
    specs: &[LaneSpec],
    agent: &mut SacAgent,
    update_rng: &mut Rng,
    threads: usize,
) -> Result<Vec<NodeResult>> {
    run_vec_driver(cfg, specs, agent, threads, &mut StepSink::Inline { update_rng }, None)
}

/// The single-wave lockstep driver behind [`run_vec`], generic over the
/// step sink and the (optionally shared) whole-outcome memo. No
/// checkpointing, no fault injection — [`run_jobs_ckpt`] is the
/// robustness-aware entry point.
pub(crate) fn run_vec_driver(
    cfg: &RunConfig,
    specs: &[LaneSpec],
    agent: &mut SacAgent,
    threads: usize,
    sink: &mut StepSink<'_>,
    shared: Option<&SharedEvalCache>,
) -> Result<Vec<NodeResult>> {
    let mut ctx = RunCtx::passthrough();
    let wr = WaveRun { shared, wave: 0, t0: 0, restore: None, done: &[] };
    run_wave(cfg, specs, agent, threads, sink, &mut ctx, wr)
}

/// Per-wave inputs of [`run_wave`] beyond the always-present driver
/// state: the shared memo, the wave's position in the job list, the
/// resume cursor (`t0 > 0` only on the wave a mid-wave checkpoint
/// restored), the restored lane images, and the results of completed
/// waves (checkpoints must carry them).
struct WaveRun<'a> {
    shared: Option<&'a SharedEvalCache>,
    wave: usize,
    t0: usize,
    restore: Option<Vec<LaneCkpt>>,
    done: &'a [NodeResult],
}

fn run_wave(
    cfg: &RunConfig,
    specs: &[LaneSpec],
    agent: &mut SacAgent,
    threads: usize,
    sink: &mut StepSink<'_>,
    ctx: &mut RunCtx,
    wr: WaveRun<'_>,
) -> Result<Vec<NodeResult>> {
    if specs.is_empty() {
        return Ok(Vec::new());
    }
    let rl = &cfg.rl;
    let b = specs.len();
    let mut lanes: Vec<Lane> = specs.iter().map(|sp| Lane::new(cfg, sp, wr.shared)).collect();
    let mut rngs: Vec<Rng> = specs.iter().map(|sp| Rng::new(sp.seed)).collect();
    if let Some(lcs) = wr.restore {
        if lcs.len() != b {
            crate::bail!("checkpoint lane count {} does not match wave width {b}", lcs.len());
        }
        for ((lane, rng), lc) in lanes.iter_mut().zip(rngs.iter_mut()).zip(lcs) {
            if lc.nm != lane.nm {
                crate::bail!("checkpoint lane node {}nm does not match job {}nm", lc.nm, lane.nm);
            }
            *rng = Rng::from_state(lc.rng);
            lane.restore(lc);
        }
    }
    let mut states = vec![0.0f32; b * SAC_STATE_DIM];
    let mut decisions = vec![LaneDecision { explore: false }; b];
    let mut s2s = vec![[0.0f32; SAC_STATE_DIM]; b];

    for t in wr.t0..rl.episodes_per_node {
        // ---- parameter pickup: pinned mode first waits for the learner
        // to process every step sent so far (so this step acts on the
        // store state the inline schedule would produce), async adopts
        // whatever snapshot is newest without waiting
        if let StepSink::Learner(client) = sink {
            client.sync(agent)?;
        }

        // ---- periodic snapshot, top-of-step: after the learner sync
        // (the rollout store equals the learner's published state) and
        // before any RNG draw, so the resumed run replays this step
        // exactly
        if ctx.should_save(t, wr.t0) {
            step_save(ctx, sink, agent, (wr.wave, t), wr.done, &lanes, &rngs);
        }
        ctx.fault.probe()?; // crash site A: step boundary

        // ---- ε coins + state gather, lane-major (Algorithm 1 line 6)
        for (i, lane) in lanes.iter().enumerate() {
            decisions[i].explore = rngs[i].uniform() < lane.eps.eps;
            states[i * SAC_STATE_DIM..(i + 1) * SAC_STATE_DIM].copy_from_slice(&lane.s);
        }

        // ---- ONE batched actor forward + per-lane sampling
        let picked = agent.act_lanes(&states, &decisions, &mut rngs)?;

        // ---- per-lane MPC refinement (line 14), lane order; each call is
        // already batched over the K candidates internally
        let mut actions = Vec::with_capacity(b);
        for (i, (lane, (action, entropy))) in lanes.iter_mut().zip(picked).enumerate() {
            if let Some(e) = entropy {
                lane.last_entropy = e;
            }
            let action = if entropy.is_some() && lane.eps.eps < rl.mpc_eps_gate {
                let mpc_ctx = Some((&lane.eval, &lane.mesh));
                let refined = agent.mpc_refine(&lane.s, &action, mpc_ctx, &mut rngs[i])?;
                // drain the rerank counters this call produced into the
                // lane so per-node attribution stays exact
                lane.stats.merge(&agent.take_eval_stats());
                refined
            } else {
                action
            };
            actions.push(action);
        }

        // the best-config reproduction recipe a checkpoint stores is
        // (pre-step mesh, action) — capture the meshes before the walk
        let pre_meshes: Vec<crate::arch::MeshConfig> = lanes.iter().map(|l| l.mesh).collect();

        // ---- env transitions: pure per-lane work fanned out by index
        let actions = &actions;
        let step_lane = |i: usize, lane: &mut Lane| {
            let out = lane.cache.evaluate(&lane.eval, &lane.mesh, &actions[i], &mut lane.scratch);
            lane.mesh = out.decoded.mesh; // the walk (line 8)
            out
        };
        let outs = parallel::scoped_chunk_map_mut(&mut lanes, threads, step_lane);
        ctx.fault.probe()?; // crash site B: mid-wave, after the env fan-out
        for (s2, out) in s2s.iter_mut().zip(&outs) {
            *s2 = state::sac_subset(&out.full_state);
        }

        // ---- replay insertion in fixed lane-major order, then learning
        // amortized on the shared step counter: one SAC update per
        // vec-step (B serial runs would perform B), wm/sur at their
        // per-step cadences — run here (inline) or on the learner thread
        let step_rows = lanes.iter().zip(actions).zip(&outs).zip(&s2s).map(
            |(((lane, action), out), s2)| make_transition(lane.s, action, out, *s2),
        );
        match sink {
            StepSink::Inline { update_rng } => {
                agent.buffer.push_batch(step_rows);
                update_tick(agent, *rl, t, update_rng)?;
            }
            StepSink::Learner(client) => client.send_step(agent, t, step_rows.collect())?,
        }
        ctx.fault.probe()?; // crash site C: replay inserted / queue non-empty

        // ---- bookkeeping, lane-major
        for (i, ((lane, out), s2)) in lanes.iter_mut().zip(&outs).zip(&s2s).enumerate() {
            lane.eps.step(lane.tracker.feasible_count > 0 || out.reward.feasible);
            if lane.tracker.record(t, out, lane.eps.eps, lane.last_entropy) {
                lane.tracker.best_repro = Some((pre_meshes[i], actions[i].clone()));
            }
            lane.s = *s2;
        }
    }

    let results: Vec<NodeResult> = lanes
        .into_iter()
        .map(|lane| {
            let mut r = lane.tracker.finish(lane.nm, rl.episodes_per_node);
            // a shared cache outlives the lane — its counters are absorbed
            // once by the sweep driver, not per lane
            if let LaneCache::Local(c) = &lane.cache {
                r.eval_stats.absorb_outcome_cache(c);
            }
            r.eval_stats.absorb_scratch(&lane.scratch);
            r.eval_stats.merge(&lane.stats);
            r
        })
        .collect();
    Ok(results)
}

/// Drive an arbitrary job list through the vec-env in waves of at most
/// `lanes` concurrent lanes, sharing `agent` (and its replay/learning
/// state) across waves. Results come back in `jobs` order. With updates
/// disabled the wave grouping is unobservable — every lane is
/// self-contained — so `lanes=1` and `lanes=len(jobs)` produce
/// bit-identical per-job results (pinned by `tests/vecenv.rs`).
///
/// [`run_jobs_stats`] with the learner report discarded.
pub fn run_jobs(
    cfg: &RunConfig,
    jobs: &[LaneSpec],
    lanes: usize,
    agent: &mut SacAgent,
    threads: usize,
) -> Result<Vec<NodeResult>> {
    Ok(run_jobs_stats(cfg, jobs, lanes, agent, threads)?.0)
}

/// [`run_jobs`] plus the learner-engine counters: with
/// `learner=pinned|async` one [`LearnerClient`] spans the whole job list
/// — the learner thread, its replay buffer, the update RNG stream and
/// the ack counter all persist across wave boundaries, exactly like the
/// inline driver's update stream — and the run's [`LearnerReport`] comes
/// back alongside the results (`None` for `learner=inline`).
pub fn run_jobs_stats(
    cfg: &RunConfig,
    jobs: &[LaneSpec],
    lanes: usize,
    agent: &mut SacAgent,
    threads: usize,
) -> Result<(Vec<NodeResult>, Option<LearnerReport>)> {
    run_jobs_stats_shared(cfg, jobs, lanes, agent, threads, None)
}

/// [`run_jobs_stats`] with every lane's whole-outcome memo replaced by
/// one process-wide [`SharedEvalCache`] — the atlas sweep's warm-state
/// layer. Pass `None` to keep the default private-per-lane memos.
///
/// This is where the config's robustness keys take effect: a [`RunCtx`]
/// built from `checkpoint_every=` / `resume=` / `crash_after=` wraps the
/// wave loop (the atlas sweep instead threads its own sweep-level context
/// through [`run_jobs_ckpt`] directly).
pub fn run_jobs_stats_shared(
    cfg: &RunConfig,
    jobs: &[LaneSpec],
    lanes: usize,
    agent: &mut SacAgent,
    threads: usize,
    shared: Option<&SharedEvalCache>,
) -> Result<(Vec<NodeResult>, Option<LearnerReport>)> {
    if jobs.is_empty() {
        return Ok((Vec::new(), None));
    }
    let mut ctx = RunCtx::for_vec(cfg, jobs, lanes)?;
    run_jobs_ckpt(cfg, jobs, lanes, agent, threads, shared, &mut ctx)
}

/// The wave loop behind [`run_jobs_stats_shared`], explicit about its
/// robustness context so the atlas sweep can share one [`RunCtx`] (and
/// one cumulative fault-probe counter) across every scenario point while
/// managing its own sweep-level checkpoints.
pub(crate) fn run_jobs_ckpt(
    cfg: &RunConfig,
    jobs: &[LaneSpec],
    lanes: usize,
    agent: &mut SacAgent,
    threads: usize,
    shared: Option<&SharedEvalCache>,
    ctx: &mut RunCtx,
) -> Result<(Vec<NodeResult>, Option<LearnerReport>)> {
    if jobs.is_empty() {
        return Ok((Vec::new(), None));
    }
    let width = lanes.max(1);
    let chunks: Vec<&[LaneSpec]> = jobs.chunks(width).collect();

    // ---- resume: decode the checkpoint (restoring the rollout agent in
    // place) and position the wave/step cursor on the interrupted step
    let mut results: Vec<NodeResult> = Vec::with_capacity(jobs.len());
    let mut start_wave = 0usize;
    let mut start_step = 0usize;
    let mut lane_restore: Option<Vec<LaneCkpt>> = None;
    let mut sink_restore: Option<SinkCkpt> = None;
    if let Some(payload) = ctx.resume.take() {
        let v = checkpoint::decode_vec(&payload, cfg, agent)?;
        if v.wave >= chunks.len() {
            crate::bail!("checkpoint wave {} out of range ({} waves)", v.wave, chunks.len());
        }
        let done_expect: usize = chunks[..v.wave].iter().map(|c| c.len()).sum();
        if v.done.len() != done_expect {
            crate::bail!(
                "checkpoint carries {} completed results, wave {} expects {done_expect}",
                v.done.len(),
                v.wave
            );
        }
        start_wave = v.wave;
        start_step = v.step;
        results = v.done;
        if v.step > 0 {
            lane_restore = Some(v.lanes);
        }
        sink_restore = Some(v.sink);
    }

    if cfg.rl.learner.off_loop() {
        let learner_resume = match sink_restore {
            Some(SinkCkpt::Learner(st)) => Some(st),
            Some(SinkCkpt::Inline { .. }) => crate::bail!(
                "checkpoint was written by learner=inline; cannot resume with learner={}",
                cfg.rl.learner.name()
            ),
            None => None,
        };
        let mut client = LearnerClient::spawn(cfg, agent, width.min(jobs.len()), learner_resume)?;
        for (w, wave) in chunks.iter().enumerate() {
            if w < start_wave {
                continue;
            }
            let t0 = if w == start_wave { start_step } else { 0 };
            let restore = if w == start_wave { lane_restore.take() } else { None };
            let mut sink = StepSink::Learner(&mut client);
            let wr = WaveRun { shared, wave: w, t0, restore, done: &results };
            let wave_results = run_wave(cfg, wave, agent, threads, &mut sink, ctx, wr)?;
            results.extend(wave_results);
            // wave-boundary generation: a resume from here lands on the
            // next wave with no mid-wave lane state to rebuild
            if w + 1 < chunks.len() && ctx.sink.is_some() {
                step_save(ctx, &mut sink, agent, (w + 1, 0), &results, &[], &[]);
            }
        }
        let report = client.finish(agent)?;
        Ok((results, Some(report)))
    } else {
        // one update stream across all waves: wave boundaries must not
        // reset the learning noise sequence
        let mut update_rng = match sink_restore {
            Some(SinkCkpt::Inline { rng }) => Rng::from_state(rng),
            Some(SinkCkpt::Learner(_)) => crate::bail!(
                "checkpoint was written by an off-loop learner; cannot resume with learner=inline"
            ),
            None => Rng::new(cfg.seed).fork(UPDATE_STREAM_TAG),
        };
        for (w, wave) in chunks.iter().enumerate() {
            if w < start_wave {
                continue;
            }
            let t0 = if w == start_wave { start_step } else { 0 };
            let restore = if w == start_wave { lane_restore.take() } else { None };
            let mut sink = StepSink::Inline { update_rng: &mut update_rng };
            let wr = WaveRun { shared, wave: w, t0, restore, done: &results };
            let wave_results = run_wave(cfg, wave, agent, threads, &mut sink, ctx, wr)?;
            results.extend(wave_results);
            if w + 1 < chunks.len() && ctx.sink.is_some() {
                step_save(ctx, &mut sink, agent, (w + 1, 0), &results, &[], &[]);
            }
        }
        Ok((results, None))
    }
}

/// Cross-lane reward statistics over a vec run's episode logs, folded in
/// lane-major order with f64 accumulation throughout — independent of
/// worker count and of how jobs were grouped into waves.
pub fn reward_stats(results: &[NodeResult]) -> RunningStat {
    let mut rs = RunningStat::new();
    for r in results {
        for e in &r.episodes {
            rs.push(e.reward);
        }
    }
    rs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Granularity;
    use crate::nn::backend;

    fn tiny_cfg() -> RunConfig {
        let mut cfg = RunConfig::default();
        cfg.granularity = Granularity::Group;
        cfg.rl.episodes_per_node = 6;
        cfg.rl.warmup_steps = 10_000; // rollout only
        cfg
    }

    fn agent(cfg: &RunConfig) -> SacAgent {
        SacAgent::new(backend::native_builtin().unwrap(), cfg.rl, &mut Rng::new(42)).unwrap()
    }

    #[test]
    fn vec_run_shapes_and_order() {
        let cfg = tiny_cfg();
        let specs = [
            LaneSpec { nm: 7, seed: 1 },
            LaneSpec { nm: 28, seed: 2 },
            LaneSpec { nm: 7, seed: 3 },
        ];
        let mut ag = agent(&cfg);
        let results = run_jobs(&cfg, &specs, 3, &mut ag, 2).unwrap();
        assert_eq!(results.len(), 3);
        for (r, sp) in results.iter().zip(&specs) {
            assert_eq!(r.nm, sp.nm);
            assert_eq!(r.episodes.len(), 6);
        }
        // lane-major replay: 3 lanes × 6 steps
        assert_eq!(ag.buffer.len(), 18);
        let rs = reward_stats(&results);
        assert_eq!(rs.count(), 18);
        assert!(rs.mean().is_finite());
    }

    #[test]
    fn empty_job_list_is_ok() {
        let cfg = tiny_cfg();
        let mut ag = agent(&cfg);
        assert!(run_jobs(&cfg, &[], 4, &mut ag, 2).unwrap().is_empty());
        // learner modes included — no thread is spawned for zero jobs
        let mut cfg = tiny_cfg();
        cfg.apply("learner", "async").unwrap();
        let (r, rep) = run_jobs_stats(&cfg, &[], 4, &mut ag, 2).unwrap();
        assert!(r.is_empty() && rep.is_none());
    }

    #[test]
    fn shared_cache_preserves_lane_results() {
        // the warm-state layer must be unobservable in the results: a
        // rollout-only run against one shared memo is bit-identical to
        // the private-per-lane default, and the shared counters land in
        // the sweep-level cache, not the lanes
        let cfg = tiny_cfg();
        let specs = [LaneSpec { nm: 7, seed: 11 }, LaneSpec { nm: 22, seed: 12 }];
        let base = run_jobs(&cfg, &specs, 2, &mut agent(&cfg), 2).unwrap();
        let shared = SharedEvalCache::new(cfg.rl.eval_cache);
        let (with_shared, _) =
            run_jobs_stats_shared(&cfg, &specs, 2, &mut agent(&cfg), 2, Some(&shared))
                .unwrap();
        assert_eq!(base.len(), with_shared.len());
        for (a, b) in base.iter().zip(&with_shared) {
            assert_eq!(a.nm, b.nm);
            assert_eq!(a.episodes.len(), b.episodes.len());
            for (ea, eb) in a.episodes.iter().zip(&b.episodes) {
                assert_eq!(ea.reward.to_bits(), eb.reward.to_bits());
                assert_eq!(ea.score.to_bits(), eb.score.to_bits());
            }
            assert_eq!(a.pareto.len(), b.pareto.len());
            assert_eq!(b.eval_stats.outcome_hits + b.eval_stats.outcome_misses, 0);
        }
        let (hits, misses) = shared.counters();
        assert!(misses > 0, "shared cache saw no traffic");
        let occ = shared.occupancy();
        assert_eq!(occ.salts.len(), 2, "one salt per (node) evaluator");
        assert_eq!(occ.hits, hits);
    }

    #[test]
    fn learner_sink_keeps_shapes_and_restores_replay() {
        // warmup 10_000 over 12 transitions: the learner absorbs every
        // step but never updates — shapes, counters and the restored
        // replay buffer are what's under test here (bit-identity and
        // live-update behavior live in tests/learner.rs)
        let mut cfg = tiny_cfg();
        cfg.apply("learner", "pinned").unwrap();
        let specs =
            [LaneSpec { nm: 7, seed: 1 }, LaneSpec { nm: 28, seed: 2 }];
        let mut ag = agent(&cfg);
        let (results, report) = run_jobs_stats(&cfg, &specs, 2, &mut ag, 2).unwrap();
        assert_eq!(results.len(), 2);
        let report = report.expect("off-loop learner always reports");
        assert_eq!(report.steps, 6, "one queue message per lockstep step");
        assert_eq!(report.sac_updates, 0, "warmup gate stayed closed");
        assert_eq!(report.snapshots, 0);
        assert!(report.queue_highwater >= 2, "at least one 2-lane batch queued");
        // the learner hands its replay buffer back on finish
        assert_eq!(ag.buffer.len(), 12);
    }

    #[test]
    fn fault_probe_kills_mid_wave_and_checkpoint_resumes() {
        // crash_after lands inside a wave (3 probes per step); the resumed
        // run must reproduce the uninterrupted episode logs bit-for-bit.
        // Full-matrix coverage (learner modes, corrupt slots, randomized
        // crash points) lives in tests/checkpoint.rs.
        let dir = std::env::temp_dir()
            .join(format!("silckpt-vecenv-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut cfg = tiny_cfg();
        cfg.out_dir = dir.to_str().unwrap().to_string();
        cfg.rl.checkpoint_every = 2;
        let specs = [LaneSpec { nm: 7, seed: 1 }, LaneSpec { nm: 28, seed: 2 }];

        let mut ref_agent = agent(&cfg);
        let (reference, _) = run_jobs_stats(&cfg, &specs, 2, &mut ref_agent, 2).unwrap();

        cfg.rl.crash_after = 11; // step 3, mid-step (probe B of t=3)
        let err = run_jobs_stats(&cfg, &specs, 2, &mut agent(&cfg), 2).unwrap_err();
        assert!(err.to_string().contains(checkpoint::INJECTED_CRASH_MSG), "{err}");

        cfg.rl.crash_after = 0;
        cfg.resume = Some(cfg.out_dir.clone());
        let mut ag = agent(&cfg);
        let (resumed, _) = run_jobs_stats(&cfg, &specs, 2, &mut ag, 2).unwrap();
        assert_eq!(reference.len(), resumed.len());
        for (a, b) in reference.iter().zip(&resumed) {
            assert_eq!(a.episodes.len(), b.episodes.len());
            for (ea, eb) in a.episodes.iter().zip(&b.episodes) {
                assert_eq!(ea.reward.to_bits(), eb.reward.to_bits());
                assert_eq!(ea.score.to_bits(), eb.score.to_bits());
                assert_eq!(ea.entropy.to_bits(), eb.entropy.to_bits());
            }
            assert_eq!(a.pareto.frontier().len(), b.pareto.frontier().len());
        }
        // replay contents restored + regenerated identically
        assert_eq!(ref_agent.buffer.len(), ag.buffer.len());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
